//! Fault-plane parity: injected faults may NEVER move the paper's
//! numbers. The simulated schedule (`faults=on` stragglers/dropouts)
//! scales only the simulated network clock — iterates, objective curves
//! and every paper-unit meter stay bit-identical to the fault-free run at
//! every shard count, a zero-probability plan is bitwise invisible even
//! on the clock, and the whole schedule is a pure function of the seed.
//! The REAL fault surface (a killed shard worker) must heal at the next
//! collective boundary — supervised restart + bit-exact batch replay, or
//! elastic reassignment — with final iterates unchanged and the recovery
//! honestly counted.
//!
//! Requires `make artifacts`.

use mbprox::algos::RunResult;
use mbprox::comm::faults::FaultsPolicy;
use mbprox::comm::{netmodel::NetModel, Network};
use mbprox::config::ExperimentConfig;
use mbprox::coordinator::Runner;
use mbprox::data::Loss;
use mbprox::objective::mean_grad_chained_host;
use mbprox::runtime::{Engine, PlanePolicy, ShardPool};
use std::path::PathBuf;
use std::time::Duration;

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// Run `cfg` on a fresh sharded runner.
fn run_with(shards: usize, cfg: &ExperimentConfig) -> RunResult {
    let dir = artifacts_dir();
    let mut r = Runner::new(Engine::new(&dir).expect("run `make artifacts` before cargo test"))
        .with_plane(PlanePolicy::Sharded)
        .with_shards(ShardPool::new(shards, &dir).expect("shard pool construction"));
    r.run(cfg).unwrap_or_else(|e| panic!("{} (shards={shards}): {e:?}", cfg.method))
}

fn bits32(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// Bitwise identity on the paper-units surface: iterates, meter report,
/// curve. `and_time` additionally pins the simulated clock (true for the
/// faults-off vs zero-probability comparison, false when a live schedule
/// is allowed to slow the clock down).
fn assert_same_units(a: &RunResult, b: &RunResult, and_time: bool, label: &str) {
    assert_eq!(bits32(&a.w), bits32(&b.w), "{label}: final iterate bits");
    assert_eq!(a.report, b.report, "{label}: ClusterMeter report");
    if and_time {
        assert_eq!(a.sim_time_s.to_bits(), b.sim_time_s.to_bits(), "{label}: simulated time");
    }
    assert_eq!(a.curve.len(), b.curve.len(), "{label}: curve length");
    for (p, q) in a.curve.iter().zip(&b.curve) {
        assert_eq!(p.samples_total, q.samples_total, "{label}: curve samples");
        assert_eq!(p.comm_rounds, q.comm_rounds, "{label}: curve rounds");
        assert_eq!(p.vec_ops, q.vec_ops, "{label}: curve vec ops");
        match (p.objective, q.objective) {
            (Some(x), Some(y)) => {
                assert_eq!(x.to_bits(), y.to_bits(), "{label}: objective bits")
            }
            (None, None) => {}
            other => panic!("{label}: objective presence mismatch {other:?}"),
        }
    }
}

fn drift_cfg() -> ExperimentConfig {
    ExperimentConfig {
        method: "mp-dsvrg".into(),
        scenario: Some("drift".into()),
        loss: Loss::Squared,
        m: 4,
        b_local: 300,
        n_budget: 2400, // T = 2
        dim: 64,
        seed: 20170707,
        eval_samples: 1024,
        eval_every: 1,
        ..ExperimentConfig::default()
    }
}

/// `faults=off` and a zero-probability `faults=on` plan must be EXACTLY
/// the same run — every bit including the simulated clock — at shards
/// {1, 2, 4}. This is the exactness-of-off contract: the fault hook's
/// `f == 1.0` short-circuit returns the charge untouched, it does not
/// multiply by one.
#[test]
fn zero_probability_plan_is_bitwise_invisible() {
    let off_cfg = drift_cfg();
    let zero_cfg = ExperimentConfig {
        faults: FaultsPolicy::On,
        straggler_p: Some(0.0),
        dropout_p: Some(0.0),
        ..drift_cfg()
    };
    let reference = run_with(1, &off_cfg);
    assert!(reference.faults.is_none(), "faults=off with no recoveries reports no meter");
    for n in [1usize, 2, 4] {
        let off = run_with(n, &off_cfg);
        let zero = run_with(n, &zero_cfg);
        assert_same_units(&reference, &off, true, &format!("off shards={n}"));
        assert_same_units(&reference, &zero, true, &format!("zero-prob shards={n}"));
        let fm = zero.faults.expect("faults=on always surfaces its meter");
        assert_eq!(fm, Default::default(), "zero-probability plan must meter nothing");
    }
}

/// A live seeded schedule: paper units stay bit-identical to the
/// fault-free reference at every shard count, only the simulated clock
/// grows — and the schedule itself (meter and clock) is a pure function
/// of the seed, so it is bit-reproducible across runs AND shard counts
/// (the charge runs once per collective on the coordinator either way).
#[test]
fn seeded_faults_scale_only_the_clock_and_reproduce_bitwise() {
    let faulty = ExperimentConfig {
        faults: FaultsPolicy::On,
        straggler_p: Some(0.3),
        slowdown_alpha: Some(1.5),
        dropout_p: Some(0.1),
        dropout_rounds: Some(2),
        ..drift_cfg()
    };
    let reference = run_with(1, &drift_cfg());
    let first = run_with(1, &faulty);
    let fm = first.faults.clone().expect("faults=on surfaces the meter");
    assert!(fm.stragglers >= 1, "p=0.3 over this run must straggle: {fm:?}");
    assert!(fm.added_time_s > 0.0, "stragglers must cost simulated time: {fm:?}");
    assert!(
        first.sim_time_s > reference.sim_time_s,
        "faulted clock must exceed the fault-free clock"
    );
    for n in [1usize, 2, 4] {
        let run = run_with(n, &faulty);
        assert_same_units(&reference, &run, false, &format!("faulty shards={n} vs fault-free"));
        assert_eq!(run.faults, first.faults, "schedule must be shard-invariant (shards={n})");
        assert_eq!(
            run.sim_time_s.to_bits(),
            first.sim_time_s.to_bits(),
            "faulted clock must be bit-reproducible (shards={n})"
        );
    }
}

/// Drive the round loop by hand so a worker can be killed at a collective
/// boundary mid-run: the next draw fan hits the dead reply channel,
/// `wait_elastic` revives the worker (same lane, fresh engine) and
/// replays the batch — final iterates bit-identical to the uninterrupted
/// run, one recovery and one replay on the tally.
fn sgd_rounds(
    kill: Option<(usize, usize)>,
    reassign: Option<(usize, usize, usize)>,
) -> (Vec<u32>, (u64, u64)) {
    let dir = artifacts_dir();
    let (d, m) = (64usize, 4usize);
    let mut r = Runner::new(Engine::new(&dir).expect("engine"))
        .with_plane(PlanePolicy::Sharded)
        .with_shards(ShardPool::new(2, &dir).expect("pool"));
    let cfg = ExperimentConfig {
        method: "minibatch-sgd".into(),
        scenario: Some("drift".into()),
        loss: Loss::Squared,
        m,
        b_local: 256,
        dim: d,
        seed: 4242,
        eval_samples: 64,
        ..ExperimentConfig::default()
    };
    let mut ctx = r.context(&cfg).unwrap();
    let pool = ctx.plane.shards.expect("sharded context");
    let mut w: Vec<f32> = vec![0.0; d];
    let mut net = Network::new(m, NetModel::default());
    for t in 0..4usize {
        if let Some((round, shard)) = kill {
            if t == round {
                pool.kill_worker(shard);
            }
        }
        if let Some((round, machine, to)) = reassign {
            if t == round {
                pool.reassign_machine(machine, to).expect("reassign at a round boundary");
            }
        }
        let batches = ctx.draw_batches_grad_only(256, false).unwrap();
        let g = mean_grad_chained_host(
            ctx.plane.engine,
            ctx.plane.shards,
            Loss::Squared,
            &batches,
            &w,
            &mut net,
            &mut ctx.meter,
        )
        .unwrap();
        for (wj, gj) in w.iter_mut().zip(&g) {
            *wj -= 0.1 * *gj;
        }
    }
    (bits32(&w), pool.recovery_counts())
}

#[test]
fn killed_worker_recovers_mid_run_with_unchanged_iterates() {
    let (w_ref, counts_ref) = sgd_rounds(None, None);
    assert_eq!(counts_ref, (0, 0), "uninterrupted run recovers nothing");
    let (w_killed, counts_killed) = sgd_rounds(Some((2, 1)), None);
    assert_eq!(w_killed, w_ref, "recovery must not move a single iterate bit");
    assert_eq!(counts_killed, (1, 1), "one supervised restart, one replayed batch");
}

#[test]
fn elastic_reassignment_is_bitwise_invisible() {
    let (w_ref, _) = sgd_rounds(None, None);
    // machine 1 moves shard 1 -> shard 0 at a round boundary: its stream
    // (read-ahead folded back in draw order) migrates lane-to-lane and
    // every later fan routes it to shard 0 — bits must not notice
    let (w_moved, counts) = sgd_rounds(None, Some((2, 1, 0)));
    assert_eq!(w_moved, w_ref, "reassignment must not move a single iterate bit");
    assert_eq!(counts, (0, 0), "a planned reassignment is not a recovery");
}

/// The failure-naming and supervision surface: a wedged job's deadline
/// error and a lost job's dead-channel error both name the shard and the
/// job label; `revive` restores a killed worker; `clear_machines` heals
/// between runs and zeroes the recovery tally.
#[test]
fn lost_and_wedged_jobs_name_the_shard_and_label() {
    let dir = artifacts_dir();
    let pool = ShardPool::new(1, &dir).expect("pool");
    let slow = pool.submit_named(0, "sleepy job", |_| {
        std::thread::sleep(Duration::from_millis(300));
        Ok(())
    });
    let err = slow.wait_deadline(Duration::from_millis(5)).unwrap_err().to_string();
    assert!(err.contains("sleepy job"), "{err}");
    assert!(err.contains("shard worker 0"), "{err}");

    pool.kill_worker(0);
    let err = pool
        .submit_named(0, "orphaned job", |_| Ok(()))
        .wait()
        .unwrap_err()
        .to_string();
    assert!(err.contains("orphaned job"), "{err}");
    assert!(err.contains("shard worker 0"), "{err}");

    // the failed wait above proves the worker loop exited, so the probe
    // inside revive is definitive: this must be a real restart
    assert!(pool.revive(0).expect("supervised restart"), "dead worker must restart");
    assert_eq!(pool.recovery_counts(), (1, 0));
    pool.submit_named(0, "post-revival job", |_| Ok(())).wait().expect("revived worker serves");

    pool.clear_machines().expect("between-run heal");
    assert_eq!(pool.recovery_counts(), (0, 0), "clear_machines zeroes the tally");
}
