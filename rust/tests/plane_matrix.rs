//! The plane matrix: every registered method × plane ∈ {host, chained,
//! sharded} through the public `Runner` API, pinning the execution-plane
//! contract (see `runtime::plane`):
//!
//! - **chained ≡ sharded, bit for bit** — identical iterate bits,
//!   objective-curve bits, ClusterMeter reports and simulated time. The
//!   sharded plane runs the same chained kernels per machine with
//!   fixed-order f64 host collectives, which are bit-identical to the
//!   device reduce.
//! - **host ≡ chained in paper units** — the host plane runs the legacy
//!   per-block kernels, so iterates agree numerically (not bitwise), but
//!   samples/memory accounting is identical, and rounds/vec-ops are
//!   identical for every method whose iteration count is
//!   data-independent (the CG-based solvers may stop at a different
//!   iteration under f64-vs-f32 dot products, so only their sample and
//!   memory charges are pinned).
//!
//! This subsumes the per-solver `force_legacy` toggles the plane API
//! replaced. Requires `make artifacts`.

use mbprox::algos::RunResult;
use mbprox::config::ExperimentConfig;
use mbprox::coordinator::{Runner, METHODS};
use mbprox::data::Loss;
use mbprox::runtime::{Engine, PlanePolicy, ShardPool};
use mbprox::util::testkit::assert_close;
use std::path::PathBuf;

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// Run `cfg` on a fresh engine under an explicit plane policy.
fn run_plane(policy: PlanePolicy, cfg: &ExperimentConfig) -> RunResult {
    let dir = artifacts_dir();
    let mut r = Runner::new(Engine::new(&dir).expect("run `make artifacts` before cargo test"))
        .with_plane(policy);
    if policy == PlanePolicy::Sharded {
        r = r.with_shards(ShardPool::new(2, &dir).expect("shard pool construction"));
    }
    r.run(cfg).unwrap_or_else(|e| panic!("{} (plane={}): {e:?}", cfg.method, policy.as_str()))
}

fn bits32(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// Full bitwise identity: iterates, curves, meters, simulated time.
fn assert_identical(a: &RunResult, b: &RunResult, label: &str) {
    assert_eq!(bits32(&a.w), bits32(&b.w), "{label}: final iterate bits");
    assert_eq!(a.report, b.report, "{label}: ClusterMeter report");
    assert_eq!(a.sim_time_s.to_bits(), b.sim_time_s.to_bits(), "{label}: simulated time");
    assert_eq!(a.curve.len(), b.curve.len(), "{label}: curve length");
    for (p, q) in a.curve.iter().zip(&b.curve) {
        assert_eq!(p.outer_iter, q.outer_iter, "{label}: curve iters");
        assert_eq!(p.samples_total, q.samples_total, "{label}: curve samples");
        assert_eq!(p.comm_rounds, q.comm_rounds, "{label}: curve rounds");
        assert_eq!(p.vec_ops, q.vec_ops, "{label}: curve vec ops");
        match (p.objective, q.objective) {
            (Some(x), Some(y)) => {
                assert_eq!(x.to_bits(), y.to_bits(), "{label}: objective bits")
            }
            (None, None) => {}
            other => panic!("{label}: objective presence mismatch {other:?}"),
        }
    }
    match (a.final_objective, b.final_objective) {
        (Some(x), Some(y)) => assert_eq!(x.to_bits(), y.to_bits(), "{label}: final objective"),
        (None, None) => {}
        other => panic!("{label}: final objective mismatch {other:?}"),
    }
}

/// Paper-units identity + numerical agreement (host vs chained). The CG
/// solvers may stop at a different iteration (f64 vs f32 residual dots),
/// so their round/vec-op counts are not pinned.
fn assert_equivalent(host: &RunResult, chained: &RunResult, pin_rounds: bool, label: &str) {
    assert_eq!(
        host.report.total_samples, chained.report.total_samples,
        "{label}: samples are draw-determined, not lane-determined"
    );
    assert_eq!(
        host.report.peak_vectors, chained.report.peak_vectors,
        "{label}: memory charges are plane-independent"
    );
    if pin_rounds {
        assert_eq!(host.report.comm_rounds, chained.report.comm_rounds, "{label}: rounds");
        assert_eq!(host.report.vec_ops, chained.report.vec_ops, "{label}: vec ops");
        assert_eq!(
            host.sim_time_s.to_bits(),
            chained.sim_time_s.to_bits(),
            "{label}: identical rounds/dims give identical simulated time"
        );
    }
    assert_close(&host.w, &chained.w, 2e-2, 2e-3);
    match (host.final_objective, chained.final_objective) {
        (Some(x), Some(y)) => {
            let rel = (x - y).abs() / y.abs().max(1e-9);
            assert!(rel < 2e-2, "{label}: final objective {x} vs {y} (rel {rel:.2e})");
        }
        (None, None) => {}
        other => panic!("{label}: final objective mismatch {other:?}"),
    }
}

fn matrix(method: &str, loss: Loss) {
    let cfg = ExperimentConfig {
        method: method.into(),
        loss,
        m: 4,
        b_local: 256,
        n_budget: 2048, // T = 2 outer steps for the minibatch-prox family
        dim: 64,
        seed: 20170707,
        eval_samples: 512,
        eval_every: 1,
        ..ExperimentConfig::default()
    };
    let host = run_plane(PlanePolicy::Host, &cfg);
    let chained = run_plane(PlanePolicy::Chained, &cfg);
    let sharded = run_plane(PlanePolicy::Sharded, &cfg);
    let tag = format!("{method}[{}]", loss.tag());
    assert_identical(&chained, &sharded, &format!("{tag} chained-vs-sharded"));
    // CG iteration counts are residual-dependent, hence lane-dependent
    let pin_rounds = !matches!(method, "mp-exact" | "disco-erm");
    assert_equivalent(&host, &chained, pin_rounds, &format!("{tag} host-vs-chained"));
}

#[test]
fn every_method_runs_on_every_plane_squared() {
    for method in METHODS {
        matrix(method, Loss::Squared);
    }
}

#[test]
fn dsvrg_plane_matrix_logistic() {
    // the logistic chained kernels across all three planes
    matrix("mp-dsvrg", Loss::Logistic);
}

#[test]
fn plane_config_key_is_honored() {
    // plane=chained with a pool attached must error loudly, not fall back
    let dir = artifacts_dir();
    let mut r = Runner::new(Engine::new(&dir).expect("engine"))
        .with_shards(ShardPool::new(1, &dir).expect("pool"));
    let cfg = ExperimentConfig {
        method: "minibatch-sgd".into(),
        n_budget: 512,
        b_local: 64,
        eval_samples: 128,
        plane: PlanePolicy::Chained,
        ..ExperimentConfig::default()
    };
    assert!(r.run(&cfg).is_err(), "plane=chained over a shard pool must be rejected");
    // plane=sharded without SHARDS self-attaches a one-worker pool
    let mut r = Runner::new(Engine::new(&dir).expect("engine"));
    let cfg = ExperimentConfig { plane: PlanePolicy::Sharded, ..cfg };
    let res = r.run(&cfg).expect("plane=sharded attaches its own pool");
    assert!(res.final_objective.is_some());
    assert!(r.shards.is_some(), "the self-attached pool persists on the runner");
    // ...but it must not leak into later runs' plane resolution: auto
    // still resolves chained and plane=chained is still legal on the
    // same runner (the user never set SHARDS)
    let cfg_chained = ExperimentConfig { plane: PlanePolicy::Chained, ..cfg.clone() };
    r.run(&cfg_chained).expect("self-attached pool must not block plane=chained");
    let cfg_auto = ExperimentConfig { plane: PlanePolicy::Auto, ..cfg };
    r.run(&cfg_auto).expect("auto after a self-attached sharded run");
}
