//! The device-resident pipeline end to end: a chained MP-DSVRG round must
//! keep every intermediate vector on device (the acceptance criterion:
//! NO full-vector downloads between evaluation checkpoints — the one
//! round-boundary materialize is the entire downlink), while reproducing
//! the host plane (legacy per-block kernels, selected via
//! `ExecPlane::host` — the `plane=host` policy) to 1e-4. Requires
//! `make artifacts`.

use mbprox::accounting::{ClusterMeter, DeviceTraffic};
use mbprox::algos::solvers::dsvrg::DsvrgSolver;
use mbprox::algos::solvers::exact_cg::ExactCgSolver;
use mbprox::algos::solvers::ProxSolver;
use mbprox::algos::{PackMode, RunContext};
use mbprox::comm::{netmodel::NetModel, Network};
use mbprox::data::synth::{SynthSpec, SynthStream};
use mbprox::data::{Loss, MachineStreams, SampleStream};
use mbprox::objective::MachineBatch;
use mbprox::runtime::{Engine, ExecPlane};
use mbprox::util::testkit::assert_close;

fn engine() -> Engine {
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    Engine::new(&dir).expect("run `make artifacts` before cargo test")
}

/// A context over pre-drawn machine batches (streams unused by solvers)
/// on an explicit plane.
fn ctx_on(plane: ExecPlane<'_>, m: usize, loss: Loss, d: usize) -> RunContext<'_> {
    let root = match loss {
        Loss::Squared => SynthStream::new(SynthSpec::least_squares(d), 7),
        Loss::Logistic => SynthStream::new(SynthSpec::logistic(d), 7),
    };
    let streams: Vec<Box<dyn SampleStream>> =
        (0..m).map(|i| Box::new(root.fork_stream(i as u64)) as Box<dyn SampleStream>).collect();
    RunContext {
        plane,
        net: Network::new(m, NetModel::default()),
        meter: ClusterMeter::new(m),
        loss,
        d,
        streams: MachineStreams::Local(streams),
        evaluator: None,
        eval_every: 0,
    }
}

fn ctx_chained(engine: &mut Engine, m: usize, loss: Loss, d: usize) -> RunContext<'_> {
    ctx_on(ExecPlane::chained(engine), m, loss, d)
}

fn ctx_host(engine: &mut Engine, m: usize, loss: Loss, d: usize) -> RunContext<'_> {
    ctx_on(ExecPlane::host(engine), m, loss, d)
}

fn draw_batches(ctx: &mut RunContext, n_per_machine: usize, retain: bool) -> Vec<MachineBatch> {
    if retain {
        ctx.draw_batches(n_per_machine, false).unwrap()
    } else {
        ctx.draw_batches_grad_only(n_per_machine, false).unwrap()
    }
}

#[test]
fn mp_dsvrg_round_performs_no_full_vector_downloads() {
    let mut e = engine();
    let d = 64;
    let m = 4;
    let mut ctx = ctx_chained(&mut e, m, Loss::Squared, d);
    assert!(
        ctx.plane.engine.chain_grad_ready("sq", d)
            && ctx.plane.engine.chain_vr_ready("sq", d)
            && ctx.plane.engine.red_ready(m, d),
        "manifest must carry the chained artifacts"
    );
    // ragged batches: 5 blocks/machine under (8,4) widths -> one k=4
    // fused group + one k=1 tail per machine
    let batches = draw_batches(&mut ctx, 4 * 256 + 200, false);
    let wprev = vec![0.01f32; d];

    let mut solver = DsvrgSolver::new(6, 2, 0.05);
    let before = DeviceTraffic::from_stats(&ctx.plane.engine.stats);
    let z = solver.solve(&mut ctx, &batches, &wprev, 0.5, 1).unwrap();
    let traffic = DeviceTraffic::from_stats(&ctx.plane.engine.stats).since(&before);

    assert_eq!(z.len(), d);
    // the acceptance criterion, metered by DeviceTraffic: across K=6
    // inner iterations (12 comm rounds), the ONLY device->host transfer
    // is the round-boundary materialize of the final iterate
    assert_eq!(traffic.downloads, 1, "one materialize per solve, got {traffic:?}");
    assert_eq!(
        traffic.download_bytes,
        (d * std::mem::size_of::<f32>()) as u64,
        "downlink must be exactly one d-vector"
    );
    assert!(traffic.chained > 0, "the round must ride the chain verb");
    // paper-units accounting is untouched by the plane change: 2 rounds
    // per inner iteration exactly as the host plane charges
    assert_eq!(ctx.meter.report().comm_rounds, 2 * 6);
}

#[test]
fn chained_dsvrg_matches_host_per_block_plane() {
    let mut e = engine();
    let d = 64;
    let m = 2;
    // p=1 sweeps the whole batch per iteration; p=3 exercises the
    // VR-aligned packing (groups tile the 3-way block partition, so the
    // chained sweep sizes equal the per-block partition's)
    for (loss, p) in
        [(Loss::Squared, 1), (Loss::Logistic, 1), (Loss::Squared, 3), (Loss::Logistic, 3)]
    {
        let wprev: Vec<f32> = (0..d).map(|j| ((j % 5) as f32 - 2.0) * 0.02).collect();
        let n_per = 5 * 256 + 100; // 6 blocks/machine

        let (z_chained, rounds_chained, ops_chained) = {
            let mut ctx = ctx_chained(&mut e, m, loss, d);
            let mut chained = DsvrgSolver::new(4, p, 0.05);
            // the chained plane packs VR-aligned fused groups — no host
            // block retention
            assert_eq!(chained.pack_mode(&ctx), PackMode::VrAligned(p));
            let batches = ctx.draw_batches_vr_aligned(n_per, false, p).unwrap();
            let z = chained.solve(&mut ctx, &batches, &wprev, 0.5, 1).unwrap();
            let rep = ctx.meter.report();
            (z, rep.comm_rounds, rep.vec_ops)
        };

        // identical streams -> identical batches for the host-plane run
        let (z_host, rounds_host, ops_host) = {
            let mut ctx = ctx_host(&mut e, m, loss, d);
            let batches = draw_batches(&mut ctx, n_per, true);
            let mut host = DsvrgSolver::new(4, p, 0.05);
            // the host plane sweeps per block and needs the host copies
            assert_eq!(host.pack_mode(&ctx), PackMode::Full);
            let z = host.solve(&mut ctx, &batches, &wprev, 0.5, 1).unwrap();
            let rep = ctx.meter.report();
            (z, rep.comm_rounds, rep.vec_ops)
        };

        assert_close(&z_chained, &z_host, 1e-4, 1e-4);
        assert_eq!(rounds_chained, rounds_host, "identical comm accounting (p={p})");
        assert_eq!(ops_chained, ops_host, "identical sweep granularity (p={p})");
    }
}

#[test]
fn vr_aligned_groups_tile_the_legacy_block_partition() {
    let mut e = engine();
    let d = 64;
    let mut ctx = ctx_chained(&mut e, 1, Loss::Squared, d);
    // 10 blocks; p=3 -> block partition [0..4, 4..7, 7..10]
    let batches = ctx.draw_batches_vr_aligned(9 * 256 + 50, false, 3).unwrap();
    let b = &batches[0];
    assert_eq!(b.n_blocks(), 10);
    let granges = b.group_ranges(3);
    assert_eq!(granges.len(), 3);
    // every group lives inside one partition segment; the per-range
    // block totals match shard_ranges(10, 3) = 4/3/3 exactly
    let block_ranges = mbprox::data::sampler::shard_ranges(10, 3);
    let mut block_cursor = 0usize;
    for (gr, br) in granges.iter().zip(&block_ranges) {
        let blocks_in_range: usize = b.groups[gr.clone()].iter().map(|g| g.k).sum();
        assert_eq!(blocks_in_range, br.len(), "group range must tile its block partition");
        block_cursor += blocks_in_range;
    }
    assert_eq!(block_cursor, 10, "partitions must cover every block");
    // group ranges partition 0..groups.len()
    assert_eq!(granges[0].start, 0);
    assert_eq!(granges.last().unwrap().end, b.groups.len());
    // fusion still happens inside segments: the 4-block segment rides k=4
    assert_eq!(b.groups[0].k, 4, "aligned packing fuses within segments");
}

#[test]
fn chained_cg_matches_host_plane() {
    let mut e = engine();
    let d = 64;
    let m = 2;
    let wprev: Vec<f32> = (0..d).map(|j| (j as f32 * 0.02).sin() * 0.1).collect();

    let x_chained = {
        let mut ctx = ctx_chained(&mut e, m, Loss::Squared, d);
        let batches = draw_batches(&mut ctx, 256 + 60, false);
        let before = DeviceTraffic::from_stats(&ctx.plane.engine.stats);
        let mut chained = ExactCgSolver::default();
        let x = chained.solve(&mut ctx, &batches, &wprev, 0.5, 1).unwrap();
        let traffic = DeviceTraffic::from_stats(&ctx.plane.engine.stats).since(&before);
        // steady-state downlink is O(1) small values: the vdot scalars (4
        // bytes each) plus the single final materialize
        let scalar_downloads = traffic.downloads - 1;
        assert_eq!(
            traffic.download_bytes as usize,
            d * std::mem::size_of::<f32>()
                + scalar_downloads as usize * std::mem::size_of::<f32>(),
            "CG downlink must be one vector + scalars only: {traffic:?}"
        );
        x
    };

    let mut ctx = ctx_host(&mut e, m, Loss::Squared, d);
    let batches = draw_batches(&mut ctx, 256 + 60, false);
    let mut host = ExactCgSolver::default();
    let x_host = host.solve(&mut ctx, &batches, &wprev, 0.5, 1).unwrap();

    // the two CG lanes run the same recurrence with f32-vs-f64 dot
    // products: both converge to the same regularized solution
    assert_close(&x_chained, &x_host, 1e-3, 1e-3);
}

#[test]
fn chained_solver_skips_host_block_retention() {
    // a chained-plane pack_mode never asks for host blocks; the chained
    // sweep must then run WITHOUT materializing vr_lits
    let mut e = engine();
    let d = 64;
    let mut ctx = ctx_chained(&mut e, 2, Loss::Squared, d);
    let batches = draw_batches(&mut ctx, 2 * 256, false); // grad-only pack
    let wprev = vec![0.0f32; d];
    let mut solver = DsvrgSolver::new(2, 1, 0.05);
    // would error with "packed grad-only" if the host-lane sweep ran
    let z = solver.solve(&mut ctx, &batches, &wprev, 0.5, 1).unwrap();
    assert_eq!(z.len(), d);
    for b in &batches {
        assert!(b.vr_lits(ctx.plane.engine).is_err(), "vr_lits must never materialize");
    }
}
