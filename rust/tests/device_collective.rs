//! DeviceCollective parity: the on-device reduce must be BIT-identical to
//! the host collective on the downloaded result AND produce the identical
//! `CommStats`/`ClusterMeter` accounting — the property that keeps the
//! paper's Table-1 counts authoritative no matter which plane the bytes
//! moved on. Requires `make artifacts`.

use mbprox::accounting::ClusterMeter;
use mbprox::comm::{netmodel::NetModel, Network};
use mbprox::runtime::{DeviceVec, Engine};
use mbprox::util::testkit::{forall, normal_vec};

fn engine() -> Engine {
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    Engine::new(&dir).expect("run `make artifacts` before cargo test")
}

fn upload_all(e: &mut Engine, locals: &[Vec<f32>]) -> Vec<DeviceVec> {
    locals.iter().map(|v| e.upload_dev(v, &[v.len()]).unwrap()).collect()
}

fn assert_bitwise(host: &[f32], dev: &[f32], what: &str) {
    assert_eq!(host.len(), dev.len(), "{what}: length");
    for (i, (h, d)) in host.iter().zip(dev).enumerate() {
        assert_eq!(
            h.to_bits(),
            d.to_bits(),
            "{what}: element {i} differs: host {h} ({:#010x}) vs device {d} ({:#010x})",
            h.to_bits(),
            d.to_bits()
        );
    }
}

#[test]
fn prop_device_avg_bitwise_matches_host_collective() {
    let mut e = engine();
    forall(24, |rng| {
        let m = [2usize, 4, 8][rng.next_below(3)];
        let d = [64usize, 128][rng.next_below(2)];
        let locals: Vec<Vec<f32>> = (0..m).map(|_| normal_vec(rng, d)).collect();

        // host path
        let mut host_net = Network::new(m, NetModel::default());
        let mut host_meter = ClusterMeter::new(m);
        let mut host_locals = locals.clone();
        host_net.all_reduce_avg(&mut host_meter, &mut host_locals);

        // device path
        let mut dev_net = Network::new(m, NetModel::default());
        let mut dev_meter = ClusterMeter::new(m);
        let handles = upload_all(&mut e, &locals);
        let out = dev_net
            .device_all_reduce_avg(&mut dev_meter, &mut e, &handles)
            .expect("device all-reduce");
        let dev_result = e.materialize(&out).unwrap();

        assert_bitwise(&host_locals[0], &dev_result, "all_reduce_avg");
        // identical comm accounting, field for field
        assert_eq!(host_net.stats.rounds, dev_net.stats.rounds);
        assert_eq!(host_net.stats.vectors_moved, dev_net.stats.vectors_moved);
        assert_eq!(host_net.stats.sim_time_s, dev_net.stats.sim_time_s);
        assert_eq!(host_meter.report(), dev_meter.report());
    });
}

#[test]
fn prop_device_weighted_bitwise_matches_host_collective() {
    let mut e = engine();
    forall(24, |rng| {
        let m = [2usize, 4, 8][rng.next_below(3)];
        let d = [64usize, 128][rng.next_below(2)];
        let locals: Vec<Vec<f32>> = (0..m).map(|_| normal_vec(rng, d)).collect();
        // integer-valued weights (batch counts) — exactly representable
        // in f32, which is what the device plane carries
        let weights: Vec<f64> = (0..m).map(|_| (1 + rng.next_below(1 << 20)) as f64).collect();

        let mut host_net = Network::new(m, NetModel::default());
        let mut host_meter = ClusterMeter::new(m);
        let mut host_locals = locals.clone();
        host_net.all_reduce_weighted(&mut host_meter, &weights, &mut host_locals);

        let mut dev_net = Network::new(m, NetModel::default());
        let mut dev_meter = ClusterMeter::new(m);
        let handles = upload_all(&mut e, &locals);
        let out = dev_net
            .device_all_reduce_weighted(&mut dev_meter, &mut e, &weights, &handles)
            .expect("device weighted all-reduce");
        let dev_result = e.materialize(&out).unwrap();

        assert_bitwise(&host_locals[0], &dev_result, "all_reduce_weighted");
        assert_eq!(host_net.stats.rounds, dev_net.stats.rounds);
        assert_eq!(host_net.stats.vectors_moved, dev_net.stats.vectors_moved);
        assert_eq!(host_net.stats.sim_time_s, dev_net.stats.sim_time_s);
        assert_eq!(host_meter.report(), dev_meter.report());
    });
}

#[test]
fn device_reduce_stays_on_device_until_materialize() {
    let mut e = engine();
    let m = 4;
    let d = 64;
    let locals: Vec<Vec<f32>> = (0..m).map(|i| vec![i as f32 * 0.5; d]).collect();
    let handles = upload_all(&mut e, &locals);
    let mut net = Network::new(m, NetModel::default());
    let mut meter = ClusterMeter::new(m);
    let before = e.stats.downloads;
    let out = net.device_all_reduce_avg(&mut meter, &mut e, &handles).unwrap();
    assert_eq!(e.stats.downloads, before, "the reduce itself must download nothing");
    let _ = e.materialize(&out).unwrap();
    assert_eq!(e.stats.downloads, before + 1, "materialize is the only download");
}

#[test]
fn fallback_cluster_sizes_charge_identical_rounds() {
    // m = 3 has no redm artifact: the device path must fall back to the
    // host collective yet charge the identical round accounting
    let mut e = engine();
    let m = 3;
    let d = 64;
    let locals: Vec<Vec<f32>> = (0..m).map(|i| vec![(i + 1) as f32; d]).collect();

    let mut host_net = Network::new(m, NetModel::default());
    let mut host_meter = ClusterMeter::new(m);
    let mut host_locals = locals.clone();
    host_net.all_reduce_avg(&mut host_meter, &mut host_locals);

    let mut dev_net = Network::new(m, NetModel::default());
    let mut dev_meter = ClusterMeter::new(m);
    let handles = upload_all(&mut e, &locals);
    let out = dev_net.device_all_reduce_avg(&mut dev_meter, &mut e, &handles).unwrap();
    let dev_result = e.materialize(&out).unwrap();

    assert_bitwise(&host_locals[0], &dev_result, "fallback all_reduce_avg");
    assert_eq!(host_net.stats.rounds, dev_net.stats.rounds);
    assert_eq!(host_net.stats.sim_time_s, dev_net.stats.sim_time_s);
    assert_eq!(host_meter.report(), dev_meter.report());
}

#[test]
fn device_broadcast_charges_like_host_broadcast() {
    let mut e = engine();
    let m = 4;
    let d = 64;
    let v: Vec<f32> = (0..d).map(|j| j as f32 * 0.01).collect();

    let mut host_net = Network::new(m, NetModel::default());
    let mut host_meter = ClusterMeter::new(m);
    let mut host_locals: Vec<Vec<f32>> = (0..m).map(|_| v.clone()).collect();
    host_net.broadcast(&mut host_meter, 1, &mut host_locals);

    let mut dev_net = Network::new(m, NetModel::default());
    let mut dev_meter = ClusterMeter::new(m);
    let h = e.upload_dev(&v, &[d]).unwrap();
    let out = dev_net.device_broadcast(&mut dev_meter, 1, &h);
    assert!(out.same_buffer(&h), "simulated broadcast is a handle clone");

    assert_eq!(host_net.stats.rounds, dev_net.stats.rounds);
    assert_eq!(host_net.stats.vectors_moved, dev_net.stats.vectors_moved);
    assert_eq!(host_net.stats.sim_time_s, dev_net.stats.sim_time_s);
    assert_eq!(host_meter.report(), dev_meter.report());
}
