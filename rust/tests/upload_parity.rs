//! Upload-lane parity: `upload=on` (staging rings) vs `upload=off`
//! (single-slot session pool) is a pure staging-structure change inside
//! each engine. The ring path decides whether to transfer by comparing a
//! pooled operand against the payload LAST DISPATCHED — never against
//! the back half's stale bytes — so it performs the exact transfer
//! sequence the slot path would: same uploads, same bytes, same cache
//! hits, and a steady-state constant operand still costs zero traffic.
//! Iterates, objective curves, sample/memory meters, simulated time, AND
//! the transfer counts/bytes of the upload meter are therefore
//! bit-identical across {upload on/off} × {host, chained, sharded}
//! planes × shard counts; only the wall-clock magnitudes
//! (`overlap_ns`/`wait_ns`) and the staging split (`staged`) may differ
//! (see the `runtime` module doc, "The upload lane").
//!
//! Requires `make artifacts`.

use mbprox::accounting::{ClusterMeter, UploadMeter};
use mbprox::algos::RunResult;
use mbprox::comm::{netmodel::NetModel, Network};
use mbprox::config::ExperimentConfig;
use mbprox::coordinator::Runner;
use mbprox::data::synth::{SynthSpec, SynthStream};
use mbprox::data::Loss;
use mbprox::objective::{distributed_mean_grad, MachineBatch};
use mbprox::runtime::{Engine, PlanePolicy, ShardPool, UploadPolicy};
use std::path::PathBuf;

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// Run `cfg` on a fresh runner under an explicit upload policy on one of
/// the three planes (`shards: None` = no pool attached — the host and
/// chained planes).
fn run_with(
    upload: UploadPolicy,
    plane: PlanePolicy,
    shards: Option<usize>,
    cfg: &ExperimentConfig,
) -> RunResult {
    let dir = artifacts_dir();
    let mut r = Runner::new(Engine::new(&dir).expect("run `make artifacts` before cargo test"))
        .with_plane(plane)
        .with_upload(upload);
    if let Some(n) = shards {
        r = r.with_shards(ShardPool::new(n, &dir).expect("shard pool construction"));
    }
    r.run(cfg).unwrap_or_else(|e| {
        panic!(
            "{} (upload={}, plane={}, shards={shards:?}): {e:?}",
            cfg.method,
            upload.as_str(),
            plane.as_str()
        )
    })
}

fn bits32(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// Full bitwise identity on everything except the wall-clock meters.
fn assert_identical(a: &RunResult, b: &RunResult, label: &str) {
    assert_eq!(bits32(&a.w), bits32(&b.w), "{label}: final iterate bits");
    assert_eq!(a.report, b.report, "{label}: ClusterMeter report");
    assert_eq!(a.sim_time_s.to_bits(), b.sim_time_s.to_bits(), "{label}: simulated time");
    assert_eq!(a.curve.len(), b.curve.len(), "{label}: curve length");
    for (p, q) in a.curve.iter().zip(&b.curve) {
        assert_eq!(p.samples_total, q.samples_total, "{label}: curve samples");
        assert_eq!(p.comm_rounds, q.comm_rounds, "{label}: curve rounds");
        assert_eq!(p.vec_ops, q.vec_ops, "{label}: curve vec ops");
        match (p.objective, q.objective) {
            (Some(x), Some(y)) => {
                assert_eq!(x.to_bits(), y.to_bits(), "{label}: objective bits")
            }
            (None, None) => {}
            other => panic!("{label}: objective presence mismatch {other:?}"),
        }
    }
}

/// The upload meter is present on every plane — the coordinator engine
/// meters even without a pool.
fn meter<'r>(run: &'r RunResult, label: &str) -> &'r UploadMeter {
    run.uploads.as_ref().unwrap_or_else(|| panic!("{label}: upload meter missing"))
}

/// The meter half of the parity surface: transfer counts and bytes are
/// bit-identical with the lane on or off, the lane-off run never stages
/// (and so banks no overlappable time), and with the lane on every
/// metered transfer runs through the rings.
fn assert_meter_parity(off: &RunResult, on: &RunResult, label: &str) {
    let (u_off, u_on) = (meter(off, label), meter(on, label));
    assert_eq!(u_on.uploads, u_off.uploads, "{label}: upload counts are parity surface");
    assert_eq!(u_on.bytes, u_off.bytes, "{label}: upload bytes are parity surface");
    assert_eq!(u_off.staged, 0, "{label}: upload=off must never stage: {u_off:?}");
    assert_eq!(u_off.overlap_ns, 0, "{label}: upload=off banks no overlap: {u_off:?}");
    assert_eq!(u_on.staged, u_on.uploads, "{label}: lane-on transfers all stage: {u_on:?}");
}

/// Every plane × shard-count leg: `upload=on` must match `upload=off`
/// bit for bit on the paper-units surface, and the meters must agree on
/// transfer counts and bytes.
fn upload_parity(cfg: &ExperimentConfig) {
    let legs: [(PlanePolicy, Option<usize>); 5] = [
        (PlanePolicy::Host, None),
        (PlanePolicy::Chained, None),
        (PlanePolicy::Sharded, Some(1)),
        (PlanePolicy::Sharded, Some(2)),
        (PlanePolicy::Sharded, Some(4)),
    ];
    for (plane, shards) in legs {
        let off = run_with(UploadPolicy::Off, plane, shards, cfg);
        let on = run_with(UploadPolicy::On, plane, shards, cfg);
        let label = format!("{} plane={} shards={shards:?}", cfg.method, plane.as_str());
        assert_identical(&off, &on, &label);
        assert_meter_parity(&off, &on, &label);
        if plane == PlanePolicy::Sharded {
            // non-vacuous: the shard fans pool the iterate every round
            let u = meter(&on, &label);
            assert!(u.uploads > 0, "{label}: sharded run metered no uploads: {u:?}");
        }
    }
}

#[test]
fn streaming_drift_upload_parity() {
    // b = 300 -> one full block + a 44-row ragged tail per machine draw;
    // with m=4 over <= 4 shards every worker owns >= 1 machine
    let cfg = ExperimentConfig {
        method: "mp-dsvrg".into(),
        scenario: Some("drift".into()),
        loss: Loss::Squared,
        m: 4,
        b_local: 300,
        n_budget: 2400, // T = 2
        dim: 64,
        seed: 20170707,
        eval_samples: 1024,
        eval_every: 1,
        ..ExperimentConfig::default()
    };
    upload_parity(&cfg);
}

#[test]
fn erm_fixed_cfg_key_beats_process_policy() {
    // 2051 fixed samples shard 513/513/513/512 across epoch-bounded
    // streams — the ragged boundary draws must stage identically
    let cfg = ExperimentConfig {
        method: "dsvrg-erm".into(),
        scenario: Some("erm-fixed".into()),
        loss: Loss::Squared,
        m: 4,
        b_local: 256,
        n_budget: 2051,
        dim: 64,
        seed: 20170707,
        eval_samples: 1024,
        eval_every: 1,
        // the config-key path (rather than Runner::with_upload): the
        // per-run key must beat the runner's process-level policy
        upload: UploadPolicy::On,
        ..ExperimentConfig::default()
    };
    let via_cfg = {
        let dir = artifacts_dir();
        let mut r = Runner::new(Engine::new(&dir).expect("engine"))
            .with_plane(PlanePolicy::Sharded)
            .with_shards(ShardPool::new(2, &dir).expect("pool"))
            .with_upload(UploadPolicy::Off); // cfg key must win
        r.run(&cfg).expect("erm-fixed with upload=on from the config")
    };
    // the cfg-key run really rode the rings: its meter staged transfers
    let u = meter(&via_cfg, "erm-fixed cfg-key");
    assert!(u.staged > 0, "cfg-key upload=on run never staged a transfer: {u:?}");
    // ...and stayed on the parity surface vs a plain lane-off run
    let cfg_default = ExperimentConfig { upload: UploadPolicy::Auto, ..cfg.clone() };
    let off = run_with(UploadPolicy::Off, PlanePolicy::Sharded, Some(2), &cfg_default);
    assert_identical(&off, &via_cfg, "erm-fixed cfg-key upload=on");
    assert_meter_parity(&off, &via_cfg, "erm-fixed cfg-key upload=on");
    upload_parity(&cfg_default);
}

/// The upload meter itself: surfaced on every plane, honest about the
/// policy that ran, and never part of the paper-units cost model.
#[test]
fn upload_meter_reports_the_policy_that_ran() {
    let cfg = ExperimentConfig {
        method: "minibatch-sgd".into(),
        scenario: Some("drift".into()),
        loss: Loss::Squared,
        m: 4,
        b_local: 256,
        n_budget: 4096, // 4 outer steps of drawing
        dim: 64,
        seed: 11,
        eval_samples: 64,
        eval_every: 0,
        ..ExperimentConfig::default()
    };
    let off = run_with(UploadPolicy::Off, PlanePolicy::Sharded, Some(2), &cfg);
    let u_off = meter(&off, "sharded upload=off");
    assert!(u_off.uploads > 0, "pooled iterates must upload regardless of policy: {u_off:?}");
    assert_eq!(u_off.staged, 0, "upload=off never stages");
    assert_eq!(u_off.overlap_ns, 0, "upload=off banks no overlappable time");
    assert_eq!(u_off.wait_ns, 0, "upload=off never waits on a stage");

    let on = run_with(UploadPolicy::On, PlanePolicy::Sharded, Some(2), &cfg);
    let u_on = meter(&on, "sharded upload=on");
    assert_eq!(u_on.uploads, u_off.uploads, "transfer counts must not depend on the lane");
    assert_eq!(u_on.bytes, u_off.bytes, "transfer bytes must not depend on the lane");
    assert!(u_on.staged > 0, "upload=on staged no transfers: {u_on:?}");
    // sync CPU PJRT: every stage runs inline and is wall-clock timed
    assert!(u_on.overlap_ns > 0, "staged transfers bank overlappable time: {u_on:?}");

    // presence on the poolless planes (auto resolves to the lane being on)
    for plane in [PlanePolicy::Host, PlanePolicy::Chained] {
        let run = run_with(UploadPolicy::Auto, plane, None, &cfg);
        let u = meter(&run, plane.as_str());
        assert_eq!(u.staged, u.uploads, "{}: lane-on transfers all stage", plane.as_str());
    }
}

/// The steady-state contract with the lane ON: a pooled operand that did
/// not change between rounds costs zero transfers — the ring's active
/// half already holds the dispatched payload, so the compare hits
/// exactly like the single-slot pool's (the bench pins this same
/// invariant as `round.same_w.uploads == 0`).
#[test]
fn steady_state_same_w_uploads_nothing_with_lane_on() {
    let dir = artifacts_dir();
    let mut engine = Engine::new(&dir).expect("run `make artifacts` before cargo test");
    engine.set_upload_lane(true);
    let root = SynthStream::new(SynthSpec::least_squares(64), 7);
    let machines: Vec<MachineBatch> = (0..2)
        .map(|i| {
            let mut s = root.fork_stream(i as u64);
            MachineBatch::pack(&mut engine, 64, &s.draw_many(512)).unwrap()
        })
        .collect();
    let mut net = Network::new(2, NetModel::default());
    let mut meter = ClusterMeter::new(2);
    let w = vec![0.02f32; 64];
    distributed_mean_grad(&mut engine, None, Loss::Squared, &machines, &w, &mut net, &mut meter)
        .unwrap();
    let (dev_uploads, lane) = (engine.stats.uploads, engine.upload_meter().clone());
    assert!(lane.uploads > 0, "fresh w: the pooled iterate must upload: {lane:?}");
    assert_eq!(lane.staged, lane.uploads, "lane on: every transfer stages: {lane:?}");
    distributed_mean_grad(&mut engine, None, Loss::Squared, &machines, &w, &mut net, &mut meter)
        .unwrap();
    assert_eq!(engine.stats.uploads, dev_uploads, "same w: a steady-state round uploads nothing");
    let after = engine.upload_meter();
    assert_eq!(after.uploads, lane.uploads, "same w: the lane meter agrees: {after:?}");
    assert_eq!(after.bytes, lane.bytes, "same w: no bytes moved either: {after:?}");
}
