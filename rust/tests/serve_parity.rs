//! mbprox-serve parity and queue semantics: the warm-cache service may
//! NEVER move the paper's numbers. A run executed on a resident runner
//! whose executable cache is already hot must be bit-identical — final
//! iterates, objective curve, every paper-unit meter, the simulated
//! clock — to the same config executed by a cold process. The cache
//! shows up ONLY in the wall-clock `cache` meter (hits/misses/
//! compile_ns), which is diagnostics, not cost model.
//!
//! Also pinned here: the bounded FIFO's contract (job-id order is queue
//! order, a full queue rejects with 429 without disturbing queued jobs,
//! per-job cache deltas are isolated) and the satellite fix that
//! resident runners reset per-run state between queued jobs (meter
//! leakage regression: two configs back-to-back on one runner vs
//! fresh-runner runs).
//!
//! Requires `make artifacts`. Servers bind port 0 (OS-assigned), so the
//! tests never collide with each other or a developer's running service.

use mbprox::comm::faults::FaultsPolicy;
use mbprox::config::{ExperimentConfig, ServeConfig};
use mbprox::coordinator::Runner;
use mbprox::data::Loss;
use mbprox::runtime::Engine;
use mbprox::serve::{http_get, http_post, http_request, Server, ServeStats};
use mbprox::util::json::Json;
use std::net::SocketAddr;
use std::path::PathBuf;

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// A fresh runner with the SAME env-derived policies the server applies
/// to its resident runners — the cold side of every comparison.
fn cold_runner() -> Runner {
    let dir = artifacts_dir();
    Runner::new(Engine::new(&dir).expect("run `make artifacts` before cargo test"))
        .with_env_shards(&dir)
        .expect("env shards")
        .with_env_plane()
        .expect("env plane")
        .with_env_prefetch()
        .expect("env prefetch")
        .with_env_pipeline()
        .expect("env pipeline")
        .with_env_upload()
        .expect("env upload")
}

/// Bind on port 0 and serve from a companion thread (that thread is the
/// executor and owns the engines). Returns the address and the handle
/// whose join yields the final [`ServeStats`] after `POST /shutdown`.
fn start_server(queue_depth: usize) -> (SocketAddr, std::thread::JoinHandle<ServeStats>) {
    let cfg = ServeConfig { port: 0, queue_depth, ..ServeConfig::default() };
    let server = Server::bind(&cfg, &artifacts_dir()).expect("bind serve port 0");
    let addr = server.addr();
    let handle = std::thread::spawn(move || server.run().expect("server run"));
    (addr, handle)
}

/// The wire body for the drift config below — the SAME key=value lines a
/// config file holds (`POST /run`'s body IS the KvConfig key set).
const DRIFT_BODY: &str = "method = mp-dsvrg\nscenario = drift\nloss = sq\nm = 4\n\
                          b_local = 300\nn_budget = 2400\ndim = 64\nseed = 20170707\n\
                          eval_samples = 1024\neval_every = 1\n";

fn drift_cfg() -> ExperimentConfig {
    ExperimentConfig {
        method: "mp-dsvrg".into(),
        scenario: Some("drift".into()),
        loss: Loss::Squared,
        m: 4,
        b_local: 300,
        n_budget: 2400,
        dim: 64,
        seed: 20170707,
        eval_samples: 1024,
        eval_every: 1,
        ..ExperimentConfig::default()
    }
}

/// POST a config and collect the ndjson event stream: returns
/// `(queued_job_id, done_run_json)` — panics on an `error` event.
fn post_run(addr: SocketAddr, body: &str) -> (u64, Json) {
    let mut stream = http_request(addr, "POST", "/run", body).expect("POST /run");
    assert_eq!(stream.status, 200, "accepted run streams 200");
    let queued = stream.next_line().expect("queued event");
    let q = Json::parse(&queued).expect("queued event is json");
    assert_eq!(q.get("event").and_then(Json::as_str), Some("queued"), "{queued}");
    let id = q.get("job").and_then(Json::as_f64).expect("job id") as u64;
    let mut run = None;
    while let Some(line) = stream.next_line() {
        let ev = Json::parse(&line).expect("event line is json");
        match ev.get("event").and_then(Json::as_str) {
            Some("start") | Some("point") => {}
            Some("done") => {
                assert_eq!(ev.get("job").and_then(Json::as_f64), Some(id as f64));
                run = Some(ev.get("run").expect("done carries run_json").clone());
            }
            Some("error") => panic!("job {id} failed: {line}"),
            other => panic!("unexpected event {other:?}: {line}"),
        }
    }
    (id, run.expect("stream ended without a done event"))
}

/// Bitwise identity on the deterministic surface of two `run_json`
/// values: everything EXCEPT the wall-clock diagnostics (`cache` always;
/// the timing fields of `stalls`/`overlap`, whose deterministic counts
/// ARE compared). This is exactly the serve contract: warm vs cold may
/// differ only in wall-clock metering.
fn assert_same_run_json(a: &Json, b: &Json, label: &str) {
    for key in [
        "name",
        "samples",
        "comm_rounds",
        "vec_ops",
        "memory",
        "peak_vectors_per_machine",
        "sim_time_s",
        "objective",
        "curve",
    ] {
        assert_eq!(a.get(key), b.get(key), "{label}: run_json field {key:?}");
    }
    // dispatch and transfer counts are seed-determined even though
    // stall/overlap/upload nanoseconds are not (upload counts AND bytes
    // are parity surface — see rust/tests/upload_parity.rs)
    for (section, count) in [
        ("stalls", "takes"),
        ("overlap", "fans"),
        ("uploads", "uploads"),
        ("uploads", "bytes"),
    ] {
        let (sa, sb) = (a.get(section), b.get(section));
        match (sa, sb) {
            (Some(Json::Null), Some(Json::Null)) | (None, None) => {}
            (Some(x), Some(y)) => {
                assert_eq!(
                    x.get(count).map(Json::as_f64),
                    y.get(count).map(Json::as_f64),
                    "{label}: {section}.{count}"
                );
            }
            other => panic!("{label}: {section} presence mismatch {other:?}"),
        }
    }
}

/// Pull the `cache` meter out of a `run_json` value.
fn cache_of(run: &Json) -> &Json {
    run.get("cache").expect("run_json carries a cache member")
}

/// The tentpole bar: a job on a warm cache is bit-identical to a cold
/// process run, and the cache shows up only in the meter — first job all
/// misses, second job all hits with zero compile time.
#[test]
fn warm_cache_run_is_bit_identical_to_cold_process_run() {
    let cold = cold_runner().run(&drift_cfg()).expect("cold run");
    let cold_json = Json::parse(&mbprox::metrics::run_json(&cold)).expect("cold run_json");

    let (addr, handle) = start_server(4);
    let (id1, run1) = post_run(addr, DRIFT_BODY);
    let (id2, run2) = post_run(addr, DRIFT_BODY);
    assert_eq!((id1, id2), (1, 2), "job ids are assigned in submission order");

    assert_same_run_json(&cold_json, &run1, "cold process vs first (cold-cache) job");
    assert_same_run_json(&cold_json, &run2, "cold process vs second (warm-cache) job");

    // per-job cache deltas: job 1 compiled everything, job 2 nothing —
    // and job 2's delta is NOT polluted by job 1's misses (isolation)
    let c1 = cache_of(&run1);
    let c2 = cache_of(&run2);
    let field = |c: &Json, k: &str| c.get(k).and_then(Json::as_f64).unwrap_or(-1.0);
    assert!(field(c1, "misses") >= 1.0, "first job compiles: {c1:?}");
    assert_eq!(field(c1, "hits"), 0.0, "nothing is warm on the first job: {c1:?}");
    assert!(field(c1, "compile_ns") >= 1.0, "compiles cost wall-clock: {c1:?}");
    assert_eq!(field(c2, "misses"), 0.0, "warm job recompiles nothing: {c2:?}");
    assert_eq!(field(c2, "compile_ns"), 0.0, "warm job spends no compile time: {c2:?}");
    assert_eq!(
        field(c2, "hits"),
        field(c1, "misses"),
        "warm job hits exactly what the cold job compiled"
    );
    assert_eq!(field(c2, "hit_rate"), 1.0, "warm hit rate is 1.0: {c2:?}");

    // /stats aggregates the per-job deltas
    let (status, stats) = http_get(addr, "/stats").expect("GET /stats");
    assert_eq!(status, 200);
    let v = Json::parse(&stats).expect("stats json");
    assert_eq!(v.get("jobs_done").and_then(Json::as_f64), Some(2.0), "{stats}");
    let ec = v.get("exec_cache").expect("exec_cache section");
    assert_eq!(field(ec, "misses"), field(c1, "misses"), "{stats}");
    assert_eq!(field(ec, "hits"), field(c2, "hits"), "{stats}");

    // ...and the per-job lane meters: the upload meter rides run_json on
    // every plane, so /stats' cross-job totals are exactly the per-job
    // sums (transfer counts and bytes are deterministic; only the
    // nanosecond fields are wall-clock)
    let up = v.get("uploads").expect("uploads section");
    let u1 = run1.get("uploads").expect("job 1 run_json uploads");
    let u2 = run2.get("uploads").expect("job 2 run_json uploads");
    let total = field(u1, "uploads") + field(u2, "uploads");
    assert_eq!(field(up, "uploads"), total, "{stats}");
    let total_b = field(u1, "bytes") + field(u2, "bytes");
    assert_eq!(field(up, "bytes"), total_b, "{stats}");

    let (status, _) = http_post(addr, "/shutdown", "").expect("POST /shutdown");
    assert_eq!(status, 200);
    let final_stats = handle.join().expect("server thread");
    assert_eq!(final_stats.jobs_done, 2);
    assert_eq!(final_stats.jobs_failed, 0);
    assert_eq!(final_stats.runners.misses, 1, "one resident runner built");
    assert_eq!(final_stats.runners.hits, 1, "second job reused it");
}

/// Queue semantics: ids are handed out in acceptance order, a malformed
/// config is rejected before it occupies a slot, and `serve.*` keys are
/// rejected from job bodies (they configure the service, not a run).
#[test]
fn queue_assigns_ids_in_order_and_rejects_bad_configs_unqueued() {
    let (addr, handle) = start_server(4);

    let (status, body) = http_post(addr, "/run", "metod = mp-dsvrg\n").expect("bad key post");
    assert_eq!(status, 400, "unknown key is rejected before queueing: {body}");
    assert!(body.contains("did you mean"), "did-you-mean reaches the wire: {body}");

    let (status, body) =
        http_post(addr, "/run", &format!("{DRIFT_BODY}serve.port = 1\n")).expect("serve-key post");
    assert_eq!(status, 400, "serve.* keys are not job keys: {body}");
    assert!(body.contains("serve"), "error names the serve namespace: {body}");

    let (status, body) = http_get(addr, "/run").expect("GET /run");
    assert_eq!(status, 405, "{body}");
    let (status, _) = http_get(addr, "/no-such-path").expect("GET unknown");
    assert_eq!(status, 404);

    // rejected submissions consumed no ids: the first accepted job is 1,
    // and sequential accepts stay in order
    let (id1, _) = post_run(addr, DRIFT_BODY);
    let (id2, _) = post_run(addr, DRIFT_BODY);
    let (id3, _) = post_run(addr, DRIFT_BODY);
    assert_eq!((id1, id2, id3), (1, 2, 3), "FIFO ids in acceptance order");

    let _ = http_post(addr, "/shutdown", "").expect("shutdown");
    let stats = handle.join().expect("server thread");
    assert_eq!(stats.jobs_accepted, 3);
    assert_eq!(stats.jobs_done, 3);
    assert_eq!(stats.jobs_rejected, 0, "400s are not queue rejections");
}

/// Bounded-queue rejection: with `serve.queue_depth = 1`, a job queued
/// behind a running one fills the only slot and the next submission gets
/// 429 — while both accepted jobs still stream to completion.
#[test]
fn full_queue_rejects_with_429() {
    let (addr, handle) = start_server(1);

    // a heavier config so job 1 is still executing while 2 and 3 arrive
    let slow_body = "method = mp-dsvrg\nscenario = drift\nloss = sq\nm = 4\n\
                     b_local = 300\nn_budget = 7200\ndim = 64\nseed = 20170707\n\
                     eval_samples = 1024\neval_every = 1\n";

    // job 1: accepted, executor picks it up (freeing the buffer slot)
    let mut s1 = http_request(addr, "POST", "/run", slow_body).expect("job 1");
    assert_eq!(s1.status, 200);
    let q1 = s1.next_line().expect("job 1 queued event");
    assert!(q1.contains("\"queued\""), "{q1}");

    // job 2: occupies the single queue slot behind the running job
    let mut s2 = http_request(addr, "POST", "/run", slow_body).expect("job 2");
    assert_eq!(s2.status, 200);
    let q2 = s2.next_line().expect("job 2 queued event");
    assert!(q2.contains("\"queued\""), "{q2}");

    // job 3: queue full -> 429 naming the depth, nothing disturbed
    let (status, body) = http_post(addr, "/run", slow_body).expect("job 3");
    assert_eq!(status, 429, "bounded queue rejects: {body}");
    assert!(body.contains("queue full"), "{body}");
    assert!(body.contains("queue_depth=1"), "rejection names the bound: {body}");

    // both accepted jobs still run to completion in order
    let done1 = s1.read_to_end();
    assert!(done1.contains("\"event\":\"done\""), "job 1 completes: {done1}");
    let done2 = s2.read_to_end();
    assert!(done2.contains("\"event\":\"done\""), "job 2 completes: {done2}");

    let _ = http_post(addr, "/shutdown", "").expect("shutdown");
    let stats = handle.join().expect("server thread");
    assert_eq!(stats.jobs_done, 2);
    assert_eq!(stats.jobs_rejected, 1, "exactly the third submission was rejected");
}

/// Meter-leakage regression (satellite 1): a resident runner executing
/// two different configs back-to-back must produce the SAME deterministic
/// results as fresh runners — per-run state (sessions, stall/overlap/
/// fault meters, recovery tallies, ClusterMeter) is reset between queued
/// runs, and a faulty run's tallies never bleed into the next job.
#[test]
fn resident_runner_runs_match_fresh_runner_runs() {
    let cfg_plain = drift_cfg();
    let cfg_faulty = ExperimentConfig {
        faults: FaultsPolicy::On,
        straggler_p: Some(0.3),
        slowdown_alpha: Some(1.5),
        dropout_p: Some(0.1),
        dropout_rounds: Some(2),
        seed: 777,
        ..drift_cfg()
    };

    let fresh_plain = cold_runner().run(&cfg_plain).expect("fresh plain");
    let fresh_faulty = cold_runner().run(&cfg_faulty).expect("fresh faulty");

    let mut resident = cold_runner();
    let r1 = resident.run(&cfg_faulty).expect("resident faulty");
    let r2 = resident.run(&cfg_plain).expect("resident plain after faulty");
    let r3 = resident.run(&cfg_faulty).expect("resident faulty again");

    let jsonify = |r: &mbprox::algos::RunResult| {
        Json::parse(&mbprox::metrics::run_json(r)).expect("run_json parses")
    };
    assert_same_run_json(&jsonify(&fresh_faulty), &jsonify(&r1), "faulty: fresh vs resident 1st");
    assert_same_run_json(&jsonify(&fresh_plain), &jsonify(&r2), "plain: fresh vs resident 2nd");
    assert_same_run_json(&jsonify(&fresh_faulty), &jsonify(&r3), "faulty: fresh vs resident 3rd");

    // the fault tally itself must not leak: the plain run between two
    // faulty ones reports no meter, and the repeated faulty run's tally
    // matches the fresh one exactly (not a running sum)
    assert_eq!(r2.faults, fresh_plain.faults, "plain run between faulty runs");
    assert!(r2.faults.is_none(), "faults=off after a faulty job reports no meter");
    assert_eq!(r1.faults, fresh_faulty.faults, "first faulty tally");
    assert_eq!(r3.faults, fresh_faulty.faults, "repeat faulty tally is not cumulative");

    // cache deltas are per-run even on the resident runner: run 1 pays
    // the compiles, later runs on the warm cache pay none
    let c1 = r1.cache.as_ref().expect("resident run meters its cache");
    let c2 = r2.cache.as_ref().expect("resident run meters its cache");
    let c3 = r3.cache.as_ref().expect("resident run meters its cache");
    assert!(c1.misses >= 1, "first resident run compiles: {c1:?}");
    assert_eq!(c2.misses, 0, "warm resident run recompiles nothing: {c2:?}");
    assert_eq!(c3.misses, 0, "warm resident run recompiles nothing: {c3:?}");
    assert_eq!(c2.hits, c1.misses, "warm run touches exactly the compiled set");
    assert_eq!(c3.hits, c1.misses, "cache delta is per-run, not cumulative");
}
