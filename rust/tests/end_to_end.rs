//! End-to-end system test: the Figure-3 protocol at CI scale.
//!
//! Generates a Table-3-like dataset, round-trips it through a real libsvm
//! file, shards the training half across simulated machines, runs MP-DANE
//! and minibatch SGD through the full AOT/PJRT stack, and checks the
//! paper's qualitative claims:
//!   (a) at large minibatch size, MP-DANE's objective beats minibatch SGD;
//!   (b) more DANE rounds K do not hurt (diminishing returns allowed);
//!   (c) the libsvm round trip is lossless at parse precision.

use mbprox::algos::mbprox::MinibatchProx;
use mbprox::algos::minibatch_sgd::MinibatchSgd;
use mbprox::algos::solvers::dane::DaneSolver;
use mbprox::algos::Method;
use mbprox::coordinator::Runner;
use mbprox::data::sampler::{shard_ranges, VecStream};
use mbprox::data::table3::CODRNA;
use mbprox::data::{libsvm, Loss, Sample, SampleStream};
use mbprox::runtime::Engine;
use mbprox::theory::{self, ProblemConsts};
use mbprox::util::prng::Prng;

fn runner() -> Runner {
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    Runner::new(Engine::new(&dir).expect("run `make artifacts` first"))
        .with_env_shards(&dir)
        .expect("shard pool construction")
        .with_env_plane()
        .expect("PLANE policy")
}

fn load_via_libsvm(n_total: usize) -> (Vec<Sample>, Vec<Sample>) {
    let spec = &CODRNA;
    let mut stream = spec.stream(20170707);
    let all = stream.draw_many(n_total);
    let dir = std::env::temp_dir().join("mbprox_e2e");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("codrna_e2e.libsvm");
    libsvm::write_samples(&path, &all).unwrap();
    let parsed = libsvm::read_samples(&path, spec.dim).unwrap();
    assert_eq!(parsed.len(), all.len(), "libsvm round trip lost samples");
    for (a, b) in all.iter().zip(&parsed).take(50) {
        assert!((a.y - b.y).abs() < 1e-4);
        for (xa, xb) in a.x.iter().zip(&b.x) {
            assert!((xa - xb).abs() < 1e-4);
        }
    }
    let half = parsed.len() / 2;
    let (train, eval) = parsed.split_at(half);
    (train.to_vec(), eval.to_vec())
}

fn run_method(
    r: &mut Runner,
    train: &[Sample],
    eval: &[Sample],
    m: usize,
    b: usize,
    k_dane: Option<usize>,
) -> f64 {
    let d = r.engine.manifest().padded_dim(train[0].x.len()).unwrap();
    let consts = ProblemConsts {
        l_lipschitz: 1.0,
        b_norm: 2.0 * (CODRNA.dim as f64).sqrt(),
        beta_smooth: 0.25,
        m,
    };
    let plan = theory::mbprox_plan(&consts, train.len() as f64, b);
    let ranges = shard_ranges(train.len(), m);
    let root = Prng::seed_from_u64(5);
    let streams: Vec<Box<dyn SampleStream>> = (0..m)
        .map(|i| {
            Box::new(VecStream::new(
                train[ranges[i].clone()].to_vec(),
                Loss::Logistic,
                root.split(i as u64),
            )) as Box<dyn SampleStream>
        })
        .collect();
    let mut ctx = r.context_over(Loss::Logistic, d, streams, eval, 0).unwrap();
    let result = match k_dane {
        Some(k) => {
            let eta = 0.1 / (consts.beta_smooth + plan.gamma);
            MinibatchProx::new(
                "mp-dane",
                b,
                plan.t_outer,
                plan.gamma,
                DaneSolver::plain(k, eta),
            )
            .run(&mut ctx)
            .unwrap()
        }
        None => {
            let gamma = theory::minibatch_sgd_gamma(&consts, plan.t_outer, plan.bm);
            MinibatchSgd { b_local: b, t_outer: plan.t_outer, gamma }.run(&mut ctx).unwrap()
        }
    };
    result.final_objective.unwrap()
}

#[test]
fn figure3_shape_holds_end_to_end() {
    let mut r = runner();
    let (train, eval) = load_via_libsvm(16_384);
    let m = 4;
    let b_large = 512;

    let sgd_large = run_method(&mut r, &train, &eval, m, b_large, None);
    let dane1_large = run_method(&mut r, &train, &eval, m, b_large, Some(1));
    let dane4_large = run_method(&mut r, &train, &eval, m, b_large, Some(4));

    // all methods leave the start point
    let start = std::f64::consts::LN_2;
    for (name, obj) in
        [("sgd", sgd_large), ("dane-K1", dane1_large), ("dane-K4", dane4_large)]
    {
        assert!(obj < start, "{name}: {obj} did not improve from ln2");
        assert!(obj > 0.05, "{name}: {obj} impossibly low");
    }

    // (a) large-b: MP-DANE beats minibatch SGD (the Figure-3 headline)
    assert!(
        dane4_large < sgd_large - 1e-3,
        "MP-DANE(K=4) {dane4_large:.4} must beat minibatch SGD {sgd_large:.4} at b={b_large}"
    );

    // (b) more DANE rounds do not hurt (diminishing returns allowed)
    assert!(
        dane4_large <= dane1_large + 5e-3,
        "K=4 ({dane4_large:.4}) should be no worse than K=1 ({dane1_large:.4})"
    );
}

#[test]
fn sgd_degrades_faster_with_b_than_mp_dane() {
    let mut r = runner();
    let (train, eval) = load_via_libsvm(16_384);
    let m = 4;

    let sgd_small = run_method(&mut r, &train, &eval, m, 32, None);
    let sgd_large = run_method(&mut r, &train, &eval, m, 512, None);
    let dane_small = run_method(&mut r, &train, &eval, m, 32, Some(4));
    let dane_large = run_method(&mut r, &train, &eval, m, 512, Some(4));

    let sgd_degradation = sgd_large - sgd_small;
    let dane_degradation = dane_large - dane_small;
    assert!(
        dane_degradation < sgd_degradation + 1e-3,
        "MP-DANE degradation {dane_degradation:.4} must not exceed SGD degradation {sgd_degradation:.4}"
    );
}
