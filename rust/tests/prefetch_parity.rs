//! Prefetch-lane parity: `prefetch=on` vs `prefetch=off` is a pure
//! scheduling change. The lane's staged packs must serve the EXACT
//! samples a synchronous draw would have produced — so iterates,
//! objective curves, sample/memory meters, and simulated time are
//! bit-identical either way, at every shard count, for streaming and
//! finite-ERM (ragged epoch boundary) scenarios, and under mismatched
//! draw sizes that force the stage-to-leftover re-split. Only the
//! wall-clock [`StallMeter`] is allowed to differ (it is excluded from
//! the parity surface — see `runtime::shard`).
//!
//! Requires `make artifacts`.

use mbprox::algos::RunResult;
use mbprox::comm::{netmodel::NetModel, Network};
use mbprox::config::ExperimentConfig;
use mbprox::coordinator::Runner;
use mbprox::data::Loss;
use mbprox::objective::mean_grad_chained_host;
use mbprox::runtime::{Engine, PlanePolicy, PrefetchPolicy, ShardPool};
use std::path::PathBuf;

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// Run `cfg` on a fresh sharded runner under an explicit prefetch policy.
fn run_with(prefetch: PrefetchPolicy, shards: usize, cfg: &ExperimentConfig) -> RunResult {
    let dir = artifacts_dir();
    let mut r = Runner::new(Engine::new(&dir).expect("run `make artifacts` before cargo test"))
        .with_plane(PlanePolicy::Sharded)
        .with_shards(ShardPool::new(shards, &dir).expect("shard pool construction"))
        .with_prefetch(prefetch);
    r.run(cfg).unwrap_or_else(|e| {
        panic!("{} (prefetch={}, shards={shards}): {e:?}", cfg.method, prefetch.as_str())
    })
}

fn bits32(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// Full bitwise identity on everything except the wall-clock stall meter.
fn assert_identical(a: &RunResult, b: &RunResult, label: &str) {
    assert_eq!(bits32(&a.w), bits32(&b.w), "{label}: final iterate bits");
    assert_eq!(a.report, b.report, "{label}: ClusterMeter report");
    assert_eq!(a.sim_time_s.to_bits(), b.sim_time_s.to_bits(), "{label}: simulated time");
    assert_eq!(a.curve.len(), b.curve.len(), "{label}: curve length");
    for (p, q) in a.curve.iter().zip(&b.curve) {
        assert_eq!(p.samples_total, q.samples_total, "{label}: curve samples");
        assert_eq!(p.comm_rounds, q.comm_rounds, "{label}: curve rounds");
        assert_eq!(p.vec_ops, q.vec_ops, "{label}: curve vec ops");
        match (p.objective, q.objective) {
            (Some(x), Some(y)) => {
                assert_eq!(x.to_bits(), y.to_bits(), "{label}: objective bits")
            }
            (None, None) => {}
            other => panic!("{label}: objective presence mismatch {other:?}"),
        }
    }
}

/// on vs off at shards ∈ {1, 2, 4} — the off run at shards=1 is the one
/// reference every other leg must match bit for bit.
fn prefetch_parity(cfg: &ExperimentConfig) {
    let reference = run_with(PrefetchPolicy::Off, 1, cfg);
    for n in [1usize, 2, 4] {
        let off = run_with(PrefetchPolicy::Off, n, cfg);
        let on = run_with(PrefetchPolicy::On, n, cfg);
        assert_identical(&reference, &off, &format!("{} off shards={n}", cfg.method));
        assert_identical(&reference, &on, &format!("{} on shards={n}", cfg.method));
    }
}

#[test]
fn streaming_drift_on_off_parity() {
    // b = 300 -> one full block + a 44-row ragged tail per machine draw;
    // constant-b draws mean every warm stage is an exact-size hit
    let cfg = ExperimentConfig {
        method: "mp-dsvrg".into(),
        scenario: Some("drift".into()),
        loss: Loss::Squared,
        m: 4,
        b_local: 300,
        n_budget: 2400, // T = 2
        dim: 64,
        seed: 20170707,
        eval_samples: 1024,
        eval_every: 1,
        ..ExperimentConfig::default()
    };
    prefetch_parity(&cfg);
}

#[test]
fn erm_fixed_ragged_epoch_on_off_parity() {
    // 2051 fixed samples shard 513/513/513/512: the epoch-bounded streams
    // return honestly-short boundary batches, and `prefetch=on` must
    // stage exactly those short batches (epoch-bounded streams do not
    // decompose, so only exact-request staging is ever used)
    let cfg = ExperimentConfig {
        method: "dsvrg-erm".into(),
        scenario: Some("erm-fixed".into()),
        loss: Loss::Squared,
        m: 4,
        b_local: 256,
        n_budget: 2051,
        dim: 64,
        seed: 20170707,
        eval_samples: 1024,
        eval_every: 1,
        // the config-key path (rather than Runner::with_prefetch): the
        // per-run key must beat the runner's Auto default
        prefetch: PrefetchPolicy::On,
        ..ExperimentConfig::default()
    };
    let via_cfg = {
        let dir = artifacts_dir();
        let mut r = Runner::new(Engine::new(&dir).expect("engine"))
            .with_plane(PlanePolicy::Sharded)
            .with_shards(ShardPool::new(2, &dir).expect("pool"));
        r.run(&cfg).expect("erm-fixed with prefetch=on from the config")
    };
    let cfg_default = ExperimentConfig { prefetch: PrefetchPolicy::Auto, ..cfg.clone() };
    let off = run_with(PrefetchPolicy::Off, 2, &cfg_default);
    assert_identical(&off, &via_cfg, "erm-fixed cfg-key prefetch=on");
    prefetch_parity(&cfg_default);
}

/// Mismatched draw sizes force the stage-to-leftover re-split: a staged
/// 300-sample pack answered by a 200-sample request must be torn down
/// into the leftover queue and re-served in draw order. The packed
/// gradients (chained kernels: bit-identical across engines) pin the
/// served samples bit for bit against the synchronous path.
#[test]
fn mismatched_draw_sizes_resplit_bitwise() {
    let grads_with = |prefetch: PrefetchPolicy| -> Vec<Vec<u32>> {
        let dir = artifacts_dir();
        let (d, m) = (64usize, 4usize);
        let mut r = Runner::new(Engine::new(&dir).expect("engine"))
            .with_plane(PlanePolicy::Sharded)
            .with_shards(ShardPool::new(2, &dir).expect("pool"))
            .with_prefetch(prefetch);
        let cfg = ExperimentConfig {
            method: "minibatch-sgd".into(),
            scenario: Some("heavy-tail".into()),
            loss: Loss::Squared,
            m,
            b_local: 300,
            dim: d,
            seed: 99,
            eval_samples: 64,
            ..ExperimentConfig::default()
        };
        let mut ctx = r.context(&cfg).unwrap();
        let w: Vec<f32> = (0..d).map(|j| (j as f32 * 0.1).cos() * 0.05).collect();
        // 300 stages 300; asking 200 splits the stage; 44 rides the
        // leftover tail; 300 spans leftovers + a fresh draw
        [300usize, 200, 44, 300]
            .into_iter()
            .map(|b| {
                let batches = ctx.draw_batches_grad_only(b, false).unwrap();
                let mut net = Network::new(m, NetModel::default());
                let g = mean_grad_chained_host(
                    ctx.plane.engine,
                    ctx.plane.shards,
                    Loss::Squared,
                    &batches,
                    &w,
                    &mut net,
                    &mut ctx.meter,
                )
                .unwrap();
                bits32(&g)
            })
            .collect()
    };
    let off = grads_with(PrefetchPolicy::Off);
    let on = grads_with(PrefetchPolicy::On);
    assert_eq!(off, on, "re-split staged samples must preserve draw order bit for bit");
}

/// The stall meter itself: surfaced on sharded runs, honest about the
/// policy that ran, and never part of the parity surface above.
#[test]
fn stall_meter_reports_the_policy_that_ran() {
    let cfg = ExperimentConfig {
        method: "minibatch-sgd".into(),
        scenario: Some("drift".into()),
        loss: Loss::Squared,
        m: 4,
        b_local: 256,
        n_budget: 4096, // 4 outer steps of drawing
        dim: 64,
        seed: 11,
        eval_samples: 64,
        eval_every: 0,
        ..ExperimentConfig::default()
    };
    let off = run_with(PrefetchPolicy::Off, 2, &cfg);
    let s_off = off.stalls.expect("sharded runs surface a stall meter");
    assert!(s_off.takes > 0, "draws must be routed through the lane");
    assert_eq!(s_off.hits, 0, "prefetch=off never serves from a stage");
    assert_eq!(s_off.takes, s_off.misses, "off: every take is a synchronous miss");

    let on = run_with(PrefetchPolicy::On, 2, &cfg);
    let s_on = on.stalls.expect("sharded runs surface a stall meter");
    assert_eq!(s_on.takes, s_off.takes, "identical draw schedule either way");
    assert_eq!(s_on.hits + s_on.misses, s_on.takes, "hits and misses partition takes");
}
