//! Integration: load every AOT artifact, execute it, and match the
//! rust-side reference numerics. Requires `make artifacts` to have run
//! (the Makefile `test` target guarantees this).

use mbprox::data::blocks::{pack_block, BLOCK_ROWS};
use mbprox::data::synth::{SynthSpec, SynthStream};
use mbprox::data::{Loss, SampleStream};
use mbprox::runtime::exec::BlockLits;
use mbprox::runtime::Engine;
use mbprox::util::testkit::assert_close;

fn artifacts_dir() -> std::path::PathBuf {
    // tests run from the crate root
    std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn engine() -> Engine {
    Engine::new(&artifacts_dir()).expect("run `make artifacts` before cargo test")
}

/// Host-side reference block gradient (sum form), mirroring ref.py.
fn ref_grad(loss: Loss, x: &[f32], y: &[f32], mask: &[f32], w: &[f32], d: usize) -> (Vec<f32>, f64, f64) {
    let rows = y.len();
    let mut g = vec![0.0f64; d];
    let mut lsum = 0.0f64;
    let mut cnt = 0.0f64;
    for r in 0..rows {
        if mask[r] == 0.0 {
            continue;
        }
        cnt += 1.0;
        let xr = &x[r * d..(r + 1) * d];
        let z: f64 = xr.iter().zip(w).map(|(&a, &b)| a as f64 * b as f64).sum();
        match loss {
            Loss::Squared => {
                let rres = z - y[r] as f64;
                lsum += 0.5 * rres * rres;
                for j in 0..d {
                    g[j] += rres * xr[j] as f64;
                }
            }
            Loss::Logistic => {
                let t = -(y[r] as f64) * z;
                lsum += (1.0 + t.exp()).ln();
                let s = 1.0 / (1.0 + (-t).exp());
                let coef = -(y[r] as f64) * s;
                for j in 0..d {
                    g[j] += coef * xr[j] as f64;
                }
            }
        }
    }
    (g.iter().map(|&v| v as f32).collect(), lsum, cnt)
}

fn make_lits(
    e: &mut Engine,
    loss: Loss,
    d: usize,
    valid: usize,
    seed: u64,
) -> (BlockLits, Vec<f32>, Vec<f32>, Vec<f32>) {
    let spec = match loss {
        Loss::Squared => SynthSpec::least_squares(d),
        Loss::Logistic => SynthSpec::logistic(d),
    };
    let mut stream = SynthStream::new(spec, seed);
    let samples = stream.draw_many(valid);
    let block = pack_block(&samples, d);
    let (x, y, mask) = (block.x.clone(), block.y.clone(), block.mask.clone());
    (BlockLits::from_block(e, &block).unwrap(), x, y, mask)
}

#[test]
fn engine_loads_manifest_and_compiles_everything() {
    let mut e = engine();
    assert_eq!(e.block_rows(), BLOCK_ROWS);
    e.warmup_all().unwrap();
    assert_eq!(e.stats.compiles as usize, e.manifest().artifacts.len());
}

#[test]
fn grad_artifacts_match_reference() {
    let mut e = engine();
    for loss in [Loss::Squared, Loss::Logistic] {
        for d in [64usize, 128] {
            let (lits, x, y, mask) = make_lits(&mut e, loss, d, 200, 42);
            let w: Vec<f32> = (0..d).map(|j| ((j % 7) as f32 - 3.0) * 0.1).collect();
            let out = e.grad_block(loss, &lits, &w).unwrap();
            let (g_ref, l_ref, c_ref) = ref_grad(loss, &x, &y, &mask, &w, d);
            assert_close(&out.grad_sum, &g_ref, 1e-3, 1e-3);
            assert!((out.loss_sum - l_ref).abs() / l_ref.max(1.0) < 1e-3);
            assert_eq!(out.count, c_ref);
        }
    }
}

#[test]
fn nm_artifact_matches_reference() {
    let mut e = engine();
    let d = 64;
    let (lits, x, _y, mask, ) = make_lits(&mut e, Loss::Squared, d, 150, 7);
    let v: Vec<f32> = (0..d).map(|j| (j as f32 * 0.01).sin()).collect();
    let (out, cnt) = e.nm_block(&lits, &v).unwrap();
    // reference: X^T diag(mask) X v
    let rows = BLOCK_ROWS;
    let mut u = vec![0.0f64; rows];
    for r in 0..rows {
        let xr = &x[r * d..(r + 1) * d];
        u[r] = xr.iter().zip(&v).map(|(&a, &b)| a as f64 * b as f64).sum::<f64>()
            * mask[r] as f64;
    }
    let mut expect = vec![0.0f32; d];
    for j in 0..d {
        let mut s = 0.0f64;
        for r in 0..rows {
            s += x[r * d + j] as f64 * u[r];
        }
        expect[j] = s as f32;
    }
    assert_close(&out, &expect, 1e-3, 1e-3);
    assert_eq!(cnt, 150.0);
}

#[test]
fn svrg_artifact_matches_host_loop() {
    let mut e = engine();
    for loss in [Loss::Squared, Loss::Logistic] {
        let d = 64;
        let valid = 100;
        let (lits, x, y, mask) = make_lits(&mut e, loss, d, valid, 11);
        let x0: Vec<f32> = (0..d).map(|j| 0.01 * j as f32).collect();
        let z = vec![0.0f32; d];
        // mu = mean gradient at z over valid rows
        let (mut mu, _, cnt) = ref_grad(loss, &x, &y, &mask, &z, d);
        for v in &mut mu {
            *v /= cnt as f32;
        }
        let wprev = vec![0.0f32; d];
        let (gamma, eta) = (0.5f32, 0.05f32);
        let (xo, xa) = e.svrg_block(loss, &lits, &x0, &z, &mu, &wprev, gamma, eta).unwrap();

        // host reference loop
        let row_grad = |w: &[f32], r: usize| -> Vec<f32> {
            let xr = &x[r * d..(r + 1) * d];
            let zdot: f64 = xr.iter().zip(w).map(|(&a, &b)| a as f64 * b as f64).sum();
            match loss {
                Loss::Squared => {
                    let c = zdot - y[r] as f64;
                    xr.iter().map(|&v| (c * v as f64) as f32).collect()
                }
                Loss::Logistic => {
                    let t = -(y[r] as f64) * zdot;
                    let s = 1.0 / (1.0 + (-t).exp());
                    let c = -(y[r] as f64) * s;
                    xr.iter().map(|&v| (c * v as f64) as f32).collect()
                }
            }
        };
        let mut xcur = x0.clone();
        let mut xsum = x0.clone();
        let mut count = 1.0f32;
        for r in 0..BLOCK_ROWS {
            if mask[r] == 0.0 {
                continue;
            }
            let gx = row_grad(&xcur, r);
            let gz = row_grad(&z, r);
            for j in 0..d {
                let g = gx[j] - gz[j] + mu[j] + gamma * (xcur[j] - wprev[j]);
                xcur[j] -= eta * g;
            }
            for j in 0..d {
                xsum[j] += xcur[j];
            }
            count += 1.0;
        }
        let xavg: Vec<f32> = xsum.iter().map(|&s| s / count).collect();
        assert_close(&xo, &xcur, 5e-3, 1e-3);
        assert_close(&xa, &xavg, 5e-3, 1e-3);
    }
}

#[test]
fn saga_artifact_matches_host_loop() {
    let mut e = engine();
    for loss in [Loss::Squared, Loss::Logistic] {
        let d = 64;
        let valid = 80;
        let (lits, x, y, mask) = make_lits(&mut e, loss, d, valid, 21);
        let x0: Vec<f32> = (0..d).map(|j| 0.02 * (j as f32 - 32.0)).collect();
        let z = vec![0.0f32; d];
        let (mut mu, _, cnt) = ref_grad(loss, &x, &y, &mask, &z, d);
        for v in &mut mu {
            *v /= cnt as f32;
        }
        let center = vec![0.0f32; d];
        let (gamma, eta) = (0.4f32, 0.03f32);
        let (xo, xa) = e.saga_block(loss, &lits, &x0, &z, &mu, &center, gamma, eta).unwrap();

        // host reference: SAGA with scalar link-residual table
        let link = |w: &[f32], r: usize| -> f64 {
            let xr = &x[r * d..(r + 1) * d];
            let zdot: f64 = xr.iter().zip(w).map(|(&a, &b)| a as f64 * b as f64).sum();
            match loss {
                Loss::Squared => zdot - y[r] as f64,
                Loss::Logistic => {
                    let t = -(y[r] as f64) * zdot;
                    -(y[r] as f64) / (1.0 + (-t).exp())
                }
            }
        };
        let n_valid: f64 = mask.iter().map(|&m| m as f64).sum::<f64>().max(1.0);
        let mut alpha: Vec<f64> = (0..BLOCK_ROWS).map(|r| link(&z, r)).collect();
        let mut xcur = x0.clone();
        let mut gbar: Vec<f64> = mu.iter().map(|&v| v as f64).collect();
        let mut xsum = x0.clone();
        let mut count = 1.0f32;
        for r in 0..BLOCK_ROWS {
            if mask[r] == 0.0 {
                continue;
            }
            let s_new = link(&xcur, r);
            let diff = s_new - alpha[r];
            let xr = &x[r * d..(r + 1) * d];
            for j in 0..d {
                let g = diff * xr[j] as f64 + gbar[j]
                    + gamma as f64 * (xcur[j] as f64 - center[j] as f64);
                xcur[j] -= eta * g as f32;
            }
            for j in 0..d {
                gbar[j] += diff / n_valid * xr[j] as f64;
            }
            alpha[r] = s_new;
            for j in 0..d {
                xsum[j] += xcur[j];
            }
            count += 1.0;
        }
        let xavg: Vec<f32> = xsum.iter().map(|&s| s / count).collect();
        assert_close(&xo, &xcur, 5e-3, 1e-3);
        assert_close(&xa, &xavg, 5e-3, 1e-3);
    }
}

#[test]
fn padded_block_equals_compact_block() {
    let mut e = engine();
    let d = 64;
    let (lits_pad, _, _, _) = make_lits(&mut e, Loss::Squared, d, 60, 99);
    let w = vec![0.05f32; d];
    let out = e.grad_block(Loss::Squared, &lits_pad, &w).unwrap();
    assert_eq!(out.count, 60.0);
    // grad of masked rows is exactly zero contribution: recompute with
    // fresh stream over the same seed but full 60 rows only
    let (lits_same, _, _, _) = make_lits(&mut e, Loss::Squared, d, 60, 99);
    let out2 = e.grad_block(Loss::Squared, &lits_same, &w).unwrap();
    assert_close(&out.grad_sum, &out2.grad_sum, 1e-6, 1e-6);
}

#[test]
fn engine_rejects_wrong_dim_inputs() {
    let mut e = engine();
    let (lits, _, _, _) = make_lits(&mut e, Loss::Squared, 64, 10, 1);
    let w_bad = vec![0.0f32; 32];
    assert!(e.grad_block(Loss::Squared, &lits, &w_bad).is_err());
    assert!(e.nm_block(&lits, &w_bad).is_err());
}

#[test]
fn engine_rejects_unknown_artifact() {
    let mut e = engine();
    assert!(e.executable("grad_sq_d999").is_err());
}

#[test]
fn manifest_rejects_corrupt_json() {
    let dir = std::env::temp_dir().join("mbprox_corrupt_manifest");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("manifest.json"), "{not json").unwrap();
    assert!(mbprox::runtime::Manifest::load(&dir).is_err());
}

#[test]
fn chained_vec_plane_matches_host_math() {
    let mut e = engine();
    let d = 64;
    let u_host: Vec<f32> = (0..d).map(|j| (j as f32 * 0.1).sin()).collect();
    let v_host: Vec<f32> = (0..d).map(|j| (j as f32 * 0.07).cos()).collect();
    let u = e.upload_dev(&u_host, &[d]).unwrap();
    let v = e.upload_dev(&v_host, &[d]).unwrap();

    let scaled = e.vec_scale(&u, 2.5).unwrap();
    let got = e.materialize(&scaled).unwrap();
    let expect: Vec<f32> = u_host.iter().map(|&x| 2.5 * x).collect();
    assert_close(&got, &expect, 1e-6, 1e-7);

    let comb = e.vec_axpby(1.5, &u, -0.5, &v).unwrap();
    let got = e.materialize(&comb).unwrap();
    let expect: Vec<f32> =
        u_host.iter().zip(&v_host).map(|(&a, &b)| 1.5 * a - 0.5 * b).collect();
    assert_close(&got, &expect, 1e-5, 1e-6);

    let dot = e.vec_dot(&u, &v).unwrap();
    let expect: f64 = u_host.iter().zip(&v_host).map(|(&a, &b)| a as f64 * b as f64).sum();
    assert!((dot - expect).abs() < 1e-3, "vec_dot {dot} vs {expect}");
}

#[test]
fn chained_grad_acc_matches_tupled_dispatch() {
    let mut e = engine();
    for loss in [Loss::Squared, Loss::Logistic] {
        let d = 64;
        let (lits, _, _, _) = make_lits(&mut e, loss, d, 180, 33);
        let w_host: Vec<f32> = (0..d).map(|j| ((j % 7) as f32 - 3.0) * 0.05).collect();
        let tupled = e.grad_block(loss, &lits, &w_host).unwrap();

        let w = e.upload_dev(&w_host, &[d]).unwrap();
        let zero = e.zeros_dev(d).unwrap();
        let before_downloads = e.stats.downloads;
        let acc = e.grad_acc(loss, &lits, &w, &zero).unwrap();
        assert_eq!(e.stats.downloads, before_downloads, "grad_acc must not download");
        let got = e.materialize(&acc).unwrap();
        assert_close(&got, &tupled.grad_sum, 1e-4, 1e-4);

        // chaining: seeding with the previous output doubles the gradient
        let acc2 = e.grad_acc(loss, &lits, &w, &acc).unwrap();
        let got2 = e.materialize(&acc2).unwrap();
        let expect: Vec<f32> = tupled.grad_sum.iter().map(|&g| 2.0 * g).collect();
        assert_close(&got2, &expect, 1e-3, 1e-3);
    }
}

#[test]
fn chained_vr_state_round_trips() {
    let mut e = engine();
    let d = 64;
    let x0: Vec<f32> = (0..d).map(|j| j as f32 * 0.01).collect();
    let s = e.vr_state_from(&x0).unwrap();
    assert_eq!(s.dims(), [2, d]);
    let host = e.materialize(&s).unwrap();
    assert_close(&host[..d], &x0, 0.0, 0.0);
    assert!(host[d..].iter().all(|&a| a == 0.0), "fresh accumulator must be zero");
    // vr_avg with inv weight 0 falls back to the carried iterate
    let fallback = e.vr_avg(&s, 0.0).unwrap();
    let got = e.materialize(&fallback).unwrap();
    assert_close(&got, &x0, 0.0, 0.0);
}

#[test]
fn dev_iterate_grad_matches_host_iterate_grad() {
    // grad_block_dev (aliased device iterate) == grad_block (host iterate)
    let mut e = engine();
    let d = 64;
    let (lits, _, _, _) = make_lits(&mut e, Loss::Squared, d, 120, 44);
    let w_host: Vec<f32> = (0..d).map(|j| (j as f32 * 0.04).sin() * 0.2).collect();
    let host_out = e.grad_block(Loss::Squared, &lits, &w_host).unwrap();
    let w_dev = e.upload_dev(&w_host, &[d]).unwrap();
    let aliases_before = e.stats.alias_installs;
    let uploads_before = e.stats.uploads;
    let dev_out = e.grad_block_dev(Loss::Squared, &lits, &w_dev).unwrap();
    assert_eq!(e.stats.alias_installs, aliases_before + 1, "device iterate must alias");
    assert_eq!(e.stats.uploads, uploads_before, "aliasing must not upload");
    // the aliased buffer holds the identical bits: identical outputs
    assert_eq!(host_out.grad_sum, dev_out.grad_sum);
    assert_eq!(host_out.loss_sum, dev_out.loss_sum);
    assert_eq!(host_out.count, dev_out.count);
}

#[test]
fn engine_stats_accumulate() {
    let mut e = engine();
    let (lits, _, _, _) = make_lits(&mut e, Loss::Squared, 64, 50, 2);
    let w = vec![0.0f32; 64];
    let before = e.stats.executions;
    for _ in 0..5 {
        e.grad_block(Loss::Squared, &lits, &w).unwrap();
    }
    assert_eq!(e.stats.executions, before + 5);
    assert!(e.mean_execute_ns() > 0.0);
}
