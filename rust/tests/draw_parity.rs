//! DataPlane draw-path parity: the draw verb must be a pure relocation.
//!
//! Per-machine streams are independent forks, so moving a machine's
//! stream to its owning shard (where the draw verb generates AND packs
//! with no coordinator-side sample materialization) must change NOTHING:
//! drawn samples, iterates, objective curves, and the sample/memory
//! meters are bit-identical between the sequential (chained) plane and
//! the sharded plane at every shard count — for a streaming scenario and
//! a finite-ERM scenario (short ragged epoch-boundary batches included)
//! from the registry. The host plane draws the identical samples and
//! charges the identical sample/memory meters (its kernels differ
//! numerically, so iterates are pinned to tolerance only).
//!
//! Requires `make artifacts`.

use mbprox::accounting::ClusterMeter;
use mbprox::algos::RunResult;
use mbprox::comm::{netmodel::NetModel, Network};
use mbprox::config::ExperimentConfig;
use mbprox::coordinator::Runner;
use mbprox::data::scenario::{self, ScenarioParams};
use mbprox::data::Loss;
use mbprox::objective::{mean_grad_chained_host, MachineBatch};
use mbprox::runtime::{Engine, PlanePolicy, ShardPool};
use mbprox::util::testkit::assert_close;
use std::path::PathBuf;

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// Run `cfg` on a fresh engine under an explicit plane policy (and pool).
fn run_with(policy: PlanePolicy, shards: Option<usize>, cfg: &ExperimentConfig) -> RunResult {
    let dir = artifacts_dir();
    let mut r = Runner::new(Engine::new(&dir).expect("run `make artifacts` before cargo test"))
        .with_plane(policy);
    if let Some(n) = shards {
        r = r.with_shards(ShardPool::new(n, &dir).expect("shard pool construction"));
    }
    r.run(cfg).unwrap_or_else(|e| {
        panic!("{} (plane={}, shards={shards:?}): {e:?}", cfg.method, policy.as_str())
    })
}

fn bits32(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// Full bitwise identity: iterates, meters (incl. per-machine peaks),
/// curves, simulated time.
fn assert_identical(a: &RunResult, b: &RunResult, label: &str) {
    assert_eq!(bits32(&a.w), bits32(&b.w), "{label}: final iterate bits");
    assert_eq!(a.report, b.report, "{label}: ClusterMeter report");
    assert_eq!(a.sim_time_s.to_bits(), b.sim_time_s.to_bits(), "{label}: simulated time");
    assert_eq!(a.curve.len(), b.curve.len(), "{label}: curve length");
    for (p, q) in a.curve.iter().zip(&b.curve) {
        assert_eq!(p.samples_total, q.samples_total, "{label}: curve samples");
        assert_eq!(p.comm_rounds, q.comm_rounds, "{label}: curve rounds");
        assert_eq!(p.vec_ops, q.vec_ops, "{label}: curve vec ops");
        match (p.objective, q.objective) {
            (Some(x), Some(y)) => {
                assert_eq!(x.to_bits(), y.to_bits(), "{label}: objective bits")
            }
            (None, None) => {}
            other => panic!("{label}: objective presence mismatch {other:?}"),
        }
    }
}

/// The draw side of the host plane: identical samples drawn, identical
/// sample/memory charges; iterates only numerically equivalent (host
/// kernels).
fn assert_draws_identical(host: &RunResult, chained: &RunResult, label: &str) {
    assert_eq!(
        host.report.total_samples, chained.report.total_samples,
        "{label}: samples are draw-determined, not plane-determined"
    );
    assert_eq!(
        host.report.peak_per_machine, chained.report.peak_per_machine,
        "{label}: per-machine memory peaks are draw-determined"
    );
    assert_close(&host.w, &chained.w, 2e-2, 2e-3);
    match (host.final_objective, chained.final_objective) {
        (Some(x), Some(y)) => {
            let rel = (x - y).abs() / y.abs().max(1e-9);
            assert!(rel < 2e-2, "{label}: final objective {x} vs {y} (rel {rel:.2e})");
        }
        (None, None) => {}
        other => panic!("{label}: final objective mismatch {other:?}"),
    }
}

/// The parity harness: sequential (chained) baseline vs sharded draws at
/// shards ∈ {1, 2, 4}, plus the host plane's draw-side identity.
fn draw_parity(cfg: &ExperimentConfig) {
    let seq = run_with(PlanePolicy::Chained, None, cfg);
    for n in [1usize, 2, 4] {
        let sharded = run_with(PlanePolicy::Sharded, Some(n), cfg);
        assert_identical(&seq, &sharded, &format!("{}[{}] shards={n}", cfg.method, cfg.b_local));
    }
    let host = run_with(PlanePolicy::Host, None, cfg);
    assert_draws_identical(&host, &seq, &format!("{} host draws", cfg.method));
}

#[test]
fn streaming_scenario_drift_ragged() {
    // b = 300 -> one full block + a 44-row ragged tail per machine draw
    let cfg = ExperimentConfig {
        method: "mp-dsvrg".into(),
        scenario: Some("drift".into()),
        loss: Loss::Squared,
        m: 4,
        b_local: 300,
        n_budget: 2400, // T = 2
        dim: 64,
        seed: 20170707,
        eval_samples: 1024,
        eval_every: 1,
        ..ExperimentConfig::default()
    };
    draw_parity(&cfg);
}

#[test]
fn erm_scenario_fixed_short_epoch_batches() {
    // 2051 fixed samples shard 513/513/513/512; per-machine draws of
    // ceil(2051/4) = 513 leave machine 3 one short — the honest ragged
    // epoch boundary must meter identically on every plane
    let cfg = ExperimentConfig {
        method: "dsvrg-erm".into(),
        scenario: Some("erm-fixed".into()),
        loss: Loss::Squared,
        m: 4,
        b_local: 256,
        n_budget: 2051,
        dim: 64,
        seed: 20170707,
        eval_samples: 1024,
        eval_every: 1,
        ..ExperimentConfig::default()
    };
    let seq = run_with(PlanePolicy::Chained, None, &cfg);
    // the short draw is real: total samples < ceil(n/m) * m
    assert!(
        seq.report.total_samples < 513 * 4,
        "expected a short epoch-boundary draw, got {} samples",
        seq.report.total_samples
    );
    assert_eq!(
        seq.report.peak_per_machine.iter().filter(|&&p| p < seq.report.peak_vectors).count(),
        1,
        "exactly one machine drew (and held) short: {:?}",
        seq.report.peak_per_machine
    );
    draw_parity(&cfg);
}

/// Sample-level pinning: the batches a sharded context draws carry the
/// EXACT samples of the family's coordinator-side forks. Both sides run
/// the identical chained-kernel mean gradient (bit-identical across
/// engines by the Grouped-lane contract), so equal gradient bits ⟺ equal
/// drawn + packed samples.
#[test]
fn sharded_draw_packs_expected_fork_samples() {
    let dir = artifacts_dir();
    let (d, m, b) = (64usize, 4usize, 300usize);
    let params = ScenarioParams {
        dim: d,
        loss: Loss::Squared,
        seed: 99,
        m,
        n_budget: 4096,
        data_path: None,
        drift_omega: None,
        pareto_alpha: None,
        sparse_density: None,
    };
    let family = scenario::by_name("heavy-tail").unwrap().build(&params).unwrap();
    let w: Vec<f32> = (0..d).map(|j| (j as f32 * 0.1).cos() * 0.05).collect();

    // expected: fork each machine's stream on the coordinator, pack on a
    // fresh engine, fold through the chained mean gradient
    let g_expected = {
        let mut engine = Engine::new(&dir).expect("engine");
        let batches: Vec<MachineBatch> = (0..m)
            .map(|i| {
                let samples = family.fork_stream(i as u64).draw_many(b);
                assert_eq!(samples.len(), b);
                MachineBatch::pack_grad_only(&mut engine, d, &samples).unwrap()
            })
            .collect();
        let mut net = Network::new(m, NetModel::default());
        let mut meter = ClusterMeter::new(m);
        mean_grad_chained_host(&mut engine, None, Loss::Squared, &batches, &w, &mut net, &mut meter)
            .unwrap()
    };

    // actual: a sharded context draws the same forks ON THE SHARDS
    let mut r = Runner::new(Engine::new(&dir).expect("engine"))
        .with_plane(PlanePolicy::Sharded)
        .with_shards(ShardPool::new(2, &dir).expect("pool"));
    let cfg = ExperimentConfig {
        method: "minibatch-sgd".into(),
        scenario: Some("heavy-tail".into()),
        loss: Loss::Squared,
        m,
        b_local: b,
        dim: d,
        seed: 99,
        eval_samples: 64,
        ..ExperimentConfig::default()
    };
    let mut ctx = r.context(&cfg).unwrap();
    let batches = ctx.draw_batches_grad_only(b, false).unwrap();
    assert!(batches.iter().all(|bt| bt.shard.is_some()), "sharded draws return stubs");
    let g_actual = {
        let mut net = Network::new(m, NetModel::default());
        mean_grad_chained_host(
            ctx.plane.engine,
            ctx.plane.shards,
            Loss::Squared,
            &batches,
            &w,
            &mut net,
            &mut ctx.meter,
        )
        .unwrap()
    };
    assert_eq!(
        bits32(&g_expected),
        bits32(&g_actual),
        "shard-drawn batches must hold the forks' exact samples"
    );
    // and the draw charged exactly what was drawn
    let rep = ctx.meter.report();
    assert_eq!(rep.total_samples, (m * b) as u64);
}

/// The coordinator's method/scenario pairing guard and the registry's
/// did-you-mean rejection, through the public Runner API.
#[test]
fn scenario_pairing_and_typos_are_rejected() {
    let dir = artifacts_dir();
    let mut r = Runner::new(Engine::new(&dir).expect("engine"));
    let base = ExperimentConfig {
        n_budget: 512,
        b_local: 64,
        eval_samples: 64,
        ..ExperimentConfig::default()
    };
    // streaming method on a finite-ERM scenario: loud rejection
    let cfg = ExperimentConfig {
        method: "mp-dsvrg".into(),
        scenario: Some("erm-fixed".into()),
        ..base.clone()
    };
    let err = r.run(&cfg).unwrap_err().to_string();
    assert!(err.contains("streaming-SO"), "{err}");
    // an ERM method on the same scenario runs
    let cfg = ExperimentConfig {
        method: "dsvrg-erm".into(),
        scenario: Some("erm-fixed".into()),
        ..base.clone()
    };
    r.run(&cfg).expect("ERM method on finite-ERM scenario");
    // unknown scenario names get the did-you-mean treatment
    let cfg = ExperimentConfig { scenario: Some("drfit".into()), ..base };
    let err = r.run(&cfg).unwrap_err().to_string();
    assert!(err.contains("did you mean 'drift'"), "{err}");
}
