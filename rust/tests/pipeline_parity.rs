//! Fan-pipeline parity: `pipeline=on` vs `pipeline=off` is a pure
//! scheduling change inside each shard worker. The pipelined loop sends
//! machine k+1's lane request only AFTER collecting machine k's reply,
//! so the lane command FIFO sees the identical arrival order either way
//! and every machine receives the exact samples the serial loop would
//! have drawn. Iterates, objective curves, sample/memory meters, and
//! simulated time are therefore bit-identical across
//! {pipeline on/off} x {prefetch on/off} x shard counts, for streaming
//! and finite-ERM (ragged epoch boundary) scenarios, and under
//! mismatched draw sizes. Only the wall-clock [`StallMeter`] /
//! [`OverlapMeter`] pair may differ (excluded from the parity surface —
//! see `runtime::shard`).
//!
//! Requires `make artifacts`.

use mbprox::algos::RunResult;
use mbprox::comm::{netmodel::NetModel, Network};
use mbprox::config::ExperimentConfig;
use mbprox::coordinator::Runner;
use mbprox::data::Loss;
use mbprox::objective::mean_grad_chained_host;
use mbprox::runtime::{Engine, PipelinePolicy, PlanePolicy, PrefetchPolicy, ShardPool};
use std::path::PathBuf;

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// Run `cfg` on a fresh sharded runner under explicit pipeline and
/// prefetch policies.
fn run_with(
    pipeline: PipelinePolicy,
    prefetch: PrefetchPolicy,
    shards: usize,
    cfg: &ExperimentConfig,
) -> RunResult {
    let dir = artifacts_dir();
    let mut r = Runner::new(Engine::new(&dir).expect("run `make artifacts` before cargo test"))
        .with_plane(PlanePolicy::Sharded)
        .with_shards(ShardPool::new(shards, &dir).expect("shard pool construction"))
        .with_prefetch(prefetch)
        .with_pipeline(pipeline);
    r.run(cfg).unwrap_or_else(|e| {
        panic!(
            "{} (pipeline={}, prefetch={}, shards={shards}): {e:?}",
            cfg.method,
            pipeline.as_str(),
            prefetch.as_str()
        )
    })
}

fn bits32(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// Full bitwise identity on everything except the wall-clock meters.
fn assert_identical(a: &RunResult, b: &RunResult, label: &str) {
    assert_eq!(bits32(&a.w), bits32(&b.w), "{label}: final iterate bits");
    assert_eq!(a.report, b.report, "{label}: ClusterMeter report");
    assert_eq!(a.sim_time_s.to_bits(), b.sim_time_s.to_bits(), "{label}: simulated time");
    assert_eq!(a.curve.len(), b.curve.len(), "{label}: curve length");
    for (p, q) in a.curve.iter().zip(&b.curve) {
        assert_eq!(p.samples_total, q.samples_total, "{label}: curve samples");
        assert_eq!(p.comm_rounds, q.comm_rounds, "{label}: curve rounds");
        assert_eq!(p.vec_ops, q.vec_ops, "{label}: curve vec ops");
        match (p.objective, q.objective) {
            (Some(x), Some(y)) => {
                assert_eq!(x.to_bits(), y.to_bits(), "{label}: objective bits")
            }
            (None, None) => {}
            other => panic!("{label}: objective presence mismatch {other:?}"),
        }
    }
}

/// The full policy cross-product at shards ∈ {1, 2, 4} — the
/// (off, off, shards=1) run is the one reference every other leg must
/// match bit for bit.
fn pipeline_parity(cfg: &ExperimentConfig) {
    let reference = run_with(PipelinePolicy::Off, PrefetchPolicy::Off, 1, cfg);
    for n in [1usize, 2, 4] {
        for pipeline in [PipelinePolicy::Off, PipelinePolicy::On] {
            for prefetch in [PrefetchPolicy::Off, PrefetchPolicy::On] {
                let run = run_with(pipeline, prefetch, n, cfg);
                let label = format!(
                    "{} pipeline={} prefetch={} shards={n}",
                    cfg.method,
                    pipeline.as_str(),
                    prefetch.as_str()
                );
                assert_identical(&reference, &run, &label);
            }
        }
    }
}

#[test]
fn streaming_drift_pipeline_parity() {
    // b = 300 -> one full block + a 44-row ragged tail per machine draw;
    // with m=4 over <= 4 shards every worker owns >= 1 machine and the
    // 2-shard legs pipeline 2 machines per fan
    let cfg = ExperimentConfig {
        method: "mp-dsvrg".into(),
        scenario: Some("drift".into()),
        loss: Loss::Squared,
        m: 4,
        b_local: 300,
        n_budget: 2400, // T = 2
        dim: 64,
        seed: 20170707,
        eval_samples: 1024,
        eval_every: 1,
        ..ExperimentConfig::default()
    };
    pipeline_parity(&cfg);
}

#[test]
fn erm_fixed_ragged_epoch_pipeline_parity() {
    // 2051 fixed samples shard 513/513/513/512: the epoch-bounded streams
    // return honestly-short boundary batches; the pipelined window must
    // carry those short replies through unchanged
    let cfg = ExperimentConfig {
        method: "dsvrg-erm".into(),
        scenario: Some("erm-fixed".into()),
        loss: Loss::Squared,
        m: 4,
        b_local: 256,
        n_budget: 2051,
        dim: 64,
        seed: 20170707,
        eval_samples: 1024,
        eval_every: 1,
        // the config-key path (rather than Runner::with_pipeline): the
        // per-run key must beat the runner's process-level policy
        pipeline: PipelinePolicy::On,
        ..ExperimentConfig::default()
    };
    let via_cfg = {
        let dir = artifacts_dir();
        let mut r = Runner::new(Engine::new(&dir).expect("engine"))
            .with_plane(PlanePolicy::Sharded)
            .with_shards(ShardPool::new(2, &dir).expect("pool"))
            .with_pipeline(PipelinePolicy::Off); // cfg key must win
        r.run(&cfg).expect("erm-fixed with pipeline=on from the config")
    };
    let cfg_default = ExperimentConfig { pipeline: PipelinePolicy::Auto, ..cfg.clone() };
    let off = run_with(PipelinePolicy::Off, PrefetchPolicy::Off, 2, &cfg_default);
    assert_identical(&off, &via_cfg, "erm-fixed cfg-key pipeline=on");
    // the cfg-key run really pipelined: its overlap meter staged packs
    let o = via_cfg.overlap.expect("sharded runs surface an overlap meter");
    assert!(o.staged > 0, "cfg-key pipeline=on run never staged a pack: {o:?}");
    pipeline_parity(&cfg_default);
}

/// Mismatched draw sizes ride the same lane re-split machinery as the
/// prefetch stage: a pipelined request window must tear down and re-serve
/// leftovers in draw order exactly like the serial loop. The packed
/// gradients (chained kernels: bit-identical across engines) pin the
/// served samples bit for bit.
#[test]
fn mismatched_draw_sizes_pipelined_bitwise() {
    let grads_with = |pipeline: PipelinePolicy| -> Vec<Vec<u32>> {
        let dir = artifacts_dir();
        let (d, m) = (64usize, 4usize);
        let mut r = Runner::new(Engine::new(&dir).expect("engine"))
            .with_plane(PlanePolicy::Sharded)
            .with_shards(ShardPool::new(2, &dir).expect("pool"))
            .with_prefetch(PrefetchPolicy::On)
            .with_pipeline(pipeline);
        let cfg = ExperimentConfig {
            method: "minibatch-sgd".into(),
            scenario: Some("heavy-tail".into()),
            loss: Loss::Squared,
            m,
            b_local: 300,
            dim: d,
            seed: 99,
            eval_samples: 64,
            ..ExperimentConfig::default()
        };
        let mut ctx = r.context(&cfg).unwrap();
        let w: Vec<f32> = (0..d).map(|j| (j as f32 * 0.1).cos() * 0.05).collect();
        // 300 stages 300; asking 200 splits the stage; 44 rides the
        // leftover tail; 300 spans leftovers + a fresh draw
        [300usize, 200, 44, 300]
            .into_iter()
            .map(|b| {
                let batches = ctx.draw_batches_grad_only(b, false).unwrap();
                let mut net = Network::new(m, NetModel::default());
                let g = mean_grad_chained_host(
                    ctx.plane.engine,
                    ctx.plane.shards,
                    Loss::Squared,
                    &batches,
                    &w,
                    &mut net,
                    &mut ctx.meter,
                )
                .unwrap();
                bits32(&g)
            })
            .collect()
    };
    let off = grads_with(PipelinePolicy::Off);
    let on = grads_with(PipelinePolicy::On);
    assert_eq!(off, on, "pipelined draw windows must preserve draw order bit for bit");
}

/// The overlap meter itself: surfaced on sharded runs, honest about the
/// policy that ran, and never part of the parity surface above.
#[test]
fn overlap_meter_reports_the_policy_that_ran() {
    let cfg = ExperimentConfig {
        method: "minibatch-sgd".into(),
        scenario: Some("drift".into()),
        loss: Loss::Squared,
        m: 4,
        b_local: 256,
        n_budget: 4096, // 4 outer steps of drawing
        dim: 64,
        seed: 11,
        eval_samples: 64,
        eval_every: 0,
        ..ExperimentConfig::default()
    };
    let off = run_with(PipelinePolicy::Off, PrefetchPolicy::Off, 2, &cfg);
    let o_off = off.overlap.expect("sharded runs surface an overlap meter");
    assert!(o_off.fans > 0, "batched fans must run regardless of policy");
    assert_eq!(o_off.staged, 0, "pipeline=off never stages a pack");
    assert_eq!(o_off.overlap_ns, 0, "pipeline=off never overlaps pack work");

    let on = run_with(PipelinePolicy::On, PrefetchPolicy::Off, 2, &cfg);
    let o_on = on.overlap.expect("sharded runs surface an overlap meter");
    // batching is unconditional: the fan count is policy-independent
    assert_eq!(o_on.fans, o_off.fans, "fan count must not depend on the pipeline policy");
    // 2 machines per shard -> every fan's first pack runs staged
    assert!(o_on.staged > 0, "pipeline=on staged no packs: {o_on:?}");
    // identical draw schedule either way, as the stall meter sees it
    let (s_off, s_on) = (off.stalls.expect("stalls"), on.stalls.expect("stalls"));
    assert_eq!(s_on.takes, s_off.takes, "identical draw schedule either way");
}
