//! Integration: every method converges on small planted problems, and the
//! measured resource profiles satisfy the Table-1 ordering relations.

use mbprox::config::ExperimentConfig;
use mbprox::coordinator::Runner;
use mbprox::data::Loss;
use mbprox::runtime::Engine;

fn runner() -> Runner {
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    Runner::new(Engine::new(&dir).expect("run `make artifacts` first"))
        .with_env_shards(&dir)
        .expect("shard pool construction")
        .with_env_plane()
        .expect("PLANE policy")
}

fn small_cfg() -> ExperimentConfig {
    ExperimentConfig {
        m: 4,
        b_local: 256,
        n_budget: 16_384,
        loss: Loss::Squared,
        dim: 64,
        seed: 20170707,
        eval_samples: 2048,
        eval_every: 0,
        ..ExperimentConfig::default()
    }
}

/// The planted least-squares problem has Bayes objective sigma^2/2 = 0.005;
/// starting objective at w=0 is ~0.5 (E[y^2]/2). A converging method must
/// close most of that gap with 16k samples.
fn assert_converged(obj: f64, floor: f64, start: f64, frac: f64, name: &str) {
    let progress = (start - obj) / (start - floor);
    assert!(
        progress > frac,
        "{name}: objective {obj:.5} (floor {floor:.5}, start {start:.5}) progress {progress:.3} <= {frac}"
    );
}

#[test]
fn mp_dsvrg_converges_squared() {
    let mut r = runner();
    let cfg = ExperimentConfig { method: "mp-dsvrg".into(), ..small_cfg() };
    let res = r.run(&cfg).unwrap();
    let obj = res.final_objective.unwrap();
    assert_converged(obj, 0.005, 0.5, 0.9, "mp-dsvrg");
    // memory: each machine holds ~b_local sample vectors at peak
    let mem = res.report.peak_vectors;
    assert!(mem >= 256 && mem < 2 * 256 + 16, "peak memory {mem} not ~b");
}

#[test]
fn mp_dane_converges_squared() {
    let mut r = runner();
    let cfg = ExperimentConfig { method: "mp-dane".into(), ..small_cfg() };
    let res = r.run(&cfg).unwrap();
    assert_converged(res.final_objective.unwrap(), 0.005, 0.5, 0.9, "mp-dane");
}

#[test]
fn mp_dane_saga_converges_squared() {
    let mut r = runner();
    let cfg = ExperimentConfig { method: "mp-dane-saga".into(), ..small_cfg() };
    let res = r.run(&cfg).unwrap();
    assert_converged(res.final_objective.unwrap(), 0.005, 0.5, 0.9, "mp-dane-saga");
}

#[test]
fn mp_exact_converges_squared() {
    let mut r = runner();
    let cfg = ExperimentConfig { method: "mp-exact".into(), ..small_cfg() };
    let res = r.run(&cfg).unwrap();
    assert_converged(res.final_objective.unwrap(), 0.005, 0.5, 0.9, "mp-exact");
}

#[test]
fn mp_oneshot_converges_squared() {
    let mut r = runner();
    let cfg = ExperimentConfig { method: "mp-oneshot".into(), ..small_cfg() };
    let res = r.run(&cfg).unwrap();
    assert_converged(res.final_objective.unwrap(), 0.005, 0.5, 0.8, "mp-oneshot");
}

#[test]
fn minibatch_sgd_converges_squared() {
    let mut r = runner();
    let cfg = ExperimentConfig { method: "minibatch-sgd".into(), b_local: 64, ..small_cfg() };
    let res = r.run(&cfg).unwrap();
    // theory caps minibatch SGD here: the beta B^2 / (2T) term of Prop. 13
    // is ~0.5/T at B=8, so 0.7 progress is the right bar at this budget
    assert_converged(res.final_objective.unwrap(), 0.005, 0.5, 0.7, "minibatch-sgd");
}

#[test]
fn accel_sgd_converges_squared() {
    let mut r = runner();
    let cfg =
        ExperimentConfig { method: "acc-minibatch-sgd".into(), b_local: 64, ..small_cfg() };
    let res = r.run(&cfg).unwrap();
    assert_converged(res.final_objective.unwrap(), 0.005, 0.5, 0.7, "acc-minibatch-sgd");
}

#[test]
fn local_sgd_converges_squared() {
    let mut r = runner();
    let cfg = ExperimentConfig { method: "local-sgd".into(), m: 1, ..small_cfg() };
    let res = r.run(&cfg).unwrap();
    assert_converged(res.final_objective.unwrap(), 0.005, 0.5, 0.7, "local-sgd");
    assert_eq!(res.report.comm_rounds, 0, "single-machine method must not communicate");
}

#[test]
fn erm_methods_converge_squared() {
    let mut r = runner();
    for method in ["dsvrg-erm", "dane-erm", "agd-erm", "disco-erm"] {
        let cfg = ExperimentConfig { method: method.into(), ..small_cfg() };
        let res = r.run(&cfg).unwrap();
        assert_converged(res.final_objective.unwrap(), 0.005, 0.5, 0.8, method);
        // batch methods hold their shard for the whole run: memory ~= n/m
        let expect = (cfg.n_budget / cfg.m) as u64;
        assert!(
            res.report.peak_vectors >= expect,
            "{method}: peak {} < shard size {expect}",
            res.report.peak_vectors
        );
    }
}

#[test]
fn logistic_methods_converge() {
    let mut r = runner();
    for method in ["mp-dsvrg", "mp-dane", "minibatch-sgd"] {
        // minibatch SGD cannot use b=256 without stalling (the paper's
        // core comparison!) — give it its optimal small batch instead.
        let b_local = if method == "minibatch-sgd" { 16 } else { 256 };
        let cfg = ExperimentConfig {
            method: method.into(),
            loss: Loss::Logistic,
            n_budget: 16_384,
            b_local,
            ..small_cfg()
        };
        let res = r.run(&cfg).unwrap();
        let obj = res.final_objective.unwrap();
        // Logistic floor on this planted model is ~0.33 (Bayes cross
        // entropy of sigmoid(z), z~N(0,4), +5% flips); the Theorem-7 rate
        // bound at n=16384 with B=2 sqrt(d)=16 adds ~0.26. Start is ln 2.
        let start = std::f64::consts::LN_2;
        assert!(
            obj < 0.62,
            "{method} (logistic): objective {obj:.4} too far from floor (start {start:.4})"
        );
        assert!(obj > 0.25, "{method} (logistic): objective {obj:.4} below plausible floor");
    }
}

#[test]
fn table1_orderings_hold() {
    // The core qualitative claims of Table 1 measured on a shared budget:
    //   comm(mp-dsvrg, large b) < comm(mp-dsvrg, small b)
    //   mem(mp-dsvrg, b) ~ b  and  mem(dsvrg-erm) ~ n/m >> b_small
    //   comm(dsvrg-erm) < comm(minibatch-sgd, small b)
    let mut r = runner();
    let base = small_cfg();

    let run = |r: &mut Runner, method: &str, b: usize| {
        let cfg = ExperimentConfig { method: method.into(), b_local: b, ..base.clone() };
        r.run(&cfg).unwrap()
    };

    let mp_small = run(&mut r, "mp-dsvrg", 256);
    let mp_large = run(&mut r, "mp-dsvrg", 2048);
    let sgd = run(&mut r, "minibatch-sgd", 64);
    let dsvrg = run(&mut r, "dsvrg-erm", 256);

    assert!(
        mp_large.report.comm_rounds < mp_small.report.comm_rounds,
        "larger b must reduce MP-DSVRG communication: {} vs {}",
        mp_large.report.comm_rounds,
        mp_small.report.comm_rounds
    );
    assert!(
        mp_large.report.peak_vectors > mp_small.report.peak_vectors,
        "larger b must increase MP-DSVRG memory"
    );
    assert!(
        dsvrg.report.comm_rounds < sgd.report.comm_rounds,
        "DSVRG-ERM must communicate less than small-b minibatch SGD: {} vs {}",
        dsvrg.report.comm_rounds,
        sgd.report.comm_rounds
    );
    assert!(
        dsvrg.report.peak_vectors > mp_small.report.peak_vectors,
        "DSVRG-ERM memory (n/m) must exceed MP-DSVRG memory (b)"
    );
}

#[test]
fn exact_and_inexact_prox_agree() {
    // With generous inner budgets, MP-DSVRG and MP-exact trajectories land
    // at comparable objectives (Theorem 7: inexactness doesn't change the
    // rate when subproblems are solved accurately enough).
    let mut r = runner();
    let cfg_e = ExperimentConfig { method: "mp-exact".into(), ..small_cfg() };
    let cfg_d = ExperimentConfig { method: "mp-dsvrg".into(), ..small_cfg() };
    let oe = r.run(&cfg_e).unwrap().final_objective.unwrap();
    let od = r.run(&cfg_d).unwrap().final_objective.unwrap();
    let rel = (od - oe).abs() / oe;
    assert!(rel < 0.25, "exact {oe:.5} vs dsvrg {od:.5} differ by {rel:.2}");
}
