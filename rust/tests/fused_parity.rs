//! Golden parity: the fused multi-block (`gradm{K}`/`nmm{K}`) dispatch
//! path and the session-cached upload path must reproduce the per-block
//! reference path across padded, ragged and empty blocks on both losses.
//! Requires `make artifacts` (the Makefile `test` target guarantees it).

use mbprox::accounting::ClusterMeter;
use mbprox::algos::solvers::{vr_sweep_machine, vr_sweep_machine_grouped, LocalSolver};
use mbprox::comm::{netmodel::NetModel, Network};
use mbprox::data::blocks::{pack_all, BLOCK_ROWS};
use mbprox::data::synth::{SynthSpec, SynthStream};
use mbprox::data::{Loss, Sample, SampleStream};
use mbprox::objective::{distributed_mean_grad, local_grad_sum, MachineBatch};
use mbprox::runtime::exec::{BlockLits, GradOut};
use mbprox::runtime::Engine;
use mbprox::util::testkit::assert_close;

fn engine() -> Engine {
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    Engine::new(&dir).expect("run `make artifacts` before cargo test")
}

fn draw(loss: Loss, d: usize, n: usize, seed: u64) -> Vec<Sample> {
    let spec = match loss {
        Loss::Squared => SynthSpec::least_squares(d),
        Loss::Logistic => SynthSpec::logistic(d),
    };
    SynthStream::new(spec, seed).draw_many(n)
}

/// The seed engine's reference: one dispatch per 256-row block, host axpy.
fn per_block_grad(e: &mut Engine, loss: Loss, samples: &[Sample], d: usize, w: &[f32]) -> GradOut {
    let blocks = pack_all(samples, d);
    let mut g = vec![0.0f32; d];
    let mut lsum = 0.0;
    let mut cnt = 0.0;
    for b in &blocks {
        let lits = BlockLits::from_block(e, b).unwrap();
        let out = e.grad_block(loss, &lits, w).unwrap();
        for j in 0..d {
            g[j] += out.grad_sum[j];
        }
        lsum += out.loss_sum;
        cnt += out.count;
    }
    GradOut { grad_sum: g, loss_sum: lsum, count: cnt }
}

#[test]
fn fused_grad_matches_per_block_path() {
    let mut e = engine();
    assert!(!e.fuse_widths().is_empty(), "manifest should carry gradm/nmm artifacts");
    let d = 64;
    // exact multiples of the widths, ragged tails, sub-width, and empty
    for n in [0usize, 100, 256, 4 * 256, 8 * 256, 5 * 256 + 60, 9 * 256 + 1] {
        for loss in [Loss::Squared, Loss::Logistic] {
            let samples = draw(loss, d, n, 42 + n as u64);
            let w: Vec<f32> = (0..d).map(|j| ((j % 5) as f32 - 2.0) * 0.05).collect();
            let reference = per_block_grad(&mut e, loss, &samples, d, &w);
            let batch = MachineBatch::pack(&mut e, d, &samples).unwrap();
            let mut meter = ClusterMeter::new(1);
            let fused = local_grad_sum(&mut e, loss, &batch, &w, meter.machine(0)).unwrap();
            assert_eq!(fused.count, reference.count, "count n={n}");
            assert_eq!(fused.count, n as f64);
            assert_close(&fused.grad_sum, &reference.grad_sum, 1e-3, 1e-3);
            assert!(
                (fused.loss_sum - reference.loss_sum).abs()
                    / reference.loss_sum.abs().max(1.0)
                    < 1e-3,
                "loss n={n} fused={} ref={}",
                fused.loss_sum,
                reference.loss_sum
            );
        }
    }
}

#[test]
fn fused_groups_cover_blocks_with_ragged_tail() {
    let mut e = engine();
    let widths: Vec<usize> = e.fuse_widths().to_vec();
    let d = 64;
    // 9 blocks + a partial: greedy grouping must cover every block exactly
    let n = 9 * BLOCK_ROWS + 17;
    let samples = draw(Loss::Squared, d, n, 3);
    let batch = MachineBatch::pack(&mut e, d, &samples).unwrap();
    assert_eq!(batch.n_blocks(), 10);
    let total_k: usize = batch.groups.iter().map(|g| g.k).sum();
    assert_eq!(total_k, batch.n_blocks());
    let total_valid: usize = batch.groups.iter().map(|g| g.valid).sum();
    assert_eq!(total_valid, n);
    for g in &batch.groups {
        assert_eq!(g.rows, g.k * BLOCK_ROWS);
        assert!(g.k == 1 || widths.contains(&g.k), "unexpected width {}", g.k);
    }
    if let Some(&widest) = widths.first() {
        assert_eq!(batch.groups[0].k, widest, "greedy packer starts widest");
    }
}

#[test]
fn fused_nm_matches_per_block_path() {
    let mut e = engine();
    let d = 64;
    let n = 6 * BLOCK_ROWS + 40; // ragged: one k=4 group + singles under (8,4)
    let samples = draw(Loss::Squared, d, n, 11);
    let v: Vec<f32> = (0..d).map(|j| (j as f32 * 0.03).sin()).collect();
    // reference per-block
    let blocks = pack_all(&samples, d);
    let mut expect = vec![0.0f32; d];
    let mut expect_cnt = 0.0;
    for b in &blocks {
        let lits = BlockLits::from_block(&mut e, b).unwrap();
        let (part, c) = e.nm_block(&lits, &v).unwrap();
        for j in 0..d {
            expect[j] += part[j];
        }
        expect_cnt += c;
    }
    // fused
    let batch = MachineBatch::pack(&mut e, d, &samples).unwrap();
    let mut got = vec![0.0f32; d];
    let mut got_cnt = 0.0;
    for g in &batch.groups {
        let (part, c) = e.nm_block(g, &v).unwrap();
        for j in 0..d {
            got[j] += part[j];
        }
        got_cnt += c;
    }
    assert_eq!(got_cnt, expect_cnt);
    assert_eq!(got_cnt, n as f64);
    assert_close(&got, &expect, 1e-3, 1e-3);
}

#[test]
fn cached_upload_path_is_bitwise_stable() {
    let mut e = engine();
    let d = 64;
    let samples = draw(Loss::Squared, d, 200, 5);
    let batch = MachineBatch::pack(&mut e, d, &samples).unwrap();
    let w: Vec<f32> = (0..d).map(|j| 0.01 * j as f32).collect();
    let first = e.grad_block(Loss::Squared, &batch.groups[0], &w).unwrap();
    let misses_before = e.stats.upload_cache_misses;
    let hits_before = e.stats.upload_cache_hits;
    let uploads_before = e.stats.uploads;
    // same w: the dispatch must reuse the resident buffer bit-for-bit
    let second = e.grad_block(Loss::Squared, &batch.groups[0], &w).unwrap();
    assert_eq!(e.stats.uploads, uploads_before, "unchanged w must not re-upload");
    assert_eq!(e.stats.upload_cache_misses, misses_before);
    assert_eq!(e.stats.upload_cache_hits, hits_before + 1);
    assert_eq!(first.grad_sum, second.grad_sum, "cached path must be bitwise identical");
    assert_eq!(first.loss_sum, second.loss_sum);
    assert_eq!(first.count, second.count);
    // changed w: exactly one refreshed upload, result tracks the new iterate
    let w2: Vec<f32> = w.iter().map(|x| x + 0.5).collect();
    let third = e.grad_block(Loss::Squared, &batch.groups[0], &w2).unwrap();
    assert_eq!(e.stats.uploads, uploads_before + 1);
    assert_eq!(e.stats.upload_cache_misses, misses_before + 1);
    assert_ne!(first.grad_sum, third.grad_sum);
    assert_eq!(e.session().generation("grad.w"), 2);
}

#[test]
fn vr_lits_upload_lazily_and_once() {
    let mut e = engine();
    let d = 64;
    let samples = draw(Loss::Squared, d, 3 * BLOCK_ROWS, 9);
    let batch = MachineBatch::pack(&mut e, d, &samples).unwrap();
    let after_pack = e.stats.uploads;
    // grad path never touches the per-block buffers
    let w = vec![0.02f32; d];
    let mut meter = ClusterMeter::new(1);
    local_grad_sum(&mut e, Loss::Squared, &batch, &w, meter.machine(0)).unwrap();
    assert_eq!(
        e.stats.uploads,
        after_pack + 1, // just the pooled w
        "grad path must not materialize per-block buffers"
    );
    // first VR access uploads the 3 blocks (x, y, mask each)...
    let n1 = batch.vr_lits(&mut e).unwrap().len();
    assert_eq!(n1, 3);
    let after_vr = e.stats.uploads;
    assert_eq!(after_vr, after_pack + 1 + 9);
    // ...and the second access reuses them
    let n2 = batch.vr_lits(&mut e).unwrap().len();
    assert_eq!(n2, 3);
    assert_eq!(e.stats.uploads, after_vr);
}

#[test]
fn grad_only_pack_serves_grad_but_refuses_vr() {
    let mut e = engine();
    let d = 64;
    let samples = draw(Loss::Squared, d, 300, 8);
    let batch = MachineBatch::pack_grad_only(&mut e, d, &samples).unwrap();
    let w = vec![0.01f32; d];
    let mut meter = ClusterMeter::new(1);
    let out = local_grad_sum(&mut e, Loss::Squared, &batch, &w, meter.machine(0)).unwrap();
    assert_eq!(out.count, 300.0);
    assert!(batch.vr_lits(&mut e).is_err(), "grad-only pack must refuse VR materialization");
}

#[test]
fn grouped_vr_sweep_matches_legacy_per_block_sweep() {
    // the group-aligned chained sweep vs the legacy per-block path on
    // ragged batches, both losses, both solvers (satellite: VR parity)
    let mut e = engine();
    let d = 64;
    // ragged: 5 full blocks + a 60-row tail -> one k=4 group + two k=1
    for loss in [Loss::Squared, Loss::Logistic] {
        for solver in [LocalSolver::Svrg, LocalSolver::Saga] {
            let samples = draw(loss, d, 5 * BLOCK_ROWS + 60, 17);
            let x0: Vec<f32> = (0..d).map(|j| 0.01 * (j as f32 - 30.0)).collect();
            let z: Vec<f32> = (0..d).map(|j| (j as f32 * 0.05).cos() * 0.1).collect();
            let mu: Vec<f32> = (0..d).map(|j| (j as f32 * 0.03).sin() * 0.1).collect();
            let center = vec![0.0f32; d];
            let (gamma, eta) = (0.5f32, 0.03f32);

            let (xe_legacy, xa_legacy, legacy_ops) = {
                let batch = MachineBatch::pack(&mut e, d, &samples).unwrap();
                let mut meter = ClusterMeter::new(1);
                let blocks = 0..batch.n_blocks();
                let (xe, xa) = vr_sweep_machine(
                    &mut e,
                    loss,
                    solver,
                    blocks,
                    &batch,
                    &x0,
                    &z,
                    &mu,
                    &center,
                    gamma,
                    eta,
                    meter.machine(0),
                )
                .unwrap();
                (xe, xa, meter.report().vec_ops)
            };

            let (xe_grouped, xa_grouped, grouped_ops) = {
                let batch = MachineBatch::pack_grad_only(&mut e, d, &samples).unwrap();
                let mut meter = ClusterMeter::new(1);
                let groups = 0..batch.n_groups();
                let (xe, xa) = vr_sweep_machine_grouped(
                    &mut e,
                    loss,
                    solver,
                    groups,
                    &batch,
                    &x0,
                    &z,
                    &mu,
                    &center,
                    gamma,
                    eta,
                    meter.machine(0),
                )
                .unwrap();
                (xe, xa, meter.report().vec_ops)
            };

            // the carried iterate is near-bitwise (the host round-trip the
            // chain replaces was lossless); the average tolerates the f32
            // on-device accumulator
            assert_close(&xe_grouped, &xe_legacy, 1e-5, 1e-6);
            assert_close(&xa_grouped, &xa_legacy, 1e-4, 1e-5);
            assert_eq!(grouped_ops, legacy_ops, "identical vec-op accounting");
        }
    }
}

#[test]
fn grouped_vr_sweep_handles_empty_batch() {
    let mut e = engine();
    let d = 64;
    let batch = MachineBatch::empty(d);
    let x0: Vec<f32> = (0..d).map(|j| 0.1 + j as f32 * 0.01).collect();
    let zeros = vec![0.0f32; d];
    let mut meter = ClusterMeter::new(1);
    let (xe, xa) = vr_sweep_machine_grouped(
        &mut e,
        Loss::Squared,
        LocalSolver::Svrg,
        0..batch.n_groups(),
        &batch,
        &x0,
        &zeros,
        &zeros,
        &zeros,
        0.5,
        0.05,
        meter.machine(0),
    )
    .unwrap();
    // nothing swept: iterate unchanged, average falls back to the iterate
    assert_close(&xe, &x0, 0.0, 0.0);
    assert_close(&xa, &x0, 0.0, 0.0);
}

#[test]
fn empty_machine_set_returns_zero_gradient() {
    // regression: used to panic on machines[0] before the emptiness check
    let mut e = engine();
    let machines: Vec<MachineBatch> = Vec::new();
    let w = vec![0.1f32; 64];
    let mut net = Network::new(0, NetModel::default());
    let mut meter = ClusterMeter::new(0);
    let (g, loss, n) =
        distributed_mean_grad(&mut e, None, Loss::Squared, &machines, &w, &mut net, &mut meter)
            .unwrap();
    assert_eq!(g, vec![0.0f32; 64]);
    assert_eq!(loss, 0.0);
    assert_eq!(n, 0.0);
}

#[test]
fn empty_batch_machine_contributes_nothing() {
    let mut e = engine();
    let d = 64;
    let machines = vec![
        MachineBatch::pack(&mut e, d, &draw(Loss::Squared, d, 300, 1)).unwrap(),
        MachineBatch::empty(d),
    ];
    let w = vec![0.05f32; d];
    let mut net = Network::new(2, NetModel::default());
    let mut meter = ClusterMeter::new(2);
    let (g, _, n) =
        distributed_mean_grad(&mut e, None, Loss::Squared, &machines, &w, &mut net, &mut meter)
            .unwrap();
    assert_eq!(n, 300.0);
    assert_eq!(g.len(), d);
}
