//! Shard-determinism parity: shards ∈ {1, 2, m} must reproduce the
//! shard-free sequential path BIT FOR BIT — identical iterate bits,
//! identical objective-curve bits, identical ClusterMeter / CommStats /
//! simulated-time accounting — on both losses, including ragged blocks.
//!
//! This is the shard plane's contract (see `runtime::shard`): per-machine
//! work runs the identical kernel sequence on whichever engine owns the
//! machine, partials join in fixed machine order, and every cross-machine
//! combine is the f64 host-order reduce (bit-identical to the `redm{M}`
//! device kernel, pinned by device_collective.rs). Requires
//! `make artifacts`.

use mbprox::algos::RunResult;
use mbprox::config::ExperimentConfig;
use mbprox::coordinator::Runner;
use mbprox::data::Loss;
use mbprox::runtime::{Engine, ShardPool};
use std::path::PathBuf;

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// Run `cfg` on a fresh engine: sequentially (`shards = None`) or over a
/// fresh pool of n workers.
fn run_plane(shards: Option<usize>, cfg: &ExperimentConfig) -> RunResult {
    let dir = artifacts_dir();
    let mut r = Runner::new(Engine::new(&dir).expect("run `make artifacts` before cargo test"));
    if let Some(n) = shards {
        r = r.with_shards(ShardPool::new(n, &dir).expect("shard pool construction"));
    }
    r.run(cfg).unwrap_or_else(|e| panic!("{} (shards={shards:?}): {e:?}", cfg.method))
}

fn bits32(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

fn assert_identical(seq: &RunResult, sharded: &RunResult, label: &str) {
    assert_eq!(bits32(&seq.w), bits32(&sharded.w), "{label}: final iterate bits");
    assert_eq!(seq.report, sharded.report, "{label}: ClusterMeter report");
    assert_eq!(
        seq.sim_time_s.to_bits(),
        sharded.sim_time_s.to_bits(),
        "{label}: simulated network time"
    );
    assert_eq!(seq.curve.len(), sharded.curve.len(), "{label}: curve length");
    for (a, b) in seq.curve.iter().zip(&sharded.curve) {
        assert_eq!(a.outer_iter, b.outer_iter, "{label}: curve iters");
        assert_eq!(a.samples_total, b.samples_total, "{label}: curve samples");
        assert_eq!(a.comm_rounds, b.comm_rounds, "{label}: curve rounds");
        assert_eq!(a.vec_ops, b.vec_ops, "{label}: curve vec ops");
        match (a.objective, b.objective) {
            (Some(x), Some(y)) => {
                let t = a.outer_iter;
                assert_eq!(x.to_bits(), y.to_bits(), "{label}: objective bits at t={t}")
            }
            (None, None) => {}
            other => panic!("{label}: objective presence mismatch {other:?}"),
        }
    }
    match (seq.final_objective, sharded.final_objective) {
        (Some(x), Some(y)) => assert_eq!(x.to_bits(), y.to_bits(), "{label}: final objective"),
        (None, None) => {}
        other => panic!("{label}: final objective mismatch {other:?}"),
    }
}

/// The parity harness: sequential baseline vs shards ∈ {1, 2, m}.
fn parity(method: &str, loss: Loss, b_local: usize, n_budget: usize) {
    let m = 4usize;
    let cfg = ExperimentConfig {
        method: method.into(),
        loss,
        m,
        b_local,
        n_budget,
        dim: 64,
        seed: 20170707,
        eval_samples: 1024,
        eval_every: 1,
        ..ExperimentConfig::default()
    };
    let seq = run_plane(None, &cfg);
    for n in [1usize, 2, m] {
        let sharded = run_plane(Some(n), &cfg);
        assert_identical(&seq, &sharded, &format!("{method}[{}] shards={n}", loss.tag()));
    }
}

#[test]
fn mp_dsvrg_squared_ragged_blocks() {
    // b = 300 -> a full block + a 44-row ragged tail per machine per draw
    parity("mp-dsvrg", Loss::Squared, 300, 3600); // T = 3
}

#[test]
fn mp_dsvrg_logistic() {
    parity("mp-dsvrg", Loss::Logistic, 256, 3072); // T = 3
}

#[test]
fn mp_dane_squared() {
    parity("mp-dane", Loss::Squared, 256, 2048); // T = 2
}

#[test]
fn mp_dane_saga_logistic_ragged() {
    // the SAGA chained kernel on the shard plane, ragged blocks
    parity("mp-dane-saga", Loss::Logistic, 300, 2400); // T = 2
}

#[test]
fn mp_oneshot_logistic() {
    parity("mp-oneshot", Loss::Logistic, 256, 2048); // T = 2
}

#[test]
fn mp_exact_cg_squared() {
    // chained CG: recurrence on the coordinator engine, matvec partials
    // fanned to the shards
    parity("mp-exact", Loss::Squared, 256, 2048); // T = 2
}

#[test]
fn minibatch_sgd_squared() {
    parity("minibatch-sgd", Loss::Squared, 64, 1024); // T = 4
}

#[test]
fn dsvrg_erm_squared() {
    // the ERM designated-machine sweep rides the plane's VR lane
    // (chained on the sequential plane, grouped-on-shard when sharded)
    parity("dsvrg-erm", Loss::Squared, 256, 2048);
}

/// The sharded evaluator in isolation: held-out evaluation fans one
/// segment per machine across the shards, and the fixed-segment-order f64
/// combine must reproduce the coordinator-engine evaluation bit for bit
/// (every objective above is already pinned through `assert_identical`;
/// this pins the evaluator without an algorithm in the loop).
#[test]
fn sharded_evaluator_objective_bits() {
    use mbprox::data::synth::{SynthSpec, SynthStream};
    use mbprox::data::SampleStream;
    use mbprox::objective::Evaluator;
    use mbprox::runtime::ExecPlane;

    let dir = artifacts_dir();
    let d = 64usize;
    let m = 4usize;
    let mut stream = SynthStream::new(SynthSpec::least_squares(d), 99);
    // ragged: segments of 1024+3 samples split 4 ways
    let samples = stream.draw_many(4 * 256 + 3);
    let w: Vec<f32> = (0..d).map(|j| (j as f32 * 0.1).cos() * 0.05).collect();

    let seq_obj = {
        let mut engine = Engine::new(&dir).expect("engine");
        let mut plane = ExecPlane::chained(&mut engine);
        let ev = Evaluator::new(&mut plane, d, Loss::Squared, &samples, m).unwrap();
        ev.objective(&mut plane, &w).unwrap()
    };
    for shards in [1usize, 2, m] {
        let mut engine = Engine::new(&dir).expect("engine");
        let pool = ShardPool::new(shards, &dir).expect("pool");
        let mut plane = ExecPlane::auto(&mut engine, Some(&pool));
        let ev = Evaluator::new(&mut plane, d, Loss::Squared, &samples, m).unwrap();
        let obj = ev.objective(&mut plane, &w).unwrap();
        assert_eq!(
            seq_obj.to_bits(),
            obj.to_bits(),
            "evaluator objective bits (shards={shards})"
        );
    }
}
