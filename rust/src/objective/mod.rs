//! Distributed objective: the bridge between algorithms and the engine.
//!
//! Wraps per-machine block sets and provides the paper's primitive
//! operations with exact resource accounting:
//!   - local block gradients (vec ops charged to the owning machine)
//!   - distributed mean gradients (all-reduce round + per-machine compute)
//!   - population-objective estimation on a held-out evaluation set
//!
//! Units: computing the gradient of `n` samples costs `n` vector
//! operations (the paper's convention); one collective is one round.

use crate::accounting::ClusterMeter;
use crate::comm::Network;
use crate::data::blocks::{pack_all, Block};
use crate::data::{Loss, Sample};
use crate::linalg;
use crate::runtime::exec::{BlockLits, GradOut};
use crate::runtime::Engine;
use anyhow::Result;

/// One machine's current minibatch (or ERM shard), packed for the engine.
pub struct MachineBatch {
    pub lits: Vec<BlockLits>,
    pub n: usize,
    pub d: usize,
}

impl MachineBatch {
    pub fn pack(engine: &Engine, engine_d: usize, samples: &[Sample]) -> Result<MachineBatch> {
        let blocks: Vec<Block> = pack_all(samples, engine_d);
        let lits = blocks
            .iter()
            .map(|b| BlockLits::from_block(engine, b))
            .collect::<Result<Vec<_>>>()?;
        Ok(MachineBatch { lits, n: samples.len(), d: engine_d })
    }

    pub fn empty(engine_d: usize) -> MachineBatch {
        MachineBatch { lits: Vec::new(), n: 0, d: engine_d }
    }
}

/// Sum-form gradient over one machine's batch. Charges `n` vec ops.
pub fn local_grad_sum(
    engine: &mut Engine,
    loss: Loss,
    batch: &MachineBatch,
    w: &[f32],
    meter: &mut crate::accounting::ResourceMeter,
) -> Result<GradOut> {
    let mut g = vec![0.0f32; batch.d];
    let mut lsum = 0.0;
    let mut cnt = 0.0;
    for blk in &batch.lits {
        let out = engine.grad_block(loss, blk, w)?;
        linalg::axpy(1.0, &out.grad_sum, &mut g);
        lsum += out.loss_sum;
        cnt += out.count;
    }
    meter.add_vec_ops(batch.n as u64);
    Ok(GradOut { grad_sum: g, loss_sum: lsum, count: cnt })
}

/// Distributed mean gradient over all machines' batches:
/// one weighted all-reduce round; returns (mean_grad, mean_loss, total_n).
pub fn distributed_mean_grad(
    engine: &mut Engine,
    loss: Loss,
    machines: &[MachineBatch],
    w: &[f32],
    net: &mut Network,
    meter: &mut ClusterMeter,
) -> Result<(Vec<f32>, f64, f64)> {
    let m = machines.len();
    let d = machines[0].d;
    let mut locals: Vec<Vec<f32>> = Vec::with_capacity(m);
    let mut weights: Vec<f64> = Vec::with_capacity(m);
    let mut loss_total = 0.0;
    let mut n_total = 0.0;
    for (i, batch) in machines.iter().enumerate() {
        let out = local_grad_sum(engine, loss, batch, w, meter.machine(i))?;
        let cnt = out.count.max(0.0);
        // local *mean* gradient, weighted by count in the reduce
        let mut gm = out.grad_sum;
        if cnt > 0.0 {
            linalg::scale(1.0 / cnt as f32, &mut gm);
        }
        locals.push(gm);
        weights.push(cnt);
        loss_total += out.loss_sum;
        n_total += cnt;
    }
    if locals.is_empty() {
        return Ok((vec![0.0; d], 0.0, 0.0));
    }
    net.all_reduce_weighted(meter, &weights, &mut locals);
    let mean_loss = if n_total > 0.0 { loss_total / n_total } else { 0.0 };
    Ok((locals.pop().unwrap(), mean_loss, n_total))
}

/// Held-out estimator of the population objective phi(w).
pub struct Evaluator {
    pub loss: Loss,
    pub batch: MachineBatch,
}

impl Evaluator {
    pub fn new(
        engine: &Engine,
        engine_d: usize,
        loss: Loss,
        samples: &[Sample],
    ) -> Result<Evaluator> {
        Ok(Evaluator { loss, batch: MachineBatch::pack(engine, engine_d, samples)? })
    }

    /// Mean instantaneous loss over the evaluation set (not metered:
    /// evaluation is experimenter-side, not part of the algorithm).
    pub fn objective(&self, engine: &mut Engine, w: &[f32]) -> Result<f64> {
        let mut lsum = 0.0;
        let mut cnt = 0.0;
        for blk in &self.batch.lits {
            let out = engine.grad_block(self.loss, blk, w)?;
            lsum += out.loss_sum;
            cnt += out.count;
        }
        Ok(if cnt > 0.0 { lsum / cnt } else { 0.0 })
    }
}

/// Prox-regularized objective value on a batch set (for tests/diagnostics):
/// phi_I(w) + gamma/2 ||w - wprev||^2 over the union of machine batches.
pub fn prox_objective(
    engine: &mut Engine,
    loss: Loss,
    machines: &[MachineBatch],
    w: &[f32],
    wprev: &[f32],
    gamma: f64,
) -> Result<f64> {
    let mut lsum = 0.0;
    let mut cnt = 0.0;
    for batch in machines {
        for blk in &batch.lits {
            let out = engine.grad_block(loss, blk, w)?;
            lsum += out.loss_sum;
            cnt += out.count;
        }
    }
    let phi = if cnt > 0.0 { lsum / cnt } else { 0.0 };
    let dist = linalg::dist2(w, wprev);
    Ok(phi + 0.5 * gamma * dist * dist)
}
