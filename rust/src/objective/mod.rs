//! Distributed objective: the bridge between algorithms and the engine.
//!
//! Wraps per-machine block sets and provides the paper's primitive
//! operations with exact resource accounting:
//!   - local block gradients (vec ops charged to the owning machine)
//!   - distributed mean gradients (all-reduce round + per-machine compute)
//!   - population-objective estimation on a held-out evaluation set
//!
//! Units: computing the gradient of `n` samples costs `n` vector
//! operations (the paper's convention); one collective is one round.
//!
//! # Device residency
//!
//! A [`MachineBatch`] keeps two device representations of the same data:
//!
//! - **Fused groups** (`groups`): consecutive 256-row blocks stacked into
//!   the widest supported `gradm{K}`/`nmm{K}` upload (K = 8/4 by
//!   default), uploaded eagerly at pack time. The grad / normal-matvec
//!   hot paths iterate these, so one machine-round costs one dispatch and
//!   one `(grad_sum, loss_sum, count)` download per *group* instead of
//!   per block; the ragged tail (fewer blocks than the narrowest width)
//!   falls back to single-block dispatch with host-side accumulation.
//! - **Per-block buffers** (`vr_lits`): the *legacy* SVRG/SAGA sweep path
//!   is per-block, so its uploads are materialized lazily on a batch's
//!   first sweep and cached for the batch lifetime — machines that are
//!   never the designated sweeper upload nothing twice. When the manifest
//!   carries the chained `svrgc{K}`/`sagac{K}` artifacts, group-aligned
//!   sweeps ride the fused `groups` uploads instead and `vr_lits` never
//!   materializes at all.
//!
//! The `*_dev` functions are the chained (device-resident) versions of
//! the same primitives: gradients fold into [`DeviceVec`] handles via the
//! `gacc{K}` accumulator chain and cross machines through the comm
//! layer's DeviceCollective, with identical paper-units accounting and
//! zero steady-state downloads.

use crate::accounting::{ClusterMeter, ResourceMeter};
use crate::comm::Network;
use crate::data::blocks::{pack_all, Block};
use crate::data::{Loss, Sample};
use crate::linalg;
use crate::runtime::exec::{BlockLits, GradOut};
use crate::runtime::shard::ShardPool;
use crate::runtime::{DeviceVec, Engine};
use anyhow::{anyhow, ensure, Result};
use std::cell::{Ref, RefCell};
use std::sync::Arc;

/// How a drawn batch is packed for the engine (see [`MachineBatch`]).
/// Solvers pick a mode per plane via their `pack_mode` hook; the plane's
/// draw verb applies it wherever the machine lives (coordinator engine or
/// owning shard).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PackMode {
    /// fused groups + host blocks retained for Host-lane per-block sweeps
    Full,
    /// fused groups only (grad/normal-matvec consumers)
    GradOnly,
    /// fused groups aligned to a p-way block partition (chained sweeps)
    VrAligned(usize),
}

/// Host-side description of a shard-resident batch: everything the
/// coordinator needs for solver bookkeeping (group structure, sweep
/// weights) without the device buffers, which stay on the owning shard's
/// engine (the shard plane's affinity rule — see `runtime::shard`).
#[derive(Clone, Debug)]
pub struct ShardBatchMeta {
    /// owning machine (== the key in the shard's batch store)
    pub machine: usize,
    /// stacked width k of each fused group, in group order
    pub group_ks: Vec<usize>,
    /// sweep-average weight of each group (1 + valid per non-empty block)
    pub group_weights: Vec<f64>,
}

/// One machine's current minibatch (or ERM shard), packed for the engine.
pub struct MachineBatch {
    /// host-side blocks pending a possible VR upload; drained (freed) when
    /// `vr_lits` materializes, and empty from the start for grad-only packs
    pending: RefCell<Vec<Block>>,
    n_blocks: usize,
    /// fused multi-block device groups — the grad/normal-matvec hot path.
    /// Empty on a coordinator-side stub (see [`MachineBatch::stub`]): the
    /// real groups live on the owning shard.
    pub groups: Vec<BlockLits>,
    /// lazily-uploaded per-block buffers for the VR sweep path
    vr: RefCell<Option<Vec<BlockLits>>>,
    pub n: usize,
    pub d: usize,
    /// sample vectors charged against the owning machine's memory meter
    /// when this batch was drawn (0 when the draw did not hold memory).
    /// `RunContext::release_batches` releases exactly this amount, so a
    /// ragged final batch can never corrupt the peak-memory meter.
    pub held: u64,
    /// `Some` on a coordinator-side stub for a shard-resident batch:
    /// per-machine compute against it must go through the fan helpers
    /// ([`fan_machines`] / [`fan_machine`]), which route to the owning
    /// shard where the device state actually lives.
    pub shard: Option<ShardBatchMeta>,
}

impl MachineBatch {
    /// Pack for the full engine surface (grad/nm hot path + VR sweeps).
    pub fn pack(engine: &mut Engine, engine_d: usize, samples: &[Sample]) -> Result<MachineBatch> {
        Self::pack_opts(engine, engine_d, samples, true, None)
    }

    /// Pack for grad/normal-matvec use only (evaluators, CG-only shards):
    /// the host block copies are dropped immediately, so the batch costs
    /// no host memory beyond the run — `vr_lits` on such a batch errors.
    pub fn pack_grad_only(
        engine: &mut Engine,
        engine_d: usize,
        samples: &[Sample],
    ) -> Result<MachineBatch> {
        Self::pack_opts(engine, engine_d, samples, false, None)
    }

    /// Pack with fused-group boundaries aligned to a p-way block
    /// partition (`shard_ranges(n_blocks, p)`): no group straddles a
    /// partition boundary, so chained VR sweeps over [`MachineBatch::
    /// group_ranges`] touch EXACTLY the blocks the legacy per-block
    /// partition would — same sweep sizes, same vec-op charges, for any
    /// p. The trade-off is narrower fusion near boundaries (a 3-block
    /// segment cannot ride a k=4 kernel); host blocks are not retained —
    /// aligned packs exist for the chained path.
    pub fn pack_vr_aligned(
        engine: &mut Engine,
        engine_d: usize,
        samples: &[Sample],
        p: usize,
    ) -> Result<MachineBatch> {
        Self::pack_opts(engine, engine_d, samples, false, Some(p))
    }

    /// Pack per an explicit [`PackMode`] — the draw verb's one switch
    /// (identical on the coordinator engine and inside a shard job).
    pub fn pack_mode(
        engine: &mut Engine,
        engine_d: usize,
        samples: &[Sample],
        mode: PackMode,
    ) -> Result<MachineBatch> {
        match mode {
            PackMode::Full => Self::pack(engine, engine_d, samples),
            PackMode::GradOnly => Self::pack_grad_only(engine, engine_d, samples),
            PackMode::VrAligned(p) => Self::pack_vr_aligned(engine, engine_d, samples, p),
        }
    }

    fn pack_opts(
        engine: &mut Engine,
        engine_d: usize,
        samples: &[Sample],
        retain_host: bool,
        vr_align: Option<usize>,
    ) -> Result<MachineBatch> {
        let mode = match (retain_host, vr_align) {
            (_, Some(p)) => PackMode::VrAligned(p),
            (true, None) => PackMode::Full,
            (false, None) => PackMode::GradOnly,
        };
        Self::pack_blocks_mode(engine, engine_d, pack_all(samples, engine_d), mode)
    }

    /// Pack from pre-packed host blocks — the prefetch lane's staged
    /// packs. `pack_all` is pure, so a batch built here from
    /// `pack_all(samples, d)` is indistinguishable from
    /// [`MachineBatch::pack_mode`] over the same samples: only the fuse
    /// grouping and device uploads (the engine-affine half of packing)
    /// happen in this call. `n` is recovered from the blocks' valid
    /// counts, which sum to the drawn sample count.
    pub fn pack_blocks_mode(
        engine: &mut Engine,
        engine_d: usize,
        blocks: Vec<Block>,
        mode: PackMode,
    ) -> Result<MachineBatch> {
        let n: usize = blocks.iter().map(|b| b.valid).sum();
        let n_blocks = blocks.len();
        let (retain_host, vr_align) = match mode {
            PackMode::Full => (true, None),
            PackMode::GradOnly => (false, None),
            PackMode::VrAligned(p) => (false, Some(p)),
        };
        let groups = match vr_align {
            None => fuse_blocks(engine, &blocks)?,
            Some(p) => {
                let p = p.clamp(1, n_blocks.max(1));
                let mut groups = Vec::new();
                for seg in crate::data::sampler::shard_ranges(n_blocks, p) {
                    groups.extend(fuse_blocks(engine, &blocks[seg])?);
                }
                groups
            }
        };
        let pending = if retain_host { blocks } else { Vec::new() };
        Ok(MachineBatch {
            pending: RefCell::new(pending),
            n_blocks,
            groups,
            vr: RefCell::new(None),
            n,
            d: engine_d,
            held: 0,
            shard: None,
        })
    }

    pub fn empty(engine_d: usize) -> MachineBatch {
        MachineBatch {
            pending: RefCell::new(Vec::new()),
            n_blocks: 0,
            groups: Vec::new(),
            vr: RefCell::new(None),
            n: 0,
            d: engine_d,
            held: 0,
            shard: None,
        }
    }

    /// Describe this (locally packed) batch for a coordinator-side stub —
    /// the host half of a shard-side pack job's reply.
    pub fn shard_meta(&self, machine: usize) -> ShardBatchMeta {
        ShardBatchMeta {
            machine,
            group_ks: self.groups.iter().map(|g| g.k).collect(),
            group_weights: self.groups.iter().map(|g| g.sweep_weight()).collect(),
        }
    }

    /// A coordinator-side stub for a batch packed on a shard: carries all
    /// the bookkeeping (counts, group structure, sweep weights) and no
    /// device state. Engine calls against a stub's `groups` see nothing —
    /// route compute through [`fan_machines`] / [`fan_machine`] instead.
    pub fn stub(engine_d: usize, n: usize, n_blocks: usize, meta: ShardBatchMeta) -> MachineBatch {
        MachineBatch {
            pending: RefCell::new(Vec::new()),
            n_blocks,
            groups: Vec::new(),
            vr: RefCell::new(None),
            n,
            d: engine_d,
            held: 0,
            shard: Some(meta),
        }
    }

    /// Number of 256-row blocks (the VR sweep granularity).
    pub fn n_blocks(&self) -> usize {
        self.n_blocks
    }

    /// Number of fused groups (device groups locally; group metadata on a
    /// stub).
    pub fn n_groups(&self) -> usize {
        match &self.shard {
            Some(m) => m.group_ks.len(),
            None => self.groups.len(),
        }
    }

    /// Stacked width k of each group, in group order (stub-safe).
    fn group_widths(&self) -> Vec<usize> {
        match &self.shard {
            Some(m) => m.group_ks.clone(),
            None => self.groups.iter().map(|g| g.k).collect(),
        }
    }

    /// Sweep-average weight of group `gi` (stub-safe; see
    /// [`BlockLits::sweep_weight`]).
    pub fn group_sweep_weight(&self, gi: usize) -> f64 {
        match &self.shard {
            Some(m) => m.group_weights[gi],
            None => self.groups[gi].sweep_weight(),
        }
    }

    /// Group-index ranges tiling the p-way BLOCK partition
    /// (`shard_ranges(n_blocks, p)`), for group-aligned VR sweeps. Exact
    /// — each range covers precisely its partition's blocks — when the
    /// batch was packed with [`MachineBatch::pack_vr_aligned`] at the
    /// same p. On an unaligned pack this is best-effort: a group is
    /// assigned to the partition containing its first block, so a group
    /// straddling a boundary shifts some blocks one partition earlier.
    /// Always a partition of `0..groups.len()`.
    pub fn group_ranges(&self, p: usize) -> Vec<std::ops::Range<usize>> {
        let p = p.clamp(1, self.n_blocks.max(1));
        let block_ranges = crate::data::sampler::shard_ranges(self.n_blocks, p);
        // cumulative first-block index of each group (widths are known on
        // stubs too, so solver bookkeeping works on either plane)
        let widths = self.group_widths();
        let mut starts = Vec::with_capacity(widths.len());
        let mut acc = 0usize;
        for k in widths {
            starts.push(acc);
            acc += k;
        }
        let mut out = Vec::with_capacity(block_ranges.len());
        let mut g = 0usize;
        for br in &block_ranges {
            let begin = g;
            while g < starts.len() && starts[g] < br.end {
                g += 1;
            }
            out.push(begin..g);
        }
        out
    }

    /// Per-block device buffers for the sequential VR sweeps, uploaded on
    /// first use and cached for the batch lifetime; the host copies are
    /// freed as part of the upload.
    pub fn vr_lits(&self, engine: &mut Engine) -> Result<Ref<'_, Vec<BlockLits>>> {
        if self.vr.borrow().is_none() {
            anyhow::ensure!(
                self.pending.borrow().len() == self.n_blocks,
                "batch was packed grad-only: no host blocks left for VR sweeps"
            );
            // upload from a borrow first — a mid-upload failure leaves the
            // host blocks intact for a retry — and only drain on success
            let lits = self
                .pending
                .borrow()
                .iter()
                .map(|b| BlockLits::from_block(engine, b))
                .collect::<Result<Vec<_>>>()?;
            *self.vr.borrow_mut() = Some(lits);
            // VR path is now fully device-resident: free the host copies
            self.pending.borrow_mut().clear();
        }
        Ok(Ref::map(self.vr.borrow(), |o| o.as_ref().expect("just materialized")))
    }
}

/// Greedily stack consecutive blocks into the widest supported fused
/// upload; the ragged tail becomes single-block (k=1) groups — the host
/// fallback path. With no multi artifacts in the manifest this degrades
/// to exactly the per-block packing of the pre-fusion engine.
fn fuse_blocks(engine: &mut Engine, blocks: &[Block]) -> Result<Vec<BlockLits>> {
    // copy: the width list must not borrow `engine` across the uploads
    let widths: Vec<usize> = engine.fuse_widths().to_vec(); // widest first, possibly empty
    let mut groups = Vec::new();
    let mut i = 0usize;
    while i < blocks.len() {
        let rem = blocks.len() - i;
        let k = widths.iter().copied().find(|&k| k <= rem).unwrap_or(1);
        groups.push(BlockLits::from_blocks(engine, &blocks[i..i + k])?);
        i += k;
    }
    Ok(groups)
}

/// Fan a per-machine computation across the cluster and join in fixed
/// machine order — THE helper behind every per-machine loop in the
/// algorithm layer.
///
/// `f` runs once per machine against *that machine's* engine and batch:
/// inline on the coordinator engine when the batches are locally packed
/// (the sequential plane — this branch IS the old per-machine loop), or
/// as ONE batched job per shard covering that shard's machines in
/// ascending order when they are stubs ([`ShardPool::fan_batches`]). The
/// closure sees only host data plus the engine/batch it is handed, so the
/// two planes execute the identical kernel sequence per machine and the
/// results are bitwise equal; joins happen in machine order and each
/// machine's meter delta is merged into `meter.machine(i)` in that order,
/// so accounting is deterministic and plane-independent.
pub fn fan_machines<T, F>(
    engine: &mut Engine,
    shards: Option<&ShardPool>,
    batches: &[MachineBatch],
    meter: &mut ClusterMeter,
    f: F,
) -> Result<Vec<T>>
where
    T: Send + 'static,
    F: Fn(&mut Engine, &MachineBatch, usize, &mut ResourceMeter) -> Result<T>
        + Clone
        + Send
        + 'static,
{
    let stubs = batches.iter().filter(|b| b.shard.is_some()).count();
    if stubs == 0 {
        let mut out = Vec::with_capacity(batches.len());
        for (i, batch) in batches.iter().enumerate() {
            out.push(f(&mut *engine, batch, i, meter.machine(i))?);
        }
        return Ok(out);
    }
    ensure!(stubs == batches.len(), "mixed local/shard batches in one fan");
    let pool = shards.ok_or_else(|| anyhow!("shard-resident batches need a shard plane"))?;
    for (i, b) in batches.iter().enumerate() {
        let machine = b.shard.as_ref().expect("stub checked above").machine;
        // hard contract, not a debug check: a reordered/filtered stub
        // slice would otherwise silently mis-attribute meter deltas
        ensure!(machine == i, "stub for machine {machine} at position {i}");
    }
    // ONE batched job per shard (ascending machine order inside each —
    // the identical per-shard execution order the old one-job-per-machine
    // fan produced), joined and meter-merged in fixed machine order
    let m = batches.len();
    let fans = pool.fan_batches(m, "machine fan", move |state, machine| {
        let (engine, batch) = state.machine(machine)?;
        let mut delta = ResourceMeter::new();
        let out = f(engine, batch, machine, &mut delta)?;
        Ok((out, delta))
    });
    let mut per: Vec<Option<(T, ResourceMeter)>> = (0..m).map(|_| None).collect();
    for fan in fans {
        // elastic wait: a worker death surfaces as a dead channel and is
        // healed + replayed at this collective boundary (see
        // ShardPool::wait_elastic); job errors still fail the run
        for (i, v) in pool.wait_elastic(fan)? {
            per[i] = Some(v);
        }
    }
    let mut out = Vec::with_capacity(m);
    for (i, slot) in per.into_iter().enumerate() {
        let (val, delta) =
            slot.ok_or_else(|| anyhow!("machine {i} missing from its shard's fan batch"))?;
        meter.machine(i).merge(&delta);
        out.push(val);
    }
    Ok(out)
}

/// [`fan_machines`] for ONE designated machine `i` (e.g. the DSVRG sweep
/// token holder): inline on the sequential plane, a single job on the
/// owning shard otherwise.
pub fn fan_machine<T, F>(
    engine: &mut Engine,
    shards: Option<&ShardPool>,
    batches: &[MachineBatch],
    i: usize,
    meter: &mut ClusterMeter,
    f: F,
) -> Result<T>
where
    T: Send + 'static,
    F: FnOnce(&mut Engine, &MachineBatch, usize, &mut ResourceMeter) -> Result<T>
        + Send
        + 'static,
{
    let batch = &batches[i];
    match &batch.shard {
        None => f(&mut *engine, batch, i, meter.machine(i)),
        Some(meta) => {
            let machine = meta.machine;
            ensure!(machine == i, "stub for machine {machine} addressed as machine {i}");
            let pool =
                shards.ok_or_else(|| anyhow!("shard-resident batch needs a shard plane"))?;
            let (val, delta) = pool.run_on_machine(machine, move |state| {
                let (engine, batch) = state.machine(machine)?;
                let mut delta = ResourceMeter::new();
                let out = f(engine, batch, machine, &mut delta)?;
                Ok((out, delta))
            })?;
            meter.machine(i).merge(&delta);
            Ok(val)
        }
    }
}

/// Sum-form gradient over one machine's batch. Charges `n` vec ops.
/// Iterates the fused groups: one dispatch + one download per group.
pub fn local_grad_sum(
    engine: &mut Engine,
    loss: Loss,
    batch: &MachineBatch,
    w: &[f32],
    meter: &mut crate::accounting::ResourceMeter,
) -> Result<GradOut> {
    let mut g = vec![0.0f32; batch.d];
    let mut lsum = 0.0;
    let mut cnt = 0.0;
    for blk in &batch.groups {
        let out = engine.grad_block(loss, blk, w)?;
        linalg::axpy(1.0, &out.grad_sum, &mut g);
        lsum += out.loss_sum;
        cnt += out.count;
    }
    meter.add_vec_ops(batch.n as u64);
    Ok(GradOut { grad_sum: g, loss_sum: lsum, count: cnt })
}

/// Device-chained [`local_grad_sum`]: folds the whole batch into ONE
/// device vector via the `gacc{K}` accumulator chain — zero downloads,
/// zero uploads beyond the iterate itself. The valid count is not
/// downloaded either: it is known at pack time (`batch.n`). Charges the
/// same `n` vec ops as the host path.
pub fn local_grad_sum_dev(
    engine: &mut Engine,
    loss: Loss,
    batch: &MachineBatch,
    w: &DeviceVec,
    meter: &mut crate::accounting::ResourceMeter,
) -> Result<DeviceVec> {
    let mut acc = engine.zeros_dev(batch.d)?;
    for blk in &batch.groups {
        acc = engine.grad_acc(loss, blk, w, &acc)?;
    }
    meter.add_vec_ops(batch.n as u64);
    Ok(acc)
}

/// Distributed mean gradient over all machines' batches:
/// one weighted all-reduce round; returns (mean_grad, mean_loss, total_n).
/// The per-machine gradients fan across the shard plane when one is
/// given; the combine runs in fixed machine order in f64 on the
/// coordinator either way, so the result is plane-independent.
pub fn distributed_mean_grad(
    engine: &mut Engine,
    shards: Option<&ShardPool>,
    loss: Loss,
    machines: &[MachineBatch],
    w: &[f32],
    net: &mut Network,
    meter: &mut ClusterMeter,
) -> Result<(Vec<f32>, f64, f64)> {
    // zero-machine early-out BEFORE touching machines[0] (an empty cluster
    // has a zero mean gradient in the iterate's dimension)
    if machines.is_empty() {
        return Ok((vec![0.0; w.len()], 0.0, 0.0));
    }
    let w_shared: Arc<[f32]> = Arc::from(w);
    let outs = fan_machines(engine, shards, machines, meter, move |eng, batch, _i, m| {
        local_grad_sum(eng, loss, batch, &w_shared, m)
    })?;
    let m = machines.len();
    let mut locals: Vec<Vec<f32>> = Vec::with_capacity(m);
    let mut weights: Vec<f64> = Vec::with_capacity(m);
    let mut loss_total = 0.0;
    let mut n_total = 0.0;
    for out in outs {
        let cnt = out.count.max(0.0);
        // local *mean* gradient, weighted by count in the reduce
        let mut gm = out.grad_sum;
        if cnt > 0.0 {
            linalg::scale(1.0 / cnt as f32, &mut gm);
        }
        locals.push(gm);
        weights.push(cnt);
        loss_total += out.loss_sum;
        n_total += cnt;
    }
    net.all_reduce_weighted(meter, &weights, &mut locals);
    let mean_loss = if n_total > 0.0 { loss_total / n_total } else { 0.0 };
    Ok((locals.pop().unwrap(), mean_loss, n_total))
}

/// The chained-kernel mean gradient as a host-in/host-out collective:
/// every machine folds its batch through the same `gacc{K}` chain +
/// `vec_scale` the single-engine chained path runs, materializes its
/// local mean on its own engine, and the partials cross machines through
/// the host collective — whose fixed-machine-order f64 interior is
/// bit-identical to the `redm{M}` device reduce (pinned by
/// rust/tests/device_collective.rs). Identical rounds/vectors/sim-time
/// accounting; the per-machine materialize is the honest price of
/// engines that share no device.
pub fn mean_grad_chained_host(
    engine: &mut Engine,
    shards: Option<&ShardPool>,
    loss: Loss,
    machines: &[MachineBatch],
    w: &[f32],
    net: &mut Network,
    meter: &mut ClusterMeter,
) -> Result<Vec<f32>> {
    if machines.is_empty() {
        return Ok(vec![0.0; w.len()]);
    }
    let w_shared: Arc<[f32]> = Arc::from(w);
    let mut locals: Vec<Vec<f32>> =
        fan_machines(engine, shards, machines, meter, move |eng, batch, _i, m| {
            let w_dev = eng.upload_dev(&w_shared, &[w_shared.len()])?;
            let gsum = local_grad_sum_dev(eng, loss, batch, &w_dev, m)?;
            let cnt = batch.n as f64;
            let gm = if cnt > 0.0 { eng.vec_scale(&gsum, (1.0 / cnt) as f32)? } else { gsum };
            eng.materialize(&gm)
        })?;
    let weights: Vec<f64> = machines.iter().map(|b| b.n as f64).collect();
    net.all_reduce_weighted(meter, &weights, &mut locals);
    Ok(locals.pop().unwrap())
}

/// Device-chained [`distributed_mean_grad`]: every machine's local mean
/// gradient is assembled on device (gacc chain + one scale) and the
/// weighted combine runs the DeviceCollective reduce — identical
/// round/vector/`sim_time_s` accounting, zero steady-state downloads.
/// Mean loss is not produced (losses only matter at evaluation
/// checkpoints, which take the tupled dispatch path).
pub fn distributed_mean_grad_dev(
    engine: &mut Engine,
    shards: Option<&ShardPool>,
    loss: Loss,
    machines: &[MachineBatch],
    w: &DeviceVec,
    net: &mut Network,
    meter: &mut ClusterMeter,
) -> Result<DeviceVec> {
    if machines.is_empty() {
        return engine.zeros_dev(w.len());
    }
    if machines.iter().any(|b| b.shard.is_some()) {
        // shard plane: the iterate crosses to the shards as host bits and
        // the mean comes back the same way — f32 round trips are exact,
        // and the host combine is bit-identical to the device reduce, so
        // the re-uploaded handle carries the very bits the single-engine
        // path would hold
        let w_host = engine.materialize(w)?;
        let mean = mean_grad_chained_host(engine, shards, loss, machines, &w_host, net, meter)?;
        return engine.upload_dev(&mean, &[w.len()]);
    }
    let m = machines.len();
    let mut locals: Vec<DeviceVec> = Vec::with_capacity(m);
    let mut weights: Vec<f64> = Vec::with_capacity(m);
    for (i, batch) in machines.iter().enumerate() {
        let gsum = local_grad_sum_dev(engine, loss, batch, w, meter.machine(i))?;
        // the pack-time count replaces the downloaded one: same value,
        // no traffic (masked rows are exact no-ops in the kernels)
        let cnt = batch.n as f64;
        // local *mean* gradient, weighted by count in the reduce —
        // the same scalar the host path applies
        let gm = if cnt > 0.0 { engine.vec_scale(&gsum, (1.0 / cnt) as f32)? } else { gsum };
        locals.push(gm);
        weights.push(cnt);
    }
    net.device_all_reduce_weighted(meter, engine, &weights, &locals)
}

/// Held-out estimator of the population objective phi(w).
///
/// The evaluation set is split into one fixed segment per cluster machine
/// (`shard_ranges(n_eval, m)`), each packed grad-only as its own batch.
/// The segmentation is plane-independent: on the sharded plane the
/// segments live on their owning shards and evaluation fans across them
/// in parallel, while host/chained planes evaluate the same segments
/// inline on the coordinator engine — per-segment `(loss_sum, count)`
/// partials are combined in fixed segment order in f64 either way, so the
/// objective value is bit-identical on every plane and shard count
/// (pinned by `rust/tests/shard_parity.rs`).
pub struct Evaluator {
    pub loss: Loss,
    /// one grad-only batch per segment; stubs when shard-resident
    pub segments: Vec<MachineBatch>,
}

/// One segment's unnormalized loss: `(loss_sum, count)` summed over the
/// fused groups in order. The shared kernel of every evaluation plane.
fn segment_loss(
    engine: &mut Engine,
    loss: Loss,
    batch: &MachineBatch,
    w: &[f32],
) -> Result<(f64, f64)> {
    let mut lsum = 0.0;
    let mut cnt = 0.0;
    for blk in &batch.groups {
        let out = engine.grad_block(loss, blk, w)?;
        lsum += out.loss_sum;
        cnt += out.count;
    }
    Ok((lsum, cnt))
}

impl Evaluator {
    /// Pack `samples` into `segments` per-segment grad-only batches on
    /// `plane`: on the coordinator engine, or each on its owning shard
    /// when the plane carries a pool (`segment i` lives on `shard_of(i)`,
    /// like machine state).
    pub fn new(
        plane: &mut crate::runtime::ExecPlane,
        engine_d: usize,
        loss: Loss,
        samples: &[Sample],
        segments: usize,
    ) -> Result<Evaluator> {
        let ranges = crate::data::sampler::shard_ranges(samples.len(), segments.max(1));
        let segments = if let Some(pool) = plane.shards {
            // one batched pack job per shard; each shard packs its own
            // segments (ascending segment order) from the shared sample set
            let all: Arc<Vec<Sample>> = Arc::new(samples.to_vec());
            let rs: Arc<Vec<std::ops::Range<usize>>> = Arc::new(ranges.clone());
            // PINNED fan: segment ids are not machine ids — an elastic
            // machine reassignment must never re-route a same-numbered
            // segment, so evaluator fans always use the base partition
            let fans =
                pool.fan_batches_pinned(rs.len(), "pack evaluator segment", move |state, i| {
                    let seg = &all[rs[i].clone()];
                    let batch = MachineBatch::pack_grad_only(&mut state.engine, engine_d, seg)?;
                    let reply = (batch.n, batch.n_blocks(), batch.shard_meta(i));
                    state.eval.insert(i, batch);
                    Ok(reply)
                });
            let mut per: Vec<Option<(usize, usize, ShardBatchMeta)>> =
                (0..ranges.len()).map(|_| None).collect();
            for fan in fans {
                for (i, v) in pool.wait_elastic(fan)? {
                    per[i] = Some(v);
                }
            }
            let mut stubs = Vec::with_capacity(ranges.len());
            for (i, slot) in per.into_iter().enumerate() {
                let (n, n_blocks, meta) =
                    slot.ok_or_else(|| anyhow!("segment {i} missing from its shard's pack fan"))?;
                stubs.push(MachineBatch::stub(engine_d, n, n_blocks, meta));
            }
            stubs
        } else {
            ranges
                .iter()
                .map(|r| MachineBatch::pack_grad_only(plane.engine, engine_d, &samples[r.clone()]))
                .collect::<Result<Vec<_>>>()?
        };
        Ok(Evaluator { loss, segments })
    }

    /// Mean instantaneous loss over the evaluation set (not metered:
    /// evaluation is experimenter-side, not part of the algorithm).
    /// Fans one job per segment across the shard plane when the segments
    /// are shard-resident; `w` rides each engine's session pool either
    /// way, so evaluation never pays a per-block upload.
    pub fn objective(&self, plane: &mut crate::runtime::ExecPlane, w: &[f32]) -> Result<f64> {
        let loss = self.loss;
        let sharded = self.segments.iter().any(|b| b.shard.is_some());
        let mut lsum = 0.0;
        let mut cnt = 0.0;
        if sharded {
            let pool = plane
                .shards
                .ok_or_else(|| anyhow!("shard-resident evaluator needs a shard plane"))?;
            let w_shared: Arc<[f32]> = Arc::from(w);
            let n_seg = self.segments.len();
            // PINNED: segments route by the base partition, never by an
            // elastic machine reassignment (see Evaluator::new)
            let fans = pool.fan_batches_pinned(n_seg, "evaluate segment", move |state, i| {
                let (engine, batch) = state.eval_segment(i)?;
                segment_loss(engine, loss, batch, &w_shared)
            });
            let mut per: Vec<Option<(f64, f64)>> = (0..n_seg).map(|_| None).collect();
            for fan in fans {
                for (i, v) in pool.wait_elastic(fan)? {
                    per[i] = Some(v);
                }
            }
            // combine in fixed segment order — the plane-independent fold
            for (i, slot) in per.into_iter().enumerate() {
                let (l, c) =
                    slot.ok_or_else(|| anyhow!("segment {i} missing from its shard's eval fan"))?;
                lsum += l;
                cnt += c;
            }
        } else {
            for batch in &self.segments {
                let (l, c) = segment_loss(plane.engine, loss, batch, w)?;
                lsum += l;
                cnt += c;
            }
        }
        Ok(if cnt > 0.0 { lsum / cnt } else { 0.0 })
    }

    /// [`Evaluator::objective`] at a plane-resident iterate. A Dev-lane
    /// handle on the single-engine plane is aliased into the session pool
    /// (zero uploads), so a chained round can hit an evaluation
    /// checkpoint without materializing its iterate; with shard-resident
    /// segments the iterate crosses as host bits (f32-exact, metered).
    pub fn objective_pv(
        &self,
        plane: &mut crate::runtime::ExecPlane,
        w: &crate::runtime::PlaneVec,
    ) -> Result<f64> {
        match w {
            crate::runtime::PlaneVec::Host(h) => self.objective(plane, h),
            crate::runtime::PlaneVec::Dev(dv) => {
                if self.segments.iter().any(|b| b.shard.is_some()) {
                    let host = plane.engine.materialize(dv)?;
                    return self.objective(plane, &host);
                }
                let mut lsum = 0.0;
                let mut cnt = 0.0;
                for batch in &self.segments {
                    for blk in &batch.groups {
                        let out = plane.engine.grad_block_dev(self.loss, blk, dv)?;
                        lsum += out.loss_sum;
                        cnt += out.count;
                    }
                }
                Ok(if cnt > 0.0 { lsum / cnt } else { 0.0 })
            }
        }
    }
}

/// Prox-regularized objective value on a batch set (for tests/diagnostics):
/// phi_I(w) + gamma/2 ||w - wprev||^2 over the union of machine batches.
/// Like `Evaluator::objective`, the iterate upload is hoisted out of the
/// block loop by the session pool.
pub fn prox_objective(
    engine: &mut Engine,
    loss: Loss,
    machines: &[MachineBatch],
    w: &[f32],
    wprev: &[f32],
    gamma: f64,
) -> Result<f64> {
    ensure!(
        machines.iter().all(|b| b.shard.is_none()),
        "prox_objective reads device groups directly: pack batches locally"
    );
    let mut lsum = 0.0;
    let mut cnt = 0.0;
    for batch in machines {
        for blk in &batch.groups {
            let out = engine.grad_block(loss, blk, w)?;
            lsum += out.loss_sum;
            cnt += out.count;
        }
    }
    let phi = if cnt > 0.0 { lsum / cnt } else { 0.0 };
    let dist = linalg::dist2(w, wprev);
    Ok(phi + 0.5 * gamma * dist * dist)
}
