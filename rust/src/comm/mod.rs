//! Simulated collectives over the m-machine cluster.
//!
//! The paper counts communication as "rounds in which vectors are averaged
//! across machines and the result is made known to one or all machines"
//! (footnote 1). These primitives implement exactly those operations over
//! the in-process machine states, charge each participating machine's
//! `ResourceMeter`, and advance the α–β network time model so the examples
//! can report simulated wall-clock alongside round counts.
//!
//! Substitution note (DESIGN.md §3): xla's PJRT handles are not `Send`,
//! so machines are deterministic SPMD-simulated states rather than tokio
//! tasks, and the collectives below are the *only* way machine state
//! crosses machine boundaries — which is what makes the round/vector
//! counts trustworthy. Since the shard plane (`runtime::shard`) landed,
//! "driven by the coordinator thread" is no longer the whole story: with
//! a `ShardPool` attached, per-machine work between collectives runs in
//! parallel on engine-per-worker threads, and the collectives join the
//! per-machine partials *in fixed machine order in f64 on the
//! coordinator* — the identical operation sequence as the sequential
//! path, so shard count never changes a result bit or a charged round.
//!
//! # DeviceCollective
//!
//! The `device_*` methods are the same collectives over device-resident
//! [`DeviceVec`] handles — the **reduce** verb of the runtime's backend
//! contract. They charge the *identical* rounds/vectors/`sim_time_s` as
//! the host methods (both funnel through the same internal `charge`), so
//! `ClusterMeter` and the paper's Table-1 counts stay authoritative no
//! matter which plane the bytes moved on. The reduce itself runs the
//! `redm{M}` artifact, whose f64 machine-order interior makes the
//! downloaded result bit-identical to the host path; cluster sizes
//! without a `redm{M}` artifact transparently fall back to
//! materialize -> host collective -> re-upload (same round accounting,
//! honestly metered extra device traffic).
//!
//! # Fault injection
//!
//! With `faults=on` a seeded [`faults::FaultPlan`] rides on the network
//! and every `charge` scales that round's [`NetModel`] time by the plan's
//! factor for the round (slowest straggler × dropout redistribution; see
//! the `faults` module docs). The scaling touches `sim_time_s` ONLY —
//! rounds, vectors, the `ClusterMeter`, and every iterate stay bitwise
//! identical with faults on or off, and `faults=off` (the default) never
//! constructs a plan at all, so not even the multiply happens. What the
//! [`crate::accounting::FaultMeter`] does NOT measure: real wall-clock
//! (it is simulated network time), and real thread failures (those are
//! the shard pool's supervised-recovery counters, merged into the same
//! meter at run end but counted on the host, not drawn from the seed).

pub mod faults;
pub mod netmodel;

use crate::accounting::ClusterMeter;
use crate::runtime::{chain, DeviceVec, Engine};
use anyhow::Result;
use faults::FaultPlan;
use netmodel::NetModel;

#[derive(Clone, Debug, Default)]
pub struct CommStats {
    pub rounds: u64,
    pub vectors_moved: u64,
    pub sim_time_s: f64,
}

pub struct Network {
    pub m: usize,
    pub stats: CommStats,
    pub model: NetModel,
    /// seeded fault injection (`faults=on`): scales each round's simulated
    /// time, never the counts. `None` (the default) is bitwise identical
    /// to a build without the fault layer.
    pub faults: Option<FaultPlan>,
}

impl Network {
    pub fn new(m: usize, model: NetModel) -> Self {
        Self { m, stats: CommStats::default(), model, faults: None }
    }

    /// Attach (or detach) a fault plan. The plan's round index is this
    /// network's own round counter, so the schedule is identical on every
    /// plane and shard count.
    pub fn with_faults(mut self, faults: Option<FaultPlan>) -> Self {
        self.faults = faults;
        self
    }

    fn charge(&mut self, meter: &mut ClusterMeter, vectors_per_machine: u64, dim: usize) {
        assert_eq!(meter.m(), self.m);
        meter.all_comm_round(vectors_per_machine);
        let round = self.stats.rounds;
        self.stats.rounds += 1;
        self.stats.vectors_moved += vectors_per_machine * self.m as u64;
        let mut dt = self.model.round_time(vectors_per_machine, dim, self.m);
        if let Some(plan) = self.faults.as_mut() {
            dt = plan.scale(round, dt);
        }
        self.stats.sim_time_s += dt;
    }

    /// Average one vector per machine; every machine ends with the mean.
    /// One round, one vector sent per machine.
    pub fn all_reduce_avg(&mut self, meter: &mut ClusterMeter, locals: &mut [Vec<f32>]) {
        assert_eq!(locals.len(), self.m);
        let dim = locals[0].len();
        let mut mean = vec![0.0f64; dim];
        for v in locals.iter() {
            assert_eq!(v.len(), dim, "ragged all-reduce");
            for (s, &x) in mean.iter_mut().zip(v) {
                *s += x as f64;
            }
        }
        let inv = 1.0 / self.m as f64;
        let mean32: Vec<f32> = mean.iter().map(|&s| (s * inv) as f32).collect();
        for v in locals.iter_mut() {
            v.copy_from_slice(&mean32);
        }
        self.charge(meter, 1, dim);
    }

    /// Weighted all-reduce: machines contribute (weight, vector); every
    /// machine ends with the weighted mean. Used to combine block-sum
    /// gradients with per-machine valid counts exactly.
    pub fn all_reduce_weighted(
        &mut self,
        meter: &mut ClusterMeter,
        weights: &[f64],
        locals: &mut [Vec<f32>],
    ) {
        assert_eq!(locals.len(), self.m);
        assert_eq!(weights.len(), self.m);
        let dim = locals[0].len();
        for v in locals.iter() {
            assert_eq!(v.len(), dim, "ragged all-reduce");
        }
        host_reduce_weighted(weights, locals);
        self.charge(meter, 1, dim);
    }

    /// One machine's vector becomes known to all. One round.
    pub fn broadcast(&mut self, meter: &mut ClusterMeter, src: usize, locals: &mut [Vec<f32>]) {
        assert!(src < self.m);
        let dim = locals[src].len();
        let v = locals[src].clone();
        for (i, l) in locals.iter_mut().enumerate() {
            if i != src {
                l.clear();
                l.extend_from_slice(&v);
            }
        }
        self.charge(meter, 1, dim);
    }

    /// All-reduce a scalar per machine (counts as one round of one vector —
    /// the paper's unit; scalars ride along with vectors in practice).
    pub fn all_reduce_scalar_sum(&mut self, meter: &mut ClusterMeter, locals: &mut [f64]) {
        assert_eq!(locals.len(), self.m);
        let sum: f64 = locals.iter().sum();
        for l in locals.iter_mut() {
            *l = sum;
        }
        self.charge(meter, 1, 1);
    }

    /// Device-resident weighted all-reduce: every machine's handle is
    /// consumed, the weighted mean comes back as ONE shared handle (the
    /// simulated cluster shares a device, so "every machine ends with the
    /// mean" is a handle clone away). Charged exactly like
    /// [`Network::all_reduce_weighted`].
    pub fn device_all_reduce_weighted(
        &mut self,
        meter: &mut ClusterMeter,
        engine: &mut Engine,
        weights: &[f64],
        locals: &[DeviceVec],
    ) -> Result<DeviceVec> {
        assert_eq!(locals.len(), self.m);
        assert_eq!(weights.len(), self.m);
        let dim = locals[0].len();
        let out = if self.m == 1 {
            // single machine: the weighted mean of one vector is itself
            locals[0].clone()
        } else if engine.red_ready(self.m, dim) && chain::weights_f32_exact(weights) {
            engine.reduce_weighted_dev(locals, weights)?
        } else {
            // honest fallback for unserved cluster sizes — or weights the
            // f32 device plane cannot carry exactly (counts > 2^24):
            // host collective, extra device traffic metered as real
            let mut host: Vec<Vec<f32>> =
                locals.iter().map(|v| engine.materialize(v)).collect::<Result<_>>()?;
            host_reduce_weighted(weights, &mut host);
            engine.upload_dev(&host.pop().unwrap(), &[dim])?
        };
        self.charge(meter, 1, dim);
        Ok(out)
    }

    /// Device-resident unweighted all-reduce (weights all 1, like
    /// [`Network::all_reduce_avg`] — and bit-identical to it).
    pub fn device_all_reduce_avg(
        &mut self,
        meter: &mut ClusterMeter,
        engine: &mut Engine,
        locals: &[DeviceVec],
    ) -> Result<DeviceVec> {
        let weights = vec![1.0f64; locals.len()];
        self.device_all_reduce_weighted(meter, engine, &weights, locals)
    }

    /// Device-resident broadcast: machine `src`'s handle becomes known to
    /// all. On the shared simulated device this is a handle clone; the
    /// round is charged exactly like [`Network::broadcast`].
    pub fn device_broadcast(
        &mut self,
        meter: &mut ClusterMeter,
        src: usize,
        v: &DeviceVec,
    ) -> DeviceVec {
        assert!(src < self.m);
        self.charge(meter, 1, v.len());
        v.clone()
    }
}

/// The host weighted-mean combine (shared by `all_reduce_weighted` and the
/// device fallback path so the two cannot drift).
fn host_reduce_weighted(weights: &[f64], locals: &mut [Vec<f32>]) {
    let dim = locals[0].len();
    let mut sum = vec![0.0f64; dim];
    let mut wtot = 0.0f64;
    for (w, v) in weights.iter().zip(locals.iter()) {
        wtot += w;
        for (s, &x) in sum.iter_mut().zip(v) {
            *s += w * x as f64;
        }
    }
    let inv = if wtot > 0.0 { 1.0 / wtot } else { 0.0 };
    let mean32: Vec<f32> = sum.iter().map(|&s| (s * inv) as f32).collect();
    for v in locals.iter_mut() {
        v.copy_from_slice(&mean32);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::testkit::{assert_close, forall, normal_vec};

    fn net(m: usize) -> (Network, ClusterMeter) {
        (Network::new(m, NetModel::default()), ClusterMeter::new(m))
    }

    #[test]
    fn all_reduce_is_mean() {
        let (mut n, mut meter) = net(2);
        let mut locals = vec![vec![1.0, 3.0], vec![3.0, 5.0]];
        n.all_reduce_avg(&mut meter, &mut locals);
        assert_close(&locals[0], &[2.0, 4.0], 1e-6, 0.0);
        assert_close(&locals[1], &[2.0, 4.0], 1e-6, 0.0);
        assert_eq!(meter.report().comm_rounds, 1);
    }

    #[test]
    fn prop_all_reduce_matches_sequential_mean() {
        forall(32, |rng| {
            let m = 1 + rng.next_below(8);
            let dim = 1 + rng.next_below(16);
            let (mut n, mut meter) = net(m);
            let mut locals: Vec<Vec<f32>> = (0..m).map(|_| normal_vec(rng, dim)).collect();
            let mut expect = vec![0.0f64; dim];
            for v in &locals {
                for (e, &x) in expect.iter_mut().zip(v) {
                    *e += x as f64 / m as f64;
                }
            }
            let expect32: Vec<f32> = expect.iter().map(|&x| x as f32).collect();
            n.all_reduce_avg(&mut meter, &mut locals);
            for v in &locals {
                assert_close(v, &expect32, 1e-5, 1e-6);
            }
        });
    }

    #[test]
    fn prop_weighted_all_reduce() {
        forall(24, |rng| {
            let m = 1 + rng.next_below(6);
            let dim = 1 + rng.next_below(8);
            let (mut n, mut meter) = net(m);
            let mut locals: Vec<Vec<f32>> = (0..m).map(|_| normal_vec(rng, dim)).collect();
            let weights: Vec<f64> = (0..m).map(|_| 1.0 + rng.next_f64() * 9.0).collect();
            let wtot: f64 = weights.iter().sum();
            let mut expect = vec![0.0f64; dim];
            for (w, v) in weights.iter().zip(&locals) {
                for (e, &x) in expect.iter_mut().zip(v) {
                    *e += w * x as f64 / wtot;
                }
            }
            let expect32: Vec<f32> = expect.iter().map(|&x| x as f32).collect();
            n.all_reduce_weighted(&mut meter, &weights, &mut locals);
            for v in &locals {
                assert_close(v, &expect32, 1e-4, 1e-5);
            }
        });
    }

    #[test]
    fn broadcast_copies_from_source() {
        let (mut n, mut meter) = net(3);
        let mut locals = vec![vec![0.0; 2], vec![7.0, 8.0], vec![0.0; 2]];
        n.broadcast(&mut meter, 1, &mut locals);
        for v in &locals {
            assert_close(v, &[7.0, 8.0], 0.0, 0.0);
        }
        assert_eq!(n.stats.rounds, 1);
    }

    #[test]
    fn scalar_sum() {
        let (mut n, mut meter) = net(4);
        let mut xs = vec![1.0, 2.0, 3.0, 4.0];
        n.all_reduce_scalar_sum(&mut meter, &mut xs);
        assert!(xs.iter().all(|&x| (x - 10.0).abs() < 1e-12));
    }

    #[test]
    fn rounds_accumulate_in_meter_and_stats() {
        let (mut n, mut meter) = net(2);
        let mut locals = vec![vec![0.0; 4], vec![1.0; 4]];
        for _ in 0..5 {
            n.all_reduce_avg(&mut meter, &mut locals);
        }
        assert_eq!(n.stats.rounds, 5);
        assert_eq!(meter.report().comm_rounds, 5);
        assert!(n.stats.sim_time_s > 0.0);
    }

    #[test]
    fn zero_probability_fault_plan_is_bitwise_invisible() {
        use faults::{FaultParams, FaultPlan};
        let m = 4;
        let drive = |mut n: Network| {
            let mut meter = ClusterMeter::new(m);
            let mut locals: Vec<Vec<f32>> = (0..m).map(|i| vec![i as f32; 8]).collect();
            for _ in 0..7 {
                n.all_reduce_avg(&mut meter, &mut locals);
            }
            (n.stats.sim_time_s.to_bits(), n.stats.rounds, locals)
        };
        let plain = drive(Network::new(m, NetModel::default()));
        let zeroed = drive(
            Network::new(m, NetModel::default())
                .with_faults(Some(FaultPlan::new(3, m, FaultParams::zero()))),
        );
        assert_eq!(plain, zeroed, "a plan that never fires must not change a bit");
    }

    #[test]
    fn live_fault_plan_scales_sim_time_only() {
        use faults::{FaultParams, FaultPlan};
        let m = 4;
        let params = FaultParams { straggler_p: 1.0, ..FaultParams::default() };
        let mut base = Network::new(m, NetModel::default());
        let mut hit = Network::new(m, NetModel::default())
            .with_faults(Some(FaultPlan::new(3, m, params)));
        let mut meter_a = ClusterMeter::new(m);
        let mut meter_b = ClusterMeter::new(m);
        let mut la: Vec<Vec<f32>> = (0..m).map(|i| vec![i as f32; 8]).collect();
        let mut lb = la.clone();
        for _ in 0..5 {
            base.all_reduce_avg(&mut meter_a, &mut la);
            hit.all_reduce_avg(&mut meter_b, &mut lb);
        }
        assert_eq!(la, lb, "faults never touch the reduced values");
        assert_eq!(base.stats.rounds, hit.stats.rounds);
        assert_eq!(base.stats.vectors_moved, hit.stats.vectors_moved);
        assert_eq!(meter_a.report(), meter_b.report(), "paper units are fault-free");
        assert!(hit.stats.sim_time_s > base.stats.sim_time_s, "p=1 must add time");
        let fm = &hit.faults.as_ref().unwrap().meter;
        assert_eq!(fm.slow_rounds, 5);
        assert!((fm.added_time_s - (hit.stats.sim_time_s - base.stats.sim_time_s)).abs() < 1e-12);
    }
}
