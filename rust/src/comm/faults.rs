//! Seeded, deterministic fault injection for the simulated cluster.
//!
//! A [`FaultPlan`] scales the α–β network model's per-round time with
//! per-machine straggler slowdowns (heavy-tail Pareto draws) and machine
//! dropout windows (a dropped machine leaves for `dropout_rounds`
//! collective rounds; survivors carry its share, and it re-enters at the
//! next collective boundary). The plan lives strictly OUTSIDE the
//! bit-parity surface: it multiplies the simulated `sim_time_s` of each
//! collective round and feeds the [`FaultMeter`], never the iterates,
//! curves, or paper-units counts (rounds, vectors, samples, memory).
//!
//! # Determinism
//!
//! Fault randomness forks off the experiment seed through a reserved
//! stream tag ([`FAULT_TAG`]), so it is independent of every data stream,
//! and each (round, machine) cell draws from its own pure split —
//! `root.split(round).split(machine)` — making the whole plan a function
//! of `(seed, m, params, round)` alone. The coordinator charges each
//! collective exactly once regardless of plane or shard count, and rounds
//! are indexed by the network's own monotone round counter, so the same
//! config produces the identical fault sequence at shards {1, 2, 4} and
//! across reruns (pinned by `rust/tests/fault_parity.rs`).
//!
//! # Exactness of the off switch
//!
//! `faults=off` never constructs a plan — the charge path does not even
//! multiply. A zero-probability plan computes a factor of exactly `1.0`
//! and returns `dt` untouched (no f64 round-trip: the `1.0` branch is
//! short-circuited), so it is asserted bitwise equal to no plan at all.

use crate::accounting::FaultMeter;
use crate::util::prng::Prng;

/// Stream-split tag reserved for fault randomness. Data streams split off
/// the raw seed with machine tags `0..m` (and the evaluator with its own
/// tag); the fault stream forks through this tag first so it can never
/// collide with them.
const FAULT_TAG: u64 = 0xFA17;

/// Whether the run constructs a [`FaultPlan`] at all. Off is the default
/// and is bitwise identical to a build without the fault layer.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum FaultsPolicy {
    #[default]
    Off,
    On,
}

impl FaultsPolicy {
    pub fn parse(s: &str) -> Option<FaultsPolicy> {
        match s {
            "off" => Some(FaultsPolicy::Off),
            "on" => Some(FaultsPolicy::On),
            _ => None,
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            FaultsPolicy::Off => "off",
            FaultsPolicy::On => "on",
        }
    }

    pub fn enabled(&self) -> bool {
        matches!(self, FaultsPolicy::On)
    }
}

/// The knobs behind the `faults.*` config namespace.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FaultParams {
    /// per-machine per-round probability of straggling (`faults.straggler_p`)
    pub straggler_p: f64,
    /// Pareto tail index of the straggler slowdown factor
    /// (`faults.slowdown_alpha`); smaller = heavier tail, draws are >= 1
    pub slowdown_alpha: f64,
    /// per-machine per-round probability of dropping out (`faults.dropout_p`)
    pub dropout_p: f64,
    /// collective rounds a dropped machine stays out before re-entering
    /// (`faults.dropout_rounds`)
    pub dropout_rounds: u64,
}

impl Default for FaultParams {
    fn default() -> Self {
        FaultParams { straggler_p: 0.1, slowdown_alpha: 1.5, dropout_p: 0.0, dropout_rounds: 3 }
    }
}

impl FaultParams {
    /// A plan that can never fire — used by the parity tests to assert the
    /// fault layer's presence is bitwise invisible.
    pub fn zero() -> Self {
        FaultParams { straggler_p: 0.0, slowdown_alpha: 1.5, dropout_p: 0.0, dropout_rounds: 1 }
    }
}

/// A seeded fault schedule over the m-machine cluster, consulted once per
/// collective round by `comm::Network::charge`. Stateless per (round,
/// machine) except for the dropout windows, which advance with the round
/// counter only — never with wall-clock or thread timing.
pub struct FaultPlan {
    root: Prng,
    m: usize,
    pub params: FaultParams,
    /// exclusive round index machine `i` stays dropped until; 0 = in
    /// (machines re-enter at the first collective boundary past their
    /// window, which is where the simulated cluster re-admits them)
    dropped_until: Vec<u64>,
    /// simulated-event counts plus added sim-time (see [`FaultMeter`])
    pub meter: FaultMeter,
}

impl FaultPlan {
    pub fn new(seed: u64, m: usize, params: FaultParams) -> FaultPlan {
        FaultPlan {
            root: Prng::seed_from_u64(seed).split(FAULT_TAG),
            m,
            params,
            dropped_until: vec![0; m],
            meter: FaultMeter::default(),
        }
    }

    /// The multiplicative sim-time factor for collective round `round`:
    /// the slowest participating machine's slowdown (a round completes
    /// when the last machine arrives) times the `m/(m-k)` redistribution
    /// factor when `k` machines are dropped out (survivors carry their
    /// share). Exactly `1.0` when nothing fires. The last active machine
    /// is never allowed to drop, so a round can always complete.
    pub fn round_factor(&mut self, round: u64) -> f64 {
        let mut dropped = 0usize;
        let mut max_slow = 1.0f64;
        for i in 0..self.m {
            if self.dropped_until[i] > round {
                dropped += 1;
                self.meter.dropped_rounds += 1;
                continue;
            }
            if self.dropped_until[i] != 0 && self.dropped_until[i] == round {
                self.meter.reentries += 1;
                self.dropped_until[i] = 0;
            }
            // fixed draw order per (round, machine): dropout first, then
            // straggler — the plan never depends on who asks or when
            let mut rng = self.root.split(round).split(i as u64);
            if self.params.dropout_p > 0.0
                && rng.next_f64() < self.params.dropout_p
                && self.m - (dropped + 1) >= 1
            {
                self.dropped_until[i] = round + self.params.dropout_rounds.max(1);
                self.meter.dropouts += 1;
                self.meter.dropped_rounds += 1;
                dropped += 1;
                continue; // a dropped machine neither works nor straggles
            }
            if self.params.straggler_p > 0.0 && rng.next_f64() < self.params.straggler_p {
                let slow = rng.next_pareto(self.params.slowdown_alpha);
                self.meter.stragglers += 1;
                if slow > max_slow {
                    max_slow = slow;
                }
            }
        }
        if dropped > 0 {
            max_slow *= self.m as f64 / (self.m - dropped) as f64;
        }
        max_slow
    }

    /// Scale one collective round's model time `dt`. A `1.0` factor
    /// returns `dt` untouched (bitwise — the multiply is skipped), which
    /// is the entire behaviour of a zero-probability plan.
    pub fn scale(&mut self, round: u64, dt: f64) -> f64 {
        let f = self.round_factor(round);
        if f == 1.0 {
            return dt;
        }
        self.meter.slow_rounds += 1;
        self.meter.added_time_s += dt * (f - 1.0);
        dt * f
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stormy() -> FaultParams {
        FaultParams { straggler_p: 0.5, slowdown_alpha: 1.2, dropout_p: 0.3, dropout_rounds: 2 }
    }

    #[test]
    fn zero_probability_plan_is_exactly_identity() {
        let mut plan = FaultPlan::new(7, 4, FaultParams::zero());
        for round in 0..50u64 {
            let dt = 0.1 + round as f64 * 1e-3;
            assert_eq!(plan.scale(round, dt).to_bits(), dt.to_bits(), "round {round}");
        }
        assert_eq!(plan.meter, FaultMeter::default(), "nothing may be recorded");
    }

    #[test]
    fn same_seed_same_plan() {
        let mut a = FaultPlan::new(42, 6, stormy());
        let mut b = FaultPlan::new(42, 6, stormy());
        for round in 0..200u64 {
            let ta = a.scale(round, 0.01);
            let tb = b.scale(round, 0.01);
            assert_eq!(ta.to_bits(), tb.to_bits(), "round {round}");
        }
        assert_eq!(a.meter, b.meter);
        assert!(a.meter.stragglers > 0, "a stormy plan must actually fire");
        assert!(a.meter.dropouts > 0);
        assert!(a.meter.added_time_s > 0.0);
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = FaultPlan::new(1, 6, stormy());
        let mut b = FaultPlan::new(2, 6, stormy());
        let fa: Vec<u64> = (0..100).map(|r| a.round_factor(r).to_bits()).collect();
        let fb: Vec<u64> = (0..100).map(|r| b.round_factor(r).to_bits()).collect();
        assert_ne!(fa, fb);
    }

    #[test]
    fn straggler_severity_is_monotone_in_p() {
        // the per-(round, machine) rng is pure, so the p=0.2 straggler set
        // is a subset of the p=0.5 set with identical slowdown draws —
        // each round's factor can only grow with p
        let mild = FaultParams { straggler_p: 0.2, dropout_p: 0.0, ..stormy() };
        let severe = FaultParams { straggler_p: 0.5, dropout_p: 0.0, ..stormy() };
        let mut a = FaultPlan::new(9, 8, mild);
        let mut b = FaultPlan::new(9, 8, severe);
        for round in 0..200u64 {
            assert!(b.round_factor(round) >= a.round_factor(round), "round {round}");
        }
        assert!(b.meter.stragglers >= a.meter.stragglers);
    }

    #[test]
    fn dropout_redistributes_and_reenters() {
        let params = FaultParams {
            straggler_p: 0.0,
            slowdown_alpha: 1.5,
            dropout_p: 1.0,
            dropout_rounds: 3,
        };
        let mut plan = FaultPlan::new(3, 4, params);
        // round 0: p=1 drops machines until only one survivor remains
        // (the last-machine guard), so the factor is m/(m-k) = 4/1
        let f0 = plan.round_factor(0);
        assert_eq!(f0, 4.0);
        assert_eq!(plan.meter.dropouts, 3);
        // rounds 1..3: the dropped machines are still out; the survivor
        // cannot drop (guard), so the factor stays at the redistribution
        for round in 1..3u64 {
            assert_eq!(plan.round_factor(round), 4.0, "round {round}");
        }
        // round 3 = the dropout window's exclusive end: all three re-enter
        // at this collective boundary (and, with p=1, immediately re-drop —
        // the re-entry is still counted)
        plan.round_factor(3);
        assert_eq!(plan.meter.reentries, 3);
    }

    #[test]
    fn last_machine_never_drops() {
        let params = FaultParams {
            straggler_p: 0.0,
            slowdown_alpha: 1.5,
            dropout_p: 1.0,
            dropout_rounds: 5,
        };
        let mut plan = FaultPlan::new(11, 1, params);
        for round in 0..20u64 {
            assert_eq!(plan.round_factor(round), 1.0, "round {round}");
        }
        assert_eq!(plan.meter.dropouts, 0);
    }

    #[test]
    fn scale_accumulates_added_time() {
        let params = FaultParams { straggler_p: 1.0, dropout_p: 0.0, ..stormy() };
        let mut plan = FaultPlan::new(5, 4, params);
        let dt = 0.25;
        let scaled = plan.scale(0, dt);
        assert!(scaled > dt, "p=1 must straggle");
        assert_eq!(plan.meter.slow_rounds, 1);
        assert!((plan.meter.added_time_s - (scaled - dt)).abs() < 1e-12);
    }

    #[test]
    fn policy_parses() {
        assert_eq!(FaultsPolicy::parse("on"), Some(FaultsPolicy::On));
        assert_eq!(FaultsPolicy::parse("off"), Some(FaultsPolicy::Off));
        assert_eq!(FaultsPolicy::parse("maybe"), None);
        assert!(!FaultsPolicy::default().enabled());
        assert_eq!(FaultsPolicy::On.as_str(), "on");
    }
}
