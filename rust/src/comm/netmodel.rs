//! α–β network cost model for simulated wall-clock.
//!
//! One collective round over `m` machines moving one `dim`-dimensional f32
//! vector per machine is modeled as a tree-structured reduce+broadcast:
//!
//! ```text
//!     T(round) = 2 * ceil(log2 m) * (alpha + bytes / bandwidth)
//! ```
//!
//! This never enters the paper's resource counts (those are rounds/vectors);
//! it only converts them into the simulated-time columns the examples print
//! so the communication-vs-computation crossover is visible. With
//! `faults=on` the per-round time is additionally scaled by the seeded
//! fault plan (see `comm::faults`) — still simulated time only.

#[derive(Clone, Debug)]
pub struct NetModel {
    /// per-message latency, seconds
    pub alpha: f64,
    /// bandwidth, bytes/second
    pub beta_bytes_per_s: f64,
}

impl Default for NetModel {
    fn default() -> Self {
        // 50 us latency, 1 GiB/s — commodity datacenter Ethernet circa the
        // paper (2017); override per run with the `net.alpha` / `net.beta`
        // config keys (validated in config::ExperimentConfig).
        Self { alpha: 50e-6, beta_bytes_per_s: 1_073_741_824.0 }
    }
}

impl NetModel {
    pub fn round_time(&self, vectors_per_machine: u64, dim: usize, m: usize) -> f64 {
        let hops = 2.0 * (m.max(2) as f64).log2().ceil();
        let bytes = vectors_per_machine as f64 * dim as f64 * 4.0;
        hops * (self.alpha + bytes / self.beta_bytes_per_s)
    }

    /// An infinitely-fast network (pure round counting).
    pub fn zero() -> Self {
        Self { alpha: 0.0, beta_bytes_per_s: f64::INFINITY }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_grows_with_dim_and_machines() {
        let nm = NetModel::default();
        assert!(nm.round_time(1, 128, 4) > nm.round_time(1, 64, 4));
        assert!(nm.round_time(1, 64, 16) > nm.round_time(1, 64, 4));
        assert!(nm.round_time(2, 64, 4) > nm.round_time(1, 64, 4));
    }

    #[test]
    fn zero_model_is_free() {
        assert_eq!(NetModel::zero().round_time(10, 1024, 64), 0.0);
    }

    #[test]
    fn latency_dominates_small_messages() {
        let nm = NetModel::default();
        let t_small = nm.round_time(1, 1, 2);
        // 2 hops * alpha
        assert!((t_small - 2.0 * nm.alpha) / t_small < 1e-3);
    }
}
