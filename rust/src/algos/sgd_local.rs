//! Single-machine streaming SGD — the "Ideal Solution" reference row of
//! Table 1 (given all n samples on one machine it is the statistically
//! optimal O(1)-memory, zero-communication method).
//!
//! Runs on machine 0 only. Samples are processed in vectorized chunks of
//! `chunk` (an engine-batching detail); each chunk applies one step with
//! the chunk-mean gradient and the smoothed inverse stepsize
//! `gamma = beta + sqrt(4 T / chunk) L / B` (Prop. 13 with m = 1), which is
//! the correct stepsize family for chunk-mean updates — per-sample
//! stepsizes do not survive chunking (the sum of per-sample steps over a
//! chunk would exceed the stability region). Suffix averaging as in
//! minibatch_sgd.rs.
//!
//! Machine 0's stream, batch and gradient all live wherever the plane
//! puts machine 0: the chunk is drawn through the plane's draw verb and
//! the chunk-mean gradient through `ExecPlane::local_mean_grad` (no
//! collective — this method communicates nothing), so on the sharded
//! plane the samples never visit the coordinator.

use super::{Method, PackMode, Recorder, RunContext, RunResult};
use crate::linalg::{self, WeightedAvg};
use anyhow::Result;

pub struct LocalSgd {
    /// total samples to consume
    pub n_total: usize,
    /// inverse stepsize gamma (Prop. 13 with m = 1, b = chunk)
    pub gamma: f64,
    /// samples per engine dispatch
    pub chunk: usize,
}

impl Method for LocalSgd {
    fn name(&self) -> String {
        format!("local-sgd[n={}]", self.n_total)
    }

    fn run(&mut self, ctx: &mut RunContext) -> Result<RunResult> {
        let d = ctx.d;
        let mut rec = Recorder::new(self.name());
        let mut w = vec![0.0f32; d];
        let mut avg = WeightedAvg::new(d);
        ctx.meter.machine(0).hold(2);
        let chunk = self.chunk.max(1);
        let steps = self.n_total.div_ceil(chunk);
        let step = (1.0 / self.gamma) as f32;
        let lane = ctx.plane.grad_lane(ctx.loss, d);
        for t in 1..=steps {
            // the draw verb charges machine 0's samples where they are
            // actually generated (coordinator or owning shard)
            let batch = ctx.draw_machine(0, chunk, false, PackMode::GradOnly)?;
            let batches = [batch];
            let w_pv = ctx.plane.lift(lane, &w)?;
            let g_pv = ctx.local_mean_grad_pv(lane, &batches, 0, &w_pv)?;
            let g = ctx.plane.into_host(g_pv)?;
            drop(batches);
            linalg::axpy(-step, &g, &mut w);
            ctx.meter.machine(0).add_vec_ops(1);
            // suffix averaging (last half) — see minibatch_sgd.rs
            if 2 * t > steps {
                avg.add(1.0, &w);
            }
            // eval iterate (and its d-length mean) built only at
            // checkpoints — the same audit as minibatch_sgd.rs
            if ctx.eval_due(t) {
                let eval_w = if avg.total_weight() > 0.0 { avg.mean() } else { w.clone() };
                if let Some(obj) = ctx.eval_now(&eval_w)? {
                    rec.point(ctx, t, Some(obj));
                }
            }
        }
        ctx.meter.machine(0).release(2);
        rec.finish(ctx, avg.mean())
    }
}
