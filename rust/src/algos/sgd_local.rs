//! Single-machine streaming SGD — the "Ideal Solution" reference row of
//! Table 1 (given all n samples on one machine it is the statistically
//! optimal O(1)-memory, zero-communication method).
//!
//! Runs on machine 0 only. Samples are processed in vectorized chunks of
//! `chunk` (an engine-batching detail); each chunk applies one step with
//! the chunk-mean gradient and the smoothed inverse stepsize
//! `gamma = beta + sqrt(4 T / chunk) L / B` (Prop. 13 with m = 1), which is
//! the correct stepsize family for chunk-mean updates — per-sample
//! stepsizes do not survive chunking (the sum of per-sample steps over a
//! chunk would exceed the stability region). Suffix averaging as in
//! minibatch_sgd.rs.

use super::{Method, Recorder, RunContext, RunResult};
use crate::linalg::WeightedAvg;
use crate::objective::{local_grad_sum, MachineBatch};
use anyhow::Result;

pub struct LocalSgd {
    /// total samples to consume
    pub n_total: usize,
    /// inverse stepsize gamma (Prop. 13 with m = 1, b = chunk)
    pub gamma: f64,
    /// samples per engine dispatch
    pub chunk: usize,
}

impl Method for LocalSgd {
    fn name(&self) -> String {
        format!("local-sgd[n={}]", self.n_total)
    }

    fn run(&mut self, ctx: &mut RunContext) -> Result<RunResult> {
        let d = ctx.d;
        let mut rec = Recorder::new(self.name());
        let mut w = vec![0.0f32; d];
        let mut avg = WeightedAvg::new(d);
        ctx.meter.machine(0).hold(2);
        let chunk = self.chunk.max(1);
        let steps = self.n_total.div_ceil(chunk);
        let step = (1.0 / self.gamma) as f32;
        for t in 1..=steps {
            let samples = ctx.streams[0].draw_many(chunk);
            ctx.meter.machine(0).add_samples(chunk as u64);
            // single-machine method: the batch lives (and dies) on the
            // coordinator engine on every plane
            let batch = MachineBatch::pack(ctx.plane.engine, d, &samples)?;
            let out =
                local_grad_sum(ctx.plane.engine, ctx.loss, &batch, &w, ctx.meter.machine(0))?;
            let cnt = out.count.max(1.0) as f32;
            for j in 0..d {
                w[j] -= step * out.grad_sum[j] / cnt;
            }
            ctx.meter.machine(0).add_vec_ops(1);
            // suffix averaging (last half) — see minibatch_sgd.rs
            if 2 * t > steps {
                avg.add(1.0, &w);
            }
            // eval iterate (and its d-length mean) built only at
            // checkpoints — the same audit as minibatch_sgd.rs
            if ctx.eval_due(t) {
                let eval_w = if avg.total_weight() > 0.0 { avg.mean() } else { w.clone() };
                if let Some(obj) = ctx.eval_now(&eval_w)? {
                    rec.point(ctx, t, Some(obj));
                }
            }
        }
        ctx.meter.machine(0).release(2);
        rec.finish(ctx, avg.mean())
    }
}
