//! Accelerated minibatch SGD (Cotter et al. 2011).
//!
//! Nesterov-accelerated stochastic gradient with minibatch gradients:
//! acceleration lets the minibatch grow to `bm = O(n^{3/4})` while keeping
//! statistical optimality, making this the most communication-efficient
//! O(1)-memory baseline in Table 1 (`B^{1/2} n^{1/4}` rounds).
//!
//! ```text
//!     y_t = w_t + ((t-1)/(t+2)) (w_t - w_{t-1})
//!     w_{t+1} = y_t - eta grad phi_{I_t}(y_t)
//! ```
//!
//! with eta = 1/gamma, gamma = beta + sqrt(4T/(bm)) L/B (the smoothed
//! stepsize of Prop. 13 — the same scaling Cotter et al. use).
//!
//! Like minibatch SGD, the gradient at the momentum point rides the
//! plane's gradient lane (chained kernels + collective on the
//! device-capable planes, tupled dispatches on the host plane).

use super::{Method, Recorder, RunContext, RunResult};
use crate::linalg::WeightedAvg;
use anyhow::Result;

pub struct AccelMinibatchSgd {
    pub b_local: usize,
    pub t_outer: usize,
    pub gamma: f64,
}

impl Method for AccelMinibatchSgd {
    fn name(&self) -> String {
        format!("acc-minibatch-sgd[b={},T={}]", self.b_local, self.t_outer)
    }

    fn run(&mut self, ctx: &mut RunContext) -> Result<RunResult> {
        let d = ctx.d;
        let mut rec = Recorder::new(self.name());
        let mut w = vec![0.0f32; d];
        let mut w_prev = vec![0.0f32; d];
        let mut avg = WeightedAvg::new(d);
        let step = (1.0 / self.gamma) as f32;
        // O(1) memory: w, w_prev, momentum point
        for i in 0..ctx.meter.m() {
            ctx.meter.machine(i).hold(3);
        }
        let lane = ctx.plane.grad_lane(ctx.loss, d);
        for t in 1..=self.t_outer {
            let mom = ((t - 1) as f32) / ((t + 2) as f32);
            let y: Vec<f32> =
                (0..d).map(|j| w[j] + mom * (w[j] - w_prev[j])).collect();
            let batches = ctx.draw_batches_grad_only(self.b_local, false)?;
            let y_pv = ctx.plane.lift(lane, &y)?;
            let g_pv = ctx.mean_grad_pv(lane, &batches, &y_pv)?;
            let g = ctx.plane.into_host(g_pv)?;
            drop(batches);
            w_prev = std::mem::replace(
                &mut w,
                (0..d).map(|j| y[j] - step * g[j]).collect(),
            );
            ctx.meter.all_vec_ops(2);
            // suffix averaging (last half) — see minibatch_sgd.rs
            if 2 * t > self.t_outer {
                avg.add(1.0, &w);
            }
            // evaluation iterate built only at checkpoints — see
            // minibatch_sgd.rs
            if ctx.eval_due(t) {
                let eval_w = if avg.total_weight() > 0.0 { avg.mean() } else { w.clone() };
                if let Some(obj) = ctx.eval_now(&eval_w)? {
                    rec.point(ctx, t, Some(obj));
                }
            }
        }
        for i in 0..ctx.meter.m() {
            ctx.meter.machine(i).release(3);
        }
        rec.finish(ctx, avg.mean())
    }
}
