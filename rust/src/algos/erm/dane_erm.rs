//! DANE on the regularized ERM objective (Shamir, Srebro & Zhang 2014),
//! written against the execution plane.
//!
//! Each round: all-reduce the full gradient (1 round), every machine
//! solves its local corrected objective with VR sweeps over its shard,
//! all-reduce the local solutions (1 round). Table 1 row: O(B^2 m) rounds
//! for quadratics, n/m memory. Reuses the same mu = global-gradient
//! identity as the minibatch DANE solver (see solvers/dane.rs) — and the
//! same plane verb for the local solves, so the two cannot drift.

use crate::algos::solvers::{Lane, LocalSolver};
use crate::algos::{Method, Recorder, RunContext, RunResult};
use anyhow::Result;

use super::ErmProblem;

pub struct DaneErm {
    pub n_total: usize,
    pub nu: f64,
    pub rounds: usize,
    /// local VR sweeps per round (multi-pass re-snapshots, Host lane only)
    pub local_passes: usize,
    pub eta: f64,
}

impl Method for DaneErm {
    fn name(&self) -> String {
        format!("dane-erm[n={},rounds={}]", self.n_total, self.rounds)
    }

    fn run(&mut self, ctx: &mut RunContext) -> Result<RunResult> {
        let mut rec = Recorder::new(self.name());
        let prob = ErmProblem::draw(ctx, self.n_total, self.nu)?;
        let d = ctx.d;
        let zero = vec![0.0f32; d];
        let lane = if self.local_passes > 1 {
            Lane::Host
        } else {
            ctx.plane.vr_lane(ctx.loss, ctx.d)
        };
        let mut z = vec![0.0f32; d];
        for k in 0..self.rounds {
            // full regularized gradient at z — 1 comm round (host path)
            let g = prob.full_grad(ctx, &z)?;
            let mut g_smooth = g.clone();
            crate::linalg::axpy(-(self.nu as f32), &z, &mut g_smooth);
            // every machine's local solve fans to its plane home (shard or
            // coordinator engine) through the shared DANE-local verb
            let z_pv = ctx.plane.lift(lane, &z)?;
            let g_pv = ctx.plane.lift(lane, &g_smooth)?;
            let locals = ctx.local_sweep_all(
                lane,
                LocalSolver::Svrg,
                &prob.shards,
                &z,
                &z_pv,
                &g_pv,
                &zero,
                self.nu as f32,
                self.eta as f32,
                self.local_passes.max(1),
            )?;
            let z_red = ctx.all_reduce_avg_pv(locals)?;
            z = ctx.plane.into_host(z_red)?;
            if let Some(obj) = ctx.maybe_eval(k + 1, &z)? {
                rec.point(ctx, k + 1, Some(obj));
            }
        }
        prob.release(ctx);
        rec.finish(ctx, z)
    }
}
