//! DANE on the regularized ERM objective (Shamir, Srebro & Zhang 2014).
//!
//! Each round: all-reduce the full gradient (1 round), every machine
//! solves its local corrected objective with SVRG sweeps over its shard,
//! all-reduce the local solutions (1 round). Table 1 row: O(B^2 m) rounds
//! for quadratics, n/m memory. Reuses the same mu = global-gradient
//! identity as the minibatch DANE solver (see solvers/dane.rs).

use crate::algos::solvers::{vr_sweep_machine, LocalSolver};
use crate::algos::{Method, Recorder, RunContext, RunResult};
use crate::objective::fan_machines;
use anyhow::Result;
use std::sync::Arc;

use super::ErmProblem;

pub struct DaneErm {
    pub n_total: usize,
    pub nu: f64,
    pub rounds: usize,
    /// local SVRG sweeps per round
    pub local_passes: usize,
    pub eta: f64,
}

impl Method for DaneErm {
    fn name(&self) -> String {
        format!("dane-erm[n={},rounds={}]", self.n_total, self.rounds)
    }

    fn run(&mut self, ctx: &mut RunContext) -> Result<RunResult> {
        let mut rec = Recorder::new(self.name());
        let prob = ErmProblem::draw(ctx, self.n_total, self.nu)?;
        let d = ctx.d;
        let zero = vec![0.0f32; d];
        let mut z = vec![0.0f32; d];
        for k in 0..self.rounds {
            let g = prob.full_grad(ctx, &z)?;
            let mut g_smooth = g.clone();
            crate::linalg::axpy(-(self.nu as f32), &z, &mut g_smooth);
            // every machine's local solve fans to its owning shard (or
            // runs inline on the sequential plane)
            let loss = ctx.loss;
            let passes = self.local_passes.max(1);
            let (nu32, eta32) = (self.nu as f32, self.eta as f32);
            let z_s: Arc<[f32]> = Arc::from(&z[..]);
            let g_s: Arc<[f32]> = Arc::from(&g_smooth[..]);
            let zero_s: Arc<[f32]> = Arc::from(&zero[..]);
            let mut locals: Vec<Vec<f32>> = fan_machines(
                ctx.engine,
                ctx.shards,
                &prob.shards,
                &mut ctx.meter,
                move |eng, shard, _i, meter| {
                    let mut xi = z_s.to_vec();
                    for _pass in 0..passes {
                        let blocks = 0..shard.n_blocks();
                        let (_xe, xa) = vr_sweep_machine(
                            eng,
                            loss,
                            LocalSolver::Svrg,
                            blocks,
                            shard,
                            &xi,
                            &z_s,
                            &g_s,
                            &zero_s,
                            nu32,
                            eta32,
                            meter,
                        )?;
                        xi = xa;
                    }
                    Ok(xi)
                },
            )?;
            ctx.net.all_reduce_avg(&mut ctx.meter, &mut locals);
            z = locals.pop().unwrap();
            if let Some(obj) = ctx.maybe_eval(k + 1, &z)? {
                rec.point(ctx, k + 1, Some(obj));
            }
        }
        prob.release(ctx);
        rec.finish(ctx, z)
    }
}
