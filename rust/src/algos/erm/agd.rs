//! Distributed accelerated gradient descent on the regularized ERM
//! objective — the naive batch baseline of Table 1 (`B^{1/2} n^{1/4}`
//! rounds of communication, each computing one full distributed gradient).
//!
//! Nesterov's method for nu-strongly-convex, (beta+nu)-smooth objectives
//! with the constant momentum (sqrt(kappa)-1)/(sqrt(kappa)+1).

use crate::algos::{Method, Recorder, RunContext, RunResult};
use anyhow::Result;

use super::ErmProblem;

pub struct DistributedAgd {
    pub n_total: usize,
    pub nu: f64,
    pub beta: f64,
    pub rounds: usize,
}

impl Method for DistributedAgd {
    fn name(&self) -> String {
        format!("agd-erm[n={},rounds={}]", self.n_total, self.rounds)
    }

    fn run(&mut self, ctx: &mut RunContext) -> Result<RunResult> {
        let mut rec = Recorder::new(self.name());
        let prob = ErmProblem::draw_grad_only(ctx, self.n_total, self.nu)?;
        let d = ctx.d;
        let smooth = self.beta + self.nu;
        let step = (1.0 / smooth) as f32;
        let kappa = smooth / self.nu.max(1e-12);
        let mom = ((kappa.sqrt() - 1.0) / (kappa.sqrt() + 1.0)) as f32;
        let mut w = vec![0.0f32; d];
        let mut w_prev = vec![0.0f32; d];
        for k in 0..self.rounds {
            let y: Vec<f32> = (0..d).map(|j| w[j] + mom * (w[j] - w_prev[j])).collect();
            let g = prob.full_grad(ctx, &y)?; // 1 comm round
            w_prev = std::mem::replace(&mut w, (0..d).map(|j| y[j] - step * g[j]).collect());
            ctx.meter.all_vec_ops(2);
            if let Some(obj) = ctx.maybe_eval(k + 1, &w)? {
                rec.point(ctx, k + 1, Some(obj));
            }
        }
        prob.release(ctx);
        rec.finish(ctx, w)
    }
}
