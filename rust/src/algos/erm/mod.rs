//! ERM-based batch baselines (Section 1/2 of the paper).
//!
//! These methods bypass the streaming setting: they draw the full sample
//! budget `n` up front, shard it across the machines (memory n/m vectors
//! per machine for the entire run) and optimize the regularized empirical
//! objective
//!
//! ```text
//!     min_w phi_S(w) + nu/2 ||w||^2 ,   nu = L / (B sqrt(n))
//! ```
//!
//! Shared setup lives here; the individual optimizers are DSVRG-on-ERM
//! (Lee et al. 2015), DANE (Shamir et al. 2014), distributed accelerated
//! GD, and a DiSCO-style distributed inexact Newton. Like the minibatch
//! solvers, each optimizer has one body programmed against the execution
//! plane.

pub mod agd;
pub mod dane_erm;
pub mod disco;
pub mod dsvrg_erm;

use super::RunContext;
use crate::objective::MachineBatch;
use crate::runtime::plane::{Lane, PlaneVec};
use anyhow::Result;

/// The fixed training set, sharded: machine i owns `shards[i]`.
pub struct ErmProblem {
    pub shards: Vec<MachineBatch>,
    pub n_total: usize,
    pub nu: f64,
}

impl ErmProblem {
    /// Draw `n_total` fresh samples (n/m requested per machine), charge
    /// memory, and build the regularized ERM problem. `n_total` records
    /// what was *actually* drawn — a finite-ERM scenario's epoch-bounded
    /// stream may return a short final shard.
    pub fn draw(ctx: &mut RunContext, n_total: usize, nu: f64) -> Result<ErmProblem> {
        let m = ctx.m();
        let per = n_total.div_ceil(m);
        let shards = ctx.draw_batches(per, true)?;
        let n_total = shards.iter().map(|b| b.n).sum();
        Ok(ErmProblem { shards, n_total, nu })
    }

    /// Like [`ErmProblem::draw`] for optimizers that only take the
    /// grad/normal-matvec path (AGD, DiSCO): no host block retention.
    pub fn draw_grad_only(ctx: &mut RunContext, n_total: usize, nu: f64) -> Result<ErmProblem> {
        let m = ctx.m();
        let per = n_total.div_ceil(m);
        let shards = ctx.draw_batches_grad_only(per, true)?;
        let n_total = shards.iter().map(|b| b.n).sum();
        Ok(ErmProblem { shards, n_total, nu })
    }

    /// Release the held shard memory (end of run): each shard recorded
    /// what it held at draw time.
    pub fn release(&self, ctx: &mut RunContext) {
        ctx.release_batches(&self.shards);
    }

    /// Regularized full gradient: one all-reduce round (the host tupled
    /// dispatch path — the gradient-only baselines read it on every
    /// plane).
    pub fn full_grad(&self, ctx: &mut RunContext, w: &[f32]) -> Result<Vec<f32>> {
        let (mut g, _, _) = ctx.mean_grad_loss(&self.shards, w)?;
        crate::linalg::axpy(self.nu as f32, w, &mut g);
        ctx.meter.all_vec_ops(1);
        Ok(g)
    }

    /// [`ErmProblem::full_grad`] on an explicit lane over plane vectors
    /// (DiSCO's Newton gradient): identical accounting, and on the Dev
    /// lane the gradient never visits the host.
    pub fn full_grad_pv(
        &self,
        ctx: &mut RunContext,
        lane: Lane,
        w: &PlaneVec,
    ) -> Result<PlaneVec> {
        let g = ctx.mean_grad_pv(lane, &self.shards, w)?;
        let out = ctx.plane.axpby(1.0, &g, self.nu as f32, w)?;
        ctx.meter.all_vec_ops(1);
        Ok(out)
    }
}
