//! DiSCO-style distributed inexact (damped) Newton on the regularized ERM
//! objective (Zhang & Lin 2015), squared loss only.
//!
//! Each Newton iteration solves `(H + nu I) v = grad` by *distributed
//! preconditioner-free CG*: every CG iteration applies the Hessian-vector
//! product through the machines' `nm_sq` blocks and all-reduces — one
//! communication round per CG step, which is where DiSCO's
//! `B^{1/2} m^{1/4}` round count comes from. The update is the damped step
//! `w <- w - v / (1 + delta)` with the Newton decrement damping.
//!
//! With the chained artifacts present the Newton state (`w`, `g`, `v`,
//! CG residuals) stays on device: the Hessian-vector product is the
//! `nacc{K}` chain + DeviceCollective reduce, and only `vdot` scalars
//! cross to the host per CG step. `w` materializes at evaluation
//! checkpoints and at the end of the run — the same places the host path
//! reads it.

use crate::algos::solvers::exact_cg::{
    chained_cg, distributed_normal_matvec, distributed_normal_matvec_dev, host_cg,
};
use crate::algos::{Method, Recorder, RunContext, RunResult};
use crate::data::Loss;
use crate::linalg;
use crate::runtime::DeviceVec;
use anyhow::{bail, Result};

use super::ErmProblem;

pub struct Disco {
    pub n_total: usize,
    pub nu: f64,
    pub newton_iters: usize,
    pub cg_tol: f64,
    pub cg_max: usize,
}

impl Disco {
    fn chain_ready(&self, ctx: &RunContext) -> bool {
        ctx.engine.chain_grad_ready(ctx.loss.tag(), ctx.d)
            && ctx.engine.chain_nm_ready(ctx.d)
            && ctx.engine.red_ready(ctx.m(), ctx.d)
    }

    fn run_legacy(
        &mut self,
        ctx: &mut RunContext,
        prob: &ErmProblem,
        rec: &mut Recorder,
    ) -> Result<Vec<f32>> {
        let d = ctx.d;
        let mut w = vec![0.0f32; d];
        for it in 0..self.newton_iters {
            let g = prob.full_grad(ctx, &w)?; // 1 round
            // distributed CG on (H + nu I) v = g — the shared driver;
            // 1 comm round per CG iteration through the hvp matvec
            let v = host_cg(
                ctx,
                |ctx, p| hvp(ctx, prob, p),
                &g,
                vec![0.0f32; d],
                self.cg_tol,
                self.cg_max,
            )?;
            // damped Newton step: delta = sqrt(v^T (H+nu) v)
            let hv_final = hvp(ctx, prob, &v)?;
            let delta = linalg::dot(&v, &hv_final).max(0.0).sqrt();
            let damp = (1.0 / (1.0 + delta)) as f32;
            linalg::axpy(-damp, &v, &mut w);
            ctx.meter.all_vec_ops(1);
            if let Some(obj) = ctx.maybe_eval(it + 1, &w)? {
                rec.point(ctx, it + 1, Some(obj));
            }
        }
        Ok(w)
    }

    fn run_chained(
        &mut self,
        ctx: &mut RunContext,
        prob: &ErmProblem,
        rec: &mut Recorder,
    ) -> Result<Vec<f32>> {
        let mut w = ctx.engine.zeros_dev(ctx.d)?;
        for it in 0..self.newton_iters {
            let g = prob.full_grad_dev(ctx, &w)?; // 1 round
            let x0 = ctx.engine.zeros_dev(ctx.d)?;
            let v = chained_cg(
                ctx,
                |ctx, p| hvp_dev(ctx, prob, p),
                &g,
                x0,
                self.cg_tol,
                self.cg_max,
            )?;
            let hv_final = hvp_dev(ctx, prob, &v)?;
            let delta = ctx.engine.vec_dot(&v, &hv_final)?.max(0.0).sqrt();
            let damp = (1.0 / (1.0 + delta)) as f32;
            w = ctx.engine.vec_axpby(1.0, &w, -damp, &v)?;
            ctx.meter.all_vec_ops(1);
            // evaluation checkpoint: the same policy as the legacy path,
            // read THROUGH the device iterate (aliased, no materialization)
            if let Some(obj) = ctx.maybe_eval_dev(it + 1, &w)? {
                rec.point(ctx, it + 1, Some(obj));
            }
        }
        // the run boundary: materialize the final iterate once
        ctx.engine.materialize(&w)
    }
}

impl Method for Disco {
    fn name(&self) -> String {
        format!("disco-erm[n={},newton={}]", self.n_total, self.newton_iters)
    }

    fn run(&mut self, ctx: &mut RunContext) -> Result<RunResult> {
        if ctx.loss != Loss::Squared {
            bail!("disco baseline implemented for the squared loss (as in the paper's analysis)");
        }
        let mut rec = Recorder::new(self.name());
        let prob = ErmProblem::draw_grad_only(ctx, self.n_total, self.nu)?;
        let w = if self.chain_ready(ctx) {
            self.run_chained(ctx, &prob, &mut rec)?
        } else {
            self.run_legacy(ctx, &prob, &mut rec)?
        };
        prob.release(ctx);
        rec.finish(ctx, w)
    }
}

/// Distributed regularized Hessian-vector product (1 comm round): the
/// same operator as the exact-CG prox system with `gamma = nu` — one
/// implementation, two callers, no drift.
fn hvp(ctx: &mut RunContext, prob: &ErmProblem, v: &[f32]) -> Result<Vec<f32>> {
    distributed_normal_matvec(ctx, &prob.shards, v, prob.nu)
}

/// Device-chained [`hvp`]: `nacc{K}` chains + DeviceCollective reduce,
/// identical accounting, zero downloads.
fn hvp_dev(ctx: &mut RunContext, prob: &ErmProblem, v: &DeviceVec) -> Result<DeviceVec> {
    distributed_normal_matvec_dev(ctx, &prob.shards, v, prob.nu)
}
