//! DiSCO-style distributed inexact (damped) Newton on the regularized ERM
//! objective (Zhang & Lin 2015), squared loss only.
//!
//! Each Newton iteration solves `(H + nu I) v = grad` by *distributed
//! preconditioner-free CG*: every CG iteration applies the Hessian-vector
//! product through the machines' `nm_sq` blocks and all-reduces — one
//! communication round per CG step, which is where DiSCO's
//! `B^{1/2} m^{1/4}` round count comes from. The update is the damped step
//! `w <- w - v / (1 + delta)` with the Newton decrement damping.

use crate::algos::{Method, Recorder, RunContext, RunResult};
use crate::data::Loss;
use crate::linalg;
use anyhow::{bail, Result};

use super::ErmProblem;

pub struct Disco {
    pub n_total: usize,
    pub nu: f64,
    pub newton_iters: usize,
    pub cg_tol: f64,
    pub cg_max: usize,
}

impl Method for Disco {
    fn name(&self) -> String {
        format!("disco-erm[n={},newton={}]", self.n_total, self.newton_iters)
    }

    fn run(&mut self, ctx: &mut RunContext) -> Result<RunResult> {
        if ctx.loss != Loss::Squared {
            bail!("disco baseline implemented for the squared loss (as in the paper's analysis)");
        }
        let mut rec = Recorder::new(self.name());
        let prob = ErmProblem::draw_grad_only(ctx, self.n_total, self.nu)?;
        let d = ctx.d;
        let mut w = vec![0.0f32; d];
        for it in 0..self.newton_iters {
            let g = prob.full_grad(ctx, &w)?; // 1 round
            // distributed CG on (H + nu I) v = g
            let mut v = vec![0.0f32; d];
            let mut hv = hvp(ctx, &prob, &v)?;
            let mut r: Vec<f32> = (0..d).map(|j| g[j] - hv[j]).collect();
            let mut p = r.clone();
            let gnorm = linalg::nrm2(&g).max(1e-30);
            let mut rs_old = linalg::dot(&r, &r);
            for _ in 0..self.cg_max {
                if rs_old.sqrt() / gnorm <= self.cg_tol {
                    break;
                }
                hv = hvp(ctx, &prob, &p)?; // 1 round per CG iteration
                let p_hp = linalg::dot(&p, &hv);
                if p_hp <= 0.0 {
                    break;
                }
                let alpha = (rs_old / p_hp) as f32;
                linalg::axpy(alpha, &p, &mut v);
                linalg::axpy(-alpha, &hv, &mut r);
                let rs_new = linalg::dot(&r, &r);
                let beta = (rs_new / rs_old) as f32;
                for j in 0..d {
                    p[j] = r[j] + beta * p[j];
                }
                ctx.meter.all_vec_ops(3);
                rs_old = rs_new;
            }
            // damped Newton step: delta = sqrt(v^T (H+nu) v)
            let hv_final = hvp(ctx, &prob, &v)?;
            let delta = linalg::dot(&v, &hv_final).max(0.0).sqrt();
            let damp = (1.0 / (1.0 + delta)) as f32;
            linalg::axpy(-damp, &v, &mut w);
            ctx.meter.all_vec_ops(1);
            if let Some(obj) = ctx.maybe_eval(it + 1, &w)? {
                rec.point(ctx, it + 1, Some(obj));
            }
        }
        prob.release(ctx);
        rec.finish(ctx, w)
    }
}

/// Distributed regularized Hessian-vector product (1 comm round).
fn hvp(ctx: &mut RunContext, prob: &ErmProblem, v: &[f32]) -> Result<Vec<f32>> {
    let m = prob.shards.len();
    let mut locals: Vec<Vec<f32>> = Vec::with_capacity(m);
    let mut weights: Vec<f64> = Vec::with_capacity(m);
    for (i, shard) in prob.shards.iter().enumerate() {
        let mut acc = vec![0.0f32; ctx.d];
        let mut cnt = 0.0;
        // fused groups: one Hessian-vector dispatch per group
        for blk in &shard.groups {
            let (part, c) = ctx.engine.nm_block(blk, v)?;
            linalg::axpy(1.0, &part, &mut acc);
            cnt += c;
        }
        if cnt > 0.0 {
            linalg::scale(1.0 / cnt as f32, &mut acc);
        }
        ctx.meter.machine(i).add_vec_ops(shard.n as u64);
        locals.push(acc);
        weights.push(cnt);
    }
    ctx.net.all_reduce_weighted(&mut ctx.meter, &weights, &mut locals);
    let mut out = locals.pop().unwrap();
    linalg::axpy(prob.nu as f32, v, &mut out);
    ctx.meter.all_vec_ops(1);
    Ok(out)
}
