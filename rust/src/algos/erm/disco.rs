//! DiSCO-style distributed inexact (damped) Newton on the regularized ERM
//! objective (Zhang & Lin 2015), squared loss only — written ONCE against
//! the execution plane.
//!
//! Each Newton iteration solves `(H + nu I) v = grad` by *distributed
//! preconditioner-free CG*: every CG iteration applies the Hessian-vector
//! product through the machines' `nm_sq` blocks and all-reduces — one
//! communication round per CG step, which is where DiSCO's
//! `B^{1/2} m^{1/4}` round count comes from. The update is the damped step
//! `w <- w - v / (1 + delta)` with the Newton decrement damping.
//!
//! On the Dev lane the Newton state (`w`, `g`, `v`, CG residuals) stays
//! on device: the Hessian-vector product is the `nacc{K}` chain +
//! DeviceCollective reduce, and only `vdot` scalars cross to the host per
//! CG step. `w` materializes at evaluation checkpoints and at the end of
//! the run — the same places the Host lane reads it.

use crate::algos::solvers::exact_cg::{normal_matvec_pv, plane_cg};
use crate::algos::{Method, Recorder, RunContext, RunResult};
use crate::data::Loss;
use crate::runtime::PlaneVec;
use anyhow::{bail, Result};

use super::ErmProblem;

pub struct Disco {
    pub n_total: usize,
    pub nu: f64,
    pub newton_iters: usize,
    pub cg_tol: f64,
    pub cg_max: usize,
}

impl Method for Disco {
    fn name(&self) -> String {
        format!("disco-erm[n={},newton={}]", self.n_total, self.newton_iters)
    }

    fn run(&mut self, ctx: &mut RunContext) -> Result<RunResult> {
        if ctx.loss != Loss::Squared {
            bail!("disco baseline implemented for the squared loss (as in the paper's analysis)");
        }
        let mut rec = Recorder::new(self.name());
        let prob = ErmProblem::draw_grad_only(ctx, self.n_total, self.nu)?;
        let lane = ctx.plane.cg_lane(ctx.loss, ctx.d, ctx.m());
        let mut w = ctx.plane.zeros(lane, ctx.d)?;
        for it in 0..self.newton_iters {
            let g = prob.full_grad_pv(ctx, lane, &w)?; // 1 round
            // distributed CG on (H + nu I) v = g — the shared driver;
            // 1 comm round per CG iteration through the hvp matvec
            let x0 = ctx.plane.zeros(lane, ctx.d)?;
            let v = plane_cg(
                ctx,
                |ctx, p| hvp(ctx, &prob, p),
                &g,
                x0,
                self.cg_tol,
                self.cg_max,
            )?;
            // damped Newton step: delta = sqrt(v^T (H+nu) v)
            let hv_final = hvp(ctx, &prob, &v)?;
            let delta = ctx.plane.dot(&v, &hv_final)?.max(0.0).sqrt();
            let damp = (1.0 / (1.0 + delta)) as f32;
            w = ctx.plane.axpby(1.0, &w, -damp, &v)?;
            ctx.meter.all_vec_ops(1);
            // evaluation checkpoint: read through the plane iterate (the
            // Dev lane aliases the handle — no materialization)
            if let Some(obj) = ctx.maybe_eval_pv(it + 1, &w)? {
                rec.point(ctx, it + 1, Some(obj));
            }
        }
        // the run boundary: materialize the final iterate once
        let w_host = ctx.plane.into_host(w)?;
        prob.release(ctx);
        rec.finish(ctx, w_host)
    }
}

/// Distributed regularized Hessian-vector product (1 comm round): the
/// same operator as the exact-CG prox system with `gamma = nu` — one
/// implementation, two callers, no drift.
fn hvp(ctx: &mut RunContext, prob: &ErmProblem, v: &PlaneVec) -> Result<PlaneVec> {
    normal_matvec_pv(ctx, &prob.shards, v, prob.nu)
}
