//! DSVRG on the regularized ERM objective (Section 2; Lee et al. 2015,
//! Shamir 2016), written against the execution plane.
//!
//! Outer epoch k: all machines all-reduce the full regularized gradient at
//! the snapshot z (1 round); a single designated machine then performs one
//! without-replacement variance-reduced pass over its *local shard* and
//! broadcasts the pass average as the new iterate (1 round). With
//! n/m >= condition number (n >= m^2 regime, see the paper), O(log 1/eps)
//! epochs reach eps on both the empirical and stochastic objectives —
//! giving the Table-1 row: O(1)~log communication, n/m memory.
//!
//! The designated sweep rides the plane's VR lane: per-block host kernels
//! on the Host lane, `[2, d]`-state chains over the fused groups on the
//! chained lanes (on the owning shard when the problem shards are
//! shard-resident). The full gradient stays on the host tupled path — the
//! epoch gradient is read once per round, so chaining it buys nothing.

use crate::algos::solvers::LocalSolver;
use crate::algos::{Method, Recorder, RunContext, RunResult};
use anyhow::Result;

use super::ErmProblem;

pub struct DsvrgErm {
    pub n_total: usize,
    pub nu: f64,
    /// epochs (theory: O(log n))
    pub epochs: usize,
    pub eta: f64,
}

impl Method for DsvrgErm {
    fn name(&self) -> String {
        format!("dsvrg-erm[n={},epochs={}]", self.n_total, self.epochs)
    }

    fn run(&mut self, ctx: &mut RunContext) -> Result<RunResult> {
        let mut rec = Recorder::new(self.name());
        let prob = ErmProblem::draw(ctx, self.n_total, self.nu)?;
        let m = prob.shards.len();
        let d = ctx.d;
        let lane = ctx.plane.vr_lane(ctx.loss, ctx.d);
        let zero = vec![0.0f32; d];
        // p = 1: each designated pass sweeps the machine's WHOLE shard.
        // The svrg kernel's quadratic term gamma (x - center) realizes
        // the nu/2 ||w||^2 regularizer with gamma = nu, center = 0.
        let mut sweeper = ctx.plane.vr_sweeper(
            lane,
            &prob.shards,
            1,
            LocalSolver::Svrg,
            &zero,
            &zero,
            self.nu as f32,
            self.eta as f32,
        )?;
        let mut z = vec![0.0f32; d];
        for k in 0..self.epochs {
            // full regularized gradient at the snapshot — 1 comm round
            let mu = prob.full_grad(ctx, &z)?;
            // mu must be the *unregularized* smooth gradient (the kernel
            // adds the quadratic term itself): subtract nu z.
            let mut mu_smooth = mu.clone();
            crate::linalg::axpy(-(self.nu as f32), &z, &mut mu_smooth);
            let j = k % m;
            // the designated sweep runs on machine j's plane home
            let z_pv = ctx.plane.lift(lane, &z)?;
            let mu_pv = ctx.plane.lift(lane, &mu_smooth)?;
            let z_new = ctx.vr_sweep(&mut sweeper, &prob.shards, j, 0, &z_pv, &mu_pv)?;
            // broadcast the new iterate — 1 comm round
            let z_bc = ctx.broadcast_pv(j, z_new);
            z = ctx.plane.into_host(z_bc)?;
            if let Some(obj) = ctx.maybe_eval(k + 1, &z)? {
                rec.point(ctx, k + 1, Some(obj));
            }
        }
        prob.release(ctx);
        rec.finish(ctx, z)
    }
}
