//! DSVRG on the regularized ERM objective (Section 2; Lee et al. 2015,
//! Shamir 2016).
//!
//! Outer epoch k: all machines all-reduce the full regularized gradient at
//! the snapshot z (1 round); a single designated machine then performs one
//! without-replacement variance-reduced pass over its *local shard* and
//! broadcasts the pass average as the new iterate (1 round). With
//! n/m >= condition number (n >= m^2 regime, see the paper), O(log 1/eps)
//! epochs reach eps on both the empirical and stochastic objectives —
//! giving the Table-1 row: O(1)~log communication, n/m memory.

use crate::algos::solvers::{vr_sweep_on, LocalSolver};
use crate::algos::{Method, Recorder, RunContext, RunResult};
use anyhow::Result;

use super::ErmProblem;

pub struct DsvrgErm {
    pub n_total: usize,
    pub nu: f64,
    /// epochs (theory: O(log n))
    pub epochs: usize,
    pub eta: f64,
}

impl Method for DsvrgErm {
    fn name(&self) -> String {
        format!("dsvrg-erm[n={},epochs={}]", self.n_total, self.epochs)
    }

    fn run(&mut self, ctx: &mut RunContext) -> Result<RunResult> {
        let mut rec = Recorder::new(self.name());
        let prob = ErmProblem::draw(ctx, self.n_total, self.nu)?;
        let m = prob.shards.len();
        let d = ctx.d;
        let mut z = vec![0.0f32; d];
        let mut x = vec![0.0f32; d];
        for k in 0..self.epochs {
            // full regularized gradient at the snapshot — 1 comm round
            let mu = prob.full_grad(ctx, &z)?;
            // designated machine sweeps its local shard once.
            // The svrg kernel's quadratic term gamma (x - center) realizes
            // the nu/2 ||w||^2 regularizer with gamma = nu, center = 0, so
            // mu must be the *unregularized* smooth gradient: subtract nu z.
            let mut mu_smooth = mu.clone();
            crate::linalg::axpy(-(self.nu as f32), &z, &mut mu_smooth);
            let j = k % m;
            let zero = vec![0.0f32; d];
            let blocks = 0..prob.shards[j].n_blocks();
            // the designated sweep runs on machine j's shard when the
            // problem shards are shard-plane-resident
            let (x_end, x_avg) = vr_sweep_on(
                ctx,
                LocalSolver::Svrg,
                blocks,
                &prob.shards,
                j,
                &x,
                &z,
                &mu_smooth,
                &zero,
                self.nu as f32,
                self.eta as f32,
            )?;
            x = x_end;
            z = x_avg;
            // broadcast the new iterate — 1 comm round
            let mut locals: Vec<Vec<f32>> = (0..m).map(|_| z.clone()).collect();
            ctx.net.broadcast(&mut ctx.meter, j, &mut locals);
            if let Some(obj) = ctx.maybe_eval(k + 1, &z)? {
                rec.point(ctx, k + 1, Some(obj));
            }
        }
        prob.release(ctx);
        rec.finish(ctx, z)
    }
}
