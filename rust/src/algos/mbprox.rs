//! The minibatch-prox outer loop (Section 3 / Algorithm 1 outer `for`).
//!
//! At iteration t every machine draws a fresh minibatch of `b_local`
//! samples (memory: b vectors held for the duration of the inner solve,
//! released afterwards — this is exactly the communication/memory tradeoff
//! knob of Figure 1), the inner [`ProxSolver`] approximately minimizes
//!
//! ```text
//!     f_t(w) = phi_{I_t}(w) + gamma/2 ||w - w_{t-1}||^2
//! ```
//!
//! and the method returns the uniform average of the iterates
//! (Theorem 4/7, weakly convex losses; `weighted` enables the
//! t-weighted average of Theorem 5/8 for strongly convex losses).

use super::solvers::ProxSolver;
use super::{Method, Recorder, RunContext, RunResult};
use crate::linalg::WeightedAvg;
use anyhow::Result;

pub struct MinibatchProx<S: ProxSolver> {
    pub b_local: usize,
    pub t_outer: usize,
    pub gamma: f64,
    pub solver: S,
    /// t-weighted averaging (strongly convex case, Theorem 5/8)
    pub weighted: bool,
    /// label used in reports, e.g. "mp-dsvrg"
    pub label: String,
}

impl<S: ProxSolver> MinibatchProx<S> {
    pub fn new(label: &str, b_local: usize, t_outer: usize, gamma: f64, solver: S) -> Self {
        Self { b_local, t_outer, gamma, solver, weighted: false, label: label.to_string() }
    }
}

impl<S: ProxSolver> Method for MinibatchProx<S> {
    fn name(&self) -> String {
        format!("{}[b={},T={},{}]", self.label, self.b_local, self.t_outer, self.solver.name())
    }

    fn run(&mut self, ctx: &mut RunContext) -> Result<RunResult> {
        let d = ctx.d;
        let mut rec = Recorder::new(self.name());
        let mut w = vec![0.0f32; d]; // w_0 = 0 (Remark 9: compete with ||w|| <= B)
        let mut avg = WeightedAvg::new(d);
        // each machine permanently holds O(1) iterate vectors
        for i in 0..ctx.meter.m() {
            ctx.meter.machine(i).hold(2);
        }
        for t in 1..=self.t_outer {
            // fresh minibatch, held in memory for the inner solve, packed
            // the way the solver's lane wants it (host blocks retained for
            // Host-lane per-block sweeps; fused groups — aligned so none
            // straddles the solver's batch partition — for chained sweeps;
            // grad-only for dispatch-verb solvers)
            let mode = self.solver.pack_mode(ctx);
            let batches = ctx.draw_batches_mode(self.b_local, true, mode)?;
            let w_new = self.solver.solve(ctx, &batches, &w, self.gamma, t)?;
            ctx.release_batches(&batches);
            drop(batches);
            w = w_new;
            let weight = if self.weighted { t as f64 } else { 1.0 };
            avg.add(weight, &w);
            // the d-length averaged iterate is only materialized at
            // checkpoints — not every outer iteration
            if ctx.eval_due(t) {
                if let Some(obj) = ctx.eval_now(&avg.mean())? {
                    rec.point(ctx, t, Some(obj));
                }
            }
        }
        for i in 0..ctx.meter.m() {
            ctx.meter.machine(i).release(2);
        }
        rec.finish(ctx, avg.mean())
    }
}
