//! Distributed minibatch SGD (Dekel et al. 2012; Proposition 13).
//!
//! Each round all machines contribute a fresh local minibatch to a single
//! averaged gradient (one all-reduce), then take the linearized step
//! `w <- w - (1/gamma_t) grad`. Streaming: the batch is *not* retained —
//! memory is O(1) vectors per machine, which is exactly the property the
//! paper contrasts with minibatch-prox's b-vector memory.
//!
//! The mean gradient rides the plane's gradient lane
//! (`ExecPlane::grad_lane`): the `gacc{K}` chain + device/host collective
//! on the chained and sharded planes (one d-vector materialize per round
//! on the Dev lane), the legacy tupled dispatches on the host plane —
//! identical rounds/vec-ops/sample accounting on every lane.

use super::{Method, Recorder, RunContext, RunResult};
use crate::linalg::{self, WeightedAvg};
use anyhow::Result;

pub struct MinibatchSgd {
    pub b_local: usize,
    pub t_outer: usize,
    /// inverse stepsize gamma (Prop. 13: beta + sqrt(4T/(bm)) L/B)
    pub gamma: f64,
}

impl Method for MinibatchSgd {
    fn name(&self) -> String {
        format!("minibatch-sgd[b={},T={}]", self.b_local, self.t_outer)
    }

    fn run(&mut self, ctx: &mut RunContext) -> Result<RunResult> {
        let d = ctx.d;
        let mut rec = Recorder::new(self.name());
        let mut w = vec![0.0f32; d];
        let mut avg = WeightedAvg::new(d);
        let step = (1.0 / self.gamma) as f32;
        // O(1) memory: iterate + gradient accumulator
        for i in 0..ctx.meter.m() {
            ctx.meter.machine(i).hold(2);
        }
        let lane = ctx.plane.grad_lane(ctx.loss, d);
        for t in 1..=self.t_outer {
            // streaming batch: packed, used once, dropped (no hold charge);
            // grad-only: no host block retention
            let batches = ctx.draw_batches_grad_only(self.b_local, false)?;
            let w_pv = ctx.plane.lift(lane, &w)?;
            let g_pv = ctx.mean_grad_pv(lane, &batches, &w_pv)?;
            let g = ctx.plane.into_host(g_pv)?;
            drop(batches);
            linalg::axpy(-step, &g, &mut w);
            ctx.meter.all_vec_ops(1);
            // suffix averaging (last half): removes the far-initialization
            // bias of uniform averaging without changing the rate
            // (Rakhlin et al. / Lacoste-Julien et al. style)
            if 2 * t > self.t_outer {
                avg.add(1.0, &w);
            }
            // evaluation iterate built only at checkpoints (the mean is a
            // d-length allocation)
            if ctx.eval_due(t) {
                let eval_w = if avg.total_weight() > 0.0 { avg.mean() } else { w.clone() };
                if let Some(obj) = ctx.eval_now(&eval_w)? {
                    rec.point(ctx, t, Some(obj));
                }
            }
        }
        for i in 0..ctx.meter.m() {
            ctx.meter.machine(i).release(2);
        }
        rec.finish(ctx, avg.mean())
    }
}
