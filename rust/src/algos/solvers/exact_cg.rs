//! Exact prox solver for least squares via distributed conjugate
//! gradient, written ONCE against the execution plane.
//!
//! The prox subproblem for the squared loss has a linear optimality system
//!
//! ```text
//!     ((1/n) X^T X + gamma I) w = (1/n) X^T y + gamma w_prev
//! ```
//!
//! whose matvec is the `nm_sq_*` artifact. Each CG iteration applies the
//! operator distributedly (every machine processes its own blocks) and
//! all-reduces the partial results — one communication round per CG
//! iteration. This is the "exact minibatch-prox" reference (Theorem 4/5)
//! that the inexact solvers are validated against, and doubles as the
//! DiSCO-style Newton system solver for the ERM baselines.
//!
//! Lane notes: the CG recurrence runs on the coordinator either way —
//! [`plane_cg`] is ONE recurrence over [`PlaneVec`]s whose per-lane
//! primitives are f64 host dots (Host lane) or the f32 `vdot` kernel (Dev
//! lane, two scalar downloads per iteration as the entire steady-state
//! downlink). On the Dev lane the vectors live on device: the matvec
//! chains `nacc{K}` accumulators into the DeviceCollective reduce (or
//! fans host-bits partials across the shard plane, where the recurrence
//! still holds device handles on the coordinator engine), the recurrences
//! are `vaxpby` dispatches, and the solution materializes once at the
//! end.

use super::{PackMode, ProxSolver};
use crate::algos::RunContext;
use crate::data::Loss;
use crate::linalg;
use crate::objective::{fan_machines, MachineBatch};
use crate::runtime::PlaneVec;
use anyhow::{bail, Result};
use std::sync::Arc;

pub struct ExactCgSolver {
    pub tol: f64,
    pub max_iters: usize,
}

impl Default for ExactCgSolver {
    fn default() -> Self {
        Self { tol: 1e-9, max_iters: 512 }
    }
}

/// One distributed application of v -> (1/n) X^T X v + gamma v.
/// Charges one comm round and per-machine vec ops; the lane follows the
/// representation of `v`. Host bits: fused tupled dispatches with host
/// accumulation. Device handle: `nacc{K}` accumulator chains per machine
/// into the DeviceCollective reduce (zero downloads) — or, with
/// shard-resident batches, host-bits partials fanned to the shards whose
/// fixed-order f64 combine is bit-identical to the device reduce.
pub fn normal_matvec_pv(
    ctx: &mut RunContext,
    batches: &[MachineBatch],
    v: &PlaneVec,
    gamma: f64,
) -> Result<PlaneVec> {
    let d = ctx.d;
    match v {
        PlaneVec::Host(vh) => {
            let v_s: Arc<[f32]> = Arc::from(&vh[..]);
            let outs: Vec<(Vec<f32>, f64)> = fan_machines(
                ctx.plane.engine,
                ctx.plane.shards,
                batches,
                &mut ctx.meter,
                move |eng, batch, _i, m| {
                    let mut acc = vec![0.0f32; d];
                    let mut cnt = 0.0f64;
                    // fused groups: one dispatch + one download per group,
                    // and `v` is uploaded once per matvec via the session
                    // pool
                    for blk in &batch.groups {
                        let (part, c) = eng.nm_block(blk, &v_s)?;
                        linalg::axpy(1.0, &part, &mut acc);
                        cnt += c;
                    }
                    if cnt > 0.0 {
                        linalg::scale(1.0 / cnt as f32, &mut acc);
                    }
                    m.add_vec_ops(batch.n as u64);
                    Ok((acc, cnt))
                },
            )?;
            let (mut locals, weights): (Vec<Vec<f32>>, Vec<f64>) = outs.into_iter().unzip();
            ctx.net.all_reduce_weighted(&mut ctx.meter, &weights, &mut locals);
            let mut out = locals.pop().unwrap();
            linalg::axpy(gamma as f32, vh, &mut out);
            // local axpy: O(1) vector ops per machine
            ctx.meter.all_vec_ops(1);
            Ok(PlaneVec::Host(out))
        }
        PlaneVec::Dev(vd) => {
            if batches.iter().any(|b| b.shard.is_some()) {
                // shard plane: the direction crosses to the shards as host
                // bits (exact), each machine chains its nacc accumulator
                // on its own engine, and the combine is the host
                // collective — bit-identical to the device reduce. The CG
                // recurrence itself stays on the coordinator engine, so
                // the iterates match the single-engine chained path
                // bit-for-bit.
                let v_host = ctx.plane.engine.materialize(vd)?;
                let v_s: Arc<[f32]> = Arc::from(&v_host[..]);
                let outs: Vec<Vec<f32>> = fan_machines(
                    ctx.plane.engine,
                    ctx.plane.shards,
                    batches,
                    &mut ctx.meter,
                    move |eng, batch, _i, m| {
                        let v_dev = eng.upload_dev(&v_s, &[d])?;
                        let mut acc = eng.zeros_dev(d)?;
                        for blk in &batch.groups {
                            acc = eng.nm_acc(blk, &v_dev, &acc)?;
                        }
                        let cnt = batch.n as f64;
                        if cnt > 0.0 {
                            acc = eng.vec_scale(&acc, (1.0 / cnt) as f32)?;
                        }
                        m.add_vec_ops(batch.n as u64);
                        eng.materialize(&acc)
                    },
                )?;
                let weights: Vec<f64> = batches.iter().map(|b| b.n as f64).collect();
                let mut locals = outs;
                ctx.net.all_reduce_weighted(&mut ctx.meter, &weights, &mut locals);
                let red = ctx.plane.engine.upload_dev(&locals.pop().unwrap(), &[d])?;
                let out = ctx.plane.engine.vec_axpby(1.0, &red, gamma as f32, vd)?;
                ctx.meter.all_vec_ops(1);
                return Ok(PlaneVec::Dev(out));
            }
            let m = batches.len();
            let mut locals = Vec::with_capacity(m);
            let mut weights: Vec<f64> = Vec::with_capacity(m);
            for (i, batch) in batches.iter().enumerate() {
                let mut acc = ctx.plane.engine.zeros_dev(ctx.d)?;
                for blk in &batch.groups {
                    acc = ctx.plane.engine.nm_acc(blk, vd, &acc)?;
                }
                // pack-time count replaces the downloaded one (same value)
                let cnt = batch.n as f64;
                if cnt > 0.0 {
                    acc = ctx.plane.engine.vec_scale(&acc, (1.0 / cnt) as f32)?;
                }
                ctx.meter.machine(i).add_vec_ops(batch.n as u64);
                locals.push(acc);
                weights.push(cnt);
            }
            let red = ctx.net.device_all_reduce_weighted(
                &mut ctx.meter,
                ctx.plane.engine,
                &weights,
                &locals,
            )?;
            let out = ctx.plane.engine.vec_axpby(1.0, &red, gamma as f32, vd)?;
            ctx.meter.all_vec_ops(1);
            Ok(PlaneVec::Dev(out))
        }
    }
}

/// Shared distributed-CG driver over [`PlaneVec`]s: solve `A x = b` from
/// warm start `x0`, where `matvec` applies `A` (charging its own comm
/// round and vec ops). Stopping rules: relative residual below `tol`
/// against the rhs norm, or a non-positive curvature `p^T A p`. The
/// recurrence is ONE code path — per-lane only the primitives differ (f64
/// host dots vs the f32 `vdot` kernel; the host `axpby` loop mirrors the
/// `vaxpby` kernel bit-for-bit) — and it serves the exact-prox system AND
/// the DiSCO Newton system, so the recurrence cannot drift between them.
pub fn plane_cg(
    ctx: &mut RunContext,
    mut matvec: impl FnMut(&mut RunContext, &PlaneVec) -> Result<PlaneVec>,
    b: &PlaneVec,
    x0: PlaneVec,
    tol: f64,
    max_iters: usize,
) -> Result<PlaneVec> {
    let mut x = x0;
    let mut ap = matvec(ctx, &x)?;
    let mut r = ctx.plane.axpby(1.0, b, -1.0, &ap)?;
    let mut p = r.clone();
    let rhs_norm = ctx.plane.dot(b, b)?.sqrt().max(1e-30);
    let mut rs_old = ctx.plane.dot(&r, &r)?;
    for _ in 0..max_iters {
        if rs_old.sqrt() / rhs_norm <= tol {
            break;
        }
        ap = matvec(ctx, &p)?;
        let p_ap = ctx.plane.dot(&p, &ap)?;
        if p_ap <= 0.0 {
            break;
        }
        let alpha = (rs_old / p_ap) as f32;
        x = ctx.plane.axpby(1.0, &x, alpha, &p)?;
        r = ctx.plane.axpby(1.0, &r, -alpha, &ap)?;
        let rs_new = ctx.plane.dot(&r, &r)?;
        let beta = (rs_new / rs_old) as f32;
        p = ctx.plane.axpby(1.0, &r, beta, &p)?;
        ctx.meter.all_vec_ops(3);
        rs_old = rs_new;
    }
    Ok(x)
}

impl ProxSolver for ExactCgSolver {
    fn name(&self) -> String {
        "exact-cg".to_string()
    }

    /// CG only needs grad + normal-matvec dispatches — no VR sweeps.
    fn pack_mode(&self, _ctx: &RunContext) -> PackMode {
        PackMode::GradOnly
    }

    fn solve(
        &mut self,
        ctx: &mut RunContext,
        batches: &[MachineBatch],
        wprev: &[f32],
        gamma: f64,
        _t: usize,
    ) -> Result<Vec<f32>> {
        if ctx.loss != Loss::Squared {
            bail!("exact-cg prox solver requires the squared loss");
        }
        let lane = ctx.plane.cg_lane(ctx.loss, ctx.d, batches.len());
        // rhs = (1/n) X^T y + gamma wprev = -grad(0) + gamma wprev
        let zero = ctx.plane.zeros(lane, ctx.d)?;
        let g0 = ctx.mean_grad_pv(lane, batches, &zero)?;
        let wprev_pv = ctx.plane.lift(lane, wprev)?;
        let b = ctx.plane.axpby(-1.0, &g0, gamma as f32, &wprev_pv)?;
        // CG with the distributed operator (warm start from wprev)
        let x = plane_cg(
            ctx,
            |ctx, v| normal_matvec_pv(ctx, batches, v, gamma),
            &b,
            wprev_pv,
            self.tol,
            self.max_iters,
        )?;
        // the round boundary: the Dev lane's one full-vector download
        ctx.plane.into_host(x)
    }
}
