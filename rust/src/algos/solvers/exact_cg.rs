//! Exact prox solver for least squares via distributed conjugate gradient.
//!
//! The prox subproblem for the squared loss has a linear optimality system
//!
//! ```text
//!     ((1/n) X^T X + gamma I) w = (1/n) X^T y + gamma w_prev
//! ```
//!
//! whose matvec is the `nm_sq_*` artifact. Each CG iteration applies the
//! operator distributedly (every machine processes its own blocks) and
//! all-reduces the partial results — one communication round per CG
//! iteration. This is the "exact minibatch-prox" reference (Theorem 4/5)
//! that the inexact solvers are validated against, and doubles as the
//! DiSCO-style Newton system solver for the ERM baselines.

use super::ProxSolver;
use crate::algos::RunContext;
use crate::data::Loss;
use crate::linalg;
use crate::objective::{distributed_mean_grad, MachineBatch};
use anyhow::{bail, Result};

pub struct ExactCgSolver {
    pub tol: f64,
    pub max_iters: usize,
}

impl Default for ExactCgSolver {
    fn default() -> Self {
        Self { tol: 1e-9, max_iters: 512 }
    }
}

/// One distributed application of v -> (1/n) X^T X v + gamma v.
/// Charges one comm round and per-machine vec ops; returns the result.
pub fn distributed_normal_matvec(
    ctx: &mut RunContext,
    batches: &[MachineBatch],
    v: &[f32],
    gamma: f64,
) -> Result<Vec<f32>> {
    let m = batches.len();
    let mut locals: Vec<Vec<f32>> = Vec::with_capacity(m);
    let mut weights: Vec<f64> = Vec::with_capacity(m);
    for (i, batch) in batches.iter().enumerate() {
        let mut acc = vec![0.0f32; ctx.d];
        let mut cnt = 0.0f64;
        // fused groups: one dispatch + one download per group, and `v` is
        // uploaded once per matvec via the session pool
        for blk in &batch.groups {
            let (part, c) = ctx.engine.nm_block(blk, v)?;
            linalg::axpy(1.0, &part, &mut acc);
            cnt += c;
        }
        if cnt > 0.0 {
            linalg::scale(1.0 / cnt as f32, &mut acc);
        }
        ctx.meter.machine(i).add_vec_ops(batch.n as u64);
        locals.push(acc);
        weights.push(cnt);
    }
    ctx.net.all_reduce_weighted(&mut ctx.meter, &weights, &mut locals);
    let mut out = locals.pop().unwrap();
    linalg::axpy(gamma as f32, v, &mut out);
    // local axpy: O(1) vector ops per machine
    ctx.meter.all_vec_ops(1);
    Ok(out)
}

impl ProxSolver for ExactCgSolver {
    fn name(&self) -> String {
        "exact-cg".to_string()
    }

    /// CG only needs grad + normal-matvec dispatches — no VR sweeps.
    fn needs_vr_blocks(&self) -> bool {
        false
    }

    fn solve(
        &mut self,
        ctx: &mut RunContext,
        batches: &[MachineBatch],
        wprev: &[f32],
        gamma: f64,
        _t: usize,
    ) -> Result<Vec<f32>> {
        if ctx.loss != Loss::Squared {
            bail!("exact-cg prox solver requires the squared loss");
        }
        let d = ctx.d;
        // rhs = (1/n) X^T y + gamma wprev = -grad(0) + gamma wprev
        let zero = vec![0.0f32; d];
        let (g0, _, _) = distributed_mean_grad(
            ctx.engine,
            ctx.loss,
            batches,
            &zero,
            &mut ctx.net,
            &mut ctx.meter,
        )?;
        let mut b = vec![0.0f32; d];
        for j in 0..d {
            b[j] = -g0[j] + (gamma as f32) * wprev[j];
        }

        // CG with the distributed operator (warm start from wprev)
        let mut x = wprev.to_vec();
        let mut ap = distributed_normal_matvec(ctx, batches, &x, gamma)?;
        let mut r: Vec<f32> = (0..d).map(|j| b[j] - ap[j]).collect();
        let mut p = r.clone();
        let b_norm = linalg::nrm2(&b).max(1e-30);
        let mut rs_old = linalg::dot(&r, &r);
        for _ in 0..self.max_iters {
            if rs_old.sqrt() / b_norm <= self.tol {
                break;
            }
            ap = distributed_normal_matvec(ctx, batches, &p, gamma)?;
            let p_ap = linalg::dot(&p, &ap);
            if p_ap <= 0.0 {
                break;
            }
            let alpha = (rs_old / p_ap) as f32;
            linalg::axpy(alpha, &p, &mut x);
            linalg::axpy(-alpha, &ap, &mut r);
            let rs_new = linalg::dot(&r, &r);
            let beta = (rs_new / rs_old) as f32;
            for j in 0..d {
                p[j] = r[j] + beta * p[j];
            }
            ctx.meter.all_vec_ops(3);
            rs_old = rs_new;
        }
        Ok(x)
    }
}
