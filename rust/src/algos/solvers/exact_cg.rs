//! Exact prox solver for least squares via distributed conjugate gradient.
//!
//! The prox subproblem for the squared loss has a linear optimality system
//!
//! ```text
//!     ((1/n) X^T X + gamma I) w = (1/n) X^T y + gamma w_prev
//! ```
//!
//! whose matvec is the `nm_sq_*` artifact. Each CG iteration applies the
//! operator distributedly (every machine processes its own blocks) and
//! all-reduces the partial results — one communication round per CG
//! iteration. This is the "exact minibatch-prox" reference (Theorem 4/5)
//! that the inexact solvers are validated against, and doubles as the
//! DiSCO-style Newton system solver for the ERM baselines.
//!
//! # Device-resident steady state
//!
//! With the chained artifacts present, the CG vectors (`x`, `r`, `p`,
//! `Ap`, `b`) live on device: the matvec chains `nacc{K}` accumulators
//! into the DeviceCollective reduce, the recurrences are `vaxpby`
//! dispatches, and the only steady-state downlink is the two `vdot`
//! scalars per iteration (8 bytes) — against 2 full vectors per machine
//! per iteration on the legacy path. The solution materializes once at
//! the end. `force_legacy` pins the host path for parity tests.

use super::ProxSolver;
use crate::algos::RunContext;
use crate::data::Loss;
use crate::linalg;
use crate::objective::{
    distributed_mean_grad, distributed_mean_grad_dev, fan_machines, MachineBatch,
};
use crate::runtime::DeviceVec;
use anyhow::{bail, Result};
use std::sync::Arc;

pub struct ExactCgSolver {
    pub tol: f64,
    pub max_iters: usize,
    /// pin the legacy host path (parity tests / diagnostics)
    pub force_legacy: bool,
}

impl Default for ExactCgSolver {
    fn default() -> Self {
        Self { tol: 1e-9, max_iters: 512, force_legacy: false }
    }
}

/// One distributed application of v -> (1/n) X^T X v + gamma v.
/// Charges one comm round and per-machine vec ops; returns the result.
/// The per-machine partials fan across the shard plane when one owns the
/// batches; the combine runs in fixed machine order on the coordinator
/// either way.
pub fn distributed_normal_matvec(
    ctx: &mut RunContext,
    batches: &[MachineBatch],
    v: &[f32],
    gamma: f64,
) -> Result<Vec<f32>> {
    let d = ctx.d;
    let v_s: Arc<[f32]> = Arc::from(v);
    let outs: Vec<(Vec<f32>, f64)> = fan_machines(
        ctx.engine,
        ctx.shards,
        batches,
        &mut ctx.meter,
        move |eng, batch, _i, m| {
            let mut acc = vec![0.0f32; d];
            let mut cnt = 0.0f64;
            // fused groups: one dispatch + one download per group, and
            // `v` is uploaded once per matvec via the session pool
            for blk in &batch.groups {
                let (part, c) = eng.nm_block(blk, &v_s)?;
                linalg::axpy(1.0, &part, &mut acc);
                cnt += c;
            }
            if cnt > 0.0 {
                linalg::scale(1.0 / cnt as f32, &mut acc);
            }
            m.add_vec_ops(batch.n as u64);
            Ok((acc, cnt))
        },
    )?;
    let (mut locals, weights): (Vec<Vec<f32>>, Vec<f64>) = outs.into_iter().unzip();
    ctx.net.all_reduce_weighted(&mut ctx.meter, &weights, &mut locals);
    let mut out = locals.pop().unwrap();
    linalg::axpy(gamma as f32, v, &mut out);
    // local axpy: O(1) vector ops per machine
    ctx.meter.all_vec_ops(1);
    Ok(out)
}

/// Device-chained [`distributed_normal_matvec`]: `nacc{K}` accumulator
/// chains per machine, DeviceCollective reduce, one `vaxpby` for the
/// `gamma v` shift. Identical rounds/vec-ops accounting, zero downloads.
pub fn distributed_normal_matvec_dev(
    ctx: &mut RunContext,
    batches: &[MachineBatch],
    v: &DeviceVec,
    gamma: f64,
) -> Result<DeviceVec> {
    if batches.iter().any(|b| b.shard.is_some()) {
        // shard plane: the direction crosses to the shards as host bits
        // (exact), each machine chains its nacc accumulator on its own
        // engine, and the combine is the host collective — bit-identical
        // to the device reduce. The CG recurrence itself stays on the
        // coordinator engine, so the iterates match the single-engine
        // chained path bit-for-bit.
        let d = ctx.d;
        let v_host = ctx.engine.materialize(v)?;
        let v_s: Arc<[f32]> = Arc::from(&v_host[..]);
        let outs: Vec<Vec<f32>> = fan_machines(
            ctx.engine,
            ctx.shards,
            batches,
            &mut ctx.meter,
            move |eng, batch, _i, m| {
                let v_dev = eng.upload_dev(&v_s, &[d])?;
                let mut acc = eng.zeros_dev(d)?;
                for blk in &batch.groups {
                    acc = eng.nm_acc(blk, &v_dev, &acc)?;
                }
                let cnt = batch.n as f64;
                if cnt > 0.0 {
                    acc = eng.vec_scale(&acc, (1.0 / cnt) as f32)?;
                }
                m.add_vec_ops(batch.n as u64);
                eng.materialize(&acc)
            },
        )?;
        let weights: Vec<f64> = batches.iter().map(|b| b.n as f64).collect();
        let mut locals = outs;
        ctx.net.all_reduce_weighted(&mut ctx.meter, &weights, &mut locals);
        let red = ctx.engine.upload_dev(&locals.pop().unwrap(), &[d])?;
        let out = ctx.engine.vec_axpby(1.0, &red, gamma as f32, v)?;
        ctx.meter.all_vec_ops(1);
        return Ok(out);
    }
    let m = batches.len();
    let mut locals: Vec<DeviceVec> = Vec::with_capacity(m);
    let mut weights: Vec<f64> = Vec::with_capacity(m);
    for (i, batch) in batches.iter().enumerate() {
        let mut acc = ctx.engine.zeros_dev(ctx.d)?;
        for blk in &batch.groups {
            acc = ctx.engine.nm_acc(blk, v, &acc)?;
        }
        // pack-time count replaces the downloaded one (same value)
        let cnt = batch.n as f64;
        if cnt > 0.0 {
            acc = ctx.engine.vec_scale(&acc, (1.0 / cnt) as f32)?;
        }
        ctx.meter.machine(i).add_vec_ops(batch.n as u64);
        locals.push(acc);
        weights.push(cnt);
    }
    let red = ctx.net.device_all_reduce_weighted(
        &mut ctx.meter,
        ctx.engine,
        &weights,
        &locals,
    )?;
    let out = ctx.engine.vec_axpby(1.0, &red, gamma as f32, v)?;
    ctx.meter.all_vec_ops(1);
    Ok(out)
}

/// Shared distributed-CG driver, host plane: solve `A x = b` from warm
/// start `x0`, where `matvec` applies `A` (charging its own comm round
/// and vec ops). Stopping rules: relative residual below `tol` against
/// the rhs norm, or a non-positive curvature `p^T A p`. One
/// implementation serves the exact-prox system AND the DiSCO Newton
/// system — the recurrence cannot drift between them.
pub fn host_cg(
    ctx: &mut RunContext,
    mut matvec: impl FnMut(&mut RunContext, &[f32]) -> Result<Vec<f32>>,
    b: &[f32],
    x0: Vec<f32>,
    tol: f64,
    max_iters: usize,
) -> Result<Vec<f32>> {
    let d = b.len();
    let mut x = x0;
    let mut ap = matvec(ctx, &x)?;
    let mut r: Vec<f32> = (0..d).map(|j| b[j] - ap[j]).collect();
    let mut p = r.clone();
    let rhs_norm = linalg::nrm2(b).max(1e-30);
    let mut rs_old = linalg::dot(&r, &r);
    for _ in 0..max_iters {
        if rs_old.sqrt() / rhs_norm <= tol {
            break;
        }
        ap = matvec(ctx, &p)?;
        let p_ap = linalg::dot(&p, &ap);
        if p_ap <= 0.0 {
            break;
        }
        let alpha = (rs_old / p_ap) as f32;
        linalg::axpy(alpha, &p, &mut x);
        linalg::axpy(-alpha, &ap, &mut r);
        let rs_new = linalg::dot(&r, &r);
        let beta = (rs_new / rs_old) as f32;
        for j in 0..d {
            p[j] = r[j] + beta * p[j];
        }
        ctx.meter.all_vec_ops(3);
        rs_old = rs_new;
    }
    Ok(x)
}

/// [`host_cg`] on the device plane: the identical recurrence
/// scalar-for-scalar, with the vectors as [`DeviceVec`] handles and the
/// two `vec_dot` scalars per iteration as the only downlink.
pub fn chained_cg(
    ctx: &mut RunContext,
    mut matvec: impl FnMut(&mut RunContext, &DeviceVec) -> Result<DeviceVec>,
    b: &DeviceVec,
    x0: DeviceVec,
    tol: f64,
    max_iters: usize,
) -> Result<DeviceVec> {
    let mut x = x0;
    let mut ap = matvec(ctx, &x)?;
    let mut r = ctx.engine.vec_axpby(1.0, b, -1.0, &ap)?;
    let mut p = r.clone();
    let rhs_norm = ctx.engine.vec_dot(b, b)?.sqrt().max(1e-30);
    let mut rs_old = ctx.engine.vec_dot(&r, &r)?;
    for _ in 0..max_iters {
        if rs_old.sqrt() / rhs_norm <= tol {
            break;
        }
        ap = matvec(ctx, &p)?;
        let p_ap = ctx.engine.vec_dot(&p, &ap)?;
        if p_ap <= 0.0 {
            break;
        }
        let alpha = (rs_old / p_ap) as f32;
        x = ctx.engine.vec_axpby(1.0, &x, alpha, &p)?;
        r = ctx.engine.vec_axpby(1.0, &r, -alpha, &ap)?;
        let rs_new = ctx.engine.vec_dot(&r, &r)?;
        let beta = (rs_new / rs_old) as f32;
        p = ctx.engine.vec_axpby(1.0, &r, beta, &p)?;
        ctx.meter.all_vec_ops(3);
        rs_old = rs_new;
    }
    Ok(x)
}

impl ExactCgSolver {
    fn chain_ready(&self, ctx: &RunContext, m: usize) -> bool {
        !self.force_legacy
            && ctx.engine.chain_grad_ready(ctx.loss.tag(), ctx.d)
            && ctx.engine.chain_nm_ready(ctx.d)
            && ctx.engine.red_ready(m, ctx.d)
    }

    fn solve_legacy(
        &mut self,
        ctx: &mut RunContext,
        batches: &[MachineBatch],
        wprev: &[f32],
        gamma: f64,
    ) -> Result<Vec<f32>> {
        let d = ctx.d;
        // rhs = (1/n) X^T y + gamma wprev = -grad(0) + gamma wprev
        let zero = vec![0.0f32; d];
        let (g0, _, _) = distributed_mean_grad(
            ctx.engine,
            ctx.shards,
            ctx.loss,
            batches,
            &zero,
            &mut ctx.net,
            &mut ctx.meter,
        )?;
        let mut b = vec![0.0f32; d];
        for j in 0..d {
            b[j] = -g0[j] + (gamma as f32) * wprev[j];
        }
        // CG with the distributed operator (warm start from wprev)
        host_cg(
            ctx,
            |ctx, v| distributed_normal_matvec(ctx, batches, v, gamma),
            &b,
            wprev.to_vec(),
            self.tol,
            self.max_iters,
        )
    }

    /// Chained CG: same recurrence scalar-for-scalar, vectors on device.
    fn solve_chained(
        &mut self,
        ctx: &mut RunContext,
        batches: &[MachineBatch],
        wprev: &[f32],
        gamma: f64,
    ) -> Result<Vec<f32>> {
        let zero = ctx.engine.zeros_dev(ctx.d)?;
        let g0 = distributed_mean_grad_dev(
            ctx.engine,
            ctx.shards,
            ctx.loss,
            batches,
            &zero,
            &mut ctx.net,
            &mut ctx.meter,
        )?;
        let wprev_dev = ctx.engine.upload_dev(wprev, &[ctx.d])?;
        // b = -g0 + gamma wprev
        let b = ctx.engine.vec_axpby(-1.0, &g0, gamma as f32, &wprev_dev)?;
        let x = chained_cg(
            ctx,
            |ctx, v| distributed_normal_matvec_dev(ctx, batches, v, gamma),
            &b,
            wprev_dev.clone(),
            self.tol,
            self.max_iters,
        )?;
        // the round boundary: the one full-vector download of this solve
        ctx.engine.materialize(&x)
    }
}

impl ProxSolver for ExactCgSolver {
    fn name(&self) -> String {
        "exact-cg".to_string()
    }

    /// CG only needs grad + normal-matvec dispatches — no VR sweeps.
    fn needs_vr_blocks(&self, _ctx: &RunContext) -> bool {
        false
    }

    fn solve(
        &mut self,
        ctx: &mut RunContext,
        batches: &[MachineBatch],
        wprev: &[f32],
        gamma: f64,
        _t: usize,
    ) -> Result<Vec<f32>> {
        if ctx.loss != Loss::Squared {
            bail!("exact-cg prox solver requires the squared loss");
        }
        if self.chain_ready(ctx, batches.len()) {
            self.solve_chained(ctx, batches, wprev, gamma)
        } else {
            self.solve_legacy(ctx, batches, wprev, gamma)
        }
    }
}
