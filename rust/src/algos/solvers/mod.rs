//! Inner solvers for the minibatch-prox subproblem (equation 12):
//!
//! ```text
//!     min_w  f_t(w) = phi_{I_t}(w) + gamma/2 ||w - w_prev||^2
//! ```
//!
//! where `I_t` is the union of per-machine minibatches. Theorem 7/8 only
//! require an inexact solution with error eta_t decaying polynomially in t,
//! which is what makes the communication-efficient inner loops (DSVRG,
//! DANE) sufficient.
//!
//! Every solver has exactly ONE body, programmed against the execution
//! plane's verbs (`runtime::plane`): the solver resolves a [`Lane`] per
//! solve and the plane supplies lane-correct mean gradients, sweeps,
//! collectives and materialization points. Which plane runs underneath —
//! host, chained, or sharded — is coordinator policy, never solver code.

pub mod dane;
pub mod dsvrg;
pub mod exact_cg;
pub mod oneshot;

use super::{PackMode, RunContext};
use anyhow::Result;

// The sweep machinery lives on the plane (`runtime::plane`); re-exported
// here because it is the solvers' vocabulary (and the parity tests').
pub use crate::runtime::plane::{
    batch_ranges, sweep_groups_weight, vr_sweep_avg_dev, vr_sweep_groups, vr_sweep_machine,
    vr_sweep_machine_grouped, Lane, LocalSolver, VrSweeper,
};

/// Approximately solve the prox subproblem on the current minibatches.
pub trait ProxSolver {
    fn name(&self) -> String;

    /// How the outer loop should pack this solver's fresh minibatches on
    /// `ctx`'s plane: grad-only for dispatch-verb solvers (CG), VR-aligned
    /// fused groups for chained sweeps, full packs (host blocks retained
    /// for the lazy per-block uploads) for Host-lane sweeps.
    fn pack_mode(&self, _ctx: &RunContext) -> PackMode {
        PackMode::Full
    }

    /// Return an (inexact) minimizer of `f_t`; `t` is the outer iteration
    /// (solvers may tighten accuracy with t per Theorem 7).
    fn solve(
        &mut self,
        ctx: &mut RunContext,
        batches: &[crate::objective::MachineBatch],
        wprev: &[f32],
        gamma: f64,
        t: usize,
    ) -> Result<Vec<f32>>;
}
