//! Inner solvers for the minibatch-prox subproblem (equation 12):
//!
//! ```text
//!     min_w  f_t(w) = phi_{I_t}(w) + gamma/2 ||w - w_prev||^2
//! ```
//!
//! where `I_t` is the union of per-machine minibatches. Theorem 7/8 only
//! require an inexact solution with error eta_t decaying polynomially in t,
//! which is what makes the communication-efficient inner loops (DSVRG,
//! DANE) sufficient.

pub mod dane;
pub mod dsvrg;
pub mod exact_cg;
pub mod oneshot;

use super::RunContext;
use crate::accounting::ResourceMeter;
use crate::data::Loss;
use crate::objective::{fan_machine, MachineBatch};
use crate::runtime::chain::VrKernel;
use crate::runtime::{DeviceVec, Engine};
use anyhow::Result;

/// Which variance-reduced kernel performs the local sweeps.
///
/// The paper's Appendix E uses SAGA for the local DANE subproblems; SVRG
/// is the Algorithm-1 (DSVRG) choice. Both are single AOT Pallas kernels
/// with identical interfaces (see python/compile/kernels/).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LocalSolver {
    Svrg,
    Saga,
}

impl LocalSolver {
    pub fn tag(self) -> &'static str {
        match self {
            LocalSolver::Svrg => "svrg",
            LocalSolver::Saga => "saga",
        }
    }

    /// The chained kernel family implementing this solver's sweeps.
    pub fn kernel(self) -> VrKernel {
        match self {
            LocalSolver::Svrg => VrKernel::Svrg,
            LocalSolver::Saga => VrKernel::Saga,
        }
    }
}

/// Approximately solve the prox subproblem on the current minibatches.
pub trait ProxSolver {
    fn name(&self) -> String;

    /// Whether `solve` runs *legacy per-block* VR sweeps over the batches
    /// (which need the host block copies retained for the lazy per-block
    /// uploads). Grad/CG-only solvers — and solvers whose sweeps ride the
    /// chained group-aligned path on this engine — return false so the
    /// outer loop can pack grad-only batches and skip the host retention.
    fn needs_vr_blocks(&self, _ctx: &RunContext) -> bool {
        true
    }

    /// `Some(p)` when the solver's chained sweeps want fused groups
    /// aligned to its p-way batch partition: the outer loop then draws
    /// via `RunContext::draw_batches_vr_aligned`, so
    /// `MachineBatch::group_ranges(p)` tiles exactly the block partition
    /// the legacy sweep would use. `None` keeps the default (widest)
    /// packing.
    fn vr_group_align(&self, _ctx: &RunContext) -> Option<usize> {
        None
    }

    /// Return an (inexact) minimizer of `f_t`; `t` is the outer iteration
    /// (solvers may tighten accuracy with t per Theorem 7).
    fn solve(
        &mut self,
        ctx: &mut RunContext,
        batches: &[MachineBatch],
        wprev: &[f32],
        gamma: f64,
        t: usize,
    ) -> Result<Vec<f32>>;
}

/// Shared helper: sweep one machine's blocks with chained
/// variance-reduced passes (SVRG or SAGA kernels).
///
/// Runs the artifact block-by-block, carrying the iterate through, and
/// combines per-block running averages weighted by their (1 + valid)
/// counts — the paper's z_k average over r = 0..|B_s|.
/// Returns `(x_end, x_avg)` and charges the swept rows to `meter`.
///
/// Takes the engine and the machine's meter directly (not a
/// [`RunContext`]) so the identical code runs inline on the coordinator
/// OR inside a shard job — the shard plane's per-machine closures are
/// exactly these helpers.
#[allow(clippy::too_many_arguments)]
pub fn vr_sweep_machine(
    engine: &mut Engine,
    loss: Loss,
    solver: LocalSolver,
    batch_blocks: std::ops::Range<usize>,
    batch: &MachineBatch,
    x0: &[f32],
    z: &[f32],
    mu: &[f32],
    center: &[f32],
    gamma: f32,
    eta: f32,
    meter: &mut ResourceMeter,
) -> Result<(Vec<f32>, Vec<f32>)> {
    let mut x = x0.to_vec();
    let mut avg = crate::linalg::WeightedAvg::new(batch.d);
    let mut total_n = 0u64;
    // per-block buffers, materialized on the batch's first sweep
    let lits = batch.vr_lits(engine)?;
    for bi in batch_blocks {
        let blk = &lits[bi];
        if blk.valid == 0 {
            continue;
        }
        let (x_end, x_avg) = match solver {
            LocalSolver::Svrg => engine.svrg_block(loss, blk, &x, z, mu, center, gamma, eta)?,
            LocalSolver::Saga => engine.saga_block(loss, blk, &x, z, mu, center, gamma, eta)?,
        };
        avg.add((1 + blk.valid) as f64, &x_avg);
        total_n += blk.valid as u64;
        x = x_end;
    }
    drop(lits);
    meter.add_vec_ops(total_n);
    let x_avg = if avg.total_weight() > 0.0 { avg.mean() } else { x.clone() };
    Ok((x, x_avg))
}

/// [`vr_sweep_machine`] on whichever plane owns machine `j`'s batch: the
/// designated-machine sweep of DSVRG/DSVRG-ERM and the per-machine local
/// solves fan through this to the owning shard (or run inline when the
/// batches are local).
#[allow(clippy::too_many_arguments)]
pub fn vr_sweep_on(
    ctx: &mut RunContext,
    solver: LocalSolver,
    batch_blocks: std::ops::Range<usize>,
    batches: &[MachineBatch],
    j: usize,
    x0: &[f32],
    z: &[f32],
    mu: &[f32],
    center: &[f32],
    gamma: f32,
    eta: f32,
) -> Result<(Vec<f32>, Vec<f32>)> {
    let loss = ctx.loss;
    if batches[j].shard.is_none() {
        // sequential plane: run inline on the borrowed slices (no copies)
        return vr_sweep_machine(
            ctx.engine,
            loss,
            solver,
            batch_blocks,
            &batches[j],
            x0,
            z,
            mu,
            center,
            gamma,
            eta,
            ctx.meter.machine(j),
        );
    }
    // shard plane: the job closure must own its operands
    let (x0, z, mu, center) = (x0.to_vec(), z.to_vec(), mu.to_vec(), center.to_vec());
    fan_machine(
        ctx.engine,
        ctx.shards,
        batches,
        j,
        &mut ctx.meter,
        move |eng, batch, _i, m| {
            vr_sweep_machine(
                eng,
                loss,
                solver,
                batch_blocks,
                batch,
                &x0,
                &z,
                &mu,
                &center,
                gamma,
                eta,
                m,
            )
        },
    )
}

/// Chained core of the group-aligned VR sweep: advance the `[2, d]` state
/// through `batch.groups[group_range]` riding the *fused* block uploads —
/// no `vr_lits` materialization, no downloads, no host round-trips
/// between groups. Returns the advanced state; divide by
/// [`sweep_groups_weight`] (via `Engine::vr_avg`) for the sweep average.
/// Charges the swept valid rows to `meter`, like the legacy path.
#[allow(clippy::too_many_arguments)]
pub fn vr_sweep_groups(
    engine: &mut Engine,
    loss: Loss,
    solver: LocalSolver,
    group_range: std::ops::Range<usize>,
    batch: &MachineBatch,
    state: DeviceVec,
    z: &DeviceVec,
    mu: &DeviceVec,
    center: &DeviceVec,
    gamma: &DeviceVec,
    eta: &DeviceVec,
    meter: &mut ResourceMeter,
) -> Result<DeviceVec> {
    let mut s = state;
    let mut total_n = 0u64;
    for gi in group_range {
        let blk = &batch.groups[gi];
        if blk.valid == 0 {
            continue;
        }
        s = engine.vr_chain(solver.kernel(), loss, blk, &s, z, mu, center, gamma, eta)?;
        total_n += blk.valid as u64;
    }
    meter.add_vec_ops(total_n);
    Ok(s)
}

/// Total sweep-average weight of `batch.groups[group_range]`: the
/// host-side divisor for the chained accumulator (`1 + valid` per
/// non-empty block, matching the legacy combiner). Stub-safe — the
/// weights ride the batch metadata, so the coordinator can compute the
/// divisor for a shard-resident batch.
pub fn sweep_groups_weight(batch: &MachineBatch, group_range: std::ops::Range<usize>) -> f64 {
    group_range.map(|gi| batch.group_sweep_weight(gi)).sum()
}

/// Host-level wrapper over the chained sweep: uploads the state and the
/// sweep-constant vectors, chains through the groups, and materializes
/// `(x_end, x_avg)` — one `[2, d]` download per *sweep* instead of two
/// `[d]` downloads per *block*. Semantics match [`vr_sweep_machine`] over
/// the same blocks (the parity tests pin this down), and the host average
/// (one f32 multiply per element) is bit-identical to the `vr_avg`
/// kernel's, so a shard job running this reproduces the single-engine
/// chained path exactly.
#[allow(clippy::too_many_arguments)]
pub fn vr_sweep_machine_grouped(
    engine: &mut Engine,
    loss: Loss,
    solver: LocalSolver,
    group_range: std::ops::Range<usize>,
    batch: &MachineBatch,
    x0: &[f32],
    z: &[f32],
    mu: &[f32],
    center: &[f32],
    gamma: f32,
    eta: f32,
    meter: &mut ResourceMeter,
) -> Result<(Vec<f32>, Vec<f32>)> {
    let d = batch.d;
    let state = engine.vr_state_from(x0)?;
    let z_dev = engine.upload_dev(z, &[d])?;
    let mu_dev = engine.upload_dev(mu, &[d])?;
    let c_dev = engine.upload_dev(center, &[d])?;
    // sweep-constant scalars: uploaded once per sweep, not per group
    let gamma_dev = engine.scalar_dev(gamma)?;
    let eta_dev = engine.scalar_dev(eta)?;
    let total_w = sweep_groups_weight(batch, group_range.clone());
    let s = vr_sweep_groups(
        engine,
        loss,
        solver,
        group_range,
        batch,
        state,
        &z_dev,
        &mu_dev,
        &c_dev,
        &gamma_dev,
        &eta_dev,
        meter,
    )?;
    let host = engine.materialize(&s)?;
    let (x_end, acc) = host.split_at(d);
    let x_avg = if total_w > 0.0 {
        let inv = (1.0 / total_w) as f32;
        acc.iter().map(|&a| a * inv).collect()
    } else {
        x_end.to_vec()
    };
    Ok((x_end.to_vec(), x_avg))
}

/// One chained sweep-plus-average, fully on device: seed the `[2, d]`
/// state from the host iterate `x0`, advance it through
/// `batch.groups[group_range]`, and return the sweep average as a handle
/// (`vr_avg`, with the empty-sweep fallback to the carried iterate). The
/// ONE implementation of the parity-sensitive sweep-average sequence —
/// chained DANE and one-shot local solves both run exactly this, so the
/// cross-plane bitwise contract cannot drift between them.
#[allow(clippy::too_many_arguments)]
pub fn vr_sweep_avg_dev(
    engine: &mut Engine,
    loss: Loss,
    solver: LocalSolver,
    group_range: std::ops::Range<usize>,
    batch: &MachineBatch,
    x0: &[f32],
    z: &DeviceVec,
    mu: &DeviceVec,
    center: &DeviceVec,
    gamma: &DeviceVec,
    eta: &DeviceVec,
    meter: &mut ResourceMeter,
) -> Result<DeviceVec> {
    let state = engine.vr_state_from(x0)?;
    let total_w = sweep_groups_weight(batch, group_range.clone());
    let state = vr_sweep_groups(
        engine,
        loss,
        solver,
        group_range,
        batch,
        state,
        z,
        mu,
        center,
        gamma,
        eta,
        meter,
    )?;
    let inv_w = if total_w > 0.0 { (1.0 / total_w) as f32 } else { 0.0 };
    engine.vr_avg(&state, inv_w)
}

/// [`vr_sweep_machine_grouped`] on whichever plane owns machine `j`'s
/// batch — the chained designated-machine sweep as a shard fan-out.
#[allow(clippy::too_many_arguments)]
pub fn vr_sweep_grouped_on(
    ctx: &mut RunContext,
    solver: LocalSolver,
    group_range: std::ops::Range<usize>,
    batches: &[MachineBatch],
    j: usize,
    x0: &[f32],
    z: &[f32],
    mu: &[f32],
    center: &[f32],
    gamma: f32,
    eta: f32,
) -> Result<(Vec<f32>, Vec<f32>)> {
    let loss = ctx.loss;
    if batches[j].shard.is_none() {
        // sequential plane: run inline on the borrowed slices (no copies)
        return vr_sweep_machine_grouped(
            ctx.engine,
            loss,
            solver,
            group_range,
            &batches[j],
            x0,
            z,
            mu,
            center,
            gamma,
            eta,
            ctx.meter.machine(j),
        );
    }
    // shard plane: the job closure must own its operands
    let (x0, z, mu, center) = (x0.to_vec(), z.to_vec(), mu.to_vec(), center.to_vec());
    fan_machine(
        ctx.engine,
        ctx.shards,
        batches,
        j,
        &mut ctx.meter,
        move |eng, batch, _i, m| {
            vr_sweep_machine_grouped(
                eng,
                loss,
                solver,
                group_range,
                batch,
                &x0,
                &z,
                &mu,
                &center,
                gamma,
                eta,
                m,
            )
        },
    )
}
