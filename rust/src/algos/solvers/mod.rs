//! Inner solvers for the minibatch-prox subproblem (equation 12):
//!
//! ```text
//!     min_w  f_t(w) = phi_{I_t}(w) + gamma/2 ||w - w_prev||^2
//! ```
//!
//! where `I_t` is the union of per-machine minibatches. Theorem 7/8 only
//! require an inexact solution with error eta_t decaying polynomially in t,
//! which is what makes the communication-efficient inner loops (DSVRG,
//! DANE) sufficient.

pub mod dane;
pub mod dsvrg;
pub mod exact_cg;
pub mod oneshot;

use super::RunContext;
use crate::objective::MachineBatch;
use anyhow::Result;

/// Which variance-reduced kernel performs the local sweeps.
///
/// The paper's Appendix E uses SAGA for the local DANE subproblems; SVRG
/// is the Algorithm-1 (DSVRG) choice. Both are single AOT Pallas kernels
/// with identical interfaces (see python/compile/kernels/).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LocalSolver {
    Svrg,
    Saga,
}

impl LocalSolver {
    pub fn tag(self) -> &'static str {
        match self {
            LocalSolver::Svrg => "svrg",
            LocalSolver::Saga => "saga",
        }
    }
}

/// Approximately solve the prox subproblem on the current minibatches.
pub trait ProxSolver {
    fn name(&self) -> String;

    /// Whether `solve` runs per-block VR sweeps over the batches (which
    /// need the host block copies retained for the lazy per-block
    /// uploads). Grad/CG-only solvers return false so the outer loop can
    /// pack grad-only batches and skip the host retention.
    fn needs_vr_blocks(&self) -> bool {
        true
    }

    /// Return an (inexact) minimizer of `f_t`; `t` is the outer iteration
    /// (solvers may tighten accuracy with t per Theorem 7).
    fn solve(
        &mut self,
        ctx: &mut RunContext,
        batches: &[MachineBatch],
        wprev: &[f32],
        gamma: f64,
        t: usize,
    ) -> Result<Vec<f32>>;
}

/// Shared helper: sweep one machine's blocks with chained
/// variance-reduced passes (SVRG or SAGA kernels).
///
/// Runs the artifact block-by-block, carrying the iterate through, and
/// combines per-block running averages weighted by their (1 + valid)
/// counts — the paper's z_k average over r = 0..|B_s|.
/// Returns `(x_end, x_avg)` and charges `n` vec ops to `machine_idx`.
#[allow(clippy::too_many_arguments)]
pub fn vr_sweep_machine(
    ctx: &mut RunContext,
    solver: LocalSolver,
    batch_blocks: std::ops::Range<usize>,
    batch: &MachineBatch,
    machine_idx: usize,
    x0: &[f32],
    z: &[f32],
    mu: &[f32],
    center: &[f32],
    gamma: f32,
    eta: f32,
) -> Result<(Vec<f32>, Vec<f32>)> {
    let mut x = x0.to_vec();
    let mut avg = crate::linalg::WeightedAvg::new(ctx.d);
    let mut total_n = 0u64;
    // per-block buffers, materialized on the batch's first sweep
    let lits = batch.vr_lits(ctx.engine)?;
    for bi in batch_blocks {
        let blk = &lits[bi];
        if blk.valid == 0 {
            continue;
        }
        let (x_end, x_avg) = match solver {
            LocalSolver::Svrg => {
                ctx.engine.svrg_block(ctx.loss, blk, &x, z, mu, center, gamma, eta)?
            }
            LocalSolver::Saga => {
                ctx.engine.saga_block(ctx.loss, blk, &x, z, mu, center, gamma, eta)?
            }
        };
        avg.add((1 + blk.valid) as f64, &x_avg);
        total_n += blk.valid as u64;
        x = x_end;
    }
    drop(lits);
    ctx.meter.machine(machine_idx).add_vec_ops(total_n);
    let x_avg = if avg.total_weight() > 0.0 { avg.mean() } else { x.clone() };
    Ok((x, x_avg))
}

/// Backwards-compatible SVRG-only wrapper (Algorithm 1 semantics).
#[allow(clippy::too_many_arguments)]
pub fn svrg_sweep_machine(
    ctx: &mut RunContext,
    batch_blocks: std::ops::Range<usize>,
    batch: &MachineBatch,
    machine_idx: usize,
    x0: &[f32],
    z: &[f32],
    mu: &[f32],
    center: &[f32],
    gamma: f32,
    eta: f32,
) -> Result<(Vec<f32>, Vec<f32>)> {
    vr_sweep_machine(
        ctx, LocalSolver::Svrg, batch_blocks, batch, machine_idx, x0, z, mu, center, gamma, eta,
    )
}
