//! DSVRG inner solver — Algorithm 1's inner loop.
//!
//! Each inner iteration k:
//!   1. one all-reduce round computes the global minibatch gradient
//!      `mu = grad phi_{I_t}(z_{k-1})`;
//!   2. the *designated* machine j sweeps its next local batch `B_s^{(j)}`
//!      once without replacement with variance-reduced updates (the
//!      `svrg_{loss}` Pallas artifact);
//!   3. the new iterate `z_k` (the sweep average) is broadcast — the
//!      second communication round.
//!
//! The (j, s) token rotates so each machine's minibatch is consumed batch
//! by batch, exactly as the paper's `s <- s+1; if s > p_j { s <- 1,
//! j <- j+1 }` bookkeeping.

use super::{svrg_sweep_machine, ProxSolver};
use crate::algos::RunContext;
use crate::objective::{distributed_mean_grad, MachineBatch};
use anyhow::Result;

pub struct DsvrgSolver {
    /// inner iterations K (theory: O(log n))
    pub k_inner: usize,
    /// batches per machine p (theory: b / condition-number)
    pub p_batches: usize,
    /// SVRG stepsize
    pub eta: f64,
}

impl DsvrgSolver {
    pub fn new(k_inner: usize, p_batches: usize, eta: f64) -> Self {
        Self { k_inner, p_batches, eta }
    }

    /// Split a machine's block list into p near-equal contiguous batches
    /// (batch granularity is whole 256-row blocks).
    fn batch_ranges(n_blocks: usize, p: usize) -> Vec<std::ops::Range<usize>> {
        let p = p.clamp(1, n_blocks.max(1));
        crate::data::sampler::shard_ranges(n_blocks, p)
    }
}

impl ProxSolver for DsvrgSolver {
    fn name(&self) -> String {
        format!("dsvrg(K={},p={})", self.k_inner, self.p_batches)
    }

    fn solve(
        &mut self,
        ctx: &mut RunContext,
        batches: &[MachineBatch],
        wprev: &[f32],
        gamma: f64,
        _t: usize,
    ) -> Result<Vec<f32>> {
        let m = batches.len();
        let mut z = wprev.to_vec();
        let mut x = wprev.to_vec();
        let mut j = 0usize; // designated machine
        let mut s = 0usize; // batch index within machine j
        let ranges: Vec<Vec<std::ops::Range<usize>>> = batches
            .iter()
            .map(|b| Self::batch_ranges(b.n_blocks(), self.p_batches))
            .collect();

        for _k in 0..self.k_inner {
            // (1) global minibatch gradient at snapshot z — 1 comm round
            let (mu, _, _) = distributed_mean_grad(
                ctx.engine,
                ctx.loss,
                batches,
                &z,
                &mut ctx.net,
                &mut ctx.meter,
            )?;
            // add the prox term's gradient? No: the svrg kernel adds
            // gamma (x - wprev) at the *current* iterate exactly, so mu is
            // the smooth-part gradient only — matching Algorithm 1 step 2.

            // (2) machine j sweeps its batch s once without replacement
            let range = ranges[j][s.min(ranges[j].len() - 1)].clone();
            let (x_end, x_avg) = svrg_sweep_machine(
                ctx,
                range,
                &batches[j],
                j,
                &x,
                &z,
                &mu,
                wprev,
                gamma as f32,
                self.eta as f32,
            )?;
            x = x_end;
            // (3) z_k = sweep average, broadcast to all machines — 1 round
            z = x_avg;
            let mut locals: Vec<Vec<f32>> = (0..m).map(|_| z.clone()).collect();
            ctx.net.broadcast(&mut ctx.meter, j, &mut locals);

            // advance the (j, s) token
            s += 1;
            if s >= ranges[j].len() {
                s = 0;
                j = (j + 1) % m;
            }
        }
        Ok(z)
    }
}
