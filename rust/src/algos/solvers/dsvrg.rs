//! DSVRG inner solver — Algorithm 1's inner loop.
//!
//! Each inner iteration k:
//!   1. one all-reduce round computes the global minibatch gradient
//!      `mu = grad phi_{I_t}(z_{k-1})`;
//!   2. the *designated* machine j sweeps its next local batch `B_s^{(j)}`
//!      once without replacement with variance-reduced updates;
//!   3. the new iterate `z_k` (the sweep average) is broadcast — the
//!      second communication round.
//!
//! The (j, s) token rotates so each machine's minibatch is consumed batch
//! by batch, exactly as the paper's `s <- s+1; if s > p_j { s <- 1,
//! j <- j+1 }` bookkeeping.
//!
//! # Device-resident steady state
//!
//! When the engine carries the chained artifacts, the whole inner loop
//! runs on [`DeviceVec`] handles: `mu` comes from the `gacc{K}`
//! accumulator chain + DeviceCollective reduce, the sweep advances a
//! `[2, d]` state through the *fused* block groups (`svrgc{K}` — batch
//! ranges are **group-aligned**, so sweeps ride the same uploads as the
//! gradient hot path and `vr_lits` never materializes), and the broadcast
//! is a charged handle clone. Bytes leave the device exactly once per
//! `solve`: the final iterate materialization at the round boundary.
//! Communication accounting is identical to the legacy path (2 rounds
//! per inner iteration); `force_legacy` pins the per-block host path for
//! parity tests and pre-chaining manifests.

use super::{
    sweep_groups_weight, vr_sweep_grouped_on, vr_sweep_groups, vr_sweep_on, LocalSolver,
    ProxSolver,
};
use crate::algos::RunContext;
use crate::objective::{
    distributed_mean_grad, distributed_mean_grad_dev, mean_grad_chained_host, MachineBatch,
};
use crate::runtime::DeviceVec;
use anyhow::Result;

pub struct DsvrgSolver {
    /// inner iterations K (theory: O(log n))
    pub k_inner: usize,
    /// batches per machine p (theory: b / condition-number)
    pub p_batches: usize,
    /// SVRG stepsize
    pub eta: f64,
    /// pin the legacy per-block host path (parity tests / diagnostics)
    pub force_legacy: bool,
}

impl DsvrgSolver {
    pub fn new(k_inner: usize, p_batches: usize, eta: f64) -> Self {
        Self { k_inner, p_batches, eta, force_legacy: false }
    }

    /// Split a machine's block list into p near-equal contiguous batches
    /// (batch granularity is whole 256-row blocks).
    fn batch_ranges(n_blocks: usize, p: usize) -> Vec<std::ops::Range<usize>> {
        let p = p.clamp(1, n_blocks.max(1));
        crate::data::sampler::shard_ranges(n_blocks, p)
    }

    /// Whether this solve can run device-resident on `ctx`'s engine. No
    /// `red_ready` requirement (consistent with DANE/one-shot): the
    /// DeviceCollective's host fallback for cluster sizes without a
    /// `redm{M}` artifact is bit-identical, so chaining stays worthwhile
    /// at any m.
    fn chain_ready(&self, ctx: &RunContext) -> bool {
        !self.force_legacy
            && ctx.engine.chain_grad_ready(ctx.loss.tag(), ctx.d)
            && ctx.engine.chain_vr_ready(ctx.loss.tag(), ctx.d)
    }

    /// Legacy per-block host path (the pre-chaining engine contract).
    fn solve_legacy(
        &mut self,
        ctx: &mut RunContext,
        batches: &[MachineBatch],
        wprev: &[f32],
        gamma: f64,
    ) -> Result<Vec<f32>> {
        let m = batches.len();
        let mut z = wprev.to_vec();
        let mut x = wprev.to_vec();
        let mut j = 0usize; // designated machine
        let mut s = 0usize; // batch index within machine j
        let ranges: Vec<Vec<std::ops::Range<usize>>> = batches
            .iter()
            .map(|b| Self::batch_ranges(b.n_blocks(), self.p_batches))
            .collect();

        for _k in 0..self.k_inner {
            // (1) global minibatch gradient at snapshot z — 1 comm round
            let (mu, _, _) = distributed_mean_grad(
                ctx.engine,
                ctx.shards,
                ctx.loss,
                batches,
                &z,
                &mut ctx.net,
                &mut ctx.meter,
            )?;
            // add the prox term's gradient? No: the svrg kernel adds
            // gamma (x - wprev) at the *current* iterate exactly, so mu is
            // the smooth-part gradient only — matching Algorithm 1 step 2.

            // (2) machine j sweeps its batch s once without replacement
            // (on j's shard when the batches are shard-resident)
            let range = ranges[j][s.min(ranges[j].len() - 1)].clone();
            let (x_end, x_avg) = vr_sweep_on(
                ctx,
                LocalSolver::Svrg,
                range,
                batches,
                j,
                &x,
                &z,
                &mu,
                wprev,
                gamma as f32,
                self.eta as f32,
            )?;
            x = x_end;
            // (3) z_k = sweep average, broadcast to all machines — 1 round
            z = x_avg;
            let mut locals: Vec<Vec<f32>> = (0..m).map(|_| z.clone()).collect();
            ctx.net.broadcast(&mut ctx.meter, j, &mut locals);

            // advance the (j, s) token
            s += 1;
            if s >= ranges[j].len() {
                s = 0;
                j = (j + 1) % m;
            }
        }
        Ok(z)
    }

    /// Chained device-resident path: identical algorithm and accounting,
    /// zero downloads until the final `materialize`.
    fn solve_chained(
        &mut self,
        ctx: &mut RunContext,
        batches: &[MachineBatch],
        wprev: &[f32],
        gamma: f64,
    ) -> Result<Vec<f32>> {
        let m = batches.len();
        let wprev_dev = ctx.engine.upload_dev(wprev, &[ctx.d])?;
        // solve-constant scalars: uploaded once, reused by every dispatch
        let gamma_dev = ctx.engine.scalar_dev(gamma as f32)?;
        let eta_dev = ctx.engine.scalar_dev(self.eta as f32)?;
        let mut z: DeviceVec = wprev_dev.clone();
        // [x; avg_accum] — x carries across inner iterations like the
        // legacy loop's `x = x_end`
        let mut state = ctx.engine.vr_state_from(wprev)?;
        let mut j = 0usize;
        let mut s = 0usize;
        // group ranges tiling the SAME p-way block partition as the
        // legacy path (exact when the batches were packed VR-aligned, the
        // mbprox outer loop's contract via vr_group_align)
        let ranges: Vec<Vec<std::ops::Range<usize>>> =
            batches.iter().map(|b| b.group_ranges(self.p_batches)).collect();

        for _k in 0..self.k_inner {
            // (1) global minibatch gradient at snapshot z — 1 comm round
            let mu = distributed_mean_grad_dev(
                ctx.engine,
                ctx.shards,
                ctx.loss,
                batches,
                &z,
                &mut ctx.net,
                &mut ctx.meter,
            )?;

            // (2) machine j sweeps its group-range s; fresh accumulator,
            // carried iterate
            state = ctx.engine.vr_reset(&state)?;
            let range = ranges[j][s.min(ranges[j].len() - 1)].clone();
            let total_w = sweep_groups_weight(&batches[j], range.clone());
            state = vr_sweep_groups(
                ctx.engine,
                ctx.loss,
                LocalSolver::Svrg,
                range,
                &batches[j],
                state,
                &z,
                &mu,
                &wprev_dev,
                &gamma_dev,
                &eta_dev,
                ctx.meter.machine(j),
            )?;

            // (3) z_k = sweep average (inv weight 0 = empty-sweep
            // fallback to the carried iterate), broadcast — 1 round
            let inv_w = if total_w > 0.0 { (1.0 / total_w) as f32 } else { 0.0 };
            let z_new = ctx.engine.vr_avg(&state, inv_w)?;
            z = ctx.net.device_broadcast(&mut ctx.meter, j, &z_new);

            s += 1;
            if s >= ranges[j].len() {
                s = 0;
                j = (j + 1) % m;
            }
        }
        // the round boundary: the ONE device->host transfer of this solve
        ctx.engine.materialize(&z)
    }

    /// Shard-plane chained solve: the identical kernel sequence per
    /// machine (gacc chains for mu, group-aligned svrgc sweeps on the
    /// designated machine, the same f32 sweep average), with cross-machine
    /// values crossing as host bits — f32 round trips are exact and the
    /// host collective is bit-identical to the device reduce, so this
    /// reproduces [`DsvrgSolver::solve_chained`] bit-for-bit while the
    /// per-machine work runs in parallel across shards. The per-iteration
    /// materialize/upload at the join points is the honest price of
    /// engines that share no device (metered on each shard).
    fn solve_sharded(
        &mut self,
        ctx: &mut RunContext,
        batches: &[MachineBatch],
        wprev: &[f32],
        gamma: f64,
    ) -> Result<Vec<f32>> {
        let m = batches.len();
        let mut z = wprev.to_vec();
        let mut x = wprev.to_vec();
        let mut j = 0usize;
        let mut s = 0usize;
        let ranges: Vec<Vec<std::ops::Range<usize>>> =
            batches.iter().map(|b| b.group_ranges(self.p_batches)).collect();

        for _k in 0..self.k_inner {
            // (1) chained mean gradient at snapshot z — 1 comm round
            let mu = mean_grad_chained_host(
                ctx.engine,
                ctx.shards,
                ctx.loss,
                batches,
                &z,
                &mut ctx.net,
                &mut ctx.meter,
            )?;

            // (2) machine j's chained sweep runs on machine j's shard
            let range = ranges[j][s.min(ranges[j].len() - 1)].clone();
            let (x_end, x_avg) = vr_sweep_grouped_on(
                ctx,
                LocalSolver::Svrg,
                range,
                batches,
                j,
                &x,
                &z,
                &mu,
                wprev,
                gamma as f32,
                self.eta as f32,
            )?;
            x = x_end;

            // (3) z_k broadcast — 1 round, charged exactly like the
            // device broadcast of the single-engine path
            z = x_avg;
            let mut locals: Vec<Vec<f32>> = (0..m).map(|_| z.clone()).collect();
            ctx.net.broadcast(&mut ctx.meter, j, &mut locals);

            s += 1;
            if s >= ranges[j].len() {
                s = 0;
                j = (j + 1) % m;
            }
        }
        Ok(z)
    }
}

impl ProxSolver for DsvrgSolver {
    fn name(&self) -> String {
        format!("dsvrg(K={},p={})", self.k_inner, self.p_batches)
    }

    /// Host block copies are only needed for the legacy per-block sweep;
    /// the chained path sweeps the fused device groups directly.
    fn needs_vr_blocks(&self, ctx: &RunContext) -> bool {
        !self.chain_ready(ctx)
    }

    /// Chained sweeps want groups aligned to the p-way batch partition,
    /// so the sweep sizes match the legacy path exactly for any p.
    fn vr_group_align(&self, ctx: &RunContext) -> Option<usize> {
        self.chain_ready(ctx).then_some(self.p_batches)
    }

    fn solve(
        &mut self,
        ctx: &mut RunContext,
        batches: &[MachineBatch],
        wprev: &[f32],
        gamma: f64,
        _t: usize,
    ) -> Result<Vec<f32>> {
        let sharded = batches.iter().any(|b| b.shard.is_some());
        if self.chain_ready(ctx) {
            if sharded {
                self.solve_sharded(ctx, batches, wprev, gamma)
            } else {
                self.solve_chained(ctx, batches, wprev, gamma)
            }
        } else {
            // the legacy path's primitives fan internally on either plane
            self.solve_legacy(ctx, batches, wprev, gamma)
        }
    }
}
