//! DSVRG inner solver — Algorithm 1's inner loop, written ONCE against
//! the execution plane.
//!
//! Each inner iteration k:
//!   1. one all-reduce round computes the global minibatch gradient
//!      `mu = grad phi_{I_t}(z_{k-1})`;
//!   2. the *designated* machine j sweeps its next local batch `B_s^{(j)}`
//!      once without replacement with variance-reduced updates;
//!   3. the new iterate `z_k` (the sweep average) is broadcast — the
//!      second communication round.
//!
//! The (j, s) token rotates so each machine's minibatch is consumed batch
//! by batch, exactly as the paper's `s <- s+1; if s > p_j { s <- 1,
//! j <- j+1 }` bookkeeping.
//!
//! The plane decides how each step executes. On the Dev lane the whole
//! loop runs on [`crate::runtime::DeviceVec`] handles — `mu` from the
//! `gacc{K}` chain + DeviceCollective, the sweep advancing a `[2, d]`
//! state over the *fused* group uploads (batch ranges are group-aligned,
//! so `vr_lits` never materializes), the broadcast a charged handle clone
//! — and bytes leave the device exactly once per solve, at the final
//! round-boundary materialize. On the Grouped lane (shard plane) the
//! identical kernels run per machine on the owning shard with host-bits
//! collectives, bit-identical to the Dev lane. On the Host lane the
//! legacy per-block kernels run (the pre-chaining contract / `plane=host`
//! policy). Communication accounting is identical on every lane: 2 rounds
//! per inner iteration.

use super::{Lane, LocalSolver, PackMode, ProxSolver};
use crate::algos::RunContext;
use crate::objective::MachineBatch;
use anyhow::Result;

pub struct DsvrgSolver {
    /// inner iterations K (theory: O(log n))
    pub k_inner: usize,
    /// batches per machine p (theory: b / condition-number)
    pub p_batches: usize,
    /// SVRG stepsize
    pub eta: f64,
}

impl DsvrgSolver {
    pub fn new(k_inner: usize, p_batches: usize, eta: f64) -> Self {
        Self { k_inner, p_batches, eta }
    }
}

impl ProxSolver for DsvrgSolver {
    fn name(&self) -> String {
        format!("dsvrg(K={},p={})", self.k_inner, self.p_batches)
    }

    /// Host blocks are only needed for Host-lane per-block sweeps; the
    /// chained lanes sweep fused groups aligned to the p-way batch
    /// partition, so sweep sizes match the per-block partition exactly
    /// for any p.
    fn pack_mode(&self, ctx: &RunContext) -> PackMode {
        match ctx.plane.vr_lane(ctx.loss, ctx.d) {
            Lane::Host => PackMode::Full,
            _ => PackMode::VrAligned(self.p_batches),
        }
    }

    fn solve(
        &mut self,
        ctx: &mut RunContext,
        batches: &[MachineBatch],
        wprev: &[f32],
        gamma: f64,
        _t: usize,
    ) -> Result<Vec<f32>> {
        let m = batches.len();
        let lane = ctx.plane.vr_lane(ctx.loss, ctx.d);
        // the sweep session owns the (j, s) partition, the solve-constant
        // operands and the carried iterate/state for this lane
        let mut sweeper = ctx.plane.vr_sweeper(
            lane,
            batches,
            self.p_batches,
            LocalSolver::Svrg,
            wprev,
            wprev,
            gamma as f32,
            self.eta as f32,
        )?;
        let mut z = ctx.plane.lift(lane, wprev)?;
        let mut j = 0usize; // designated machine
        let mut s = 0usize; // batch index within machine j

        for _k in 0..self.k_inner {
            // (1) global minibatch gradient at snapshot z — 1 comm round.
            // The prox term's gradient is NOT added here: the VR kernels
            // add gamma (x - wprev) at the *current* iterate exactly, so
            // mu is the smooth-part gradient only — Algorithm 1 step 2.
            let mu = ctx.mean_grad_pv(lane, batches, &z)?;

            // (2) machine j sweeps its batch s once without replacement
            // (on j's shard when the batches are shard-resident)
            let z_new = ctx.vr_sweep(&mut sweeper, batches, j, s, &z, &mu)?;

            // (3) z_k = sweep average, broadcast to all machines — 1 round
            z = ctx.broadcast_pv(j, z_new);

            // advance the (j, s) token
            s += 1;
            if s >= sweeper.n_batches(j) {
                s = 0;
                j = (j + 1) % m;
            }
        }
        // the round boundary: the Dev lane's ONE device->host transfer
        ctx.plane.into_host(z)
    }
}
