//! One-shot averaging (EMSO, Li et al. 2014 / Zhang et al. 2012).
//!
//! Each machine solves its *local* prox subproblem (equation 13) on its own
//! minibatch to high accuracy, then a single all-reduce averages the local
//! solutions. The paper uses this as the prior-work comparison point: it
//! works empirically but carries no convergence guarantee for (1) — our
//! benches show where it falls behind DSVRG/DANE inner solvers.
//!
//! Local solve: SVRG sweeps with local snapshots (works for both losses);
//! the re-snapshot between sweeps uses the machine's *local* gradient —
//! no communication until the final average, which is the method's point.

use super::{svrg_sweep_machine, ProxSolver};
use crate::algos::RunContext;
use crate::objective::{local_grad_sum, MachineBatch};
use anyhow::Result;

pub struct OneShotSolver {
    /// local SVRG sweeps (each re-snapshots on the local gradient)
    pub local_sweeps: usize,
    pub eta: f64,
}

impl OneShotSolver {
    pub fn new(local_sweeps: usize, eta: f64) -> Self {
        Self { local_sweeps, eta }
    }
}

impl ProxSolver for OneShotSolver {
    fn name(&self) -> String {
        format!("oneshot-emso(sweeps={})", self.local_sweeps)
    }

    fn solve(
        &mut self,
        ctx: &mut RunContext,
        batches: &[MachineBatch],
        wprev: &[f32],
        gamma: f64,
        _t: usize,
    ) -> Result<Vec<f32>> {
        let m = batches.len();
        let mut locals: Vec<Vec<f32>> = Vec::with_capacity(m);
        for (i, batch) in batches.iter().enumerate() {
            let mut xi = wprev.to_vec();
            for _sweep in 0..self.local_sweeps.max(1) {
                // local full gradient at the snapshot (charged locally)
                let gs = local_grad_sum(ctx.engine, ctx.loss, batch, &xi, ctx.meter.machine(i))?;
                let cnt = gs.count.max(1.0) as f32;
                let mu: Vec<f32> = gs.grad_sum.iter().map(|&g| g / cnt).collect();
                let snapshot = xi.clone();
                let blocks = 0..batch.n_blocks();
                let (_x_end, x_avg) = svrg_sweep_machine(
                    ctx,
                    blocks,
                    batch,
                    i,
                    &xi,
                    &snapshot,
                    &mu,
                    wprev,
                    gamma as f32,
                    self.eta as f32,
                )?;
                xi = x_avg;
            }
            locals.push(xi);
        }
        // the single communication round that gives the method its name
        ctx.net.all_reduce_avg(&mut ctx.meter, &mut locals);
        Ok(locals.pop().unwrap())
    }
}
