//! One-shot averaging (EMSO, Li et al. 2014 / Zhang et al. 2012),
//! written ONCE against the execution plane.
//!
//! Each machine solves its *local* prox subproblem (equation 13) on its own
//! minibatch to high accuracy, then a single all-reduce averages the local
//! solutions. The paper uses this as the prior-work comparison point: it
//! works empirically but carries no convergence guarantee for (1) — our
//! benches show where it falls behind DSVRG/DANE inner solvers.
//!
//! Local solve: SVRG sweeps with local snapshots (works for both losses);
//! the re-snapshot between sweeps uses the machine's *local* gradient —
//! no communication until the final average, which is the method's point.
//!
//! Lane notes: on the chained lanes each local solve runs on device — the
//! local snapshot gradient is the `gacc{K}` chain + one `vec_scale`, the
//! sweep advances a `[2, d]` state over the machine's fused groups, and
//! the per-machine downlink is one d-vector per extra sweep (the next
//! sweep's state seed). On the Dev lane the local solutions stay resident
//! and the single round is the DeviceCollective; on the Grouped lane each
//! machine solves on its own shard in parallel and the host collective
//! combines the materialized solutions — bit-identical either way.

use super::{vr_sweep_avg_dev, vr_sweep_machine, Lane, LocalSolver, PackMode, ProxSolver};
use crate::accounting::ResourceMeter;
use crate::algos::RunContext;
use crate::data::Loss;
use crate::objective::{fan_machines, local_grad_sum, local_grad_sum_dev, MachineBatch};
use crate::runtime::plane::PlaneLocals;
use crate::runtime::{DeviceVec, Engine};
use anyhow::Result;
use std::sync::Arc;

pub struct OneShotSolver {
    /// local SVRG sweeps (each re-snapshots on the local gradient)
    pub local_sweeps: usize,
    pub eta: f64,
}

impl OneShotSolver {
    pub fn new(local_sweeps: usize, eta: f64) -> Self {
        Self { local_sweeps, eta }
    }
}

/// One machine's chained local solve: `sweeps` SVRG passes over the fused
/// groups, each re-snapshotting on the machine's own chained gradient.
/// Returns the final sweep average as a device handle on `engine` — the
/// caller decides whether it crosses machines as a handle (Dev lane's
/// DeviceCollective) or as host bits (Grouped lane); the bits agree.
#[allow(clippy::too_many_arguments)]
fn chained_local_solve(
    engine: &mut Engine,
    loss: Loss,
    batch: &MachineBatch,
    wprev: &[f32],
    gamma: f32,
    eta: f32,
    sweeps: usize,
    meter: &mut ResourceMeter,
) -> Result<DeviceVec> {
    let d = batch.d;
    let wprev_dev = engine.upload_dev(wprev, &[d])?;
    let gamma_dev = engine.scalar_dev(gamma)?;
    let eta_dev = engine.scalar_dev(eta)?;
    let sweeps = sweeps.max(1);
    let mut xi = wprev.to_vec();
    let mut last: Option<DeviceVec> = None;
    for sweep in 0..sweeps {
        // local snapshot gradient at xi: gacc chain + one scale
        let xi_dev = engine.upload_dev(&xi, &[d])?;
        let gs = local_grad_sum_dev(engine, loss, batch, &xi_dev, meter)?;
        let cnt = batch.n as f64;
        let mu_dev = if cnt > 0.0 { engine.vec_scale(&gs, (1.0 / cnt) as f32)? } else { gs };
        // one group-aligned sweep from (and snapshotted at) xi
        let x_avg = vr_sweep_avg_dev(
            engine,
            loss,
            LocalSolver::Svrg,
            0..batch.n_groups(),
            batch,
            &xi,
            &xi_dev,
            &mu_dev,
            &wprev_dev,
            &gamma_dev,
            &eta_dev,
            meter,
        )?;
        if sweep + 1 < sweeps {
            // the next sweep's state seed — the per-sweep downlink
            xi = engine.materialize(&x_avg)?;
        }
        last = Some(x_avg);
    }
    Ok(last.expect("sweeps >= 1"))
}

impl ProxSolver for OneShotSolver {
    fn name(&self) -> String {
        format!("oneshot-emso(sweeps={})", self.local_sweeps)
    }

    /// Host blocks are only needed for Host-lane per-block sweeps.
    fn pack_mode(&self, ctx: &RunContext) -> PackMode {
        match ctx.plane.vr_lane(ctx.loss, ctx.d) {
            Lane::Host => PackMode::Full,
            _ => PackMode::GradOnly,
        }
    }

    fn solve(
        &mut self,
        ctx: &mut RunContext,
        batches: &[MachineBatch],
        wprev: &[f32],
        gamma: f64,
        _t: usize,
    ) -> Result<Vec<f32>> {
        let loss = ctx.loss;
        let sweeps = self.local_sweeps.max(1);
        let eta = self.eta as f32;
        let gamma32 = gamma as f32;
        let lane = ctx.plane.vr_lane(ctx.loss, ctx.d);
        let wprev_s: Arc<[f32]> = Arc::from(wprev);

        let locals = match lane {
            Lane::Dev => {
                // single-engine chained lane: local solutions stay
                // resident, the single round is the DeviceCollective
                let mut ls = Vec::with_capacity(batches.len());
                for (i, batch) in batches.iter().enumerate() {
                    ls.push(chained_local_solve(
                        ctx.plane.engine,
                        loss,
                        batch,
                        wprev,
                        gamma32,
                        eta,
                        sweeps,
                        ctx.meter.machine(i),
                    )?);
                }
                PlaneLocals::Dev(ls)
            }
            Lane::Grouped => {
                // shard plane: each machine solves on its own shard with
                // the same kernel sequence; solutions cross as host bits
                let wprev_s = Arc::clone(&wprev_s);
                PlaneLocals::Host(fan_machines(
                    ctx.plane.engine,
                    ctx.plane.shards,
                    batches,
                    &mut ctx.meter,
                    move |eng, batch, _i, meter| {
                        let v = chained_local_solve(
                            eng, loss, batch, &wprev_s, gamma32, eta, sweeps, meter,
                        )?;
                        eng.materialize(&v)
                    },
                )?)
            }
            Lane::Host => {
                // legacy per-block sweeps (either machine plane)
                let wprev_s = Arc::clone(&wprev_s);
                PlaneLocals::Host(fan_machines(
                    ctx.plane.engine,
                    ctx.plane.shards,
                    batches,
                    &mut ctx.meter,
                    move |eng, batch, _i, meter| {
                        let mut xi = wprev_s.to_vec();
                        for _sweep in 0..sweeps {
                            // local full gradient at the snapshot
                            // (charged locally)
                            let gs = local_grad_sum(eng, loss, batch, &xi, meter)?;
                            let cnt = gs.count.max(1.0) as f32;
                            let mu: Vec<f32> = gs.grad_sum.iter().map(|&g| g / cnt).collect();
                            let snapshot = xi.clone();
                            let blocks = 0..batch.n_blocks();
                            let (_x_end, x_avg) = vr_sweep_machine(
                                eng,
                                loss,
                                LocalSolver::Svrg,
                                blocks,
                                batch,
                                &xi,
                                &snapshot,
                                &mu,
                                &wprev_s,
                                gamma32,
                                eta,
                                meter,
                            )?;
                            xi = x_avg;
                        }
                        Ok(xi)
                    },
                )?)
            }
        };
        // the single communication round that gives the method its name
        let z = ctx.all_reduce_avg_pv(locals)?;
        ctx.plane.into_host(z)
    }
}
