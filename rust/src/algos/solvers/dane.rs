//! Inexact DANE (+ AIDE catalyst) inner solver — Algorithm 2.
//!
//! Three nested loops: minibatch-prox (outer, lives in `mbprox`), AIDE
//! extrapolation (R), DANE rounds (K). Each DANE round:
//!   1. one all-reduce computes the global gradient at `z_{k-1}`;
//!   2. every machine approximately solves its local corrected objective
//!      (equation 33) with prox-SVRG sweeps over its local minibatch;
//!   3. one all-reduce averages the local solutions (equation 34).
//!
//! Key identity (see DESIGN.md): with snapshot `z_{k-1}` the SVRG step for
//! the DANE-corrected local objective is
//!
//! ```text
//!     dl(x,xi) - dl(z,xi) + g_global + (gamma+kappa) (x - center)
//! ```
//!
//! with `center = (gamma w_prev + kappa y_{r-1}) / (gamma+kappa)` — i.e.
//! exactly the `svrg_{loss}` artifact with `mu = g_global`, so the same
//! Pallas kernel serves DSVRG and DANE.
//!
//! # Device-resident steady state
//!
//! With the chained artifacts present (and one local pass, the paper's
//! configuration), a DANE round runs on the device plane: the global
//! gradient is the `gacc{K}` accumulator chain + DeviceCollective reduce,
//! every machine's local solve advances a `[2, d]` state through its
//! *fused* block groups (`svrgc{K}`/`sagac{K}` — no `vr_lits`, no
//! per-block downloads), and the solution average is the DeviceCollective
//! again. Downlink per round: ONE d-vector (the broadcast iterate `z`,
//! which seeds the next round's sweep states) — against two `[d]` vectors
//! per block per machine on the legacy path. On the shard plane the same
//! kernels run per machine on the owning shard's engine and the combines
//! run the host collective in fixed machine order — bit-identical to the
//! DeviceCollective (see `runtime::shard`). `force_legacy` pins the
//! per-block host path for parity tests.

use super::{vr_sweep_machine, vr_sweep_machine_grouped, LocalSolver, ProxSolver};
use crate::algos::RunContext;
use crate::linalg;
use crate::objective::{
    distributed_mean_grad, distributed_mean_grad_dev, fan_machines, local_grad_sum,
    mean_grad_chained_host, MachineBatch,
};
use anyhow::Result;
use std::sync::Arc;

pub struct DaneSolver {
    /// DANE rounds per AIDE step (theory: O(log n))
    pub k_inner: usize,
    /// AIDE catalyst steps (1 = plain DANE, the b <= b* regime)
    pub r_outer: usize,
    /// catalyst regularization kappa (0 in the b <= b* regime)
    pub kappa: f64,
    /// local VR sweeps per DANE round (paper's experiments: 1 pass)
    pub local_passes: usize,
    /// VR stepsize
    pub eta: f64,
    /// which VR kernel performs the local solve (paper's App. E: SAGA)
    pub local_solver: LocalSolver,
    /// pin the legacy per-block host path (parity tests / diagnostics)
    pub force_legacy: bool,
}

impl DaneSolver {
    pub fn plain(k_inner: usize, eta: f64) -> Self {
        Self {
            k_inner,
            r_outer: 1,
            kappa: 0.0,
            local_passes: 1,
            eta,
            local_solver: LocalSolver::Svrg,
            force_legacy: false,
        }
    }

    pub fn aide(k_inner: usize, r_outer: usize, kappa: f64, eta: f64) -> Self {
        Self {
            k_inner,
            r_outer,
            kappa,
            local_passes: 1,
            eta,
            local_solver: LocalSolver::Svrg,
            force_legacy: false,
        }
    }

    pub fn with_local_solver(mut self, s: LocalSolver) -> Self {
        self.local_solver = s;
        self
    }

    /// Whether the DANE rounds can ride the chained kernels: needs the
    /// gacc/VR-chain artifacts plus the one-pass configuration (multi-pass
    /// re-snapshots stay on the legacy path). No `red_ready` requirement:
    /// the DeviceCollective's host fallback for unserved cluster sizes is
    /// bit-identical, so chaining stays worthwhile at any m.
    fn chain_ready(&self, ctx: &RunContext) -> bool {
        !self.force_legacy
            && self.local_passes <= 1
            && ctx.engine.chain_grad_ready(ctx.loss.tag(), ctx.d)
            && ctx.engine.chain_vr_ready(ctx.loss.tag(), ctx.d)
    }

    /// K DANE rounds on `min_w phi_I(w) + geff/2 ||w - center||^2`
    /// starting from `z0` — legacy per-block plane.
    fn dane_rounds_legacy(
        &self,
        ctx: &mut RunContext,
        batches: &[MachineBatch],
        z0: &[f32],
        center: &[f32],
        geff: f64,
    ) -> Result<Vec<f32>> {
        let mut z = z0.to_vec();
        for _k in 0..self.k_inner {
            // (1) global gradient at z — 1 comm round
            let (g, _, _) = distributed_mean_grad(
                ctx.engine,
                ctx.shards,
                ctx.loss,
                batches,
                &z,
                &mut ctx.net,
                &mut ctx.meter,
            )?;
            // (2) local solves: prox-SVRG sweeps with mu = g (see header),
            // fanned across the shard plane when one is present
            let loss = ctx.loss;
            let d = ctx.d;
            let solver = self.local_solver;
            let passes = self.local_passes.max(1);
            let eta = self.eta as f32;
            let geff32 = geff as f32;
            let z_s: Arc<[f32]> = Arc::from(&z[..]);
            let g_s: Arc<[f32]> = Arc::from(&g[..]);
            let c_s: Arc<[f32]> = Arc::from(center);
            let mut locals: Vec<Vec<f32>> = fan_machines(
                ctx.engine,
                ctx.shards,
                batches,
                &mut ctx.meter,
                move |eng, batch, _i, m| {
                    let mut xi = z_s.to_vec();
                    let mut snapshot = z_s.to_vec();
                    let mut mu = g_s.to_vec();
                    for pass in 0..passes {
                        if pass > 0 {
                            // re-snapshot locally:
                            // mu' = grad_i(x) + (g - grad_i(z))
                            let gi_z = local_grad_sum(eng, loss, batch, &z_s, m)?;
                            let gi_x = local_grad_sum(eng, loss, batch, &xi, m)?;
                            let cnt = gi_z.count.max(1.0) as f32;
                            mu = g_s.to_vec();
                            for j in 0..d {
                                mu[j] += gi_x.grad_sum[j] / cnt - gi_z.grad_sum[j] / cnt;
                            }
                            snapshot = xi.clone();
                        }
                        let blocks = 0..batch.n_blocks();
                        let (_x_end, x_avg) = vr_sweep_machine(
                            eng, loss, solver, blocks, batch, &xi, &snapshot, &mu, &c_s,
                            geff32, eta, m,
                        )?;
                        xi = x_avg;
                    }
                    Ok(xi)
                },
            )?;
            // (3) average local solutions — 1 comm round
            ctx.net.all_reduce_avg(&mut ctx.meter, &mut locals);
            z = locals.pop().unwrap();
        }
        Ok(z)
    }

    /// K DANE rounds on the chained device plane (single engine): the
    /// gradient and the local solutions never visit the host except for
    /// the one `z` materialization per round that seeds the sweep states.
    fn dane_rounds_chained(
        &self,
        ctx: &mut RunContext,
        batches: &[MachineBatch],
        z0: &[f32],
        center: &[f32],
        geff: f64,
    ) -> Result<Vec<f32>> {
        let m = batches.len();
        let d = ctx.d;
        let mut z_host = z0.to_vec();
        let mut z_dev = ctx.engine.upload_dev(&z_host, &[d])?;
        let c_dev = ctx.engine.upload_dev(center, &[d])?;
        let gamma_dev = ctx.engine.scalar_dev(geff as f32)?;
        let eta_dev = ctx.engine.scalar_dev(self.eta as f32)?;
        for _k in 0..self.k_inner {
            // (1) global gradient at z — 1 comm round, fully chained
            let g_dev = distributed_mean_grad_dev(
                ctx.engine,
                ctx.shards,
                ctx.loss,
                batches,
                &z_dev,
                &mut ctx.net,
                &mut ctx.meter,
            )?;
            // (2) every machine's one-pass local solve rides its fused
            // groups; only the state seed needs host bits (z, already
            // known everywhere from the broadcast semantics)
            let mut locals = Vec::with_capacity(m);
            for (i, batch) in batches.iter().enumerate() {
                locals.push(super::vr_sweep_avg_dev(
                    ctx.engine,
                    ctx.loss,
                    self.local_solver,
                    0..batch.n_groups(),
                    batch,
                    &z_host,
                    &z_dev,
                    &g_dev,
                    &c_dev,
                    &gamma_dev,
                    &eta_dev,
                    ctx.meter.machine(i),
                )?);
            }
            // (3) average local solutions — the DeviceCollective reduce
            z_dev = ctx.net.device_all_reduce_avg(&mut ctx.meter, ctx.engine, &locals)?;
            // the round-boundary downlink: one d-vector, seeding the next
            // round's sweep states
            z_host = ctx.engine.materialize(&z_dev)?;
        }
        Ok(z_host)
    }

    /// The chained rounds on the shard plane: identical kernels per
    /// machine on the owning shard, host collectives in fixed machine
    /// order — bit-identical to [`DaneSolver::dane_rounds_chained`].
    fn dane_rounds_sharded(
        &self,
        ctx: &mut RunContext,
        batches: &[MachineBatch],
        z0: &[f32],
        center: &[f32],
        geff: f64,
    ) -> Result<Vec<f32>> {
        let mut z = z0.to_vec();
        for _k in 0..self.k_inner {
            // (1) chained global gradient at z — 1 comm round
            let g = mean_grad_chained_host(
                ctx.engine,
                ctx.shards,
                ctx.loss,
                batches,
                &z,
                &mut ctx.net,
                &mut ctx.meter,
            )?;
            // (2) local solves fan to the shards, one chained sweep each
            let loss = ctx.loss;
            let solver = self.local_solver;
            let eta = self.eta as f32;
            let geff32 = geff as f32;
            let z_s: Arc<[f32]> = Arc::from(&z[..]);
            let g_s: Arc<[f32]> = Arc::from(&g[..]);
            let c_s: Arc<[f32]> = Arc::from(center);
            let mut locals: Vec<Vec<f32>> = fan_machines(
                ctx.engine,
                ctx.shards,
                batches,
                &mut ctx.meter,
                move |eng, batch, _i, m| {
                    let (_x_end, x_avg) = vr_sweep_machine_grouped(
                        eng,
                        loss,
                        solver,
                        0..batch.n_groups(),
                        batch,
                        &z_s,
                        &z_s,
                        &g_s,
                        &c_s,
                        geff32,
                        eta,
                        m,
                    )?;
                    Ok(x_avg)
                },
            )?;
            // (3) average — host collective, bit-identical to the reduce
            ctx.net.all_reduce_avg(&mut ctx.meter, &mut locals);
            z = locals.pop().unwrap();
        }
        Ok(z)
    }

    fn dane_rounds(
        &self,
        ctx: &mut RunContext,
        batches: &[MachineBatch],
        z0: &[f32],
        center: &[f32],
        geff: f64,
    ) -> Result<Vec<f32>> {
        if self.chain_ready(ctx) {
            if batches.iter().any(|b| b.shard.is_some()) {
                self.dane_rounds_sharded(ctx, batches, z0, center, geff)
            } else {
                self.dane_rounds_chained(ctx, batches, z0, center, geff)
            }
        } else {
            self.dane_rounds_legacy(ctx, batches, z0, center, geff)
        }
    }
}

impl ProxSolver for DaneSolver {
    fn name(&self) -> String {
        if self.r_outer <= 1 && self.kappa == 0.0 {
            format!("dane(K={},{})", self.k_inner, self.local_solver.tag())
        } else {
            format!("aide(K={},R={},kappa={:.3})", self.k_inner, self.r_outer, self.kappa)
        }
    }

    /// Host block copies are only needed for the legacy per-block sweeps;
    /// the chained rounds sweep the fused device groups directly.
    fn needs_vr_blocks(&self, ctx: &RunContext) -> bool {
        !self.chain_ready(ctx)
    }

    fn solve(
        &mut self,
        ctx: &mut RunContext,
        batches: &[MachineBatch],
        wprev: &[f32],
        gamma: f64,
        _t: usize,
    ) -> Result<Vec<f32>> {
        let d = ctx.d;
        if self.r_outer <= 1 || self.kappa == 0.0 {
            // plain DANE on f_t
            return self.dane_rounds(ctx, batches, wprev, wprev, gamma);
        }
        // AIDE: catalyst outer loop (equations 35-36)
        let q = gamma / (gamma + self.kappa);
        let mut alpha = q.sqrt();
        let mut y = wprev.to_vec();
        #[allow(unused_assignments)] // rebound via mem::replace each round
        let mut x_prev = wprev.to_vec();
        let mut x = wprev.to_vec();
        let geff = gamma + self.kappa;
        for _r in 0..self.r_outer {
            // center of the augmented quadratic:
            // gamma/2||w-wprev||^2 + kappa/2||w-y||^2
            //   = geff/2 ||w - (gamma wprev + kappa y)/geff||^2 + const
            let mut center = vec![0.0f32; d];
            for j in 0..d {
                center[j] =
                    ((gamma * wprev[j] as f64 + self.kappa * y[j] as f64) / geff) as f32;
            }
            let z = self.dane_rounds(ctx, batches, &y, &center, geff)?;
            x_prev = std::mem::replace(&mut x, z);
            // alpha_r solves alpha^2 = (1-alpha) alpha_{r-1}^2 + q alpha
            let a2 = alpha * alpha;
            let disc = (q - a2) * (q - a2) + 4.0 * a2;
            let alpha_new = 0.5 * ((q - a2) + disc.sqrt());
            let coef = (alpha * (1.0 - alpha)) / (alpha * alpha + alpha_new);
            // y = x + coef (x - x_prev)
            y = x.clone();
            let diff = linalg::sub(&x, &x_prev);
            linalg::axpy(coef as f32, &diff, &mut y);
            alpha = alpha_new;
        }
        Ok(x)
    }
}
