//! Inexact DANE (+ AIDE catalyst) inner solver — Algorithm 2, written
//! ONCE against the execution plane.
//!
//! Three nested loops: minibatch-prox (outer, lives in `mbprox`), AIDE
//! extrapolation (R), DANE rounds (K). Each DANE round:
//!   1. one all-reduce computes the global gradient at `z_{k-1}`;
//!   2. every machine approximately solves its local corrected objective
//!      (equation 33) with prox-VR sweeps over its local minibatch;
//!   3. one all-reduce averages the local solutions (equation 34).
//!
//! Key identity (see DESIGN.md): with snapshot `z_{k-1}` the SVRG step for
//! the DANE-corrected local objective is
//!
//! ```text
//!     dl(x,xi) - dl(z,xi) + g_global + (gamma+kappa) (x - center)
//! ```
//!
//! with `center = (gamma w_prev + kappa y_{r-1}) / (gamma+kappa)` — i.e.
//! exactly the VR artifact with `mu = g_global`, so the same Pallas kernel
//! serves DSVRG and DANE.
//!
//! Lane notes: with one local pass (the paper's configuration) the rounds
//! ride whatever lane the plane resolves — on the Dev lane the global
//! gradient is the `gacc{K}` chain + DeviceCollective, every local solve
//! advances a `[2, d]` state over the machine's fused groups, and the
//! downlink per round is ONE d-vector (the averaged `z`, which seeds the
//! next round's sweep states). Multi-pass local solves re-snapshot on
//! corrected local gradients, which only the Host lane implements — the
//! solver forces `Lane::Host` for them, exactly the pre-plane behavior.

use super::{Lane, LocalSolver, PackMode, ProxSolver};
use crate::algos::RunContext;
use crate::linalg;
use crate::objective::MachineBatch;
use anyhow::Result;

pub struct DaneSolver {
    /// DANE rounds per AIDE step (theory: O(log n))
    pub k_inner: usize,
    /// AIDE catalyst steps (1 = plain DANE, the b <= b* regime)
    pub r_outer: usize,
    /// catalyst regularization kappa (0 in the b <= b* regime)
    pub kappa: f64,
    /// local VR sweeps per DANE round (paper's experiments: 1 pass)
    pub local_passes: usize,
    /// VR stepsize
    pub eta: f64,
    /// which VR kernel performs the local solve (paper's App. E: SAGA)
    pub local_solver: LocalSolver,
}

impl DaneSolver {
    pub fn plain(k_inner: usize, eta: f64) -> Self {
        Self {
            k_inner,
            r_outer: 1,
            kappa: 0.0,
            local_passes: 1,
            eta,
            local_solver: LocalSolver::Svrg,
        }
    }

    pub fn aide(k_inner: usize, r_outer: usize, kappa: f64, eta: f64) -> Self {
        Self {
            k_inner,
            r_outer,
            kappa,
            local_passes: 1,
            eta,
            local_solver: LocalSolver::Svrg,
        }
    }

    pub fn with_local_solver(mut self, s: LocalSolver) -> Self {
        self.local_solver = s;
        self
    }

    /// The lane this solver's rounds run on: the plane's VR lane, except
    /// that multi-pass local solves (re-snapshotting) are Host-lane only.
    fn lane(&self, ctx: &RunContext) -> Lane {
        if self.local_passes > 1 {
            Lane::Host
        } else {
            ctx.plane.vr_lane(ctx.loss, ctx.d)
        }
    }

    /// K DANE rounds on `min_w phi_I(w) + geff/2 ||w - center||^2`
    /// starting from `z0` — the one body, lane-polymorphic via the plane.
    fn dane_rounds(
        &self,
        ctx: &mut RunContext,
        batches: &[MachineBatch],
        z0: &[f32],
        center: &[f32],
        geff: f64,
    ) -> Result<Vec<f32>> {
        let lane = self.lane(ctx);
        let mut z_host = z0.to_vec();
        let mut z = ctx.plane.lift(lane, z0)?;
        for _k in 0..self.k_inner {
            // (1) global gradient at z — 1 comm round
            let g = ctx.mean_grad_pv(lane, batches, &z)?;
            // (2) every machine's local solve: VR sweeps with mu = g (see
            // header), fanned across the shard plane when one is present
            let locals = ctx.local_sweep_all(
                lane,
                self.local_solver,
                batches,
                &z_host,
                &z,
                &g,
                center,
                geff as f32,
                self.eta as f32,
                self.local_passes.max(1),
            )?;
            // (3) average local solutions — 1 comm round
            z = ctx.all_reduce_avg_pv(locals)?;
            // the round-boundary downlink on the Dev lane: one d-vector,
            // seeding the next round's sweep states (a copy elsewhere)
            z_host = ctx.plane.to_host(&z)?;
        }
        Ok(z_host)
    }
}

impl ProxSolver for DaneSolver {
    fn name(&self) -> String {
        if self.r_outer <= 1 && self.kappa == 0.0 {
            format!("dane(K={},{})", self.k_inner, self.local_solver.tag())
        } else {
            format!("aide(K={},R={},kappa={:.3})", self.k_inner, self.r_outer, self.kappa)
        }
    }

    /// Host blocks are only needed for Host-lane per-block sweeps; the
    /// chained lanes sweep each machine's full fused-group set directly.
    fn pack_mode(&self, ctx: &RunContext) -> PackMode {
        match self.lane(ctx) {
            Lane::Host => PackMode::Full,
            _ => PackMode::GradOnly,
        }
    }

    fn solve(
        &mut self,
        ctx: &mut RunContext,
        batches: &[MachineBatch],
        wprev: &[f32],
        gamma: f64,
        _t: usize,
    ) -> Result<Vec<f32>> {
        let d = ctx.d;
        if self.r_outer <= 1 || self.kappa == 0.0 {
            // plain DANE on f_t
            return self.dane_rounds(ctx, batches, wprev, wprev, gamma);
        }
        // AIDE: catalyst outer loop (equations 35-36)
        let q = gamma / (gamma + self.kappa);
        let mut alpha = q.sqrt();
        let mut y = wprev.to_vec();
        #[allow(unused_assignments)] // rebound via mem::replace each round
        let mut x_prev = wprev.to_vec();
        let mut x = wprev.to_vec();
        let geff = gamma + self.kappa;
        for _r in 0..self.r_outer {
            // center of the augmented quadratic:
            // gamma/2||w-wprev||^2 + kappa/2||w-y||^2
            //   = geff/2 ||w - (gamma wprev + kappa y)/geff||^2 + const
            let mut center = vec![0.0f32; d];
            for j in 0..d {
                center[j] =
                    ((gamma * wprev[j] as f64 + self.kappa * y[j] as f64) / geff) as f32;
            }
            let z = self.dane_rounds(ctx, batches, &y, &center, geff)?;
            x_prev = std::mem::replace(&mut x, z);
            // alpha_r solves alpha^2 = (1-alpha) alpha_{r-1}^2 + q alpha
            let a2 = alpha * alpha;
            let disc = (q - a2) * (q - a2) + 4.0 * a2;
            let alpha_new = 0.5 * ((q - a2) + disc.sqrt());
            let coef = (alpha * (1.0 - alpha)) / (alpha * alpha + alpha_new);
            // y = x + coef (x - x_prev)
            y = x.clone();
            let diff = linalg::sub(&x, &x_prev);
            linalg::axpy(coef as f32, &diff, &mut y);
            alpha = alpha_new;
        }
        Ok(x)
    }
}
