//! Algorithms: minibatch-prox (the paper's contribution), its inner
//! solvers (DSVRG / DANE / exact-CG / one-shot averaging), and every
//! baseline from Table 1.
//!
//! All methods implement [`Method`] over a shared [`RunContext`] that
//! owns ONE [`ExecPlane`] (engine access + fan/join + collectives + VR
//! sweeps + materialization points — see `runtime::plane`), the simulated
//! network, per-machine meters, the per-machine sample streams and the
//! held-out evaluator. Solvers are written once against the plane verbs;
//! which plane executes them is runtime policy, not algorithm code.
//! Resource accounting conventions are in `accounting` / `objective`.

pub mod accel_sgd;
pub mod erm;
pub mod mbprox;
pub mod minibatch_sgd;
pub mod sgd_local;
pub mod solvers;

use crate::accounting::{
    CacheMeter, ClusterMeter, FaultMeter, OverlapMeter, ResourceReport, StallMeter, UploadMeter,
};
use crate::comm::Network;
use crate::data::{Loss, MachineStreams};
use crate::objective::{self, Evaluator, MachineBatch};
use crate::runtime::plane::{
    ExecPlane, Lane, LocalSolver, PlaneLocals, PlaneVec, VrSweeper,
};
use anyhow::Result;

pub use crate::objective::PackMode;

/// Everything a method needs to run: the execution plane, simulated
/// cluster fabric, the per-machine streams (coordinator-held or
/// shard-resident — see [`MachineStreams`]), and the evaluation hook.
pub struct RunContext<'e> {
    /// THE execution plane (host | chained | sharded) every engine access
    /// goes through; selection is coordinator policy (`plane=` / `PLANE`)
    pub plane: ExecPlane<'e>,
    pub net: Network,
    pub meter: ClusterMeter,
    pub loss: Loss,
    /// padded (artifact) feature dimension
    pub d: usize,
    /// the DataPlane state: machine streams, drawn from exclusively
    /// through the plane's draw verb
    pub streams: MachineStreams,
    pub evaluator: Option<Evaluator>,
    /// evaluate every `eval_every` outer iterations (0 = only at the end)
    pub eval_every: usize,
}

impl<'e> RunContext<'e> {
    pub fn m(&self) -> usize {
        self.streams.len()
    }

    /// Draw a fresh minibatch of `b_local` samples on every machine,
    /// charging samples (and memory if `hold`). Batches support the full
    /// engine surface including VR sweeps.
    pub fn draw_batches(&mut self, b_local: usize, hold: bool) -> Result<Vec<MachineBatch>> {
        self.draw_batches_mode(b_local, hold, PackMode::Full)
    }

    /// Like [`RunContext::draw_batches`] for methods that only take the
    /// grad/normal-matvec path: host block copies are dropped right after
    /// the fused upload (no host memory retained per batch).
    pub fn draw_batches_grad_only(
        &mut self,
        b_local: usize,
        hold: bool,
    ) -> Result<Vec<MachineBatch>> {
        self.draw_batches_mode(b_local, hold, PackMode::GradOnly)
    }

    /// Draw batches whose fused groups are aligned to a p-way block
    /// partition ([`MachineBatch::pack_vr_aligned`]): chained VR sweeps
    /// over `group_ranges(p)` then touch exactly the blocks the Host-lane
    /// per-block partition would. No host blocks are retained.
    pub fn draw_batches_vr_aligned(
        &mut self,
        b_local: usize,
        hold: bool,
        p: usize,
    ) -> Result<Vec<MachineBatch>> {
        self.draw_batches_mode(b_local, hold, PackMode::VrAligned(p))
    }

    /// Draw with an explicit [`PackMode`] — the plane's draw verb
    /// ([`ExecPlane::draw_batches`]): inline on the coordinator engine,
    /// or generated AND packed on the owning shards with no
    /// coordinator-side sample materialization.
    pub fn draw_batches_mode(
        &mut self,
        b_local: usize,
        hold: bool,
        mode: PackMode,
    ) -> Result<Vec<MachineBatch>> {
        let d = self.d;
        self.plane.draw_batches(&mut self.streams, &mut self.meter, d, b_local, hold, mode)
    }

    /// Draw verb for ONE machine ([`ExecPlane::draw_machine`]): the
    /// single-machine methods' stream advances wherever the machine
    /// lives.
    pub fn draw_machine(
        &mut self,
        i: usize,
        n: usize,
        hold: bool,
        mode: PackMode,
    ) -> Result<MachineBatch> {
        let d = self.d;
        self.plane.draw_machine(&mut self.streams, &mut self.meter, i, d, n, hold, mode)
    }

    /// Release the memory charged when `batches` were drawn: each batch
    /// records its own held count, so ragged final batches release
    /// exactly what they held (the b_local assumption corrupted the
    /// peak-memory meter whenever a machine drew short).
    pub fn release_batches(&mut self, batches: &[MachineBatch]) {
        assert_eq!(batches.len(), self.meter.m(), "one batch per machine");
        for (i, batch) in batches.iter().enumerate() {
            self.meter.machine(i).release(batch.held);
        }
    }

    // ---- plane verbs, with the context's net/meter/loss threaded in ----

    /// Distributed mean gradient at `z` on `lane` — one all-reduce round
    /// (see [`ExecPlane::mean_grad`]).
    pub fn mean_grad_pv(
        &mut self,
        lane: Lane,
        batches: &[MachineBatch],
        z: &PlaneVec,
    ) -> Result<PlaneVec> {
        self.plane.mean_grad(lane, &mut self.net, &mut self.meter, self.loss, batches, z)
    }

    /// Machine-local mean gradient on `lane` — no collective, no round
    /// charged (see [`ExecPlane::local_mean_grad`]). The single-machine
    /// methods' gradient read.
    pub fn local_mean_grad_pv(
        &mut self,
        lane: Lane,
        batches: &[MachineBatch],
        i: usize,
        z: &PlaneVec,
    ) -> Result<PlaneVec> {
        self.plane.local_mean_grad(lane, &mut self.meter, self.loss, batches, i, z)
    }

    /// Host-level distributed mean gradient with the mean loss and total
    /// count — the tupled dispatch path (ERM full gradients, evaluation
    /// probes; the SGD baselines now ride the plane's chained lane via
    /// [`RunContext::mean_grad_pv`]).
    pub fn mean_grad_loss(
        &mut self,
        batches: &[MachineBatch],
        w: &[f32],
    ) -> Result<(Vec<f32>, f64, f64)> {
        objective::distributed_mean_grad(
            self.plane.engine,
            self.plane.shards,
            self.loss,
            batches,
            w,
            &mut self.net,
            &mut self.meter,
        )
    }

    /// Average per-machine locals — one round ([`ExecPlane::all_reduce_avg`]).
    pub fn all_reduce_avg_pv(&mut self, locals: PlaneLocals) -> Result<PlaneVec> {
        self.plane.all_reduce_avg(&mut self.net, &mut self.meter, locals)
    }

    /// Broadcast machine `src`'s value — one round ([`ExecPlane::broadcast`]).
    pub fn broadcast_pv(&mut self, src: usize, v: PlaneVec) -> PlaneVec {
        self.plane.broadcast(&mut self.net, &mut self.meter, src, v)
    }

    /// Advance a designated-machine sweep session ([`VrSweeper::sweep`]).
    #[allow(clippy::too_many_arguments)]
    pub fn vr_sweep(
        &mut self,
        sweeper: &mut VrSweeper,
        batches: &[MachineBatch],
        j: usize,
        s: usize,
        z: &PlaneVec,
        mu: &PlaneVec,
    ) -> Result<PlaneVec> {
        sweeper.sweep(&mut self.plane, &mut self.meter, self.loss, batches, j, s, z, mu)
    }

    /// Per-machine DANE-style local solves ([`ExecPlane::local_sweep_all`]).
    #[allow(clippy::too_many_arguments)]
    pub fn local_sweep_all(
        &mut self,
        lane: Lane,
        kernel: LocalSolver,
        batches: &[MachineBatch],
        z_host: &[f32],
        z: &PlaneVec,
        mu: &PlaneVec,
        center: &[f32],
        gamma: f32,
        eta: f32,
        passes: usize,
    ) -> Result<PlaneLocals> {
        self.plane.local_sweep_all(
            lane,
            &mut self.meter,
            self.loss,
            kernel,
            batches,
            z_host,
            z,
            mu,
            center,
            gamma,
            eta,
            passes,
        )
    }

    // ---- evaluation ----------------------------------------------------

    /// Whether outer iteration `t` is an evaluation checkpoint. Public so
    /// methods can skip building their evaluation iterate (e.g. the
    /// running average's d-length mean) on the iterations that will not
    /// evaluate it.
    pub fn eval_due(&self, t: usize) -> bool {
        self.eval_every > 0 && t % self.eval_every == 0
    }

    pub fn maybe_eval(&mut self, t: usize, w: &[f32]) -> Result<Option<f64>> {
        if !self.eval_due(t) {
            return Ok(None);
        }
        self.eval_now(w)
    }

    /// [`RunContext::maybe_eval`] at a plane-resident iterate: the same
    /// checkpoint policy, evaluated through the session-alias path on the
    /// chained plane so the iterate is never materialized for the
    /// checkpoint.
    pub fn maybe_eval_pv(&mut self, t: usize, w: &PlaneVec) -> Result<Option<f64>> {
        if !self.eval_due(t) {
            return Ok(None);
        }
        match &self.evaluator {
            Some(ev) => Ok(Some(ev.objective_pv(&mut self.plane, w)?)),
            None => Ok(None),
        }
    }

    pub fn eval_now(&mut self, w: &[f32]) -> Result<Option<f64>> {
        match &self.evaluator {
            Some(ev) => Ok(Some(ev.objective(&mut self.plane, w)?)),
            None => Ok(None),
        }
    }
}

/// One checkpoint on a method's trajectory.
#[derive(Clone, Debug)]
pub struct CurvePoint {
    pub outer_iter: usize,
    pub samples_total: u64,
    pub comm_rounds: u64,
    pub vec_ops: u64,
    pub objective: Option<f64>,
}

#[derive(Clone, Debug)]
pub struct RunResult {
    pub name: String,
    pub w: Vec<f32>,
    pub report: ResourceReport,
    pub curve: Vec<CurvePoint>,
    pub sim_time_s: f64,
    pub final_objective: Option<f64>,
    /// Dispatch-stall accounting for the sharded plane's draw verb
    /// (wall-clock the workers spent waiting on their prefetch lanes,
    /// plus the staged-pack hit rate). `None` off the sharded plane.
    /// Wall-clock only — never part of the simulated cost model, so it
    /// carries no parity obligation (see `runtime::shard`).
    pub stalls: Option<StallMeter>,
    /// Fan-pipelining accounting for the sharded plane (how much pack
    /// work ran while the next lane draw was already in flight).
    /// `None` off the sharded plane. Wall-clock only, like `stalls` —
    /// never part of the simulated cost model.
    pub overlap: Option<OverlapMeter>,
    /// Upload-lane accounting: host->device transfers this run across
    /// the coordinator engine AND every shard engine (the lane runs on
    /// all of them), with how many staged through the rings and the
    /// wall-clock the staging could overlap with dispatch. Present on
    /// every plane — the coordinator engine meters even without a pool.
    /// Wall-clock only, like `stalls`/`overlap` — never part of the
    /// simulated cost model, and the transfer COUNTS are bit-identical
    /// with the lane on or off (pinned by `rust/tests/upload_parity.rs`).
    pub uploads: Option<UploadMeter>,
    /// Fault accounting: the seeded simulated schedule (stragglers,
    /// dropouts, added simulated seconds — deterministic, from the
    /// network's `FaultPlan`) merged with the REAL recovery tally
    /// (worker revivals and batch replays, from the shard pool).
    /// `None` when faults are off AND nothing was recovered; a genuine
    /// worker death is reported even with `faults=off`. Never part of
    /// the paper's cost model — iterates/curves carry no fault marks.
    pub faults: Option<FaultMeter>,
    /// Executable-cache accounting for THIS run: the coordinator and
    /// shard engines' content-addressed cache deltas (hits/misses/compile
    /// wall-clock/evictions), filled by the coordinator's `Runner::run`
    /// from before/after snapshots. `None` when no runner recorded it
    /// (methods driven outside a `Runner`). Wall-clock only, like
    /// `stalls`/`overlap` — never part of the simulated cost model, so a
    /// warm-cache run is bit-identical to a cold one everywhere else
    /// (pinned by `rust/tests/serve_parity.rs`).
    pub cache: Option<CacheMeter>,
}

/// A distributed stochastic optimization method.
pub trait Method {
    fn name(&self) -> String;
    fn run(&mut self, ctx: &mut RunContext) -> Result<RunResult>;
}

/// Shared trajectory-recording helper used by every method.
pub struct Recorder {
    name: String,
    curve: Vec<CurvePoint>,
}

impl Recorder {
    pub fn new(name: impl Into<String>) -> Self {
        Self { name: name.into(), curve: Vec::new() }
    }

    pub fn point(&mut self, ctx: &RunContext, t: usize, objective: Option<f64>) {
        let rep = ctx.meter.report();
        self.curve.push(CurvePoint {
            outer_iter: t,
            samples_total: rep.total_samples,
            comm_rounds: rep.comm_rounds,
            vec_ops: rep.vec_ops,
            objective,
        });
    }

    pub fn finish(self, ctx: &mut RunContext, w: Vec<f32>) -> Result<RunResult> {
        let final_objective = ctx.eval_now(&w)?;
        // the coordinator engine's lane meters on every plane; shard
        // engines add theirs when a pool is attached
        let mut uploads = ctx.plane.engine.upload_meter().clone();
        let (stalls, overlap) = match ctx.plane.shards {
            Some(pool) => {
                let (s, o, u) = pool.gathered_run_meters()?;
                uploads.merge(&u);
                (Some(s), Some(o))
            }
            None => (None, None),
        };
        // simulated schedule (from the fault plan, deterministic) merged
        // with the real recovery tally (from the pool); surfaced whenever
        // either has something to say
        let mut fm = ctx.net.faults.as_ref().map(|p| p.meter.clone()).unwrap_or_default();
        if let Some(pool) = ctx.plane.shards {
            let (recoveries, replays) = pool.recovery_counts();
            fm.recoveries += recoveries;
            fm.replays += replays;
        }
        let faults = if ctx.net.faults.is_some() || fm.any() { Some(fm) } else { None };
        Ok(RunResult {
            name: self.name,
            report: ctx.meter.report(),
            curve: self.curve,
            sim_time_s: ctx.net.stats.sim_time_s,
            final_objective,
            stalls,
            overlap,
            uploads: Some(uploads),
            faults,
            cache: None,
            w,
        })
    }
}

#[cfg(test)]
mod tests {
    // RunContext/Recorder behaviour is exercised end-to-end by the
    // integration tests (rust/tests/algo_integration.rs and
    // rust/tests/plane_matrix.rs); unit coverage here focuses on the pure
    // helpers.
    use super::*;

    #[test]
    fn curve_point_fields_round_trip() {
        let p = CurvePoint {
            outer_iter: 3,
            samples_total: 100,
            comm_rounds: 7,
            vec_ops: 42,
            objective: Some(0.5),
        };
        assert_eq!(p.outer_iter, 3);
        assert_eq!(p.objective, Some(0.5));
    }
}
