//! The experiment coordinator: builds run contexts from configs, selects
//! methods via the theory-driven parameter plans, and executes runs.
//!
//! This is the crate's top-level orchestration layer — the CLI, examples
//! and benches all go through [`Runner`].

use crate::algos::erm::agd::DistributedAgd;
use crate::algos::erm::dane_erm::DaneErm;
use crate::algos::erm::disco::Disco;
use crate::algos::erm::dsvrg_erm::DsvrgErm;
use crate::algos::accel_sgd::AccelMinibatchSgd;
use crate::algos::mbprox::MinibatchProx;
use crate::algos::minibatch_sgd::MinibatchSgd;
use crate::algos::sgd_local::LocalSgd;
use crate::algos::solvers::dane::DaneSolver;
use crate::algos::solvers::LocalSolver;
use crate::algos::solvers::dsvrg::DsvrgSolver;
use crate::algos::solvers::exact_cg::ExactCgSolver;
use crate::algos::solvers::oneshot::OneShotSolver;
use crate::algos::{Method, RunContext, RunResult};
use crate::accounting::{CacheMeter, ClusterMeter};
use crate::comm::{faults::FaultPlan, netmodel::NetModel, Network};
use crate::config::ExperimentConfig;
use crate::data::scenario::{self, ScenarioParams, Setting, StreamFamily};
use crate::data::synth::{SynthSpec, SynthStream};
use crate::data::table3::DatasetSpec;
use crate::data::{Loss, MachineStreams, Sample, SampleStream};
use crate::objective::Evaluator;
use crate::runtime::{
    default_artifacts_dir, Engine, ExecPlane, PipelinePolicy, PlanePolicy, PrefetchPolicy,
    ShardPool, UploadPolicy,
};
use crate::theory::{self, ProblemConsts};
use anyhow::{anyhow, bail, Result};
use std::path::Path;

/// Problem constants used for the theory plans; row_norm=1 streams give
/// beta≈1 (squared) / 0.25 (logistic). The norm bound B tracks the planted
/// model norm of the matching `SynthSpec` (which scales with sqrt(dim) to
/// keep signal strength dimension-independent — see data::synth).
pub fn problem_consts(cfg: &ExperimentConfig) -> ProblemConsts {
    let (beta, b_norm) = match cfg.loss {
        Loss::Squared => (1.0, SynthSpec::signal_norm(cfg.dim, 1.0)),
        Loss::Logistic => (0.25, SynthSpec::signal_norm(cfg.dim, 2.0)),
    };
    ProblemConsts { l_lipschitz: 1.0, b_norm, beta_smooth: beta, m: cfg.m }
}

pub struct Runner {
    pub engine: Engine,
    pub net_model: NetModel,
    /// the shard pool backing the sharded plane; `None` drives machines
    /// on the coordinator engine. Results are bit-identical either way —
    /// the pool buys wall-clock only.
    pub shards: Option<ShardPool>,
    /// process-level execution-plane policy (`PLANE` env / default
    /// `Auto`); a per-run `plane=` config key overrides it when not
    /// `Auto`. Resolved ONCE per context into an [`ExecPlane`].
    pub plane: PlanePolicy,
    /// process-level draw-prefetch policy (`PREFETCH` env / default
    /// `Auto` = on); a per-run `prefetch=` config key overrides it when
    /// not `Auto`. Bit-parity is unconditional — this only moves
    /// dispatch-stall time.
    pub prefetch: PrefetchPolicy,
    /// process-level batched-fan pipeline policy (`PIPELINE` env /
    /// default `Auto` = on); a per-run `pipeline=` config key overrides
    /// it when not `Auto`. Bit-parity is unconditional — this only moves
    /// engine idle time.
    pub pipeline: PipelinePolicy,
    /// process-level upload-lane policy (`UPLOAD` env / default `Auto` =
    /// on); a per-run `upload=` config key overrides it when not `Auto`.
    /// Bit-parity is unconditional — the lane only moves host->device
    /// staging time, never bits or the metered transfer counts.
    pub upload: UploadPolicy,
    /// the pool in `shards` was self-attached by a `plane=sharded` run
    /// (not by `SHARDS`/`with_shards`): it is kept for later sharded
    /// runs but ignored when resolving `auto`/`chained`/`host`, so one
    /// sharded run cannot change which plane later runs resolve to
    self_pool: bool,
}

/// Parse the `SHARDS` environment variable: unset/empty/`0` means the
/// sequential plane, `n >= 1` a pool of n workers (n = 1 exercises the
/// full shard machinery on a single worker — the CI parity leg). Any
/// other value is an error — a typo must not silently fall back to the
/// sequential plane.
pub fn shards_from_env() -> Result<Option<usize>> {
    let raw = match std::env::var("SHARDS") {
        Err(_) => return Ok(None),
        Ok(raw) => raw,
    };
    let trimmed = raw.trim();
    if trimmed.is_empty() {
        return Ok(None);
    }
    let n: usize = trimmed
        .parse()
        .map_err(|_| anyhow!("SHARDS='{raw}' is not a shard count (unset/0 = sequential)"))?;
    Ok((n >= 1).then_some(n))
}

impl Runner {
    pub fn from_env() -> Result<Runner> {
        Runner::new(Engine::from_env()?)
            .with_env_shards(&default_artifacts_dir())?
            .with_env_plane()?
            .with_env_prefetch()?
            .with_env_pipeline()?
            .with_env_upload()
    }

    pub fn new(engine: Engine) -> Runner {
        Runner {
            engine,
            net_model: NetModel::default(),
            shards: None,
            plane: PlanePolicy::Auto,
            prefetch: PrefetchPolicy::Auto,
            pipeline: PipelinePolicy::Auto,
            upload: UploadPolicy::Auto,
            self_pool: false,
        }
    }

    /// Attach an explicit shard pool.
    pub fn with_shards(mut self, pool: ShardPool) -> Runner {
        self.shards = Some(pool);
        self.self_pool = false;
        self
    }

    /// Attach a shard pool per the `SHARDS` env var (no-op when unset/0),
    /// building the workers' engines from `artifacts_dir`.
    pub fn with_env_shards(mut self, artifacts_dir: &Path) -> Result<Runner> {
        if let Some(n) = shards_from_env()? {
            self.shards = Some(ShardPool::new(n, artifacts_dir)?);
            self.self_pool = false;
        }
        Ok(self)
    }

    /// Set the process-level plane policy explicitly.
    pub fn with_plane(mut self, plane: PlanePolicy) -> Runner {
        self.plane = plane;
        self
    }

    /// Adopt the `PLANE` env var as the process-level policy (unset =
    /// `Auto`; a typo is an error, not a silent fallback). Composes with
    /// `SHARDS`: e.g. `PLANE=host SHARDS=4` runs the legacy kernels
    /// fanned across four shard engines.
    pub fn with_env_plane(mut self) -> Result<Runner> {
        self.plane = PlanePolicy::from_env()?;
        Ok(self)
    }

    /// Set the process-level draw-prefetch policy explicitly.
    pub fn with_prefetch(mut self, prefetch: PrefetchPolicy) -> Runner {
        self.prefetch = prefetch;
        self
    }

    /// Adopt the `PREFETCH` env var as the process-level prefetch policy
    /// (unset = `Auto` = on; a typo is an error, not a silent fallback).
    pub fn with_env_prefetch(mut self) -> Result<Runner> {
        self.prefetch = PrefetchPolicy::from_env()?;
        Ok(self)
    }

    /// Set the process-level batched-fan pipeline policy explicitly.
    pub fn with_pipeline(mut self, pipeline: PipelinePolicy) -> Runner {
        self.pipeline = pipeline;
        self
    }

    /// Adopt the `PIPELINE` env var as the process-level pipeline policy
    /// (unset = `Auto` = on; a typo is an error, not a silent fallback).
    pub fn with_env_pipeline(mut self) -> Result<Runner> {
        self.pipeline = PipelinePolicy::from_env()?;
        Ok(self)
    }

    /// Set the process-level upload-lane policy explicitly.
    pub fn with_upload(mut self, upload: UploadPolicy) -> Runner {
        self.upload = upload;
        self
    }

    /// Adopt the `UPLOAD` env var as the process-level upload-lane policy
    /// (unset = `Auto` = on; a typo is an error, not a silent fallback).
    pub fn with_env_upload(mut self) -> Result<Runner> {
        self.upload = UploadPolicy::from_env()?;
        Ok(self)
    }

    /// Padded artifact dim for a native dim.
    pub fn padded_dim(&self, native: usize) -> Result<usize> {
        self.engine.manifest().padded_dim(native)
    }

    /// Resolve the effective policy for one run (per-run `plane=` key
    /// beats the process-level policy unless it is `Auto`) and make sure
    /// the pool it needs exists: `plane=sharded` with no pool attaches a
    /// single-worker pool (the full shard machinery on one worker), so
    /// the policy is self-sufficient without `SHARDS`.
    fn resolve_plane(&mut self, cfg_plane: PlanePolicy) -> Result<PlanePolicy> {
        let policy =
            if cfg_plane != PlanePolicy::Auto { cfg_plane } else { self.plane };
        if policy == PlanePolicy::Sharded && self.shards.is_none() {
            let dir = self.engine.manifest().dir.clone();
            self.shards = Some(ShardPool::new(1, &dir)?);
            self.self_pool = true;
        }
        Ok(policy)
    }

    /// Resolve the effective prefetch policy for one run: a per-run
    /// `prefetch=` key beats the process-level policy unless it is
    /// `Auto` — exactly [`Runner::resolve_plane`]'s rule.
    fn resolve_prefetch(&self, cfg_prefetch: PrefetchPolicy) -> PrefetchPolicy {
        if cfg_prefetch != PrefetchPolicy::Auto {
            cfg_prefetch
        } else {
            self.prefetch
        }
    }

    /// Resolve the effective pipeline policy for one run: a per-run
    /// `pipeline=` key beats the process-level policy unless it is
    /// `Auto` — exactly [`Runner::resolve_plane`]'s rule.
    fn resolve_pipeline(&self, cfg_pipeline: PipelinePolicy) -> PipelinePolicy {
        if cfg_pipeline != PipelinePolicy::Auto {
            cfg_pipeline
        } else {
            self.pipeline
        }
    }

    /// Resolve the effective upload-lane policy for one run: a per-run
    /// `upload=` key beats the process-level policy unless it is `Auto`
    /// — exactly [`Runner::resolve_plane`]'s rule.
    fn resolve_upload(&self, cfg_upload: UploadPolicy) -> UploadPolicy {
        if cfg_upload != UploadPolicy::Auto {
            cfg_upload
        } else {
            self.upload
        }
    }

    /// Resolve the effective network model for one run: per-run
    /// `net.alpha` / `net.beta` keys override the runner's model
    /// field-by-field (an absent key keeps the runner's value).
    fn resolve_net_model(&self, cfg: &ExperimentConfig) -> NetModel {
        NetModel {
            alpha: cfg.net_alpha.unwrap_or(self.net_model.alpha),
            beta_bytes_per_s: cfg.net_beta.unwrap_or(self.net_model.beta_bytes_per_s),
        }
    }

    /// Build a context from the config's data axis (the scenario
    /// registry, a named dataset, or the default planted-model stream) +
    /// evaluator, validating the method/scenario setting pairing.
    pub fn context(&mut self, cfg: &ExperimentConfig) -> Result<RunContext<'_>> {
        let family = build_family(cfg)?;
        validate_pairing(&cfg.method, family.as_ref())?;
        let d = self.padded_dim(family.dim())?;
        let loss = family.loss();
        let streams: Vec<Box<dyn SampleStream>> =
            (0..cfg.m).map(|i| family.fork_stream(i as u64)).collect();
        let mut eval_stream = family.fork_stream(EVAL_TAG);
        let eval_samples = eval_stream.draw_many(cfg.eval_samples);
        // faults ride the network, seeded like scenario.* off the run seed;
        // faults=off builds no plan (bitwise identical to no fault layer)
        let faults = cfg.fault_params().map(|p| FaultPlan::new(cfg.seed, cfg.m, p));
        self.build_context(
            cfg.plane,
            cfg.prefetch,
            cfg.pipeline,
            cfg.upload,
            self.resolve_net_model(cfg),
            faults,
            loss,
            d,
            streams,
            &eval_samples,
            cfg.eval_every,
        )
    }

    /// Build a context over caller-supplied per-machine streams and a
    /// held-out evaluation set — the examples/benches/tests entry point.
    /// Plane policy resolves exactly as in [`Runner::context`] (the
    /// process-level policy; no per-run override).
    pub fn context_over(
        &mut self,
        loss: Loss,
        d: usize,
        streams: Vec<Box<dyn SampleStream>>,
        eval_samples: &[Sample],
        eval_every: usize,
    ) -> Result<RunContext<'_>> {
        self.build_context(
            PlanePolicy::Auto,
            PrefetchPolicy::Auto,
            PipelinePolicy::Auto,
            UploadPolicy::Auto,
            self.net_model.clone(),
            None,
            loss,
            d,
            streams,
            eval_samples,
            eval_every,
        )
    }

    #[allow(clippy::too_many_arguments)]
    fn build_context(
        &mut self,
        cfg_plane: PlanePolicy,
        cfg_prefetch: PrefetchPolicy,
        cfg_pipeline: PipelinePolicy,
        cfg_upload: UploadPolicy,
        net_model: NetModel,
        faults: Option<FaultPlan>,
        loss: Loss,
        d: usize,
        streams: Vec<Box<dyn SampleStream>>,
        eval_samples: &[Sample],
        eval_every: usize,
    ) -> Result<RunContext<'_>> {
        let m = streams.len();
        let policy = self.resolve_plane(cfg_plane)?;
        let prefetch = self.resolve_prefetch(cfg_prefetch);
        let pipeline = self.resolve_pipeline(cfg_pipeline);
        let upload = self.resolve_upload(cfg_upload);
        // the coordinator engine's per-run state resets here too: stale
        // session slots from a previous run must not alias into this one,
        // and the cache-meter epoch restarts (one hit/miss per artifact
        // per run). clear_machines does the same for each shard engine —
        // before this fix only the shard side was reset, and a resident
        // Runner leaked coordinator session slots across queued runs.
        self.engine.reset_session();
        // the lane flag is per-run too: the coordinator engine and every
        // shard engine must agree on the resolved policy before any
        // upload of this run happens (clear_machines resets the shard
        // meters, so the broadcast goes after it)
        self.engine.set_upload_lane(upload.enabled());
        if let Some(pool) = &self.shards {
            // stale machine/stream/evaluator state from a previous run
            // must not leak in (the installs below land on cleared shards)
            pool.clear_machines()?;
            pool.set_upload_lane(upload.enabled())?;
        }
        // a self-attached pool serves plane=sharded runs only: for every
        // other policy the runner behaves as if SHARDS were never set
        let pool = if self.self_pool && policy != PlanePolicy::Sharded {
            None
        } else {
            self.shards.as_ref()
        };
        let mut plane = ExecPlane::new(&mut self.engine, pool, policy)?
            .with_prefetch(prefetch)
            .with_pipeline(pipeline)
            .with_upload(upload);
        // DataPlane residency: with a pool on the plane, each machine's
        // stream moves to its owning shard's prefetch lane (next to its
        // batches) and the draw verb generates + packs shard-side — one
        // round ahead of the engine when prefetch is on — from then on
        let streams = if let Some(pool) = plane.shards {
            for (i, s) in streams.into_iter().enumerate() {
                pool.install_stream(i, s)?;
            }
            MachineStreams::Sharded { m }
        } else {
            MachineStreams::Local(streams)
        };
        let evaluator = Some(Evaluator::new(&mut plane, d, loss, eval_samples, m)?);
        Ok(RunContext {
            plane,
            net: Network::new(m, net_model).with_faults(faults),
            meter: ClusterMeter::new(m),
            loss,
            d,
            streams,
            evaluator,
            eval_every,
        })
    }

    /// Build the method named in the config with theory-driven parameters.
    pub fn method(&self, cfg: &ExperimentConfig) -> Result<Box<dyn Method>> {
        build_method(&cfg.method, cfg)
    }

    /// Run one experiment end to end. A `dataset=` run first resolves the
    /// dataset's native loss/dim into the config ([`effective_config`]) so
    /// the theory-driven method plan and the data the context serves
    /// cannot disagree. The result carries this run's executable-cache
    /// delta (`RunResult::cache`): the engines' meters are cumulative for
    /// the runner's lifetime, so the per-run view is a before/after
    /// snapshot — on a resident serve runner, job N+1's delta is isolated
    /// from job N's.
    pub fn run(&mut self, cfg: &ExperimentConfig) -> Result<RunResult> {
        let cfg = effective_config(cfg)?;
        let mut method = self.method(&cfg)?;
        let before = self.cache_meter_total()?;
        let mut ctx = self.context(&cfg)?;
        let mut result = method.run(&mut ctx)?;
        drop(ctx);
        let after = self.cache_meter_total()?;
        result.cache = Some(after.since(&before));
        Ok(result)
    }

    /// Whole-process executable-cache meter: the coordinator engine's
    /// plus every shard engine's, cumulative for their lifetimes. Take
    /// [`CacheMeter::since`] snapshots for per-run deltas.
    pub fn cache_meter_total(&self) -> Result<CacheMeter> {
        let mut total = self.engine.cache_meter().clone();
        if let Some(pool) = &self.shards {
            total.merge(&pool.gathered_cache()?);
        }
        Ok(total)
    }

    /// Cap resident compiled executables on the coordinator engine and
    /// every shard engine (`serve.cache_capacity`).
    pub fn set_exec_cache_capacity(&mut self, cap: usize) -> Result<()> {
        self.engine.set_exec_cache_capacity(cap);
        if let Some(pool) = &self.shards {
            pool.set_exec_cache_capacity(cap)?;
        }
        Ok(())
    }
}

/// Resolve the data axis back into the config: a named dataset imposes
/// its own loss and native dimension (the scenario registry already
/// takes both from the config, so only `dataset=` needs this). Without
/// it, `dataset=codrna method=mp-dsvrg` would build squared-loss theory
/// plans (the `loss=` default) while the context serves logistic data.
pub fn effective_config(cfg: &ExperimentConfig) -> Result<ExperimentConfig> {
    match &cfg.dataset {
        Some(name) => {
            let spec = DatasetSpec::by_name(name)
                .ok_or_else(|| anyhow!("unknown dataset '{name}'"))?;
            Ok(ExperimentConfig { loss: spec.loss, dim: spec.dim, ..cfg.clone() })
        }
        None => Ok(cfg.clone()),
    }
}

/// Stream-split tag reserved for the held-out evaluation stream.
const EVAL_TAG: u64 = 0xE7A1;

/// Resolve the config's data axis into a stream family: the `scenario=`
/// registry (did-you-mean rejection on unknown names), a named Table-3
/// dataset, or the default planted-model stream. `scenario=` and
/// `dataset=` are mutually exclusive — the dataset specs predate the
/// registry and remain the Figure-3 protocol's entry point.
pub fn build_family(cfg: &ExperimentConfig) -> Result<Box<dyn StreamFamily>> {
    match (&cfg.scenario, &cfg.dataset) {
        (Some(_), Some(_)) => {
            bail!("scenario= and dataset= are mutually exclusive (pick one data axis)")
        }
        (Some(name), None) => {
            let params = ScenarioParams {
                dim: cfg.dim,
                loss: cfg.loss,
                seed: cfg.seed,
                m: cfg.m,
                n_budget: cfg.n_budget,
                data_path: cfg.data_path.clone(),
                drift_omega: cfg.drift_omega,
                pareto_alpha: cfg.pareto_alpha,
                sparse_density: cfg.sparse_density,
            };
            scenario::by_name(name)?.build(&params)
        }
        (None, Some(name)) => {
            let spec = DatasetSpec::by_name(name)
                .ok_or_else(|| anyhow!("unknown dataset '{name}'"))?;
            Ok(Box::new(spec.stream(cfg.seed)))
        }
        (None, None) => {
            let spec = match cfg.loss {
                Loss::Squared => SynthSpec::least_squares(cfg.dim),
                Loss::Logistic => SynthSpec::logistic(cfg.dim),
            };
            Ok(Box::new(SynthStream::new(spec, cfg.seed)))
        }
    }
}

/// Per-method declared optimization setting — one row per registered
/// method (the tests pin that this table and [`METHODS`] agree exactly,
/// so a new method cannot be registered without declaring its setting).
/// Streaming methods require fresh i.i.d. draws; the ERM baselines
/// materialize a fixed set up front and accept either setting (a stream
/// can always feed a finite draw).
pub const METHOD_SETTINGS: [(&str, Setting); 12] = [
    ("mp-dsvrg", Setting::StreamingSo),
    ("mp-dane", Setting::StreamingSo),
    ("mp-dane-saga", Setting::StreamingSo),
    ("mp-exact", Setting::StreamingSo),
    ("mp-oneshot", Setting::StreamingSo),
    ("minibatch-sgd", Setting::StreamingSo),
    ("acc-minibatch-sgd", Setting::StreamingSo),
    ("local-sgd", Setting::StreamingSo),
    ("dsvrg-erm", Setting::FiniteErm),
    ("dane-erm", Setting::FiniteErm),
    ("agd-erm", Setting::FiniteErm),
    ("disco-erm", Setting::FiniteErm),
];

/// Look a method's setting up in [`METHOD_SETTINGS`]. Unlisted names
/// (the `emso`/`ideal` aliases) default to streaming — the stricter of
/// the two pairings.
pub fn method_setting(name: &str) -> Setting {
    METHOD_SETTINGS
        .iter()
        .find(|(n, _)| *n == name)
        .map(|&(_, s)| s)
        .unwrap_or(Setting::StreamingSo)
}

/// Reject method/scenario pairings the paper's accounting cannot honor:
/// a streaming-SO method on a finite-ERM scenario would recycle a fixed
/// sample set while charging it as fresh population draws.
fn validate_pairing(method: &str, family: &dyn StreamFamily) -> Result<()> {
    if method_setting(method) == Setting::StreamingSo && family.setting() == Setting::FiniteErm {
        bail!(
            "method '{method}' is streaming-SO (fresh i.i.d. draws every round) but the \
             scenario is {}: pick an ERM method (dsvrg-erm | dane-erm | agd-erm | disco-erm) \
             or a streaming scenario",
            family.setting().as_str()
        );
    }
    Ok(())
}

/// Construct a method by name using the theory plans (DESIGN.md §6).
pub fn build_method(name: &str, cfg: &ExperimentConfig) -> Result<Box<dyn Method>> {
    let c = problem_consts(cfg);
    let n = cfg.n_budget as f64;
    let plan = theory::mbprox_plan(&c, n, cfg.b_local);
    Ok(match name {
        "mp-dsvrg" => {
            let ds = theory::dsvrg_plan(&c, &plan, cfg.b_local, n);
            Box::new(MinibatchProx::new(
                "mp-dsvrg",
                cfg.b_local,
                plan.t_outer,
                plan.gamma,
                DsvrgSolver::new(ds.k_inner, ds.p_batches, ds.eta),
            ))
        }
        "mp-dane" => {
            let dp = theory::dane_plan(&c, &plan, cfg.b_local, n, cfg.dim);
            let eta = 0.1 / (c.beta_smooth + plan.gamma + dp.kappa);
            let solver = if dp.kappa > 0.0 && dp.r_outer > 1 {
                DaneSolver::aide(dp.k_inner, dp.r_outer, dp.kappa, eta)
            } else {
                DaneSolver::plain(dp.k_inner, eta)
            };
            Box::new(MinibatchProx::new(
                "mp-dane",
                cfg.b_local,
                plan.t_outer,
                plan.gamma,
                solver,
            ))
        }
        "mp-dane-saga" => {
            // the paper's Appendix-E configuration: SAGA local solves,
            // R=1, kappa=0, one local pass per DANE round
            let dp = theory::dane_plan(&c, &plan, cfg.b_local, n, cfg.dim);
            let eta = 0.1 / (c.beta_smooth + plan.gamma);
            Box::new(MinibatchProx::new(
                "mp-dane-saga",
                cfg.b_local,
                plan.t_outer,
                plan.gamma,
                DaneSolver::plain(dp.k_inner, eta).with_local_solver(LocalSolver::Saga),
            ))
        }
        "mp-exact" => Box::new(MinibatchProx::new(
            "mp-exact",
            cfg.b_local,
            plan.t_outer,
            plan.gamma,
            ExactCgSolver::default(),
        )),
        "mp-oneshot" | "emso" => {
            let eta = 0.1 / (c.beta_smooth + plan.gamma);
            Box::new(MinibatchProx::new(
                "mp-oneshot",
                cfg.b_local,
                plan.t_outer,
                plan.gamma,
                OneShotSolver::new(2, eta),
            ))
        }
        "minibatch-sgd" => {
            let gamma = theory::minibatch_sgd_gamma(&c, plan.t_outer, plan.bm);
            Box::new(MinibatchSgd { b_local: cfg.b_local, t_outer: plan.t_outer, gamma })
        }
        "acc-minibatch-sgd" => {
            let gamma = theory::minibatch_sgd_gamma(&c, plan.t_outer, plan.bm);
            Box::new(AccelMinibatchSgd { b_local: cfg.b_local, t_outer: plan.t_outer, gamma })
        }
        "local-sgd" | "ideal" => {
            let chunk = 256usize;
            let steps = cfg.n_budget.div_ceil(chunk);
            let gamma = theory::minibatch_sgd_gamma(
                &ProblemConsts { m: 1, ..c },
                steps,
                chunk,
            );
            Box::new(LocalSgd { n_total: cfg.n_budget, gamma, chunk })
        }
        "dsvrg-erm" => {
            let nu = theory::erm_nu(&c, n);
            Box::new(DsvrgErm {
                n_total: cfg.n_budget,
                nu,
                epochs: (n.ln().ceil() as usize).max(4),
                eta: 0.1 / (c.beta_smooth + nu),
            })
        }
        "dane-erm" => {
            let nu = theory::erm_nu(&c, n);
            Box::new(DaneErm {
                n_total: cfg.n_budget,
                nu,
                rounds: (n.ln().ceil() as usize).max(4),
                local_passes: 1,
                eta: 0.1 / (c.beta_smooth + nu),
            })
        }
        "agd-erm" => {
            let nu = theory::erm_nu(&c, n);
            // Nesterov iteration count ~ sqrt(kappa) log(1/eps) ~ B^0.5 n^0.25
            let rounds = ((c.beta_smooth / nu).sqrt() * n.ln()).ceil().min(2000.0) as usize;
            Box::new(DistributedAgd { n_total: cfg.n_budget, nu, beta: c.beta_smooth, rounds })
        }
        "disco-erm" => {
            let nu = theory::erm_nu(&c, n);
            Box::new(Disco {
                n_total: cfg.n_budget,
                nu,
                newton_iters: 4,
                cg_tol: 1e-8,
                cg_max: 256,
            })
        }
        other => return Err(anyhow!("unknown method '{other}' (see coordinator::METHODS)")),
    })
}

/// All method names `build_method` accepts.
pub const METHODS: [&str; 12] = [
    "mp-dsvrg",
    "mp-dane",
    "mp-dane-saga",
    "mp-exact",
    "mp-oneshot",
    "minibatch-sgd",
    "acc-minibatch-sgd",
    "local-sgd",
    "dsvrg-erm",
    "dane-erm",
    "agd-erm",
    "disco-erm",
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builds_every_registered_method() {
        let cfg = ExperimentConfig::default();
        for name in METHODS {
            let m = build_method(name, &cfg).unwrap();
            assert!(!m.name().is_empty());
        }
        assert!(build_method("nope", &cfg).is_err());
    }

    #[test]
    fn family_axis_resolves_and_validates() {
        // default: planted synth, streaming
        let cfg = ExperimentConfig::default();
        let fam = build_family(&cfg).unwrap();
        assert_eq!(fam.setting(), Setting::StreamingSo);
        assert_eq!(fam.dim(), cfg.dim);
        // registry scenarios resolve by name; typos get a suggestion
        let cfg_drift =
            ExperimentConfig { scenario: Some("drift".into()), ..ExperimentConfig::default() };
        assert_eq!(build_family(&cfg_drift).unwrap().setting(), Setting::StreamingSo);
        let cfg_typo =
            ExperimentConfig { scenario: Some("drfit".into()), ..ExperimentConfig::default() };
        let err = build_family(&cfg_typo).unwrap_err().to_string();
        assert!(err.contains("did you mean 'drift'"), "{err}");
        // scenario and dataset are mutually exclusive
        let cfg_both = ExperimentConfig {
            scenario: Some("drift".into()),
            dataset: Some("year".into()),
            ..ExperimentConfig::default()
        };
        assert!(build_family(&cfg_both).is_err());
        // the pairing guard: streaming methods reject finite-ERM families
        let cfg_erm =
            ExperimentConfig { scenario: Some("erm-fixed".into()), ..ExperimentConfig::default() };
        let fam = build_family(&cfg_erm).unwrap();
        assert!(validate_pairing("mp-dsvrg", fam.as_ref()).is_err());
        assert!(validate_pairing("minibatch-sgd", fam.as_ref()).is_err());
        assert!(validate_pairing("dsvrg-erm", fam.as_ref()).is_ok());
        // ERM methods also run on streaming families (they draw n up front)
        let fam = build_family(&ExperimentConfig::default()).unwrap();
        assert!(validate_pairing("dane-erm", fam.as_ref()).is_ok());
    }

    #[test]
    fn effective_config_resolves_dataset_loss_and_dim() {
        // the theory plan must see the dataset's native loss/dim, not the
        // `loss=`/`dim=` defaults
        let cfg =
            ExperimentConfig { dataset: Some("codrna".into()), ..ExperimentConfig::default() };
        let eff = effective_config(&cfg).unwrap();
        assert_eq!(eff.loss, Loss::Logistic);
        assert_eq!(eff.dim, 8);
        // non-dataset configs pass through untouched
        let eff = effective_config(&ExperimentConfig::default()).unwrap();
        assert_eq!(eff.loss, Loss::Squared);
        let bad = ExperimentConfig { dataset: Some("nope".into()), ..ExperimentConfig::default() };
        assert!(effective_config(&bad).is_err());
    }

    #[test]
    fn method_settings_cover_the_registry() {
        // every registered method must have a declared settings row (a
        // new METHODS entry without one fails here, not silently at
        // validate_pairing time) — and no stale rows either
        for m in METHODS {
            assert!(
                METHOD_SETTINGS.iter().any(|(n, _)| *n == m),
                "method '{m}' missing from METHOD_SETTINGS"
            );
        }
        assert_eq!(METHOD_SETTINGS.len(), METHODS.len());
        assert_eq!(method_setting("mp-dsvrg"), Setting::StreamingSo);
        assert_eq!(method_setting("disco-erm"), Setting::FiniteErm);
        // aliases default to the stricter streaming classification
        assert_eq!(method_setting("emso"), Setting::StreamingSo);
    }

    #[test]
    fn theory_params_flow_into_names() {
        let cfg =
            ExperimentConfig { b_local: 128, n_budget: 65_536, ..ExperimentConfig::default() };
        let m = build_method("mp-dsvrg", &cfg).unwrap();
        // T = n/(b m) = 65536/(128*4) = 128
        assert!(m.name().contains("T=128"), "{}", m.name());
    }
}
