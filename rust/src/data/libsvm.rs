//! libsvm text format writer + parsers: whole-file reads and the chunked
//! out-of-core [`LibsvmChunkStream`].
//!
//! Format: one sample per line, `label idx:val idx:val ...` with 1-based
//! indices and omitted zeros. The end-to-end driver generates the
//! Table-3-like datasets, writes them through this writer, and re-parses
//! them — exercising a real data-loading path (the paper's experiments
//! load libsvm files). The chunk stream backs the `libsvm` scenario in
//! the registry (`data::scenario`): machines stream disjoint strided
//! shards of the file without ever materializing it.

use super::{Loss, Sample, SampleStream};
use std::collections::VecDeque;
use std::fs::File;
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::path::{Path, PathBuf};

pub fn write_samples<P: AsRef<Path>>(path: P, samples: &[Sample]) -> std::io::Result<()> {
    let f = std::fs::File::create(path)?;
    let mut w = BufWriter::new(f);
    for s in samples {
        write_sample_line(&mut w, s)?;
    }
    Ok(())
}

fn write_sample_line<W: Write>(w: &mut W, s: &Sample) -> std::io::Result<()> {
    // labels are written compactly: integers as integers
    if s.y == s.y.trunc() && s.y.abs() < 1e7 {
        write!(w, "{}", s.y as i64)?;
    } else {
        write!(w, "{}", s.y)?;
    }
    for (j, &v) in s.x.iter().enumerate() {
        if v != 0.0 {
            write!(w, " {}:{}", j + 1, v)?;
        }
    }
    writeln!(w)
}

/// Parse a libsvm file. `dim` fixes the feature dimension (indices beyond
/// it are an error); lines that are empty or start with '#' are skipped.
pub fn read_samples<P: AsRef<Path>>(path: P, dim: usize) -> std::io::Result<Vec<Sample>> {
    let f = std::fs::File::open(path)?;
    let reader = std::io::BufReader::new(f);
    let mut out = Vec::new();
    for (lineno, line) in reader.lines().enumerate() {
        let line = line?;
        match parse_line(&line, dim) {
            Ok(Some(s)) => out.push(s),
            Ok(None) => {}
            Err(e) => {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::InvalidData,
                    format!("line {}: {}", lineno + 1, e),
                ))
            }
        }
    }
    Ok(out)
}

pub fn parse_line(line: &str, dim: usize) -> Result<Option<Sample>, String> {
    let line = line.trim();
    if line.is_empty() || line.starts_with('#') {
        return Ok(None);
    }
    let mut parts = line.split_whitespace();
    let label_tok = parts.next().ok_or("missing label")?;
    let y: f32 = label_tok.parse().map_err(|_| format!("bad label '{label_tok}'"))?;
    let mut x = vec![0.0f32; dim];
    for tok in parts {
        let (idx_s, val_s) = tok.split_once(':').ok_or_else(|| format!("bad pair '{tok}'"))?;
        let idx: usize = idx_s.parse().map_err(|_| format!("bad index '{idx_s}'"))?;
        if idx == 0 || idx > dim {
            return Err(format!("index {idx} out of range 1..={dim}"));
        }
        let val: f32 = val_s.parse().map_err(|_| format!("bad value '{val_s}'"))?;
        x[idx - 1] = val;
    }
    Ok(Some(Sample { x, y }))
}

/// Count the data samples in a libsvm file without materializing them
/// (one streaming pass; comments/blank lines are skipped). Validates
/// every line parses within `dim` — a malformed file fails at scenario
/// build time, not mid-run.
pub fn count_samples<P: AsRef<Path>>(path: P, dim: usize) -> std::io::Result<usize> {
    let reader = BufReader::new(File::open(path)?);
    let mut n = 0usize;
    for (lineno, line) in reader.lines().enumerate() {
        match parse_line(&line?, dim) {
            Ok(Some(_)) => n += 1,
            Ok(None) => {}
            Err(e) => {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::InvalidData,
                    format!("line {}: {}", lineno + 1, e),
                ))
            }
        }
    }
    Ok(n)
}

/// Chunked, strided, out-of-core libsvm stream: serves the samples whose
/// data-line index satisfies `idx % stride == offset`, parsing `chunk`
/// samples ahead at a time — the file is never materialized. `draw()`
/// reopens the file at EOF (epochs in file order, trivially without
/// replacement); `draw_many` never crosses the epoch boundary, so the
/// final batch of an epoch may run SHORT and callers charge what was
/// actually drawn. `Send` by construction (plain file handle + buffers),
/// so a machine's shard of the file streams on its owning shard.
pub struct LibsvmChunkStream {
    path: PathBuf,
    dim: usize,
    loss: Loss,
    stride: usize,
    offset: usize,
    chunk: usize,
    reader: Option<BufReader<File>>,
    /// index of the next data line (comments/blanks excluded)
    line_idx: usize,
    buf: VecDeque<Sample>,
    /// EOF reached; set back to false when the next epoch opens
    at_eof: bool,
}

impl LibsvmChunkStream {
    /// `stride`/`offset` select every stride-th data line starting at
    /// `offset` (machine sharding); `stride = 1, offset = 0` streams the
    /// whole file. `chunk` is the read-ahead depth in samples.
    pub fn open(
        path: impl Into<PathBuf>,
        dim: usize,
        loss: Loss,
        stride: usize,
        offset: usize,
        chunk: usize,
    ) -> std::io::Result<LibsvmChunkStream> {
        assert!(stride >= 1 && offset < stride, "offset must lie below stride");
        let path = path.into();
        File::open(&path)?; // fail at construction, not first draw
        Ok(LibsvmChunkStream {
            path,
            dim,
            loss,
            stride,
            offset,
            chunk: chunk.max(1),
            reader: None,
            line_idx: 0,
            buf: VecDeque::new(),
            at_eof: false,
        })
    }

    /// Read ahead until `chunk` samples are buffered or EOF; opens the
    /// file (a fresh epoch) when no reader is live.
    fn refill(&mut self) {
        if self.reader.is_none() {
            let f = File::open(&self.path)
                .unwrap_or_else(|e| panic!("libsvm reopen {}: {e}", self.path.display()));
            self.reader = Some(BufReader::new(f));
            self.line_idx = 0;
            self.at_eof = false;
        }
        let reader = self.reader.as_mut().expect("just opened");
        let mut line = String::new();
        while self.buf.len() < self.chunk {
            line.clear();
            let n = reader
                .read_line(&mut line)
                .unwrap_or_else(|e| panic!("libsvm read {}: {e}", self.path.display()));
            if n == 0 {
                self.reader = None;
                self.at_eof = true;
                return;
            }
            // cheap data-line test first: lines outside this shard's
            // stride are skipped WITHOUT parsing (m strided shards must
            // not cost m full-file parses per epoch); the scenario
            // builder's counting pass already validated every line
            let t = line.trim();
            if t.is_empty() || t.starts_with('#') {
                continue;
            }
            if self.line_idx % self.stride == self.offset {
                match parse_line(t, self.dim) {
                    Ok(Some(s)) => self.buf.push_back(s),
                    Ok(None) => {}
                    Err(e) => panic!("libsvm parse {}: {e}", self.path.display()),
                }
            }
            self.line_idx += 1;
        }
    }
}

impl SampleStream for LibsvmChunkStream {
    fn dim(&self) -> usize {
        self.dim
    }

    fn loss(&self) -> Loss {
        self.loss
    }

    fn draw(&mut self) -> Sample {
        // single draws roll across epochs (reopening at EOF); an empty
        // strided shard would loop forever, so fail loudly after one
        // sample-free pass
        for _ in 0..2 {
            if let Some(s) = self.buf.pop_front() {
                return s;
            }
            self.refill();
        }
        self.buf.pop_front().unwrap_or_else(|| {
            panic!(
                "libsvm shard {}%{} of {} holds no samples",
                self.offset,
                self.stride,
                self.path.display()
            )
        })
    }

    fn draw_many(&mut self, n: usize) -> Vec<Sample> {
        // a call that begins exactly at the epoch boundary starts a new
        // epoch; within a call, the boundary ends the batch (short batch)
        if self.buf.is_empty() && self.at_eof {
            self.at_eof = false;
        }
        let mut out = Vec::with_capacity(n);
        while out.len() < n {
            if self.buf.is_empty() {
                if self.at_eof {
                    break;
                }
                self.refill();
                if self.buf.is_empty() && self.at_eof {
                    break;
                }
            }
            out.push(self.buf.pop_front().expect("non-empty buffer"));
        }
        out
    }

    fn draws_decompose(&self) -> bool {
        // draw_many bounds epochs per call (single draws roll across
        // them), so a read-ahead cannot be re-split bit-identically
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{SynthSpec, SynthStream};
    use crate::data::SampleStream;
    use crate::util::testkit::assert_close;

    #[test]
    fn parse_basic_line() {
        let s = parse_line("1 1:0.5 3:-2", 4).unwrap().unwrap();
        assert_eq!(s.y, 1.0);
        assert_eq!(s.x, vec![0.5, 0.0, -2.0, 0.0]);
    }

    #[test]
    fn skips_comments_and_blanks() {
        assert!(parse_line("# comment", 4).unwrap().is_none());
        assert!(parse_line("   ", 4).unwrap().is_none());
    }

    #[test]
    fn rejects_bad_lines() {
        assert!(parse_line("1 5:1", 4).is_err()); // out of range
        assert!(parse_line("1 0:1", 4).is_err()); // 1-based
        assert!(parse_line("x 1:1", 4).is_err()); // bad label
        assert!(parse_line("1 1-1", 4).is_err()); // bad pair
    }

    #[test]
    fn round_trip_through_file() {
        let mut stream = SynthStream::new(SynthSpec::least_squares(12), 9);
        let samples = stream.draw_many(50);
        let dir = std::env::temp_dir().join("mbprox_libsvm_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("round_trip.libsvm");
        write_samples(&path, &samples).unwrap();
        let back = read_samples(&path, 12).unwrap();
        assert_eq!(back.len(), samples.len());
        for (a, b) in samples.iter().zip(&back) {
            assert!((a.y - b.y).abs() < 1e-4);
            assert_close(&a.x, &b.x, 1e-4, 1e-5);
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn chunked_stream_strides_and_bounds_epochs() {
        let mut stream = SynthStream::new(SynthSpec::least_squares(6), 21);
        let samples = stream.draw_many(11);
        let dir = std::env::temp_dir().join("mbprox_libsvm_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("chunked.libsvm");
        write_samples(&path, &samples).unwrap();
        assert_eq!(count_samples(&path, 6).unwrap(), 11);

        // stride 3, offset 1 -> data lines 1,4,7,10 (4 samples per epoch)
        let mut s =
            LibsvmChunkStream::open(&path, 6, crate::data::Loss::Squared, 3, 1, 2).unwrap();
        let b1 = s.draw_many(3);
        let b2 = s.draw_many(3);
        assert_eq!(b1.len(), 3);
        assert_eq!(b2.len(), 1, "epoch boundary yields a short batch");
        for (got, want) in b1.iter().chain(&b2).zip([1usize, 4, 7, 10]) {
            assert!((got.y - samples[want].y).abs() < 1e-4, "file order per epoch");
        }
        // next call starts epoch 2 at the top of the shard
        let b3 = s.draw_many(2);
        assert_eq!(b3.len(), 2);
        assert!((b3[0].y - samples[1].y).abs() < 1e-4);

        // single draws roll across epochs without shortening
        let mut r = LibsvmChunkStream::open(&path, 6, crate::data::Loss::Squared, 1, 0, 4).unwrap();
        for k in 0..23 {
            let got = r.draw();
            assert!((got.y - samples[k % 11].y).abs() < 1e-4, "draw {k}");
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn sparse_zeros_are_omitted_and_restored() {
        let s = Sample { x: vec![0.0, 1.5, 0.0, 0.0], y: -1.0 };
        let dir = std::env::temp_dir().join("mbprox_libsvm_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("sparse.libsvm");
        write_samples(&path, std::slice::from_ref(&s)).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.trim(), "-1 2:1.5");
        let back = read_samples(&path, 4).unwrap();
        assert_eq!(back[0], s);
        std::fs::remove_file(&path).ok();
    }
}
