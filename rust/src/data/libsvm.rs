//! libsvm text format writer + parser.
//!
//! Format: one sample per line, `label idx:val idx:val ...` with 1-based
//! indices and omitted zeros. The end-to-end driver generates the
//! Table-3-like datasets, writes them through this writer, and re-parses
//! them — exercising a real data-loading path (the paper's experiments
//! load libsvm files).

use super::Sample;
use std::io::{BufRead, BufWriter, Write};
use std::path::Path;

pub fn write_samples<P: AsRef<Path>>(path: P, samples: &[Sample]) -> std::io::Result<()> {
    let f = std::fs::File::create(path)?;
    let mut w = BufWriter::new(f);
    for s in samples {
        write_sample_line(&mut w, s)?;
    }
    Ok(())
}

fn write_sample_line<W: Write>(w: &mut W, s: &Sample) -> std::io::Result<()> {
    // labels are written compactly: integers as integers
    if s.y == s.y.trunc() && s.y.abs() < 1e7 {
        write!(w, "{}", s.y as i64)?;
    } else {
        write!(w, "{}", s.y)?;
    }
    for (j, &v) in s.x.iter().enumerate() {
        if v != 0.0 {
            write!(w, " {}:{}", j + 1, v)?;
        }
    }
    writeln!(w)
}

/// Parse a libsvm file. `dim` fixes the feature dimension (indices beyond
/// it are an error); lines that are empty or start with '#' are skipped.
pub fn read_samples<P: AsRef<Path>>(path: P, dim: usize) -> std::io::Result<Vec<Sample>> {
    let f = std::fs::File::open(path)?;
    let reader = std::io::BufReader::new(f);
    let mut out = Vec::new();
    for (lineno, line) in reader.lines().enumerate() {
        let line = line?;
        match parse_line(&line, dim) {
            Ok(Some(s)) => out.push(s),
            Ok(None) => {}
            Err(e) => {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::InvalidData,
                    format!("line {}: {}", lineno + 1, e),
                ))
            }
        }
    }
    Ok(out)
}

pub fn parse_line(line: &str, dim: usize) -> Result<Option<Sample>, String> {
    let line = line.trim();
    if line.is_empty() || line.starts_with('#') {
        return Ok(None);
    }
    let mut parts = line.split_whitespace();
    let label_tok = parts.next().ok_or("missing label")?;
    let y: f32 = label_tok.parse().map_err(|_| format!("bad label '{label_tok}'"))?;
    let mut x = vec![0.0f32; dim];
    for tok in parts {
        let (idx_s, val_s) = tok.split_once(':').ok_or_else(|| format!("bad pair '{tok}'"))?;
        let idx: usize = idx_s.parse().map_err(|_| format!("bad index '{idx_s}'"))?;
        if idx == 0 || idx > dim {
            return Err(format!("index {idx} out of range 1..={dim}"));
        }
        let val: f32 = val_s.parse().map_err(|_| format!("bad value '{val_s}'"))?;
        x[idx - 1] = val;
    }
    Ok(Some(Sample { x, y }))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{SynthSpec, SynthStream};
    use crate::data::SampleStream;
    use crate::util::testkit::assert_close;

    #[test]
    fn parse_basic_line() {
        let s = parse_line("1 1:0.5 3:-2", 4).unwrap().unwrap();
        assert_eq!(s.y, 1.0);
        assert_eq!(s.x, vec![0.5, 0.0, -2.0, 0.0]);
    }

    #[test]
    fn skips_comments_and_blanks() {
        assert!(parse_line("# comment", 4).unwrap().is_none());
        assert!(parse_line("   ", 4).unwrap().is_none());
    }

    #[test]
    fn rejects_bad_lines() {
        assert!(parse_line("1 5:1", 4).is_err()); // out of range
        assert!(parse_line("1 0:1", 4).is_err()); // 1-based
        assert!(parse_line("x 1:1", 4).is_err()); // bad label
        assert!(parse_line("1 1-1", 4).is_err()); // bad pair
    }

    #[test]
    fn round_trip_through_file() {
        let mut stream = SynthStream::new(SynthSpec::least_squares(12), 9);
        let samples = stream.draw_many(50);
        let dir = std::env::temp_dir().join("mbprox_libsvm_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("round_trip.libsvm");
        write_samples(&path, &samples).unwrap();
        let back = read_samples(&path, 12).unwrap();
        assert_eq!(back.len(), samples.len());
        for (a, b) in samples.iter().zip(&back) {
            assert!((a.y - b.y).abs() < 1e-4);
            assert_close(&a.x, &b.x, 1e-4, 1e-5);
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn sparse_zeros_are_omitted_and_restored() {
        let s = Sample { x: vec![0.0, 1.5, 0.0, 0.0], y: -1.0 };
        let dir = std::env::temp_dir().join("mbprox_libsvm_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("sparse.libsvm");
        write_samples(&path, std::slice::from_ref(&s)).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.trim(), "-1 2:1.5");
        let back = read_samples(&path, 4).unwrap();
        assert_eq!(back[0], s);
        std::fs::remove_file(&path).ok();
    }
}
