//! Samplers: the streaming "button" and without-replacement epochs.
//!
//! Algorithm 1 step 2 requires processing a local batch *without
//! replacement* (the Shamir 2016 analysis DSVRG relies on);
//! `WithoutReplacement` provides permutation epochs over a materialized
//! slice. `Reservoir`-style streaming is not needed — machines either
//! stream (minibatch methods) or hold a fixed shard (ERM methods).

use super::Sample;
use crate::util::prng::Prng;

/// Permutation epochs over `n` indices: `next()` yields each index exactly
/// once per epoch, reshuffling between epochs.
pub struct WithoutReplacement {
    perm: Vec<usize>,
    pos: usize,
    rng: Prng,
}

impl WithoutReplacement {
    pub fn new(n: usize, rng: Prng) -> Self {
        let mut s = Self { perm: (0..n).collect(), pos: 0, rng };
        s.reshuffle();
        s
    }

    fn reshuffle(&mut self) {
        self.rng.shuffle(&mut self.perm);
        self.pos = 0;
    }

    pub fn len(&self) -> usize {
        self.perm.len()
    }

    pub fn is_empty(&self) -> bool {
        self.perm.is_empty()
    }

    /// Next index; starts a fresh permutation when the epoch ends.
    pub fn next_index(&mut self) -> usize {
        if self.pos >= self.perm.len() {
            self.reshuffle();
        }
        let i = self.perm[self.pos];
        self.pos += 1;
        i
    }

    /// Draw `k` indices, SPILLING into a fresh epoch when fewer than `k`
    /// remain: the batch is always full-length, but its tail samples the
    /// next permutation (so a sample can repeat within the batch). This
    /// is the recycling protocol of the Figure-3 driver. For honest
    /// finite-sample batches use [`WithoutReplacement::next_batch_in_epoch`].
    pub fn next_batch(&mut self, k: usize) -> Vec<usize> {
        (0..k).map(|_| self.next_index()).collect()
    }

    /// Draw up to `k` indices strictly within the current epoch — the
    /// final batch of an epoch may be SHORT, and callers must charge what
    /// was actually drawn. A call that begins exactly at the boundary
    /// starts a fresh permutation (a batch never straddles two epochs).
    pub fn next_batch_in_epoch(&mut self, k: usize) -> Vec<usize> {
        if self.pos >= self.perm.len() {
            self.reshuffle();
        }
        let take = k.min(self.perm.len() - self.pos);
        let out = self.perm[self.pos..self.pos + take].to_vec();
        self.pos += take;
        out
    }

    /// Remaining indices in the current epoch.
    pub fn remaining_in_epoch(&self) -> usize {
        self.perm.len() - self.pos
    }
}

/// A materialized dataset exposed as a `SampleStream` via permutation
/// epochs. Two explicit epoch-boundary policies:
///
/// - [`VecStream::new`] — *recycling*: `draw_many` always returns the
///   requested count, spilling into a fresh permutation mid-batch (the
///   Figure-3 protocol: minibatches drawn from a fixed training half).
/// - [`VecStream::epoch_bounded`] — *honest finite batches*: `draw_many`
///   never crosses an epoch boundary, so the final batch of an epoch runs
///   short and the caller charges only what was drawn. This is what the
///   finite-ERM scenarios serve.
pub struct VecStream {
    samples: Vec<super::Sample>,
    order: WithoutReplacement,
    loss: super::Loss,
    epoch_bounded: bool,
}

impl VecStream {
    pub fn new(samples: Vec<super::Sample>, loss: super::Loss, rng: Prng) -> Self {
        assert!(!samples.is_empty(), "VecStream needs at least one sample");
        let order = WithoutReplacement::new(samples.len(), rng);
        Self { samples, order, loss, epoch_bounded: false }
    }

    /// The epoch-bounded variant: `draw_many` may return a short final
    /// batch at the epoch boundary instead of spilling into the next
    /// permutation.
    pub fn epoch_bounded(samples: Vec<super::Sample>, loss: super::Loss, rng: Prng) -> Self {
        let mut s = Self::new(samples, loss, rng);
        s.epoch_bounded = true;
        s
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }
}

impl super::SampleStream for VecStream {
    fn dim(&self) -> usize {
        self.samples[0].x.len()
    }

    fn loss(&self) -> super::Loss {
        self.loss
    }

    fn draw(&mut self) -> super::Sample {
        self.samples[self.order.next_index()].clone()
    }

    fn draw_many(&mut self, n: usize) -> Vec<super::Sample> {
        let idx = if self.epoch_bounded {
            self.order.next_batch_in_epoch(n)
        } else {
            self.order.next_batch(n)
        };
        idx.into_iter().map(|i| self.samples[i].clone()).collect()
    }

    fn draws_decompose(&self) -> bool {
        // the recycling variant is a plain sequence of single draws; the
        // epoch-bounded one decides boundaries per call and cannot be
        // re-split by the prefetch lane
        !self.epoch_bounded
    }
}

/// Split a materialized dataset into `m` contiguous shards (machine i gets
/// shard i). Sizes differ by at most one.
pub fn shard_ranges(n: usize, m: usize) -> Vec<std::ops::Range<usize>> {
    assert!(m > 0);
    let base = n / m;
    let extra = n % m;
    let mut out = Vec::with_capacity(m);
    let mut start = 0;
    for i in 0..m {
        let len = base + usize::from(i < extra);
        out.push(start..start + len);
        start += len;
    }
    out
}

/// View of a machine's shard.
pub fn shard<'a>(samples: &'a [Sample], ranges: &[std::ops::Range<usize>], i: usize) -> &'a [Sample] {
    &samples[ranges[i].clone()]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::testkit::forall;

    #[test]
    fn epoch_is_permutation() {
        let mut s = WithoutReplacement::new(13, Prng::seed_from_u64(1));
        let mut seen = vec![false; 13];
        for _ in 0..13 {
            let i = s.next_index();
            assert!(!seen[i], "index {i} repeated within epoch");
            seen[i] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn epochs_reshuffle() {
        let mut s = WithoutReplacement::new(32, Prng::seed_from_u64(2));
        let e1: Vec<usize> = (0..32).map(|_| s.next_index()).collect();
        let e2: Vec<usize> = (0..32).map(|_| s.next_index()).collect();
        assert_ne!(e1, e2);
        let mut e2s = e2.clone();
        e2s.sort_unstable();
        assert_eq!(e2s, (0..32).collect::<Vec<_>>());
    }

    #[test]
    fn prop_epoch_permutation_any_n() {
        forall(24, |rng| {
            let n = 1 + rng.next_below(100);
            let mut s = WithoutReplacement::new(n, Prng::seed_from_u64(rng.next_u64()));
            let mut seen = vec![false; n];
            for _ in 0..n {
                let i = s.next_index();
                assert!(!seen[i]);
                seen[i] = true;
            }
        });
    }

    #[test]
    fn prop_shards_partition() {
        forall(32, |rng| {
            let n = rng.next_below(1000);
            let m = 1 + rng.next_below(16);
            let ranges = shard_ranges(n, m);
            assert_eq!(ranges.len(), m);
            let total: usize = ranges.iter().map(|r| r.len()).sum();
            assert_eq!(total, n);
            // contiguous & ordered
            let mut expect_start = 0;
            for r in &ranges {
                assert_eq!(r.start, expect_start);
                expect_start = r.end;
            }
            // balanced
            let (min, max) = ranges
                .iter()
                .fold((usize::MAX, 0), |(lo, hi), r| (lo.min(r.len()), hi.max(r.len())));
            assert!(max - min <= 1);
        });
    }

    #[test]
    fn vec_stream_draws_epoch_permutations() {
        use crate::data::{Loss, Sample, SampleStream};
        let samples: Vec<Sample> =
            (0..5).map(|i| Sample { x: vec![i as f32], y: i as f32 }).collect();
        let mut vs = VecStream::new(samples, Loss::Squared, Prng::seed_from_u64(1));
        assert_eq!(vs.dim(), 1);
        assert_eq!(vs.len(), 5);
        let epoch: Vec<f32> = (0..5).map(|_| vs.draw().y).collect();
        let mut sorted = epoch.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(sorted, vec![0.0, 1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    #[should_panic(expected = "at least one sample")]
    fn vec_stream_rejects_empty() {
        use crate::data::Loss;
        let _ = VecStream::new(vec![], Loss::Squared, Prng::seed_from_u64(1));
    }

    #[test]
    fn batch_spills_into_next_epoch() {
        let mut s = WithoutReplacement::new(4, Prng::seed_from_u64(3));
        let batch = s.next_batch(6);
        assert_eq!(batch.len(), 6);
        // first 4 are a permutation
        let mut first4 = batch[..4].to_vec();
        first4.sort_unstable();
        assert_eq!(first4, vec![0, 1, 2, 3]);
    }

    #[test]
    fn epoch_bounded_batch_runs_short_at_boundary() {
        let mut s = WithoutReplacement::new(10, Prng::seed_from_u64(5));
        let b1 = s.next_batch_in_epoch(6);
        let b2 = s.next_batch_in_epoch(6);
        assert_eq!(b1.len(), 6);
        assert_eq!(b2.len(), 4, "final batch charges only what remains");
        let mut all: Vec<usize> = b1.iter().chain(&b2).copied().collect();
        all.sort_unstable();
        assert_eq!(all, (0..10).collect::<Vec<_>>(), "one epoch, no repeats");
        // the next call starts a fresh permutation, full-length again
        assert_eq!(s.next_batch_in_epoch(6).len(), 6);
    }

    #[test]
    fn prop_epoch_bounded_batches_tile_epochs() {
        forall(24, |rng| {
            let n = 1 + rng.next_below(60);
            let k = 1 + rng.next_below(20);
            let mut s = WithoutReplacement::new(n, Prng::seed_from_u64(rng.next_u64()));
            let mut seen = vec![false; n];
            let mut drawn = 0usize;
            while drawn < n {
                let b = s.next_batch_in_epoch(k);
                assert!(!b.is_empty() && b.len() <= k);
                assert!(b.len() == k || drawn + b.len() == n, "only the final batch is short");
                for i in b {
                    assert!(!seen[i], "index {i} repeated within epoch");
                    seen[i] = true;
                    drawn += 1;
                }
            }
        });
    }

    #[test]
    fn vec_stream_epoch_bounded_draw_many() {
        use crate::data::{Loss, Sample, SampleStream};
        let samples: Vec<Sample> =
            (0..5).map(|i| Sample { x: vec![i as f32], y: i as f32 }).collect();
        let mut vs =
            VecStream::epoch_bounded(samples.clone(), Loss::Squared, Prng::seed_from_u64(8));
        let b1 = vs.draw_many(3);
        let b2 = vs.draw_many(3);
        assert_eq!(b1.len(), 3);
        assert_eq!(b2.len(), 2, "short final batch at the epoch boundary");
        let mut ys: Vec<f32> = b1.iter().chain(&b2).map(|s| s.y).collect();
        ys.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(ys, vec![0.0, 1.0, 2.0, 3.0, 4.0]);
        // the recycling constructor keeps the always-full contract
        let mut vr = VecStream::new(samples, Loss::Squared, Prng::seed_from_u64(8));
        assert_eq!(vr.draw_many(7).len(), 7);
    }
}
