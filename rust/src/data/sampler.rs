//! Samplers: the streaming "button" and without-replacement epochs.
//!
//! Algorithm 1 step 2 requires processing a local batch *without
//! replacement* (the Shamir 2016 analysis DSVRG relies on);
//! `WithoutReplacement` provides permutation epochs over a materialized
//! slice. `Reservoir`-style streaming is not needed — machines either
//! stream (minibatch methods) or hold a fixed shard (ERM methods).

use super::Sample;
use crate::util::prng::Prng;

/// Permutation epochs over `n` indices: `next()` yields each index exactly
/// once per epoch, reshuffling between epochs.
pub struct WithoutReplacement {
    perm: Vec<usize>,
    pos: usize,
    rng: Prng,
}

impl WithoutReplacement {
    pub fn new(n: usize, rng: Prng) -> Self {
        let mut s = Self { perm: (0..n).collect(), pos: 0, rng };
        s.reshuffle();
        s
    }

    fn reshuffle(&mut self) {
        self.rng.shuffle(&mut self.perm);
        self.pos = 0;
    }

    pub fn len(&self) -> usize {
        self.perm.len()
    }

    pub fn is_empty(&self) -> bool {
        self.perm.is_empty()
    }

    /// Next index; starts a fresh permutation when the epoch ends.
    pub fn next_index(&mut self) -> usize {
        if self.pos >= self.perm.len() {
            self.reshuffle();
        }
        let i = self.perm[self.pos];
        self.pos += 1;
        i
    }

    /// Draw `k` indices without replacement *within* the current epoch
    /// (spilling into a fresh epoch if fewer than `k` remain).
    pub fn next_batch(&mut self, k: usize) -> Vec<usize> {
        (0..k).map(|_| self.next_index()).collect()
    }

    /// Remaining indices in the current epoch.
    pub fn remaining_in_epoch(&self) -> usize {
        self.perm.len() - self.pos
    }
}

/// A materialized dataset exposed as a `SampleStream` via permutation
/// epochs (the Figure-3 protocol: minibatches drawn from a fixed training
/// half). Used by the libsvm-loading end-to-end driver.
pub struct VecStream {
    samples: Vec<super::Sample>,
    order: WithoutReplacement,
    loss: super::Loss,
}

impl VecStream {
    pub fn new(samples: Vec<super::Sample>, loss: super::Loss, rng: Prng) -> Self {
        assert!(!samples.is_empty(), "VecStream needs at least one sample");
        let order = WithoutReplacement::new(samples.len(), rng);
        Self { samples, order, loss }
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }
}

impl super::SampleStream for VecStream {
    fn dim(&self) -> usize {
        self.samples[0].x.len()
    }

    fn loss(&self) -> super::Loss {
        self.loss
    }

    fn draw(&mut self) -> super::Sample {
        self.samples[self.order.next_index()].clone()
    }
}

/// Split a materialized dataset into `m` contiguous shards (machine i gets
/// shard i). Sizes differ by at most one.
pub fn shard_ranges(n: usize, m: usize) -> Vec<std::ops::Range<usize>> {
    assert!(m > 0);
    let base = n / m;
    let extra = n % m;
    let mut out = Vec::with_capacity(m);
    let mut start = 0;
    for i in 0..m {
        let len = base + usize::from(i < extra);
        out.push(start..start + len);
        start += len;
    }
    out
}

/// View of a machine's shard.
pub fn shard<'a>(samples: &'a [Sample], ranges: &[std::ops::Range<usize>], i: usize) -> &'a [Sample] {
    &samples[ranges[i].clone()]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::testkit::forall;

    #[test]
    fn epoch_is_permutation() {
        let mut s = WithoutReplacement::new(13, Prng::seed_from_u64(1));
        let mut seen = vec![false; 13];
        for _ in 0..13 {
            let i = s.next_index();
            assert!(!seen[i], "index {i} repeated within epoch");
            seen[i] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn epochs_reshuffle() {
        let mut s = WithoutReplacement::new(32, Prng::seed_from_u64(2));
        let e1: Vec<usize> = (0..32).map(|_| s.next_index()).collect();
        let e2: Vec<usize> = (0..32).map(|_| s.next_index()).collect();
        assert_ne!(e1, e2);
        let mut e2s = e2.clone();
        e2s.sort_unstable();
        assert_eq!(e2s, (0..32).collect::<Vec<_>>());
    }

    #[test]
    fn prop_epoch_permutation_any_n() {
        forall(24, |rng| {
            let n = 1 + rng.next_below(100);
            let mut s = WithoutReplacement::new(n, Prng::seed_from_u64(rng.next_u64()));
            let mut seen = vec![false; n];
            for _ in 0..n {
                let i = s.next_index();
                assert!(!seen[i]);
                seen[i] = true;
            }
        });
    }

    #[test]
    fn prop_shards_partition() {
        forall(32, |rng| {
            let n = rng.next_below(1000);
            let m = 1 + rng.next_below(16);
            let ranges = shard_ranges(n, m);
            assert_eq!(ranges.len(), m);
            let total: usize = ranges.iter().map(|r| r.len()).sum();
            assert_eq!(total, n);
            // contiguous & ordered
            let mut expect_start = 0;
            for r in &ranges {
                assert_eq!(r.start, expect_start);
                expect_start = r.end;
            }
            // balanced
            let (min, max) = ranges
                .iter()
                .fold((usize::MAX, 0), |(lo, hi), r| (lo.min(r.len()), hi.max(r.len())));
            assert!(max - min <= 1);
        });
    }

    #[test]
    fn vec_stream_draws_epoch_permutations() {
        use crate::data::{Loss, Sample, SampleStream};
        let samples: Vec<Sample> =
            (0..5).map(|i| Sample { x: vec![i as f32], y: i as f32 }).collect();
        let mut vs = VecStream::new(samples, Loss::Squared, Prng::seed_from_u64(1));
        assert_eq!(vs.dim(), 1);
        assert_eq!(vs.len(), 5);
        let epoch: Vec<f32> = (0..5).map(|_| vs.draw().y).collect();
        let mut sorted = epoch.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(sorted, vec![0.0, 1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    #[should_panic(expected = "at least one sample")]
    fn vec_stream_rejects_empty() {
        use crate::data::Loss;
        let _ = VecStream::new(vec![], Loss::Squared, Prng::seed_from_u64(1));
    }

    #[test]
    fn batch_spills_into_next_epoch() {
        let mut s = WithoutReplacement::new(4, Prng::seed_from_u64(3));
        let batch = s.next_batch(6);
        assert_eq!(batch.len(), 6);
        // first 4 are a permutation
        let mut first4 = batch[..4].to_vec();
        first4.sort_unstable();
        assert_eq!(first4, vec![0, 1, 2, 3]);
    }
}
