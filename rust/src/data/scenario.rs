//! The scenario registry: named, config-selectable stream families.
//!
//! The paper's setting (arXiv:1702.06269) is streaming stochastic
//! optimization — every machine holds a "button" producing fresh i.i.d.
//! samples — while the related work it is measured against (one-shot
//! averaging, arXiv:1209.4129; distributed SVRG, arXiv:1507.07595)
//! largely lives in the finite-sample ERM regime. The registry makes that
//! distinction a first-class, configurable axis: a [`ScenarioDef`] names
//! a [`StreamFamily`] constructor and declares its [`Setting`], and the
//! coordinator validates the method/scenario pairing (a streaming-SO
//! method must not silently run on a finite sample set as if it were a
//! population).
//!
//! Families are selected with the `scenario=` config key; an unknown name
//! is rejected with a did-you-mean suggestion, exactly like unknown
//! config keys. Every stream a family forks is `Send`, so on the sharded
//! execution plane machine streams move to their owning shards and the
//! draw verb generates + packs entirely shard-side.
//!
//! Registered families:
//!
//! | name         | setting       | what it streams                               |
//! |--------------|---------------|-----------------------------------------------|
//! | `synth`      | streaming-SO  | planted-model i.i.d. stream (`loss=` sq/log)  |
//! | `drift`      | streaming-SO  | planted model w* rotates over time             |
//! | `heavy-tail` | streaming-SO  | Pareto-scaled elliptical covariates            |
//! | `sparse`     | streaming-SO  | Bernoulli-masked sparse features               |
//! | `erm-fixed`  | finite-ERM    | fixed planted sample set, epoch shards         |
//! | `libsvm`     | finite-ERM    | chunked out-of-core libsvm file (`data_path=`) |

use super::libsvm::{count_samples, LibsvmChunkStream};
use super::sampler::{shard_ranges, VecStream};
use super::synth::{eigen_scales, label_for, planted_model, SynthSpec, SynthStream};
use super::{Loss, Sample, SampleStream};
use crate::util::closest_name;
use crate::util::prng::Prng;
use anyhow::{anyhow, bail, Result};

/// Which optimization setting a scenario serves: fresh i.i.d. draws from
/// a population (the paper's streaming setting) or epochs over a fixed
/// finite sample set (the ERM baselines' setting).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Setting {
    StreamingSo,
    FiniteErm,
}

impl Setting {
    pub fn as_str(self) -> &'static str {
        match self {
            Setting::StreamingSo => "streaming-SO",
            Setting::FiniteErm => "finite-ERM",
        }
    }
}

/// A configured stream family: one planted model / dataset, arbitrarily
/// many independent per-machine streams over it. `fork_stream(i)` for
/// machine tags `0..m` yields the machine streams (independent forks for
/// streaming families, disjoint shards for finite-ERM families); any
/// other tag (the coordinator's held-out evaluation tag) yields a fresh
/// population stream for estimating the stochastic objective.
pub trait StreamFamily: Send {
    /// Native feature dimension of every stream in the family.
    fn dim(&self) -> usize;
    fn loss(&self) -> Loss;
    fn setting(&self) -> Setting {
        Setting::StreamingSo
    }
    fn fork_stream(&self, tag: u64) -> Box<dyn SampleStream>;
}

/// The baseline planted-model stream is itself a (streaming-SO) family.
impl StreamFamily for SynthStream {
    fn dim(&self) -> usize {
        self.spec().dim
    }

    fn loss(&self) -> Loss {
        self.spec().loss
    }

    fn fork_stream(&self, tag: u64) -> Box<dyn SampleStream> {
        Box::new(SynthStream::fork_stream(self, tag))
    }
}

/// Everything a scenario constructor may draw on, lifted from the
/// experiment config by the coordinator.
#[derive(Clone, Debug)]
pub struct ScenarioParams {
    pub dim: usize,
    pub loss: Loss,
    pub seed: u64,
    /// number of machines (finite-ERM families shard their sample set
    /// m ways; machine tags are `0..m`)
    pub m: usize,
    /// total sample budget (the finite-ERM families' fixed set size)
    pub n_budget: usize,
    /// on-disk dataset path (`data_path=` key; required by `libsvm`)
    pub data_path: Option<String>,
    /// drift scenario: per-draw rotation angle override
    /// (`scenario.drift_omega`; `None` = [`DriftFamily`]'s default)
    pub drift_omega: Option<f64>,
    /// heavy-tail scenario: Pareto tail index override
    /// (`scenario.pareto_alpha`; must exceed 2 — the config layer
    /// validates, the builder re-checks)
    pub pareto_alpha: Option<f64>,
    /// sparse scenario: active-feature fraction override in (0, 1]
    /// (`scenario.sparse_density`)
    pub sparse_density: Option<f64>,
}

type BuildFn = fn(&ScenarioParams) -> Result<Box<dyn StreamFamily>>;

/// One registry entry: a named family constructor and its declared
/// setting.
pub struct ScenarioDef {
    pub name: &'static str,
    pub help: &'static str,
    pub setting: Setting,
    build: BuildFn,
}

impl ScenarioDef {
    pub fn build(&self, p: &ScenarioParams) -> Result<Box<dyn StreamFamily>> {
        (self.build)(p)
    }
}

/// The registry — ONE source of truth for scenario names, shown by the
/// CLI help and matched by the did-you-mean rejection.
pub const SCENARIOS: &[ScenarioDef] = &[
    ScenarioDef {
        name: "synth",
        help: "planted-model i.i.d. stream (loss= picks sq|log)",
        setting: Setting::StreamingSo,
        build: build_synth,
    },
    ScenarioDef {
        name: "drift",
        help: "planted model w* rotates over time (streaming non-stationarity)",
        setting: Setting::StreamingSo,
        build: build_drift,
    },
    ScenarioDef {
        name: "heavy-tail",
        help: "Pareto-scaled elliptical covariates (finite variance, heavy tails)",
        setting: Setting::StreamingSo,
        build: build_heavy_tail,
    },
    ScenarioDef {
        name: "sparse",
        help: "Bernoulli-masked sparse features, rescaled to keep E||x||^2",
        setting: Setting::StreamingSo,
        build: build_sparse,
    },
    ScenarioDef {
        name: "erm-fixed",
        help: "fixed planted sample set (n_budget), sharded per machine in epochs",
        setting: Setting::FiniteErm,
        build: build_erm_fixed,
    },
    ScenarioDef {
        name: "libsvm",
        help: "chunked out-of-core libsvm streaming (data_path=, strided machine shards)",
        setting: Setting::FiniteErm,
        build: build_libsvm,
    },
];

/// Look a scenario up by name; unknown names are rejected with the same
/// did-you-mean behavior as unknown config keys.
pub fn by_name(name: &str) -> Result<&'static ScenarioDef> {
    if let Some(def) = SCENARIOS.iter().find(|d| d.name == name) {
        return Ok(def);
    }
    match closest_name(name, SCENARIOS.iter().map(|d| d.name)) {
        Some(best) => bail!("unknown scenario '{name}' (did you mean '{best}'?)"),
        None => {
            let known: Vec<&str> = SCENARIOS.iter().map(|d| d.name).collect();
            bail!("unknown scenario '{name}' (known: {})", known.join(" | "))
        }
    }
}

fn base_spec(p: &ScenarioParams) -> SynthSpec {
    match p.loss {
        Loss::Squared => SynthSpec::least_squares(p.dim),
        Loss::Logistic => SynthSpec::logistic(p.dim),
    }
}

fn build_synth(p: &ScenarioParams) -> Result<Box<dyn StreamFamily>> {
    Ok(Box::new(SynthStream::new(base_spec(p), p.seed)))
}

// ---- drift: the planted model rotates over time -----------------------

/// Seed-mixing tag for the drift rotation plane (distinct from the
/// synth WSTAR tag so the two scenarios plant different models).
const DRIFT_TAG: u64 = 0x4452_4946_5421; // "DRIFT!"

/// Default drift rate: one full revolution of w* every 8192 samples per
/// stream — slow against a typical minibatch, visible across a run.
const DRIFT_OMEGA: f64 = std::f64::consts::TAU / 8192.0;

/// Streaming non-stationarity: the planted model rotates in a fixed
/// random 2-plane, w*(t) = cos(omega t) u + sin(omega t) v with u ⊥ v,
/// ‖u‖ = ‖v‖ = model_norm, where t counts the *stream's own* draws (so a
/// machine's sequence does not depend on cluster interleaving).
pub struct DriftFamily {
    spec: SynthSpec,
    u: Vec<f32>,
    v: Vec<f32>,
    scales: Vec<f32>,
    omega: f64,
    rng: Prng,
}

impl DriftFamily {
    pub fn new(spec: SynthSpec, seed: u64) -> DriftFamily {
        let mut model_rng = Prng::seed_from_u64(seed ^ DRIFT_TAG);
        let u = planted_model(spec.dim, spec.model_norm, &mut model_rng);
        let v = if spec.dim > 1 {
            // second direction: plant, orthogonalize against u, renorm
            let raw = planted_model(spec.dim, spec.model_norm, &mut model_rng);
            let uu: f64 = u.iter().map(|&a| (a as f64) * (a as f64)).sum();
            let uv: f64 = u.iter().zip(&raw).map(|(&a, &b)| a as f64 * b as f64).sum();
            let proj = uv / uu.max(f64::MIN_POSITIVE);
            let mut w: Vec<f64> =
                raw.iter().zip(&u).map(|(&r, &a)| r as f64 - proj * a as f64).collect();
            let norm = w.iter().map(|&x| x * x).sum::<f64>().sqrt();
            if norm > 1e-9 {
                for x in &mut w {
                    *x = *x / norm * spec.model_norm;
                }
                w.iter().map(|&x| x as f32).collect()
            } else {
                u.clone() // astronomically unlikely parallel draw
            }
        } else {
            u.clone()
        };
        let scales = eigen_scales(spec.dim, spec.cond, spec.row_norm);
        DriftFamily { spec, u, v, scales, omega: DRIFT_OMEGA, rng: Prng::seed_from_u64(seed) }
    }

    /// Override the rotation rate (`scenario.drift_omega`; radians per
    /// draw). The planted basis is unchanged, so omega=default reproduces
    /// `new` exactly.
    pub fn with_omega(mut self, omega: f64) -> DriftFamily {
        self.omega = omega;
        self
    }

    pub fn omega(&self) -> f64 {
        self.omega
    }

    /// The rotation-plane basis (tests pin orthogonality and norms).
    pub fn basis(&self) -> (&[f32], &[f32]) {
        (&self.u, &self.v)
    }
}

impl StreamFamily for DriftFamily {
    fn dim(&self) -> usize {
        self.spec.dim
    }

    fn loss(&self) -> Loss {
        self.spec.loss
    }

    fn fork_stream(&self, tag: u64) -> Box<dyn SampleStream> {
        Box::new(DriftStream {
            spec: self.spec.clone(),
            u: self.u.clone(),
            v: self.v.clone(),
            scales: self.scales.clone(),
            omega: self.omega,
            t: 0,
            rng: self.rng.split(tag.wrapping_add(1)),
        })
    }
}

pub struct DriftStream {
    spec: SynthSpec,
    u: Vec<f32>,
    v: Vec<f32>,
    scales: Vec<f32>,
    omega: f64,
    /// stream-local draw counter (the rotation clock)
    t: u64,
    rng: Prng,
}

impl SampleStream for DriftStream {
    fn dim(&self) -> usize {
        self.spec.dim
    }

    fn loss(&self) -> Loss {
        self.spec.loss
    }

    fn draw(&mut self) -> Sample {
        let d = self.spec.dim;
        let mut x = vec![0.0f32; d];
        for j in 0..d {
            x[j] = self.rng.next_normal_f32() * self.scales[j];
        }
        let theta = self.omega * self.t as f64;
        let zu: f64 = x.iter().zip(&self.u).map(|(&a, &b)| a as f64 * b as f64).sum();
        let zv: f64 = x.iter().zip(&self.v).map(|(&a, &b)| a as f64 * b as f64).sum();
        let z = theta.cos() * zu + theta.sin() * zv;
        let y = label_for(self.spec.loss, z, self.spec.noise, &mut self.rng);
        self.t += 1;
        Sample { x, y }
    }
}

fn build_drift(p: &ScenarioParams) -> Result<Box<dyn StreamFamily>> {
    let mut fam = DriftFamily::new(base_spec(p), p.seed);
    if let Some(omega) = p.drift_omega {
        if !omega.is_finite() || omega < 0.0 {
            bail!("scenario.drift_omega must be a finite angle >= 0, got {omega}");
        }
        fam = fam.with_omega(omega);
    }
    Ok(Box::new(fam))
}

// ---- heavy-tail: Pareto-scaled elliptical covariates ------------------

const HEAVY_TAG: u64 = 0x4845_4156_5921; // "HEAVY!"

/// Pareto tail index of the radial scale. alpha = 4 keeps the covariate
/// second moment finite (E[s^2] = alpha/(alpha-2) = 2) while the fourth
/// moment diverges — gradients see genuinely heavy tails.
const HEAVY_ALPHA: f64 = 4.0;

/// Elliptical heavy-tailed covariates: x = s · diag(scales) · g with
/// g ~ N(0, I) and s ~ Pareto(alpha), normalized by sqrt(E[s^2]) so
/// E‖x‖² stays row_norm² (the smoothness pin) while tail events dominate
/// individual gradients.
pub struct HeavyTailFamily {
    spec: SynthSpec,
    w_star: Vec<f32>,
    scales: Vec<f32>,
    alpha: f64,
    rng: Prng,
}

impl HeavyTailFamily {
    pub fn new(spec: SynthSpec, seed: u64) -> HeavyTailFamily {
        let mut model_rng = Prng::seed_from_u64(seed ^ HEAVY_TAG);
        let w_star = planted_model(spec.dim, spec.model_norm, &mut model_rng);
        let scales = eigen_scales(spec.dim, spec.cond, spec.row_norm);
        HeavyTailFamily { spec, w_star, scales, alpha: HEAVY_ALPHA, rng: Prng::seed_from_u64(seed) }
    }

    /// Override the Pareto tail index (`scenario.pareto_alpha`; must
    /// exceed 2 so E[s^2] = alpha/(alpha-2) stays finite — smaller alpha
    /// means heavier tails). The normalization tracks the new alpha, so
    /// E‖x‖² stays pinned at row_norm² for every valid choice.
    pub fn with_alpha(mut self, alpha: f64) -> HeavyTailFamily {
        assert!(alpha > 2.0, "Pareto tail index must exceed 2, got {alpha}");
        self.alpha = alpha;
        self
    }

    pub fn alpha(&self) -> f64 {
        self.alpha
    }
}

impl StreamFamily for HeavyTailFamily {
    fn dim(&self) -> usize {
        self.spec.dim
    }

    fn loss(&self) -> Loss {
        self.spec.loss
    }

    fn fork_stream(&self, tag: u64) -> Box<dyn SampleStream> {
        Box::new(HeavyTailStream {
            spec: self.spec.clone(),
            w_star: self.w_star.clone(),
            scales: self.scales.clone(),
            alpha: self.alpha,
            inv_rms: (self.alpha / (self.alpha - 2.0)).sqrt().recip() as f32,
            rng: self.rng.split(tag.wrapping_add(1)),
        })
    }
}

pub struct HeavyTailStream {
    spec: SynthSpec,
    w_star: Vec<f32>,
    scales: Vec<f32>,
    alpha: f64,
    /// 1 / sqrt(E[s^2]) — keeps E‖x‖² at row_norm²
    inv_rms: f32,
    rng: Prng,
}

impl SampleStream for HeavyTailStream {
    fn dim(&self) -> usize {
        self.spec.dim
    }

    fn loss(&self) -> Loss {
        self.spec.loss
    }

    fn draw(&mut self) -> Sample {
        let d = self.spec.dim;
        let s = (self.rng.next_pareto(self.alpha) as f32) * self.inv_rms;
        let mut x = vec![0.0f32; d];
        for j in 0..d {
            x[j] = self.rng.next_normal_f32() * self.scales[j] * s;
        }
        let z: f64 = x.iter().zip(&self.w_star).map(|(&a, &b)| a as f64 * b as f64).sum();
        let y = label_for(self.spec.loss, z, self.spec.noise, &mut self.rng);
        Sample { x, y }
    }
}

fn build_heavy_tail(p: &ScenarioParams) -> Result<Box<dyn StreamFamily>> {
    let mut fam = HeavyTailFamily::new(base_spec(p), p.seed);
    if let Some(alpha) = p.pareto_alpha {
        if !alpha.is_finite() || alpha <= 2.0 {
            bail!("scenario.pareto_alpha must exceed 2 (finite variance), got {alpha}");
        }
        fam = fam.with_alpha(alpha);
    }
    Ok(Box::new(fam))
}

// ---- sparse: Bernoulli-masked features --------------------------------

const SPARSE_TAG: u64 = 0x5350_4152_5321; // "SPARS!"

/// Default keep probability per coordinate.
const SPARSE_DENSITY: f64 = 0.1;

/// Sparse features: each coordinate is nonzero with probability
/// `density`, scaled by 1/sqrt(density) so E‖x‖² stays row_norm². Labels
/// come from the planted model on the *sparse* covariate.
pub struct SparseFamily {
    spec: SynthSpec,
    w_star: Vec<f32>,
    scales: Vec<f32>,
    density: f64,
    rng: Prng,
}

impl SparseFamily {
    pub fn new(spec: SynthSpec, seed: u64) -> SparseFamily {
        let mut model_rng = Prng::seed_from_u64(seed ^ SPARSE_TAG);
        let w_star = planted_model(spec.dim, spec.model_norm, &mut model_rng);
        let scales = eigen_scales(spec.dim, spec.cond, spec.row_norm);
        let rng = Prng::seed_from_u64(seed);
        SparseFamily { spec, w_star, scales, density: SPARSE_DENSITY, rng }
    }

    /// Override the per-coordinate keep probability
    /// (`scenario.sparse_density`, in (0, 1]). The 1/sqrt(density)
    /// rescale tracks the new density, so E‖x‖² stays at row_norm².
    pub fn with_density(mut self, density: f64) -> SparseFamily {
        assert!(
            density > 0.0 && density <= 1.0,
            "sparse density must lie in (0, 1], got {density}"
        );
        self.density = density;
        self
    }

    pub fn density(&self) -> f64 {
        self.density
    }
}

impl StreamFamily for SparseFamily {
    fn dim(&self) -> usize {
        self.spec.dim
    }

    fn loss(&self) -> Loss {
        self.spec.loss
    }

    fn fork_stream(&self, tag: u64) -> Box<dyn SampleStream> {
        Box::new(SparseStream {
            spec: self.spec.clone(),
            w_star: self.w_star.clone(),
            scales: self.scales.clone(),
            density: self.density,
            inv_sqrt_density: (1.0 / self.density.sqrt()) as f32,
            rng: self.rng.split(tag.wrapping_add(1)),
        })
    }
}

pub struct SparseStream {
    spec: SynthSpec,
    w_star: Vec<f32>,
    scales: Vec<f32>,
    density: f64,
    inv_sqrt_density: f32,
    rng: Prng,
}

impl SampleStream for SparseStream {
    fn dim(&self) -> usize {
        self.spec.dim
    }

    fn loss(&self) -> Loss {
        self.spec.loss
    }

    fn draw(&mut self) -> Sample {
        let d = self.spec.dim;
        let mut x = vec![0.0f32; d];
        for j in 0..d {
            if self.rng.next_f64() < self.density {
                x[j] = self.rng.next_normal_f32() * self.scales[j] * self.inv_sqrt_density;
            }
        }
        let z: f64 = x.iter().zip(&self.w_star).map(|(&a, &b)| a as f64 * b as f64).sum();
        let y = label_for(self.spec.loss, z, self.spec.noise, &mut self.rng);
        Sample { x, y }
    }
}

fn build_sparse(p: &ScenarioParams) -> Result<Box<dyn StreamFamily>> {
    let mut fam = SparseFamily::new(base_spec(p), p.seed);
    if let Some(density) = p.sparse_density {
        if !density.is_finite() || density <= 0.0 || density > 1.0 {
            bail!("scenario.sparse_density must lie in (0, 1], got {density}");
        }
        fam = fam.with_density(density);
    }
    Ok(Box::new(fam))
}

// ---- erm-fixed: a fixed finite sample set, sharded per machine --------

/// Stream-split tag for the materialized training set (machine tags are
/// 0..m, the coordinator's eval tag is large — this one must collide with
/// neither).
const ERM_DATA_TAG: u64 = 0x4552_4D21; // "ERM!"

/// Finite-ERM: `n_budget` planted-model samples materialized once and
/// sharded contiguously across machines; machine tag `i < m` gets an
/// epoch-bounded [`VecStream`] over shard i (honest short batches at the
/// epoch boundary — see `data::sampler`), any other tag a fresh
/// population stream (the held-out evaluator estimates the *stochastic*
/// objective either way).
pub struct ErmFixedFamily {
    root: SynthStream,
    shards: Vec<Vec<Sample>>,
    prng: Prng,
}

impl ErmFixedFamily {
    pub fn new(spec: SynthSpec, seed: u64, m: usize, n_total: usize) -> ErmFixedFamily {
        assert!(m >= 1, "need at least one machine shard");
        let root = SynthStream::new(spec, seed);
        let mut data = SynthStream::fork_stream(&root, ERM_DATA_TAG);
        let n = n_total.max(m);
        let samples = data.draw_many(n);
        let shards = shard_ranges(n, m).into_iter().map(|r| samples[r].to_vec()).collect();
        ErmFixedFamily { root, shards, prng: Prng::seed_from_u64(seed ^ ERM_DATA_TAG) }
    }

    /// Total fixed-set size across machine shards.
    pub fn n_total(&self) -> usize {
        self.shards.iter().map(Vec::len).sum()
    }
}

impl StreamFamily for ErmFixedFamily {
    fn dim(&self) -> usize {
        self.root.spec().dim
    }

    fn loss(&self) -> Loss {
        self.root.spec().loss
    }

    fn setting(&self) -> Setting {
        Setting::FiniteErm
    }

    fn fork_stream(&self, tag: u64) -> Box<dyn SampleStream> {
        match self.shards.get(tag as usize) {
            Some(shard) => Box::new(VecStream::epoch_bounded(
                shard.clone(),
                self.loss(),
                self.prng.split(tag.wrapping_add(1)),
            )),
            // non-machine tags (held-out evaluation): fresh population draws
            None => Box::new(SynthStream::fork_stream(&self.root, tag)),
        }
    }
}

fn build_erm_fixed(p: &ScenarioParams) -> Result<Box<dyn StreamFamily>> {
    Ok(Box::new(ErmFixedFamily::new(base_spec(p), p.seed, p.m.max(1), p.n_budget)))
}

// ---- libsvm: chunked out-of-core file streaming -----------------------

/// Read-ahead depth of each machine's chunk reader, in samples.
const LIBSVM_CHUNK: usize = 4096;

/// Finite-ERM over an on-disk libsvm file, never materialized: machine
/// tag `i < m` streams the data lines with `index % m == i` through a
/// [`LibsvmChunkStream`] (epochs in file order; short final batches at
/// the epoch boundary), any other tag streams the whole file (the
/// held-out evaluator's pass).
pub struct LibsvmFamily {
    path: std::path::PathBuf,
    dim: usize,
    loss: Loss,
    m: usize,
    n_samples: usize,
}

impl LibsvmFamily {
    pub fn open(
        path: impl Into<std::path::PathBuf>,
        dim: usize,
        loss: Loss,
        m: usize,
    ) -> Result<LibsvmFamily> {
        let path = path.into();
        let n_samples = count_samples(&path, dim)
            .map_err(|e| anyhow!("libsvm scenario {}: {e}", path.display()))?;
        if n_samples < m.max(1) {
            bail!(
                "libsvm scenario {}: {n_samples} samples cannot shard across {m} machines",
                path.display()
            );
        }
        Ok(LibsvmFamily { path, dim, loss, m: m.max(1), n_samples })
    }

    pub fn n_samples(&self) -> usize {
        self.n_samples
    }
}

impl StreamFamily for LibsvmFamily {
    fn dim(&self) -> usize {
        self.dim
    }

    fn loss(&self) -> Loss {
        self.loss
    }

    fn setting(&self) -> Setting {
        Setting::FiniteErm
    }

    fn fork_stream(&self, tag: u64) -> Box<dyn SampleStream> {
        let (stride, offset) = if (tag as usize) < self.m {
            (self.m, tag as usize)
        } else {
            (1, 0)
        };
        Box::new(
            LibsvmChunkStream::open(&self.path, self.dim, self.loss, stride, offset, LIBSVM_CHUNK)
                .unwrap_or_else(|e| panic!("libsvm reopen {}: {e}", self.path.display())),
        )
    }
}

fn build_libsvm(p: &ScenarioParams) -> Result<Box<dyn StreamFamily>> {
    let path = p
        .data_path
        .as_ref()
        .ok_or_else(|| anyhow!("scenario=libsvm needs data_path=<file.libsvm>"))?;
    Ok(Box::new(LibsvmFamily::open(path, p.dim, p.loss, p.m.max(1))?))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn params() -> ScenarioParams {
        ScenarioParams {
            dim: 16,
            loss: Loss::Squared,
            seed: 7,
            m: 4,
            n_budget: 103, // deliberately ragged across 4 shards
            data_path: None,
            drift_omega: None,
            pareto_alpha: None,
            sparse_density: None,
        }
    }

    fn assert_send<T: Send + ?Sized>() {}

    #[test]
    fn streams_and_families_are_send() {
        assert_send::<Box<dyn SampleStream>>();
        assert_send::<Box<dyn StreamFamily>>();
    }

    #[test]
    fn registry_lookup_and_did_you_mean() {
        assert_eq!(by_name("drift").unwrap().setting, Setting::StreamingSo);
        assert_eq!(by_name("erm-fixed").unwrap().setting, Setting::FiniteErm);
        let err = by_name("drfit").unwrap_err().to_string();
        assert!(err.contains("did you mean 'drift'"), "{err}");
        let err = by_name("zzzzqqqq").unwrap_err().to_string();
        assert!(err.contains("unknown scenario"), "{err}");
    }

    #[test]
    fn forks_are_deterministic_and_independent() {
        for def in SCENARIOS {
            if def.name == "libsvm" {
                continue; // needs a file; covered below
            }
            let p = params();
            let fam_a = def.build(&p).unwrap();
            let fam_b = def.build(&p).unwrap();
            let mut s1 = fam_a.fork_stream(2);
            let mut s2 = fam_b.fork_stream(2);
            for k in 0..20 {
                assert_eq!(s1.draw(), s2.draw(), "{}: draw {k} not deterministic", def.name);
            }
            let mut o1 = fam_a.fork_stream(0);
            let mut o2 = fam_a.fork_stream(1);
            assert_ne!(o1.draw(), o2.draw(), "{}: forks must be independent", def.name);
        }
    }

    #[test]
    fn drift_basis_is_orthonormal_and_labels_drift() {
        let fam = DriftFamily::new(SynthSpec::least_squares(16), 11);
        let (u, v) = fam.basis();
        let uu: f64 = u.iter().map(|&a| (a as f64).powi(2)).sum();
        let vv: f64 = v.iter().map(|&a| (a as f64).powi(2)).sum();
        let uv: f64 = u.iter().zip(v).map(|(&a, &b)| a as f64 * b as f64).sum();
        assert!((uu.sqrt() - 4.0).abs() < 1e-3, "norm u {}", uu.sqrt());
        assert!((vv.sqrt() - 4.0).abs() < 1e-3, "norm v {}", vv.sqrt());
        assert!(uv.abs() / uu < 1e-5, "u.v = {uv}");
        // the label-generating direction rotates: the same stream's
        // empirical E[x y] correlates with u early and decorrelates after
        // a quarter turn
        let mut s = fam.fork_stream(0);
        let estimate = |s: &mut Box<dyn SampleStream>, n: usize| -> Vec<f64> {
            let mut g = vec![0.0f64; 16];
            for _ in 0..n {
                let smp = s.draw();
                for j in 0..16 {
                    g[j] += smp.x[j] as f64 * smp.y as f64;
                }
            }
            g
        };
        let early = estimate(&mut s, 512);
        // skip to a quarter turn (8192/4 = 2048 draws in)
        for _ in 0..1536 {
            s.draw();
        }
        let late = estimate(&mut s, 512);
        let corr = |g: &[f64], dir: &[f32]| -> f64 {
            let num: f64 = g.iter().zip(dir).map(|(&a, &b)| a * b as f64).sum();
            let gn = g.iter().map(|&a| a * a).sum::<f64>().sqrt();
            let dn = dir.iter().map(|&a| (a as f64).powi(2)).sum::<f64>().sqrt();
            num / (gn * dn)
        };
        assert!(corr(&early, u) > 0.6, "early window tracks u: {}", corr(&early, u));
        assert!(
            corr(&late, u) < corr(&late, v),
            "after a quarter turn the signal rotated toward v"
        );
    }

    #[test]
    fn heavy_tail_keeps_second_moment_with_heavy_tails() {
        let fam = HeavyTailFamily::new(SynthSpec::least_squares(16), 3);
        let mut s = fam.fork_stream(0);
        let n = 6000;
        let mut acc = 0.0;
        let mut max_sq: f64 = 0.0;
        for _ in 0..n {
            let smp = s.draw();
            let sq: f64 = smp.x.iter().map(|&v| (v as f64).powi(2)).sum();
            acc += sq;
            max_sq = max_sq.max(sq);
        }
        // s^2 has tail index 2 (log-divergent variance), so the empirical
        // second moment converges slowly — bounds are deliberately loose
        let mean_sq = acc / n as f64;
        assert!((0.5..2.0).contains(&mean_sq), "E||x||^2 = {mean_sq}");
        assert!(max_sq > 5.0 * mean_sq, "tails should dominate: max {max_sq} mean {mean_sq}");
    }

    #[test]
    fn sparse_density_and_moment() {
        let fam = SparseFamily::new(SynthSpec::least_squares(32), 5);
        let mut s = fam.fork_stream(0);
        let n = 3000;
        let mut nnz = 0usize;
        let mut acc = 0.0;
        for _ in 0..n {
            let smp = s.draw();
            nnz += smp.x.iter().filter(|&&v| v != 0.0).count();
            acc += smp.x.iter().map(|&v| (v as f64).powi(2)).sum::<f64>();
        }
        let density = nnz as f64 / (n * 32) as f64;
        assert!((density - SPARSE_DENSITY).abs() < 0.02, "density {density}");
        let mean_sq = acc / n as f64;
        assert!((mean_sq - 1.0).abs() < 0.15, "E||x||^2 = {mean_sq}");
    }

    #[test]
    fn scenario_knobs_override_the_defaults() {
        // drift: a zero rotation rate makes the stream stationary — the
        // same seed's samples match a DriftFamily pinned at theta=0
        let p_frozen = ScenarioParams { drift_omega: Some(0.0), ..params() };
        let frozen = by_name("drift").unwrap().build(&p_frozen).unwrap();
        let manual = DriftFamily::new(base_spec(&params()), params().seed).with_omega(0.0);
        let mut a = frozen.fork_stream(0);
        let mut b = manual.fork_stream(0);
        for _ in 0..16 {
            assert_eq!(a.draw(), b.draw());
        }
        // no override = the registry default (an omega() accessor pins it)
        let dflt = DriftFamily::new(base_spec(&params()), 1);
        assert_eq!(dflt.omega(), std::f64::consts::TAU / 8192.0);

        // sparse: the configured density shows up empirically
        let p_dense = ScenarioParams { sparse_density: Some(0.5), ..params() };
        let fam = by_name("sparse").unwrap().build(&p_dense).unwrap();
        let mut s = fam.fork_stream(0);
        let n = 2000;
        let mut nnz = 0usize;
        for _ in 0..n {
            nnz += s.draw().x.iter().filter(|&&v| v != 0.0).count();
        }
        let density = nnz as f64 / (n * 16) as f64;
        assert!((density - 0.5).abs() < 0.03, "density {density}");

        // heavy-tail: the normalization tracks the configured alpha, so
        // the second moment stays pinned (bounds loose — smaller alpha
        // converges slower)
        let p_heavy = ScenarioParams { pareto_alpha: Some(3.0), ..params() };
        let fam = by_name("heavy-tail").unwrap().build(&p_heavy).unwrap();
        let mut s = fam.fork_stream(0);
        let mut acc = 0.0;
        for _ in 0..6000 {
            acc += s.draw().x.iter().map(|&v| (v as f64).powi(2)).sum::<f64>();
        }
        let mean_sq = acc / 6000.0;
        assert!((0.3..3.0).contains(&mean_sq), "E||x||^2 = {mean_sq}");

        // invalid overrides are rejected at build with the key name
        let bad = ScenarioParams { pareto_alpha: Some(2.0), ..params() };
        let err = by_name("heavy-tail").unwrap().build(&bad).unwrap_err().to_string();
        assert!(err.contains("pareto_alpha"), "{err}");
        let bad = ScenarioParams { sparse_density: Some(0.0), ..params() };
        let err = by_name("sparse").unwrap().build(&bad).unwrap_err().to_string();
        assert!(err.contains("sparse_density"), "{err}");
        let bad = ScenarioParams { drift_omega: Some(f64::NAN), ..params() };
        let err = by_name("drift").unwrap().build(&bad).unwrap_err().to_string();
        assert!(err.contains("drift_omega"), "{err}");
    }

    #[test]
    fn erm_fixed_shards_partition_and_run_short() {
        let p = params();
        let fam = ErmFixedFamily::new(base_spec(&p), p.seed, p.m, p.n_budget);
        assert_eq!(fam.n_total(), 103);
        assert_eq!(fam.setting(), Setting::FiniteErm);
        // each machine's first epoch is a permutation of its shard; a
        // 26/26/26/25 split drawn as 30-sample batches runs short
        let mut total = 0usize;
        for i in 0..4u64 {
            let mut s = fam.fork_stream(i);
            let b = s.draw_many(30);
            assert!(b.len() == 26 || b.len() == 25, "machine {i} epoch size {}", b.len());
            total += b.len();
        }
        assert_eq!(total, 103, "machine shards partition the fixed set");
        // eval tag is a fresh population stream, not a shard
        let mut ev = fam.fork_stream(0xE7A1);
        assert_eq!(ev.draw_many(40).len(), 40);
    }

    #[test]
    fn libsvm_family_strides_machines() {
        use crate::data::libsvm::write_samples;
        let mut root = SynthStream::new(SynthSpec::least_squares(8), 31);
        let samples = root.draw_many(10);
        let dir = std::env::temp_dir().join("mbprox_scenario_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("family.libsvm");
        write_samples(&path, &samples).unwrap();

        let p = ScenarioParams {
            data_path: Some(path.to_string_lossy().into_owned()),
            dim: 8,
            m: 3,
            ..params()
        };
        let fam = by_name("libsvm").unwrap().build(&p).unwrap();
        assert_eq!(fam.setting(), Setting::FiniteErm);
        // machine shards stride the file: 4 + 3 + 3 samples
        let mut total = 0usize;
        for i in 0..3u64 {
            let b = fam.fork_stream(i).draw_many(10);
            assert!(b.len() == 4 || b.len() == 3, "machine {i} shard size {}", b.len());
            total += b.len();
        }
        assert_eq!(total, 10);
        // missing data_path is rejected at build
        let p_missing = ScenarioParams { data_path: None, ..params() };
        let err = by_name("libsvm").unwrap().build(&p_missing).unwrap_err().to_string();
        assert!(err.contains("data_path"), "{err}");
        std::fs::remove_file(&path).ok();
    }
}
