//! Data substrate: sample streams, synthetic generators, dataset specs,
//! libsvm text IO, samplers and block packing.
//!
//! The paper's setting is *stochastic* optimization: each machine has a
//! "button" producing i.i.d. samples. `SampleStream` is that button;
//! `synth` provides planted-model implementations; `table3` mirrors the
//! paper's four evaluation datasets (Appendix E, Table 3) with synthetic
//! equivalents (substitution documented in DESIGN.md §3); `libsvm` gives a
//! real on-disk format so the end-to-end driver exercises a genuine
//! load/parse path; `blocks` packs samples into the fixed-shape padded
//! blocks the AOT artifacts consume.

pub mod blocks;
pub mod libsvm;
pub mod sampler;
pub mod synth;
pub mod table3;

/// Loss family. Matches the artifact name tags (`sq` / `log`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Loss {
    Squared,
    Logistic,
}

impl Loss {
    pub fn tag(self) -> &'static str {
        match self {
            Loss::Squared => "sq",
            Loss::Logistic => "log",
        }
    }

    pub fn parse(s: &str) -> Option<Loss> {
        match s {
            "sq" | "squared" => Some(Loss::Squared),
            "log" | "logistic" => Some(Loss::Logistic),
            _ => None,
        }
    }
}

/// One labeled example. `x` has the dataset's native dimension; block
/// packing pads features to the artifact dimension.
#[derive(Clone, Debug, PartialEq)]
pub struct Sample {
    pub x: Vec<f32>,
    pub y: f32,
}

/// The i.i.d. "button": draw samples from the underlying distribution.
pub trait SampleStream {
    fn dim(&self) -> usize;
    fn loss(&self) -> Loss;
    fn draw(&mut self) -> Sample;

    fn draw_many(&mut self, n: usize) -> Vec<Sample> {
        (0..n).map(|_| self.draw()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loss_tags_round_trip() {
        assert_eq!(Loss::parse(Loss::Squared.tag()), Some(Loss::Squared));
        assert_eq!(Loss::parse(Loss::Logistic.tag()), Some(Loss::Logistic));
        assert_eq!(Loss::parse("bogus"), None);
    }
}
