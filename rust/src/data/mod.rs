//! Data substrate: the DataPlane's stream side — sample streams, the
//! scenario registry, samplers, libsvm IO and block packing.
//!
//! The paper's setting is *stochastic* optimization: each machine has a
//! "button" producing i.i.d. samples. [`SampleStream`] is that button. It
//! is `Send` by contract: a machine's stream is a shard-resident object
//! on the sharded execution plane — moved to the owning shard at context
//! construction, drawn and packed there by the plane's **draw** verb
//! (`runtime::plane::ExecPlane::draw_batches`, the fifth verb next to
//! upload/dispatch/chain/reduce) with zero coordinator-side sample
//! materialization. [`MachineStreams`] names the two homes a cluster's
//! streams can have.
//!
//! `scenario` is the registry of named, config-selectable stream families
//! (`scenario=` key): planted-model synth, streaming drift, heavy-tailed
//! covariates, sparse features, fixed finite sample sets and chunked
//! out-of-core libsvm — each declaring whether it is streaming-SO or
//! finite-ERM so the coordinator can validate method/scenario pairings.
//! `synth` provides the planted-model generators; `table3` mirrors the
//! paper's four evaluation datasets (Appendix E, Table 3) with synthetic
//! equivalents (substitution documented in DESIGN.md §3); `libsvm` gives
//! a real on-disk format (whole-file and chunked out-of-core readers);
//! `sampler` holds the without-replacement epoch machinery; `blocks`
//! packs samples into the fixed-shape padded blocks the AOT artifacts
//! consume.

pub mod blocks;
pub mod libsvm;
pub mod sampler;
pub mod scenario;
pub mod synth;
pub mod table3;

/// Loss family. Matches the artifact name tags (`sq` / `log`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Loss {
    Squared,
    Logistic,
}

impl Loss {
    pub fn tag(self) -> &'static str {
        match self {
            Loss::Squared => "sq",
            Loss::Logistic => "log",
        }
    }

    pub fn parse(s: &str) -> Option<Loss> {
        match s {
            "sq" | "squared" => Some(Loss::Squared),
            "log" | "logistic" => Some(Loss::Logistic),
            _ => None,
        }
    }
}

/// One labeled example. `x` has the dataset's native dimension; block
/// packing pads features to the artifact dimension.
#[derive(Clone, Debug, PartialEq)]
pub struct Sample {
    pub x: Vec<f32>,
    pub y: f32,
}

/// The i.i.d. "button": draw samples from the underlying distribution.
///
/// `Send` is part of the contract: on the sharded execution plane a
/// machine's stream lives on the owning shard's worker thread (see
/// `runtime::shard::ShardState`), so the draw verb can generate and pack
/// entirely shard-side.
///
/// `draw_many` may return FEWER than `n` samples: finite streams (epoch
/// samplers, out-of-core files) never cross an epoch boundary inside one
/// batch, so the final batch of an epoch can run short — callers must
/// charge what was actually drawn, not what was requested. The default
/// implementation (infinite streams) always returns exactly `n`.
pub trait SampleStream: Send {
    fn dim(&self) -> usize;
    fn loss(&self) -> Loss;
    fn draw(&mut self) -> Sample;

    fn draw_many(&mut self, n: usize) -> Vec<Sample> {
        (0..n).map(|_| self.draw()).collect()
    }

    /// Whether `draw_many(a + b)` yields the same samples as
    /// `draw_many(a)` then `draw_many(b)` — true for the default
    /// implementation (sequential `draw` calls) and every infinite
    /// stream. Epoch-batching streams, whose `draw_many` decides epoch
    /// boundaries per CALL, must return false: the shard plane's prefetch
    /// lane re-splits a speculative read-ahead only when this holds, and
    /// refuses (pointing at `prefetch=off`) otherwise.
    fn draws_decompose(&self) -> bool {
        true
    }
}

/// Where a cluster's per-machine sample streams live — the DataPlane's
/// state side, owned by the run context and operated on exclusively
/// through the plane's draw verb.
pub enum MachineStreams {
    /// Streams held by the coordinator (host/chained planes, and any
    /// context built over caller-supplied streams without a shard pool):
    /// the draw verb draws and packs them inline on the coordinator
    /// engine.
    Local(Vec<Box<dyn SampleStream>>),
    /// Streams moved to their owning shards at context construction
    /// (machine i's stream lives on `shard_of(i)`'s prefetch lane — see
    /// `runtime::shard` — next to its batches): the draw verb generates
    /// and packs on the shard, optionally one round ahead of the engine,
    /// and the coordinator holds only the machine count.
    Sharded { m: usize },
}

impl MachineStreams {
    /// Number of machines (= streams) in the cluster.
    pub fn len(&self) -> usize {
        match self {
            MachineStreams::Local(v) => v.len(),
            MachineStreams::Sharded { m } => *m,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl From<Vec<Box<dyn SampleStream>>> for MachineStreams {
    fn from(streams: Vec<Box<dyn SampleStream>>) -> MachineStreams {
        MachineStreams::Local(streams)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loss_tags_round_trip() {
        assert_eq!(Loss::parse(Loss::Squared.tag()), Some(Loss::Squared));
        assert_eq!(Loss::parse(Loss::Logistic.tag()), Some(Loss::Logistic));
        assert_eq!(Loss::parse("bogus"), None);
    }
}
