//! The paper's four evaluation datasets (Appendix E, Table 3) as synthetic
//! equivalents.
//!
//! | name     | #samples   | #features | loss     |
//! |----------|------------|-----------|----------|
//! | codrna   |   271,617  |     8     | logistic |
//! | covtype  |   581,012  |    54     | logistic |
//! | kddcup99 | 1,131,571  |   127     | logistic |
//! | year     |   463,715  |    90     | squared  |
//!
//! The real libsvm files are not available offline; per DESIGN.md §3 we
//! substitute planted-model generators matched on (n, d, loss) with a
//! moderate condition number and noise, which preserves the Figure-3
//! behaviour the paper demonstrates (minibatch-size sensitivity and the
//! effect of extra DANE rounds). `scale` shrinks n for CI-speed runs while
//! keeping d and the loss fixed.

use super::synth::{SynthSpec, SynthStream};
use super::Loss;

#[derive(Clone, Debug)]
pub struct DatasetSpec {
    pub name: &'static str,
    pub n_total: usize,
    pub dim: usize,
    pub loss: Loss,
}

pub const CODRNA: DatasetSpec =
    DatasetSpec { name: "codrna", n_total: 271_617, dim: 8, loss: Loss::Logistic };
pub const COVTYPE: DatasetSpec =
    DatasetSpec { name: "covtype", n_total: 581_012, dim: 54, loss: Loss::Logistic };
pub const KDDCUP99: DatasetSpec =
    DatasetSpec { name: "kddcup99", n_total: 1_131_571, dim: 127, loss: Loss::Logistic };
pub const YEAR: DatasetSpec =
    DatasetSpec { name: "year", n_total: 463_715, dim: 90, loss: Loss::Squared };

pub const ALL: [&DatasetSpec; 4] = [&CODRNA, &COVTYPE, &KDDCUP99, &YEAR];

impl DatasetSpec {
    pub fn by_name(name: &str) -> Option<&'static DatasetSpec> {
        ALL.iter().copied().find(|d| d.name == name)
    }

    /// Training-set size following the paper's protocol ("randomly select
    /// half of the samples for training, the remaining ... for estimating
    /// the stochastic objective"), optionally scaled down by `scale`.
    pub fn n_train(&self, scale: f64) -> usize {
        (((self.n_total / 2) as f64) * scale).max(64.0) as usize
    }

    pub fn n_eval(&self, scale: f64) -> usize {
        self.n_train(scale).min(50_000)
    }

    /// Planted-model stream matched to this dataset.
    pub fn stream(&self, seed: u64) -> SynthStream {
        let spec = match self.loss {
            Loss::Squared => {
                SynthSpec { noise: 0.3, cond: 10.0, ..SynthSpec::least_squares(self.dim) }
            }
            Loss::Logistic => {
                SynthSpec { noise: 0.05, cond: 10.0, ..SynthSpec::logistic(self.dim) }
            }
        };
        SynthStream::new(spec, seed ^ fnv1a(self.name))
    }

    /// Artifact feature dimension this dataset pads to (64 or 128).
    pub fn padded_dim(&self) -> usize {
        if self.dim <= 64 {
            64
        } else {
            128
        }
    }
}

fn fnv1a(s: &str) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SampleStream;

    #[test]
    fn table3_matches_paper() {
        assert_eq!(CODRNA.n_total, 271_617);
        assert_eq!(COVTYPE.dim, 54);
        assert_eq!(KDDCUP99.n_total, 1_131_571);
        assert_eq!(YEAR.loss, Loss::Squared);
        assert_eq!(ALL.len(), 4);
    }

    #[test]
    fn padded_dims() {
        assert_eq!(CODRNA.padded_dim(), 64);
        assert_eq!(COVTYPE.padded_dim(), 64);
        assert_eq!(YEAR.padded_dim(), 128);
        assert_eq!(KDDCUP99.padded_dim(), 128);
    }

    #[test]
    fn streams_have_native_dim_and_loss() {
        for spec in ALL {
            let mut s = spec.stream(1);
            assert_eq!(s.dim(), spec.dim);
            assert_eq!(s.loss(), spec.loss);
            let smp = s.draw();
            assert_eq!(smp.x.len(), spec.dim);
        }
    }

    #[test]
    fn by_name_lookup() {
        assert_eq!(DatasetSpec::by_name("year").unwrap().dim, 90);
        assert!(DatasetSpec::by_name("nope").is_none());
    }

    #[test]
    fn scaled_train_sizes() {
        assert_eq!(CODRNA.n_train(1.0), 135_808);
        assert!(CODRNA.n_train(0.01) >= 64);
    }

    #[test]
    fn different_datasets_different_models() {
        let a = CODRNA.stream(1);
        let b = COVTYPE.stream(1);
        assert_ne!(a.w_star()[0], b.w_star()[0]);
    }
}
