//! Planted-model synthetic data streams.
//!
//! Least squares: x ~ N(0, Σ) with geometric eigenvalue decay (controlled
//! condition number), y = <x, w*> + σ·ξ. Logistic: labels in {-1, +1} with
//! P(y=+1|x) = sigmoid(<x, w*>) plus optional label flip noise. Features
//! are scaled so rows have expected squared norm ≈ `row_norm²`, which pins
//! the smoothness β ≈ row_norm² for the theory-driven parameter choices
//! (footnote 4: "we can equivalently assume ‖x‖² ≤ β").

use super::{Loss, Sample, SampleStream};
use crate::util::prng::Prng;

/// Seed-mixing tag separating the planted-model stream from the sample
/// stream (both derive from the user's single seed).
const WSTAR_TAG: u64 = 0x5753_5441_5221; // "WSTAR!"

#[derive(Clone, Debug)]
pub struct SynthSpec {
    pub dim: usize,
    pub loss: Loss,
    /// norm of the planted model w*
    pub model_norm: f64,
    /// covariance eigenvalue ratio first/last (1.0 = isotropic)
    pub cond: f64,
    /// additive label noise std (squared loss) / label flip prob (logistic)
    pub noise: f64,
    /// target sqrt(E‖x‖²) (≈ sqrt of smoothness β)
    pub row_norm: f64,
}

impl SynthSpec {
    /// With E‖x‖² = 1 spread over d coordinates, a random-direction w* of
    /// norm W gives signal variance E⟨x,w*⟩² ≈ W²/d — so W must scale with
    /// sqrt(d) to keep the signal-to-noise ratio dimension-independent.
    pub fn signal_norm(dim: usize, target_z_std: f64) -> f64 {
        target_z_std * (dim as f64).sqrt()
    }

    pub fn least_squares(dim: usize) -> Self {
        Self {
            dim,
            loss: Loss::Squared,
            model_norm: Self::signal_norm(dim, 1.0),
            cond: 4.0,
            noise: 0.1,
            row_norm: 1.0,
        }
    }

    pub fn logistic(dim: usize) -> Self {
        Self {
            dim,
            loss: Loss::Logistic,
            model_norm: Self::signal_norm(dim, 2.0),
            cond: 4.0,
            noise: 0.02,
            row_norm: 1.0,
        }
    }

    /// Smoothness of the induced instantaneous loss (used by `theory`).
    /// Squared loss: β = E‖x‖²; logistic: β = E‖x‖²/4.
    pub fn beta(&self) -> f64 {
        let b = self.row_norm * self.row_norm;
        match self.loss {
            Loss::Squared => b,
            Loss::Logistic => b / 4.0,
        }
    }
}

/// A random-direction planted model of norm `model_norm`, drawn from
/// `rng`. Shared by every planted-model scenario stream (synth, drift,
/// heavy-tail, sparse) so their models are constructed identically.
pub(crate) fn planted_model(dim: usize, model_norm: f64, rng: &mut Prng) -> Vec<f32> {
    let mut w: Vec<f32> = (0..dim).map(|_| rng.next_normal_f32()).collect();
    let norm = (w.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>()).sqrt();
    for v in &mut w {
        *v = (*v as f64 / norm * model_norm) as f32;
    }
    w
}

/// Per-coordinate feature scales: geometric eigenvalue decay
/// lambda_j ∝ cond^(−j/(d−1)), normalized so E‖x‖² = row_norm².
pub(crate) fn eigen_scales(dim: usize, cond: f64, row_norm: f64) -> Vec<f32> {
    let mut scales: Vec<f32> = (0..dim)
        .map(|j| {
            let t = if dim > 1 { j as f64 / (dim - 1) as f64 } else { 0.0 };
            (cond.powf(-t)).sqrt() as f32
        })
        .collect();
    let sum_sq: f64 = scales.iter().map(|&s| (s as f64) * (s as f64)).sum();
    let fix = (row_norm * row_norm / sum_sq).sqrt();
    for s in &mut scales {
        *s = (*s as f64 * fix) as f32;
    }
    scales
}

/// A planted-model label for margin `z = <x, w*>`: additive Gaussian
/// noise (squared loss) or a sigmoid sign with flip probability `noise`
/// (logistic). Consumes the stream rng in a fixed order, so every
/// scenario stream built on it stays deterministic.
pub(crate) fn label_for(loss: Loss, z: f64, noise: f64, rng: &mut Prng) -> f32 {
    match loss {
        Loss::Squared => (z + noise * rng.next_normal()) as f32,
        Loss::Logistic => {
            let p = 1.0 / (1.0 + (-z).exp());
            let mut y = if rng.next_f64() < p { 1.0 } else { -1.0 };
            if rng.next_f64() < noise {
                y = -y;
            }
            y
        }
    }
}

/// Deterministic stream of planted-model samples.
pub struct SynthStream {
    spec: SynthSpec,
    w_star: Vec<f32>,
    /// per-coordinate feature scales (sqrt of covariance eigenvalues),
    /// normalized so E‖x‖² = row_norm².
    scales: Vec<f32>,
    rng: Prng,
}

impl SynthStream {
    /// `seed` controls both the planted model and the stream; use
    /// `fork_stream` to give machines independent streams over the *same*
    /// planted model.
    pub fn new(spec: SynthSpec, seed: u64) -> Self {
        let mut model_rng = Prng::seed_from_u64(seed ^ WSTAR_TAG);
        let w = planted_model(spec.dim, spec.model_norm, &mut model_rng);
        let scales = eigen_scales(spec.dim, spec.cond, spec.row_norm);
        Self { spec, w_star: w, scales, rng: Prng::seed_from_u64(seed) }
    }

    /// Same planted model, independent sample stream (per-machine streams).
    pub fn fork_stream(&self, tag: u64) -> SynthStream {
        SynthStream {
            spec: self.spec.clone(),
            w_star: self.w_star.clone(),
            scales: self.scales.clone(),
            rng: self.rng.split(tag.wrapping_add(1)),
        }
    }

    pub fn w_star(&self) -> &[f32] {
        &self.w_star
    }

    pub fn spec(&self) -> &SynthSpec {
        &self.spec
    }

    /// Bayes-optimal population objective value (squared loss only):
    /// E[0.5 (y − x·w*)²] = σ²/2.
    pub fn bayes_objective(&self) -> Option<f64> {
        match self.spec.loss {
            Loss::Squared => Some(0.5 * self.spec.noise * self.spec.noise),
            Loss::Logistic => None,
        }
    }
}

impl SampleStream for SynthStream {
    fn dim(&self) -> usize {
        self.spec.dim
    }

    fn loss(&self) -> Loss {
        self.spec.loss
    }

    fn draw(&mut self) -> Sample {
        let d = self.spec.dim;
        let mut x = vec![0.0f32; d];
        for j in 0..d {
            x[j] = self.rng.next_normal_f32() * self.scales[j];
        }
        let z: f64 = x.iter().zip(&self.w_star).map(|(&a, &b)| a as f64 * b as f64).sum();
        let y = label_for(self.spec.loss, z, self.spec.noise, &mut self.rng);
        Sample { x, y }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = SynthStream::new(SynthSpec::least_squares(8), 1);
        let mut b = SynthStream::new(SynthSpec::least_squares(8), 1);
        for _ in 0..10 {
            assert_eq!(a.draw(), b.draw());
        }
    }

    #[test]
    fn forked_streams_share_model_but_differ() {
        let a = SynthStream::new(SynthSpec::least_squares(8), 2);
        let mut f1 = a.fork_stream(0);
        let mut f2 = a.fork_stream(1);
        assert_eq!(f1.w_star(), a.w_star());
        assert_ne!(f1.draw(), f2.draw());
    }

    #[test]
    fn model_norm_is_controlled() {
        let s = SynthStream::new(SynthSpec::least_squares(32), 3);
        let n: f64 = s.w_star().iter().map(|&v| (v as f64).powi(2)).sum::<f64>().sqrt();
        assert!((n - 32f64.sqrt()).abs() < 1e-4, "norm {n}");
    }

    #[test]
    fn signal_strength_is_dimension_independent() {
        for d in [8usize, 64] {
            let mut s = SynthStream::new(SynthSpec::least_squares(d), 9);
            let n = 4000;
            let mut zz = 0.0;
            for _ in 0..n {
                let smp = s.draw();
                let z: f64 = smp
                    .x
                    .iter()
                    .zip(s.w_star())
                    .map(|(&a, &b)| a as f64 * b as f64)
                    .sum();
                zz += z * z;
            }
            let var = zz / n as f64;
            assert!((0.4..2.5).contains(&var), "d={d}: signal var {var}");
        }
    }

    #[test]
    fn row_norms_match_target() {
        let mut s = SynthStream::new(SynthSpec::least_squares(16), 4);
        let n = 4000;
        let mut acc = 0.0;
        for _ in 0..n {
            let smp = s.draw();
            acc += smp.x.iter().map(|&v| (v as f64).powi(2)).sum::<f64>();
        }
        let mean_sq = acc / n as f64;
        assert!((mean_sq - 1.0).abs() < 0.1, "E||x||^2 = {mean_sq}");
    }

    #[test]
    fn logistic_labels_are_signs() {
        let mut s = SynthStream::new(SynthSpec::logistic(8), 5);
        for _ in 0..100 {
            let smp = s.draw();
            assert!(smp.y == 1.0 || smp.y == -1.0);
        }
    }

    #[test]
    fn squared_loss_noise_floor() {
        let s = SynthStream::new(SynthSpec::least_squares(8), 6);
        assert!((s.bayes_objective().unwrap() - 0.005).abs() < 1e-9);
    }
}
