//! Block packing: samples -> fixed-shape padded blocks for the artifacts.
//!
//! Every AOT artifact consumes `(X[B, d], y[B], mask[B])` with B = 256 and
//! d ∈ {64, 128}. The packer pads features with zeros up to `d`, pads the
//! row tail with masked-out rows, and records the valid count. The
//! sum+count output convention of the artifacts makes block composition
//! exact (verified by the padding property tests on both sides).

use super::Sample;

pub const BLOCK_ROWS: usize = 256;

#[derive(Clone, Debug)]
pub struct Block {
    /// row-major BLOCK_ROWS x d
    pub x: Vec<f32>,
    pub y: Vec<f32>,
    pub mask: Vec<f32>,
    pub valid: usize,
    pub d: usize,
}

impl Block {
    pub fn rows(&self) -> usize {
        BLOCK_ROWS
    }
}

/// Pack up to BLOCK_ROWS samples into one block, padding features to `d`.
pub fn pack_block(samples: &[Sample], d: usize) -> Block {
    assert!(samples.len() <= BLOCK_ROWS, "pack_block: too many rows");
    let valid = samples.len();
    let mut x = vec![0.0f32; BLOCK_ROWS * d];
    let mut y = vec![0.0f32; BLOCK_ROWS];
    let mut mask = vec![0.0f32; BLOCK_ROWS];
    for (r, s) in samples.iter().enumerate() {
        assert!(s.x.len() <= d, "sample dim {} exceeds block dim {d}", s.x.len());
        x[r * d..r * d + s.x.len()].copy_from_slice(&s.x);
        y[r] = s.y;
        mask[r] = 1.0;
    }
    Block { x, y, mask, valid, d }
}

/// Pack an arbitrary slice into ceil(n/B) blocks.
pub fn pack_all(samples: &[Sample], d: usize) -> Vec<Block> {
    samples.chunks(BLOCK_ROWS).map(|c| pack_block(c, d)).collect()
}

/// Pack by index list (used by without-replacement batches).
pub fn pack_indices(samples: &[Sample], idx: &[usize], d: usize) -> Vec<Block> {
    idx.chunks(BLOCK_ROWS)
        .map(|chunk| {
            let rows: Vec<Sample> = chunk.iter().map(|&i| samples[i].clone()).collect();
            pack_block(&rows, d)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::testkit::forall;

    fn sample(d: usize, v: f32) -> Sample {
        Sample { x: vec![v; d], y: v }
    }

    #[test]
    fn pads_rows_and_features() {
        let samples = vec![sample(3, 1.0), sample(3, 2.0)];
        let b = pack_block(&samples, 8);
        assert_eq!(b.valid, 2);
        assert_eq!(b.x.len(), BLOCK_ROWS * 8);
        assert_eq!(&b.x[0..3], &[1.0, 1.0, 1.0]);
        assert_eq!(&b.x[3..8], &[0.0; 5]);
        assert_eq!(b.mask[0], 1.0);
        assert_eq!(b.mask[2], 0.0);
        assert_eq!(b.y[1], 2.0);
    }

    #[test]
    fn prop_pack_all_covers_everything() {
        forall(24, |rng| {
            let n = rng.next_below(1000);
            let d = 4;
            let samples: Vec<Sample> = (0..n).map(|i| sample(d, i as f32)).collect();
            let blocks = pack_all(&samples, 8);
            assert_eq!(blocks.len(), n.div_ceil(BLOCK_ROWS));
            let total_valid: usize = blocks.iter().map(|b| b.valid).sum();
            assert_eq!(total_valid, n);
            // mask sum equals valid count
            for b in &blocks {
                let msum: f32 = b.mask.iter().sum();
                assert_eq!(msum as usize, b.valid);
                // mask is a prefix
                for r in 0..BLOCK_ROWS {
                    assert_eq!(b.mask[r], if r < b.valid { 1.0 } else { 0.0 });
                }
            }
        });
    }

    #[test]
    fn pack_indices_selects_rows() {
        let samples: Vec<Sample> = (0..10).map(|i| sample(2, i as f32)).collect();
        let blocks = pack_indices(&samples, &[7, 3, 9], 4);
        assert_eq!(blocks.len(), 1);
        assert_eq!(blocks[0].valid, 3);
        assert_eq!(blocks[0].y[0], 7.0);
        assert_eq!(blocks[0].y[1], 3.0);
        assert_eq!(blocks[0].y[2], 9.0);
    }

    #[test]
    #[should_panic(expected = "exceeds block dim")]
    fn rejects_oversized_samples() {
        pack_block(&[sample(16, 1.0)], 8);
    }

    #[test]
    fn empty_pack_is_fully_masked() {
        let b = pack_block(&[], 4);
        assert_eq!(b.valid, 0);
        assert!(b.mask.iter().all(|&m| m == 0.0));
    }
}
