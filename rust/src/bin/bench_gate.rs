//! bench_gate — diff a fresh `BENCH_runtime.json` against the committed
//! `BENCH_baseline.json`, failing (exit 1) on regression.
//!
//! Usage: `bench_gate <baseline.json> <fresh.json>`
//!
//! `bench_gate --write-baseline <baseline.json> <fresh.json>` rewrites the
//! baseline instead of gating: every counter bound is widened just enough
//! to admit the fresh run's value (absent sides stay absent — a counter
//! pinned only by `max` never grows a `min`), while `_comment` and
//! `medians` ride through verbatim. The rewrite is a convenience for
//! intentional behaviour changes, not a green button: review the diff
//! before committing, because a real regression would widen its own bound.
//!
//! The baseline pins two kinds of expectations:
//!
//! - `counters`: machine-independent bounds on the bench's named scalars
//!   (`{"name": {"min": x, "max": y}}`, either side optional). These are
//!   structural invariants — upload counts per round, cache-hit totals,
//!   served-reduce flags, prefetch hit rates — that hold on any host, so
//!   CI can gate on them without a calibrated reference machine.
//! - `medians`: optional wall-clock pins (`{"bench name": {"p50_ns": n,
//!   "rel_tol": t}}`) checked as `fresh_p50 <= p50_ns * (1 + rel_tol)`.
//!   Empty by default: raw latencies are machine-dependent, so entries
//!   belong here only when CI runs on calibrated hardware.
//!
//! Baseline names that the fresh report does not carry are violations
//! too — a silently dropped counter is how a perf gate rots. So are
//! malformed baseline entries: a bound that is not an object, a
//! non-numeric `min`/`max`/`p50_ns`/`rel_tol`, or a `counters` section
//! that is not an object all produce failing checks naming the offending
//! scenario and field, instead of silently unbounding the gate.

use mbprox::util::json::{escape_str, Json};
use std::process::ExitCode;

/// One checked expectation, pass or fail.
struct Check {
    name: String,
    detail: String,
    ok: bool,
}

/// Read one bound side (`min`/`max`) of a counter entry. `Ok(None)` means
/// the side is absent (legitimately unbounded); a present-but-non-numeric
/// value is an error naming the counter and the side — a typo like
/// `{"min": "zero"}` must fail the gate, not silently unbound the check.
fn bound_side(counter: &str, bound: &Json, side: &str) -> Result<Option<f64>, String> {
    match bound.get(side) {
        None => Ok(None),
        Some(v) => match v.as_f64() {
            Some(x) => Ok(Some(x)),
            None => Err(format!("counter '{counter}': '{side}' is not a number")),
        },
    }
}

fn check_counters(baseline: &Json, fresh: &Json, out: &mut Vec<Check>) {
    let bounds = match baseline.get("counters") {
        None => return,
        Some(section) => match section.as_obj() {
            Some(m) => m,
            None => {
                out.push(Check {
                    name: "baseline counters".into(),
                    detail: "'counters' is not an object of {name: {min, max}} bounds".into(),
                    ok: false,
                });
                return;
            }
        },
    };
    let fresh_counters = match fresh.get("counters") {
        Some(section) => match section.as_obj() {
            Some(m) => Some(m),
            None => {
                out.push(Check {
                    name: "fresh counters".into(),
                    detail: "'counters' is not an object in the fresh report".into(),
                    ok: false,
                });
                return;
            }
        },
        None => None,
    };
    for (name, bound) in bounds {
        if bound.as_obj().is_none() {
            out.push(Check {
                name: format!("counter {name}"),
                detail: "baseline bound is not an object (want {\"min\": x, \"max\": y})"
                    .to_string(),
                ok: false,
            });
            continue;
        }
        let (min, max) = match (bound_side(name, bound, "min"), bound_side(name, bound, "max")) {
            (Ok(lo), Ok(hi)) => (lo, hi),
            (lo, hi) => {
                for e in [lo.err(), hi.err()].into_iter().flatten() {
                    out.push(Check {
                        name: format!("counter {name}"),
                        detail: format!("malformed baseline bound: {e}"),
                        ok: false,
                    });
                }
                continue;
            }
        };
        let got = fresh_counters.and_then(|c| c.get(name));
        let (ok, detail) = match got {
            None => (false, "missing from fresh report".to_string()),
            Some(v) => match v.as_f64() {
                None => (false, "fresh value is not a number".to_string()),
                Some(v) => {
                    let lo_ok = min.map_or(true, |lo| v >= lo);
                    let hi_ok = max.map_or(true, |hi| v <= hi);
                    let range = match (min, max) {
                        (Some(lo), Some(hi)) => format!("[{lo}, {hi}]"),
                        (Some(lo), None) => format!(">= {lo}"),
                        (None, Some(hi)) => format!("<= {hi}"),
                        (None, None) => "(unbounded)".to_string(),
                    };
                    (lo_ok && hi_ok, format!("{v} vs {range}"))
                }
            },
        };
        out.push(Check { name: format!("counter {name}"), detail, ok });
    }
}

fn check_medians(baseline: &Json, fresh: &Json, out: &mut Vec<Check>) {
    let pins = match baseline.get("medians") {
        None => return,
        Some(section) => match section.as_obj() {
            Some(m) => m,
            None => {
                out.push(Check {
                    name: "baseline medians".into(),
                    detail: "'medians' is not an object of {name: {p50_ns, rel_tol}} pins".into(),
                    ok: false,
                });
                return;
            }
        },
    };
    let benches = fresh.get("benches").and_then(Json::as_arr).unwrap_or(&[]);
    for (name, pin) in pins {
        // a pin without a numeric p50_ns can never gate anything — name it
        // rather than comparing against NaN and printing garbage
        let p50 = match pin.get("p50_ns").and_then(Json::as_f64) {
            Some(x) => x,
            None => {
                out.push(Check {
                    name: format!("median {name}"),
                    detail: "malformed baseline pin: 'p50_ns' missing or not a number".into(),
                    ok: false,
                });
                continue;
            }
        };
        let tol = match pin.get("rel_tol") {
            None => 0.25,
            Some(v) => match v.as_f64() {
                Some(t) => t,
                None => {
                    out.push(Check {
                        name: format!("median {name}"),
                        detail: "malformed baseline pin: 'rel_tol' is not a number".into(),
                        ok: false,
                    });
                    continue;
                }
            },
        };
        let got = benches
            .iter()
            .find(|b| b.get("name").and_then(Json::as_str) == Some(name))
            .and_then(|b| b.get("p50_ns"))
            .and_then(Json::as_f64);
        let (ok, detail) = match got {
            None => (false, "bench missing from fresh report".to_string()),
            Some(v) => {
                let limit = p50 * (1.0 + tol);
                (v <= limit, format!("{v:.0}ns vs limit {limit:.0}ns (p50 {p50:.0} +{tol})"))
            }
        };
        out.push(Check { name: format!("median {name}"), detail, ok });
    }
}

/// Run every baseline expectation against the fresh report.
fn gate(baseline: &Json, fresh: &Json) -> Vec<Check> {
    let mut checks = Vec::new();
    check_counters(baseline, fresh, &mut checks);
    check_medians(baseline, fresh, &mut checks);
    checks
}

/// Print a counter bound: integers without a trailing `.0`, everything
/// else in Rust's (non-scientific, round-trippable) float form.
fn fmt_num(x: f64) -> String {
    if x.fract() == 0.0 && x.abs() < 9.0e15 {
        format!("{}", x as i64)
    } else {
        format!("{x}")
    }
}

/// Render a preserved JSON subtree (the `_comment` block, `medians` pins)
/// at `indent` two-space levels: one array element / object field per
/// line, matching the committed baseline's shape. Keys come out in
/// BTreeMap order, so the output is deterministic.
fn render(j: &Json, indent: usize) -> String {
    let pad = "  ".repeat(indent);
    match j {
        Json::Null => "null".into(),
        Json::Bool(b) => b.to_string(),
        Json::Num(x) => fmt_num(*x),
        Json::Str(s) => escape_str(s),
        Json::Arr(items) => {
            if items.is_empty() {
                return "[]".into();
            }
            let inner: Vec<String> =
                items.iter().map(|v| format!("{pad}  {}", render(v, indent + 1))).collect();
            format!("[\n{}\n{pad}]", inner.join(",\n"))
        }
        Json::Obj(m) => {
            if m.is_empty() {
                return "{}".into();
            }
            let inner: Vec<String> = m
                .iter()
                .map(|(k, v)| format!("{pad}  {}: {}", escape_str(k), render(v, indent + 1)))
                .collect();
            format!("{{\n{}\n{pad}}}", inner.join(",\n"))
        }
    }
}

/// `--write-baseline`: regenerate the baseline text from a fresh report.
/// Every counter bound is widened just enough to admit the fresh value;
/// absent bound sides stay absent, extra bound keys ride through, and
/// `_comment`/`medians` are preserved verbatim. Counters are emitted in
/// sorted order (the parser's map is ordered), so reruns are stable.
/// Returns the new baseline text plus human-readable notes on every
/// change; malformed baselines refuse to rewrite instead of guessing.
fn write_baseline(old: &Json, fresh: &Json) -> Result<(String, Vec<String>), String> {
    let bounds = old
        .get("counters")
        .and_then(Json::as_obj)
        .ok_or("baseline has no 'counters' object")?;
    let fresh_counters = fresh
        .get("counters")
        .and_then(Json::as_obj)
        .ok_or("fresh report has no 'counters' object")?;
    let mut notes = Vec::new();
    let mut out = String::from("{\n");
    if let Some(c) = old.get("_comment") {
        out.push_str(&format!("  \"_comment\": {},\n", render(c, 1)));
    }
    out.push_str("  \"counters\": {\n");
    let mut entries = Vec::new();
    for (name, bound) in bounds {
        let bobj = bound
            .as_obj()
            .ok_or_else(|| format!("counter '{name}': bound is not an object"))?;
        let mut min = bound_side(name, bound, "min")?;
        let mut max = bound_side(name, bound, "max")?;
        match fresh_counters.get(name).and_then(Json::as_f64) {
            None => notes.push(format!("counter '{name}': missing from fresh report (kept)")),
            Some(v) => {
                if let Some(lo) = min.filter(|&lo| v < lo) {
                    notes.push(format!(
                        "counter '{name}': min widened {} -> {}",
                        fmt_num(lo),
                        fmt_num(v)
                    ));
                    min = Some(v);
                }
                if let Some(hi) = max.filter(|&hi| v > hi) {
                    notes.push(format!(
                        "counter '{name}': max widened {} -> {}",
                        fmt_num(hi),
                        fmt_num(v)
                    ));
                    max = Some(v);
                }
            }
        }
        let mut parts = Vec::new();
        if let Some(lo) = min {
            parts.push(format!("\"min\": {}", fmt_num(lo)));
        }
        if let Some(hi) = max {
            parts.push(format!("\"max\": {}", fmt_num(hi)));
        }
        for (k, v) in bobj {
            if k != "min" && k != "max" {
                parts.push(format!("{}: {}", escape_str(k), render(v, 2)));
            }
        }
        entries.push(format!("    {}: {{{}}}", escape_str(name), parts.join(", ")));
    }
    out.push_str(&entries.join(",\n"));
    out.push_str("\n  },\n");
    let medians = old.get("medians").cloned().unwrap_or_else(|| Json::Obj(Default::default()));
    out.push_str(&format!("  \"medians\": {}\n}}\n", render(&medians, 1)));
    let unpinned = fresh_counters.keys().filter(|k| !bounds.contains_key(*k)).count();
    if unpinned > 0 {
        notes.push(format!("{unpinned} fresh counter(s) have no baseline bound"));
    }
    Ok((out, notes))
}

fn load(path: &str) -> Result<Json, String> {
    let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
    Json::parse(&text).map_err(|e| format!("{path}: {e}"))
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let (write_mode, baseline_path, fresh_path) = match args.as_slice() {
        [flag, b, f] if flag == "--write-baseline" => (true, b.as_str(), f.as_str()),
        [b, f] => (false, b.as_str(), f.as_str()),
        _ => {
            eprintln!("usage: bench_gate [--write-baseline] <baseline.json> <fresh.json>");
            return ExitCode::from(2);
        }
    };
    let (baseline, fresh) = match (load(baseline_path), load(fresh_path)) {
        (Ok(b), Ok(f)) => (b, f),
        (b, f) => {
            for e in [b.err(), f.err()].into_iter().flatten() {
                eprintln!("bench_gate: {e}");
            }
            return ExitCode::from(2);
        }
    };

    if write_mode {
        return match write_baseline(&baseline, &fresh) {
            Ok((text, notes)) => {
                if let Err(e) = std::fs::write(baseline_path, &text) {
                    eprintln!("bench_gate: writing {baseline_path}: {e}");
                    return ExitCode::from(2);
                }
                println!("bench_gate: rewrote {baseline_path} from {fresh_path}");
                for n in &notes {
                    println!("  {n}");
                }
                if notes.is_empty() {
                    println!("  (no bounds needed widening)");
                }
                println!(
                    "bench_gate: REVIEW THE DIFF before committing — bounds were only\n\
                     widened to admit this fresh run, so a real regression would ride\n\
                     in unnoticed through a blindly accepted rewrite."
                );
                ExitCode::SUCCESS
            }
            Err(e) => {
                eprintln!("bench_gate: --write-baseline: {e}");
                ExitCode::from(2)
            }
        };
    }

    let checks = gate(&baseline, &fresh);
    let failed = checks.iter().filter(|c| !c.ok).count();
    println!("bench_gate: {} vs {}", fresh_path, baseline_path);
    for c in &checks {
        println!("  [{}] {:<48} {}", if c.ok { "ok" } else { "FAIL" }, c.name, c.detail);
    }
    if failed > 0 {
        eprintln!("bench_gate: {failed}/{} checks failed", checks.len());
        return ExitCode::FAILURE;
    }
    println!("bench_gate: {} checks passed", checks.len());
    ExitCode::SUCCESS
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(text: &str) -> Json {
        Json::parse(text).unwrap()
    }

    fn fresh() -> Json {
        let text = r#"{
          "benches": [{"name": "pack 256", "iters": 8, "mean_ns": 1000.0,
                       "p50_ns": 900.0, "p10_ns": 800.0, "p90_ns": 1200.0,
                       "min_ns": 700.0, "throughput_ops_per_sec": 1.0}],
          "counters": {"round.same_w.uploads": 0.0, "prefetch.on.hit_rate": 0.857},
          "notes": {}
        }"#;
        parse(text)
    }

    #[test]
    fn counter_bounds_pass_and_fail() {
        let text = r#"{"counters": {
          "round.same_w.uploads": {"max": 0},
          "prefetch.on.hit_rate": {"min": 0.5, "max": 1.0}
        }}"#;
        let checks = gate(&parse(text), &fresh());
        assert_eq!(checks.len(), 2);
        assert!(checks.iter().all(|c| c.ok), "both in bounds");

        let tight = r#"{"counters": {"prefetch.on.hit_rate": {"min": 0.9}}}"#;
        let checks = gate(&parse(tight), &fresh());
        assert!(!checks[0].ok, "0.857 < min 0.9 must fail");
    }

    #[test]
    fn missing_counter_is_a_violation() {
        let text = r#"{"counters": {"engine.executions": {"min": 1}}}"#;
        let checks = gate(&parse(text), &fresh());
        assert!(!checks[0].ok);
        assert!(checks[0].detail.contains("missing"));
    }

    #[test]
    fn median_pins_respect_rel_tol() {
        // 900 <= 800 * 1.25 = 1000
        let ok = r#"{"medians": {"pack 256": {"p50_ns": 800.0, "rel_tol": 0.25}}}"#;
        assert!(gate(&parse(ok), &fresh())[0].ok);
        // 900 > 700 * 1.1 = 770
        let slow = r#"{"medians": {"pack 256": {"p50_ns": 700.0, "rel_tol": 0.1}}}"#;
        assert!(!gate(&parse(slow), &fresh())[0].ok);
        let gone = r#"{"medians": {"no such bench": {"p50_ns": 1.0, "rel_tol": 0.5}}}"#;
        assert!(!gate(&parse(gone), &fresh())[0].ok);
    }

    #[test]
    fn empty_baseline_passes() {
        let empty = r#"{"counters": {}, "medians": {}}"#;
        assert!(gate(&parse(empty), &fresh()).is_empty());
    }

    #[test]
    fn malformed_counter_bound_names_the_counter() {
        // non-numeric min: must FAIL naming counter + side, not pass unbounded
        let bad = r#"{"counters": {"round.same_w.uploads": {"min": "zero"}}}"#;
        let checks = gate(&parse(bad), &fresh());
        assert_eq!(checks.len(), 1);
        assert!(!checks[0].ok);
        assert!(checks[0].name.contains("round.same_w.uploads"), "{}", checks[0].name);
        assert!(checks[0].detail.contains("'min' is not a number"), "{}", checks[0].detail);

        // bound that is not an object at all
        let scalar = r#"{"counters": {"prefetch.on.hit_rate": 0.5}}"#;
        let checks = gate(&parse(scalar), &fresh());
        assert!(!checks[0].ok);
        assert!(checks[0].name.contains("prefetch.on.hit_rate"));
        assert!(checks[0].detail.contains("not an object"), "{}", checks[0].detail);

        // both sides malformed → one named failure per side
        let both = r#"{"counters": {"x": {"min": [], "max": "a"}}}"#;
        let checks = gate(&parse(both), &fresh());
        assert_eq!(checks.len(), 2);
        assert!(checks.iter().all(|c| !c.ok && c.name.contains('x')));
    }

    #[test]
    fn malformed_sections_fail_loudly() {
        let checks = gate(&parse(r#"{"counters": [1, 2]}"#), &fresh());
        assert_eq!(checks.len(), 1);
        assert!(!checks[0].ok);
        assert!(checks[0].detail.contains("not an object"));

        let checks = gate(&parse(r#"{"medians": "fast"}"#), &fresh());
        assert_eq!(checks.len(), 1);
        assert!(!checks[0].ok);

        // fresh report with a scalar counters section
        let base = r#"{"counters": {"a": {"min": 0}}}"#;
        let bad_fresh = parse(r#"{"counters": 7, "benches": []}"#);
        let checks = gate(&parse(base), &bad_fresh);
        assert_eq!(checks.len(), 1);
        assert!(!checks[0].ok);
        assert!(checks[0].name.contains("fresh counters"));
    }

    #[test]
    fn malformed_median_pins_name_the_field() {
        let no_p50 = r#"{"medians": {"pack 256": {"rel_tol": 0.25}}}"#;
        let checks = gate(&parse(no_p50), &fresh());
        assert!(!checks[0].ok);
        assert!(checks[0].detail.contains("p50_ns"), "{}", checks[0].detail);

        let bad_tol = r#"{"medians": {"pack 256": {"p50_ns": 800.0, "rel_tol": "loose"}}}"#;
        let checks = gate(&parse(bad_tol), &fresh());
        assert!(!checks[0].ok);
        assert!(checks[0].detail.contains("rel_tol"), "{}", checks[0].detail);
    }

    #[test]
    fn write_baseline_widens_only_what_the_fresh_run_violates() {
        let base = r#"{
          "_comment": ["keep me"],
          "counters": {
            "round.same_w.uploads": {"max": 0},
            "prefetch.on.hit_rate": {"min": 0.5, "max": 1.0},
            "engine.executions": {"min": 10}
          },
          "medians": {"pack 256": {"p50_ns": 800.0, "rel_tol": 0.25}}
        }"#;
        let f = r#"{"counters": {
          "round.same_w.uploads": 3.0,
          "prefetch.on.hit_rate": 0.857,
          "engine.executions": 4.0,
          "brand.new.counter": 1.0
        }, "benches": []}"#;
        let (text, notes) = write_baseline(&parse(base), &parse(f)).expect("rewrites");
        let v = parse(&text);
        let c = v.get("counters").unwrap();
        // violated bounds widened just enough to admit the fresh values
        let up = c.get("round.same_w.uploads").unwrap();
        assert_eq!(up.get("max").unwrap().as_f64(), Some(3.0));
        assert!(up.get("min").is_none(), "absent sides stay absent");
        let ex = c.get("engine.executions").unwrap();
        assert_eq!(ex.get("min").unwrap().as_f64(), Some(4.0));
        // in-bounds counter untouched
        let hr = c.get("prefetch.on.hit_rate").unwrap();
        assert_eq!(hr.get("min").unwrap().as_f64(), Some(0.5));
        assert_eq!(hr.get("max").unwrap().as_f64(), Some(1.0));
        // unpinned fresh counters are NOT auto-added
        assert!(c.get("brand.new.counter").is_none());
        // _comment and medians ride through verbatim
        let comment = v.get("_comment").unwrap().as_arr().unwrap();
        assert_eq!(comment[0].as_str(), Some("keep me"));
        let pin = v.get("medians").unwrap().get("pack 256").unwrap();
        assert_eq!(pin.get("p50_ns").unwrap().as_f64(), Some(800.0));
        // every widening is named so the diff review has a map
        assert!(notes.iter().any(|n| n.contains("max widened 0 -> 3")), "{notes:?}");
        assert!(notes.iter().any(|n| n.contains("min widened 10 -> 4")), "{notes:?}");
        assert!(notes.iter().any(|n| n.contains("no baseline bound")), "{notes:?}");
    }

    #[test]
    fn write_baseline_keeps_missing_counters_and_rejects_malformed_bounds() {
        let base = r#"{"counters": {"gone.counter": {"min": 2, "max": 5}}, "medians": {}}"#;
        let f = r#"{"counters": {}, "benches": []}"#;
        let (text, notes) = write_baseline(&parse(base), &parse(f)).expect("rewrites");
        let v = parse(&text);
        let b = v.get("counters").unwrap().get("gone.counter").unwrap();
        assert_eq!(b.get("min").unwrap().as_f64(), Some(2.0));
        assert_eq!(b.get("max").unwrap().as_f64(), Some(5.0));
        assert!(notes.iter().any(|n| n.contains("missing")), "{notes:?}");

        // a malformed bound refuses to rewrite instead of guessing
        let bad = r#"{"counters": {"x": {"min": "zero"}}}"#;
        let err = write_baseline(&parse(bad), &parse(f)).unwrap_err();
        assert!(err.contains("'min' is not a number"), "{err}");
        assert!(write_baseline(&parse(r#"{"medians": {}}"#), &parse(f)).is_err());
    }

    #[test]
    fn write_baseline_output_formats_integers_without_decimals() {
        let base = r#"{"counters": {"a": {"min": 1, "max": 2}}, "medians": {}}"#;
        let f = r#"{"counters": {"a": 1.5}, "benches": []}"#;
        let (text, notes) = write_baseline(&parse(base), &parse(f)).expect("rewrites");
        assert!(notes.is_empty(), "1.5 is in [1, 2]: {notes:?}");
        assert!(text.contains("\"min\": 1, \"max\": 2"), "{text}");
        assert!(!text.contains("1.0"), "{text}");
    }

    #[test]
    fn non_numeric_fresh_counter_fails() {
        let base = r#"{"counters": {"round.same_w.uploads": {"max": 0}}}"#;
        let f = parse(r#"{"counters": {"round.same_w.uploads": "none"}, "benches": []}"#);
        let checks = gate(&parse(base), &f);
        assert!(!checks[0].ok);
        assert!(checks[0].detail.contains("not a number"), "{}", checks[0].detail);
    }
}
