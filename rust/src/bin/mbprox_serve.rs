//! `mbprox_serve` — dedicated binary for the persistent run service.
//!
//! Thin wrapper over `serve::Server`: the same service `mbprox serve`
//! starts, packaged as its own binary so deployments that only ever run
//! the service don't need the full CLI. Takes ONLY `serve.*` keys
//! (experiment configs are POSTed to /run as KvConfig key=value lines);
//! blocks until `POST /shutdown`.

use anyhow::Result;
use mbprox::config::{ExperimentConfig, KvConfig, ServeConfig, CONFIG_KEYS};
use mbprox::runtime::default_artifacts_dir;
use mbprox::serve::Server;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.iter().any(|a| a == "--help" || a == "-h" || a == "help") {
        println!(
            "mbprox_serve [serve.key=value ...]\n\n\
             Persistent run service: POST experiment configs (the same\n\
             key=value lines `mbprox run` accepts) to /run and stream\n\
             ndjson progress events; GET /stats for cumulative job and\n\
             cache counters; POST /shutdown to stop.\n\n\
             serve keys (from config::CONFIG_KEYS):"
        );
        for (key, help) in CONFIG_KEYS.iter().filter(|(k, _)| k.starts_with("serve.")) {
            println!("  {key:<22} {help}");
        }
        return Ok(());
    }
    let mut kv = KvConfig::default();
    for a in &args {
        if let Some(path) = a.strip_prefix("config=") {
            kv = KvConfig::load(std::path::Path::new(path))?;
        }
    }
    let overrides: Vec<String> =
        args.iter().filter(|a| !a.starts_with("config=")).cloned().collect();
    let kv = ExperimentConfig::apply_overrides(kv, &overrides)?;
    let cfg = ServeConfig::from_kv(&kv)?;
    let server = Server::bind(&cfg, &default_artifacts_dir())?;
    eprintln!(
        "# mbprox_serve listening on http://{} (queue_depth={}, cache_capacity={})",
        server.addr(),
        cfg.queue_depth,
        cfg.cache_capacity.map(|c| c.to_string()).unwrap_or_else(|| "unbounded".into())
    );
    let stats = server.run()?;
    eprintln!(
        "# mbprox_serve stopped: {} done, {} failed, {} rejected, cache {}h/{}m",
        stats.jobs_done,
        stats.jobs_failed,
        stats.jobs_rejected,
        stats.exec_cache.hits,
        stats.exec_cache.misses
    );
    Ok(())
}
