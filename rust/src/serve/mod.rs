//! mbprox-serve: a persistent run service over a resident `Runner`.
//!
//! The paper's regime is many configurations swept over one problem
//! family; a cold `mbprox run` pays engine construction plus artifact
//! compilation before the first minibatch-prox iteration. This module
//! amortizes that cost the same way the paper amortizes communication
//! across local work: a long-lived process owns warm
//! [`Runner`]/[`ShardPool`](crate::runtime::ShardPool) instances and
//! executes a queue of configs against the content-addressed executable
//! cache (`runtime::cache`), so a thousand queued configs pay lowering
//! and compilation once.
//!
//! # Wire format
//!
//! Plain HTTP/1.1, hand-rolled on `std::net` (the offline image has no
//! HTTP dependency). The request body of `POST /run` IS the existing
//! `KvConfig` key set — `key = value` lines, `#` comments and
//! `[section]` headers exactly as `mbprox run` reads from a file. No new
//! schema: if a config file runs, its bytes POST.
//!
//! - `POST /run` — validate (the full `ExperimentConfig::from_kv` path:
//!   unknown keys get did-you-mean, `serve.*` keys are rejected — they
//!   configure the service, not a run), enqueue, and stream progress as
//!   newline-delimited JSON events until the job finishes:
//!   `{"event":"queued","job":N}` on acceptance,
//!   `{"event":"start","job":N}` when execution begins, one
//!   `{"event":"point",...}` per objective-curve point, and finally
//!   `{"event":"done","job":N,"run":{...}}` carrying the full `run_json`
//!   (including the job's `cache` meter delta), or
//!   `{"event":"error","job":N,"error":"..."}`. A malformed config is
//!   HTTP 400 before anything is queued; a full queue is HTTP 429.
//!   Curve points stream when the job completes (runs execute
//!   synchronously on the warm pool; points are not emitted mid-run).
//! - `GET /stats` — cumulative [`ServeStats`] as JSON: job counts, the
//!   executable-cache totals, the warm-runner cache meter, the resident
//!   runner key, and the cross-job wall-clock meter totals (`stalls` /
//!   `overlap` / `uploads` summed over every finished job's run record).
//! - `POST /shutdown` — drain the queue, stop accepting, and return from
//!   [`Server::run`] with the final stats.
//!
//! # Queue semantics
//!
//! One bounded FIFO queue (`serve.queue_depth`), one executor: the
//! thread that calls [`Server::run`] owns every engine (PJRT handles are
//! not `Send`, so runners never cross threads) and executes jobs
//! strictly in acceptance order. Job ids are assigned inside the enqueue
//! critical section, so id order IS queue order. A full queue rejects
//! immediately with 429 — clients retry; the service never blocks a
//! connection on another job's runtime.
//!
//! # What the cache key includes — and excludes
//!
//! Warm runners are keyed by [`cache::pool_key`](crate::runtime::cache):
//! the artifacts-dir content hash, the shard count and the process-level
//! plane/prefetch/pipeline policies. Method, b_local, seed, scenario and
//! every other experiment key are deliberately NOT in the key: they are
//! per-run state the resident runner rebuilds from scratch (its context
//! teardown resets sessions, meters and shard state between jobs), and
//! cross-plane bit-parity is unconditional. Compiled executables hash
//! (artifact bytes, manifest entry) — see `runtime::cache`.
//!
//! # What `CacheMeter` does NOT measure
//!
//! The meter counts host wall-clock only: compile time saved, hits,
//! misses, evictions. It never touches the simulated cost model —
//! rounds, vectors, samples, memory and the objective curve are charged
//! identically warm or cold, and a warm-cache run returns bit-identical
//! iterates/curves/paper-unit meters to a cold-process run
//! (`rust/tests/serve_parity.rs` pins this).

use crate::accounting::{CacheMeter, OverlapMeter, StallMeter, UploadMeter};
use crate::config::{ExperimentConfig, KvConfig, ServeConfig};
use crate::coordinator::{shards_from_env, Runner};
use crate::metrics::run_json;
use crate::runtime::cache::{manifest_hash, pool_key, KeyedCache};
use crate::runtime::{
    Engine, Manifest, PipelinePolicy, PlanePolicy, PrefetchPolicy, UploadPolicy,
};
use crate::util::json::escape_str;
use anyhow::{anyhow, Context, Result};
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{self, Receiver, Sender, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// How many warm runner instances stay resident at once. One per
/// cache-relevant config subset; within one server process the subset is
/// fixed by the artifacts dir and process env, so in practice a single
/// slot stays hot and the second is headroom.
const WARM_RUNNERS: usize = 2;

/// Per-connection socket timeout: a stalled peer must not pin a handler
/// thread forever. Generous — job streams only write when events arrive.
const IO_TIMEOUT: Duration = Duration::from_secs(600);

/// Cumulative service counters, rendered by `GET /stats` and returned by
/// [`Server::run`] at shutdown.
#[derive(Clone, Debug, Default)]
pub struct ServeStats {
    /// jobs accepted into the queue (each eventually done or failed)
    pub jobs_accepted: u64,
    /// jobs that ran to a `done` event
    pub jobs_done: u64,
    /// jobs that errored during execution (`error` event streamed)
    pub jobs_failed: u64,
    /// submissions rejected with 429 (bounded queue full)
    pub jobs_rejected: u64,
    /// executable-cache totals across all jobs (sum of per-job deltas)
    pub exec_cache: CacheMeter,
    /// warm-runner instance cache meter (misses = runner builds)
    pub runners: CacheMeter,
    /// draw dispatch-stall totals across all finished jobs (sharded-plane
    /// jobs only contribute; wall-clock diagnostics, never cost model)
    pub stalls: StallMeter,
    /// fan-pipeline overlap totals across all finished jobs
    pub overlap: OverlapMeter,
    /// upload-lane totals across all finished jobs (every plane
    /// contributes — the coordinator engine meters even without shards)
    pub uploads: UploadMeter,
}

impl ServeStats {
    pub fn to_json(&self, runner_key: &str, queue_capacity: usize) -> String {
        fn meter(c: &CacheMeter) -> String {
            format!(
                "{{\"hits\":{},\"misses\":{},\"compile_ns\":{},\"evictions\":{},\"hit_rate\":{}}}",
                c.hits,
                c.misses,
                c.compile_ns,
                c.evictions,
                c.hit_rate()
            )
        }
        format!(
            "{{\"jobs_accepted\":{},\"jobs_done\":{},\"jobs_failed\":{},\"jobs_rejected\":{},\
             \"queue_capacity\":{},\"exec_cache\":{},\"runners\":{},\
             \"stalls\":{{\"takes\":{},\"hits\":{},\"misses\":{},\"stall_ns\":{}}},\
             \"overlap\":{{\"fans\":{},\"staged\":{},\"overlap_ns\":{},\"serial_ns\":{}}},\
             \"uploads\":{{\"uploads\":{},\"staged\":{},\"overlap_ns\":{},\"wait_ns\":{},\
             \"bytes\":{}}},\"runner_key\":{}}}",
            self.jobs_accepted,
            self.jobs_done,
            self.jobs_failed,
            self.jobs_rejected,
            queue_capacity,
            meter(&self.exec_cache),
            meter(&self.runners),
            self.stalls.takes,
            self.stalls.hits,
            self.stalls.misses,
            self.stalls.stall_ns,
            self.overlap.fans,
            self.overlap.staged,
            self.overlap.overlap_ns,
            self.overlap.serial_ns,
            self.uploads.uploads,
            self.uploads.staged,
            self.uploads.overlap_ns,
            self.uploads.wait_ns,
            self.uploads.bytes,
            escape_str(runner_key),
        )
    }
}

/// One accepted unit of work, or the shutdown marker.
enum Job {
    Run { id: u64, kv: KvConfig, events: Sender<String> },
    Shutdown,
}

/// The enqueue critical section: id assignment and `try_send` happen
/// under one lock so job-id order is exactly queue order.
struct Enqueue {
    tx: SyncSender<Job>,
    next_id: u64,
}

/// The run service. [`Server::bind`] claims the port (0 = OS-assigned,
/// queryable via [`Server::addr`] — the tests' and benches' form);
/// [`Server::run`] serves until `POST /shutdown`.
pub struct Server {
    cfg: ServeConfig,
    artifacts_dir: PathBuf,
    listener: TcpListener,
    addr: SocketAddr,
}

impl Server {
    pub fn bind(cfg: &ServeConfig, artifacts_dir: &Path) -> Result<Server> {
        let listener = TcpListener::bind(("127.0.0.1", cfg.port))
            .with_context(|| format!("binding serve port {}", cfg.port))?;
        let addr = listener.local_addr().context("resolving bound serve address")?;
        Ok(Server { cfg: cfg.clone(), artifacts_dir: artifacts_dir.to_path_buf(), listener, addr })
    }

    /// The bound address (resolves `serve.port = 0` to the real port).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Serve until `POST /shutdown`, then return the final stats. The
    /// calling thread becomes the executor and owns every engine; accept
    /// and per-connection streaming run on companion threads.
    pub fn run(self) -> Result<ServeStats> {
        let Server { cfg, artifacts_dir, listener, addr } = self;
        let stats = Arc::new(Mutex::new(ServeStats::default()));
        let stopping = Arc::new(AtomicBool::new(false));
        let (tx, rx) = mpsc::sync_channel::<Job>(cfg.queue_depth);
        let enqueue = Arc::new(Mutex::new(Enqueue { tx, next_id: 1 }));
        let runner_key = resident_runner_key(&artifacts_dir)?;

        let accept = {
            let enqueue = Arc::clone(&enqueue);
            let stats = Arc::clone(&stats);
            let stopping = Arc::clone(&stopping);
            let runner_key = runner_key.clone();
            let queue_depth = cfg.queue_depth;
            std::thread::Builder::new().name("serve-accept".into()).spawn(move || {
                for conn in listener.incoming() {
                    if stopping.load(Ordering::SeqCst) {
                        break;
                    }
                    let stream = match conn {
                        Ok(s) => s,
                        Err(_) => continue,
                    };
                    let enqueue = Arc::clone(&enqueue);
                    let stats = Arc::clone(&stats);
                    let stopping = Arc::clone(&stopping);
                    let runner_key = runner_key.clone();
                    let _ = std::thread::Builder::new().name("serve-conn".into()).spawn(
                        move || {
                            if let Err(e) = handle_connection(
                                stream,
                                &enqueue,
                                &stats,
                                &stopping,
                                &runner_key,
                                queue_depth,
                            ) {
                                eprintln!("serve: connection error: {e:#}");
                            }
                        },
                    );
                }
            })?
        };

        // the executor: this thread owns the warm runners (PJRT handles
        // are not Send) and drains the FIFO strictly in id order
        let mut runners: KeyedCache<Runner> = KeyedCache::new(WARM_RUNNERS);
        while let Ok(job) = rx.recv() {
            match job {
                Job::Shutdown => break,
                Job::Run { id, kv, events } => {
                    let _ = events.send(format!("{{\"event\":\"start\",\"job\":{id}}}"));
                    let outcome =
                        execute_job(id, &kv, &runner_key, &cfg, &artifacts_dir, &mut runners, &events);
                    let mut st = stats.lock().unwrap();
                    st.runners = runners.meter.clone();
                    match outcome {
                        Ok(json) => {
                            st.jobs_done += 1;
                            if let Some(delta) = last_run_cache_delta(&json) {
                                st.exec_cache.merge(&delta);
                            }
                            let (stalls, overlap, uploads) = last_run_meters(&json);
                            if let Some(s) = stalls {
                                st.stalls.merge(&s);
                            }
                            if let Some(o) = overlap {
                                st.overlap.merge(&o);
                            }
                            if let Some(u) = uploads {
                                st.uploads.merge(&u);
                            }
                            drop(st);
                            let _ = events
                                .send(format!("{{\"event\":\"done\",\"job\":{id},\"run\":{json}}}"));
                        }
                        Err(e) => {
                            st.jobs_failed += 1;
                            drop(st);
                            let msg = escape_str(&format!("{e:#}"));
                            let _ = events
                                .send(format!("{{\"event\":\"error\",\"job\":{id},\"error\":{msg}}}"));
                        }
                    }
                    // dropping `events` ends the client's stream
                }
            }
        }

        stopping.store(true, Ordering::SeqCst);
        let _ = TcpStream::connect(addr); // wake the accept loop
        let _ = accept.join();
        let final_stats = stats.lock().unwrap().clone();
        Ok(final_stats)
    }
}

/// The resident-runner cache key for this process: artifacts-dir content
/// hash + shard count + process-level plane/prefetch/pipeline/upload
/// policies (see the module doc for what is deliberately excluded).
fn resident_runner_key(artifacts_dir: &Path) -> Result<String> {
    let manifest = Manifest::load(artifacts_dir)?;
    Ok(pool_key(
        manifest_hash(&manifest)?,
        shards_from_env()?.unwrap_or(0),
        PlanePolicy::from_env()?,
        PrefetchPolicy::from_env()?,
        PipelinePolicy::from_env()?,
        UploadPolicy::from_env()?,
    ))
}

/// Run one job on the warm runner for `runner_key`, building (and
/// capacity-capping) it on first use. Streams one `point` event per
/// objective-curve point, then returns the run's `run_json`.
fn execute_job(
    id: u64,
    kv: &KvConfig,
    runner_key: &str,
    cfg: &ServeConfig,
    artifacts_dir: &Path,
    runners: &mut KeyedCache<Runner>,
    events: &Sender<String>,
) -> Result<String> {
    let exp = ExperimentConfig::from_kv(kv)?;
    let cache_capacity = cfg.cache_capacity;
    let dir = artifacts_dir.to_path_buf();
    let runner = runners.get_or_try_insert_with(runner_key, || {
        let mut r = Runner::new(Engine::new(&dir)?)
            .with_env_shards(&dir)?
            .with_env_plane()?
            .with_env_prefetch()?
            .with_env_pipeline()?
            .with_env_upload()?;
        if let Some(cap) = cache_capacity {
            r.set_exec_cache_capacity(cap)?;
        }
        Ok(r)
    })?;
    let result = runner.run(&exp)?;
    for p in &result.curve {
        let obj = p.objective.map(|o| o.to_string()).unwrap_or_else(|| "null".into());
        let _ = events.send(format!(
            "{{\"event\":\"point\",\"job\":{id},\"t\":{},\"samples\":{},\"rounds\":{},\
             \"objective\":{obj}}}",
            p.outer_iter, p.samples_total, p.comm_rounds
        ));
    }
    Ok(run_json(&result))
}

/// Extract the `cache` meter delta back out of a rendered `run_json`
/// (the executor aggregates per-job deltas into the service totals
/// without holding a second copy of the result).
fn last_run_cache_delta(json: &str) -> Option<CacheMeter> {
    let v = crate::util::json::Json::parse(json).ok()?;
    let c = v.get("cache")?;
    Some(CacheMeter {
        hits: c.get("hits")?.as_f64()? as u64,
        misses: c.get("misses")?.as_f64()? as u64,
        compile_ns: c.get("compile_ns")?.as_f64()? as u64,
        evictions: c.get("evictions")?.as_f64()? as u64,
    })
}

/// Extract the per-job wall-clock meters (`stalls` / `overlap` /
/// `uploads`) back out of a rendered `run_json` — the `GET /stats`
/// aggregation's read side, mirroring [`last_run_cache_delta`]. A `null`
/// section (e.g. `stalls` off the sharded plane) contributes nothing.
fn last_run_meters(
    json: &str,
) -> (Option<StallMeter>, Option<OverlapMeter>, Option<UploadMeter>) {
    let v = match crate::util::json::Json::parse(json) {
        Ok(v) => v,
        Err(_) => return (None, None, None),
    };
    let stalls = v.get("stalls").and_then(|s| {
        Some(StallMeter {
            takes: s.get("takes")?.as_f64()? as u64,
            hits: s.get("hits")?.as_f64()? as u64,
            misses: s.get("misses")?.as_f64()? as u64,
            stall_ns: s.get("stall_ns")?.as_f64()? as u64,
        })
    });
    let overlap = v.get("overlap").and_then(|o| {
        Some(OverlapMeter {
            fans: o.get("fans")?.as_f64()? as u64,
            staged: o.get("staged")?.as_f64()? as u64,
            overlap_ns: o.get("overlap_ns")?.as_f64()? as u64,
            serial_ns: o.get("serial_ns")?.as_f64()? as u64,
        })
    });
    let uploads = v.get("uploads").and_then(|u| {
        Some(UploadMeter {
            uploads: u.get("uploads")?.as_f64()? as u64,
            staged: u.get("staged")?.as_f64()? as u64,
            overlap_ns: u.get("overlap_ns")?.as_f64()? as u64,
            wait_ns: u.get("wait_ns")?.as_f64()? as u64,
            bytes: u.get("bytes")?.as_f64()? as u64,
        })
    });
    (stalls, overlap, uploads)
}

/// One parsed HTTP request (the tiny subset the wire format needs).
struct Request {
    method: String,
    path: String,
    body: String,
}

fn read_request(stream: &mut TcpStream) -> Result<Request> {
    let mut reader = BufReader::new(stream.try_clone().context("cloning connection")?);
    let mut line = String::new();
    reader.read_line(&mut line).context("reading request line")?;
    let mut parts = line.split_whitespace();
    let method = parts.next().unwrap_or("").to_uppercase();
    let path = parts.next().unwrap_or("").to_string();
    if method.is_empty() || path.is_empty() {
        anyhow::bail!("malformed request line {line:?}");
    }
    let mut content_length = 0usize;
    let mut expects_continue = false;
    loop {
        let mut h = String::new();
        reader.read_line(&mut h).context("reading header")?;
        let h = h.trim();
        if h.is_empty() {
            break;
        }
        if let Some((name, value)) = h.split_once(':') {
            let name = name.trim().to_ascii_lowercase();
            let value = value.trim();
            if name == "content-length" {
                content_length =
                    value.parse().with_context(|| format!("Content-Length {value:?}"))?;
            } else if name == "expect" && value.eq_ignore_ascii_case("100-continue") {
                expects_continue = true;
            }
        }
    }
    if expects_continue {
        stream
            .write_all(b"HTTP/1.1 100 Continue\r\n\r\n")
            .context("writing 100 Continue")?;
    }
    anyhow::ensure!(content_length <= 1 << 20, "request body too large ({content_length} bytes)");
    let mut body = vec![0u8; content_length];
    reader.read_exact(&mut body).context("reading request body")?;
    Ok(Request { method, path, body: String::from_utf8_lossy(&body).into_owned() })
}

fn respond(stream: &mut TcpStream, status: u16, reason: &str, body: &str) -> Result<()> {
    let head = format!(
        "HTTP/1.1 {status} {reason}\r\nContent-Type: application/json\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(body.as_bytes())?;
    stream.flush()?;
    Ok(())
}

fn handle_connection(
    mut stream: TcpStream,
    enqueue: &Mutex<Enqueue>,
    stats: &Mutex<ServeStats>,
    stopping: &AtomicBool,
    runner_key: &str,
    queue_depth: usize,
) -> Result<()> {
    let _ = stream.set_read_timeout(Some(IO_TIMEOUT));
    let _ = stream.set_write_timeout(Some(IO_TIMEOUT));
    let req = read_request(&mut stream)?;
    match (req.method.as_str(), req.path.as_str()) {
        ("POST", "/run") if stopping.load(Ordering::SeqCst) => respond(
            &mut stream,
            503,
            "Service Unavailable",
            "{\"error\":\"server is shutting down\"}",
        ),
        ("POST", "/run") => handle_run(stream, &req.body, enqueue, stats, queue_depth),
        ("GET", "/stats") => {
            let body = stats.lock().unwrap().to_json(runner_key, queue_depth);
            respond(&mut stream, 200, "OK", &body)
        }
        ("POST", "/shutdown") => {
            stopping.store(true, Ordering::SeqCst);
            // blocking send: shutdown queues behind accepted jobs, so
            // every already-queued run still streams its result
            let tx = enqueue.lock().unwrap().tx.clone();
            tx.send(Job::Shutdown).map_err(|_| anyhow!("executor is gone"))?;
            respond(&mut stream, 200, "OK", "{\"ok\":true}")
        }
        (_, "/run") | (_, "/stats") | (_, "/shutdown") => respond(
            &mut stream,
            405,
            "Method Not Allowed",
            "{\"error\":\"use POST /run, GET /stats, POST /shutdown\"}",
        ),
        _ => respond(&mut stream, 404, "Not Found", "{\"error\":\"unknown path\"}"),
    }
}

fn handle_run(
    mut stream: TcpStream,
    body: &str,
    enqueue: &Mutex<Enqueue>,
    stats: &Mutex<ServeStats>,
    queue_depth: usize,
) -> Result<()> {
    // validate BEFORE queueing: a malformed config must not occupy a slot
    let kv = match KvConfig::parse(body) {
        Ok(kv) => kv,
        Err(e) => {
            let msg = format!("{{\"error\":{}}}", escape_str(&format!("{e:#}")));
            return respond(&mut stream, 400, "Bad Request", &msg);
        }
    };
    if let Err(e) = ExperimentConfig::from_kv(&kv) {
        let msg = format!("{{\"error\":{}}}", escape_str(&format!("{e:#}")));
        return respond(&mut stream, 400, "Bad Request", &msg);
    }
    let (ev_tx, ev_rx): (Sender<String>, Receiver<String>) = mpsc::channel();
    let id = {
        let mut q = enqueue.lock().unwrap();
        let id = q.next_id;
        match q.tx.try_send(Job::Run { id, kv, events: ev_tx }) {
            Ok(()) => {
                q.next_id += 1;
                drop(q);
                stats.lock().unwrap().jobs_accepted += 1;
                id
            }
            Err(TrySendError::Full(_)) => {
                drop(q);
                stats.lock().unwrap().jobs_rejected += 1;
                let msg = format!(
                    "{{\"error\":\"job queue full (serve.queue_depth={queue_depth}); retry\"}}"
                );
                return respond(&mut stream, 429, "Too Many Requests", &msg);
            }
            Err(TrySendError::Disconnected(_)) => {
                return respond(
                    &mut stream,
                    503,
                    "Service Unavailable",
                    "{\"error\":\"executor is gone\"}",
                );
            }
        }
    };
    // accepted: stream ndjson events until the executor drops our sender
    stream.write_all(
        b"HTTP/1.1 200 OK\r\nContent-Type: application/x-ndjson\r\nConnection: close\r\n\r\n",
    )?;
    stream.write_all(format!("{{\"event\":\"queued\",\"job\":{id}}}\n").as_bytes())?;
    stream.flush()?;
    while let Ok(line) = ev_rx.recv() {
        stream.write_all(line.as_bytes())?;
        stream.write_all(b"\n")?;
        stream.flush()?;
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Tiny blocking HTTP client — shared by the integration tests, the
// concurrent-clients bench scenario and ad-hoc scripting. Not a general
// client: it speaks exactly the dialect the server above emits
// (Connection: close, response terminated by EOF).

/// A streaming response: status line parsed, body readable line-by-line
/// (the `/run` ndjson event stream).
pub struct HttpStream {
    pub status: u16,
    reader: BufReader<TcpStream>,
}

impl HttpStream {
    /// Next body line, `None` at end of stream.
    pub fn next_line(&mut self) -> Option<String> {
        let mut line = String::new();
        match self.reader.read_line(&mut line) {
            Ok(0) | Err(_) => None,
            Ok(_) => Some(line.trim_end().to_string()),
        }
    }

    /// Drain the remaining body.
    pub fn read_to_end(mut self) -> String {
        let mut out = String::new();
        while let Some(l) = self.next_line() {
            out.push_str(&l);
            out.push('\n');
        }
        out
    }
}

/// Open a request and return once the response HEAD is parsed; the body
/// streams through the returned [`HttpStream`].
pub fn http_request(
    addr: SocketAddr,
    method: &str,
    path: &str,
    body: &str,
) -> Result<HttpStream> {
    let mut stream = TcpStream::connect(addr).with_context(|| format!("connecting {addr}"))?;
    let _ = stream.set_read_timeout(Some(IO_TIMEOUT));
    let req = format!(
        "{method} {path} HTTP/1.1\r\nHost: {addr}\r\nContent-Length: {}\r\n\
         Connection: close\r\n\r\n{body}",
        body.len()
    );
    stream.write_all(req.as_bytes()).context("writing request")?;
    stream.flush()?;
    let mut reader = BufReader::new(stream);
    let mut status_line = String::new();
    reader.read_line(&mut status_line).context("reading status line")?;
    let status: u16 = status_line
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| anyhow!("malformed status line {status_line:?}"))?;
    loop {
        let mut h = String::new();
        reader.read_line(&mut h).context("reading response header")?;
        if h.trim().is_empty() {
            break;
        }
    }
    Ok(HttpStream { status, reader })
}

/// POST and drain: returns `(status, full body)`.
pub fn http_post(addr: SocketAddr, path: &str, body: &str) -> Result<(u16, String)> {
    let s = http_request(addr, "POST", path, body)?;
    let status = s.status;
    Ok((status, s.read_to_end()))
}

/// GET and drain: returns `(status, full body)`.
pub fn http_get(addr: SocketAddr, path: &str) -> Result<(u16, String)> {
    let s = http_request(addr, "GET", path, "")?;
    let status = s.status;
    Ok((status, s.read_to_end()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serve_stats_json_is_parseable() {
        let mut st = ServeStats::default();
        st.jobs_accepted = 3;
        st.jobs_done = 2;
        st.jobs_rejected = 1;
        st.exec_cache.record_miss(500);
        st.exec_cache.record_hit();
        st.runners.record_miss(9);
        st.stalls.record(true, 120);
        st.overlap.fans = 2;
        st.overlap.record(true, 300);
        st.uploads.record(true, 5, 1280, 900);
        st.uploads.add_wait(40);
        let j = st.to_json(
            "artifacts=00;shards=0;plane=auto;prefetch=auto;pipeline=auto;upload=auto",
            4,
        );
        let v = crate::util::json::Json::parse(&j).expect("valid json");
        assert_eq!(v.get("jobs_accepted").unwrap().as_usize(), Some(3));
        assert_eq!(v.get("jobs_rejected").unwrap().as_usize(), Some(1));
        assert_eq!(v.get("queue_capacity").unwrap().as_usize(), Some(4));
        let c = v.get("exec_cache").unwrap();
        assert_eq!(c.get("hits").unwrap().as_usize(), Some(1));
        assert_eq!(c.get("misses").unwrap().as_usize(), Some(1));
        assert_eq!(c.get("hit_rate").unwrap().as_f64(), Some(0.5));
        let s = v.get("stalls").unwrap();
        assert_eq!(s.get("takes").unwrap().as_usize(), Some(1));
        assert_eq!(s.get("stall_ns").unwrap().as_usize(), Some(120));
        let o = v.get("overlap").unwrap();
        assert_eq!(o.get("fans").unwrap().as_usize(), Some(2));
        assert_eq!(o.get("overlap_ns").unwrap().as_usize(), Some(300));
        let u = v.get("uploads").unwrap();
        assert_eq!(u.get("uploads").unwrap().as_usize(), Some(5));
        assert_eq!(u.get("staged").unwrap().as_usize(), Some(5));
        assert_eq!(u.get("overlap_ns").unwrap().as_usize(), Some(900));
        assert_eq!(u.get("wait_ns").unwrap().as_usize(), Some(40));
        assert_eq!(u.get("bytes").unwrap().as_usize(), Some(1280));
        assert!(v.get("runner_key").unwrap().as_str().unwrap().contains("upload=auto"));
    }

    #[test]
    fn cache_delta_round_trips_through_run_json() {
        // the executor's stats aggregation reads the delta back out of
        // the rendered run_json; the formats must stay in sync
        let json = "{\"cache\": {\"hits\": 4, \"misses\": 2, \"compile_ns\": 77, \
                     \"evictions\": 1, \"hit_rate\": 0.6666}, \"curve\": []}";
        let d = last_run_cache_delta(json).expect("delta parses");
        assert_eq!(d, CacheMeter { hits: 4, misses: 2, compile_ns: 77, evictions: 1 });
        assert_eq!(last_run_cache_delta("{\"cache\": null}"), None);
    }

    #[test]
    fn meters_round_trip_through_run_json() {
        // same contract as the cache delta: /stats aggregation reads the
        // per-job meters back out of the rendered run_json
        let json = "{\"stalls\": {\"takes\": 8, \"hits\": 6, \"misses\": 2, \
                     \"stall_ns\": 1500, \"hit_rate\": 0.75}, \
                     \"overlap\": {\"fans\": 4, \"staged\": 3, \"overlap_ns\": 900, \
                     \"serial_ns\": 300, \"overlap_frac\": 0.75}, \
                     \"uploads\": {\"uploads\": 10, \"staged\": 7, \"overlap_ns\": 1200, \
                     \"wait_ns\": 400, \"bytes\": 2560}, \"curve\": []}";
        let (s, o, u) = last_run_meters(json);
        assert_eq!(s, Some(StallMeter { takes: 8, hits: 6, misses: 2, stall_ns: 1500 }));
        assert_eq!(o, Some(OverlapMeter { fans: 4, staged: 3, overlap_ns: 900, serial_ns: 300 }));
        let want =
            UploadMeter { uploads: 10, staged: 7, overlap_ns: 1200, wait_ns: 400, bytes: 2560 };
        assert_eq!(u, Some(want));
        // null sections (host/chained planes) contribute nothing
        let none = "{\"stalls\": null, \"overlap\": null, \"uploads\": null}";
        let (s, o, u) = last_run_meters(none);
        assert_eq!((s, o, u), (None, None, None));
    }
}
