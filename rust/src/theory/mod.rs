//! Closed-form parameter selection and resource predictions from the
//! paper's theory (Theorems 4/5/7/8/10/14/16, Tables 1–2).
//!
//! Everything is expressed in the paper's primitives: Lipschitz constant
//! `L`, norm bound `B`, smoothness `beta`, machines `m`, target accuracy
//! `eps`. Algorithms take their stepsizes/loop counts from here; the
//! table/figure benches print these predictions next to the measured
//! counters so paper-vs-measured comparisons are mechanical.

/// Problem-level constants for the theory formulas.
#[derive(Clone, Copy, Debug)]
pub struct ProblemConsts {
    pub l_lipschitz: f64,
    pub b_norm: f64,
    pub beta_smooth: f64,
    pub m: usize,
}

impl ProblemConsts {
    /// Statistically optimal sample complexity `n(eps) = L^2 B^2 / eps^2`.
    pub fn n_eps(&self, eps: f64) -> f64 {
        let lb = self.l_lipschitz * self.b_norm;
        (lb / eps).powi(2)
    }

    /// Inverse: accuracy achievable from n samples, `eps(n) = LB/sqrt(n)`.
    pub fn eps_of_n(&self, n: f64) -> f64 {
        self.l_lipschitz * self.b_norm / n.sqrt()
    }
}

/// Minibatch-prox outer-loop parameters (Theorem 7 / Theorem 10).
#[derive(Clone, Copy, Debug)]
pub struct MbProxPlan {
    /// outer iterations T = n / (b m)
    pub t_outer: usize,
    /// prox regularization gamma = sqrt(8 T / (b m)) * L / B
    pub gamma: f64,
    /// global minibatch size per outer iteration (b m)
    pub bm: usize,
}

/// Plan the outer loop for total sample budget `n`, per-machine minibatch
/// `b_local`, `m` machines.
pub fn mbprox_plan(c: &ProblemConsts, n: f64, b_local: usize) -> MbProxPlan {
    let bm = b_local * c.m;
    let t = (n / bm as f64).max(1.0);
    let gamma = (8.0 * t / bm as f64).sqrt() * c.l_lipschitz / c.b_norm;
    MbProxPlan { t_outer: t.round() as usize, gamma, bm }
}

/// MP-DSVRG inner-loop parameters (Theorem 10).
#[derive(Clone, Copy, Debug)]
pub struct DsvrgPlan {
    /// DSVRG iterations per prox solve, K = O(log n)
    pub k_inner: usize,
    /// local batches per machine, p_i: one pass over b/p samples per inner
    /// iteration suffices to contract by a constant factor
    pub p_batches: usize,
    /// SVRG stepsize eta = c / (beta + gamma)
    pub eta: f64,
}

pub fn dsvrg_plan(c: &ProblemConsts, plan: &MbProxPlan, b_local: usize, n: f64) -> DsvrgPlan {
    // condition number of the prox subproblem
    let kappa = (c.beta_smooth + plan.gamma) / plan.gamma;
    // batch size >= condition number => p = floor(b / kappa), at least 1
    let p = ((b_local as f64) / kappa).floor().max(1.0) as usize;
    let k = (n.max(2.0).ln()).ceil() as usize;
    DsvrgPlan { k_inner: k.max(1), p_batches: p, eta: 0.1 / (c.beta_smooth + plan.gamma) }
}

/// MP-DANE parameters (Theorems 14/16). `b_star` splits the two regimes.
#[derive(Clone, Copy, Debug)]
pub struct DanePlan {
    pub kappa: f64,
    pub r_outer: usize,
    pub k_inner: usize,
    pub b_star: f64,
}

pub fn dane_b_star(c: &ProblemConsts, n: f64, d: usize) -> f64 {
    let log_md = ((c.m * d).max(2) as f64).ln();
    n * c.l_lipschitz.powi(2)
        / (32.0 * (c.m as f64).powi(2) * c.beta_smooth.powi(2) * c.b_norm.powi(2) * log_md)
}

pub fn dane_plan(c: &ProblemConsts, plan: &MbProxPlan, b_local: usize, n: f64, d: usize) -> DanePlan {
    let b_star = dane_b_star(c, n, d);
    let log_n = n.max(2.0).ln();
    if (b_local as f64) <= b_star {
        DanePlan { kappa: 0.0, r_outer: 1, k_inner: log_n.ceil() as usize, b_star }
    } else {
        let log_dm = ((c.m * d).max(2) as f64).ln();
        let kappa =
            (16.0 * c.beta_smooth * (log_dm / b_local as f64).sqrt() - plan.gamma).max(0.0);
        let r = ((b_local as f64).powf(0.25) * (c.m as f64).sqrt()
            * (c.beta_smooth * c.b_norm).sqrt()
            / (n.powf(0.25) * c.l_lipschitz.sqrt())
            * log_n)
            .ceil()
            .max(1.0) as usize;
        DanePlan { kappa, r_outer: r, k_inner: log_n.ceil() as usize, b_star }
    }
}

/// Minibatch SGD stepsize (Proposition 13): gamma_t = beta + sqrt(4T/b)·L/B
/// (inverse stepsize). Returns gamma (use step 1/gamma).
pub fn minibatch_sgd_gamma(c: &ProblemConsts, t_total: usize, bm: usize) -> f64 {
    c.beta_smooth + (4.0 * t_total as f64 / bm as f64).sqrt() * c.l_lipschitz / c.b_norm
}

/// Cotter et al. maximal minibatch size for accelerated minibatch SGD:
/// bm_max ≍ n^{3/4} / sqrt(B) (total across machines).
pub fn accel_sgd_max_bm(c: &ProblemConsts, n: f64) -> f64 {
    n.powf(0.75) / c.b_norm.sqrt()
}

/// ERM regularization for the batch methods (§1): nu = L/(B sqrt(n)).
pub fn erm_nu(c: &ProblemConsts, n: f64) -> f64 {
    c.l_lipschitz / (c.b_norm * n.sqrt())
}

/// Table-1 predicted resources (per machine, ignoring constants/logs).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PredictedRow {
    pub communication: f64,
    pub computation: f64,
    pub memory: f64,
}

pub fn predict_mp_dsvrg(c: &ProblemConsts, n: f64, b_local: usize) -> PredictedRow {
    let log_n = n.max(2.0).ln();
    PredictedRow {
        communication: n / (c.m as f64 * b_local as f64) * log_n,
        computation: n / c.m as f64 * log_n,
        memory: b_local as f64,
    }
}

pub fn predict_dsvrg_erm(c: &ProblemConsts, n: f64) -> PredictedRow {
    let log_n = n.max(2.0).ln();
    PredictedRow {
        communication: log_n, // O(1) iterations x O(1) rounds, up to log factors
        computation: n / c.m as f64 * log_n,
        memory: n / c.m as f64,
    }
}

pub fn predict_acc_minibatch_sgd(c: &ProblemConsts, n: f64) -> PredictedRow {
    PredictedRow {
        communication: c.b_norm.sqrt() * n.powf(0.25),
        computation: n / c.m as f64,
        memory: 1.0,
    }
}

pub fn predict_mp_dane(c: &ProblemConsts, n: f64, b_local: usize, d: usize) -> PredictedRow {
    let b_star = dane_b_star(c, n, d);
    let m = c.m as f64;
    let b = b_local as f64;
    if b <= b_star {
        PredictedRow { communication: n / (m * b), computation: n / m, memory: b }
    } else {
        PredictedRow {
            communication: c.b_norm.sqrt() * n.powf(0.75) / (m.sqrt() * b.powf(0.75)),
            computation: c.b_norm.sqrt() * n.powf(0.75) * b.powf(0.25) / m.sqrt(),
            memory: b,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn consts() -> ProblemConsts {
        ProblemConsts { l_lipschitz: 1.0, b_norm: 1.0, beta_smooth: 1.0, m: 4 }
    }

    #[test]
    fn n_eps_round_trip() {
        let c = consts();
        let n = c.n_eps(0.01);
        assert!((c.eps_of_n(n) - 0.01).abs() < 1e-12);
        assert_eq!(n, 10_000.0);
    }

    #[test]
    fn mbprox_plan_respects_bt_product() {
        let c = consts();
        let n = 65_536.0;
        for b in [16usize, 64, 256] {
            let p = mbprox_plan(&c, n, b);
            // T * b * m == n
            assert_eq!(p.t_outer * b * c.m, n as usize);
            // gamma = sqrt(8T/(bm)) L/B
            let expect = (8.0 * p.t_outer as f64 / p.bm as f64).sqrt();
            assert!((p.gamma - expect).abs() < 1e-9);
        }
    }

    #[test]
    fn gamma_decreases_with_b() {
        let c = consts();
        let n = 65_536.0;
        let g1 = mbprox_plan(&c, n, 16).gamma;
        let g2 = mbprox_plan(&c, n, 256).gamma;
        assert!(g2 < g1);
    }

    #[test]
    fn dsvrg_plan_batches_shrink_with_conditioning() {
        let c = consts();
        let n = 65_536.0;
        let plan_small_b = mbprox_plan(&c, n, 64);
        let ds = dsvrg_plan(&c, &plan_small_b, 64, n);
        assert!(ds.k_inner >= 1);
        assert!(ds.p_batches >= 1);
        assert!(ds.eta > 0.0 && ds.eta < 1.0);
    }

    #[test]
    fn dane_regimes_split_at_b_star() {
        let c = consts();
        let n = 1.0e6;
        let d = 64;
        let b_star = dane_b_star(&c, n, d);
        assert!(b_star > 0.0);
        let below = dane_plan(&c, &mbprox_plan(&c, n, (b_star * 0.5) as usize), (b_star * 0.5) as usize, n, d);
        assert_eq!(below.kappa, 0.0);
        assert_eq!(below.r_outer, 1);
        let above_b = (b_star * 4.0) as usize;
        let above = dane_plan(&c, &mbprox_plan(&c, n, above_b), above_b, n, d);
        assert!(above.r_outer >= 1);
    }

    #[test]
    fn predictions_have_paper_shapes() {
        let c = consts();
        let n = 1.0e6;
        // MP-DSVRG communication falls linearly in b; memory rises linearly
        let p1 = predict_mp_dsvrg(&c, n, 100);
        let p2 = predict_mp_dsvrg(&c, n, 1000);
        assert!((p1.communication / p2.communication - 10.0).abs() < 1e-9);
        assert!((p2.memory / p1.memory - 10.0).abs() < 1e-9);
        // computation independent of b
        assert!((p1.computation - p2.computation).abs() < 1e-9);
        // DSVRG-ERM memory = n/m
        assert_eq!(predict_dsvrg_erm(&c, n).memory, n / 4.0);
    }

    #[test]
    fn sgd_gamma_exceeds_beta() {
        let c = consts();
        assert!(minibatch_sgd_gamma(&c, 100, 64) > c.beta_smooth);
    }

    #[test]
    fn erm_nu_scales_inverse_sqrt_n() {
        let c = consts();
        assert!((erm_nu(&c, 10_000.0) - 0.01).abs() < 1e-12);
    }
}
