//! mbprox CLI — run distributed stochastic optimization experiments.
//!
//! Usage:
//!   mbprox run   [key=value ...]        run one method (see --help)
//!   mbprox sweep [key=value ...]        sweep b_local over a log grid
//!   mbprox list                         list registered methods
//!   mbprox info                         engine / artifact information
//!
//! Common keys: method, m, b_local, n_budget, loss (sq|log), dim, seed,
//! eval_samples, eval_every, dataset (codrna|covtype|kddcup99|year),
//! config=<path> loads a key=value file first.

use anyhow::{anyhow, Result};
use mbprox::config::{ExperimentConfig, KvConfig};
use mbprox::coordinator::{Runner, METHODS};
use mbprox::metrics;

fn parse_cfg(args: &[String]) -> Result<ExperimentConfig> {
    let mut kv = KvConfig::default();
    // load config file first if given
    for a in args {
        if let Some(path) = a.strip_prefix("config=") {
            kv = KvConfig::load(std::path::Path::new(path))?;
        }
    }
    let overrides: Vec<String> =
        args.iter().filter(|a| !a.starts_with("config=")).cloned().collect();
    let kv = ExperimentConfig::apply_overrides(kv, &overrides)?;
    ExperimentConfig::from_kv(&kv)
}

fn cmd_run(args: &[String]) -> Result<()> {
    let cfg = parse_cfg(args)?;
    let mut runner = Runner::from_env()?;
    eprintln!(
        "# engine platform={} artifacts={}",
        runner.engine.platform(),
        runner.engine.manifest().artifacts.len()
    );
    let result = runner.run(&cfg)?;
    print!("{}", metrics::resource_table(&[&result]));
    if !result.curve.is_empty() {
        println!("\n# trajectory");
        print!("{}", metrics::curve_csv(&result));
    }
    Ok(())
}

fn cmd_sweep(args: &[String]) -> Result<()> {
    let base = parse_cfg(args)?;
    let mut runner = Runner::from_env()?;
    let mut results = Vec::new();
    let mut b = 64usize;
    let b_max = base.n_budget / base.m;
    while b <= b_max {
        let cfg = ExperimentConfig { b_local: b, ..base.clone() };
        match runner.run(&cfg) {
            Ok(r) => results.push(r),
            Err(e) => eprintln!("b={b}: {e}"),
        }
        b *= 4;
    }
    let refs: Vec<&_> = results.iter().collect();
    print!("{}", metrics::resource_table(&refs));
    Ok(())
}

fn cmd_info() -> Result<()> {
    let runner = Runner::from_env()?;
    let m = runner.engine.manifest();
    println!("platform: {}", runner.engine.platform());
    println!("artifacts dir: {}", m.dir.display());
    println!("block rows: {}", m.block);
    println!("dims: {:?}", m.dims);
    for a in &m.artifacts {
        println!("  {:<16} kind={:?} d={} outputs={:?}", a.name, a.kind, a.d, a.outputs);
    }
    Ok(())
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(String::as_str) {
        Some("run") => cmd_run(&args[1..]),
        Some("sweep") => cmd_sweep(&args[1..]),
        Some("list") => {
            for m in METHODS {
                println!("{m}");
            }
            Ok(())
        }
        Some("info") => cmd_info(),
        Some("help") | Some("--help") | None => {
            println!(
                "mbprox — Minibatch-Prox distributed stochastic optimization\n\n\
                 subcommands:\n  run [key=value ...]\n  sweep [key=value ...]\n  list\n  info\n\n\
                 keys: method m b_local n_budget loss dim seed eval_samples eval_every dataset\n\
                 methods: {}",
                METHODS.join(" ")
            );
            Ok(())
        }
        Some(other) => Err(anyhow!("unknown subcommand '{other}' (try help)")),
    }
}
