//! mbprox CLI — run distributed stochastic optimization experiments.
//!
//! Usage:
//!   mbprox run   [key=value ...]        run one method (see run --help)
//!   mbprox sweep [key=value ...]        sweep b_local over a log grid
//!   mbprox serve [serve.key=value ...]  persistent run service (serve --help)
//!   mbprox list                         list methods + accepted keys
//!   mbprox info                         engine / artifact information
//!
//! Configuration is `key = value` pairs (`config=<path>` loads a file
//! first); the accepted key set is `config::CONFIG_KEYS` — unknown keys
//! are rejected with a did-you-mean suggestion. The `plane=` key (or the
//! `PLANE` env var) selects the execution plane: `auto` (sharded when
//! `SHARDS` attaches a pool, chained otherwise), `host` (legacy
//! per-block), `chained` (single-engine device-resident), or `sharded`
//! (engine-per-worker). All planes produce the same results with
//! identical paper-units accounting — see `runtime::plane`.

use anyhow::{anyhow, Result};
use mbprox::config::{ExperimentConfig, KvConfig, ServeConfig, CONFIG_KEYS};
use mbprox::coordinator::{Runner, METHODS};
use mbprox::data::scenario::SCENARIOS;
use mbprox::metrics;
use mbprox::runtime::default_artifacts_dir;
use mbprox::serve::Server;

fn parse_cfg(args: &[String]) -> Result<ExperimentConfig> {
    let mut kv = KvConfig::default();
    // load config file first if given
    for a in args {
        if let Some(path) = a.strip_prefix("config=") {
            kv = KvConfig::load(std::path::Path::new(path))?;
        }
    }
    let overrides: Vec<String> =
        args.iter().filter(|a| !a.starts_with("config=")).cloned().collect();
    let kv = ExperimentConfig::apply_overrides(kv, &overrides)?;
    ExperimentConfig::from_kv(&kv)
}

/// The accepted key set, rendered from the one source of truth.
fn print_keys() {
    println!("keys (key=value; config=<path> loads a file first):");
    for (key, help) in CONFIG_KEYS {
        println!("  {key:<14} {help}");
    }
    println!("\nscenarios (scenario=; from the data::scenario registry):");
    for def in SCENARIOS {
        println!("  {:<12} [{}] {}", def.name, def.setting.as_str(), def.help);
    }
}

fn cmd_run(args: &[String]) -> Result<()> {
    if args.iter().any(|a| a == "--help" || a == "-h" || a == "help") {
        println!("mbprox run [key=value ...]\n");
        print_keys();
        println!("\nmethods: {}", METHODS.join(" "));
        return Ok(());
    }
    let cfg = parse_cfg(args)?;
    let mut runner = Runner::from_env()?;
    eprintln!(
        "# engine platform={} artifacts={}",
        runner.engine.platform(),
        runner.engine.manifest().artifacts.len()
    );
    let result = runner.run(&cfg)?;
    print!("{}", metrics::resource_table(&[&result]));
    // the paper's memory axis, per machine ("memory" above is their max)
    println!("# peak vectors per machine: {}", result.report.peaks_display());
    if let Some(s) = &result.stalls {
        println!(
            "# draw dispatch: {} takes, {:.0}% prefetch hits, {:.3} ms stalled",
            s.takes,
            s.hit_rate() * 100.0,
            s.stall_ns as f64 / 1e6
        );
    }
    if let Some(o) = &result.overlap {
        println!(
            "# fan pipeline: {} fans, {} staged packs, {:.0}% of pack work overlapped ({:.3} ms)",
            o.fans,
            o.staged,
            o.overlap_frac() * 100.0,
            o.overlap_ns as f64 / 1e6
        );
    }
    if let Some(u) = &result.uploads {
        println!(
            "# upload lane: {} uploads ({} B), {} staged, {:.3} ms overlappable \
             ({:.3} ms waited)",
            u.uploads,
            u.bytes,
            u.staged,
            u.overlap_ns as f64 / 1e6,
            u.wait_ns as f64 / 1e6
        );
    }
    if let Some(f) = &result.faults {
        println!(
            "# faults: {} stragglers, {} dropouts ({} machine-rounds out, {} re-entries), \
             {} worker recoveries ({} batches replayed), +{:.4} s simulated",
            f.stragglers,
            f.dropouts,
            f.dropped_rounds,
            f.reentries,
            f.recoveries,
            f.replays,
            f.added_time_s
        );
    }
    if !result.curve.is_empty() {
        println!("\n# trajectory");
        print!("{}", metrics::curve_csv(&result));
    }
    Ok(())
}

fn cmd_sweep(args: &[String]) -> Result<()> {
    let base = parse_cfg(args)?;
    let mut runner = Runner::from_env()?;
    let mut results = Vec::new();
    let mut b = 64usize;
    let b_max = base.n_budget / base.m;
    while b <= b_max {
        let cfg = ExperimentConfig { b_local: b, ..base.clone() };
        match runner.run(&cfg) {
            Ok(r) => results.push(r),
            Err(e) => eprintln!("b={b}: {e}"),
        }
        b *= 4;
    }
    let refs: Vec<&_> = results.iter().collect();
    print!("{}", metrics::resource_table(&refs));
    Ok(())
}

/// `mbprox serve`: the persistent run service (the dedicated
/// `mbprox_serve` binary is the same entry point packaged standalone).
/// Takes ONLY `serve.*` keys — experiment configs are POSTed to /run —
/// and blocks until `POST /shutdown`.
fn cmd_serve(args: &[String]) -> Result<()> {
    if args.iter().any(|a| a == "--help" || a == "-h" || a == "help") {
        println!(
            "mbprox serve [serve.key=value ...]\n\n\
             Persistent run service: POST experiment configs (the same\n\
             key=value lines `mbprox run` accepts) to /run and stream\n\
             ndjson progress events; GET /stats for cumulative job and\n\
             cache counters; POST /shutdown to stop.\n\n\
             serve keys (from config::CONFIG_KEYS):"
        );
        for (key, help) in CONFIG_KEYS.iter().filter(|(k, _)| k.starts_with("serve.")) {
            println!("  {key:<22} {help}");
        }
        return Ok(());
    }
    let mut kv = KvConfig::default();
    for a in args {
        if let Some(path) = a.strip_prefix("config=") {
            kv = KvConfig::load(std::path::Path::new(path))?;
        }
    }
    let overrides: Vec<String> =
        args.iter().filter(|a| !a.starts_with("config=")).cloned().collect();
    let kv = ExperimentConfig::apply_overrides(kv, &overrides)?;
    let cfg = ServeConfig::from_kv(&kv)?;
    let server = Server::bind(&cfg, &default_artifacts_dir())?;
    eprintln!(
        "# mbprox serve listening on http://{} (queue_depth={}, cache_capacity={})",
        server.addr(),
        cfg.queue_depth,
        cfg.cache_capacity.map(|c| c.to_string()).unwrap_or_else(|| "unbounded".into())
    );
    let stats = server.run()?;
    eprintln!(
        "# mbprox serve stopped: {} done, {} failed, {} rejected, cache {}h/{}m",
        stats.jobs_done,
        stats.jobs_failed,
        stats.jobs_rejected,
        stats.exec_cache.hits,
        stats.exec_cache.misses
    );
    Ok(())
}

fn cmd_info() -> Result<()> {
    let runner = Runner::from_env()?;
    let m = runner.engine.manifest();
    println!("platform: {}", runner.engine.platform());
    println!("plane policy: {}", runner.plane.as_str());
    println!("artifacts dir: {}", m.dir.display());
    println!("block rows: {}", m.block);
    println!("dims: {:?}", m.dims);
    for a in &m.artifacts {
        println!("  {:<16} kind={:?} d={} outputs={:?}", a.name, a.kind, a.d, a.outputs);
    }
    Ok(())
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(String::as_str) {
        Some("run") => cmd_run(&args[1..]),
        Some("sweep") => cmd_sweep(&args[1..]),
        Some("serve") => cmd_serve(&args[1..]),
        Some("list") => {
            println!("methods:");
            for m in METHODS {
                println!("  {m}");
            }
            println!();
            print_keys();
            Ok(())
        }
        Some("info") => cmd_info(),
        Some("help") | Some("--help") | None => {
            println!(
                "mbprox — Minibatch-Prox distributed stochastic optimization\n\n\
                 subcommands:\n  run [key=value ...]   (run --help for keys)\n  \
                 sweep [key=value ...]\n  serve [serve.key=value ...]   (serve --help)\n  \
                 list\n  info\n"
            );
            print_keys();
            println!("\nmethods: {}", METHODS.join(" "));
            Ok(())
        }
        Some(other) => Err(anyhow!("unknown subcommand '{other}' (try help)")),
    }
}
