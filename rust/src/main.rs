//! mbprox CLI — run distributed stochastic optimization experiments.
//!
//! Usage:
//!   mbprox run   [key=value ...]        run one method (see run --help)
//!   mbprox sweep [key=value ...]        sweep b_local over a log grid
//!   mbprox list                         list methods + accepted keys
//!   mbprox info                         engine / artifact information
//!
//! Configuration is `key = value` pairs (`config=<path>` loads a file
//! first); the accepted key set is `config::CONFIG_KEYS` — unknown keys
//! are rejected with a did-you-mean suggestion. The `plane=` key (or the
//! `PLANE` env var) selects the execution plane: `auto` (sharded when
//! `SHARDS` attaches a pool, chained otherwise), `host` (legacy
//! per-block), `chained` (single-engine device-resident), or `sharded`
//! (engine-per-worker). All planes produce the same results with
//! identical paper-units accounting — see `runtime::plane`.

use anyhow::{anyhow, Result};
use mbprox::config::{ExperimentConfig, KvConfig, CONFIG_KEYS};
use mbprox::coordinator::{Runner, METHODS};
use mbprox::data::scenario::SCENARIOS;
use mbprox::metrics;

fn parse_cfg(args: &[String]) -> Result<ExperimentConfig> {
    let mut kv = KvConfig::default();
    // load config file first if given
    for a in args {
        if let Some(path) = a.strip_prefix("config=") {
            kv = KvConfig::load(std::path::Path::new(path))?;
        }
    }
    let overrides: Vec<String> =
        args.iter().filter(|a| !a.starts_with("config=")).cloned().collect();
    let kv = ExperimentConfig::apply_overrides(kv, &overrides)?;
    ExperimentConfig::from_kv(&kv)
}

/// The accepted key set, rendered from the one source of truth.
fn print_keys() {
    println!("keys (key=value; config=<path> loads a file first):");
    for (key, help) in CONFIG_KEYS {
        println!("  {key:<14} {help}");
    }
    println!("\nscenarios (scenario=; from the data::scenario registry):");
    for def in SCENARIOS {
        println!("  {:<12} [{}] {}", def.name, def.setting.as_str(), def.help);
    }
}

fn cmd_run(args: &[String]) -> Result<()> {
    if args.iter().any(|a| a == "--help" || a == "-h" || a == "help") {
        println!("mbprox run [key=value ...]\n");
        print_keys();
        println!("\nmethods: {}", METHODS.join(" "));
        return Ok(());
    }
    let cfg = parse_cfg(args)?;
    let mut runner = Runner::from_env()?;
    eprintln!(
        "# engine platform={} artifacts={}",
        runner.engine.platform(),
        runner.engine.manifest().artifacts.len()
    );
    let result = runner.run(&cfg)?;
    print!("{}", metrics::resource_table(&[&result]));
    // the paper's memory axis, per machine ("memory" above is their max)
    println!("# peak vectors per machine: {}", result.report.peaks_display());
    if let Some(s) = &result.stalls {
        println!(
            "# draw dispatch: {} takes, {:.0}% prefetch hits, {:.3} ms stalled",
            s.takes,
            s.hit_rate() * 100.0,
            s.stall_ns as f64 / 1e6
        );
    }
    if let Some(o) = &result.overlap {
        println!(
            "# fan pipeline: {} fans, {} staged packs, {:.0}% of pack work overlapped ({:.3} ms)",
            o.fans,
            o.staged,
            o.overlap_frac() * 100.0,
            o.overlap_ns as f64 / 1e6
        );
    }
    if let Some(f) = &result.faults {
        println!(
            "# faults: {} stragglers, {} dropouts ({} machine-rounds out, {} re-entries), \
             {} worker recoveries ({} batches replayed), +{:.4} s simulated",
            f.stragglers,
            f.dropouts,
            f.dropped_rounds,
            f.reentries,
            f.recoveries,
            f.replays,
            f.added_time_s
        );
    }
    if !result.curve.is_empty() {
        println!("\n# trajectory");
        print!("{}", metrics::curve_csv(&result));
    }
    Ok(())
}

fn cmd_sweep(args: &[String]) -> Result<()> {
    let base = parse_cfg(args)?;
    let mut runner = Runner::from_env()?;
    let mut results = Vec::new();
    let mut b = 64usize;
    let b_max = base.n_budget / base.m;
    while b <= b_max {
        let cfg = ExperimentConfig { b_local: b, ..base.clone() };
        match runner.run(&cfg) {
            Ok(r) => results.push(r),
            Err(e) => eprintln!("b={b}: {e}"),
        }
        b *= 4;
    }
    let refs: Vec<&_> = results.iter().collect();
    print!("{}", metrics::resource_table(&refs));
    Ok(())
}

fn cmd_info() -> Result<()> {
    let runner = Runner::from_env()?;
    let m = runner.engine.manifest();
    println!("platform: {}", runner.engine.platform());
    println!("plane policy: {}", runner.plane.as_str());
    println!("artifacts dir: {}", m.dir.display());
    println!("block rows: {}", m.block);
    println!("dims: {:?}", m.dims);
    for a in &m.artifacts {
        println!("  {:<16} kind={:?} d={} outputs={:?}", a.name, a.kind, a.d, a.outputs);
    }
    Ok(())
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(String::as_str) {
        Some("run") => cmd_run(&args[1..]),
        Some("sweep") => cmd_sweep(&args[1..]),
        Some("list") => {
            println!("methods:");
            for m in METHODS {
                println!("  {m}");
            }
            println!();
            print_keys();
            Ok(())
        }
        Some("info") => cmd_info(),
        Some("help") | Some("--help") | None => {
            println!(
                "mbprox — Minibatch-Prox distributed stochastic optimization\n\n\
                 subcommands:\n  run [key=value ...]   (run --help for keys)\n  \
                 sweep [key=value ...]\n  list\n  info\n"
            );
            print_keys();
            println!("\nmethods: {}", METHODS.join(" "));
            Ok(())
        }
        Some(other) => Err(anyhow!("unknown subcommand '{other}' (try help)")),
    }
}
