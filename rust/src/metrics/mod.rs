//! Run records and report output: ascii tables, CSV and JSON writers for
//! the benches/examples (consumed by EXPERIMENTS.md).

use crate::algos::RunResult;
use crate::util::json::escape_str;
use std::fmt::Write as _;
use std::path::Path;

/// Render a set of runs as the Table-1-style resource table.
pub fn resource_table(runs: &[&RunResult]) -> String {
    let mut out = String::new();
    let _ = writeln!(
        out,
        "{:<34} {:>10} {:>12} {:>14} {:>10} {:>12} {:>12}",
        "method", "samples", "comm_rounds", "vec_ops", "memory", "sim_time_s", "objective"
    );
    for r in runs {
        let obj = r
            .final_objective
            .map(|o| format!("{o:.6}"))
            .unwrap_or_else(|| "-".to_string());
        let _ = writeln!(
            out,
            "{:<34} {:>10} {:>12} {:>14} {:>10} {:>12.4} {:>12}",
            truncate(&r.name, 34),
            r.report.total_samples,
            r.report.comm_rounds,
            r.report.vec_ops,
            r.report.peak_vectors,
            r.sim_time_s,
            obj
        );
    }
    out
}

fn truncate(s: &str, n: usize) -> String {
    if s.len() <= n {
        s.to_string()
    } else {
        format!("{}…", &s[..n - 1])
    }
}

/// CSV of a run's trajectory curve.
pub fn curve_csv(run: &RunResult) -> String {
    let mut out = String::from("outer_iter,samples,comm_rounds,vec_ops,objective\n");
    for p in &run.curve {
        let obj = p.objective.map(|o| o.to_string()).unwrap_or_default();
        let _ = writeln!(
            out,
            "{},{},{},{},{}",
            p.outer_iter, p.samples_total, p.comm_rounds, p.vec_ops, obj
        );
    }
    out
}

/// JSON record of a run (hand-rolled writer; schema is stable for tooling).
pub fn run_json(run: &RunResult) -> String {
    let mut out = String::from("{");
    let _ = write!(out, "\"name\": {}, ", escape_str(&run.name));
    let _ = write!(
        out,
        "\"samples\": {}, \"comm_rounds\": {}, \"vec_ops\": {}, \"memory\": {}, ",
        run.report.total_samples, run.report.comm_rounds, run.report.vec_ops,
        run.report.peak_vectors
    );
    // the paper's memory axis, per machine (cluster max is "memory")
    let peaks: Vec<String> = run.report.peak_per_machine.iter().map(u64::to_string).collect();
    let _ = write!(out, "\"peak_vectors_per_machine\": [{}], ", peaks.join(","));
    let _ = write!(out, "\"sim_time_s\": {}, ", run.sim_time_s);
    match run.final_objective {
        Some(o) => {
            let _ = write!(out, "\"objective\": {o}, ");
        }
        None => {
            let _ = write!(out, "\"objective\": null, ");
        }
    }
    // wall-clock dispatch-stall accounting (sharded plane only; see
    // `runtime::shard` — never part of the simulated cost model)
    match &run.stalls {
        Some(s) => {
            let _ = write!(
                out,
                "\"stalls\": {{\"takes\": {}, \"hits\": {}, \"misses\": {}, \
                 \"stall_ns\": {}, \"hit_rate\": {}}}, ",
                s.takes,
                s.hits,
                s.misses,
                s.stall_ns,
                s.hit_rate()
            );
        }
        None => {
            let _ = write!(out, "\"stalls\": null, ");
        }
    }
    // wall-clock fan-pipelining accounting (sharded plane only; like
    // `stalls`, outside the simulated cost model)
    match &run.overlap {
        Some(o) => {
            let _ = write!(
                out,
                "\"overlap\": {{\"fans\": {}, \"staged\": {}, \"overlap_ns\": {}, \
                 \"serial_ns\": {}, \"overlap_frac\": {}}}, ",
                o.fans,
                o.staged,
                o.overlap_ns,
                o.serial_ns,
                o.overlap_frac()
            );
        }
        None => {
            let _ = write!(out, "\"overlap\": null, ");
        }
    }
    // wall-clock upload-lane accounting (every plane — the coordinator
    // engine meters even without a pool; like `stalls`/`overlap`,
    // outside the simulated cost model, and the counts are identical
    // with the lane on or off)
    match &run.uploads {
        Some(u) => {
            let _ = write!(
                out,
                "\"uploads\": {{\"uploads\": {}, \"staged\": {}, \"overlap_ns\": {}, \
                 \"wait_ns\": {}, \"bytes\": {}}}, ",
                u.uploads, u.staged, u.overlap_ns, u.wait_ns, u.bytes
            );
        }
        None => {
            let _ = write!(out, "\"uploads\": null, ");
        }
    }
    // wall-clock executable-cache accounting for this run (filled by
    // `Runner::run`; like `stalls`/`overlap`, never part of the
    // simulated cost model — the curve below is bit-identical warm or
    // cold, which is exactly what the serve parity tests compare)
    match &run.cache {
        Some(c) => {
            let _ = write!(
                out,
                "\"cache\": {{\"hits\": {}, \"misses\": {}, \"compile_ns\": {}, \
                 \"evictions\": {}, \"hit_rate\": {}}}, ",
                c.hits,
                c.misses,
                c.compile_ns,
                c.evictions,
                c.hit_rate()
            );
        }
        None => {
            let _ = write!(out, "\"cache\": null, ");
        }
    }
    let _ = write!(out, "\"curve\": [");
    for (i, p) in run.curve.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        let obj = p.objective.map(|o| o.to_string()).unwrap_or_else(|| "null".into());
        let _ = write!(
            out,
            "{{\"t\": {}, \"samples\": {}, \"rounds\": {}, \"objective\": {obj}}}",
            p.outer_iter, p.samples_total, p.comm_rounds
        );
    }
    out.push_str("]}");
    out
}

/// Write text to a file, creating parents.
pub fn write_report(path: &Path, text: &str) -> std::io::Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    std::fs::write(path, text)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accounting::{CacheMeter, OverlapMeter, ResourceReport, StallMeter, UploadMeter};
    use crate::algos::CurvePoint;
    use crate::util::json::Json;

    fn dummy_run() -> RunResult {
        RunResult {
            name: "test-method".into(),
            w: vec![0.0; 4],
            report: ResourceReport {
                m: 2,
                total_samples: 100,
                comm_rounds: 5,
                vectors_sent: 5,
                vec_ops: 50,
                peak_vectors: 12,
                peak_per_machine: vec![12, 7],
            },
            curve: vec![CurvePoint {
                outer_iter: 1,
                samples_total: 50,
                comm_rounds: 2,
                vec_ops: 25,
                objective: Some(0.25),
            }],
            sim_time_s: 0.5,
            final_objective: Some(0.125),
            stalls: Some(StallMeter { takes: 8, hits: 6, misses: 2, stall_ns: 1500 }),
            overlap: Some(OverlapMeter { fans: 4, staged: 3, overlap_ns: 900, serial_ns: 300 }),
            uploads: Some(UploadMeter {
                uploads: 10,
                staged: 7,
                overlap_ns: 1200,
                wait_ns: 400,
                bytes: 2560,
            }),
            faults: None,
            cache: Some(CacheMeter { hits: 3, misses: 1, compile_ns: 2000, evictions: 0 }),
        }
    }

    #[test]
    fn table_contains_rows() {
        let run = dummy_run();
        let t = resource_table(&[&run]);
        assert!(t.contains("test-method"));
        assert!(t.contains("100"));
    }

    #[test]
    fn csv_has_header_and_rows() {
        let c = curve_csv(&dummy_run());
        let mut lines = c.lines();
        assert!(lines.next().unwrap().starts_with("outer_iter"));
        assert_eq!(lines.next().unwrap(), "1,50,2,25,0.25");
    }

    #[test]
    fn json_is_parseable_by_our_parser() {
        let j = run_json(&dummy_run());
        let v = Json::parse(&j).expect("valid json");
        assert_eq!(v.get("samples").unwrap().as_usize(), Some(100));
        assert_eq!(v.get("curve").unwrap().as_arr().unwrap().len(), 1);
        let peaks = v.get("peak_vectors_per_machine").unwrap().as_arr().unwrap();
        assert_eq!(peaks.len(), 2);
        assert_eq!(peaks[0].as_usize(), Some(12));
        assert_eq!(peaks[1].as_usize(), Some(7));
        let stalls = v.get("stalls").unwrap();
        assert_eq!(stalls.get("takes").unwrap().as_usize(), Some(8));
        assert_eq!(stalls.get("hit_rate").unwrap().as_f64(), Some(0.75));
        let overlap = v.get("overlap").unwrap();
        assert_eq!(overlap.get("fans").unwrap().as_usize(), Some(4));
        assert_eq!(overlap.get("overlap_frac").unwrap().as_f64(), Some(0.75));
        let cache = v.get("cache").unwrap();
        assert_eq!(cache.get("hits").unwrap().as_usize(), Some(3));
        assert_eq!(cache.get("misses").unwrap().as_usize(), Some(1));
        assert_eq!(cache.get("compile_ns").unwrap().as_usize(), Some(2000));
        assert_eq!(cache.get("hit_rate").unwrap().as_f64(), Some(0.75));
        let uploads = v.get("uploads").unwrap();
        assert_eq!(uploads.get("uploads").unwrap().as_usize(), Some(10));
        assert_eq!(uploads.get("staged").unwrap().as_usize(), Some(7));
        assert_eq!(uploads.get("overlap_ns").unwrap().as_usize(), Some(1200));
        assert_eq!(uploads.get("wait_ns").unwrap().as_usize(), Some(400));
        assert_eq!(uploads.get("bytes").unwrap().as_usize(), Some(2560));
        // off the sharded plane, the wall-clock meters are explicit nulls
        let mut run = dummy_run();
        run.stalls = None;
        run.overlap = None;
        run.uploads = None;
        run.cache = None;
        let v = Json::parse(&run_json(&run)).expect("valid json");
        assert!(matches!(v.get("stalls"), Some(Json::Null)));
        assert!(matches!(v.get("overlap"), Some(Json::Null)));
        assert!(matches!(v.get("uploads"), Some(Json::Null)));
        assert!(matches!(v.get("cache"), Some(Json::Null)));
    }
}
