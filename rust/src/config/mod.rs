//! Experiment configuration: a small `key = value` format (the offline
//! image has no serde/toml) with typed accessors, env overrides, and the
//! composite `ExperimentConfig` every binary builds its runs from.
//!
//! File format: one `key = value` per line, `#` comments, sections are
//! flattened as `section.key`. This covers everything the examples and
//! benches need without a full TOML grammar.
//!
//! Unknown keys are REJECTED with a did-you-mean suggestion when a
//! `KvConfig` is turned into an [`ExperimentConfig`]: a typo like
//! `b_locl=1024` must not silently fall back to the default. The accepted
//! key set is [`CONFIG_KEYS`] — the single source of truth the CLI's
//! `run --help` / `list` output prints.

use crate::comm::faults::{FaultParams, FaultsPolicy};
use crate::data::Loss;
use crate::runtime::{PipelinePolicy, PlanePolicy, PrefetchPolicy, UploadPolicy};
use crate::util::closest_name;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// The accepted experiment keys with one-line help — ONE source of truth
/// for parsing, validation and the CLI usage output.
pub const CONFIG_KEYS: &[(&str, &str)] = &[
    ("method", "method name (see `mbprox list`)"),
    ("m", "number of machines"),
    ("b_local", "per-machine minibatch size b"),
    ("n_budget", "total sample budget n"),
    ("loss", "loss function: sq | log"),
    ("dim", "native feature dimension"),
    ("seed", "PRNG seed (u64)"),
    ("eval_samples", "held-out evaluation set size"),
    ("eval_every", "evaluate every k outer iterations (0 = end only)"),
    ("scenario", "named data scenario (the registry list below / `mbprox list`)"),
    ("data_path", "libsvm file path (scenario=libsvm)"),
    ("dataset", "named dataset: codrna | covtype | kddcup99 | year"),
    ("plane", "execution plane: auto | host | chained | sharded"),
    ("prefetch", "shard-plane draw prefetch: auto | on | off (bit-identical either way)"),
    ("pipeline", "shard-plane batched-fan pipelining: auto | on | off (bit-identical either way)"),
    ("upload", "engine upload lane: staging rings: auto | on | off (bit-identical either way)"),
    ("scenario.drift_omega", "drift scenario: per-draw rotation angle (radians; default tau/8192)"),
    ("scenario.pareto_alpha", "heavy-tail scenario: Pareto tail index (> 2 for finite variance)"),
    ("scenario.sparse_density", "sparse scenario: expected fraction of active features (0, 1]"),
    ("net.alpha", "network model per-message latency, seconds (default 50e-6)"),
    ("net.beta", "network model bandwidth, bytes/second (default 1 GiB/s)"),
    ("faults", "fault injection: on | off (default off = bitwise identical to no fault layer)"),
    ("faults.straggler_p", "per-machine per-round straggler probability in [0, 1] (default 0.1)"),
    ("faults.slowdown_alpha", "straggler Pareto tail index > 0; smaller = heavier (default 1.5)"),
    ("faults.dropout_p", "per-machine per-round dropout probability in [0, 1] (default 0)"),
    ("faults.dropout_rounds", "rounds a dropped machine stays out before re-entry (default 3)"),
    ("serve.port", "mbprox serve: TCP port to listen on (0 = OS-assigned; serve mode only)"),
    ("serve.queue_depth", "mbprox serve: bounded FIFO job-queue depth >= 1 (serve mode only)"),
    (
        "serve.cache_capacity",
        "mbprox serve: max resident compiled executables per engine (unset = unbounded)",
    ),
];

#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct KvConfig {
    map: BTreeMap<String, String>,
}

impl KvConfig {
    pub fn parse(text: &str) -> Result<KvConfig> {
        let mut map = BTreeMap::new();
        let mut section = String::new();
        for (ln, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
                section = name.trim().to_string();
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| anyhow!("line {}: expected key = value", ln + 1))?;
            let key = if section.is_empty() {
                k.trim().to_string()
            } else {
                format!("{section}.{}", k.trim())
            };
            map.insert(key, v.trim().trim_matches('"').to_string());
        }
        Ok(KvConfig { map })
    }

    pub fn load(path: &Path) -> Result<KvConfig> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {}", path.display()))?;
        Self::parse(&text)
    }

    pub fn set(&mut self, key: &str, value: impl ToString) {
        self.map.insert(key.to_string(), value.to_string());
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.map.get(key).map(String::as_str)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("config key '{key}'='{v}'")),
        }
    }

    /// Full-width u64 accessor (seeds): `get_usize(...) as u64` would
    /// truncate on 32-bit targets and reject values above usize::MAX
    /// inconsistently across platforms.
    pub fn get_u64(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("config key '{key}'='{v}'")),
        }
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("config key '{key}'='{v}'")),
        }
    }

    pub fn get_str(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.map.keys().map(String::as_str)
    }

    /// Canonical serialization: one `key=value` line per entry in sorted
    /// key order (the backing map is a `BTreeMap`, so ordering is free),
    /// values exactly as stored after parse normalization (comments
    /// stripped, whitespace trimmed, quotes removed, `[section]` headers
    /// flattened to `section.key`). Two configs that parse to the same
    /// map — whatever their surface syntax — serialize identically, and
    /// parsing a canonical string reproduces the exact map. This is the
    /// serve layer's content-hash input, so the format must stay stable:
    /// values are NOT reformatted (`1e-2` and `0.01` are different
    /// canonical texts by design — the hash addresses the config text,
    /// not parsed semantics).
    pub fn to_canonical_string(&self) -> String {
        let mut out = String::new();
        for (k, v) in &self.map {
            out.push_str(k);
            out.push('=');
            out.push_str(v);
            out.push('\n');
        }
        out
    }

    /// Stable 64-bit content hash of the canonical serialization
    /// (FNV-1a; comparable across processes and releases).
    pub fn content_hash(&self) -> u64 {
        crate::util::hash::fnv1a_64(self.to_canonical_string().as_bytes())
    }

    /// Reject any key outside `known`, suggesting the closest accepted
    /// key by edit distance ("did you mean ...?"). Namespaced keys
    /// (`section.key` — what `[section]` headers flatten to) pass through
    /// as config extensions outside the experiment namespace, EXCEPT the
    /// `scenario.`, `net.` and `faults.` sections: their keys
    /// (`scenario.drift_omega`, `net.alpha`, `faults.straggler_p`, ...)
    /// are part of the accepted set, so a typo there gets the same
    /// did-you-mean rejection as a flat key.
    pub fn expect_keys(&self, known: &[(&str, &str)]) -> Result<()> {
        const GUARDED: &[&str] = &["scenario.", "net.", "faults.", "serve."];
        for key in self.keys() {
            if known.iter().any(|(k, _)| *k == key) {
                continue;
            }
            if key.contains('.') && !GUARDED.iter().any(|ns| key.starts_with(ns)) {
                continue;
            }
            // shared matcher (util::closest_name) — scenario names reject
            // typos with the identical behavior
            match closest_name(key, known.iter().map(|(k, _)| *k)) {
                Some(best) => bail!("unknown config key '{key}' (did you mean '{best}'?)"),
                None => bail!("unknown config key '{key}' (see `mbprox run --help` for keys)"),
            }
        }
        Ok(())
    }

    /// Optional float accessor (no default: absent key = `None`).
    pub fn get_opt_f64(&self, key: &str) -> Result<Option<f64>> {
        match self.get(key) {
            None => Ok(None),
            Some(v) => v
                .parse()
                .map(Some)
                .with_context(|| format!("config key '{key}'='{v}'")),
        }
    }

    /// Optional u64 accessor (no default: absent key = `None`).
    pub fn get_opt_u64(&self, key: &str) -> Result<Option<u64>> {
        match self.get(key) {
            None => Ok(None),
            Some(v) => v
                .parse()
                .map(Some)
                .with_context(|| format!("config key '{key}'='{v}'")),
        }
    }
}

/// Top-level experiment description shared by the CLI and examples.
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    pub m: usize,
    pub b_local: usize,
    pub n_budget: usize,
    pub loss: Loss,
    pub dim: usize,
    pub seed: u64,
    pub eval_samples: usize,
    pub eval_every: usize,
    pub method: String,
    /// named data scenario from the registry (`scenario=` key; see
    /// `data::scenario::SCENARIOS`). Mutually exclusive with `dataset`.
    pub scenario: Option<String>,
    /// on-disk libsvm path (`data_path=` key; the `libsvm` scenario)
    pub data_path: Option<String>,
    pub dataset: Option<String>,
    /// execution-plane policy (`plane=` key; `Auto` defers to the
    /// runner's `PLANE` env / default)
    pub plane: PlanePolicy,
    /// shard-plane draw prefetch (`prefetch=` key; `Auto` defers to the
    /// runner's `PREFETCH` env / default). Bit-parity is unconditional —
    /// this knob trades dispatch-stall time only.
    pub prefetch: PrefetchPolicy,
    /// shard-plane batched-fan pipelining (`pipeline=` key; `Auto` defers
    /// to the runner's `PIPELINE` env / default). Bit-parity is
    /// unconditional — this knob trades engine idle time only.
    pub pipeline: PipelinePolicy,
    /// engine upload lane (`upload=` key; `Auto` defers to the runner's
    /// `UPLOAD` env / default). Bit-parity is unconditional — this knob
    /// trades host->device staging time only.
    pub upload: UploadPolicy,
    /// drift scenario: per-draw rotation angle in radians
    /// (`scenario.drift_omega`; `None` = the scenario's default)
    pub drift_omega: Option<f64>,
    /// heavy-tail scenario: Pareto tail index (`scenario.pareto_alpha`;
    /// must exceed 2 so gradients keep finite variance)
    pub pareto_alpha: Option<f64>,
    /// sparse scenario: expected active-feature fraction in (0, 1]
    /// (`scenario.sparse_density`)
    pub sparse_density: Option<f64>,
    /// network model per-message latency override in seconds
    /// (`net.alpha`; `None` = the runner's model)
    pub net_alpha: Option<f64>,
    /// network model bandwidth override in bytes/second (`net.beta`)
    pub net_beta: Option<f64>,
    /// fault injection switch (`faults=` key). Off (the default) never
    /// constructs a fault plan, so the run is bitwise identical to a
    /// build without the fault layer; the `faults.*` knobs below are
    /// rejected unless this is on — fault injection never runs implicitly.
    pub faults: FaultsPolicy,
    /// straggler probability (`faults.straggler_p`; `None` = default 0.1)
    pub straggler_p: Option<f64>,
    /// straggler Pareto tail index (`faults.slowdown_alpha`)
    pub slowdown_alpha: Option<f64>,
    /// dropout probability (`faults.dropout_p`; `None` = default 0)
    pub dropout_p: Option<f64>,
    /// dropout window in collective rounds (`faults.dropout_rounds`)
    pub dropout_rounds: Option<u64>,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        Self {
            m: 4,
            b_local: 512,
            n_budget: 65_536,
            loss: Loss::Squared,
            dim: 64,
            seed: 17,
            eval_samples: 4096,
            eval_every: 0,
            method: "mp-dsvrg".to_string(),
            scenario: None,
            data_path: None,
            dataset: None,
            plane: PlanePolicy::Auto,
            prefetch: PrefetchPolicy::Auto,
            pipeline: PipelinePolicy::Auto,
            upload: UploadPolicy::Auto,
            drift_omega: None,
            pareto_alpha: None,
            sparse_density: None,
            net_alpha: None,
            net_beta: None,
            faults: FaultsPolicy::Off,
            straggler_p: None,
            slowdown_alpha: None,
            dropout_p: None,
            dropout_rounds: None,
        }
    }
}

impl ExperimentConfig {
    pub fn from_kv(kv: &KvConfig) -> Result<ExperimentConfig> {
        kv.expect_keys(CONFIG_KEYS)?;
        // serve.* keys configure the run service, not a run: accepting
        // them here would silently do nothing (mirrors the
        // faults.*-without-faults=on rule below)
        for key in kv.keys() {
            if key.starts_with("serve.") {
                bail!(
                    "'{key}' is a serve-mode setting — serve.* keys are only accepted \
                     by `mbprox serve` (job configs POSTed to /run carry no serve.* keys)"
                );
            }
        }
        let dflt = ExperimentConfig::default();
        let loss_s = kv.get_str("loss", dflt.loss.tag());
        let loss = Loss::parse(&loss_s).ok_or_else(|| anyhow!("bad loss '{loss_s}'"))?;
        let dim = kv.get_usize("dim", dflt.dim)?;
        if dim == 0 {
            bail!("dim must be positive");
        }
        let plane_s = kv.get_str("plane", dflt.plane.as_str());
        let plane = PlanePolicy::parse(&plane_s)
            .ok_or_else(|| anyhow!("bad plane '{plane_s}' (auto|host|chained|sharded)"))?;
        let prefetch_s = kv.get_str("prefetch", dflt.prefetch.as_str());
        let prefetch = PrefetchPolicy::parse(&prefetch_s)
            .ok_or_else(|| anyhow!("bad prefetch '{prefetch_s}' (auto|on|off)"))?;
        let pipeline_s = kv.get_str("pipeline", dflt.pipeline.as_str());
        let pipeline = PipelinePolicy::parse(&pipeline_s)
            .ok_or_else(|| anyhow!("bad pipeline '{pipeline_s}' (auto|on|off)"))?;
        let upload_s = kv.get_str("upload", dflt.upload.as_str());
        let upload = UploadPolicy::parse(&upload_s)
            .ok_or_else(|| anyhow!("bad upload '{upload_s}' (auto|on|off)"))?;
        let drift_omega = kv.get_opt_f64("scenario.drift_omega")?;
        if let Some(w) = drift_omega {
            if !w.is_finite() || w < 0.0 {
                bail!("scenario.drift_omega must be a finite angle >= 0, got {w}");
            }
        }
        let pareto_alpha = kv.get_opt_f64("scenario.pareto_alpha")?;
        if let Some(a) = pareto_alpha {
            if !a.is_finite() || a <= 2.0 {
                bail!(
                    "scenario.pareto_alpha must exceed 2 (finite gradient variance), got {a}"
                );
            }
        }
        let sparse_density = kv.get_opt_f64("scenario.sparse_density")?;
        if let Some(p) = sparse_density {
            if !p.is_finite() || p <= 0.0 || p > 1.0 {
                bail!("scenario.sparse_density must lie in (0, 1], got {p}");
            }
        }
        let net_alpha = kv.get_opt_f64("net.alpha")?;
        if let Some(a) = net_alpha {
            if !a.is_finite() || a < 0.0 {
                bail!("net.alpha must be a finite latency >= 0 seconds, got {a}");
            }
        }
        let net_beta = kv.get_opt_f64("net.beta")?;
        if let Some(b) = net_beta {
            // infinity is legal (a free network, like NetModel::zero)
            if !(b > 0.0) {
                bail!("net.beta must be a positive bandwidth in bytes/s, got {b}");
            }
        }
        let faults_s = kv.get_str("faults", dflt.faults.as_str());
        let faults = FaultsPolicy::parse(&faults_s)
            .ok_or_else(|| anyhow!("bad faults '{faults_s}' (on|off)"))?;
        let straggler_p = kv.get_opt_f64("faults.straggler_p")?;
        if let Some(p) = straggler_p {
            if !p.is_finite() || !(0.0..=1.0).contains(&p) {
                bail!("faults.straggler_p must be a probability in [0, 1], got {p}");
            }
        }
        let slowdown_alpha = kv.get_opt_f64("faults.slowdown_alpha")?;
        if let Some(a) = slowdown_alpha {
            if !a.is_finite() || a <= 0.0 {
                bail!("faults.slowdown_alpha must be a finite Pareto index > 0, got {a}");
            }
        }
        let dropout_p = kv.get_opt_f64("faults.dropout_p")?;
        if let Some(p) = dropout_p {
            if !p.is_finite() || !(0.0..=1.0).contains(&p) {
                bail!("faults.dropout_p must be a probability in [0, 1], got {p}");
            }
        }
        let dropout_rounds = kv.get_opt_u64("faults.dropout_rounds")?;
        if let Some(r) = dropout_rounds {
            if r == 0 {
                bail!("faults.dropout_rounds must be >= 1 (a dropout lasts whole rounds)");
            }
        }
        if !faults.enabled() {
            // a fault knob on a faults=off run would silently do nothing —
            // reject it, like a typo'd key
            const KNOBS: [&str; 4] = [
                "faults.straggler_p",
                "faults.slowdown_alpha",
                "faults.dropout_p",
                "faults.dropout_rounds",
            ];
            for knob in KNOBS {
                if kv.get(knob).is_some() {
                    bail!(
                        "'{knob}' is set but faults=off — add faults=on \
                         (fault injection never runs implicitly)"
                    );
                }
            }
        }
        Ok(ExperimentConfig {
            m: kv.get_usize("m", dflt.m)?,
            b_local: kv.get_usize("b_local", dflt.b_local)?,
            n_budget: kv.get_usize("n_budget", dflt.n_budget)?,
            loss,
            dim,
            seed: kv.get_u64("seed", dflt.seed)?,
            eval_samples: kv.get_usize("eval_samples", dflt.eval_samples)?,
            eval_every: kv.get_usize("eval_every", dflt.eval_every)?,
            method: kv.get_str("method", &dflt.method),
            scenario: kv.get("scenario").map(str::to_string),
            data_path: kv.get("data_path").map(str::to_string),
            dataset: kv.get("dataset").map(str::to_string),
            plane,
            prefetch,
            pipeline,
            upload,
            drift_omega,
            pareto_alpha,
            sparse_density,
            net_alpha,
            net_beta,
            faults,
            straggler_p,
            slowdown_alpha,
            dropout_p,
            dropout_rounds,
        })
    }

    /// The fault-plan parameters this run asks for: `None` when
    /// `faults=off` (no plan is ever built), defaults filled in for
    /// absent knobs when on.
    pub fn fault_params(&self) -> Option<FaultParams> {
        if !self.faults.enabled() {
            return None;
        }
        let d = FaultParams::default();
        Some(FaultParams {
            straggler_p: self.straggler_p.unwrap_or(d.straggler_p),
            slowdown_alpha: self.slowdown_alpha.unwrap_or(d.slowdown_alpha),
            dropout_p: self.dropout_p.unwrap_or(d.dropout_p),
            dropout_rounds: self.dropout_rounds.unwrap_or(d.dropout_rounds),
        })
    }

    /// Apply `key=value` CLI overrides on top of a config.
    pub fn apply_overrides(mut kv: KvConfig, overrides: &[String]) -> Result<KvConfig> {
        for o in overrides {
            let (k, v) =
                o.split_once('=').ok_or_else(|| anyhow!("override '{o}' is not key=value"))?;
            kv.set(k.trim(), v.trim());
        }
        Ok(kv)
    }
}

/// The run service's own settings (`mbprox serve`): the `serve.*`
/// namespace, and ONLY that namespace — experiment keys belong to job
/// configs POSTed to `/run`, and a stray one here is rejected exactly as
/// loudly as a `serve.*` key inside an experiment config.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ServeConfig {
    /// TCP port to listen on; 0 = OS-assigned ephemeral port (the bound
    /// address is printed at startup and queryable via `Server::addr`)
    pub port: u16,
    /// bounded FIFO job-queue depth (>= 1); a full queue rejects with
    /// HTTP 429 rather than blocking the client
    pub queue_depth: usize,
    /// max resident compiled executables per engine (`None` = unbounded,
    /// the non-serve default)
    pub cache_capacity: Option<usize>,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig { port: 7070, queue_depth: 16, cache_capacity: None }
    }
}

impl ServeConfig {
    pub fn from_kv(kv: &KvConfig) -> Result<ServeConfig> {
        for key in kv.keys() {
            if !key.starts_with("serve.") {
                bail!(
                    "'{key}' is not a serve.* setting — `mbprox serve` takes only serve.* \
                     keys (experiment configs are POSTed to /run, not passed at startup)"
                );
            }
        }
        // typo'd serve.* keys get the shared did-you-mean path
        kv.expect_keys(CONFIG_KEYS)?;
        let dflt = ServeConfig::default();
        let port = kv.get_u64("serve.port", u64::from(dflt.port))?;
        if port > 65_535 {
            bail!("serve.port must lie in [0, 65535] (0 = OS-assigned), got {port}");
        }
        let queue_depth = kv.get_usize("serve.queue_depth", dflt.queue_depth)?;
        if queue_depth == 0 {
            bail!("serve.queue_depth must be >= 1 (a depth-0 queue could accept no job)");
        }
        let cache_capacity = match kv.get_opt_u64("serve.cache_capacity")? {
            None => None,
            Some(0) => bail!(
                "serve.cache_capacity must be >= 1 (a capacity-0 cache would recompile \
                 every dispatch); unset it for an unbounded cache"
            ),
            Some(c) => Some(c as usize),
        };
        Ok(ServeConfig { port: port as u16, queue_depth, cache_capacity })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_comments() {
        let kv = KvConfig::parse(
            "# header\nm = 8\n[net]\nalpha = 1e-4 # inline\nname = \"x\"\n",
        )
        .unwrap();
        assert_eq!(kv.get("m"), Some("8"));
        assert_eq!(kv.get("net.alpha"), Some("1e-4"));
        assert_eq!(kv.get("net.name"), Some("x"));
    }

    #[test]
    fn typed_getters_with_defaults() {
        let kv = KvConfig::parse("a = 3\nb = 2.5\nc = 18446744073709551615\n").unwrap();
        assert_eq!(kv.get_usize("a", 0).unwrap(), 3);
        assert_eq!(kv.get_f64("b", 0.0).unwrap(), 2.5);
        assert_eq!(kv.get_usize("missing", 7).unwrap(), 7);
        assert!(kv.get_usize("b", 0).is_err());
        // u64 accessor takes the full range regardless of usize width
        assert_eq!(kv.get_u64("c", 0).unwrap(), u64::MAX);
        assert_eq!(kv.get_u64("missing", 9).unwrap(), 9);
    }

    #[test]
    fn experiment_from_kv_and_overrides() {
        let kv = KvConfig::parse("m = 8\nloss = log\n").unwrap();
        let kv =
            ExperimentConfig::apply_overrides(kv, &["b_local=128".into(), "m=2".into()]).unwrap();
        let ec = ExperimentConfig::from_kv(&kv).unwrap();
        assert_eq!(ec.m, 2);
        assert_eq!(ec.b_local, 128);
        assert_eq!(ec.loss, Loss::Logistic);
        assert_eq!(ec.plane, PlanePolicy::Auto);
    }

    #[test]
    fn plane_key_parses() {
        let kv = KvConfig::parse("plane = host\n").unwrap();
        assert_eq!(ExperimentConfig::from_kv(&kv).unwrap().plane, PlanePolicy::Host);
        let kv = KvConfig::parse("plane = warp\n").unwrap();
        assert!(ExperimentConfig::from_kv(&kv).is_err());
    }

    #[test]
    fn unknown_keys_rejected_with_suggestion() {
        // the motivating typo: b_locl silently fell back to b_local=512
        let kv = KvConfig::parse("b_locl = 1024\n").unwrap();
        let err = ExperimentConfig::from_kv(&kv).unwrap_err().to_string();
        assert!(err.contains("b_locl"), "{err}");
        assert!(err.contains("did you mean 'b_local'"), "{err}");
        // far-from-everything keys get the generic pointer
        let kv = KvConfig::parse("zzzzqqqq = 1\n").unwrap();
        let err = ExperimentConfig::from_kv(&kv).unwrap_err().to_string();
        assert!(err.contains("unknown config key"), "{err}");
        // sectioned keys outside the guarded namespaces are the documented
        // file format for extensions, not typos: '[paths]\ncache=...'
        // flattens to 'paths.cache' and must pass
        let kv = KvConfig::parse("m = 8\n[paths]\ncache = /tmp/x\n").unwrap();
        assert_eq!(ExperimentConfig::from_kv(&kv).unwrap().m, 8);
    }

    #[test]
    fn scenario_keys_parse() {
        let kv = KvConfig::parse("scenario = drift\ndata_path = /tmp/x.libsvm\n").unwrap();
        let ec = ExperimentConfig::from_kv(&kv).unwrap();
        assert_eq!(ec.scenario.as_deref(), Some("drift"));
        assert_eq!(ec.data_path.as_deref(), Some("/tmp/x.libsvm"));
        // the scenario key itself is typo-guarded like every other key
        let kv = KvConfig::parse("scenaro = drift\n").unwrap();
        let err = ExperimentConfig::from_kv(&kv).unwrap_err().to_string();
        assert!(err.contains("did you mean 'scenario'"), "{err}");
    }

    #[test]
    fn prefetch_key_parses() {
        let kv = KvConfig::parse("prefetch = off\n").unwrap();
        assert_eq!(ExperimentConfig::from_kv(&kv).unwrap().prefetch, PrefetchPolicy::Off);
        let kv = KvConfig::parse("prefetch = sometimes\n").unwrap();
        assert!(ExperimentConfig::from_kv(&kv).is_err());
        assert_eq!(
            ExperimentConfig::default().prefetch,
            PrefetchPolicy::Auto,
            "prefetch defaults to auto (= on wherever the lane exists)"
        );
    }

    #[test]
    fn pipeline_key_parses() {
        let kv = KvConfig::parse("pipeline = off\n").unwrap();
        assert_eq!(ExperimentConfig::from_kv(&kv).unwrap().pipeline, PipelinePolicy::Off);
        let kv = KvConfig::parse("pipeline = maybe\n").unwrap();
        assert!(ExperimentConfig::from_kv(&kv).is_err());
        assert_eq!(
            ExperimentConfig::default().pipeline,
            PipelinePolicy::Auto,
            "pipeline defaults to auto (= on wherever batched fans run)"
        );
        // the new key is typo-guarded like every other key
        let kv = KvConfig::parse("pipelin = on\n").unwrap();
        let err = ExperimentConfig::from_kv(&kv).unwrap_err().to_string();
        assert!(err.contains("did you mean 'pipeline'"), "{err}");
    }

    #[test]
    fn upload_key_parses() {
        let kv = KvConfig::parse("upload = off\n").unwrap();
        assert_eq!(ExperimentConfig::from_kv(&kv).unwrap().upload, UploadPolicy::Off);
        let kv = KvConfig::parse("upload = maybe\n").unwrap();
        assert!(ExperimentConfig::from_kv(&kv).is_err());
        assert_eq!(
            ExperimentConfig::default().upload,
            UploadPolicy::Auto,
            "upload defaults to auto (= on wherever pooled operands upload)"
        );
        // the new key is typo-guarded like every other key
        let kv = KvConfig::parse("uploda = on\n").unwrap();
        let err = ExperimentConfig::from_kv(&kv).unwrap_err().to_string();
        assert!(err.contains("did you mean 'upload'"), "{err}");
    }

    #[test]
    fn scenario_namespace_parses_and_validates() {
        // section syntax and flat dotted keys are the same namespace
        let kv = KvConfig::parse(
            "[scenario]\ndrift_omega = 0.01\npareto_alpha = 3.5\nsparse_density = 0.2\n",
        )
        .unwrap();
        let ec = ExperimentConfig::from_kv(&kv).unwrap();
        assert_eq!(ec.drift_omega, Some(0.01));
        assert_eq!(ec.pareto_alpha, Some(3.5));
        assert_eq!(ec.sparse_density, Some(0.2));
        // absent keys mean "the scenario's own default"
        let ec = ExperimentConfig::from_kv(&KvConfig::parse("m = 2\n").unwrap()).unwrap();
        assert_eq!(ec.drift_omega, None);
        assert_eq!(ec.pareto_alpha, None);
        assert_eq!(ec.sparse_density, None);
        // domain guards: alpha <= 2 has infinite gradient variance,
        // density outside (0,1] is not a probability
        for bad in ["scenario.pareto_alpha = 2.0\n", "scenario.pareto_alpha = nan\n"] {
            let err =
                ExperimentConfig::from_kv(&KvConfig::parse(bad).unwrap()).unwrap_err().to_string();
            assert!(err.contains("pareto_alpha"), "{err}");
        }
        for bad in ["scenario.sparse_density = 0\n", "scenario.sparse_density = 1.5\n"] {
            let err =
                ExperimentConfig::from_kv(&KvConfig::parse(bad).unwrap()).unwrap_err().to_string();
            assert!(err.contains("sparse_density"), "{err}");
        }
        let err = ExperimentConfig::from_kv(&KvConfig::parse("scenario.drift_omega = -1\n").unwrap())
            .unwrap_err()
            .to_string();
        assert!(err.contains("drift_omega"), "{err}");
    }

    #[test]
    fn scenario_namespace_typos_are_rejected() {
        // unlike other sections, scenario.* is part of the accepted key
        // set — a typo must not silently leave the scenario on defaults
        let kv = KvConfig::parse("[scenario]\ndrift_omga = 0.01\n").unwrap();
        let err = ExperimentConfig::from_kv(&kv).unwrap_err().to_string();
        assert!(err.contains("scenario.drift_omga"), "{err}");
        assert!(err.contains("did you mean 'scenario.drift_omega'"), "{err}");
        // unguarded sections still pass through as config extensions
        let kv = KvConfig::parse("m = 8\n[paths]\ncache = /tmp/x\n").unwrap();
        assert_eq!(ExperimentConfig::from_kv(&kv).unwrap().m, 8);
    }

    #[test]
    fn net_namespace_parses_and_validates() {
        let kv = KvConfig::parse("[net]\nalpha = 1e-4\nbeta = 1e9\n").unwrap();
        let ec = ExperimentConfig::from_kv(&kv).unwrap();
        assert_eq!(ec.net_alpha, Some(1e-4));
        assert_eq!(ec.net_beta, Some(1e9));
        // absent = the runner's model; inf bandwidth = a free network
        let ec = ExperimentConfig::from_kv(&KvConfig::parse("m = 2\n").unwrap()).unwrap();
        assert_eq!(ec.net_alpha, None);
        assert_eq!(ec.net_beta, None);
        let kv = KvConfig::parse("net.beta = inf\n").unwrap();
        assert_eq!(ExperimentConfig::from_kv(&kv).unwrap().net_beta, Some(f64::INFINITY));
        for bad in ["net.alpha = -1\n", "net.alpha = inf\n", "net.beta = 0\n", "net.beta = -2\n"] {
            let err =
                ExperimentConfig::from_kv(&KvConfig::parse(bad).unwrap()).unwrap_err().to_string();
            assert!(err.contains("net."), "{bad}: {err}");
        }
        // net.* is a guarded namespace now: typos get did-you-mean
        let kv = KvConfig::parse("[net]\nalpa = 1e-4\n").unwrap();
        let err = ExperimentConfig::from_kv(&kv).unwrap_err().to_string();
        assert!(err.contains("did you mean 'net.alpha'"), "{err}");
    }

    #[test]
    fn faults_namespace_parses_and_validates() {
        let kv = KvConfig::parse(
            "faults = on\n[faults]\nstraggler_p = 0.3\nslowdown_alpha = 1.2\ndropout_p = 0.1\ndropout_rounds = 2\n",
        )
        .unwrap();
        let ec = ExperimentConfig::from_kv(&kv).unwrap();
        assert_eq!(ec.faults, FaultsPolicy::On);
        assert_eq!(ec.straggler_p, Some(0.3));
        assert_eq!(ec.slowdown_alpha, Some(1.2));
        assert_eq!(ec.dropout_p, Some(0.1));
        assert_eq!(ec.dropout_rounds, Some(2));
        let p = ec.fault_params().unwrap();
        assert_eq!(p.straggler_p, 0.3);
        assert_eq!(p.dropout_rounds, 2);
        // defaults fill absent knobs; off builds no plan at all
        let ec = ExperimentConfig::from_kv(&KvConfig::parse("faults = on\n").unwrap()).unwrap();
        assert_eq!(ec.fault_params(), Some(FaultParams::default()));
        let ec = ExperimentConfig::from_kv(&KvConfig::parse("m = 2\n").unwrap()).unwrap();
        assert_eq!(ec.faults, FaultsPolicy::Off);
        assert_eq!(ec.fault_params(), None);
        // domain guards
        for bad in [
            "faults = on\nfaults.straggler_p = 1.5\n",
            "faults = on\nfaults.dropout_p = -0.1\n",
            "faults = on\nfaults.slowdown_alpha = 0\n",
            "faults = on\nfaults.dropout_rounds = 0\n",
            "faults = maybe\n",
        ] {
            assert!(ExperimentConfig::from_kv(&KvConfig::parse(bad).unwrap()).is_err(), "{bad}");
        }
    }

    #[test]
    fn fault_knobs_without_the_switch_are_rejected() {
        // a knob that silently does nothing is worse than an error
        let kv = KvConfig::parse("faults.straggler_p = 0.3\n").unwrap();
        let err = ExperimentConfig::from_kv(&kv).unwrap_err().to_string();
        assert!(err.contains("faults=on"), "{err}");
        // faults.* is a guarded namespace: typos get did-you-mean
        let kv = KvConfig::parse("faults = on\nfaults.stragler_p = 0.3\n").unwrap();
        let err = ExperimentConfig::from_kv(&kv).unwrap_err().to_string();
        assert!(err.contains("did you mean 'faults.straggler_p'"), "{err}");
    }

    #[test]
    fn loads_from_file() {
        let dir = std::env::temp_dir().join("mbprox_config_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("exp.conf");
        std::fs::write(&path, "method = mp-dane\nm = 16\n").unwrap();
        let kv = KvConfig::load(&path).unwrap();
        let ec = ExperimentConfig::from_kv(&kv).unwrap();
        assert_eq!(ec.method, "mp-dane");
        assert_eq!(ec.m, 16);
        assert!(KvConfig::load(std::path::Path::new("/no/such/file")).is_err());
    }

    #[test]
    fn rejects_malformed() {
        assert!(KvConfig::parse("novalue\n").is_err());
        let kv = KvConfig::parse("loss = martian\n").unwrap();
        assert!(ExperimentConfig::from_kv(&kv).is_err());
    }

    #[test]
    fn canonical_serialization_round_trips() {
        // property: parse -> serialize -> parse is the identity, and the
        // canonical text is a fixed point of serialization
        use crate::util::testkit::forall;
        const KEYS: [&str; 8] = [
            "m",
            "b_local",
            "seed",
            "plane",
            "scenario",
            "scenario.drift_omega",
            "net.alpha",
            "serve.port",
        ];
        const VALS: [&str; 6] = ["1", "8", "2.5", "1e-4", "drift", "auto"];
        forall(64, |rng| {
            let mut kv = KvConfig::default();
            for _ in 0..rng.next_below(KEYS.len() + 1) {
                kv.set(KEYS[rng.next_below(KEYS.len())], VALS[rng.next_below(VALS.len())]);
            }
            let text = kv.to_canonical_string();
            let re = KvConfig::parse(&text).unwrap();
            assert_eq!(re, kv, "parse(serialize(kv)) != kv for:\n{text}");
            assert_eq!(re.to_canonical_string(), text, "canonical text is not a fixed point");
            assert_eq!(re.content_hash(), kv.content_hash());
        });
    }

    #[test]
    fn canonical_ordering_is_stable() {
        // insertion order must not leak into the canonical form
        let mut a = KvConfig::default();
        a.set("m", 8);
        a.set("b_local", 512);
        let mut b = KvConfig::default();
        b.set("b_local", 512);
        b.set("m", 8);
        assert_eq!(a.to_canonical_string(), "b_local=512\nm=8\n");
        assert_eq!(a.to_canonical_string(), b.to_canonical_string());
        assert_eq!(a.content_hash(), b.content_hash());
    }

    #[test]
    fn semantically_equal_configs_hash_equal() {
        // surface syntax — sections vs dotted keys, comments, quotes,
        // whitespace, line order — must not change the content hash
        let variants = [
            "m = 8\nscenario.drift_omega = 0.01\n",
            "m=8 # machines\n[scenario]\ndrift_omega = \"0.01\"\n",
            "[scenario]\ndrift_omega = 0.01\n# trailing comment\nm =\t8\n",
        ];
        let hashes: Vec<u64> =
            variants.iter().map(|t| KvConfig::parse(t).unwrap().content_hash()).collect();
        assert!(hashes.windows(2).all(|w| w[0] == w[1]), "{hashes:x?}");
        // a real difference must change it
        let other = KvConfig::parse("m = 8\nscenario.drift_omega = 0.02\n").unwrap();
        assert_ne!(other.content_hash(), hashes[0]);
        // exact value formatting is part of the address by design
        let reformatted = KvConfig::parse("m = 8\nscenario.drift_omega = 1e-2\n").unwrap();
        assert_ne!(reformatted.content_hash(), hashes[0]);
    }

    #[test]
    fn serve_config_parses_and_validates() {
        let kv = KvConfig::parse(
            "[serve]\nport = 8080\nqueue_depth = 4\ncache_capacity = 32\n",
        )
        .unwrap();
        let sc = ServeConfig::from_kv(&kv).unwrap();
        assert_eq!(sc.port, 8080);
        assert_eq!(sc.queue_depth, 4);
        assert_eq!(sc.cache_capacity, Some(32));
        // defaults: absent keys, empty config
        let sc = ServeConfig::from_kv(&KvConfig::default()).unwrap();
        assert_eq!(sc, ServeConfig::default());
        assert_eq!(sc.cache_capacity, None, "default cache is unbounded");
        // port 0 is the documented OS-assigned form
        let sc = ServeConfig::from_kv(&KvConfig::parse("serve.port = 0\n").unwrap()).unwrap();
        assert_eq!(sc.port, 0);
    }

    #[test]
    fn serve_config_rejects_bad_values_loudly() {
        // non-numeric port
        let err = ServeConfig::from_kv(&KvConfig::parse("serve.port = http\n").unwrap())
            .unwrap_err()
            .to_string();
        assert!(err.contains("serve.port"), "{err}");
        // out-of-range port
        let err = ServeConfig::from_kv(&KvConfig::parse("serve.port = 70000\n").unwrap())
            .unwrap_err()
            .to_string();
        assert!(err.contains("65535"), "{err}");
        // a depth-0 queue could accept no job
        let err = ServeConfig::from_kv(&KvConfig::parse("serve.queue_depth = 0\n").unwrap())
            .unwrap_err()
            .to_string();
        assert!(err.contains("serve.queue_depth"), "{err}");
        // capacity 0 would recompile every dispatch
        let err = ServeConfig::from_kv(&KvConfig::parse("serve.cache_capacity = 0\n").unwrap())
            .unwrap_err()
            .to_string();
        assert!(err.contains("serve.cache_capacity"), "{err}");
    }

    #[test]
    fn serve_namespace_typos_get_did_you_mean() {
        // serve.* is a guarded namespace: typos take the shared matcher
        let err = ServeConfig::from_kv(&KvConfig::parse("serve.prot = 8080\n").unwrap())
            .unwrap_err()
            .to_string();
        assert!(err.contains("did you mean 'serve.port'"), "{err}");
        let err = ServeConfig::from_kv(&KvConfig::parse("[serve]\nqueue_dept = 4\n").unwrap())
            .unwrap_err()
            .to_string();
        assert!(err.contains("did you mean 'serve.queue_depth'"), "{err}");
    }

    #[test]
    fn serve_keys_outside_serve_mode_are_rejected() {
        // mirrors the faults.*-without-faults=on rule: a serve.* key in a
        // run config would silently do nothing
        let kv = KvConfig::parse("m = 8\nserve.port = 8080\n").unwrap();
        let err = ExperimentConfig::from_kv(&kv).unwrap_err().to_string();
        assert!(err.contains("serve.port"), "{err}");
        assert!(err.contains("mbprox serve"), "{err}");
        // and the mirror image: experiment keys are not serve settings
        let kv = KvConfig::parse("serve.port = 8080\nm = 8\n").unwrap();
        let err = ServeConfig::from_kv(&kv).unwrap_err().to_string();
        assert!(err.contains("'m'"), "{err}");
        assert!(err.contains("POSTed to /run"), "{err}");
    }
}
