//! # mbprox
//!
//! Reproduction of *"Memory and Communication Efficient Distributed
//! Stochastic Optimization with Minibatch-Prox"* (Wang, Wang & Srebro,
//! 2017) as a three-layer rust + JAX + Pallas stack:
//!
//! - **L3 (this crate)**: the distributed coordinator — simulated
//!   m-machine cluster, collectives with exact round/vector accounting,
//!   the minibatch-prox outer loop, MP-DSVRG / MP-DANE inner solvers, and
//!   every baseline from Table 1.
//! - **L2/L1 (`python/compile`)**: JAX graphs calling Pallas kernels,
//!   AOT-lowered once to HLO text (`make artifacts`) and executed here via
//!   the PJRT CPU client — Python is never on the request path.
//!
//! Start with [`runtime::Engine`] + [`runtime::plane::ExecPlane`] +
//! [`algos`]; see `examples/quickstart.rs`.

pub mod accounting;
pub mod algos;
pub mod comm;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod linalg;
pub mod metrics;
pub mod objective;
pub mod runtime;
pub mod serve;
pub mod theory;
pub mod util;

pub use runtime::Engine;
