//! Resource accounting in the paper's units (Table 1 / Table 2).
//!
//! Everything is counted **per machine** in units of *vectors*:
//!   - `vec_ops`        computation: number of d-dimensional vector operations
//!   - `comm_rounds`    rounds of communication the machine participates in
//!   - `vectors_sent`   vectors transmitted by the machine
//!   - `samples`        samples drawn from the stream
//!   - `peak_vectors`   maximum number of vectors simultaneously stored
//!                      (memory; a stored sample counts as one vector)
//!
//! The `MemoryTracker` is a high-water-mark gauge; algorithms charge
//! allocations/frees as they hold or release sample blocks and iterates.

#[derive(Clone, Debug, Default, PartialEq)]
pub struct ResourceMeter {
    pub vec_ops: u64,
    pub comm_rounds: u64,
    pub vectors_sent: u64,
    pub samples: u64,
    cur_vectors: i64,
    pub peak_vectors: u64,
}

impl ResourceMeter {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add_vec_ops(&mut self, n: u64) {
        self.vec_ops += n;
    }

    pub fn add_comm_round(&mut self, vectors: u64) {
        self.comm_rounds += 1;
        self.vectors_sent += vectors;
    }

    pub fn add_samples(&mut self, n: u64) {
        self.samples += n;
    }

    /// Charge `n` vectors of storage; returns a guard-less handle — callers
    /// must `release` symmetric amounts (checked in debug).
    pub fn hold(&mut self, n: u64) {
        self.cur_vectors += n as i64;
        self.peak_vectors = self.peak_vectors.max(self.cur_vectors as u64);
    }

    pub fn release(&mut self, n: u64) {
        self.cur_vectors -= n as i64;
        debug_assert!(self.cur_vectors >= 0, "released more memory than held");
    }

    pub fn current_vectors(&self) -> i64 {
        self.cur_vectors
    }

    /// Merge another meter (e.g. fold sub-phase accounting into a parent).
    pub fn merge(&mut self, other: &ResourceMeter) {
        self.vec_ops += other.vec_ops;
        self.comm_rounds += other.comm_rounds;
        self.vectors_sent += other.vectors_sent;
        self.samples += other.samples;
        // memory: concurrent composition — peak is max of (our current +
        // their peak) vs our existing peak
        self.peak_vectors = self
            .peak_vectors
            .max((self.cur_vectors.max(0) as u64) + other.peak_vectors);
    }
}

/// Per-machine meters for an m-machine run, plus helpers that produce the
/// Table 1 row (max over machines, the paper's "per machine" bound).
#[derive(Clone, Debug)]
pub struct ClusterMeter {
    pub machines: Vec<ResourceMeter>,
}

impl ClusterMeter {
    pub fn new(m: usize) -> Self {
        Self { machines: vec![ResourceMeter::new(); m] }
    }

    pub fn m(&self) -> usize {
        self.machines.len()
    }

    pub fn machine(&mut self, i: usize) -> &mut ResourceMeter {
        &mut self.machines[i]
    }

    /// Charge the same comm round on every machine (a collective).
    pub fn all_comm_round(&mut self, vectors_per_machine: u64) {
        for m in &mut self.machines {
            m.add_comm_round(vectors_per_machine);
        }
    }

    /// Charge identical local computation on every machine (SPMD step).
    pub fn all_vec_ops(&mut self, n: u64) {
        for m in &mut self.machines {
            m.add_vec_ops(n);
        }
    }

    pub fn report(&self) -> ResourceReport {
        let mx = |f: fn(&ResourceMeter) -> u64| self.machines.iter().map(f).max().unwrap_or(0);
        let total_samples: u64 = self.machines.iter().map(|m| m.samples).sum();
        ResourceReport {
            m: self.machines.len(),
            total_samples,
            comm_rounds: mx(|r| r.comm_rounds),
            vectors_sent: mx(|r| r.vectors_sent),
            vec_ops: mx(|r| r.vec_ops),
            peak_vectors: mx(|r| r.peak_vectors),
            peak_per_machine: self.machines.iter().map(|r| r.peak_vectors).collect(),
        }
    }
}

/// Host<->device traffic summary derived from the engine's
/// [`crate::runtime::EngineStats`] — the runtime-layer companion of the
/// paper-units [`ResourceReport`]. One row per bench/run shows whether the
/// device-residency contract holds: uploads per round O(1), one download
/// per fused group on the dispatch verb, and NO downloads at all on the
/// chain verb (`chained` counts dispatches whose output stayed on device).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct DeviceTraffic {
    pub executions: u64,
    /// executions whose output stayed on device (the chain verb)
    pub chained: u64,
    pub uploads: u64,
    pub upload_bytes: u64,
    pub downloads: u64,
    pub download_bytes: u64,
    pub cache_hits: u64,
    pub cache_misses: u64,
}

impl DeviceTraffic {
    pub fn from_stats(s: &crate::runtime::EngineStats) -> DeviceTraffic {
        DeviceTraffic {
            executions: s.executions,
            chained: s.chained_dispatches,
            uploads: s.uploads,
            upload_bytes: s.upload_bytes,
            downloads: s.downloads,
            download_bytes: s.download_bytes,
            cache_hits: s.upload_cache_hits,
            cache_misses: s.upload_cache_misses,
        }
    }

    /// Traffic accrued since an earlier snapshot (per-phase deltas).
    pub fn since(&self, earlier: &DeviceTraffic) -> DeviceTraffic {
        DeviceTraffic {
            executions: self.executions - earlier.executions,
            chained: self.chained - earlier.chained,
            uploads: self.uploads - earlier.uploads,
            upload_bytes: self.upload_bytes - earlier.upload_bytes,
            downloads: self.downloads - earlier.downloads,
            download_bytes: self.download_bytes - earlier.download_bytes,
            cache_hits: self.cache_hits - earlier.cache_hits,
            cache_misses: self.cache_misses - earlier.cache_misses,
        }
    }

    pub fn header() -> String {
        format!(
            "{:<28} {:>10} {:>8} {:>9} {:>12} {:>10} {:>12} {:>10} {:>10}",
            "phase", "dispatches", "chained", "uploads", "up_bytes", "downloads", "down_bytes",
            "hits", "misses"
        )
    }

    pub fn row(&self, name: &str) -> String {
        format!(
            "{:<28} {:>10} {:>8} {:>9} {:>12} {:>10} {:>12} {:>10} {:>10}",
            name,
            self.executions,
            self.chained,
            self.uploads,
            self.upload_bytes,
            self.downloads,
            self.download_bytes,
            self.cache_hits,
            self.cache_misses
        )
    }
}

/// Draw-staging counters for the shard plane's prefetch lane: how many
/// machine draws the engine thread requested (`takes`), how many were
/// served from a warm stage (`hits`) vs drawn synchronously on demand
/// (`misses`), and the total wall-clock the engine thread spent blocked
/// waiting for packs (`stall_ns` — the dispatch stall the lane exists to
/// hide). One meter per shard; reset between runs so the numbers are
/// per-run, and gathered via [`crate::runtime::ShardPool::gathered_stalls`].
#[derive(Clone, Debug, Default, PartialEq)]
pub struct StallMeter {
    /// draw requests the engine thread routed through the lane
    pub takes: u64,
    /// takes served from a warm stage (the pack was ready before the ask)
    pub hits: u64,
    /// takes that drew synchronously (cold stage, size mismatch, or
    /// prefetch off)
    pub misses: u64,
    /// nanoseconds the engine thread blocked waiting for its packs
    pub stall_ns: u64,
}

impl StallMeter {
    /// Record one served take.
    pub fn record(&mut self, hit: bool, stall_ns: u64) {
        self.takes += 1;
        if hit {
            self.hits += 1;
        } else {
            self.misses += 1;
        }
        self.stall_ns += stall_ns;
    }

    /// Fold another shard's meter in (cluster totals).
    pub fn merge(&mut self, other: &StallMeter) {
        self.takes += other.takes;
        self.hits += other.hits;
        self.misses += other.misses;
        self.stall_ns += other.stall_ns;
    }

    /// Fraction of takes served from a warm stage (0 when nothing drawn).
    pub fn hit_rate(&self) -> f64 {
        if self.takes == 0 {
            0.0
        } else {
            self.hits as f64 / self.takes as f64
        }
    }
}

/// Software-pipeline counters for the shard plane's batched fans: how many
/// batched per-shard fan jobs the worker executed (`fans`), how many lane
/// requests were issued ahead of their collect point (`staged`), and how
/// the engine thread's wall-clock split between work done while a staged
/// request was in flight on the lane (`overlap_ns`) and work done with
/// nothing staged (`serial_ns` — all of it when `pipeline=off`). Like
/// [`StallMeter`], this is wall-clock-only diagnostics: it measures what
/// the real machine overlapped, NOT the paper's simulated cost model,
/// which charges identical units whether the pipeline is on or off. One
/// meter per shard; reset between runs and gathered via
/// [`crate::runtime::ShardPool::gathered_overlap`].
#[derive(Clone, Debug, Default, PartialEq)]
pub struct OverlapMeter {
    /// batched per-shard fan jobs the worker executed
    pub fans: u64,
    /// lane requests issued ahead of their collect point
    pub staged: u64,
    /// engine-work nanoseconds spent while a staged request was in flight
    pub overlap_ns: u64,
    /// engine-work nanoseconds spent with nothing staged
    pub serial_ns: u64,
}

impl OverlapMeter {
    /// Record one machine's engine-work slice within a fan.
    pub fn record(&mut self, staged: bool, work_ns: u64) {
        if staged {
            self.staged += 1;
            self.overlap_ns += work_ns;
        } else {
            self.serial_ns += work_ns;
        }
    }

    /// Fold another shard's meter in (cluster totals).
    pub fn merge(&mut self, other: &OverlapMeter) {
        self.fans += other.fans;
        self.staged += other.staged;
        self.overlap_ns += other.overlap_ns;
        self.serial_ns += other.serial_ns;
    }

    /// Fraction of engine-work wall-clock that ran under a staged request
    /// (0 when no work was recorded).
    pub fn overlap_frac(&self) -> f64 {
        let total = self.overlap_ns + self.serial_ns;
        if total == 0 {
            0.0
        } else {
            self.overlap_ns as f64 / total as f64
        }
    }
}

/// Upload-lane counters for the engine's staging-ring double buffer:
/// `uploads`/`bytes` count the host->device transfers the engine actually
/// performed for pooled small operands (identical with the lane on or off
/// — the lane reorders transfers, it never adds or drops one), `staged`
/// counts the transfers that ran into the BACK ring half while a dispatch
/// could still be in flight (with their wall-clock in `overlap_ns`), and
/// `wait_ns` is the time the dispatch boundary blocked on a stage that
/// had not finished. Like [`StallMeter`] and [`OverlapMeter`], this is
/// wall-clock-only diagnostics: it measures what the real machine
/// overlapped, NOT the paper's simulated cost model, which charges
/// identical units whether the lane is on or off. One meter per engine
/// (coordinator + each shard); reset per run and gathered via
/// [`crate::runtime::ShardPool::gathered_run_meters`].
#[derive(Clone, Debug, Default, PartialEq)]
pub struct UploadMeter {
    /// host->device transfers performed for pooled/ring operands
    pub uploads: u64,
    /// transfers staged into the back ring half (lane on only)
    pub staged: u64,
    /// wall-clock nanoseconds of staged transfers (overlappable work)
    pub overlap_ns: u64,
    /// nanoseconds the dispatch boundary blocked waiting on a stage
    pub wait_ns: u64,
    /// bytes moved by the counted transfers (equal with the lane on/off)
    pub bytes: u64,
}

impl UploadMeter {
    /// Record `n` transfers moving `bytes`; `staged` marks them as ring
    /// stages with `work_ns` of overlappable transfer wall-clock.
    pub fn record(&mut self, staged: bool, n: u64, bytes: u64, work_ns: u64) {
        self.uploads += n;
        self.bytes += bytes;
        if staged && n > 0 {
            self.staged += n;
            self.overlap_ns += work_ns;
        }
    }

    /// Charge time the dispatch boundary spent blocked on a stage.
    pub fn add_wait(&mut self, ns: u64) {
        self.wait_ns += ns;
    }

    /// Fold another engine's meter in (cluster totals).
    pub fn merge(&mut self, other: &UploadMeter) {
        self.uploads += other.uploads;
        self.staged += other.staged;
        self.overlap_ns += other.overlap_ns;
        self.wait_ns += other.wait_ns;
        self.bytes += other.bytes;
    }

    /// True when any transfer was recorded at all.
    pub fn any(&self) -> bool {
        *self != UploadMeter::default()
    }
}

/// Fault-injection and recovery counters for one run. The simulated-event
/// fields (stragglers, dropouts, re-entries, `added_time_s`) come from the
/// seeded `comm::faults::FaultPlan` and are deterministic functions of the
/// experiment seed — identical across reruns and shard counts. The
/// recovery fields (`recoveries`, `replays`) count REAL events on this
/// host: shard workers the pool restarted and fan batches it replayed.
/// Like [`StallMeter`] and [`OverlapMeter`], nothing here touches the
/// paper's cost model: rounds, vectors, samples and memory are charged
/// identically with faults on or off, and the meter does NOT measure
/// wall-clock — `added_time_s` is simulated network time only.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultMeter {
    /// collective rounds whose simulated time any fault scaled
    pub slow_rounds: u64,
    /// straggler events (machine-rounds drawn slow)
    pub stragglers: u64,
    /// dropout events (a machine leaving the cluster)
    pub dropouts: u64,
    /// machine-rounds spent dropped out (including the drop round)
    pub dropped_rounds: u64,
    /// machines re-admitted at a collective boundary
    pub reentries: u64,
    /// shard workers restarted by supervised recovery (real, not simulated)
    pub recoveries: u64,
    /// fan batches replayed after a worker death (real, not simulated)
    pub replays: u64,
    /// simulated seconds added on top of the fault-free network model
    pub added_time_s: f64,
}

impl FaultMeter {
    /// Fold another meter in (cluster totals / plan + pool combine).
    pub fn merge(&mut self, other: &FaultMeter) {
        self.slow_rounds += other.slow_rounds;
        self.stragglers += other.stragglers;
        self.dropouts += other.dropouts;
        self.dropped_rounds += other.dropped_rounds;
        self.reentries += other.reentries;
        self.recoveries += other.recoveries;
        self.replays += other.replays;
        self.added_time_s += other.added_time_s;
    }

    /// True when any fault or recovery event was recorded at all.
    pub fn any(&self) -> bool {
        *self != FaultMeter::default()
    }
}

/// Content-addressed cache counters for the runtime's executable cache
/// (and the serve layer's warm-instance cache): `hits` are lookups served
/// from an already-compiled entry, `misses` are lookups that had to
/// compile (with the wall-clock spent compiling in `compile_ns`), and
/// `evictions` counts entries dropped by a capacity cap. Like
/// [`StallMeter`] and [`OverlapMeter`], this is wall-clock/host-side
/// diagnostics ONLY: it does NOT measure the paper's simulated cost model
/// — rounds, vectors, samples and memory are charged identically whether
/// a run compiled everything cold or hit a warm cache, and iterates are
/// bit-identical either way (pinned by `rust/tests/serve_parity.rs`).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct CacheMeter {
    /// lookups served from an already-resident entry
    pub hits: u64,
    /// lookups that had to build (compile) the entry
    pub misses: u64,
    /// wall-clock nanoseconds spent building on misses
    pub compile_ns: u64,
    /// entries dropped to stay under a capacity cap
    pub evictions: u64,
}

impl CacheMeter {
    /// Record a lookup served warm.
    pub fn record_hit(&mut self) {
        self.hits += 1;
    }

    /// Record a lookup that compiled, with the build wall-clock.
    pub fn record_miss(&mut self, compile_ns: u64) {
        self.misses += 1;
        self.compile_ns += compile_ns;
    }

    /// Record one capacity eviction.
    pub fn record_eviction(&mut self) {
        self.evictions += 1;
    }

    /// Fold another meter in (coordinator engine + shard engines).
    pub fn merge(&mut self, other: &CacheMeter) {
        self.hits += other.hits;
        self.misses += other.misses;
        self.compile_ns += other.compile_ns;
        self.evictions += other.evictions;
    }

    /// Counters accrued since an earlier snapshot — the per-job view on a
    /// resident engine whose meter is cumulative across queued runs.
    pub fn since(&self, earlier: &CacheMeter) -> CacheMeter {
        CacheMeter {
            hits: self.hits - earlier.hits,
            misses: self.misses - earlier.misses,
            compile_ns: self.compile_ns - earlier.compile_ns,
            evictions: self.evictions - earlier.evictions,
        }
    }

    /// Fraction of lookups served warm (0 when nothing was looked up).
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// The Table-1 row: per-machine maxima + total samples.
#[derive(Clone, Debug, PartialEq)]
pub struct ResourceReport {
    pub m: usize,
    pub total_samples: u64,
    pub comm_rounds: u64,
    pub vectors_sent: u64,
    pub vec_ops: u64,
    /// cluster max of the per-machine peaks — the paper's "memory per
    /// machine" bound
    pub peak_vectors: u64,
    /// every machine's peak held-vector count, in machine order: the
    /// honest memory axis (a ragged draw or a designated-sweeper role
    /// shows up here, not just in the max)
    pub peak_per_machine: Vec<u64>,
}

impl ResourceReport {
    pub fn header() -> String {
        format!(
            "{:<22} {:>10} {:>12} {:>14} {:>12} {:>12}",
            "method", "samples", "comm_rounds", "vec_ops", "memory", "vectors_sent"
        )
    }

    pub fn row(&self, name: &str) -> String {
        format!(
            "{:<22} {:>10} {:>12} {:>14} {:>12} {:>12}",
            name, self.total_samples, self.comm_rounds, self.vec_ops, self.peak_vectors,
            self.vectors_sent
        )
    }

    /// Per-machine peaks as a compact display string, e.g. `"514 514 513"`.
    pub fn peaks_display(&self) -> String {
        self.peak_per_machine.iter().map(u64::to_string).collect::<Vec<_>>().join(" ")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::testkit::forall;

    #[test]
    fn memory_high_water_mark() {
        let mut m = ResourceMeter::new();
        m.hold(10);
        m.hold(5);
        m.release(12);
        m.hold(4);
        assert_eq!(m.peak_vectors, 15);
        assert_eq!(m.current_vectors(), 7);
    }

    #[test]
    fn comm_round_counts_vectors() {
        let mut m = ResourceMeter::new();
        m.add_comm_round(3);
        m.add_comm_round(1);
        assert_eq!(m.comm_rounds, 2);
        assert_eq!(m.vectors_sent, 4);
    }

    #[test]
    fn cluster_collective_charges_everyone() {
        let mut c = ClusterMeter::new(4);
        c.all_comm_round(2);
        c.machine(1).add_vec_ops(7);
        let r = c.report();
        assert_eq!(r.comm_rounds, 1);
        assert_eq!(r.vec_ops, 7); // max over machines
    }

    #[test]
    fn prop_merge_is_additive_on_flows() {
        forall(32, |rng| {
            let mut a = ResourceMeter::new();
            let mut b = ResourceMeter::new();
            let (x, y) = (rng.next_below(100) as u64, rng.next_below(100) as u64);
            a.add_vec_ops(x);
            b.add_vec_ops(y);
            a.add_samples(x);
            b.add_samples(y);
            let mut merged = a.clone();
            merged.merge(&b);
            assert_eq!(merged.vec_ops, x + y);
            assert_eq!(merged.samples, x + y);
        });
    }

    #[test]
    fn prop_peak_never_decreases() {
        forall(32, |rng| {
            let mut m = ResourceMeter::new();
            let mut held: u64 = 0;
            let mut last_peak = 0;
            for _ in 0..50 {
                if rng.next_f64() < 0.6 {
                    let n = rng.next_below(10) as u64;
                    m.hold(n);
                    held += n;
                } else if held > 0 {
                    let n = (rng.next_below(held as usize) + 1) as u64;
                    m.release(n.min(held));
                    held -= n.min(held);
                }
                assert!(m.peak_vectors >= last_peak);
                last_peak = m.peak_vectors;
            }
        });
    }

    #[test]
    fn report_rows_align() {
        let c = ClusterMeter::new(2);
        let r = c.report();
        assert_eq!(ResourceReport::header().len(), r.row("x").len());
    }

    #[test]
    fn report_carries_per_machine_peaks() {
        let mut c = ClusterMeter::new(3);
        c.machine(0).hold(5);
        c.machine(1).hold(9);
        c.machine(1).release(9);
        c.machine(2).hold(2);
        let r = c.report();
        assert_eq!(r.peak_per_machine, vec![5, 9, 2]);
        assert_eq!(r.peak_vectors, 9, "cluster peak is the per-machine max");
        assert_eq!(r.peaks_display(), "5 9 2");
    }

    #[test]
    fn stall_meter_records_and_merges() {
        let mut a = StallMeter::default();
        a.record(true, 10);
        a.record(false, 100);
        a.record(true, 5);
        assert_eq!(a.takes, 3);
        assert_eq!(a.hits, 2);
        assert_eq!(a.misses, 1);
        assert_eq!(a.stall_ns, 115);
        assert!((a.hit_rate() - 2.0 / 3.0).abs() < 1e-12);
        let mut b = StallMeter::default();
        b.record(false, 50);
        b.merge(&a);
        assert_eq!(b.takes, 4);
        assert_eq!(b.hits, 2);
        assert_eq!(b.misses, 2);
        assert_eq!(b.stall_ns, 165);
        assert_eq!(StallMeter::default().hit_rate(), 0.0);
    }

    #[test]
    fn overlap_meter_records_and_merges() {
        let mut a = OverlapMeter::default();
        a.fans += 1;
        a.record(true, 10);
        a.record(false, 100);
        a.record(true, 5);
        assert_eq!(a.fans, 1);
        assert_eq!(a.staged, 2);
        assert_eq!(a.overlap_ns, 15);
        assert_eq!(a.serial_ns, 100);
        assert!((a.overlap_frac() - 15.0 / 115.0).abs() < 1e-12);
        let mut b = OverlapMeter::default();
        b.fans += 1;
        b.record(false, 50);
        b.merge(&a);
        assert_eq!(b.fans, 2);
        assert_eq!(b.staged, 2);
        assert_eq!(b.overlap_ns, 15);
        assert_eq!(b.serial_ns, 150);
        assert_eq!(OverlapMeter::default().overlap_frac(), 0.0);
    }

    #[test]
    fn upload_meter_records_and_merges() {
        let mut a = UploadMeter::default();
        a.record(true, 2, 64, 10);
        a.record(false, 1, 32, 100);
        a.record(true, 1, 32, 5);
        // a skipped transfer records nothing, staged or not
        a.record(true, 0, 0, 7);
        a.add_wait(3);
        assert_eq!(a.uploads, 4);
        assert_eq!(a.staged, 3);
        assert_eq!(a.overlap_ns, 15);
        assert_eq!(a.wait_ns, 3);
        assert_eq!(a.bytes, 128);
        assert!(a.any());
        let mut b = UploadMeter::default();
        b.record(false, 1, 16, 50);
        b.merge(&a);
        assert_eq!(b.uploads, 5);
        assert_eq!(b.staged, 3);
        assert_eq!(b.overlap_ns, 15);
        assert_eq!(b.wait_ns, 3);
        assert_eq!(b.bytes, 144);
        assert!(!UploadMeter::default().any());
    }

    #[test]
    fn fault_meter_merges_and_reports_any() {
        let mut a = FaultMeter::default();
        assert!(!a.any());
        a.slow_rounds = 2;
        a.stragglers = 3;
        a.added_time_s = 0.5;
        assert!(a.any());
        let mut b =
            FaultMeter { dropouts: 1, dropped_rounds: 4, reentries: 1, ..Default::default() };
        b.recoveries = 1;
        b.replays = 2;
        b.merge(&a);
        assert_eq!(b.slow_rounds, 2);
        assert_eq!(b.stragglers, 3);
        assert_eq!(b.dropouts, 1);
        assert_eq!(b.dropped_rounds, 4);
        assert_eq!(b.reentries, 1);
        assert_eq!(b.recoveries, 1);
        assert_eq!(b.replays, 2);
        assert!((b.added_time_s - 0.5).abs() < 1e-12);
    }

    #[test]
    fn cache_meter_records_merges_and_deltas() {
        let mut a = CacheMeter::default();
        assert_eq!(a.hit_rate(), 0.0);
        a.record_miss(100);
        a.record_hit();
        a.record_hit();
        a.record_eviction();
        assert_eq!(a.hits, 2);
        assert_eq!(a.misses, 1);
        assert_eq!(a.compile_ns, 100);
        assert_eq!(a.evictions, 1);
        assert!((a.hit_rate() - 2.0 / 3.0).abs() < 1e-12);
        let mut b = CacheMeter::default();
        b.record_miss(50);
        b.merge(&a);
        assert_eq!(b.hits, 2);
        assert_eq!(b.misses, 2);
        assert_eq!(b.compile_ns, 150);
        assert_eq!(b.evictions, 1);
        // since: the per-job delta on a cumulative meter
        let d = b.since(&a);
        assert_eq!(d, CacheMeter { hits: 0, misses: 1, compile_ns: 50, evictions: 0 });
    }

    #[test]
    fn device_traffic_deltas() {
        let a = DeviceTraffic { executions: 3, uploads: 5, upload_bytes: 100, ..Default::default() };
        let b = DeviceTraffic {
            executions: 10,
            uploads: 6,
            upload_bytes: 356,
            cache_hits: 4,
            ..Default::default()
        };
        let d = b.since(&a);
        assert_eq!(d.executions, 7);
        assert_eq!(d.uploads, 1);
        assert_eq!(d.upload_bytes, 256);
        assert_eq!(d.cache_hits, 4);
        assert_eq!(DeviceTraffic::header().len(), d.row("x").len());
    }
}
