//! Dense vector operations for the coordinator hot path.
//!
//! Everything the paper counts as a "vector operation" at the L3 layer goes
//! through here, so callers can meter them uniformly (see `accounting`).
//! Kept deliberately simple: contiguous `f32` slices, no blocking — the
//! heavy matrix work lives in the AOT HLO artifacts, not here.

pub mod cg;

/// y += alpha * x
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

/// <x, y>
#[inline]
pub fn dot(x: &[f32], y: &[f32]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    x.iter().zip(y).map(|(&a, &b)| a as f64 * b as f64).sum()
}

/// ||x||_2
#[inline]
pub fn nrm2(x: &[f32]) -> f64 {
    dot(x, x).sqrt()
}

/// ||x - y||_2
#[inline]
pub fn dist2(x: &[f32], y: &[f32]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    x.iter()
        .zip(y)
        .map(|(&a, &b)| {
            let d = a as f64 - b as f64;
            d * d
        })
        .sum::<f64>()
        .sqrt()
}

/// x *= alpha
#[inline]
pub fn scale(alpha: f32, x: &mut [f32]) {
    for xi in x.iter_mut() {
        *xi *= alpha;
    }
}

/// out = x - y (allocating)
pub fn sub(x: &[f32], y: &[f32]) -> Vec<f32> {
    debug_assert_eq!(x.len(), y.len());
    x.iter().zip(y).map(|(&a, &b)| a - b).collect()
}

/// out = x + y (allocating)
pub fn add(x: &[f32], y: &[f32]) -> Vec<f32> {
    debug_assert_eq!(x.len(), y.len());
    x.iter().zip(y).map(|(&a, &b)| a + b).collect()
}

/// dst = src
#[inline]
pub fn copy(src: &[f32], dst: &mut [f32]) {
    dst.copy_from_slice(src);
}

/// Weighted running average accumulator: acc = acc + w * x
pub struct WeightedAvg {
    sum: Vec<f64>,
    total_w: f64,
}

impl WeightedAvg {
    pub fn new(dim: usize) -> Self {
        Self { sum: vec![0.0; dim], total_w: 0.0 }
    }

    pub fn add(&mut self, w: f64, x: &[f32]) {
        debug_assert_eq!(x.len(), self.sum.len());
        for (s, &xi) in self.sum.iter_mut().zip(x) {
            *s += w * xi as f64;
        }
        self.total_w += w;
    }

    pub fn total_weight(&self) -> f64 {
        self.total_w
    }

    pub fn mean(&self) -> Vec<f32> {
        if self.total_w == 0.0 {
            return self.sum.iter().map(|_| 0.0).collect();
        }
        self.sum.iter().map(|&s| (s / self.total_w) as f32).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::testkit::{assert_close, assert_close_scalar, forall, normal_vec};

    #[test]
    fn axpy_matches_manual() {
        let x = vec![1.0, 2.0, 3.0];
        let mut y = vec![10.0, 20.0, 30.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, vec![12.0, 24.0, 36.0]);
    }

    #[test]
    fn dot_and_norm() {
        assert_eq!(dot(&[1.0, 2.0], &[3.0, 4.0]), 11.0);
        assert_close_scalar(nrm2(&[3.0, 4.0]), 5.0, 1e-12, 0.0);
    }

    #[test]
    fn prop_dot_symmetric_and_linear() {
        forall(32, |rng| {
            let n = 1 + rng.next_below(64);
            let x = normal_vec(rng, n);
            let y = normal_vec(rng, n);
            let z = normal_vec(rng, n);
            assert_close_scalar(dot(&x, &y), dot(&y, &x), 1e-9, 1e-9);
            let xy = add(&x, &y);
            assert_close_scalar(dot(&xy, &z), dot(&x, &z) + dot(&y, &z), 1e-5, 1e-5);
        });
    }

    #[test]
    fn prop_dist_triangle_inequality() {
        forall(32, |rng| {
            let n = 1 + rng.next_below(32);
            let x = normal_vec(rng, n);
            let y = normal_vec(rng, n);
            let z = normal_vec(rng, n);
            assert!(dist2(&x, &z) <= dist2(&x, &y) + dist2(&y, &z) + 1e-5);
        });
    }

    #[test]
    fn weighted_avg_mean() {
        let mut acc = WeightedAvg::new(2);
        acc.add(1.0, &[1.0, 0.0]);
        acc.add(3.0, &[5.0, 4.0]);
        assert_close(&acc.mean(), &[4.0, 3.0], 1e-6, 1e-6);
        assert_eq!(acc.total_weight(), 4.0);
    }

    #[test]
    fn weighted_avg_empty_is_zero() {
        let acc = WeightedAvg::new(3);
        assert_eq!(acc.mean(), vec![0.0, 0.0, 0.0]);
    }
}
