//! Conjugate gradient over an abstract SPD operator.
//!
//! Used by (a) the exact minibatch-prox solver for least squares — the prox
//! subproblem `min_w phi_I(w) + gamma/2||w - w_prev||^2` has optimality
//! system `((1/n) X^T X + gamma I) w = (1/n) X^T y + gamma w_prev`, whose
//! matvec is the AOT `nm_sq_*` artifact — and (b) the DiSCO-style
//! distributed Newton baseline (distributed CG on the regularized Hessian).

use super::{axpy, copy, dot};

/// An SPD linear operator `v -> A v`. Implementations report how many
/// "vector operations" one application costs so callers can meter compute
/// in the paper's units (see `accounting`).
pub trait LinearOp {
    fn dim(&self) -> usize;
    fn apply(&mut self, v: &[f32], out: &mut [f32]);
    /// Cost of one apply, in vector operations (paper units).
    fn cost_vec_ops(&self) -> u64 {
        1
    }
}

#[derive(Clone, Debug)]
pub struct CgResult {
    pub iters: usize,
    pub residual_norm: f64,
    pub converged: bool,
    pub vec_ops: u64,
}

/// Solve `A x = b` to relative residual `tol`, starting from `x` in place.
pub fn solve<A: LinearOp>(
    a: &mut A,
    b: &[f32],
    x: &mut [f32],
    tol: f64,
    max_iters: usize,
) -> CgResult {
    let n = a.dim();
    assert_eq!(b.len(), n);
    assert_eq!(x.len(), n);
    let mut vec_ops: u64 = 0;

    let mut r = vec![0.0f32; n];
    let mut ap = vec![0.0f32; n];
    // r = b - A x
    a.apply(x, &mut ap);
    vec_ops += a.cost_vec_ops();
    for i in 0..n {
        r[i] = b[i] - ap[i];
    }
    vec_ops += 1;
    let mut p = r.clone();
    let b_norm = dot(b, b).sqrt().max(1e-30);
    let mut rs_old = dot(&r, &r);
    vec_ops += 1;

    let mut iters = 0;
    while iters < max_iters {
        let res = rs_old.sqrt() / b_norm;
        if res <= tol {
            return CgResult { iters, residual_norm: res, converged: true, vec_ops };
        }
        a.apply(&p, &mut ap);
        vec_ops += a.cost_vec_ops();
        let p_ap = dot(&p, &ap);
        if p_ap <= 0.0 {
            // not SPD (or numerical breakdown) — stop with what we have
            break;
        }
        let alpha = (rs_old / p_ap) as f32;
        axpy(alpha, &p, x);
        axpy(-alpha, &ap, &mut r);
        vec_ops += 2;
        let rs_new = dot(&r, &r);
        vec_ops += 1;
        let beta = (rs_new / rs_old) as f32;
        for i in 0..n {
            p[i] = r[i] + beta * p[i];
        }
        vec_ops += 1;
        rs_old = rs_new;
        iters += 1;
    }
    let res = rs_old.sqrt() / b_norm;
    CgResult { iters, residual_norm: res, converged: res <= tol, vec_ops }
}

/// Dense symmetric operator for tests and small problems.
pub struct DenseOp {
    pub a: Vec<f32>, // row-major n x n
    pub n: usize,
}

impl LinearOp for DenseOp {
    fn dim(&self) -> usize {
        self.n
    }

    fn apply(&mut self, v: &[f32], out: &mut [f32]) {
        for i in 0..self.n {
            let row = &self.a[i * self.n..(i + 1) * self.n];
            out[i] = dot(row, v) as f32;
        }
    }
}

/// `v -> (M^T M / rows + gamma I) v` given an explicit matrix — the
/// rust-side reference for the `nm_sq` artifact path (used in tests).
pub struct NormalEqOp {
    pub m: Vec<f32>, // row-major rows x n
    pub rows: usize,
    pub n: usize,
    pub gamma: f32,
}

impl LinearOp for NormalEqOp {
    fn dim(&self) -> usize {
        self.n
    }

    fn apply(&mut self, v: &[f32], out: &mut [f32]) {
        let mut u = vec![0.0f32; self.rows];
        for r in 0..self.rows {
            u[r] = dot(&self.m[r * self.n..(r + 1) * self.n], v) as f32;
        }
        let scale = 1.0 / self.rows as f32;
        for j in 0..self.n {
            let mut s = 0.0f64;
            for r in 0..self.rows {
                s += self.m[r * self.n + j] as f64 * u[r] as f64;
            }
            out[j] = s as f32 * scale + self.gamma * v[j];
        }
    }
}

#[allow(dead_code)]
fn _use_copy(dst: &mut [f32], src: &[f32]) {
    copy(src, dst);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::testkit::{assert_close, forall, normal_vec};

    #[test]
    fn solves_identity() {
        let n = 5;
        let mut a = DenseOp {
            a: (0..n * n).map(|i| if i % (n + 1) == 0 { 1.0 } else { 0.0 }).collect(),
            n,
        };
        let b = vec![1.0, 2.0, 3.0, 4.0, 5.0];
        let mut x = vec![0.0; n];
        let res = solve(&mut a, &b, &mut x, 1e-8, 50);
        assert!(res.converged);
        assert_close(&x, &b, 1e-5, 1e-5);
    }

    #[test]
    fn prop_solves_random_spd_systems() {
        forall(24, |rng| {
            let n = 2 + rng.next_below(12);
            // A = B^T B / n + 0.5 I is SPD
            let rows = n + 4;
            let m = normal_vec(rng, rows * n);
            let mut op = NormalEqOp { m, rows, n, gamma: 0.5 };
            let xstar = normal_vec(rng, n);
            let mut b = vec![0.0f32; n];
            op.apply(&xstar, &mut b);
            let mut x = vec![0.0f32; n];
            let res = solve(&mut op, &b, &mut x, 1e-9, 200);
            assert!(res.converged, "residual {}", res.residual_norm);
            assert_close(&x, &xstar, 1e-2, 1e-3);
        });
    }

    #[test]
    fn prop_monotone_residual_target() {
        forall(12, |rng| {
            let n = 4;
            let rows = 8;
            let m = normal_vec(rng, rows * n);
            let mut op = NormalEqOp { m, rows, n, gamma: 1.0 };
            let b = normal_vec(rng, n);
            let mut x_loose = vec![0.0f32; n];
            let loose = solve(&mut op, &b, &mut x_loose, 1e-2, 100);
            let mut x_tight = vec![0.0f32; n];
            let tight = solve(&mut op, &b, &mut x_tight, 1e-8, 100);
            assert!(tight.iters >= loose.iters);
            assert!(tight.residual_norm <= loose.residual_norm + 1e-12);
        });
    }

    #[test]
    fn counts_vec_ops() {
        let n = 4;
        let mut a = DenseOp {
            a: (0..n * n).map(|i| if i % (n + 1) == 0 { 2.0 } else { 0.0 }).collect(),
            n,
        };
        let b = vec![1.0; n];
        let mut x = vec![0.0; n];
        let res = solve(&mut a, &b, &mut x, 1e-10, 50);
        assert!(res.vec_ops > 0);
    }
}
