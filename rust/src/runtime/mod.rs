//! PJRT runtime: load AOT HLO-text artifacts, compile once, execute on the
//! request path. Pattern follows /opt/xla-example/load_hlo:
//! `PjRtClient::cpu() -> HloModuleProto::from_text_file -> compile ->
//! execute`. Executables are cached per artifact; Python never runs here.
//!
//! # The five-verb contract
//!
//! The runtime's contract is five verbs. Four are *device* verbs a
//! backend must implement — a GPU/TPU port supplies these and inherits
//! every algorithm unchanged:
//!
//! 1. **upload** — move host bytes into a device buffer. Block operands
//!    (`X`, `y`, `mask`) are uploaded once at pack time
//!    ([`exec::BlockLits`], optionally K stacked blocks per fused group);
//!    small per-call vectors ride the [`ExecSession`] pool, which
//!    re-uploads a named slot only when its bits changed and can *alias*
//!    an existing device handle outright (zero traffic).
//! 2. **dispatch** — execute a tupled artifact against device buffers and
//!    download its one output tuple ([`Engine::execute_pooled`]). The
//!    fused `gradm{K}`/`nmm{K}` artifacts reduce across K stacked blocks
//!    on device, so a machine-round costs one download per *group*.
//! 3. **chain** — execute a single-output artifact and keep the result on
//!    device ([`Engine::execute_chained`] -> [`chain::DeviceVec`]). The
//!    output handle feeds the next dispatch's input directly; host bytes
//!    move only at explicit [`Engine::materialize`] points (evaluation
//!    checkpoints, round boundaries). This is what drops the steady-state
//!    downlink of an inner iteration from O(#blocks * d) to zero.
//! 4. **reduce** — average per-machine device handles across the cluster
//!    (the `redm{M}` artifacts, driven by `comm::Network`'s
//!    DeviceCollective path). The kernel accumulates in f64 in host
//!    collective order, so its downloaded result is bit-identical to the
//!    host `all_reduce_*` on the same inputs — the paper-units
//!    round/vector accounting stays authoritative either way.
//!
//! The fifth is the *data-plane* verb, owned by the execution plane
//! rather than the backend:
//!
//! 5. **draw** — generate a fresh per-machine minibatch from the
//!    machine's sample stream and pack it through verbs 1–2, on the
//!    engine that owns the machine ([`plane::ExecPlane::draw_batches`]).
//!    Streams are `Send`, shard-resident objects — on the sharded plane
//!    each stream lives on its shard's *prefetch lane* thread
//!    (`runtime::shard`'s lane; see below), so samples are generated AND
//!    packed shard-side, optionally one round ahead of the engine — the
//!    coordinator sees only metadata stubs, and the serial coordinator
//!    draw bottleneck is gone. Per-machine streams are independent forks,
//!    which makes the draw site irrelevant to the bits: every plane draws
//!    the identical sample sequence (pinned by
//!    `rust/tests/draw_parity.rs` and `rust/tests/prefetch_parity.rs`).
//!    Sample and memory meters charge what was actually drawn — finite
//!    streams (`data::scenario`'s finite-ERM families) may return short
//!    final batches at epoch boundaries.
//!
//! # The execution plane
//!
//! Algorithms never touch the verbs directly: they program against
//! [`plane::ExecPlane`], the ONE execution-plane API that owns engine
//! access, the per-machine fan/join, the collectives, the VR sweeps and
//! the materialization points. It has three implementations — `Host`
//! (legacy per-block dispatches), `Chained` (the DeviceVec pipeline) and
//! `Sharded` (the engine-per-worker [`shard::ShardPool`]) — selected by
//! runtime policy ([`plane::PlanePolicy`]: the `plane=` config key /
//! `PLANE` env, resolved once in the coordinator; `auto` = sharded when a
//! pool is attached, chained otherwise). Every solver has exactly one
//! body; a GPU/TPU backend that implements the four device verbs below
//! plugs in underneath the plane and inherits every algorithm. See
//! `rust/tests/plane_matrix.rs` for the cross-plane contract (chained and
//! sharded bit-identical; host numerically equivalent with identical
//! paper-units accounting).
//!
//! # The shard plane
//!
//! The device verbs describe ONE engine. The [`shard::ShardPool`] scales
//! them across host cores without changing them: a fixed pool of worker
//! threads, each owning its *own* engine (PJRT handles are not `Send`, so
//! engines never cross threads), with machines partitioned machine->shard
//! at cluster construction. The **engine affinity rule**: all of a
//! machine's state — its sample stream, packed blocks, session slots,
//! chained intermediates — lives on its shard, and work for that machine
//! only ever runs there. Fan-outs **join only at collectives**: each
//! machine's partial is materialized on its shard, and the coordinator
//! reduces the host partials *in fixed machine order in f64* (the same
//! IEEE operation sequence as `Network::all_reduce_*` and the `redm{M}`
//! kernel), so every shard count — including the shard-free sequential
//! path — produces bit-identical iterates and identical paper-units
//! accounting. What the plane buys is wall-clock: the per-machine compute
//! between collectives is embarrassingly parallel, and with the chained
//! pipeline that compute is the hot path. The cost is honest extra
//! device<->host traffic at the join points (a per-machine partial must
//! materialize where the single-engine chained path could keep it
//! resident), all metered through each shard's [`EngineStats`] and
//! aggregated via [`shard::ShardPool::gathered_stats`].
//!
//! Fan-outs submit **one batched job per shard** ([`shard::FanBatch`]
//! via [`shard::ShardPool::fan_batches`]), not one job per machine: the
//! job runs the shard's machines in ascending machine order — the exact
//! order the old per-machine submissions executed in, so batching is
//! bit-invisible — and the coordinator reassembles results into machine
//! order before any merge. Inside the draw fan the worker additionally
//! software-pipelines against its prefetch lane under
//! [`plane::PipelinePolicy`] (the `pipeline=` config key / `PIPELINE`
//! env): machine k+1's lane draw is requested while machine k's pack
//! runs ([`shard::LaneClient::request`] / [`shard::LaneTicket`]), with
//! the overlapped pack time metered by
//! [`accounting::OverlapMeter`](crate::accounting::OverlapMeter) —
//! wall-clock only, like the stall meter: it never measures (or
//! perturbs) the simulated paper-units cost model. Ordering and parity
//! details are in the `shard` module docs; diagnostics gather in one
//! round trip per shard via [`shard::ShardPool::per_shard_metrics`].
//!
//! # The prefetch lane
//!
//! Each shard worker has a companion host-only **prefetch lane** thread
//! that owns the shard's sample streams and runs round t+1's draw+pack
//! into staged host-side block packs while the engine thread dispatches
//! round t (double buffering: one stage per machine, refilled right after
//! it is consumed). The worker's draw job collects the staged pack over a
//! handoff channel ([`shard::LaneClient::take`]) and performs only the
//! engine-affine fuse+upload itself; the wait inside `take` is the
//! **dispatch stall** the lane hides, metered per shard
//! ([`accounting::StallMeter`](crate::accounting::StallMeter), gathered
//! by [`shard::ShardPool::gathered_stalls`] into each run's report).
//! Bit-parity is unconditional — a cold stage (and `prefetch=off`
//! entirely) falls back to the identical synchronous draw, and a warm
//! stage holds exactly the `draw_many` result the request would have
//! produced — so the `prefetch=` policy ([`plane::PrefetchPolicy`]: the
//! `prefetch=` config key / `PREFETCH` env, default auto = on) trades
//! stall time only, never bytes. The full staging contract (stream
//! ownership, mismatched-size re-splits, epoch-boundary refusal) is in
//! the `shard` module docs. When the fan pipeline is on, the worker
//! overlaps the other direction too — it packs machine k while the lane
//! already draws machine k+1.
//!
//! # The upload lane
//!
//! Every engine — the coordinator's and each shard worker's — carries an
//! **upload lane** ([`Engine::set_upload_lane`], resolved by the
//! coordinator from the `upload=` config key / `UPLOAD` env,
//! [`plane::UploadPolicy`]): with the lane on, the pooled small operands
//! of [`Engine::execute_pooled`] route through [`ExecSession`]'s
//! two-slot **staging rings** (`ring_stage`/`swap`/`ring_get`) instead of
//! the single-slot pool. A changed operand is staged into the *back*
//! ring half — the half an in-flight dispatch is NOT reading — and
//! swapped in at the dispatch boundary, so a backend with asynchronous
//! transfers can run round t+1's upload while round t's fused dispatch
//! is still executing; the generation-tagged ring meta guarantees a
//! stale buffer is never dispatched (see the `session` module docs for
//! the slot-swap generation rule). Bit-parity is unconditional: the
//! stage decision compares against the payload last dispatched (never
//! the back half's stale bytes), so the lane performs the exact transfer
//! sequence — same uploads, same bytes, same cache hits — as the slot
//! path, and the steady-state constant operand (the pooled iterate
//! between evaluations) still costs zero traffic. What changes is only
//! the staging structure, metered per engine by
//! [`accounting::UploadMeter`](crate::accounting::UploadMeter):
//! `staged`/`overlap_ns` record transfers that ran into the back half
//! (the overlappable work), `wait_ns` the time the dispatch boundary
//! actually blocked on a stage — ALL of it on today's synchronous CPU
//! PJRT, shrinking toward zero on an async backend. Like the stall and
//! overlap meters this is wall-clock-only diagnostics: it never measures
//! (or perturbs) the simulated paper-units cost model, which charges
//! identical units with the lane on or off. The lane also seeds the
//! MultiDev plane: each engine pins its uploads to one PJRT device
//! ordinal ([`Engine::new_on_device`] — shard s uses device s where the
//! platform exposes several, degrading to device 0), so the same ring
//! machinery becomes the per-device data plane.
//!
//! # Faults and elasticity
//!
//! The shard plane is supervised: a worker thread that dies mid-run
//! (fault injection via [`shard::ShardPool::kill_worker`], or a genuine
//! crash) is healed at the next collective boundary by
//! [`shard::ShardPool::wait_elastic`] — supervised restart from the
//! retained artifacts dir plus a bit-exact replay of the interrupted fan
//! batch, falling back to **elastic reassignment** of the dead shard's
//! machines onto survivors ([`shard::ShardPool::reassign_machine`],
//! stream and read-ahead migrating lane-to-lane) when the restart
//! fails. Neither path moves a single bit of the iterates: partials are
//! engine-independent and collectives join in fixed machine order, so
//! only wall-clock and the recovery tally
//! ([`shard::ShardPool::recovery_counts`]) change — the same honesty
//! rule as the stall and overlap meters. Simulated fault *schedules*
//! (stragglers/dropouts under `faults=on`) never touch this plane at
//! all: they scale the simulated network clock in `comm::faults`, and
//! `rust/tests/fault_parity.rs` pins both surfaces.
//!
//! # The executable cache
//!
//! Compiled executables live in a **content-addressed** cache
//! ([`cache::ExecCache`]): the key is [`cache::artifact_key`] — a stable
//! FNV-1a hash of the lowered HLO-text bytes plus the canonical manifest
//! entry, deliberately excluding the artifact's name and path. Two
//! manifest entries with identical content share one compiled
//! executable; re-lowering to byte-identical HLO keeps the entry valid.
//! Name→key resolution is memoized per engine, so the steady-state
//! dispatch path costs one `HashMap` probe exactly as before. The cache
//! is unbounded by default (every prior behavior preserved);
//! [`Engine::set_exec_cache_capacity`] (the `serve.cache_capacity` key)
//! caps residency with insertion-order eviction — an evicted executable
//! recompiles on next use, correct but cold. The attached
//! [`accounting::CacheMeter`](crate::accounting::CacheMeter) records one
//! hit or miss per *distinct artifact per session epoch*
//! ([`Engine::reset_session`] starts a new epoch — the serve layer's
//! per-job boundary), plus compile wall-clock and evictions; like the
//! stall/overlap meters it is wall-clock-only and never touches the
//! simulated paper-units cost model. Warm-vs-cold bit-parity is pinned
//! by `rust/tests/serve_parity.rs`.
//!
//! # Traffic counters
//!
//! [`EngineStats`] meters the contract: `uploads`/`upload_bytes` count
//! every `buffer_from_host_buffer` call, `downloads`/`download_bytes`
//! every device->host fetch (tupled outputs and materializations alike),
//! `chained_dispatches` the executions that downloaded nothing,
//! `alias_installs` the zero-copy slot installs,
//! `upload_cache_hits`/`_misses` the session pool's behavior, and
//! `literal_conversions` (the legacy §Perf counter) the per-dispatch
//! output conversions. `accounting::DeviceTraffic` renders them;
//! `bench_runtime` writes them (including downlink bytes per round) to
//! `BENCH_runtime.json` so the perf trajectory is trackable across PRs.

pub mod artifact;
pub mod cache;
pub mod chain;
pub mod exec;
pub mod plane;
pub mod session;
pub mod shard;

use crate::accounting::{CacheMeter, UploadMeter};
use anyhow::{anyhow, Context, Result};
use std::collections::{HashMap, HashSet};
use std::path::Path;
use std::time::Instant;

pub use artifact::{default_artifacts_dir, ArtifactKind, ArtifactMeta, Manifest};
pub use cache::{artifact_key, manifest_hash, pool_key, ExecCache, KeyedCache};
pub use chain::DeviceVec;
pub use plane::{
    ExecPlane, Lane, LocalSolver, PipelinePolicy, PlaneKind, PlaneLocals, PlanePolicy, PlaneVec,
    PrefetchPolicy, UploadPolicy,
};
pub use session::ExecSession;
pub use shard::{
    FanBatch, LaneClient, LaneTicket, Pending, ShardMetrics, ShardPool, ShardState, TakeReply,
};

#[derive(Clone, Debug, Default)]
pub struct EngineStats {
    pub compiles: u64,
    pub compile_ns: u128,
    pub executions: u64,
    pub execute_ns: u128,
    /// host<->device literal conversions (perf counter for §Perf)
    pub literal_conversions: u64,
    /// host->device buffer creations (blocks + session misses)
    pub uploads: u64,
    /// bytes moved host->device
    pub upload_bytes: u64,
    /// device->host output fetches, metered by the typed wrappers
    /// (grad/vr/nm) alongside `download_bytes`, so count and bytes always
    /// agree; the raw `Engine::execute` path counts only
    /// `literal_conversions`
    pub downloads: u64,
    /// bytes moved device->host (typed-wrapper outputs + materializations)
    pub download_bytes: u64,
    /// session-slot reuses: an upload that was skipped entirely
    pub upload_cache_hits: u64,
    /// session-slot refreshes: contents changed, re-uploaded
    pub upload_cache_misses: u64,
    /// chained executions: dispatches whose output stayed on device
    /// (no literal fetch, no download — see `Engine::execute_chained`)
    pub chained_dispatches: u64,
    /// zero-copy session-slot installs of device handles
    pub alias_installs: u64,
}

impl EngineStats {
    /// Total bytes moved across the host<->device boundary.
    pub fn bytes_moved(&self) -> u64 {
        self.upload_bytes + self.download_bytes
    }

    /// Fold another engine's counters into this one (the shard plane's
    /// cross-engine aggregation: every field is a flow, so merge is a
    /// plain sum). Exhaustive destructure — adding a counter without
    /// aggregating it is a compile error, not a silent zero.
    pub fn merge(&mut self, other: &EngineStats) {
        let EngineStats {
            compiles,
            compile_ns,
            executions,
            execute_ns,
            literal_conversions,
            uploads,
            upload_bytes,
            downloads,
            download_bytes,
            upload_cache_hits,
            upload_cache_misses,
            chained_dispatches,
            alias_installs,
        } = other;
        self.compiles += compiles;
        self.compile_ns += compile_ns;
        self.executions += executions;
        self.execute_ns += execute_ns;
        self.literal_conversions += literal_conversions;
        self.uploads += uploads;
        self.upload_bytes += upload_bytes;
        self.downloads += downloads;
        self.download_bytes += download_bytes;
        self.upload_cache_hits += upload_cache_hits;
        self.upload_cache_misses += upload_cache_misses;
        self.chained_dispatches += chained_dispatches;
        self.alias_installs += alias_installs;
    }
}

/// The PJRT engine: one CPU client + a compiled-executable cache + the
/// session buffer pool for small per-call operands.
pub struct Engine {
    client: xla::PjRtClient,
    manifest: Manifest,
    /// content-addressed compiled-executable cache (see the module doc's
    /// "The executable cache" section)
    execs: ExecCache,
    /// memoized artifact-name -> content-key resolution (stable for the
    /// engine's lifetime: the manifest is loaded once)
    name_keys: HashMap<String, u64>,
    /// content keys already metered this session epoch — one hit/miss per
    /// distinct artifact per epoch; cleared by `reset_session`
    touched: HashSet<u64>,
    session: ExecSession,
    /// supported fused-dispatch widths, computed once from the manifest
    fuse_widths: Vec<usize>,
    /// per-dim cached zero vectors: the seeds of the chained accumulators
    /// (uploaded once per length, ever)
    zeros: HashMap<usize, DeviceVec>,
    /// bit-pattern-keyed cache of length-1 scalar operands (gamma/eta,
    /// CG coefficients): recurring constants upload once, ever
    scalars: HashMap<u32, DeviceVec>,
    /// PJRT device ordinal this engine's uploads land on (`None` = the
    /// client default, device 0) — the MultiDev seed: shard s pins
    /// device s where the platform exposes several
    device: Option<usize>,
    /// whether pooled operands route through the staging-ring upload
    /// lane (see the module doc's "The upload lane"); set per run by the
    /// coordinator from the resolved `upload=` policy
    upload_lane: bool,
    /// the upload lane's wall-clock meter (reset per run alongside the
    /// session; outside the simulated cost model like the stall meter)
    uploads: UploadMeter,
    pub stats: EngineStats,
}

impl Engine {
    /// Load the manifest and lazily compile artifacts on first use.
    pub fn new(artifacts_dir: &Path) -> Result<Engine> {
        Engine::new_on_device(artifacts_dir, 0)
    }

    /// [`Engine::new`] pinned to PJRT device ordinal `device_index` — the
    /// MultiDev seed: a shard pool constructs shard s's engine on device
    /// s, so every upload this engine performs lands on its own device
    /// where the platform exposes several. An index past the client's
    /// device count degrades gracefully to the default device 0 (today's
    /// CPU client exposes one), never an error.
    pub fn new_on_device(artifacts_dir: &Path, device_index: usize) -> Result<Engine> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client =
            xla::PjRtClient::cpu().map_err(|e| anyhow!("PjRtClient::cpu failed: {e:?}"))?;
        let device = match client.device_count() {
            n if device_index > 0 && device_index < n => Some(device_index),
            _ => None,
        };
        let fuse_widths = manifest.fuse_widths();
        Ok(Engine {
            client,
            manifest,
            execs: ExecCache::new(),
            name_keys: HashMap::new(),
            touched: HashSet::new(),
            session: ExecSession::new(),
            fuse_widths,
            zeros: HashMap::new(),
            scalars: HashMap::new(),
            device,
            upload_lane: false,
            uploads: UploadMeter::default(),
            stats: EngineStats::default(),
        })
    }

    /// Load from the default artifacts dir ($MBPROX_ARTIFACTS or ./artifacts).
    pub fn from_env() -> Result<Engine> {
        Engine::new(&default_artifacts_dir())
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// The underlying PJRT client (for device-buffer management).
    pub fn client(&self) -> &xla::PjRtClient {
        &self.client
    }

    /// The session upload pool (inspection / invalidation).
    pub fn session(&self) -> &ExecSession {
        &self.session
    }

    /// Drop every pooled small-operand buffer (block uploads are owned by
    /// callers and unaffected) and start a new cache-meter epoch: the
    /// next touch of each artifact records one hit/miss again. Compiled
    /// executables stay resident — that warmth is the point. The upload
    /// meter restarts too (per-run semantics); the lane *policy* flag is
    /// untouched — the coordinator re-resolves it per run.
    pub fn reset_session(&mut self) {
        self.session.clear();
        self.touched.clear();
        self.uploads = UploadMeter::default();
    }

    /// Enable/disable the staging-ring upload lane (see the module doc's
    /// "The upload lane"). Bit-parity is unconditional either way; the
    /// coordinator resolves the `upload=` policy and flips every engine
    /// (its own + each shard's) per run.
    pub fn set_upload_lane(&mut self, on: bool) {
        self.upload_lane = on;
    }

    /// Whether pooled operands currently route through the staging rings.
    pub fn upload_lane(&self) -> bool {
        self.upload_lane
    }

    /// The upload lane's meter for the current run (reset with the
    /// session; gather per shard via `ShardPool::gathered_run_meters`).
    pub fn upload_meter(&self) -> &UploadMeter {
        &self.uploads
    }

    /// The PJRT device ordinal this engine's uploads land on (0 = the
    /// client default — see [`Engine::new_on_device`]).
    pub fn device_index(&self) -> usize {
        self.device.unwrap_or(0)
    }

    /// The executable cache's meter (cumulative for the engine's
    /// lifetime; take [`CacheMeter::since`] snapshots for per-job views).
    pub fn cache_meter(&self) -> &CacheMeter {
        &self.execs.meter
    }

    /// Cap resident compiled executables (insertion-order eviction past
    /// the cap; `serve.cache_capacity`). Default is unbounded.
    pub fn set_exec_cache_capacity(&mut self, cap: usize) {
        self.execs.set_capacity(cap);
    }

    /// Number of compiled executables currently resident.
    pub fn exec_cache_len(&self) -> usize {
        self.execs.len()
    }

    pub fn block_rows(&self) -> usize {
        self.manifest.block
    }

    /// Supported fused-dispatch widths, widest first (empty when the
    /// manifest carries no multi-block artifacts). Computed once at load.
    pub fn fuse_widths(&self) -> &[usize] {
        &self.fuse_widths
    }

    /// Chained-gradient readiness (gacc coverage + vector plane) for a
    /// loss tag at dim `d` — see `Manifest::chain_grad_ready`.
    pub fn chain_grad_ready(&self, loss_tag: &str, d: usize) -> bool {
        self.manifest.chain_grad_ready(loss_tag, d)
    }

    /// Chained VR-sweep readiness for a loss tag at dim `d`.
    pub fn chain_vr_ready(&self, loss_tag: &str, d: usize) -> bool {
        self.manifest.chain_vr_ready(loss_tag, d)
    }

    /// Chained normal-matvec (CG/DiSCO) readiness at dim `d`.
    pub fn chain_nm_ready(&self, d: usize) -> bool {
        self.manifest.chain_nm_ready(d)
    }

    /// Whether the on-device cross-machine reduce serves `m` machines at
    /// dim `d` (m == 1 is an identity, always served).
    pub fn red_ready(&self, m: usize, d: usize) -> bool {
        self.manifest.red_ready(m, d)
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Eagerly compile every artifact (used by the integration tests and
    /// long-running examples to pay compile cost up front).
    pub fn warmup_all(&mut self) -> Result<()> {
        let names: Vec<String> = self.manifest.artifacts.iter().map(|a| a.name.clone()).collect();
        for n in names {
            self.executable(&n)?;
        }
        Ok(())
    }

    /// Resolve an artifact name to its content key (memoized: the file is
    /// hashed once per name per engine lifetime).
    fn exec_key(&mut self, name: &str) -> Result<u64> {
        if let Some(&key) = self.name_keys.get(name) {
            return Ok(key);
        }
        let meta = self
            .manifest
            .find(name)
            .ok_or_else(|| anyhow!("artifact '{name}' not in manifest"))?;
        let key = cache::artifact_key(meta)?;
        self.name_keys.insert(name.to_string(), key);
        Ok(key)
    }

    /// Get (compiling if needed) the executable for `name`, via the
    /// content-addressed cache: identical artifact content under two
    /// names compiles once, and a warm entry is a metered cache hit.
    pub fn executable(&mut self, name: &str) -> Result<&xla::PjRtLoadedExecutable> {
        let key = self.exec_key(name)?;
        if self.execs.contains(key) {
            if self.touched.insert(key) {
                self.execs.meter.record_hit();
            }
        } else {
            let meta = self
                .manifest
                .find(name)
                .ok_or_else(|| anyhow!("artifact '{name}' not in manifest"))?
                .clone();
            let t0 = Instant::now();
            let proto = xla::HloModuleProto::from_text_file(&meta.file)
                .map_err(|e| anyhow!("parsing {}: {e:?}", meta.file.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe =
                self.client.compile(&comp).map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
            let dt = t0.elapsed().as_nanos();
            self.stats.compiles += 1;
            self.stats.compile_ns += dt;
            self.touched.insert(key);
            self.execs.insert(key, exe, dt as u64);
        }
        Ok(self.execs.get(key).unwrap())
    }

    /// Execute artifact `name` with device-buffer inputs; returns the
    /// decomposed output tuple as literals.
    ///
    /// NOTE: always goes through `execute_b` (buffer inputs). The crate's
    /// literal-input `execute` leaks its internal literal->buffer
    /// conversions (~70 KB/call measured — see EXPERIMENTS.md §Perf), so
    /// block operands are uploaded once (`upload`/`upload_mat`) and small
    /// per-call vectors go through the session pool, with rust-side Drop
    /// reclaiming them deterministically.
    pub fn execute(
        &mut self,
        name: &str,
        inputs: &[&xla::PjRtBuffer],
    ) -> Result<Vec<xla::Literal>> {
        self.executable(name)?; // ensure compiled (borrow gymnastics)
        let exe = self.execs.get(self.name_keys[name]).unwrap();
        Self::dispatch(&mut self.stats, exe, name, inputs)
    }

    /// Execute with `block_inputs` (caller-owned device buffers) followed
    /// by `pooled_tail`: (slot, host data) pairs routed through the
    /// session pool — or through the staging-ring upload lane when it is
    /// enabled (same transfers, different staging structure; see the
    /// module doc's "The upload lane") — so unchanged operands are not
    /// re-uploaded. Input order is `block_inputs ++ pooled_tail`,
    /// matching every artifact's (block operands, small vectors)
    /// signature.
    pub fn execute_pooled(
        &mut self,
        name: &str,
        block_inputs: &[&xla::PjRtBuffer],
        pooled_tail: &[(&'static str, &[f32])],
    ) -> Result<Vec<xla::Literal>> {
        self.executable(name)?;
        if self.upload_lane {
            return self.execute_ringed(name, block_inputs, pooled_tail);
        }
        for (key, data) in pooled_tail {
            let (up0, b0) = (self.stats.uploads, self.stats.upload_bytes);
            self.session.ensure(&self.client, self.device, &mut self.stats, key, data)?;
            self.uploads.record(
                false,
                self.stats.uploads - up0,
                self.stats.upload_bytes - b0,
                0,
            );
        }
        let mut inputs: Vec<&xla::PjRtBuffer> =
            Vec::with_capacity(block_inputs.len() + pooled_tail.len());
        inputs.extend_from_slice(block_inputs);
        for (key, _) in pooled_tail {
            inputs.push(self.session.get(key)?);
        }
        let exe = self.execs.get(self.name_keys[name]).unwrap();
        Self::dispatch(&mut self.stats, exe, name, &inputs)
    }

    /// The upload-lane arm of [`Engine::execute_pooled`]: each pooled
    /// operand stages through its double-buffered ring
    /// ([`ExecSession::ring_stage`] — an active-half hit costs nothing,
    /// like the slot path), freshly staged payloads swap in together at
    /// the dispatch boundary, and the dispatch reads the active halves.
    /// On today's synchronous backend the stage completes inline, so its
    /// whole wall-clock is charged as boundary wait alongside the
    /// overlappable `staged` time; an async backend's upload verb would
    /// pay only the residue that did not finish under the previous
    /// dispatch.
    fn execute_ringed(
        &mut self,
        name: &str,
        block_inputs: &[&xla::PjRtBuffer],
        pooled_tail: &[(&'static str, &[f32])],
    ) -> Result<Vec<xla::Literal>> {
        let mut pending: Vec<&'static str> = Vec::with_capacity(pooled_tail.len());
        for (key, data) in pooled_tail {
            let (up0, b0) = (self.stats.uploads, self.stats.upload_bytes);
            let t0 = Instant::now();
            let staged =
                self.session.ring_stage(&self.client, self.device, &mut self.stats, key, data)?;
            let dt = t0.elapsed().as_nanos() as u64;
            self.uploads.record(
                staged,
                self.stats.uploads - up0,
                self.stats.upload_bytes - b0,
                dt,
            );
            if staged {
                self.uploads.add_wait(dt);
                pending.push(key);
            }
        }
        // the dispatch boundary: expose every freshly staged payload
        for key in &pending {
            self.session.swap(key)?;
        }
        let mut inputs: Vec<&xla::PjRtBuffer> =
            Vec::with_capacity(block_inputs.len() + pooled_tail.len());
        inputs.extend_from_slice(block_inputs);
        for (key, _) in pooled_tail {
            inputs.push(self.session.ring_get(key)?);
        }
        let exe = self.execs.get(self.name_keys[name]).unwrap();
        Self::dispatch(&mut self.stats, exe, name, &inputs)
    }

    /// Like [`Engine::execute_pooled`] with already-resident session slots
    /// in the tail: the caller has `ensure`d or [`Engine::alias_slot`]ed
    /// every key beforehand (the aliasing path is how a device-resident
    /// [`DeviceVec`] flows into a tupled artifact without a download).
    pub fn execute_slots(
        &mut self,
        name: &str,
        block_inputs: &[&xla::PjRtBuffer],
        slot_keys: &[&'static str],
    ) -> Result<Vec<xla::Literal>> {
        self.executable(name)?;
        let mut inputs: Vec<&xla::PjRtBuffer> =
            Vec::with_capacity(block_inputs.len() + slot_keys.len());
        inputs.extend_from_slice(block_inputs);
        for key in slot_keys {
            inputs.push(self.session.get(key)?);
        }
        let exe = self.execs.get(self.name_keys[name]).unwrap();
        Self::dispatch(&mut self.stats, exe, name, &inputs)
    }

    /// Install a device handle into a session slot without any upload.
    pub fn alias_slot(&mut self, key: &'static str, v: &DeviceVec) {
        self.session.alias(&mut self.stats, key, v.shared());
    }

    fn dispatch(
        stats: &mut EngineStats,
        exe: &xla::PjRtLoadedExecutable,
        name: &str,
        inputs: &[&xla::PjRtBuffer],
    ) -> Result<Vec<xla::Literal>> {
        let t0 = Instant::now();
        let out = exe
            .execute_b::<&xla::PjRtBuffer>(inputs)
            .map_err(|e| anyhow!("executing {name}: {e:?}"))?;
        let mut lit = out[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching output of {name}: {e:?}"))?;
        stats.executions += 1;
        stats.execute_ns += t0.elapsed().as_nanos();
        stats.literal_conversions += 1;
        // lowered with return_tuple=True: output is always a tuple
        let parts = lit.decompose_tuple().map_err(|e| anyhow!("untupling {name}: {e:?}"))?;
        Ok(parts)
    }

    /// Execute a *chained* artifact (single array output, lowered with
    /// return_tuple=False) and keep the result on device: no literal
    /// fetch, no download — the returned [`DeviceVec`] feeds the next
    /// dispatch directly. `out_dims` is the artifact's output shape
    /// (checked against the manifest by the typed wrappers in `chain`).
    pub fn execute_chained(
        &mut self,
        name: &str,
        inputs: &[&xla::PjRtBuffer],
        out_dims: Vec<usize>,
    ) -> Result<DeviceVec> {
        self.executable(name)?;
        let exe = self.execs.get(self.name_keys[name]).unwrap();
        let t0 = Instant::now();
        let mut out = exe
            .execute_b::<&xla::PjRtBuffer>(inputs)
            .map_err(|e| anyhow!("executing {name} (chained): {e:?}"))?;
        self.stats.executions += 1;
        self.stats.execute_ns += t0.elapsed().as_nanos();
        self.stats.chained_dispatches += 1;
        anyhow::ensure!(
            !out.is_empty() && !out[0].is_empty(),
            "{name}: chained execution returned no output buffer"
        );
        let buf = out.swap_remove(0).swap_remove(0);
        Ok(DeviceVec::from_buffer(buf, out_dims))
    }

    /// Download a device vector to the host — the ONLY way bytes leave
    /// the device on the chained path, charged like every other download.
    /// Call sites are evaluation checkpoints and round boundaries.
    pub fn materialize(&mut self, v: &DeviceVec) -> Result<Vec<f32>> {
        let lit = v
            .buffer()
            .to_literal_sync()
            .map_err(|e| anyhow!("materializing DeviceVec{:?}: {e:?}", v.dims()))?;
        self.stats.downloads += 1;
        self.stats.download_bytes += (v.len() * std::mem::size_of::<f32>()) as u64;
        self.stats.literal_conversions += 1;
        let host = lit_to_vec(&lit)?;
        anyhow::ensure!(
            host.len() == v.len(),
            "materialized {} elements for DeviceVec{:?}",
            host.len(),
            v.dims()
        );
        Ok(host)
    }

    /// Download a length-1 device vector as a scalar (the CG loop's O(1)
    /// steady-state downlink).
    pub fn materialize_scalar(&mut self, v: &DeviceVec) -> Result<f32> {
        anyhow::ensure!(v.len() == 1, "materialize_scalar on DeviceVec{:?}", v.dims());
        let host = self.materialize(v)?;
        Ok(host[0])
    }

    /// The cached device zero vector of length `n` — the seed of every
    /// chained accumulator. Uploaded once per length, ever.
    pub fn zeros_dev(&mut self, n: usize) -> Result<DeviceVec> {
        if let Some(z) = self.zeros.get(&n) {
            return Ok(z.clone());
        }
        let z = self.upload_dev(&vec![0.0f32; n], &[n])?;
        self.zeros.insert(n, z.clone());
        Ok(z)
    }

    /// A length-1 device handle for a scalar operand, cached by exact bit
    /// pattern: recurring constants (gamma/eta, the CG recurrence's
    /// 1.0/-1.0, per-batch 1/cnt factors) upload once, ever. The cache is
    /// capped so a long run with ever-fresh coefficients cannot grow it
    /// unboundedly — past the cap, scalars upload fresh (correct, just
    /// uncached).
    pub fn scalar_dev(&mut self, x: f32) -> Result<DeviceVec> {
        const SCALAR_CACHE_CAP: usize = 4096;
        let key = x.to_bits();
        if let Some(s) = self.scalars.get(&key) {
            return Ok(s.clone());
        }
        let s = self.upload_dev(&[x], &[1])?;
        if self.scalars.len() < SCALAR_CACHE_CAP {
            self.scalars.insert(key, s.clone());
        }
        Ok(s)
    }

    /// Upload a host vector/matrix as a device handle (row-major; charged
    /// like every upload).
    pub fn upload_dev(&mut self, data: &[f32], dims: &[usize]) -> Result<DeviceVec> {
        anyhow::ensure!(
            data.len() == dims.iter().product::<usize>(),
            "upload_dev: {} elements for dims {dims:?}",
            data.len()
        );
        self.stats.uploads += 1;
        self.stats.upload_bytes += (data.len() * std::mem::size_of::<f32>()) as u64;
        let buf = self
            .client
            .buffer_from_host_buffer(data, dims, self.device)
            .map_err(|e| anyhow!("uploading DeviceVec{dims:?}: {e:?}"))?;
        Ok(DeviceVec::from_buffer(buf, dims.to_vec()))
    }

    /// Upload a 1-D f32 vector to the device (uncached; see
    /// [`Engine::execute_pooled`] for the cached path).
    pub fn upload(&mut self, data: &[f32]) -> Result<xla::PjRtBuffer> {
        self.stats.uploads += 1;
        self.stats.upload_bytes += (data.len() * std::mem::size_of::<f32>()) as u64;
        self.client
            .buffer_from_host_buffer(data, &[data.len()], self.device)
            .map_err(|e| anyhow!("uploading vec[{}]: {e:?}", data.len()))
    }

    /// Upload a row-major matrix to the device.
    pub fn upload_mat(&mut self, data: &[f32], rows: usize, cols: usize) -> Result<xla::PjRtBuffer> {
        anyhow::ensure!(data.len() == rows * cols, "matrix upload size mismatch");
        self.stats.uploads += 1;
        self.stats.upload_bytes += (data.len() * std::mem::size_of::<f32>()) as u64;
        self.client
            .buffer_from_host_buffer(data, &[rows, cols], self.device)
            .map_err(|e| anyhow!("uploading mat[{rows}x{cols}]: {e:?}"))
    }

    /// Mean execute latency in nanoseconds (for perf reports).
    pub fn mean_execute_ns(&self) -> f64 {
        if self.stats.executions == 0 {
            0.0
        } else {
            self.stats.execute_ns as f64 / self.stats.executions as f64
        }
    }
}

/// Literal construction helpers.
pub fn lit_vec(data: &[f32]) -> xla::Literal {
    xla::Literal::vec1(data)
}

pub fn lit_mat(data: &[f32], rows: usize, cols: usize) -> Result<xla::Literal> {
    anyhow::ensure!(data.len() == rows * cols, "matrix literal size mismatch");
    xla::Literal::vec1(data)
        .reshape(&[rows as i64, cols as i64])
        .map_err(|e| anyhow!("reshape: {e:?}"))
}

pub fn lit_to_vec(l: &xla::Literal) -> Result<Vec<f32>> {
    l.to_vec::<f32>().map_err(|e| anyhow!("literal to_vec: {e:?}"))
}

pub fn lit_scalar1(x: f32) -> xla::Literal {
    xla::Literal::vec1(&[x])
}

/// Read a single f32 from a length-1 literal.
pub fn lit_first(l: &xla::Literal) -> Result<f32> {
    let v = lit_to_vec(l)?;
    v.first().copied().context("empty literal")
}
