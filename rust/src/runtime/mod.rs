//! PJRT runtime: load AOT HLO-text artifacts, compile once, execute on the
//! request path. Pattern follows /opt/xla-example/load_hlo:
//! `PjRtClient::cpu() -> HloModuleProto::from_text_file -> compile ->
//! execute`. Executables are cached per artifact; Python never runs here.
//!
//! # Device-residency contract
//!
//! The engine is built so that steady-state dispatch moves O(1) small
//! vectors per *round*, not per block:
//!
//! - **Block operands** (`X`, `y`, `mask`) are uploaded once when a batch
//!   is packed ([`exec::BlockLits`]) and reused by every artifact call.
//!   The hot grad/normal-matvec paths consume *fused multi-block* uploads
//!   (`gradm{K}`/`nmm{K}` artifacts, K stacked 256-row blocks per
//!   dispatch) whose cross-block reduction happens on device, so one call
//!   downloads one `(grad_sum, loss_sum, count)` tuple per group.
//! - **Small per-call vectors** (the iterate `w`, the six VR-sweep
//!   vectors, CG directions, scalars) go through the [`ExecSession`]
//!   buffer pool: a named slot re-uploads only when its contents changed,
//!   so an unchanged iterate costs zero host->device traffic no matter how
//!   many blocks it is dispatched against.
//! - **Downloads** happen only at artifact outputs; every typed wrapper
//!   fetches exactly one (tupled) result per dispatch.
//!
//! # Traffic counters
//!
//! [`EngineStats`] meters the contract: `uploads`/`upload_bytes` count
//! every `buffer_from_host_buffer` call, `downloads`/`download_bytes`
//! every device->host literal fetch, `upload_cache_hits`/`_misses` the
//! session pool's behavior, and `literal_conversions` (the legacy §Perf
//! counter) the per-dispatch output conversions. `accounting::
//! DeviceTraffic` renders them; `bench_runtime` writes them to
//! `BENCH_runtime.json` so the perf trajectory is trackable across PRs.

pub mod artifact;
pub mod exec;
pub mod session;

use anyhow::{anyhow, Context, Result};
use std::collections::HashMap;
use std::path::Path;
use std::time::Instant;

pub use artifact::{default_artifacts_dir, ArtifactKind, ArtifactMeta, Manifest};
pub use session::ExecSession;

#[derive(Clone, Debug, Default)]
pub struct EngineStats {
    pub compiles: u64,
    pub compile_ns: u128,
    pub executions: u64,
    pub execute_ns: u128,
    /// host<->device literal conversions (perf counter for §Perf)
    pub literal_conversions: u64,
    /// host->device buffer creations (blocks + session misses)
    pub uploads: u64,
    /// bytes moved host->device
    pub upload_bytes: u64,
    /// device->host output fetches, metered by the typed wrappers
    /// (grad/vr/nm) alongside `download_bytes`, so count and bytes always
    /// agree; the raw `Engine::execute` path counts only
    /// `literal_conversions`
    pub downloads: u64,
    /// bytes moved device->host (typed-wrapper outputs)
    pub download_bytes: u64,
    /// session-slot reuses: an upload that was skipped entirely
    pub upload_cache_hits: u64,
    /// session-slot refreshes: contents changed, re-uploaded
    pub upload_cache_misses: u64,
}

impl EngineStats {
    /// Total bytes moved across the host<->device boundary.
    pub fn bytes_moved(&self) -> u64 {
        self.upload_bytes + self.download_bytes
    }
}

/// The PJRT engine: one CPU client + a compiled-executable cache + the
/// session buffer pool for small per-call operands.
pub struct Engine {
    client: xla::PjRtClient,
    manifest: Manifest,
    execs: HashMap<String, xla::PjRtLoadedExecutable>,
    session: ExecSession,
    /// supported fused-dispatch widths, computed once from the manifest
    fuse_widths: Vec<usize>,
    pub stats: EngineStats,
}

impl Engine {
    /// Load the manifest and lazily compile artifacts on first use.
    pub fn new(artifacts_dir: &Path) -> Result<Engine> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client =
            xla::PjRtClient::cpu().map_err(|e| anyhow!("PjRtClient::cpu failed: {e:?}"))?;
        let fuse_widths = manifest.fuse_widths();
        Ok(Engine {
            client,
            manifest,
            execs: HashMap::new(),
            session: ExecSession::new(),
            fuse_widths,
            stats: EngineStats::default(),
        })
    }

    /// Load from the default artifacts dir ($MBPROX_ARTIFACTS or ./artifacts).
    pub fn from_env() -> Result<Engine> {
        Engine::new(&default_artifacts_dir())
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// The underlying PJRT client (for device-buffer management).
    pub fn client(&self) -> &xla::PjRtClient {
        &self.client
    }

    /// The session upload pool (inspection / invalidation).
    pub fn session(&self) -> &ExecSession {
        &self.session
    }

    /// Drop every pooled small-operand buffer (block uploads are owned by
    /// callers and unaffected).
    pub fn reset_session(&mut self) {
        self.session.clear();
    }

    pub fn block_rows(&self) -> usize {
        self.manifest.block
    }

    /// Supported fused-dispatch widths, widest first (empty when the
    /// manifest carries no multi-block artifacts). Computed once at load.
    pub fn fuse_widths(&self) -> &[usize] {
        &self.fuse_widths
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Eagerly compile every artifact (used by the integration tests and
    /// long-running examples to pay compile cost up front).
    pub fn warmup_all(&mut self) -> Result<()> {
        let names: Vec<String> = self.manifest.artifacts.iter().map(|a| a.name.clone()).collect();
        for n in names {
            self.executable(&n)?;
        }
        Ok(())
    }

    /// Get (compiling if needed) the executable for `name`.
    pub fn executable(&mut self, name: &str) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.execs.contains_key(name) {
            let meta = self
                .manifest
                .find(name)
                .ok_or_else(|| anyhow!("artifact '{name}' not in manifest"))?
                .clone();
            let t0 = Instant::now();
            let proto = xla::HloModuleProto::from_text_file(&meta.file)
                .map_err(|e| anyhow!("parsing {}: {e:?}", meta.file.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe =
                self.client.compile(&comp).map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
            self.stats.compiles += 1;
            self.stats.compile_ns += t0.elapsed().as_nanos();
            self.execs.insert(name.to_string(), exe);
        }
        Ok(self.execs.get(name).unwrap())
    }

    /// Execute artifact `name` with device-buffer inputs; returns the
    /// decomposed output tuple as literals.
    ///
    /// NOTE: always goes through `execute_b` (buffer inputs). The crate's
    /// literal-input `execute` leaks its internal literal->buffer
    /// conversions (~70 KB/call measured — see EXPERIMENTS.md §Perf), so
    /// block operands are uploaded once (`upload`/`upload_mat`) and small
    /// per-call vectors go through the session pool, with rust-side Drop
    /// reclaiming them deterministically.
    pub fn execute(
        &mut self,
        name: &str,
        inputs: &[&xla::PjRtBuffer],
    ) -> Result<Vec<xla::Literal>> {
        self.executable(name)?; // ensure compiled (borrow gymnastics)
        let exe = self.execs.get(name).unwrap();
        Self::dispatch(&mut self.stats, exe, name, inputs)
    }

    /// Execute with `block_inputs` (caller-owned device buffers) followed
    /// by `pooled_tail`: (slot, host data) pairs routed through the
    /// session pool, so unchanged operands are not re-uploaded. Input
    /// order is `block_inputs ++ pooled_tail`, matching every artifact's
    /// (block operands, small vectors) signature.
    pub fn execute_pooled(
        &mut self,
        name: &str,
        block_inputs: &[&xla::PjRtBuffer],
        pooled_tail: &[(&'static str, &[f32])],
    ) -> Result<Vec<xla::Literal>> {
        self.executable(name)?;
        for (key, data) in pooled_tail {
            self.session.ensure(&self.client, &mut self.stats, key, data)?;
        }
        let mut inputs: Vec<&xla::PjRtBuffer> =
            Vec::with_capacity(block_inputs.len() + pooled_tail.len());
        inputs.extend_from_slice(block_inputs);
        for (key, _) in pooled_tail {
            inputs.push(self.session.get(key)?);
        }
        let exe = self.execs.get(name).unwrap();
        Self::dispatch(&mut self.stats, exe, name, &inputs)
    }

    fn dispatch(
        stats: &mut EngineStats,
        exe: &xla::PjRtLoadedExecutable,
        name: &str,
        inputs: &[&xla::PjRtBuffer],
    ) -> Result<Vec<xla::Literal>> {
        let t0 = Instant::now();
        let out = exe
            .execute_b::<&xla::PjRtBuffer>(inputs)
            .map_err(|e| anyhow!("executing {name}: {e:?}"))?;
        let mut lit = out[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching output of {name}: {e:?}"))?;
        stats.executions += 1;
        stats.execute_ns += t0.elapsed().as_nanos();
        stats.literal_conversions += 1;
        // lowered with return_tuple=True: output is always a tuple
        let parts = lit.decompose_tuple().map_err(|e| anyhow!("untupling {name}: {e:?}"))?;
        Ok(parts)
    }

    /// Upload a 1-D f32 vector to the device (uncached; see
    /// [`Engine::execute_pooled`] for the cached path).
    pub fn upload(&mut self, data: &[f32]) -> Result<xla::PjRtBuffer> {
        self.stats.uploads += 1;
        self.stats.upload_bytes += (data.len() * std::mem::size_of::<f32>()) as u64;
        self.client
            .buffer_from_host_buffer(data, &[data.len()], None)
            .map_err(|e| anyhow!("uploading vec[{}]: {e:?}", data.len()))
    }

    /// Upload a row-major matrix to the device.
    pub fn upload_mat(&mut self, data: &[f32], rows: usize, cols: usize) -> Result<xla::PjRtBuffer> {
        anyhow::ensure!(data.len() == rows * cols, "matrix upload size mismatch");
        self.stats.uploads += 1;
        self.stats.upload_bytes += (data.len() * std::mem::size_of::<f32>()) as u64;
        self.client
            .buffer_from_host_buffer(data, &[rows, cols], None)
            .map_err(|e| anyhow!("uploading mat[{rows}x{cols}]: {e:?}"))
    }

    /// Mean execute latency in nanoseconds (for perf reports).
    pub fn mean_execute_ns(&self) -> f64 {
        if self.stats.executions == 0 {
            0.0
        } else {
            self.stats.execute_ns as f64 / self.stats.executions as f64
        }
    }
}

/// Literal construction helpers.
pub fn lit_vec(data: &[f32]) -> xla::Literal {
    xla::Literal::vec1(data)
}

pub fn lit_mat(data: &[f32], rows: usize, cols: usize) -> Result<xla::Literal> {
    anyhow::ensure!(data.len() == rows * cols, "matrix literal size mismatch");
    xla::Literal::vec1(data)
        .reshape(&[rows as i64, cols as i64])
        .map_err(|e| anyhow!("reshape: {e:?}"))
}

pub fn lit_to_vec(l: &xla::Literal) -> Result<Vec<f32>> {
    l.to_vec::<f32>().map_err(|e| anyhow!("literal to_vec: {e:?}"))
}

pub fn lit_scalar1(x: f32) -> xla::Literal {
    xla::Literal::vec1(&[x])
}

/// Read a single f32 from a length-1 literal.
pub fn lit_first(l: &xla::Literal) -> Result<f32> {
    let v = lit_to_vec(l)?;
    v.first().copied().context("empty literal")
}
