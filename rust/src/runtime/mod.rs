//! PJRT runtime: load AOT HLO-text artifacts, compile once, execute on the
//! request path. Pattern follows /opt/xla-example/load_hlo:
//! `PjRtClient::cpu() -> HloModuleProto::from_text_file -> compile ->
//! execute`. Executables are cached per artifact; Python never runs here.

pub mod artifact;
pub mod exec;

use anyhow::{anyhow, Context, Result};
use std::collections::HashMap;
use std::path::Path;
use std::time::Instant;

pub use artifact::{default_artifacts_dir, ArtifactKind, ArtifactMeta, Manifest};

#[derive(Clone, Debug, Default)]
pub struct EngineStats {
    pub compiles: u64,
    pub compile_ns: u128,
    pub executions: u64,
    pub execute_ns: u128,
    /// host<->device literal conversions (perf counter for §Perf)
    pub literal_conversions: u64,
}

/// The PJRT engine: one CPU client + a compiled-executable cache.
pub struct Engine {
    client: xla::PjRtClient,
    manifest: Manifest,
    execs: HashMap<String, xla::PjRtLoadedExecutable>,
    pub stats: EngineStats,
}

impl Engine {
    /// Load the manifest and lazily compile artifacts on first use.
    pub fn new(artifacts_dir: &Path) -> Result<Engine> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client =
            xla::PjRtClient::cpu().map_err(|e| anyhow!("PjRtClient::cpu failed: {e:?}"))?;
        Ok(Engine { client, manifest, execs: HashMap::new(), stats: EngineStats::default() })
    }

    /// Load from the default artifacts dir ($MBPROX_ARTIFACTS or ./artifacts).
    pub fn from_env() -> Result<Engine> {
        Engine::new(&default_artifacts_dir())
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// The underlying PJRT client (for device-buffer management).
    pub fn client(&self) -> &xla::PjRtClient {
        &self.client
    }

    pub fn block_rows(&self) -> usize {
        self.manifest.block
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Eagerly compile every artifact (used by the integration tests and
    /// long-running examples to pay compile cost up front).
    pub fn warmup_all(&mut self) -> Result<()> {
        let names: Vec<String> = self.manifest.artifacts.iter().map(|a| a.name.clone()).collect();
        for n in names {
            self.executable(&n)?;
        }
        Ok(())
    }

    /// Get (compiling if needed) the executable for `name`.
    pub fn executable(&mut self, name: &str) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.execs.contains_key(name) {
            let meta = self
                .manifest
                .find(name)
                .ok_or_else(|| anyhow!("artifact '{name}' not in manifest"))?
                .clone();
            let t0 = Instant::now();
            let proto = xla::HloModuleProto::from_text_file(&meta.file)
                .map_err(|e| anyhow!("parsing {}: {e:?}", meta.file.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe =
                self.client.compile(&comp).map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
            self.stats.compiles += 1;
            self.stats.compile_ns += t0.elapsed().as_nanos();
            self.execs.insert(name.to_string(), exe);
        }
        Ok(self.execs.get(name).unwrap())
    }

    /// Execute artifact `name` with device-buffer inputs; returns the
    /// decomposed output tuple as literals.
    ///
    /// NOTE: always goes through `execute_b` (buffer inputs). The crate's
    /// literal-input `execute` leaks its internal literal->buffer
    /// conversions (~70 KB/call measured — see EXPERIMENTS.md §Perf), so
    /// block operands are uploaded once (`upload`/`upload_mat`) and small
    /// per-call vectors are uploaded fresh, with rust-side Drop reclaiming
    /// them deterministically.
    pub fn execute(
        &mut self,
        name: &str,
        inputs: &[&xla::PjRtBuffer],
    ) -> Result<Vec<xla::Literal>> {
        self.executable(name)?; // ensure compiled (borrow gymnastics)
        let t0 = Instant::now();
        let exe = self.execs.get(name).unwrap();
        let out = exe
            .execute_b::<&xla::PjRtBuffer>(inputs)
            .map_err(|e| anyhow!("executing {name}: {e:?}"))?;
        let mut lit = out[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching output of {name}: {e:?}"))?;
        self.stats.executions += 1;
        self.stats.execute_ns += t0.elapsed().as_nanos();
        self.stats.literal_conversions += 1;
        // lowered with return_tuple=True: output is always a tuple
        let parts = lit.decompose_tuple().map_err(|e| anyhow!("untupling {name}: {e:?}"))?;
        Ok(parts)
    }

    /// Upload a 1-D f32 vector to the device.
    pub fn upload(&self, data: &[f32]) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, &[data.len()], None)
            .map_err(|e| anyhow!("uploading vec[{}]: {e:?}", data.len()))
    }

    /// Upload a row-major matrix to the device.
    pub fn upload_mat(&self, data: &[f32], rows: usize, cols: usize) -> Result<xla::PjRtBuffer> {
        anyhow::ensure!(data.len() == rows * cols, "matrix upload size mismatch");
        self.client
            .buffer_from_host_buffer(data, &[rows, cols], None)
            .map_err(|e| anyhow!("uploading mat[{rows}x{cols}]: {e:?}"))
    }

    /// Mean execute latency in nanoseconds (for perf reports).
    pub fn mean_execute_ns(&self) -> f64 {
        if self.stats.executions == 0 {
            0.0
        } else {
            self.stats.execute_ns as f64 / self.stats.executions as f64
        }
    }
}

/// Literal construction helpers.
pub fn lit_vec(data: &[f32]) -> xla::Literal {
    xla::Literal::vec1(data)
}

pub fn lit_mat(data: &[f32], rows: usize, cols: usize) -> Result<xla::Literal> {
    anyhow::ensure!(data.len() == rows * cols, "matrix literal size mismatch");
    xla::Literal::vec1(data)
        .reshape(&[rows as i64, cols as i64])
        .map_err(|e| anyhow!("reshape: {e:?}"))
}

pub fn lit_to_vec(l: &xla::Literal) -> Result<Vec<f32>> {
    l.to_vec::<f32>().map_err(|e| anyhow!("literal to_vec: {e:?}"))
}

pub fn lit_scalar1(x: f32) -> xla::Literal {
    xla::Literal::vec1(&[x])
}

/// Read a single f32 from a length-1 literal.
pub fn lit_first(l: &xla::Literal) -> Result<f32> {
    let v = lit_to_vec(l)?;
    v.first().copied().context("empty literal")
}
