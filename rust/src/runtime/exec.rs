//! Typed artifact wrappers: the coordinator-facing API over the engine.
//!
//! Three call families map 1:1 onto the artifact kinds:
//!   - `grad_block` / `gradm{K}`  -> (grad_sum[d], loss_sum, count)
//!   - `svrg_block`/`saga_block`  -> (x_out[d], x_avg[d])
//!   - `nm_block` / `nmm{K}`      -> (X^T diag(mask) X v, count)
//!
//! Block operands are uploaded to the device **once** per block group
//! (`BlockLits`) and reused across every artifact call in the inner loops
//! (DSVRG/SAGA sweeps, CG iterations). A `BlockLits` may hold `k` stacked
//! 256-row blocks: the grad/normal-matvec wrappers then dispatch the fused
//! `gradm{k}`/`nmm{k}` artifacts, which reduce across the stacked blocks
//! *on device* so one call downloads one output tuple per group.
//!
//! The small per-call vectors (iterates, directions, scalars) go through
//! the engine's [`super::ExecSession`] pool: each named slot re-uploads
//! only when its contents changed, so e.g. the iterate `w` is moved to the
//! device once per round rather than once per block. This is both the
//! §Perf hot-path optimization and the workaround for the literal-input
//! `execute` leak (see runtime::Engine::execute).

use super::{lit_first, lit_to_vec, ArtifactKind, Engine, Manifest};
use crate::data::blocks::Block;
use crate::data::Loss;
use anyhow::{ensure, Result};

/// Output of one block-gradient call (sum over valid rows + count).
#[derive(Clone, Debug)]
pub struct GradOut {
    pub grad_sum: Vec<f32>,
    pub loss_sum: f64,
    pub count: f64,
}

/// Device-resident (X, y, mask) operands for `k` stacked blocks,
/// uploaded once. `k == 1` is a plain single-block upload.
pub struct BlockLits {
    pub x: xla::PjRtBuffer,
    pub y: xla::PjRtBuffer,
    pub mask: xla::PjRtBuffer,
    /// total valid rows across the stacked blocks
    pub valid: usize,
    /// per stacked block valid-row counts (`valids.len() == k`). The
    /// group-aligned VR sweep combiner needs these: each non-empty block
    /// contributes `1 + valid` to the sweep-average weight, and the
    /// chained kernel's accumulator is divided by that total host-side.
    pub valids: Vec<usize>,
    pub d: usize,
    /// total rows (k * block rows)
    pub rows: usize,
    /// stacked 256-row blocks in this upload (fused-dispatch width)
    pub k: usize,
}

impl BlockLits {
    pub fn from_block(engine: &mut Engine, block: &Block) -> Result<BlockLits> {
        let rows = block.rows();
        Ok(BlockLits {
            x: engine.upload_mat(&block.x, rows, block.d)?,
            y: engine.upload(&block.y)?,
            mask: engine.upload(&block.mask)?,
            valid: block.valid,
            valids: vec![block.valid],
            d: block.d,
            rows,
            k: 1,
        })
    }

    /// Stack `blocks` (equal shape, consecutive) into ONE fused upload for
    /// the multi-block grad/normal-matvec artifacts.
    pub fn from_blocks(engine: &mut Engine, blocks: &[Block]) -> Result<BlockLits> {
        ensure!(!blocks.is_empty(), "cannot stack zero blocks");
        if blocks.len() == 1 {
            return Self::from_block(engine, &blocks[0]);
        }
        let d = blocks[0].d;
        let per_rows = blocks[0].rows();
        ensure!(
            blocks.iter().all(|b| b.d == d && b.rows() == per_rows),
            "stacked blocks must share shape"
        );
        let k = blocks.len();
        let rows = k * per_rows;
        let mut x = Vec::with_capacity(rows * d);
        let mut y = Vec::with_capacity(rows);
        let mut mask = Vec::with_capacity(rows);
        let mut valids = Vec::with_capacity(k);
        for b in blocks {
            x.extend_from_slice(&b.x);
            y.extend_from_slice(&b.y);
            mask.extend_from_slice(&b.mask);
            valids.push(b.valid);
        }
        Ok(BlockLits {
            x: engine.upload_mat(&x, rows, d)?,
            y: engine.upload(&y)?,
            mask: engine.upload(&mask)?,
            valid: valids.iter().sum(),
            valids,
            d,
            rows,
            k,
        })
    }

    /// The sweep-average weight this group contributes: `1 + valid` per
    /// non-empty stacked block (empty blocks are skipped, exactly like
    /// the legacy per-block combiner).
    pub fn sweep_weight(&self) -> f64 {
        self.valids.iter().filter(|&&v| v > 0).map(|&v| (1 + v) as f64).sum()
    }
}

impl Engine {
    fn artifact_for(&self, kind: ArtifactKind, loss: Loss, d: usize) -> String {
        Manifest::name_for(kind, loss.tag(), d)
    }

    /// Fused block gradient+loss: the `grad_{loss}_d{d}` artifact for a
    /// single block, or the on-device-reducing `gradm{k}_{loss}_d{d}`
    /// when `blk` stacks k blocks. The iterate `w` rides the session pool
    /// (one upload per round, not per block).
    pub fn grad_block(&mut self, loss: Loss, blk: &BlockLits, w: &[f32]) -> Result<GradOut> {
        ensure!(w.len() == blk.d, "w dim {} != block dim {}", w.len(), blk.d);
        let name = Manifest::name_for_k(ArtifactKind::Grad, loss.tag(), blk.d, blk.k)?;
        let outs =
            self.execute_pooled(&name, &[&blk.x, &blk.y, &blk.mask], &[("grad.w", w)])?;
        Self::unpack_grad(&mut self.stats, blk, &name, outs)
    }

    /// [`Engine::grad_block`] at a *device-resident* iterate: the
    /// [`super::DeviceVec`] is aliased into the `grad.w` session slot
    /// (zero uploads) so evaluation checkpoints can read losses at an
    /// iterate that never visited the host. Downloads the usual tuple —
    /// this is a dispatch-verb call, not a chain-verb one.
    pub fn grad_block_dev(
        &mut self,
        loss: Loss,
        blk: &BlockLits,
        w: &super::DeviceVec,
    ) -> Result<GradOut> {
        ensure!(w.dims() == [blk.d], "w {w:?} != block dim {}", blk.d);
        let name = Manifest::name_for_k(ArtifactKind::Grad, loss.tag(), blk.d, blk.k)?;
        self.alias_slot("grad.w", w);
        let outs = self.execute_slots(&name, &[&blk.x, &blk.y, &blk.mask], &["grad.w"])?;
        Self::unpack_grad(&mut self.stats, blk, &name, outs)
    }

    fn unpack_grad(
        stats: &mut super::EngineStats,
        blk: &BlockLits,
        name: &str,
        outs: Vec<xla::Literal>,
    ) -> Result<GradOut> {
        ensure!(outs.len() == 3, "{name} returned {} outputs", outs.len());
        stats.downloads += 1;
        stats.download_bytes += ((blk.d + 2) * std::mem::size_of::<f32>()) as u64;
        Ok(GradOut {
            grad_sum: lit_to_vec(&outs[0])?,
            loss_sum: lit_first(&outs[1])? as f64,
            count: lit_first(&outs[2])? as f64,
        })
    }

    /// One without-replacement SVRG sweep via `svrg_{loss}_d{d}`.
    #[allow(clippy::too_many_arguments)]
    pub fn svrg_block(
        &mut self,
        loss: Loss,
        blk: &BlockLits,
        x0: &[f32],
        z: &[f32],
        mu: &[f32],
        wprev: &[f32],
        gamma: f32,
        eta: f32,
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        self.vr_block(ArtifactKind::Svrg, loss, blk, x0, z, mu, wprev, gamma, eta)
    }

    /// One without-replacement SAGA sweep via `saga_{loss}_d{d}` — the
    /// paper's Appendix-E local solver. Same interface as `svrg_block`
    /// except the fourth vector is the quadratic `center` (the kernel
    /// initializes its gradient table at the snapshot `z` itself).
    #[allow(clippy::too_many_arguments)]
    pub fn saga_block(
        &mut self,
        loss: Loss,
        blk: &BlockLits,
        x0: &[f32],
        z: &[f32],
        mu: &[f32],
        center: &[f32],
        gamma: f32,
        eta: f32,
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        self.vr_block(ArtifactKind::Saga, loss, blk, x0, z, mu, center, gamma, eta)
    }

    #[allow(clippy::too_many_arguments)]
    fn vr_block(
        &mut self,
        kind: ArtifactKind,
        loss: Loss,
        blk: &BlockLits,
        x0: &[f32],
        z: &[f32],
        mu: &[f32],
        center: &[f32],
        gamma: f32,
        eta: f32,
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        ensure!(
            x0.len() == blk.d && z.len() == blk.d && mu.len() == blk.d && center.len() == blk.d
        );
        ensure!(blk.k == 1, "VR sweeps are sequential: per-block dispatch only");
        let name = self.artifact_for(kind, loss, blk.d);
        // x0 is the loop-carried iterate (changes every block); z/mu/center
        // and the scalars are sweep-constant and hit the pool after the
        // first block of a sweep.
        let gamma_arr = [gamma];
        let eta_arr = [eta];
        let outs = self.execute_pooled(
            &name,
            &[&blk.x, &blk.y, &blk.mask],
            &[
                ("vr.x0", x0),
                ("vr.z", z),
                ("vr.mu", mu),
                ("vr.center", center),
                ("vr.gamma", &gamma_arr),
                ("vr.eta", &eta_arr),
            ],
        )?;
        ensure!(outs.len() == 2, "{name} returned {} outputs", outs.len());
        self.stats.downloads += 1;
        self.stats.download_bytes += (2 * blk.d * std::mem::size_of::<f32>()) as u64;
        Ok((lit_to_vec(&outs[0])?, lit_to_vec(&outs[1])?))
    }

    /// Regularized-normal-equation matvec building block (squared loss):
    /// returns (X^T diag(mask) X v, count). Dispatches the fused
    /// `nmm{k}` artifact for stacked groups; `v` rides the session pool
    /// (one upload per CG iteration, not per block per machine).
    pub fn nm_block(&mut self, blk: &BlockLits, v: &[f32]) -> Result<(Vec<f32>, f64)> {
        ensure!(v.len() == blk.d);
        let name =
            Manifest::name_for_k(ArtifactKind::NormalMatvec, Loss::Squared.tag(), blk.d, blk.k)?;
        let outs = self.execute_pooled(&name, &[&blk.x, &blk.mask], &[("nm.v", v)])?;
        ensure!(outs.len() == 2);
        self.stats.downloads += 1;
        self.stats.download_bytes += ((blk.d + 1) * std::mem::size_of::<f32>()) as u64;
        Ok((lit_to_vec(&outs[0])?, lit_first(&outs[1])? as f64))
    }
}
