//! Typed artifact wrappers: the coordinator-facing API over the engine.
//!
//! Three call families map 1:1 onto the artifact kinds:
//!   - `grad_block`        -> (grad_sum[d], loss_sum, count)
//!   - `svrg_block`/`saga_block` -> (x_out[d], x_avg[d])
//!   - `nm_block`          -> (X^T diag(mask) X v, count)
//!
//! Block operands are uploaded to the device **once** per block
//! (`BlockLits`) and reused across every artifact call in the inner loops
//! (DSVRG/SAGA sweeps, CG iterations); only the small per-call vectors
//! (iterates, scalars) are uploaded fresh. This is both the §Perf hot-path
//! optimization and the workaround for the literal-input `execute` leak
//! (see runtime::Engine::execute).

use super::{lit_first, lit_to_vec, ArtifactKind, Engine, Manifest};
use crate::data::blocks::Block;
use crate::data::Loss;
use anyhow::{ensure, Result};

/// Output of one block-gradient call (sum over valid rows + count).
#[derive(Clone, Debug)]
pub struct GradOut {
    pub grad_sum: Vec<f32>,
    pub loss_sum: f64,
    pub count: f64,
}

/// Device-resident (X, y, mask) operands for one block, uploaded once.
pub struct BlockLits {
    pub x: xla::PjRtBuffer,
    pub y: xla::PjRtBuffer,
    pub mask: xla::PjRtBuffer,
    pub valid: usize,
    pub d: usize,
}

impl BlockLits {
    pub fn from_block(engine: &Engine, block: &Block) -> Result<BlockLits> {
        let rows = block.rows();
        Ok(BlockLits {
            x: engine.upload_mat(&block.x, rows, block.d)?,
            y: engine.upload(&block.y)?,
            mask: engine.upload(&block.mask)?,
            valid: block.valid,
            d: block.d,
        })
    }
}

impl Engine {
    fn artifact_for(&self, kind: ArtifactKind, loss: Loss, d: usize) -> String {
        Manifest::name_for(kind, loss.tag(), d)
    }

    /// Fused block gradient+loss via the `grad_{loss}_d{d}` artifact.
    pub fn grad_block(&mut self, loss: Loss, blk: &BlockLits, w: &[f32]) -> Result<GradOut> {
        ensure!(w.len() == blk.d, "w dim {} != block dim {}", w.len(), blk.d);
        let name = self.artifact_for(ArtifactKind::Grad, loss, blk.d);
        let w_b = self.upload(w)?;
        let outs = self.execute(&name, &[&blk.x, &blk.y, &blk.mask, &w_b])?;
        ensure!(outs.len() == 3, "grad artifact returned {} outputs", outs.len());
        Ok(GradOut {
            grad_sum: lit_to_vec(&outs[0])?,
            loss_sum: lit_first(&outs[1])? as f64,
            count: lit_first(&outs[2])? as f64,
        })
    }

    /// One without-replacement SVRG sweep via `svrg_{loss}_d{d}`.
    #[allow(clippy::too_many_arguments)]
    pub fn svrg_block(
        &mut self,
        loss: Loss,
        blk: &BlockLits,
        x0: &[f32],
        z: &[f32],
        mu: &[f32],
        wprev: &[f32],
        gamma: f32,
        eta: f32,
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        self.vr_block(ArtifactKind::Svrg, loss, blk, x0, z, mu, wprev, gamma, eta)
    }

    /// One without-replacement SAGA sweep via `saga_{loss}_d{d}` — the
    /// paper's Appendix-E local solver. Same interface as `svrg_block`
    /// except the fourth vector is the quadratic `center` (the kernel
    /// initializes its gradient table at the snapshot `z` itself).
    #[allow(clippy::too_many_arguments)]
    pub fn saga_block(
        &mut self,
        loss: Loss,
        blk: &BlockLits,
        x0: &[f32],
        z: &[f32],
        mu: &[f32],
        center: &[f32],
        gamma: f32,
        eta: f32,
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        self.vr_block(ArtifactKind::Saga, loss, blk, x0, z, mu, center, gamma, eta)
    }

    #[allow(clippy::too_many_arguments)]
    fn vr_block(
        &mut self,
        kind: ArtifactKind,
        loss: Loss,
        blk: &BlockLits,
        x0: &[f32],
        z: &[f32],
        mu: &[f32],
        center: &[f32],
        gamma: f32,
        eta: f32,
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        ensure!(
            x0.len() == blk.d && z.len() == blk.d && mu.len() == blk.d && center.len() == blk.d
        );
        let name = self.artifact_for(kind, loss, blk.d);
        let x0_b = self.upload(x0)?;
        let z_b = self.upload(z)?;
        let mu_b = self.upload(mu)?;
        let c_b = self.upload(center)?;
        let g_b = self.upload(&[gamma])?;
        let e_b = self.upload(&[eta])?;
        let outs = self.execute(
            &name,
            &[&blk.x, &blk.y, &blk.mask, &x0_b, &z_b, &mu_b, &c_b, &g_b, &e_b],
        )?;
        ensure!(outs.len() == 2, "{name} returned {} outputs", outs.len());
        Ok((lit_to_vec(&outs[0])?, lit_to_vec(&outs[1])?))
    }

    /// Regularized-normal-equation matvec building block (squared loss):
    /// returns (X^T diag(mask) X v, count).
    pub fn nm_block(&mut self, blk: &BlockLits, v: &[f32]) -> Result<(Vec<f32>, f64)> {
        ensure!(v.len() == blk.d);
        let name = self.artifact_for(ArtifactKind::NormalMatvec, Loss::Squared, blk.d);
        let v_b = self.upload(v)?;
        let outs = self.execute(&name, &[&blk.x, &blk.mask, &v_b])?;
        ensure!(outs.len() == 2);
        Ok((lit_to_vec(&outs[0])?, lit_first(&outs[1])? as f64))
    }
}
