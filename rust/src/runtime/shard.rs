//! ShardPool: the engine-per-worker shard plane.
//!
//! PJRT handles are not `Send`, so device state can never migrate between
//! threads. The shard plane therefore gives every worker thread its *own*
//! [`Engine`] (constructed on the worker, from the same artifacts dir as
//! the coordinator's) plus a shard-local store of machine state, and the
//! coordinator ships only **host** data across the boundary: job closures
//! in, `Vec<f32>` partials and meter deltas out.
//!
//! # Engine affinity
//!
//! Machines are partitioned machine -> shard once, at pool construction
//! (`shard_of(i) = i % shards`). ALL of a machine's state — its sample
//! stream (installed at context construction; the draw verb generates
//! and packs shard-side), its packed
//! [`crate::objective::MachineBatch`], its session-pool slots, any
//! chained [`super::DeviceVec`] intermediates — lives on its shard for
//! the machine's whole lifetime. A job for machine `i` is only ever
//! submitted to `shard_of(i)`, so the affinity rule is structural: there
//! is no API through which a buffer could reach another thread.
//!
//! # Join points and determinism
//!
//! Each shard runs its jobs strictly in submission order (one mpsc
//! channel per worker), and the coordinator submits machine jobs in
//! machine order, so the per-shard execution order is a deterministic
//! function of the machine->shard partition — never of thread timing.
//! Fan-outs join only at collectives: the coordinator waits for every
//! machine's partial *in fixed machine order* and reduces them in f64 on
//! the host (`comm::Network`), which is the same operation sequence the
//! sequential path performs — results are bit-identical for every shard
//! count. See `objective::fan_machines` for the fan/join helper.
//!
//! # The prefetch lane
//!
//! Every worker has a companion **prefetch lane** thread that owns the
//! shard's [`crate::data::SampleStream`]s (streams are installed on the
//! lane, not the worker — see [`ShardPool::install_stream`]). The lane is
//! host-only: it draws round t+1's samples and packs them into staged
//! [`Block`]s while the engine thread is dispatching round t; the engine
//! thread's draw job then merely collects the staged pack over the
//! handoff channel ([`LaneClient::take`]) and does the engine-affine
//! fuse+upload itself. Stages are one-deep per machine and the lane
//! re-draws a machine's last request right after serving it, which is
//! exactly double buffering: one pack in flight on the lane, one being
//! consumed by the engine.
//!
//! **Why bit-parity holds.** The lane never invents or reorders draws: a
//! take with a cold stage draws synchronously (the fallback — also the
//! entire behavior when prefetch is off), and a warm stage holds exactly
//! the `draw_many(n)` result the next same-sized request would have
//! produced, because requests arrive per machine in submission order and
//! each speculative draw is consumed by the next request before another
//! speculation may start. A request whose size differs from the staged
//! pack pushes the staged *samples* back into a leftover queue and
//! re-serves from it — bit-exact whenever the stream's `draw_many`
//! decomposes into single draws ([`crate::data::SampleStream::
//! draws_decompose`]); epoch-batching streams (where re-splitting would
//! change epoch boundaries) refuse with an error naming `prefetch=off`.
//! One trailing speculative draw per machine can remain un-consumed at
//! run end; it is never metered (only served takes charge samples) and
//! the stream dies with the run's `clear_machines`, so no later run can
//! observe it.
//!
//! The engine thread's wait inside `take` is the **dispatch stall** the
//! lane exists to hide; each worker meters it (plus stage hit/miss
//! counts) in its [`StallMeter`], gathered per run via
//! [`ShardPool::gathered_stalls`].
//!
//! # Batched fans and the software pipeline
//!
//! A plane fan used to submit one job per *machine*; it now submits one
//! [`FanBatch`] job per *shard* ([`ShardPool::fan_batches`]), covering
//! every machine the shard owns in ascending machine order. Per-shard
//! execution order is unchanged — ascending machine order is exactly the
//! order the old per-machine submissions enqueued — so batching alone is
//! bit-invisible; it just removes per-machine channel round-trips and
//! gives the worker a loop it can pipeline.
//!
//! With `pipeline=on` (see `PipelinePolicy` in `runtime::plane`) the
//! worker's batched draw loop runs a one-deep software pipeline against
//! its lane: split [`LaneClient::take`] into [`LaneClient::request`] /
//! [`LaneTicket::collect`], and issue machine k+1's request immediately
//! after collecting machine k's reply — BEFORE the engine-affine
//! fuse+upload of machine k's blocks. The lane then draws and packs k+1
//! while the engine uploads k: true thread overlap, biggest when the
//! stage is cold (prefetch off or first round). Because request(k+1) is
//! sent only AFTER collect(k), lane commands arrive in the identical FIFO
//! order as the serial loop — the pipeline changes WHEN the lane works,
//! never WHAT it draws, so bit-parity is unconditional.
//!
//! Each worker's [`OverlapMeter`] records what the pipeline actually
//! bought: engine-work nanoseconds spent while a staged request was in
//! flight (`overlap_ns`) vs with nothing staged (`serial_ns`). Like the
//! [`StallMeter`] it is wall-clock-only diagnostics — the simulated
//! paper-units (rounds, bytes, samples, memory) are identical with the
//! pipeline on or off, and the parity tests pin that. Meters travel via
//! [`ShardPool::per_shard_metrics`]: ONE gather job per shard, all
//! submitted before any wait, carrying stats + stalls + overlap + uploads
//! together.
//!
//! # The upload lane and MultiDev seeding
//!
//! The **upload lane** (see the `runtime` module docs) is engine-level:
//! when the coordinator broadcasts [`ShardPool::set_upload_lane`], every
//! shard engine routes its pooled operands through the staging rings, and
//! each worker's [`crate::accounting::UploadMeter`] fills in as a side
//! effect of its engine running the exact same `execute_pooled` code the
//! coordinator runs. Nothing in this file stages or meters uploads
//! itself. Each worker also constructs its engine with
//! `Engine::new_on_device(dir, shard_index)` — shard s targets PJRT
//! device s where the client exposes one, falling back to device 0
//! otherwise — which seeds the MultiDev plane without changing any bits
//! (device placement never enters the simulated cost model).
//!
//! # Supervised workers and elastic reassignment
//!
//! Worker threads are supervised. A panicking *job* was already contained
//! by `catch_unwind`; a dying worker *thread* (simulated by
//! [`ShardPool::kill_worker`], which makes the loop exit exactly like a
//! hard crash — queued jobs are dropped unran) is healed at the next
//! collective boundary: every fan batch carries a replay recipe (its
//! closure is `Clone`), so [`ShardPool::wait_elastic`] turns a dead reply
//! channel into [`ShardPool::revive`] — join the dead thread, rebuild the
//! engine from the retained artifacts dir, keep the SAME prefetch lane —
//! followed by a replay of the interrupted batch. Because streams live on
//! the lane (which survives the worker) and the dropped job never
//! consumed its takes, a replayed draw fan draws the exact samples the
//! dead worker would have: final iterates are bit-identical to an
//! uninterrupted run (pinned by `rust/tests/fault_parity.rs`). What is
//! NOT restored: shard-resident state the dead worker had already built
//! this run (packed batches, evaluator segments, session slots). A
//! replayed draw re-packs its batches; anything else that addresses lost
//! state fails with the honest "no batch / not resident" error, and the
//! between-run `clear_machines` heals the pool for the next run
//! regardless.
//!
//! When a worker cannot be revived (engine reconstruction fails),
//! `wait_elastic` falls back to **elastic reassignment**: each of the
//! dead shard's machines moves to a surviving shard
//! ([`ShardPool::reassign_machine`]) — its stream, with any staged
//! read-ahead folded back in draw order, migrates lane-to-lane — and the
//! batch replays under the new grouping. Reassignment only ever happens
//! at a collective boundary (the wait IS the boundary), and bits never
//! change: per-machine partials are independent of which engine computes
//! them, and collectives join in fixed machine order regardless of the
//! machine->shard grouping. Only wall-clock moves. Both recovery paths
//! count into [`ShardPool::recovery_counts`], surfaced on the run's
//! `FaultMeter`.

use super::{Engine, EngineStats};
use crate::accounting::{CacheMeter, OverlapMeter, StallMeter, UploadMeter};
use crate::data::blocks::{pack_all, Block};
use crate::data::{Sample, SampleStream};
use anyhow::{anyhow, Context, Result};
use std::cell::{Cell, RefCell};
use std::collections::{HashMap, VecDeque};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::{Path, PathBuf};
use std::sync::mpsc;
use std::thread;
use std::time::Duration;

/// Everything a worker thread owns: its private engine, the device state
/// of the machines assigned to its shard, and the handle to its prefetch
/// lane (which owns those machines' sample streams). Lives on the worker
/// thread only — jobs receive `&mut ShardState` and must keep it there.
pub struct ShardState {
    pub engine: Engine,
    /// machine id -> that machine's current packed batch (replaced on
    /// every fresh draw; cleared between runs)
    pub batches: HashMap<usize, crate::objective::MachineBatch>,
    /// held-out evaluator segments owned by this shard (segment id ->
    /// grad-only batch; packed once per run context, cleared between
    /// runs) — the sharded `Evaluator` fan reads these
    pub eval: HashMap<usize, crate::objective::MachineBatch>,
    /// this shard's prefetch lane: the draw verb takes staged packs from
    /// it (or has it draw synchronously when prefetch is off / the stage
    /// is cold) and fuses+uploads them on `engine`
    pub lane: LaneClient,
    /// per-run draw staging counters (dispatch stall, stage hits/misses);
    /// reset by `clear_machines`
    pub stalls: StallMeter,
    /// per-run batched-fan pipeline counters (fans run, requests staged,
    /// overlapped vs serial engine-work wall-clock); reset by
    /// `clear_machines`
    pub overlap: OverlapMeter,
}

impl ShardState {
    /// The machine's current batch alongside the engine (split borrow, so
    /// the job can dispatch against it).
    pub fn machine(&mut self, i: usize) -> Result<(&mut Engine, &crate::objective::MachineBatch)> {
        let batch = self
            .batches
            .get(&i)
            .ok_or_else(|| anyhow!("machine {i} has no batch on this shard (draw first)"))?;
        Ok((&mut self.engine, batch))
    }

    /// Evaluator segment `i`'s batch alongside the engine.
    pub fn eval_segment(
        &mut self,
        i: usize,
    ) -> Result<(&mut Engine, &crate::objective::MachineBatch)> {
        let batch = self
            .eval
            .get(&i)
            .ok_or_else(|| anyhow!("evaluator segment {i} is not resident on this shard"))?;
        Ok((&mut self.engine, batch))
    }
}

type Job = Box<dyn FnOnce(&mut ShardState) + Send + 'static>;

/// One message to a worker thread: a job, or the fault-injection order to
/// die on the spot (the loop returns immediately, dropping every queued
/// job — exactly what a hard process crash does to in-flight work).
enum WorkerMsg {
    Job(Job),
    Die,
}

/// A submitted job's typed reply. `wait` blocks until the worker ran the
/// closure (or died); join fan-outs in machine order for determinism.
/// Carries its shard and label so failures name the job that was lost.
pub struct Pending<T> {
    rx: mpsc::Receiver<Result<T>>,
    shard: usize,
    label: String,
}

impl<T> Pending<T> {
    pub fn wait(self) -> Result<T> {
        let Pending { rx, shard, label } = self;
        rx.recv().map_err(|_| {
            anyhow!("job '{label}' lost: shard worker {shard} is gone (crashed or pool shut down)")
        })?
    }

    /// [`Pending::wait`] with a deadline: a worker wedged in a job (or a
    /// dead channel) surfaces as an error naming the shard and job label
    /// instead of blocking the coordinator forever.
    pub fn wait_deadline(self, timeout: Duration) -> Result<T> {
        let Pending { rx, shard, label } = self;
        match rx.recv_timeout(timeout) {
            Ok(res) => res,
            Err(mpsc::RecvTimeoutError::Timeout) => Err(anyhow!(
                "job '{label}' on shard worker {shard} did not finish within {timeout:?} \
                 (worker wedged or job deadlocked)"
            )),
            Err(mpsc::RecvTimeoutError::Disconnected) => Err(anyhow!(
                "job '{label}' lost: shard worker {shard} is gone (crashed or pool shut down)"
            )),
        }
    }
}

/// One shard's slice of a batched fan (see [`ShardPool::fan_batches`]):
/// the machines this shard's single job covers, in ascending machine
/// order, and the pending per-machine results. The coordinator waits one
/// `FanBatch` per shard instead of one `Pending` per machine — fewer
/// channel round-trips, same fixed-order join (results carry their
/// machine ids, so the caller reassembles machine order exactly). Each
/// batch also carries a replay recipe (the fan closure is `Clone`), which
/// is what lets [`ShardPool::wait_elastic`] heal a dead worker.
pub struct FanBatch<T> {
    /// machines this shard's job runs, ascending
    pub machines: Vec<usize>,
    shard: usize,
    label: String,
    /// pinned batches address shard-resident state packed at context
    /// construction (evaluator segments); they may be replayed on their
    /// own shard but never reassigned to another
    pinned: bool,
    pending: Pending<Vec<(usize, T)>>,
    replay: Option<Box<dyn ReplayFan<T>>>,
}

impl<T> FanBatch<T> {
    /// Block until the shard ran every machine in this batch; returns
    /// `(machine, result)` pairs in ascending machine order.
    pub fn wait(self) -> Result<Vec<(usize, T)>> {
        self.pending.wait()
    }

    /// [`FanBatch::wait`] with a deadline (see [`Pending::wait_deadline`]);
    /// the error additionally names the machines the batch covered.
    pub fn wait_deadline(self, timeout: Duration) -> Result<Vec<(usize, T)>> {
        let machines = format!("{:?}", self.machines);
        self.pending
            .wait_deadline(timeout)
            .with_context(|| format!("fan batch over machines {machines}"))
    }
}

/// The replay half of a fan batch: re-submits the batch's closure for an
/// arbitrary machine subset on an arbitrary shard, so a lost batch can be
/// rerun in place (revived worker) or split across survivors
/// (reassignment).
trait ReplayFan<T> {
    fn resubmit(
        &self,
        pool: &ShardPool,
        shard: usize,
        label: &str,
        machines: &[usize],
    ) -> Pending<Vec<(usize, T)>>;
}

struct ReplayF<F> {
    f: F,
}

impl<T, F> ReplayFan<T> for ReplayF<F>
where
    T: Send + 'static,
    F: Fn(&mut ShardState, &[usize]) -> Result<Vec<(usize, T)>> + Clone + Send + 'static,
{
    fn resubmit(
        &self,
        pool: &ShardPool,
        shard: usize,
        label: &str,
        machines: &[usize],
    ) -> Pending<Vec<(usize, T)>> {
        let ms = machines.to_vec();
        let f = self.f.clone();
        pool.submit_named(shard, label, move |state| {
            state.overlap.fans += 1;
            f(state, &ms)
        })
    }
}

/// A machine's stream plus its pending read-ahead (staged speculation
/// folded back in draw order), pulled off a lane for elastic
/// reassignment.
type StolenStream = (Box<dyn SampleStream>, VecDeque<Sample>);

/// One message to a shard's prefetch lane thread.
enum LaneCmd {
    /// Move machine `i`'s stream onto the lane (context construction).
    Install(usize, Box<dyn SampleStream>),
    /// Serve machine `machine` its next `n`-sample pack at block dim `d`;
    /// the engine thread blocks on `reply`. With `prefetch` set, the lane
    /// immediately re-draws the same request into the stage afterwards
    /// (the double buffer).
    Take {
        machine: usize,
        n: usize,
        d: usize,
        prefetch: bool,
        reply: mpsc::Sender<Result<TakeReply>>,
    },
    /// Remove machine `machine`'s stream and read-ahead for elastic
    /// reassignment; replies `None` when the lane holds no stream for it.
    /// Any staged pack is folded back into the leftover queue FIRST, so
    /// the stream's draw position travels bit-exactly.
    Steal { machine: usize, reply: mpsc::Sender<Option<StolenStream>> },
    /// Re-install a stolen stream on the reassignment target's lane,
    /// leftover read-ahead and all.
    Adopt { machine: usize, stream: Box<dyn SampleStream>, leftovers: VecDeque<Sample> },
    /// Drop all streams, stages, leftovers and queued refills (between
    /// runs).
    Clear { reply: mpsc::Sender<()> },
}

/// What a take hands back to the engine thread: host-packed blocks ready
/// for the engine-affine fuse+upload, the honest drawn count (short at an
/// epoch boundary), and whether the stage was warm.
pub struct TakeReply {
    pub blocks: Vec<Block>,
    pub drawn: u64,
    pub hit: bool,
}

/// Handle to one shard's prefetch lane (cloneable: the pool keeps one for
/// stream installs, the worker's [`ShardState`] one for takes).
#[derive(Clone)]
pub struct LaneClient {
    tx: mpsc::Sender<LaneCmd>,
}

impl LaneClient {
    /// Ask the lane for machine `machine`'s next `n`-sample pack and
    /// block until it arrives. The caller times this wait — it is the
    /// dispatch stall. Equivalent to [`LaneClient::request`] followed
    /// immediately by [`LaneTicket::collect`].
    pub fn take(&self, machine: usize, n: usize, d: usize, prefetch: bool) -> Result<TakeReply> {
        self.request(machine, n, d, prefetch)?.collect()
    }

    /// Send the take command WITHOUT waiting for the reply — the
    /// pipelined fan's half of a take. The returned ticket collects the
    /// reply later; the lane starts drawing/packing the moment the
    /// command arrives, concurrently with whatever the engine thread does
    /// until the collect.
    pub fn request(&self, machine: usize, n: usize, d: usize, pf: bool) -> Result<LaneTicket> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(LaneCmd::Take { machine, n, d, prefetch: pf, reply })
            .map_err(|_| anyhow!("prefetch lane for machine {machine} is gone"))?;
        Ok(LaneTicket { machine, rx })
    }
}

/// An in-flight lane take (see [`LaneClient::request`]): the reply
/// channel for one machine's pack, collected at the pipeline's collect
/// point. At most one is in flight per machine at a time (the lane serves
/// its command queue in FIFO order, so tickets complete in request order).
pub struct LaneTicket {
    machine: usize,
    rx: mpsc::Receiver<Result<TakeReply>>,
}

impl LaneTicket {
    /// Block until the lane serves this request. The caller times this
    /// wait — with the pipeline on it is the residual dispatch stall the
    /// overlap could not hide.
    pub fn collect(self) -> Result<TakeReply> {
        let machine = self.machine;
        self.rx
            .recv()
            .map_err(|_| anyhow!("prefetch lane died before replying (machine {machine})"))?
    }
}

/// A speculatively drawn pack, one-deep per machine. The samples are kept
/// alongside the packed blocks so a mismatched follow-up request can push
/// them back (leftover queue) instead of losing them.
struct Staged {
    n_request: usize,
    d: usize,
    samples: Vec<Sample>,
    blocks: Vec<Block>,
}

/// The lane thread's state: the shard's streams plus staging buffers.
#[derive(Default)]
struct LaneState {
    streams: HashMap<usize, Box<dyn SampleStream>>,
    staged: HashMap<usize, Staged>,
    /// samples pushed back from a mismatched stage, served before any new
    /// stream draw (preserves the draw order bit-for-bit)
    leftovers: HashMap<usize, VecDeque<Sample>>,
    /// queued speculative refills `(machine, n, d)`, run only when no
    /// command is waiting
    want: VecDeque<(usize, usize, usize)>,
}

impl LaneState {
    fn handle(&mut self, cmd: LaneCmd) {
        match cmd {
            LaneCmd::Install(i, stream) => {
                self.staged.remove(&i);
                self.leftovers.remove(&i);
                self.streams.insert(i, stream);
            }
            LaneCmd::Take { machine, n, d, prefetch, reply } => {
                let res = self.serve_take(machine, n, d);
                let ok = res.is_ok();
                let _ = reply.send(res);
                if prefetch && ok {
                    self.want.push_back((machine, n, d));
                }
            }
            LaneCmd::Steal { machine, reply } => {
                // fold any staged speculation back first — the staged
                // samples were drawn before anything still in the leftover
                // queue, so they go to the FRONT (same rule as a
                // mismatched stage) and the draw position moves intact
                if let Some(stage) = self.staged.remove(&machine) {
                    let left = self.leftovers.entry(machine).or_default();
                    for s in stage.samples.into_iter().rev() {
                        left.push_front(s);
                    }
                }
                self.want.retain(|&(i, _, _)| i != machine);
                let leftovers = self.leftovers.remove(&machine).unwrap_or_default();
                let out = self.streams.remove(&machine).map(|stream| (stream, leftovers));
                let _ = reply.send(out);
            }
            LaneCmd::Adopt { machine, stream, leftovers } => {
                self.staged.remove(&machine);
                if leftovers.is_empty() {
                    self.leftovers.remove(&machine);
                } else {
                    self.leftovers.insert(machine, leftovers);
                }
                self.streams.insert(machine, stream);
            }
            LaneCmd::Clear { reply } => {
                self.streams.clear();
                self.staged.clear();
                self.leftovers.clear();
                self.want.clear();
                let _ = reply.send(());
            }
        }
    }

    fn serve_take(&mut self, i: usize, n: usize, d: usize) -> Result<TakeReply> {
        if let Some(stage) = self.staged.remove(&i) {
            if stage.n_request == n && stage.d == d {
                return Ok(TakeReply {
                    drawn: stage.samples.len() as u64,
                    blocks: stage.blocks,
                    hit: true,
                });
            }
            // mismatched speculation: re-splitting the read-ahead only
            // changes no bits when draw_many decomposes into single draws
            let decomposes = self.streams.get(&i).map(|s| s.draws_decompose()).unwrap_or(false);
            anyhow::ensure!(
                decomposes,
                "prefetch staged a {}-sample pack for machine {i} but the next draw \
                 requested {n}; this stream's epoch batching cannot re-split a read-ahead \
                 bit-identically — rerun with prefetch=off",
                stage.n_request
            );
            // the staged samples were drawn (leftovers-then-stream) before
            // anything still sitting in the leftover queue, so they go to
            // the FRONT to restore the draw order exactly
            let left = self.leftovers.entry(i).or_default();
            for s in stage.samples.into_iter().rev() {
                left.push_front(s);
            }
        }
        let samples = self.draw_samples(i, n)?;
        let blocks = pack_all(&samples, d);
        Ok(TakeReply { drawn: samples.len() as u64, blocks, hit: false })
    }

    /// Draw `n` samples for machine `i`: leftovers first (pushed-back
    /// read-ahead), then the stream — the exact order a lane-less draw
    /// sequence would have produced.
    fn draw_samples(&mut self, i: usize, n: usize) -> Result<Vec<Sample>> {
        let stream = self
            .streams
            .get_mut(&i)
            .ok_or_else(|| anyhow!("machine {i} has no stream on this shard"))?;
        let mut out = Vec::with_capacity(n);
        if let Some(left) = self.leftovers.get_mut(&i) {
            while out.len() < n {
                match left.pop_front() {
                    Some(s) => out.push(s),
                    None => break,
                }
            }
        }
        if out.len() < n {
            out.extend(stream.draw_many(n - out.len()));
        }
        Ok(out)
    }

    /// Run one queued speculative draw. A still-warm stage means the last
    /// speculation was never consumed — drawing again would lose samples,
    /// so the refill is dropped (the next take will miss, never misdraw).
    fn refill(&mut self, i: usize, n: usize, d: usize) {
        if self.staged.contains_key(&i) || !self.streams.contains_key(&i) {
            return;
        }
        let samples = match self.draw_samples(i, n) {
            Ok(s) => s,
            Err(_) => return,
        };
        let blocks = pack_all(&samples, d);
        self.staged.insert(i, Staged { n_request: n, d, samples, blocks });
    }
}

fn lane_main(rx: mpsc::Receiver<LaneCmd>) {
    let mut st = LaneState::default();
    loop {
        // a queued take must never wait behind speculative work: drain
        // every pending command, then do at most ONE refill, then re-check
        loop {
            match rx.try_recv() {
                Ok(cmd) => st.handle(cmd),
                Err(mpsc::TryRecvError::Empty) => break,
                Err(mpsc::TryRecvError::Disconnected) => return,
            }
        }
        if let Some((i, n, d)) = st.want.pop_front() {
            st.refill(i, n, d);
            continue;
        }
        match rx.recv() {
            Ok(cmd) => st.handle(cmd),
            Err(_) => return,
        }
    }
}

struct Worker {
    tx: mpsc::Sender<WorkerMsg>,
    handle: Option<thread::JoinHandle<()>>,
}

struct Lane {
    tx: mpsc::Sender<LaneCmd>,
    handle: Option<thread::JoinHandle<()>>,
}

/// A supervised pool of worker threads, each owning one [`Engine`] plus a
/// companion prefetch lane thread (see module docs). Dropping the pool
/// shuts the workers down, then the lanes, and joins them all. The pool
/// is coordinator-thread-only (interior mutability backs the supervision
/// and the elastic partition; none of it is `Sync`).
pub struct ShardPool {
    workers: RefCell<Vec<Worker>>,
    lanes: Vec<Lane>,
    n_shards: usize,
    /// artifacts dir the engines load from — retained so supervised
    /// recovery can rebuild a dead worker's engine
    dir: PathBuf,
    /// elastic partition overrides (machine -> shard); empty = the
    /// construction-time partition `i % shards`. Reset between runs by
    /// `clear_machines`.
    reassigned: RefCell<HashMap<usize, usize>>,
    /// supervised worker restarts this run (see `recovery_counts`)
    recoveries: Cell<u64>,
    /// fan batches replayed after a worker death this run
    replays: Cell<u64>,
}

impl ShardPool {
    /// Spawn `shards` workers, each constructing its own engine from
    /// `artifacts_dir` *on its thread*. Fails if any engine fails to load
    /// (the pool is torn down cleanly in that case).
    pub fn new(shards: usize, artifacts_dir: &Path) -> Result<ShardPool> {
        anyhow::ensure!(shards >= 1, "shard pool needs at least one worker");
        let mut workers = Vec::with_capacity(shards);
        let mut lanes = Vec::with_capacity(shards);
        let mut readies = Vec::with_capacity(shards);
        for s in 0..shards {
            let (lane_tx, lane_rx) = mpsc::channel::<LaneCmd>();
            let lane_handle = thread::Builder::new()
                .name(format!("shard-{s}-lane"))
                .spawn(move || lane_main(lane_rx))
                .with_context(|| format!("spawning prefetch lane {s}"))?;
            lanes.push(Lane { tx: lane_tx.clone(), handle: Some(lane_handle) });
            let lane = LaneClient { tx: lane_tx };
            let (tx, rx) = mpsc::channel::<WorkerMsg>();
            let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
            let dir: PathBuf = artifacts_dir.to_path_buf();
            let handle = thread::Builder::new()
                .name(format!("shard-{s}"))
                .spawn(move || worker_main(rx, dir, ready_tx, lane, s))
                .with_context(|| format!("spawning shard worker {s}"))?;
            workers.push(Worker { tx, handle: Some(handle) });
            readies.push(ready_rx);
        }
        let pool = ShardPool {
            workers: RefCell::new(workers),
            lanes,
            n_shards: shards,
            dir: artifacts_dir.to_path_buf(),
            reassigned: RefCell::new(HashMap::new()),
            recoveries: Cell::new(0),
            replays: Cell::new(0),
        };
        for (s, ready) in readies.into_iter().enumerate() {
            ready
                .recv()
                .map_err(|_| anyhow!("shard worker {s} died during startup"))?
                .with_context(|| format!("shard worker {s}: engine construction failed"))?;
        }
        Ok(pool)
    }

    /// Number of worker shards.
    pub fn shards(&self) -> usize {
        self.n_shards
    }

    /// The current machine->shard partition: the construction-time
    /// `i % shards` unless an elastic reassignment overrode the machine.
    pub fn shard_of(&self, machine: usize) -> usize {
        if let Some(&s) = self.reassigned.borrow().get(&machine) {
            return s;
        }
        machine % self.n_shards
    }

    /// The construction-time partition, ignoring elastic overrides.
    /// Evaluator segments are pinned here: they are packed once per run
    /// context and must not be re-routed by a machine reassignment whose
    /// machine id happens to match a segment id.
    fn shard_of_base(&self, machine: usize) -> usize {
        machine % self.n_shards
    }

    /// Enqueue `f` on `shard`; returns immediately with the typed reply
    /// handle. Jobs on one shard run strictly in submission order.
    pub fn submit<T: Send + 'static>(
        &self,
        shard: usize,
        f: impl FnOnce(&mut ShardState) -> Result<T> + Send + 'static,
    ) -> Pending<T> {
        self.submit_named(shard, "shard job", f)
    }

    /// [`ShardPool::submit`] with a label naming the job in failure
    /// reports. The closure runs under `catch_unwind`, so a panicking job
    /// no longer kills its worker silently: the panic message (and the
    /// label saying which machine/verb) travels back through the reply
    /// channel and the worker stays up for subsequent jobs.
    pub fn submit_named<T: Send + 'static>(
        &self,
        shard: usize,
        label: &str,
        f: impl FnOnce(&mut ShardState) -> Result<T> + Send + 'static,
    ) -> Pending<T> {
        let label = label.to_string();
        let job_label = label.clone();
        let (tx, rx) = mpsc::channel::<Result<T>>();
        let job: Job = Box::new(move |state| {
            // AssertUnwindSafe: a panicking job may leave its own
            // machine's shard state partially updated; the run that hit
            // the panic is abandoned and `clear_machines` rebuilds state
            // before the next one
            let result = catch_unwind(AssertUnwindSafe(|| f(state))).unwrap_or_else(|payload| {
                Err(anyhow!(
                    "{job_label} panicked on its shard worker: {}",
                    panic_message(&*payload)
                ))
            });
            let _ = tx.send(result);
        });
        // a dead worker drops the job (and with it the reply sender), so
        // `wait` surfaces the failure instead of hanging
        let _ = self.workers.borrow()[shard].tx.send(WorkerMsg::Job(job));
        Pending { rx, shard, label }
    }

    /// Submit to the shard owning `machine` and block for the result.
    pub fn run_on_machine<T: Send + 'static>(
        &self,
        machine: usize,
        f: impl FnOnce(&mut ShardState) -> Result<T> + Send + 'static,
    ) -> Result<T> {
        self.submit_named(self.shard_of(machine), &format!("machine {machine} job"), f).wait()
    }

    /// The batched fan, raw form: ONE job per shard, handed the full
    /// ascending list of machines (`0..m` filtered by ownership) that
    /// shard covers, so the closure controls its own loop — the pipelined
    /// draw fan lives on this. Shards with no machines (`m` < shard
    /// count, or every machine reassigned away) get no job. Every job is
    /// submitted before this returns; wait the returned batches in order
    /// for the deterministic join.
    pub fn fan_batches_raw<T, F>(&self, m: usize, label: &str, f: F) -> Vec<FanBatch<T>>
    where
        T: Send + 'static,
        F: Fn(&mut ShardState, &[usize]) -> Result<Vec<(usize, T)>> + Clone + Send + 'static,
    {
        self.fan_batches_raw_inner(m, label, f, false)
    }

    fn fan_batches_raw_inner<T, F>(
        &self,
        m: usize,
        label: &str,
        f: F,
        pinned: bool,
    ) -> Vec<FanBatch<T>>
    where
        T: Send + 'static,
        F: Fn(&mut ShardState, &[usize]) -> Result<Vec<(usize, T)>> + Clone + Send + 'static,
    {
        // group machines by their CURRENT shard (base partition when
        // pinned); iterating 0..m keeps each group ascending, which is
        // the per-shard execution order bit-parity depends on
        let mut groups: Vec<Vec<usize>> = vec![Vec::new(); self.shards()];
        for i in 0..m {
            let s = if pinned { self.shard_of_base(i) } else { self.shard_of(i) };
            groups[s].push(i);
        }
        let mut out = Vec::with_capacity(self.shards());
        for (s, machines) in groups.into_iter().enumerate() {
            if machines.is_empty() {
                continue;
            }
            let ms = machines.clone();
            let fj = f.clone();
            let pending = self.submit_named(s, label, move |state| {
                state.overlap.fans += 1;
                fj(state, &ms)
            });
            out.push(FanBatch {
                machines,
                shard: s,
                label: label.to_string(),
                pinned,
                pending,
                replay: Some(Box::new(ReplayF { f: f.clone() })),
            });
        }
        out
    }

    /// The batched fan, per-machine form: like the old one-job-per-machine
    /// fan but with one job per shard running its machines in ascending
    /// order — the identical per-shard execution order the per-machine
    /// submissions produced, so results and meters are bit-for-bit
    /// unchanged. A failing machine fails its whole shard batch (the run
    /// aborts either way).
    pub fn fan_batches<T, F>(&self, m: usize, label: &str, f: F) -> Vec<FanBatch<T>>
    where
        T: Send + 'static,
        F: Fn(&mut ShardState, usize) -> Result<T> + Clone + Send + 'static,
    {
        self.fan_batches_raw_inner(m, label, Self::per_machine(f), false)
    }

    /// [`ShardPool::fan_batches`] over the construction-time partition,
    /// immune to elastic reassignment. For fans whose "machine" ids are
    /// really ids of shard-resident state packed at context construction
    /// (evaluator segments): a reassigned MACHINE id must not drag the
    /// same-numbered SEGMENT to a shard that never packed it.
    /// [`ShardPool::wait_elastic`] replays pinned batches in place but
    /// refuses to reassign them.
    pub fn fan_batches_pinned<T, F>(&self, m: usize, label: &str, f: F) -> Vec<FanBatch<T>>
    where
        T: Send + 'static,
        F: Fn(&mut ShardState, usize) -> Result<T> + Clone + Send + 'static,
    {
        self.fan_batches_raw_inner(m, label, Self::per_machine(f), true)
    }

    fn per_machine<T, F>(
        f: F,
    ) -> impl Fn(&mut ShardState, &[usize]) -> Result<Vec<(usize, T)>> + Clone + Send + 'static
    where
        T: Send + 'static,
        F: Fn(&mut ShardState, usize) -> Result<T> + Clone + Send + 'static,
    {
        move |state: &mut ShardState, machines: &[usize]| {
            let mut out = Vec::with_capacity(machines.len());
            for &i in machines {
                out.push((i, f(state, i)?));
            }
            Ok(out)
        }
    }

    /// Install machine `machine`'s sample stream on its shard's prefetch
    /// lane. Safe to call before submitting draw jobs: the install is
    /// enqueued on the lane channel ahead of any take those jobs send.
    pub fn install_stream(&self, machine: usize, stream: Box<dyn SampleStream>) -> Result<()> {
        let shard = self.shard_of(machine);
        self.lanes[shard]
            .tx
            .send(LaneCmd::Install(machine, stream))
            .map_err(|_| anyhow!("prefetch lane {shard} is gone"))
    }

    /// FAULT INJECTION: order `shard`'s worker thread to die on the spot.
    /// The worker loop returns at the [`WorkerMsg::Die`] message, dropping
    /// every queued job unran — the same observable effect as a hard crash
    /// mid-round (reply channels error instead of delivering). The
    /// prefetch lane — and with it the shard's streams and read-ahead —
    /// survives; healing is [`ShardPool::wait_elastic`]'s job at the next
    /// collective boundary, or [`ShardPool::clear_machines`]' between
    /// runs.
    pub fn kill_worker(&self, shard: usize) {
        let _ = self.workers.borrow()[shard].tx.send(WorkerMsg::Die);
    }

    /// Definitive liveness probe: send the worker a no-op job. The send
    /// fails if and only if the worker's receiver is dropped, which
    /// happens exactly when its loop exited — unlike `JoinHandle::
    /// is_finished`, which can lag a worker that just processed Die (the
    /// thread is still tearing down) and wrongly report it alive.
    fn worker_alive(&self, shard: usize) -> bool {
        self.workers.borrow()[shard].tx.send(WorkerMsg::Job(Box::new(|_| {}))).is_ok()
    }

    /// Supervised restart: if `shard`'s worker is dead, join the corpse,
    /// spawn a fresh worker thread, rebuild its [`Engine`] from the
    /// retained artifacts dir and hand it the SAME prefetch lane (streams
    /// and read-ahead survive a worker death untouched). Returns whether a
    /// restart actually happened — `Ok(false)` means the worker was alive.
    /// What the new engine does NOT have: shard-resident state the dead
    /// worker built this run (packed batches, evaluator segments, session
    /// slots) — see the module docs for what that implies.
    pub fn revive(&self, shard: usize) -> Result<bool> {
        anyhow::ensure!(shard < self.n_shards, "no shard worker {shard}");
        if self.worker_alive(shard) {
            return Ok(false);
        }
        let mut workers = self.workers.borrow_mut();
        let w = &mut workers[shard];
        if let Some(h) = w.handle.take() {
            let _ = h.join();
        }
        let lane = LaneClient { tx: self.lanes[shard].tx.clone() };
        let (tx, rx) = mpsc::channel::<WorkerMsg>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let dir = self.dir.clone();
        let handle = thread::Builder::new()
            .name(format!("shard-{shard}"))
            .spawn(move || worker_main(rx, dir, ready_tx, lane, shard))
            .with_context(|| format!("respawning shard worker {shard}"))?;
        w.tx = tx;
        w.handle = Some(handle);
        ready_rx
            .recv()
            .map_err(|_| anyhow!("shard worker {shard} died again during supervised restart"))?
            .with_context(|| {
                format!("supervised restart of shard worker {shard}: engine reconstruction failed")
            })?;
        self.recoveries.set(self.recoveries.get() + 1);
        Ok(true)
    }

    /// Elastically move `machine` to `to_shard`: its sample stream — with
    /// any staged read-ahead folded back in draw order — migrates
    /// lane-to-lane, its stale device state is evicted from the old worker
    /// (if that worker still lives), and every subsequent non-pinned fan
    /// routes it to `to_shard`. Only call at a collective boundary; bits
    /// never change (per-machine partials are engine-independent and
    /// collectives join in fixed machine order), only wall-clock balance
    /// does. Overrides last until `clear_machines`.
    pub fn reassign_machine(&self, machine: usize, to_shard: usize) -> Result<()> {
        anyhow::ensure!(to_shard < self.n_shards, "no shard worker {to_shard}");
        let from = self.shard_of(machine);
        if from == to_shard {
            return Ok(());
        }
        let (reply, rx) = mpsc::channel();
        self.lanes[from]
            .tx
            .send(LaneCmd::Steal { machine, reply })
            .map_err(|_| anyhow!("prefetch lane {from} is gone"))?;
        let stolen = rx.recv().map_err(|_| anyhow!("prefetch lane {from} died during steal"))?;
        if let Some((stream, leftovers)) = stolen {
            self.lanes[to_shard]
                .tx
                .send(LaneCmd::Adopt { machine, stream, leftovers })
                .map_err(|_| anyhow!("prefetch lane {to_shard} is gone"))?;
        }
        // fire-and-forget eviction: a dead old worker has no state to
        // evict, and a live one must not serve the machine stale batches
        let _ = self.workers.borrow()[from].tx.send(WorkerMsg::Job(Box::new(move |state| {
            state.batches.remove(&machine);
        })));
        self.reassigned.borrow_mut().insert(machine, to_shard);
        Ok(())
    }

    /// [`FanBatch::wait`] with supervised healing: a batch lost to a
    /// worker death (dead reply channel, NOT a job error — job errors and
    /// contained panics pass straight through) is replayed instead of
    /// failing the run. First choice is [`ShardPool::revive`] + replay on
    /// the same shard; if the worker is unrecoverable, the dead shard's
    /// machines are reassigned round-robin over surviving shards
    /// ([`ShardPool::reassign_machine`]) and the batch replays under the
    /// new grouping — unless the batch is pinned, which cannot move (its
    /// state exists only on its packing shard). Results come back in
    /// ascending machine order either way, bit-identical to an
    /// uninterrupted run; only `recovery_counts` and wall-clock tell the
    /// difference.
    pub fn wait_elastic<T: Send + 'static>(&self, batch: FanBatch<T>) -> Result<Vec<(usize, T)>> {
        let FanBatch { machines, shard, label, pinned, pending, replay } = batch;
        if let Ok(res) = pending.rx.recv() {
            return res;
        }
        // the reply sender was dropped without sending: the worker loop
        // exited with the job queued or running — a worker death
        let replay = replay.ok_or_else(|| {
            anyhow!(
                "job '{label}' lost: shard worker {shard} is gone and the batch carries no \
                 replay recipe"
            )
        })?;
        match self.revive(shard) {
            Ok(_) => {
                self.replays.set(self.replays.get() + 1);
                replay.resubmit(self, shard, &label, &machines).wait().with_context(|| {
                    format!("replaying '{label}' after reviving shard worker {shard}")
                })
            }
            Err(revive_err) => {
                anyhow::ensure!(
                    !pinned,
                    "shard worker {shard} is unrecoverable ({revive_err:#}) and pinned batch \
                     '{label}' addresses state only that shard holds — it cannot be reassigned"
                );
                let survivors: Vec<usize> =
                    (0..self.n_shards).filter(|&s| s != shard && self.worker_alive(s)).collect();
                anyhow::ensure!(
                    !survivors.is_empty(),
                    "shard worker {shard} is unrecoverable ({revive_err:#}) and no surviving \
                     shard remains to adopt its machines"
                );
                for (k, &i) in machines.iter().enumerate() {
                    self.reassign_machine(i, survivors[k % survivors.len()])?;
                }
                self.replays.set(self.replays.get() + 1);
                // replay under the new grouping: every sub-batch submitted
                // before any wait, joined in shard order, reassembled in
                // machine order
                let mut groups: Vec<(usize, Vec<usize>)> = Vec::new();
                for &i in &machines {
                    let s = self.shard_of(i);
                    match groups.iter_mut().find(|(gs, _)| *gs == s) {
                        Some((_, ms)) => ms.push(i),
                        None => groups.push((s, vec![i])),
                    }
                }
                groups.sort_by_key(|&(s, _)| s);
                let pends: Vec<_> =
                    groups.iter().map(|(s, ms)| replay.resubmit(self, *s, &label, ms)).collect();
                let mut out = Vec::with_capacity(machines.len());
                for p in pends {
                    out.extend(p.wait().with_context(|| {
                        format!(
                            "replaying '{label}' after reassigning dead shard worker {shard}'s \
                             machines"
                        )
                    })?);
                }
                out.sort_by_key(|&(i, _)| i);
                Ok(out)
            }
        }
    }

    /// This run's recovery tally: `(supervised worker restarts, fan
    /// batches replayed)`. Both are REAL host events — they happen (or
    /// not) per execution, unlike the simulated fault schedule — and both
    /// reset at `clear_machines`. Surfaced on the run's `FaultMeter`.
    pub fn recovery_counts(&self) -> (u64, u64) {
        (self.recoveries.get(), self.replays.get())
    }

    /// Drop every shard-resident machine batch, sample stream (lane-side),
    /// staged pack, evaluator segment and session slot, and zero the stall
    /// and overlap meters (between runs: stale machine state from a
    /// previous experiment must not outlive it, and the wall-clock meters
    /// are per-run). Also the pool's healing point: dead workers are
    /// revived FIRST (so a kill in the previous run never leaks into the
    /// next), then the elastic overrides and recovery counters reset —
    /// pre-run healing is not a mid-run recovery.
    pub fn clear_machines(&self) -> Result<()> {
        for s in 0..self.n_shards {
            self.revive(s)?;
        }
        self.reassigned.borrow_mut().clear();
        self.recoveries.set(0);
        self.replays.set(0);
        let pends: Vec<Pending<()>> = (0..self.shards())
            .map(|s| {
                self.submit_named(s, "clear shard state", |state| {
                    state.batches.clear();
                    state.eval.clear();
                    state.stalls = StallMeter::default();
                    state.overlap = OverlapMeter::default();
                    state.engine.reset_session();
                    Ok(())
                })
            })
            .collect();
        for p in pends {
            p.wait()?;
        }
        for (s, lane) in self.lanes.iter().enumerate() {
            let (reply, rx) = mpsc::channel::<()>();
            lane.tx
                .send(LaneCmd::Clear { reply })
                .map_err(|_| anyhow!("prefetch lane {s} is gone"))?;
            rx.recv().map_err(|_| anyhow!("prefetch lane {s} died during clear"))?;
        }
        Ok(())
    }

    /// Per-shard diagnostics in shard order, ONE batched job per shard:
    /// engine traffic counters, stall meter and overlap meter travel
    /// together, and every gather job is submitted before any wait — a
    /// single channel round-trip per shard instead of one per meter per
    /// call.
    pub fn per_shard_metrics(&self) -> Result<Vec<ShardMetrics>> {
        let pends: Vec<Pending<ShardMetrics>> = (0..self.shards())
            .map(|s| {
                self.submit_named(s, "gather shard metrics", |state| {
                    Ok(ShardMetrics {
                        stats: state.engine.stats.clone(),
                        stalls: state.stalls.clone(),
                        overlap: state.overlap.clone(),
                        uploads: state.engine.upload_meter().clone(),
                        cache: state.engine.cache_meter().clone(),
                    })
                })
            })
            .collect();
        pends.into_iter().map(|p| p.wait()).collect()
    }

    /// Per-shard engine traffic counters, gathered in shard order.
    pub fn per_shard_stats(&self) -> Result<Vec<EngineStats>> {
        Ok(self.per_shard_metrics()?.into_iter().map(|m| m.stats).collect())
    }

    /// All shard engines' traffic counters merged into one [`EngineStats`]
    /// (the coordinator engine's stats are NOT included — add them for a
    /// whole-process view).
    pub fn gathered_stats(&self) -> Result<EngineStats> {
        let mut total = EngineStats::default();
        for s in self.per_shard_metrics()? {
            total.merge(&s.stats);
        }
        Ok(total)
    }

    /// Per-shard draw-staging counters (dispatch stall, stage hit/miss),
    /// gathered in shard order. Per-run: zeroed by `clear_machines`.
    pub fn per_shard_stalls(&self) -> Result<Vec<StallMeter>> {
        Ok(self.per_shard_metrics()?.into_iter().map(|m| m.stalls).collect())
    }

    /// All shards' stall meters folded into one cluster total.
    pub fn gathered_stalls(&self) -> Result<StallMeter> {
        let mut total = StallMeter::default();
        for s in self.per_shard_metrics()? {
            total.merge(&s.stalls);
        }
        Ok(total)
    }

    /// Per-shard batched-fan pipeline counters, gathered in shard order.
    /// Per-run: zeroed by `clear_machines`.
    pub fn per_shard_overlap(&self) -> Result<Vec<OverlapMeter>> {
        Ok(self.per_shard_metrics()?.into_iter().map(|m| m.overlap).collect())
    }

    /// All shards' overlap meters folded into one cluster total.
    pub fn gathered_overlap(&self) -> Result<OverlapMeter> {
        let mut total = OverlapMeter::default();
        for s in self.per_shard_metrics()? {
            total.merge(&s.overlap);
        }
        Ok(total)
    }

    /// The run recorder's gather: all three per-run wall-clock meters
    /// folded into cluster totals from ONE per-shard round-trip. The
    /// upload meter is the shard engines' total only — the recorder adds
    /// the coordinator engine's own meter on top.
    pub fn gathered_run_meters(&self) -> Result<(StallMeter, OverlapMeter, UploadMeter)> {
        let mut stalls = StallMeter::default();
        let mut overlap = OverlapMeter::default();
        let mut uploads = UploadMeter::default();
        for s in self.per_shard_metrics()? {
            stalls.merge(&s.stalls);
            overlap.merge(&s.overlap);
            uploads.merge(&s.uploads);
        }
        Ok((stalls, overlap, uploads))
    }

    /// All shard engines' executable-cache meters folded into one total.
    /// Cumulative for the pool's lifetime (NOT zeroed by
    /// `clear_machines` — warm executables outlive runs by design); the
    /// serve layer takes [`CacheMeter::since`] snapshots per job.
    pub fn gathered_cache(&self) -> Result<CacheMeter> {
        let mut total = CacheMeter::default();
        for s in self.per_shard_metrics()? {
            total.merge(&s.cache);
        }
        Ok(total)
    }

    /// Switch every shard engine's upload lane on or off (the resolved
    /// `upload=` policy; see `Engine::set_upload_lane`). The coordinator
    /// broadcasts this per run, right after `clear_machines` — the lane
    /// changes wall-clock staging only, never bits, so flipping it
    /// between runs is always safe.
    pub fn set_upload_lane(&self, on: bool) -> Result<()> {
        let pends: Vec<Pending<()>> = (0..self.shards())
            .map(|s| {
                self.submit_named(s, "set upload lane", move |state| {
                    state.engine.set_upload_lane(on);
                    Ok(())
                })
            })
            .collect();
        for p in pends {
            p.wait()?;
        }
        Ok(())
    }

    /// Cap every shard engine's resident compiled executables (the
    /// `serve.cache_capacity` key; see `Engine::set_exec_cache_capacity`).
    pub fn set_exec_cache_capacity(&self, cap: usize) -> Result<()> {
        let pends: Vec<Pending<()>> = (0..self.shards())
            .map(|s| {
                self.submit_named(s, "cap exec cache", move |state| {
                    state.engine.set_exec_cache_capacity(cap);
                    Ok(())
                })
            })
            .collect();
        for p in pends {
            p.wait()?;
        }
        Ok(())
    }
}

/// One shard's gathered diagnostic meters (see
/// [`ShardPool::per_shard_metrics`]): all host-side bookkeeping, no
/// engine state.
#[derive(Clone, Debug)]
pub struct ShardMetrics {
    pub stats: EngineStats,
    pub stalls: StallMeter,
    pub overlap: OverlapMeter,
    pub uploads: UploadMeter,
    pub cache: CacheMeter,
}

impl Drop for ShardPool {
    fn drop(&mut self) {
        // closing the channels ends the worker loops; workers first (they
        // hold lane clients and may have takes in flight), then the lanes
        let workers = self.workers.get_mut();
        for w in workers.iter_mut() {
            let (dead_tx, _) = mpsc::channel::<WorkerMsg>();
            w.tx = dead_tx; // drop the live sender
        }
        for w in workers.iter_mut() {
            if let Some(h) = w.handle.take() {
                let _ = h.join();
            }
        }
        for l in &mut self.lanes {
            let (dead_tx, _) = mpsc::channel::<LaneCmd>();
            l.tx = dead_tx;
        }
        for l in &mut self.lanes {
            if let Some(h) = l.handle.take() {
                let _ = h.join();
            }
        }
    }
}

fn panic_message(payload: &dyn std::any::Any) -> &str {
    if let Some(s) = payload.downcast_ref::<&'static str>() {
        s
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.as_str()
    } else {
        "non-string panic payload"
    }
}

fn worker_main(
    rx: mpsc::Receiver<WorkerMsg>,
    dir: PathBuf,
    ready: mpsc::Sender<Result<()>>,
    lane: LaneClient,
    device_index: usize,
) {
    let engine = match Engine::new_on_device(&dir, device_index) {
        Ok(e) => e,
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };
    let _ = ready.send(Ok(()));
    let mut state = ShardState {
        engine,
        batches: HashMap::new(),
        eval: HashMap::new(),
        lane,
        stalls: StallMeter::default(),
        overlap: OverlapMeter::default(),
    };
    while let Ok(msg) = rx.recv() {
        match msg {
            WorkerMsg::Job(job) => job(&mut state),
            // fault injection: exit like a hard crash — every queued job
            // (and its reply sender) drops unran
            WorkerMsg::Die => return,
        }
    }
}

#[cfg(test)]
mod tests {
    // ShardPool needs compiled artifacts; behavioural coverage lives in
    // rust/tests/shard_parity.rs and rust/tests/prefetch_parity.rs. The
    // prefetch lane is host-only (no engine), so its staging protocol is
    // fully testable here.
    use super::*;
    use crate::data::sampler::VecStream;
    use crate::data::synth::{SynthSpec, SynthStream};
    use crate::data::Loss;
    use crate::util::prng::Prng;

    fn spawn_lane() -> (LaneClient, thread::JoinHandle<()>) {
        let (tx, rx) = mpsc::channel::<LaneCmd>();
        let h = thread::spawn(move || lane_main(rx));
        (LaneClient { tx }, h)
    }

    fn block_ys(blocks: &[Block]) -> Vec<f32> {
        blocks.iter().flat_map(|b| b.y[..b.valid].to_vec()).collect()
    }

    fn ys(samples: &[Sample]) -> Vec<f32> {
        samples.iter().map(|s| s.y).collect()
    }

    fn tiny_epoch_stream() -> VecStream {
        let samples: Vec<Sample> =
            (0..5).map(|i| Sample { x: vec![i as f32], y: i as f32 }).collect();
        VecStream::epoch_bounded(samples, Loss::Squared, Prng::seed_from_u64(11))
    }

    #[test]
    fn lane_thread_serves_the_exact_draw_sequence() {
        let (client, h) = spawn_lane();
        client
            .tx
            .send(LaneCmd::Install(3, Box::new(SynthStream::new(SynthSpec::least_squares(8), 42))))
            .unwrap();
        let mut reference = SynthStream::new(SynthSpec::least_squares(8), 42);
        let mut first_hit = true;
        for _ in 0..5 {
            let reply = client.take(3, 300, 8, true).unwrap();
            let want = reference.draw_many(300);
            assert_eq!(reply.drawn as usize, want.len());
            assert_eq!(block_ys(&reply.blocks), ys(&want));
            if first_hit {
                assert!(!reply.hit, "the first take is a cold miss");
                first_hit = false;
            }
        }
        drop(client);
        h.join().unwrap();
    }

    #[test]
    fn lane_thread_resplits_mismatched_sizes_bit_exactly() {
        // whether each take lands on a warm stage (leftover re-split) or a
        // cold one (synchronous draw) is timing-dependent; EITHER path must
        // serve the exact draw sequence of a lane-less stream
        let (client, h) = spawn_lane();
        client
            .tx
            .send(LaneCmd::Install(0, Box::new(SynthStream::new(SynthSpec::least_squares(4), 7))))
            .unwrap();
        let mut reference = SynthStream::new(SynthSpec::least_squares(4), 7);
        for &n in &[300usize, 100, 37, 300, 513] {
            let reply = client.take(0, n, 4, true).unwrap();
            let want = reference.draw_many(n);
            assert_eq!(reply.drawn as usize, n);
            assert_eq!(block_ys(&reply.blocks), ys(&want), "request size {n}");
        }
        drop(client);
        h.join().unwrap();
    }

    #[test]
    fn warm_stage_hits_and_serves_identical_samples() {
        let mut st = LaneState::default();
        st.handle(LaneCmd::Install(0, Box::new(SynthStream::new(SynthSpec::least_squares(4), 9))));
        let mut reference = SynthStream::new(SynthSpec::least_squares(4), 9);
        let r1 = st.serve_take(0, 10, 4).unwrap();
        assert!(!r1.hit, "cold stage draws synchronously");
        assert_eq!(block_ys(&r1.blocks), ys(&reference.draw_many(10)));
        st.refill(0, 10, 4);
        let r2 = st.serve_take(0, 10, 4).unwrap();
        assert!(r2.hit, "refilled stage serves warm");
        assert_eq!(block_ys(&r2.blocks), ys(&reference.draw_many(10)));
    }

    #[test]
    fn mismatched_resplit_on_decomposable_stream_preserves_order() {
        let mut st = LaneState::default();
        st.handle(LaneCmd::Install(0, Box::new(SynthStream::new(SynthSpec::least_squares(4), 5))));
        let mut reference = SynthStream::new(SynthSpec::least_squares(4), 5);
        // stage 300, consume 100 (leftovers keep 200), restage 100 from
        // leftovers, then mismatch again — the push-back must go to the
        // FRONT so the remaining leftover suffix stays behind it
        st.refill(0, 300, 4);
        let r1 = st.serve_take(0, 100, 4).unwrap();
        assert!(!r1.hit);
        assert_eq!(block_ys(&r1.blocks), ys(&reference.draw_many(100)));
        st.refill(0, 100, 4);
        let r2 = st.serve_take(0, 37, 4).unwrap();
        assert_eq!(block_ys(&r2.blocks), ys(&reference.draw_many(37)));
        let r3 = st.serve_take(0, 400, 4).unwrap();
        assert_eq!(block_ys(&r3.blocks), ys(&reference.draw_many(400)));
    }

    #[test]
    fn epoch_bounded_streams_stage_short_batches_exactly() {
        let mut st = LaneState::default();
        st.handle(LaneCmd::Install(1, Box::new(tiny_epoch_stream())));
        let mut reference = tiny_epoch_stream();
        // 5 samples drawn 3 at a time: 3, short 2, fresh epoch's 3 — the
        // warm stage carries the short batch with its honest drawn count
        for round in 0..4 {
            st.refill(1, 3, 4);
            let reply = st.serve_take(1, 3, 4).unwrap();
            let want = reference.draw_many(3);
            assert!(reply.hit || round == 0);
            assert_eq!(reply.drawn as usize, want.len());
            assert_eq!(block_ys(&reply.blocks), ys(&want), "round {round}");
        }
    }

    #[test]
    fn mismatched_resplit_of_epoch_batched_stream_errors() {
        let mut st = LaneState::default();
        st.handle(LaneCmd::Install(0, Box::new(tiny_epoch_stream())));
        st.refill(0, 3, 4);
        let err = st.serve_take(0, 2, 4).unwrap_err().to_string();
        assert!(err.contains("prefetch=off"), "{err}");
    }

    #[test]
    fn refill_never_overwrites_a_live_stage() {
        let mut st = LaneState::default();
        st.handle(LaneCmd::Install(0, Box::new(SynthStream::new(SynthSpec::least_squares(4), 3))));
        let mut reference = SynthStream::new(SynthSpec::least_squares(4), 3);
        st.refill(0, 8, 4);
        st.refill(0, 8, 4); // dropped: the first stage is still warm
        assert_eq!(block_ys(&st.serve_take(0, 8, 4).unwrap().blocks), ys(&reference.draw_many(8)));
        assert_eq!(block_ys(&st.serve_take(0, 8, 4).unwrap().blocks), ys(&reference.draw_many(8)));
    }

    #[test]
    fn clear_drops_streams_stages_and_queued_refills() {
        let mut st = LaneState::default();
        st.handle(LaneCmd::Install(0, Box::new(SynthStream::new(SynthSpec::least_squares(4), 1))));
        st.refill(0, 4, 4);
        st.want.push_back((0, 4, 4));
        let (reply, rx) = mpsc::channel();
        st.handle(LaneCmd::Clear { reply });
        rx.recv().unwrap();
        assert!(st.streams.is_empty() && st.staged.is_empty() && st.want.is_empty());
        let err = st.serve_take(0, 4, 4).unwrap_err().to_string();
        assert!(err.contains("no stream"), "{err}");
    }

    #[test]
    fn pipelined_request_collect_serves_the_serial_draw_order() {
        // the pipelined fan's protocol: request(k+1) is issued after
        // collect(k) but BEFORE machine k's pack is consumed; the lane
        // must serve the identical per-machine sequences a serial
        // take-loop would, interleaving or not
        let (client, h) = spawn_lane();
        for i in 0..2usize {
            client
                .tx
                .send(LaneCmd::Install(
                    i,
                    Box::new(SynthStream::new(SynthSpec::least_squares(4), 100 + i as u64)),
                ))
                .unwrap();
        }
        let mut refs: Vec<SynthStream> =
            (0..2).map(|i| SynthStream::new(SynthSpec::least_squares(4), 100 + i as u64)).collect();
        for _round in 0..3 {
            // one-deep window over machines [0, 1], like the batched fan
            let mut pending = Some(client.request(0, 50, 4, true).unwrap());
            for i in 0..2usize {
                let reply = pending.take().unwrap().collect().unwrap();
                if i + 1 < 2 {
                    pending = Some(client.request(i + 1, 50, 4, true).unwrap());
                }
                assert_eq!(reply.drawn, 50);
                assert_eq!(block_ys(&reply.blocks), ys(&refs[i].draw_many(50)), "machine {i}");
            }
        }
        drop(client);
        h.join().unwrap();
    }

    #[test]
    fn take_is_request_then_collect() {
        let (client, h) = spawn_lane();
        client
            .tx
            .send(LaneCmd::Install(0, Box::new(SynthStream::new(SynthSpec::least_squares(4), 17))))
            .unwrap();
        let mut reference = SynthStream::new(SynthSpec::least_squares(4), 17);
        let r1 = client.take(0, 20, 4, false).unwrap();
        assert_eq!(block_ys(&r1.blocks), ys(&reference.draw_many(20)));
        let r2 = client.request(0, 20, 4, false).unwrap().collect().unwrap();
        assert_eq!(block_ys(&r2.blocks), ys(&reference.draw_many(20)));
        drop(client);
        h.join().unwrap();
    }

    #[test]
    fn steal_then_adopt_preserves_the_draw_position_bit_exactly() {
        // machine 2 lives on lane A with a warm stage and a leftover
        // suffix; stealing folds the stage back to the FRONT of the
        // leftovers, and the adopted lane must continue the exact
        // lane-less draw sequence
        let mut a = LaneState::default();
        a.handle(LaneCmd::Install(2, Box::new(SynthStream::new(SynthSpec::least_squares(4), 21))));
        let mut reference = SynthStream::new(SynthSpec::least_squares(4), 21);
        a.refill(2, 300, 4);
        let r1 = a.serve_take(2, 100, 4).unwrap(); // leaves 200 leftovers
        assert_eq!(block_ys(&r1.blocks), ys(&reference.draw_many(100)));
        a.refill(2, 50, 4); // stages 50 drawn FROM the leftovers
        a.want.push_back((2, 50, 4));
        let (reply, rx) = mpsc::channel();
        a.handle(LaneCmd::Steal { machine: 2, reply });
        let (stream, leftovers) = rx.recv().unwrap().expect("machine 2 had a stream");
        assert!(a.streams.is_empty() && a.staged.is_empty() && a.want.is_empty());
        let mut b = LaneState::default();
        b.handle(LaneCmd::Adopt { machine: 2, stream, leftovers });
        for &n in &[75usize, 300] {
            let r = b.serve_take(2, n, 4).unwrap();
            assert_eq!(block_ys(&r.blocks), ys(&reference.draw_many(n)), "post-adopt take {n}");
        }
    }

    #[test]
    fn steal_of_an_unknown_machine_replies_none() {
        let mut st = LaneState::default();
        let (reply, rx) = mpsc::channel();
        st.handle(LaneCmd::Steal { machine: 9, reply });
        assert!(rx.recv().unwrap().is_none());
    }

    #[test]
    fn panic_messages_downcast() {
        assert_eq!(panic_message(&"boom"), "boom");
        assert_eq!(panic_message(&String::from("kaboom")), "kaboom");
        assert_eq!(panic_message(&42usize), "non-string panic payload");
    }

    #[test]
    fn shard_of_is_a_partition() {
        // construction without artifacts fails cleanly, so test the
        // partition arithmetic through a throwaway modulus
        for shards in 1..5usize {
            for i in 0..20usize {
                assert!(i % shards < shards);
            }
        }
    }

    #[test]
    fn pool_construction_fails_without_artifacts() {
        let err = ShardPool::new(2, Path::new("/nonexistent/artifacts"));
        assert!(err.is_err());
    }
}
