//! ShardPool: the engine-per-worker shard plane.
//!
//! PJRT handles are not `Send`, so device state can never migrate between
//! threads. The shard plane therefore gives every worker thread its *own*
//! [`Engine`] (constructed on the worker, from the same artifacts dir as
//! the coordinator's) plus a shard-local store of machine state, and the
//! coordinator ships only **host** data across the boundary: job closures
//! in, `Vec<f32>` partials and meter deltas out.
//!
//! # Engine affinity
//!
//! Machines are partitioned machine -> shard once, at pool construction
//! (`shard_of(i) = i % shards`). ALL of a machine's state — its sample
//! stream (installed at context construction; the draw verb generates
//! and packs shard-side), its packed
//! [`crate::objective::MachineBatch`], its session-pool slots, any
//! chained [`super::DeviceVec`] intermediates — lives on its shard for
//! the machine's whole lifetime. A job for machine `i` is only ever
//! submitted to `shard_of(i)`, so the affinity rule is structural: there
//! is no API through which a buffer could reach another thread.
//!
//! # Join points and determinism
//!
//! Each shard runs its jobs strictly in submission order (one mpsc
//! channel per worker), and the coordinator submits machine jobs in
//! machine order, so the per-shard execution order is a deterministic
//! function of the machine->shard partition — never of thread timing.
//! Fan-outs join only at collectives: the coordinator waits for every
//! machine's partial *in fixed machine order* and reduces them in f64 on
//! the host (`comm::Network`), which is the same operation sequence the
//! sequential path performs — results are bit-identical for every shard
//! count. See `objective::fan_machines` for the fan/join helper.

use super::{Engine, EngineStats};
use anyhow::{anyhow, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::mpsc;
use std::thread;

/// Everything a worker thread owns: its private engine, the device state
/// of the machines assigned to its shard, and those machines' sample
/// streams (the DataPlane's shard-resident side). Lives on the worker
/// thread only — jobs receive `&mut ShardState` and must keep it there.
pub struct ShardState {
    pub engine: Engine,
    /// machine id -> that machine's current packed batch (replaced on
    /// every fresh draw; cleared between runs)
    pub batches: HashMap<usize, crate::objective::MachineBatch>,
    /// machine id -> that machine's sample stream, installed at context
    /// construction (cleared between runs). The plane's draw verb
    /// advances it and packs the drawn samples here, on this engine — no
    /// coordinator-side sample materialization for shard-owned machines.
    pub streams: HashMap<usize, Box<dyn crate::data::SampleStream>>,
    /// held-out evaluator segments owned by this shard (segment id ->
    /// grad-only batch; packed once per run context, cleared between
    /// runs) — the sharded `Evaluator` fan reads these
    pub eval: HashMap<usize, crate::objective::MachineBatch>,
}

impl ShardState {
    /// The machine's current batch alongside the engine (split borrow, so
    /// the job can dispatch against it).
    pub fn machine(&mut self, i: usize) -> Result<(&mut Engine, &crate::objective::MachineBatch)> {
        let batch = self
            .batches
            .get(&i)
            .ok_or_else(|| anyhow!("machine {i} has no batch on this shard (draw first)"))?;
        Ok((&mut self.engine, batch))
    }

    /// Evaluator segment `i`'s batch alongside the engine.
    pub fn eval_segment(
        &mut self,
        i: usize,
    ) -> Result<(&mut Engine, &crate::objective::MachineBatch)> {
        let batch = self
            .eval
            .get(&i)
            .ok_or_else(|| anyhow!("evaluator segment {i} is not resident on this shard"))?;
        Ok((&mut self.engine, batch))
    }
}

type Job = Box<dyn FnOnce(&mut ShardState) + Send + 'static>;

/// A submitted job's typed reply. `wait` blocks until the worker ran the
/// closure (or died); join fan-outs in machine order for determinism.
pub struct Pending<T> {
    rx: mpsc::Receiver<Result<T>>,
}

impl<T> Pending<T> {
    pub fn wait(self) -> Result<T> {
        self.rx
            .recv()
            .map_err(|_| anyhow!("shard worker died before replying (panicked job?)"))?
    }
}

struct Worker {
    tx: mpsc::Sender<Job>,
    handle: Option<thread::JoinHandle<()>>,
}

/// A fixed pool of worker threads, each owning one [`Engine`] (see module
/// docs). Dropping the pool shuts the workers down and joins them.
pub struct ShardPool {
    workers: Vec<Worker>,
}

impl ShardPool {
    /// Spawn `shards` workers, each constructing its own engine from
    /// `artifacts_dir` *on its thread*. Fails if any engine fails to load
    /// (the pool is torn down cleanly in that case).
    pub fn new(shards: usize, artifacts_dir: &Path) -> Result<ShardPool> {
        anyhow::ensure!(shards >= 1, "shard pool needs at least one worker");
        let mut workers = Vec::with_capacity(shards);
        let mut readies = Vec::with_capacity(shards);
        for s in 0..shards {
            let (tx, rx) = mpsc::channel::<Job>();
            let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
            let dir: PathBuf = artifacts_dir.to_path_buf();
            let handle = thread::Builder::new()
                .name(format!("shard-{s}"))
                .spawn(move || worker_main(rx, dir, ready_tx))
                .with_context(|| format!("spawning shard worker {s}"))?;
            workers.push(Worker { tx, handle: Some(handle) });
            readies.push(ready_rx);
        }
        let pool = ShardPool { workers };
        for (s, ready) in readies.into_iter().enumerate() {
            ready
                .recv()
                .map_err(|_| anyhow!("shard worker {s} died during startup"))?
                .with_context(|| format!("shard worker {s}: engine construction failed"))?;
        }
        Ok(pool)
    }

    /// Number of worker shards.
    pub fn shards(&self) -> usize {
        self.workers.len()
    }

    /// The fixed machine->shard partition (decided at construction).
    pub fn shard_of(&self, machine: usize) -> usize {
        machine % self.workers.len()
    }

    /// Enqueue `f` on `shard`; returns immediately with the typed reply
    /// handle. Jobs on one shard run strictly in submission order.
    pub fn submit<T: Send + 'static>(
        &self,
        shard: usize,
        f: impl FnOnce(&mut ShardState) -> Result<T> + Send + 'static,
    ) -> Pending<T> {
        let (tx, rx) = mpsc::channel::<Result<T>>();
        let job: Job = Box::new(move |state| {
            let _ = tx.send(f(state));
        });
        // a dead worker drops the job (and with it the reply sender), so
        // `wait` surfaces the failure instead of hanging
        let _ = self.workers[shard].tx.send(job);
        Pending { rx }
    }

    /// Submit to the shard owning `machine` and block for the result.
    pub fn run_on_machine<T: Send + 'static>(
        &self,
        machine: usize,
        f: impl FnOnce(&mut ShardState) -> Result<T> + Send + 'static,
    ) -> Result<T> {
        self.submit(self.shard_of(machine), f).wait()
    }

    /// Drop every shard-resident machine batch, sample stream, evaluator
    /// segment and session slot (between runs: stale machine state from a
    /// previous experiment must not outlive it).
    pub fn clear_machines(&self) -> Result<()> {
        let pends: Vec<Pending<()>> = (0..self.shards())
            .map(|s| {
                self.submit(s, |state| {
                    state.batches.clear();
                    state.streams.clear();
                    state.eval.clear();
                    state.engine.reset_session();
                    Ok(())
                })
            })
            .collect();
        for p in pends {
            p.wait()?;
        }
        Ok(())
    }

    /// Per-shard engine traffic counters, gathered in shard order.
    pub fn per_shard_stats(&self) -> Result<Vec<EngineStats>> {
        let pends: Vec<Pending<EngineStats>> = (0..self.shards())
            .map(|s| self.submit(s, |state| Ok(state.engine.stats.clone())))
            .collect();
        pends.into_iter().map(|p| p.wait()).collect()
    }

    /// All shard engines' traffic counters merged into one [`EngineStats`]
    /// (the coordinator engine's stats are NOT included — add them for a
    /// whole-process view).
    pub fn gathered_stats(&self) -> Result<EngineStats> {
        let mut total = EngineStats::default();
        for s in self.per_shard_stats()? {
            total.merge(&s);
        }
        Ok(total)
    }
}

impl Drop for ShardPool {
    fn drop(&mut self) {
        // closing the channels ends the worker loops; then join
        for w in &mut self.workers {
            let (dead_tx, _) = mpsc::channel::<Job>();
            w.tx = dead_tx; // drop the live sender
        }
        for w in &mut self.workers {
            if let Some(h) = w.handle.take() {
                let _ = h.join();
            }
        }
    }
}

fn worker_main(rx: mpsc::Receiver<Job>, dir: PathBuf, ready: mpsc::Sender<Result<()>>) {
    let engine = match Engine::new(&dir) {
        Ok(e) => e,
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };
    let _ = ready.send(Ok(()));
    let mut state = ShardState {
        engine,
        batches: HashMap::new(),
        streams: HashMap::new(),
        eval: HashMap::new(),
    };
    while let Ok(job) = rx.recv() {
        job(&mut state);
    }
}

#[cfg(test)]
mod tests {
    // ShardPool needs compiled artifacts; behavioural coverage lives in
    // rust/tests/shard_parity.rs. The pure helpers are testable here.
    use super::*;

    #[test]
    fn shard_of_is_a_partition() {
        // construction without artifacts fails cleanly, so test the
        // partition arithmetic through a throwaway modulus
        for shards in 1..5usize {
            for i in 0..20usize {
                assert!(i % shards < shards);
            }
        }
    }

    #[test]
    fn pool_construction_fails_without_artifacts() {
        let err = ShardPool::new(2, Path::new("/nonexistent/artifacts"));
        assert!(err.is_err());
    }
}
