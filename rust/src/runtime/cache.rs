//! Content-addressed caches for the runtime layer.
//!
//! Two cache surfaces live here, both metered by
//! [`accounting::CacheMeter`](crate::accounting::CacheMeter):
//!
//! - [`ExecCache`]: the engine's compiled-executable cache, keyed by the
//!   **content hash** of an artifact — [`artifact_key`] hashes the lowered
//!   HLO-text bytes plus the canonical manifest entry (kind, loss, dim,
//!   block, fuse width, chained flag, argument shapes, outputs, sha256).
//!   The artifact *name* and *file path* are deliberately excluded: two
//!   manifest entries with identical content share one compiled
//!   executable, and re-lowering an artifact to byte-identical HLO keeps
//!   its cache entry valid. A capacity cap (the `serve.cache_capacity`
//!   key) evicts in insertion order; an evicted entry recompiles on next
//!   use — correct, just cold again.
//! - [`KeyedCache`]: a small LRU map for **warm instances** (the serve
//!   layer's resident `Runner`/`ShardPool`s), keyed by the canonical
//!   serialization [`pool_key`] of the cache-relevant config subset:
//!   artifacts-dir hash ([`manifest_hash`]), shard count, and the
//!   plane/prefetch/pipeline/upload policies. Everything else (method, b_local,
//!   seed, scenario, ...) is per-run state the resident instance replays
//!   from scratch, so it is excluded from the key on purpose.
//!
//! Neither cache touches the paper's simulated cost model: a warm run is
//! bit-identical to a cold one in iterates, curves and paper-unit meters
//! (`rust/tests/serve_parity.rs`), and the meter records wall-clock
//! compile time only.

use crate::accounting::CacheMeter;
use crate::runtime::artifact::{ArtifactMeta, Manifest};
use crate::runtime::plane::{PipelinePolicy, PlanePolicy, PrefetchPolicy, UploadPolicy};
use crate::util::hash::Fnv64;
use anyhow::{Context, Result};
use std::collections::{HashMap, VecDeque};

/// Content hash of one artifact: the lowered HLO bytes + the canonical
/// manifest entry. Name/path excluded — see the module doc.
pub fn artifact_key(meta: &ArtifactMeta) -> Result<u64> {
    let bytes = std::fs::read(&meta.file)
        .with_context(|| format!("hashing artifact {}", meta.file.display()))?;
    let mut h = Fnv64::new();
    h.field(&bytes);
    h.field(canonical_meta(meta).as_bytes());
    Ok(h.finish())
}

/// Canonical (order-stable, unambiguous) serialization of the
/// cache-relevant manifest fields.
fn canonical_meta(meta: &ArtifactMeta) -> String {
    let shapes: Vec<String> = meta
        .arg_shapes
        .iter()
        .map(|s| s.iter().map(usize::to_string).collect::<Vec<_>>().join("x"))
        .collect();
    format!(
        "kind={:?};loss={};d={};block={};k={};chained={};args={};outs={};sha256={}",
        meta.kind,
        meta.loss,
        meta.d,
        meta.block,
        meta.k,
        meta.chained,
        shapes.join(","),
        meta.outputs.join(","),
        meta.sha256,
    )
}

/// Content hash of a whole artifacts directory: every artifact's
/// (name, content key), folded in manifest order with the block size and
/// dim table. Identifies "the same lowered artifact set" across
/// processes — the first component of [`pool_key`].
pub fn manifest_hash(m: &Manifest) -> Result<u64> {
    let mut h = Fnv64::new();
    h.field(&(m.block as u64).to_le_bytes());
    for d in &m.dims {
        h.field(&(*d as u64).to_le_bytes());
    }
    for a in &m.artifacts {
        h.field(a.name.as_bytes());
        h.field(&artifact_key(a)?.to_le_bytes());
    }
    Ok(h.finish())
}

/// Canonical serialization of the cache-relevant config subset a warm
/// `Engine`/`ShardPool` instance is keyed by. Stable field order, exact
/// value formatting — two configs that agree on this subset may share a
/// warm instance (bit-parity across planes/policies is unconditional, so
/// nothing else about a run can invalidate the instance).
pub fn pool_key(
    manifest_hash: u64,
    shards: usize,
    plane: PlanePolicy,
    prefetch: PrefetchPolicy,
    pipeline: PipelinePolicy,
    upload: UploadPolicy,
) -> String {
    format!(
        "artifacts={manifest_hash:016x};shards={shards};plane={};prefetch={};pipeline={};upload={}",
        plane.as_str(),
        prefetch.as_str(),
        pipeline.as_str(),
        upload.as_str(),
    )
}

/// The engine's compiled-executable cache: content key -> compiled
/// executable, with an optional capacity cap (insertion-order eviction)
/// and a [`CacheMeter`]. The meter is cumulative for the life of the
/// engine; per-job views are taken with [`CacheMeter::since`] snapshots.
pub struct ExecCache {
    map: HashMap<u64, xla::PjRtLoadedExecutable>,
    order: VecDeque<u64>,
    cap: Option<usize>,
    pub meter: CacheMeter,
}

impl Default for ExecCache {
    fn default() -> Self {
        ExecCache::new()
    }
}

impl ExecCache {
    pub fn new() -> ExecCache {
        ExecCache {
            map: HashMap::new(),
            order: VecDeque::new(),
            cap: None,
            meter: CacheMeter::default(),
        }
    }

    /// Cap the number of resident executables (>= 1). Entries past the
    /// cap evict in insertion order, metered as evictions.
    pub fn set_capacity(&mut self, cap: usize) {
        self.cap = Some(cap.max(1));
        self.shrink();
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn contains(&self, key: u64) -> bool {
        self.map.contains_key(&key)
    }

    pub fn get(&self, key: u64) -> Option<&xla::PjRtLoadedExecutable> {
        self.map.get(&key)
    }

    /// Insert a freshly compiled executable under its content key,
    /// recording the miss and evicting past the cap.
    pub fn insert(&mut self, key: u64, exe: xla::PjRtLoadedExecutable, compile_ns: u64) {
        self.meter.record_miss(compile_ns);
        if self.map.insert(key, exe).is_none() {
            self.order.push_back(key);
        }
        self.shrink();
    }

    fn shrink(&mut self) {
        if let Some(cap) = self.cap {
            while self.map.len() > cap {
                match self.order.pop_front() {
                    Some(old) => {
                        if self.map.remove(&old).is_some() {
                            self.meter.record_eviction();
                        }
                    }
                    None => break,
                }
            }
        }
    }
}

/// A small LRU cache of warm values keyed by canonical strings (the serve
/// layer's resident `Runner` instances under [`pool_key`]). Generic so the
/// policy is unit-testable without building engines.
pub struct KeyedCache<V> {
    entries: Vec<(String, V)>,
    cap: usize,
    pub meter: CacheMeter,
}

impl<V> KeyedCache<V> {
    /// `cap` is clamped to >= 1 (a zero-capacity warm cache would rebuild
    /// every lookup and defeat the resident-service design).
    pub fn new(cap: usize) -> KeyedCache<V> {
        KeyedCache { entries: Vec::new(), cap: cap.max(1), meter: CacheMeter::default() }
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// The warm value for `key`, building (and timing) it on a miss.
    /// Recently used entries survive the cap; the least recently used is
    /// evicted past it.
    pub fn get_or_try_insert_with(
        &mut self,
        key: &str,
        build: impl FnOnce() -> Result<V>,
    ) -> Result<&mut V> {
        if let Some(pos) = self.entries.iter().position(|(k, _)| k == key) {
            self.meter.record_hit();
            let entry = self.entries.remove(pos);
            self.entries.push(entry); // most recently used last
        } else {
            let t0 = std::time::Instant::now();
            let v = build()?;
            self.meter.record_miss(t0.elapsed().as_nanos() as u64);
            self.entries.push((key.to_string(), v));
            while self.entries.len() > self.cap {
                self.entries.remove(0);
                self.meter.record_eviction();
            }
        }
        Ok(&mut self.entries.last_mut().unwrap().1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pool_key_is_canonical_and_policy_sensitive() {
        let k = pool_key(
            0xabc,
            4,
            PlanePolicy::Auto,
            PrefetchPolicy::On,
            PipelinePolicy::Off,
            UploadPolicy::On,
        );
        assert_eq!(
            k,
            "artifacts=0000000000000abc;shards=4;plane=auto;prefetch=on;pipeline=off;upload=on"
        );
        let k2 = pool_key(
            0xabc,
            4,
            PlanePolicy::Auto,
            PrefetchPolicy::On,
            PipelinePolicy::On,
            UploadPolicy::On,
        );
        assert_ne!(k, k2, "policy is part of the cache-relevant subset");
        let k3 = pool_key(
            0xabc,
            4,
            PlanePolicy::Auto,
            PrefetchPolicy::On,
            PipelinePolicy::Off,
            UploadPolicy::Off,
        );
        assert_ne!(k, k3, "the upload policy is part of the cache-relevant subset");
    }

    #[test]
    fn keyed_cache_hits_misses_and_evicts_lru() {
        let mut c: KeyedCache<usize> = KeyedCache::new(2);
        assert!(c.is_empty());
        assert_eq!(*c.get_or_try_insert_with("a", || Ok(1)).unwrap(), 1);
        assert_eq!(*c.get_or_try_insert_with("b", || Ok(2)).unwrap(), 2);
        // warm hit does not rebuild
        assert_eq!(*c.get_or_try_insert_with("a", || panic!("must not build")).unwrap(), 1);
        assert_eq!(c.meter.hits, 1);
        assert_eq!(c.meter.misses, 2);
        // "b" is now least recently used: inserting "c" evicts it
        assert_eq!(*c.get_or_try_insert_with("c", || Ok(3)).unwrap(), 3);
        assert_eq!(c.meter.evictions, 1);
        assert_eq!(c.len(), 2);
        assert_eq!(*c.get_or_try_insert_with("b", || Ok(22)).unwrap(), 22, "b was evicted");
    }

    #[test]
    fn keyed_cache_build_errors_do_not_poison() {
        let mut c: KeyedCache<usize> = KeyedCache::new(2);
        assert!(c.get_or_try_insert_with("a", || anyhow::bail!("boom")).is_err());
        assert!(c.is_empty());
        assert_eq!(*c.get_or_try_insert_with("a", || Ok(7)).unwrap(), 7);
    }

    #[test]
    fn keyed_cache_capacity_clamps_to_one() {
        let mut c: KeyedCache<usize> = KeyedCache::new(0);
        c.get_or_try_insert_with("a", || Ok(1)).unwrap();
        assert_eq!(c.len(), 1, "cap 0 clamps to 1: the resident value survives");
    }

    fn meta_fixture(dir: &std::path::Path, file: &str, body: &str) -> ArtifactMeta {
        std::fs::create_dir_all(dir).unwrap();
        let path = dir.join(file);
        std::fs::write(&path, body).unwrap();
        ArtifactMeta {
            name: "grad_sq_d2".into(),
            file: path,
            kind: crate::runtime::ArtifactKind::Grad,
            loss: "sq".into(),
            d: 2,
            block: 8,
            arg_shapes: vec![vec![8, 2], vec![8], vec![8], vec![2]],
            outputs: vec!["grad_sum".into(), "loss_sum".into(), "count".into()],
            k: 1,
            chained: false,
            sha256: "x".into(),
        }
    }

    #[test]
    fn artifact_key_is_content_addressed() {
        let dir = std::env::temp_dir().join("mbprox_cache_test_key");
        let a = meta_fixture(&dir, "a.hlo.txt", "HloModule m1");
        let k1 = artifact_key(&a).unwrap();
        // same content under a different NAME and PATH: same key
        let mut b = meta_fixture(&dir, "b.hlo.txt", "HloModule m1");
        b.name = "grad_sq_d2_alias".into();
        assert_eq!(artifact_key(&b).unwrap(), k1, "name/path are not content");
        // different bytes: different key
        let c = meta_fixture(&dir, "c.hlo.txt", "HloModule m2");
        assert_ne!(artifact_key(&c).unwrap(), k1);
        // different manifest entry over the same bytes: different key
        let mut d = meta_fixture(&dir, "a.hlo.txt", "HloModule m1");
        d.k = 4;
        assert_ne!(artifact_key(&d).unwrap(), k1);
        // a missing file is an error, not a silent hash of nothing
        let mut gone = meta_fixture(&dir, "a.hlo.txt", "HloModule m1");
        gone.file = dir.join("missing.hlo.txt");
        assert!(artifact_key(&gone).is_err());
    }
}
