//! ExecSession: a device buffer pool for the small per-call operands.
//!
//! The engine's block operands (`X`, `y`, `mask`) are uploaded once per
//! block and owned by the caller (`BlockLits`), but the *small* vectors —
//! the iterate `w`, the six DSVRG/SAGA sweep vectors, the CG direction —
//! used to be re-uploaded on every dispatch even when their contents had
//! not changed since the previous call. The session caches those uploads
//! in named slots: a slot re-uploads only when the host bytes differ from
//! what is already resident, so e.g. one outer round's iterate `w` is
//! uploaded exactly once no matter how many blocks it is dispatched
//! against (O(1) vector uploads per round instead of O(#blocks)).
//!
//! Identity is (slot name, content): slots are compared by exact *bit*
//! equality of the f32 payload (`to_bits`, so -0.0 != 0.0 and identical
//! NaN patterns match), which makes staleness impossible by construction
//! — a payload whose device bits would differ can never alias a cached
//! buffer. Each refresh bumps the slot's generation (surfaced for
//! tests/diagnostics).
//!
//! A slot can also **alias** an existing device buffer (a chained
//! dispatch's output handle) without any host copy or upload — the bridge
//! that lets device-resident [`super::chain::DeviceVec`]s flow into the
//! tupled artifacts' pooled-input signatures (e.g. evaluating the loss at
//! an iterate that never visited the host). An aliased slot has no host
//! bytes to compare against, so a later `ensure` with host data always
//! refreshes it.

use super::EngineStats;
use anyhow::{anyhow, Result};
use std::collections::HashMap;
use std::rc::Rc;

/// Exact bit equality (not float `==`): distinguishes -0.0 from 0.0 and
/// treats identical NaN patterns as equal — the device buffer holds bits,
/// not values.
fn bitwise_eq(a: &[f32], b: &[f32]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
}

struct Slot {
    /// host copy of the payload currently resident on device; `None` for
    /// aliased device buffers (no host bytes exist)
    host: Option<Vec<f32>>,
    buf: Rc<xla::PjRtBuffer>,
    generation: u64,
}

/// Named-slot upload cache (see module docs).
#[derive(Default)]
pub struct ExecSession {
    slots: HashMap<&'static str, Slot>,
}

impl ExecSession {
    pub fn new() -> ExecSession {
        ExecSession { slots: HashMap::new() }
    }

    /// Make `key` hold a device copy of `data`, re-uploading only when the
    /// contents changed. Traffic is charged to `stats`.
    pub fn ensure(
        &mut self,
        client: &xla::PjRtClient,
        stats: &mut EngineStats,
        key: &'static str,
        data: &[f32],
    ) -> Result<()> {
        if let Some(slot) = self.slots.get(key) {
            if slot.host.as_deref().is_some_and(|h| bitwise_eq(h, data)) {
                stats.upload_cache_hits += 1;
                return Ok(());
            }
        }
        let buf = client
            .buffer_from_host_buffer(data, &[data.len()], None)
            .map_err(|e| anyhow!("uploading slot '{key}' [{}]: {e:?}", data.len()))?;
        stats.uploads += 1;
        stats.upload_bytes += (data.len() * std::mem::size_of::<f32>()) as u64;
        stats.upload_cache_misses += 1;
        let generation = self.slots.get(key).map_or(1, |s| s.generation + 1);
        // the replaced buffer (if any) is dropped here — PJRT reclaims it
        // deterministically via the crate's Drop impl
        self.slots
            .insert(key, Slot { host: Some(data.to_vec()), buf: Rc::new(buf), generation });
        Ok(())
    }

    /// Make `key` alias an already-resident device buffer. Zero traffic:
    /// this is a handle install, not an upload (`stats.alias_installs`).
    /// The slot's generation still advances so staleness stays observable.
    pub fn alias(
        &mut self,
        stats: &mut EngineStats,
        key: &'static str,
        buf: Rc<xla::PjRtBuffer>,
    ) {
        stats.alias_installs += 1;
        let generation = self.slots.get(key).map_or(1, |s| s.generation + 1);
        self.slots.insert(key, Slot { host: None, buf, generation });
    }

    /// The device buffer currently resident in `key` (after `ensure`).
    pub fn get(&self, key: &'static str) -> Result<&xla::PjRtBuffer> {
        self.slots
            .get(key)
            .map(|s| s.buf.as_ref())
            .ok_or_else(|| anyhow!("session slot '{key}' is empty (ensure first)"))
    }

    /// Like [`ExecSession::get`] but returns a shared handle, so the
    /// caller can release the session borrow before building an input
    /// list that must coexist with other engine borrows.
    pub fn get_shared(&self, key: &'static str) -> Result<Rc<xla::PjRtBuffer>> {
        self.slots
            .get(key)
            .map(|s| Rc::clone(&s.buf))
            .ok_or_else(|| anyhow!("session slot '{key}' is empty (ensure first)"))
    }

    /// How many times `key` has been (re-)uploaded or aliased; 0 if never.
    pub fn generation(&self, key: &'static str) -> u64 {
        self.slots.get(key).map_or(0, |s| s.generation)
    }

    /// Drop one slot's device buffer.
    pub fn invalidate(&mut self, key: &'static str) {
        self.slots.remove(key);
    }

    /// Drop every cached buffer (e.g. between benchmark sections).
    pub fn clear(&mut self) {
        self.slots.clear();
    }

    pub fn len(&self) -> usize {
        self.slots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::bitwise_eq;

    #[test]
    fn bit_equality_semantics() {
        assert!(bitwise_eq(&[1.0, 2.0], &[1.0, 2.0]));
        assert!(!bitwise_eq(&[1.0], &[1.0, 2.0]));
        // float == would say these are equal; the device bits differ
        assert!(!bitwise_eq(&[0.0], &[-0.0]));
        // float == would say these differ; the device bits are identical
        assert!(bitwise_eq(&[f32::NAN], &[f32::NAN]));
    }
}
