//! ExecSession: a device buffer pool for the small per-call operands.
//!
//! The engine's block operands (`X`, `y`, `mask`) are uploaded once per
//! block and owned by the caller (`BlockLits`), but the *small* vectors —
//! the iterate `w`, the six DSVRG/SAGA sweep vectors, the CG direction —
//! used to be re-uploaded on every dispatch even when their contents had
//! not changed since the previous call. The session caches those uploads
//! in named slots: a slot re-uploads only when the host bytes differ from
//! what is already resident, so e.g. one outer round's iterate `w` is
//! uploaded exactly once no matter how many blocks it is dispatched
//! against (O(1) vector uploads per round instead of O(#blocks)).
//!
//! Identity is (slot name, content): slots are compared by exact *bit*
//! equality of the f32 payload (`to_bits`, so -0.0 != 0.0 and identical
//! NaN patterns match), which makes staleness impossible by construction
//! — a payload whose device bits would differ can never alias a cached
//! buffer. Each refresh bumps the slot's generation (surfaced for
//! tests/diagnostics).
//!
//! A slot can also **alias** an existing device buffer (a chained
//! dispatch's output handle) without any host copy or upload — the bridge
//! that lets device-resident [`super::chain::DeviceVec`]s flow into the
//! tupled artifacts' pooled-input signatures (e.g. evaluating the loss at
//! an iterate that never visited the host). An aliased slot has no host
//! bytes to compare against, so a later `ensure` with host data always
//! refreshes it.
//!
//! # Staging rings
//!
//! A plain slot has one resident buffer, so refreshing it *replaces* the
//! previous upload — safe on a synchronous backend (a dispatch has
//! finished with its inputs by the time `ensure` runs again), but a
//! pipelined worker that stages machine k+1's operand while machine k's
//! dispatch is still in flight needs two generations alive at once. A
//! **ring** ([`ExecSession::ensure_ring`] / [`ExecSession::swap`]) is the
//! double-buffered slot pair for exactly that: each key holds an A and a
//! B half, reads ([`ExecSession::ring_get`]) resolve the *active* half,
//! and `ensure_ring` writes only the *staged* (inactive) half — the
//! in-flight dispatch's operand is never touched. `swap` flips which half
//! is active once the staged generation is ready to be consumed.
//!
//! The slot-swap generation rule: each half carries its own generation,
//! bumped when `ensure_ring` re-uploads that half (bit-identical staged
//! bytes are a cache hit, like `ensure`); `swap` changes which half
//! serves reads but never touches a generation, so
//! [`ExecSession::ring_generation`] reports how many times the *currently
//! active* payload was refreshed — staleness stays observable across
//! swaps. A double swap without a stage in between simply returns reads
//! to the previous payload: generations never move, so a consumer
//! comparing generations can always tell a re-exposed old payload from a
//! fresh one — a stale buffer can never masquerade as a new upload.
//!
//! The hot path enters through [`ExecSession::ring_stage`], the engine
//! upload lane's per-operand step (`upload=` policy — see the `runtime`
//! module docs): when the *active* half already holds exactly the
//! requested bits it short-circuits (a cache hit: no stage, no swap — the
//! steady-state constant operand costs zero traffic, exactly like
//! `ensure`); otherwise it force-uploads the staged half (even if that
//! half's stale bytes happen to match — the upload decision must depend
//! only on the payload last *dispatched*, so lane-on and lane-off perform
//! bit-identical transfer sequences) and the caller swaps at the dispatch
//! boundary. On today's synchronous CPU PJRT the stage completes before
//! control returns, so the boundary never consumes a half-written buffer;
//! an asynchronous backend's upload verb slots into the staged half and
//! relies on the generation rule above for the same guarantee.

use super::EngineStats;
use anyhow::{anyhow, Result};
use std::collections::HashMap;
use std::rc::Rc;

/// Exact bit equality (not float `==`): distinguishes -0.0 from 0.0 and
/// treats identical NaN patterns as equal — the device buffer holds bits,
/// not values.
fn bitwise_eq(a: &[f32], b: &[f32]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
}

struct Slot {
    /// host copy of the payload currently resident on device; `None` for
    /// aliased device buffers (no host bytes exist)
    host: Option<Vec<f32>>,
    buf: Rc<xla::PjRtBuffer>,
    generation: u64,
}

/// The pure half-selection state machine behind a staging ring: which of
/// the two halves is active, and each half's refresh generation. Kept
/// separate from the buffers so the swap/generation rules are unit-testable
/// without a PJRT client (the buffers themselves can only live on the
/// owning worker thread).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
struct RingMeta {
    /// index (0 or 1) of the half that serves reads
    active: usize,
    /// per-half refresh generations; 0 = never uploaded
    gens: [u64; 2],
}

impl RingMeta {
    /// The half `ensure_ring` writes into: the one NOT serving reads.
    fn staged(&self) -> usize {
        1 - self.active
    }

    /// A fresh upload landed in the staged half.
    fn bump_staged(&mut self) {
        self.gens[self.staged()] += 1;
    }

    /// Flip which half serves reads. Generations are untouched — swapping
    /// changes *which* payload is visible, not how often it was refreshed.
    fn swap(&mut self) {
        self.active = 1 - self.active;
    }

    /// Refresh generation of the payload currently serving reads.
    fn active_generation(&self) -> u64 {
        self.gens[self.active]
    }
}

struct RingSlot {
    /// the A/B halves; a half is `None` until its first upload
    halves: [Option<Slot>; 2],
    meta: RingMeta,
}

/// Named-slot upload cache (see module docs).
#[derive(Default)]
pub struct ExecSession {
    slots: HashMap<&'static str, Slot>,
    rings: HashMap<&'static str, RingSlot>,
}

impl ExecSession {
    pub fn new() -> ExecSession {
        ExecSession { slots: HashMap::new(), rings: HashMap::new() }
    }

    /// Make `key` hold a device copy of `data` on device `device`,
    /// re-uploading only when the contents changed. Traffic is charged to
    /// `stats`.
    pub fn ensure(
        &mut self,
        client: &xla::PjRtClient,
        device: Option<usize>,
        stats: &mut EngineStats,
        key: &'static str,
        data: &[f32],
    ) -> Result<()> {
        if let Some(slot) = self.slots.get(key) {
            if slot.host.as_deref().is_some_and(|h| bitwise_eq(h, data)) {
                stats.upload_cache_hits += 1;
                return Ok(());
            }
        }
        let buf = client
            .buffer_from_host_buffer(data, &[data.len()], device)
            .map_err(|e| anyhow!("uploading slot '{key}' [{}]: {e:?}", data.len()))?;
        stats.uploads += 1;
        stats.upload_bytes += (data.len() * std::mem::size_of::<f32>()) as u64;
        stats.upload_cache_misses += 1;
        let generation = self.slots.get(key).map_or(1, |s| s.generation + 1);
        // the replaced buffer (if any) is dropped here — PJRT reclaims it
        // deterministically via the crate's Drop impl
        self.slots
            .insert(key, Slot { host: Some(data.to_vec()), buf: Rc::new(buf), generation });
        Ok(())
    }

    /// Make `key` alias an already-resident device buffer. Zero traffic:
    /// this is a handle install, not an upload (`stats.alias_installs`).
    /// The slot's generation still advances so staleness stays observable.
    pub fn alias(
        &mut self,
        stats: &mut EngineStats,
        key: &'static str,
        buf: Rc<xla::PjRtBuffer>,
    ) {
        stats.alias_installs += 1;
        let generation = self.slots.get(key).map_or(1, |s| s.generation + 1);
        self.slots.insert(key, Slot { host: None, buf, generation });
    }

    /// The device buffer currently resident in `key` (after `ensure`).
    pub fn get(&self, key: &'static str) -> Result<&xla::PjRtBuffer> {
        self.slots
            .get(key)
            .map(|s| s.buf.as_ref())
            .ok_or_else(|| anyhow!("session slot '{key}' is empty (ensure first)"))
    }

    /// Like [`ExecSession::get`] but returns a shared handle, so the
    /// caller can release the session borrow before building an input
    /// list that must coexist with other engine borrows.
    pub fn get_shared(&self, key: &'static str) -> Result<Rc<xla::PjRtBuffer>> {
        self.slots
            .get(key)
            .map(|s| Rc::clone(&s.buf))
            .ok_or_else(|| anyhow!("session slot '{key}' is empty (ensure first)"))
    }

    /// How many times `key` has been (re-)uploaded or aliased; 0 if never.
    pub fn generation(&self, key: &'static str) -> u64 {
        self.slots.get(key).map_or(0, |s| s.generation)
    }

    /// Drop one slot's device buffer.
    pub fn invalidate(&mut self, key: &'static str) {
        self.slots.remove(key);
    }

    /// Upload `data` into ring `key`'s **staged** half, leaving the active
    /// half (a potentially in-flight dispatch's operand) untouched. Like
    /// [`ExecSession::ensure`], bit-identical bytes against what the staged
    /// half already holds are a cache hit; otherwise the half is re-uploaded
    /// and its generation bumped. Call [`ExecSession::swap`] to make the
    /// staged payload the one reads resolve.
    pub fn ensure_ring(
        &mut self,
        client: &xla::PjRtClient,
        device: Option<usize>,
        stats: &mut EngineStats,
        key: &'static str,
        data: &[f32],
    ) -> Result<()> {
        let ring = self
            .rings
            .entry(key)
            .or_insert_with(|| RingSlot { halves: [None, None], meta: RingMeta::default() });
        let staged = ring.meta.staged();
        if let Some(slot) = &ring.halves[staged] {
            if slot.host.as_deref().is_some_and(|h| bitwise_eq(h, data)) {
                stats.upload_cache_hits += 1;
                return Ok(());
            }
        }
        Self::upload_half(client, device, stats, ring, staged, key, data)
    }

    /// The upload-lane staging step for ring `key` (see module docs).
    ///
    /// Returns `false` when the **active** half already holds exactly
    /// `data` — a cache hit: nothing staged, and the caller must NOT swap
    /// (the active payload keeps serving reads). Otherwise force-uploads
    /// `data` into the staged half — deliberately skipping `ensure_ring`'s
    /// staged-half bit comparison, so the transfer decision depends only
    /// on the payload last dispatched and the lane performs the exact
    /// upload sequence the single-slot [`ExecSession::ensure`] path would
    /// — and returns `true`: the caller swaps at the dispatch boundary.
    pub fn ring_stage(
        &mut self,
        client: &xla::PjRtClient,
        device: Option<usize>,
        stats: &mut EngineStats,
        key: &'static str,
        data: &[f32],
    ) -> Result<bool> {
        let ring = self
            .rings
            .entry(key)
            .or_insert_with(|| RingSlot { halves: [None, None], meta: RingMeta::default() });
        if let Some(slot) = &ring.halves[ring.meta.active] {
            if slot.host.as_deref().is_some_and(|h| bitwise_eq(h, data)) {
                stats.upload_cache_hits += 1;
                return Ok(false);
            }
        }
        let staged = ring.meta.staged();
        Self::upload_half(client, device, stats, ring, staged, key, data)?;
        Ok(true)
    }

    /// Shared ring-half upload: meter the transfer, bump the staged
    /// generation and install the fresh payload.
    fn upload_half(
        client: &xla::PjRtClient,
        device: Option<usize>,
        stats: &mut EngineStats,
        ring: &mut RingSlot,
        half: usize,
        key: &'static str,
        data: &[f32],
    ) -> Result<()> {
        let buf = client
            .buffer_from_host_buffer(data, &[data.len()], device)
            .map_err(|e| anyhow!("uploading ring '{key}' [{}]: {e:?}", data.len()))?;
        stats.uploads += 1;
        stats.upload_bytes += (data.len() * std::mem::size_of::<f32>()) as u64;
        stats.upload_cache_misses += 1;
        ring.meta.bump_staged();
        let generation = ring.meta.gens[half];
        ring.halves[half] = Some(Slot { host: Some(data.to_vec()), buf: Rc::new(buf), generation });
        Ok(())
    }

    /// Flip ring `key` so the half last written by
    /// [`ExecSession::ensure_ring`] serves subsequent
    /// [`ExecSession::ring_get`] reads. Errors if the ring does not exist.
    pub fn swap(&mut self, key: &'static str) -> Result<()> {
        let ring = self
            .rings
            .get_mut(key)
            .ok_or_else(|| anyhow!("session ring '{key}' is empty (ensure_ring first)"))?;
        ring.meta.swap();
        Ok(())
    }

    /// The device buffer in ring `key`'s **active** half.
    pub fn ring_get(&self, key: &'static str) -> Result<&xla::PjRtBuffer> {
        self.rings
            .get(key)
            .and_then(|r| r.halves[r.meta.active].as_ref())
            .map(|s| s.buf.as_ref())
            .ok_or_else(|| anyhow!("session ring '{key}' has no active payload (swap first)"))
    }

    /// Refresh generation of ring `key`'s active half; 0 if the ring does
    /// not exist or its active half was never uploaded.
    pub fn ring_generation(&self, key: &'static str) -> u64 {
        self.rings.get(key).map_or(0, |r| r.meta.active_generation())
    }

    /// Drop every cached buffer (e.g. between benchmark sections).
    pub fn clear(&mut self) {
        self.slots.clear();
        self.rings.clear();
    }

    pub fn len(&self) -> usize {
        self.slots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::{bitwise_eq, RingMeta};

    #[test]
    fn bit_equality_semantics() {
        assert!(bitwise_eq(&[1.0, 2.0], &[1.0, 2.0]));
        assert!(!bitwise_eq(&[1.0], &[1.0, 2.0]));
        // float == would say these are equal; the device bits differ
        assert!(!bitwise_eq(&[0.0], &[-0.0]));
        // float == would say these differ; the device bits are identical
        assert!(bitwise_eq(&[f32::NAN], &[f32::NAN]));
    }

    #[test]
    fn ring_meta_swap_and_generation_rule() {
        let mut m = RingMeta::default();
        // fresh ring: half 0 active, nothing uploaded anywhere
        assert_eq!(m.active, 0);
        assert_eq!(m.staged(), 1);
        assert_eq!(m.active_generation(), 0);

        // first upload lands in the staged half; the active payload (none
        // yet) is untouched until the swap
        m.bump_staged();
        assert_eq!(m.gens, [0, 1]);
        assert_eq!(m.active_generation(), 0);
        m.swap();
        assert_eq!(m.active, 1);
        assert_eq!(m.staged(), 0);
        assert_eq!(m.active_generation(), 1);

        // second upload refreshes the now-staged half 0
        m.bump_staged();
        assert_eq!(m.gens, [1, 1]);
        m.swap();
        assert_eq!(m.active_generation(), 1);

        // swapping alone never advances a generation
        m.swap();
        m.swap();
        assert_eq!(m.gens, [1, 1]);

        // repeated refreshes of one half accumulate on that half only
        m.bump_staged();
        m.bump_staged();
        assert_eq!(m.gens[m.staged()], 3);
        assert_eq!(m.active_generation(), 1);
    }

    #[test]
    fn ring_meta_double_swap_without_stage_restores_the_old_payload() {
        let mut m = RingMeta::default();
        // stage+swap twice so both halves hold distinct generations
        m.bump_staged();
        m.swap();
        m.bump_staged();
        m.bump_staged();
        m.swap();
        assert_eq!(m.active, 0);
        assert_eq!(m.gens, [2, 1]);
        assert_eq!(m.active_generation(), 2);

        // double swap with NO stage in between: reads return to the
        // previous payload and no generation moves — the re-exposed old
        // half is distinguishable from a fresh upload (gen unchanged),
        // which is the staleness guarantee the upload lane leans on
        m.swap();
        assert_eq!(m.active_generation(), 1);
        m.swap();
        assert_eq!(m.active, 0);
        assert_eq!(m.gens, [2, 1]);
        assert_eq!(m.active_generation(), 2);

        // a stage after the double swap lands in the staged half only
        m.bump_staged();
        assert_eq!(m.gens, [2, 2]);
        assert_eq!(m.active_generation(), 2);
    }
}
