//! Artifact manifest: parses `artifacts/manifest.json` emitted by
//! `python/compile/aot.py` and exposes the typed registry the engine
//! compiles from.

use crate::util::json::Json;
use anyhow::{anyhow, bail, ensure, Context, Result};
use std::path::{Path, PathBuf};

#[derive(Clone, Debug, PartialEq)]
pub struct ArtifactMeta {
    pub name: String,
    pub file: PathBuf,
    pub kind: ArtifactKind,
    pub loss: String,
    pub d: usize,
    pub block: usize,
    pub arg_shapes: Vec<Vec<usize>>,
    pub outputs: Vec<String>,
    /// stacked 256-row blocks per dispatch (1 = single-block artifact);
    /// for the `Reduce` kind this records the machine count M instead
    pub k: usize,
    /// single-output artifact lowered with return_tuple=False: executed
    /// via the chained path (output buffer feeds the next dispatch)
    pub chained: bool,
    pub sha256: String,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ArtifactKind {
    Grad,
    Svrg,
    Saga,
    NormalMatvec,
    /// fused K-block gradient with on-device reduction (`gradm{K}_*`)
    GradMulti,
    /// fused K-block normal-equation matvec (`nmm{K}_*`)
    NormalMatvecMulti,
    /// chained K-block gradient accumulate (`gacc{K}_*`): acc + grad_sum
    GradAcc,
    /// chained K-block normal-matvec accumulate (`nacc{K}_*`)
    NormalMatvecAcc,
    /// chained K-block SVRG sweep over a `[2, d]` state (`svrgc{K}_*`)
    SvrgChain,
    /// chained K-block SAGA sweep over a `[2, d]` state (`sagac{K}_*`)
    SagaChain,
    /// vector plane: s * x
    VecScale,
    /// vector plane: a*u + b*v
    VecAxpby,
    /// vector plane: <u, v> as a length-1 array (the CG scalar downlink)
    VecDot,
    /// vector plane: sweep-average extraction from a VR state
    VrAvg,
    /// vector plane: zero a VR state's accumulator, keep its iterate
    VrReset,
    /// cross-machine weighted mean over M vectors (`redm{M}_*`), f64
    /// interior in host collective order (bitwise parity)
    Reduce,
}

impl ArtifactKind {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "grad" => ArtifactKind::Grad,
            "svrg" => ArtifactKind::Svrg,
            "saga" => ArtifactKind::Saga,
            "nm" => ArtifactKind::NormalMatvec,
            "grad_multi" => ArtifactKind::GradMulti,
            "nm_multi" => ArtifactKind::NormalMatvecMulti,
            "gacc" => ArtifactKind::GradAcc,
            "nacc" => ArtifactKind::NormalMatvecAcc,
            "svrgc" => ArtifactKind::SvrgChain,
            "sagac" => ArtifactKind::SagaChain,
            "vscale" => ArtifactKind::VecScale,
            "vaxpby" => ArtifactKind::VecAxpby,
            "vdot" => ArtifactKind::VecDot,
            "vravg" => ArtifactKind::VrAvg,
            "vrreset" => ArtifactKind::VrReset,
            "red" => ArtifactKind::Reduce,
            other => bail!("unknown artifact kind '{other}'"),
        })
    }
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub block: usize,
    pub dims: Vec<usize>,
    pub artifacts: Vec<ArtifactMeta>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let mpath = dir.join("manifest.json");
        let text = std::fs::read_to_string(&mpath)
            .with_context(|| format!("reading {} (run `make artifacts`?)", mpath.display()))?;
        let v = Json::parse(&text).map_err(|e| anyhow!("{}: {e}", mpath.display()))?;
        let block =
            v.get("block").and_then(Json::as_usize).ok_or_else(|| anyhow!("missing 'block'"))?;
        let dims: Vec<usize> = v
            .get("dims")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("missing 'dims'"))?
            .iter()
            .filter_map(Json::as_usize)
            .collect();
        let mut artifacts = Vec::new();
        for a in v
            .get("artifacts")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("missing 'artifacts'"))?
        {
            let get_str = |k: &str| -> Result<String> {
                Ok(a.get(k)
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("artifact missing '{k}'"))?
                    .to_string())
            };
            let get_usize = |k: &str| -> Result<usize> {
                a.get(k).and_then(Json::as_usize).ok_or_else(|| anyhow!("artifact missing '{k}'"))
            };
            let arg_shapes = a
                .get("arg_shapes")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("missing arg_shapes"))?
                .iter()
                .map(|s| {
                    s.as_arr()
                        .map(|xs| xs.iter().filter_map(Json::as_usize).collect::<Vec<_>>())
                        .ok_or_else(|| anyhow!("bad arg shape"))
                })
                .collect::<Result<Vec<_>>>()?;
            let outputs = a
                .get("outputs")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("missing outputs"))?
                .iter()
                .filter_map(Json::as_str)
                .map(str::to_string)
                .collect();
            artifacts.push(ArtifactMeta {
                name: get_str("name")?,
                file: dir.join(get_str("file")?),
                kind: ArtifactKind::parse(&get_str("kind")?)?,
                loss: get_str("loss")?,
                d: get_usize("d")?,
                block: get_usize("block")?,
                arg_shapes,
                outputs,
                // absent in pre-fusion manifests: single-block artifact
                k: a.get("k").and_then(Json::as_usize).unwrap_or(1),
                // absent in pre-chaining manifests: tupled artifact
                chained: a.get("chained").and_then(Json::as_bool).unwrap_or(false),
                sha256: get_str("sha256")?,
            });
        }
        if artifacts.is_empty() {
            bail!("manifest has no artifacts");
        }
        Ok(Manifest { dir: dir.to_path_buf(), block, dims, artifacts })
    }

    pub fn find(&self, name: &str) -> Option<&ArtifactMeta> {
        self.artifacts.iter().find(|a| a.name == name)
    }

    /// Canonical *single-block* artifact name for (kind, loss-tag, dim).
    /// The multi kinds resolve to their single-block family base (their
    /// fused names embed a width — see [`Manifest::name_for_k`]).
    pub fn name_for(kind: ArtifactKind, loss_tag: &str, d: usize) -> String {
        let k = match kind {
            ArtifactKind::Grad | ArtifactKind::GradMulti => "grad",
            ArtifactKind::Svrg => "svrg",
            ArtifactKind::Saga => "saga",
            ArtifactKind::NormalMatvec | ArtifactKind::NormalMatvecMulti => "nm",
        };
        format!("{k}_{loss_tag}_d{d}")
    }

    /// Canonical artifact name for (kind, loss-tag, dim, fuse width):
    /// `k == 1` selects the single-block artifact, `k > 1` the fused
    /// multi-block variant (e.g. `gradm4_sq_d64`). Matches python's
    /// `kernels.common.multi_artifact_name`.
    pub fn name_for_k(kind: ArtifactKind, loss_tag: &str, d: usize, k: usize) -> Result<String> {
        if k <= 1 {
            // width 1 IS the single-block artifact (name_for maps the
            // multi kinds to their single-block family base)
            return Ok(Self::name_for(kind, loss_tag, d));
        }
        let base = match kind {
            ArtifactKind::Grad | ArtifactKind::GradMulti => "grad",
            ArtifactKind::NormalMatvec | ArtifactKind::NormalMatvecMulti => "nm",
            other => bail!("no multi-block variant for artifact kind {other:?}"),
        };
        Ok(format!("{base}m{k}_{loss_tag}_d{d}"))
    }

    /// Canonical *chained* artifact name (single-output family; the width
    /// is always embedded, including k=1). Matches python's
    /// `kernels.common.chain_artifact_name`.
    pub fn chain_name(kind: ArtifactKind, loss_tag: &str, d: usize, k: usize) -> Result<String> {
        let base = match kind {
            ArtifactKind::GradAcc => "gacc",
            ArtifactKind::NormalMatvecAcc => "nacc",
            ArtifactKind::SvrgChain => "svrgc",
            ArtifactKind::SagaChain => "sagac",
            other => bail!("no chained variant for artifact kind {other:?}"),
        };
        ensure!(k >= 1, "chained width must be >= 1, got {k}");
        Ok(format!("{base}{k}_{loss_tag}_d{d}"))
    }

    /// Canonical vector-plane artifact name (`vscale_d64`, ...). Matches
    /// python's `kernels.common.vec_artifact_name`.
    pub fn vec_name(kind: ArtifactKind, d: usize) -> Result<String> {
        let base = match kind {
            ArtifactKind::VecScale => "vscale",
            ArtifactKind::VecAxpby => "vaxpby",
            ArtifactKind::VecDot => "vdot",
            ArtifactKind::VrAvg => "vravg",
            ArtifactKind::VrReset => "vrreset",
            other => bail!("{other:?} is not a vector-plane artifact kind"),
        };
        Ok(format!("{base}_d{d}"))
    }

    /// Canonical cross-machine reduce artifact name (`redm4_d64`).
    /// Matches python's `kernels.common.red_artifact_name`.
    pub fn red_name(m: usize, d: usize) -> Result<String> {
        ensure!(m >= 2, "cross-machine reduce needs m >= 2, got {m}");
        Ok(format!("redm{m}_d{d}"))
    }

    /// Fused-dispatch widths usable by the packer, widest first: a width
    /// K qualifies only if *every* hot-path artifact exists at K — the
    /// fused gradient for each (loss, dim) that has a single-block
    /// gradient, and the fused normal-matvec for each dim that has a
    /// single-block one. Pre-fusion manifests yield an empty vec and the
    /// engine degrades to per-block dispatch everywhere.
    pub fn fuse_widths(&self) -> Vec<usize> {
        let mut ks: Vec<usize> = self
            .artifacts
            .iter()
            .filter(|a| a.kind == ArtifactKind::GradMulti && a.k > 1)
            .map(|a| a.k)
            .collect();
        ks.sort_unstable();
        ks.dedup();
        let singles: Vec<&ArtifactMeta> = self
            .artifacts
            .iter()
            .filter(|a| matches!(a.kind, ArtifactKind::Grad | ArtifactKind::NormalMatvec))
            .collect();
        ks.retain(|&k| {
            singles.iter().all(|a| {
                Self::name_for_k(a.kind, &a.loss, a.d, k)
                    .ok()
                    .and_then(|n| self.find(&n))
                    .is_some()
            })
        });
        ks.reverse(); // widest first for the greedy packer
        ks
    }

    /// The widths the chained dispatch path must cover: every fused group
    /// width the packer can emit, plus 1 for the ragged single-block tail.
    fn required_chain_widths(&self) -> Vec<usize> {
        let mut ks = self.fuse_widths();
        if !ks.contains(&1) {
            ks.push(1);
        }
        ks
    }

    fn has(&self, name: Result<String>) -> bool {
        name.ok().and_then(|n| self.find(&n)).is_some()
    }

    /// Vector-plane readiness at dim `d`: scale/axpby/dot present.
    pub fn vec_ready(&self, d: usize) -> bool {
        [ArtifactKind::VecScale, ArtifactKind::VecAxpby, ArtifactKind::VecDot]
            .into_iter()
            .all(|k| self.has(Self::vec_name(k, d)))
    }

    /// Chained gradient readiness for (loss-tag, dim): `gacc{K}` exists at
    /// every width the packer can emit (plus the k=1 tail), and the
    /// vector plane is present for the scale step.
    pub fn chain_grad_ready(&self, loss_tag: &str, d: usize) -> bool {
        self.vec_ready(d)
            && self
                .required_chain_widths()
                .into_iter()
                .all(|k| self.has(Self::chain_name(ArtifactKind::GradAcc, loss_tag, d, k)))
    }

    /// Chained VR-sweep readiness for (loss-tag, dim): both sweep kernels
    /// at every packer width plus the state helpers.
    pub fn chain_vr_ready(&self, loss_tag: &str, d: usize) -> bool {
        self.has(Self::vec_name(ArtifactKind::VrAvg, d))
            && self.has(Self::vec_name(ArtifactKind::VrReset, d))
            && self.required_chain_widths().into_iter().all(|k| {
                self.has(Self::chain_name(ArtifactKind::SvrgChain, loss_tag, d, k))
                    && self.has(Self::chain_name(ArtifactKind::SagaChain, loss_tag, d, k))
            })
    }

    /// Chained normal-matvec (CG/DiSCO) readiness at dim `d`.
    pub fn chain_nm_ready(&self, d: usize) -> bool {
        self.vec_ready(d)
            && self
                .required_chain_widths()
                .into_iter()
                .all(|k| self.has(Self::chain_name(ArtifactKind::NormalMatvecAcc, "sq", d, k)))
    }

    /// Whether the on-device cross-machine reduce serves an m-machine
    /// cluster at dim `d` (m == 1 is an identity, always served).
    pub fn red_ready(&self, m: usize, d: usize) -> bool {
        m == 1 || self.has(Self::red_name(m, d))
    }

    /// Smallest supported artifact dim >= `native_dim`.
    pub fn padded_dim(&self, native_dim: usize) -> Result<usize> {
        self.dims
            .iter()
            .copied()
            .filter(|&d| d >= native_dim)
            .min()
            .ok_or_else(|| anyhow!("no artifact dim >= {native_dim} (have {:?})", self.dims))
    }
}

/// Default artifacts directory: $MBPROX_ARTIFACTS or ./artifacts.
pub fn default_artifacts_dir() -> PathBuf {
    std::env::var_os("MBPROX_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_fixture(dir: &Path) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"block": 8, "dims": [2],
                "artifacts": [
                  {"name": "grad_sq_d2", "file": "grad_sq_d2.hlo.txt",
                   "kind": "grad", "loss": "sq", "d": 2, "block": 8,
                   "arg_shapes": [[8,2],[8],[8],[2]],
                   "outputs": ["grad_sum","loss_sum","count"],
                   "sha256": "x"}]}"#,
        )
        .unwrap();
    }

    #[test]
    fn loads_manifest() {
        // each test gets its own dir: cargo runs tests in parallel and
        // write_fixture truncates manifest.json
        let dir = std::env::temp_dir().join("mbprox_manifest_test_load");
        write_fixture(&dir);
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.block, 8);
        assert_eq!(m.dims, vec![2]);
        let a = m.find("grad_sq_d2").unwrap();
        assert_eq!(a.kind, ArtifactKind::Grad);
        assert_eq!(a.arg_shapes[0], vec![8, 2]);
        assert_eq!(a.outputs.len(), 3);
    }

    #[test]
    fn padded_dim_selection() {
        let dir = std::env::temp_dir().join("mbprox_manifest_test_pad");
        write_fixture(&dir);
        let mut m = Manifest::load(&dir).unwrap();
        m.dims = vec![64, 128];
        assert_eq!(m.padded_dim(8).unwrap(), 64);
        assert_eq!(m.padded_dim(64).unwrap(), 64);
        assert_eq!(m.padded_dim(65).unwrap(), 128);
        assert!(m.padded_dim(129).is_err());
    }

    #[test]
    fn name_for_matches_python() {
        assert_eq!(Manifest::name_for(ArtifactKind::Grad, "sq", 64), "grad_sq_d64");
        assert_eq!(Manifest::name_for(ArtifactKind::Svrg, "log", 128), "svrg_log_d128");
        assert_eq!(Manifest::name_for(ArtifactKind::Saga, "sq", 64), "saga_sq_d64");
        assert_eq!(Manifest::name_for(ArtifactKind::NormalMatvec, "sq", 64), "nm_sq_d64");
    }

    #[test]
    fn name_for_k_matches_python() {
        assert_eq!(
            Manifest::name_for_k(ArtifactKind::Grad, "sq", 64, 1).unwrap(),
            "grad_sq_d64"
        );
        assert_eq!(
            Manifest::name_for_k(ArtifactKind::Grad, "sq", 64, 4).unwrap(),
            "gradm4_sq_d64"
        );
        assert_eq!(
            Manifest::name_for_k(ArtifactKind::GradMulti, "log", 128, 8).unwrap(),
            "gradm8_log_d128"
        );
        assert_eq!(
            Manifest::name_for_k(ArtifactKind::NormalMatvec, "sq", 64, 8).unwrap(),
            "nmm8_sq_d64"
        );
        assert!(Manifest::name_for_k(ArtifactKind::Svrg, "sq", 64, 4).is_err());
        // a multi kind at width 1 IS the single-block artifact — never the
        // malformed width-less base name
        assert_eq!(
            Manifest::name_for_k(ArtifactKind::GradMulti, "sq", 64, 1).unwrap(),
            "grad_sq_d64"
        );
        assert_eq!(
            Manifest::name_for_k(ArtifactKind::NormalMatvecMulti, "sq", 128, 1).unwrap(),
            "nm_sq_d128"
        );
    }

    #[test]
    fn fuse_widths_require_full_coverage() {
        let dir = std::env::temp_dir().join("mbprox_manifest_test_widths");
        write_fixture(&dir);
        let mut m = Manifest::load(&dir).unwrap();
        // pre-fusion manifest: no multi artifacts, no widths
        assert!(m.fuse_widths().is_empty());
        let base = m.artifacts[0].clone();
        let mk = |name: &str, kind: ArtifactKind, loss: &str, k: usize| ArtifactMeta {
            name: name.to_string(),
            kind,
            loss: loss.to_string(),
            k,
            ..base.clone()
        };
        // gradm4 exists for the only (loss, d) pair and nmm4 covers nm — but
        // there is no nm single, so only the grad coverage is required
        m.artifacts.push(mk("gradm4_sq_d2", ArtifactKind::GradMulti, "sq", 4));
        assert_eq!(m.fuse_widths(), vec![4]);
        // an nm single without its fused companion disqualifies the width
        m.artifacts.push(mk("nm_sq_d2", ArtifactKind::NormalMatvec, "sq", 1));
        assert!(m.fuse_widths().is_empty());
        m.artifacts.push(mk("nmm4_sq_d2", ArtifactKind::NormalMatvecMulti, "sq", 4));
        assert_eq!(m.fuse_widths(), vec![4]);
        // widest first
        m.artifacts.push(mk("gradm8_sq_d2", ArtifactKind::GradMulti, "sq", 8));
        m.artifacts.push(mk("nmm8_sq_d2", ArtifactKind::NormalMatvecMulti, "sq", 8));
        assert_eq!(m.fuse_widths(), vec![8, 4]);
    }

    #[test]
    fn missing_dir_is_error() {
        assert!(Manifest::load(Path::new("/definitely/not/here")).is_err());
    }

    #[test]
    fn chain_names_match_python() {
        assert_eq!(
            Manifest::chain_name(ArtifactKind::GradAcc, "sq", 64, 1).unwrap(),
            "gacc1_sq_d64"
        );
        assert_eq!(
            Manifest::chain_name(ArtifactKind::SvrgChain, "log", 128, 8).unwrap(),
            "svrgc8_log_d128"
        );
        assert_eq!(
            Manifest::chain_name(ArtifactKind::SagaChain, "sq", 64, 4).unwrap(),
            "sagac4_sq_d64"
        );
        assert_eq!(
            Manifest::chain_name(ArtifactKind::NormalMatvecAcc, "sq", 64, 4).unwrap(),
            "nacc4_sq_d64"
        );
        assert!(Manifest::chain_name(ArtifactKind::Grad, "sq", 64, 4).is_err());
        assert_eq!(Manifest::vec_name(ArtifactKind::VecAxpby, 64).unwrap(), "vaxpby_d64");
        assert_eq!(Manifest::vec_name(ArtifactKind::VrReset, 128).unwrap(), "vrreset_d128");
        assert!(Manifest::vec_name(ArtifactKind::Reduce, 64).is_err());
        assert_eq!(Manifest::red_name(4, 64).unwrap(), "redm4_d64");
        assert!(Manifest::red_name(1, 64).is_err());
    }

    #[test]
    fn chain_readiness_requires_full_width_coverage() {
        let dir = std::env::temp_dir().join("mbprox_manifest_test_chain");
        write_fixture(&dir);
        let mut m = Manifest::load(&dir).unwrap();
        let base = m.artifacts[0].clone();
        let mk = |name: &str, kind: ArtifactKind, k: usize| ArtifactMeta {
            name: name.to_string(),
            kind,
            loss: "sq".to_string(),
            k,
            chained: true,
            ..base.clone()
        };
        // pre-chaining manifest: nothing is ready
        assert!(!m.vec_ready(2));
        assert!(!m.chain_grad_ready("sq", 2));
        assert!(!m.chain_vr_ready("sq", 2));
        assert!(!m.chain_nm_ready(2));
        assert!(m.red_ready(1, 2)); // identity: always served
        assert!(!m.red_ready(4, 2));
        // vector plane alone is not enough for the grad chain
        m.artifacts.push(mk("vscale_d2", ArtifactKind::VecScale, 1));
        m.artifacts.push(mk("vaxpby_d2", ArtifactKind::VecAxpby, 1));
        m.artifacts.push(mk("vdot_d2", ArtifactKind::VecDot, 1));
        assert!(m.vec_ready(2));
        assert!(!m.chain_grad_ready("sq", 2));
        // no fused widths in this fixture: k=1 coverage suffices
        m.artifacts.push(mk("gacc1_sq_d2", ArtifactKind::GradAcc, 1));
        assert!(m.chain_grad_ready("sq", 2));
        assert!(!m.chain_grad_ready("log", 2));
        m.artifacts.push(mk("nacc1_sq_d2", ArtifactKind::NormalMatvecAcc, 1));
        assert!(m.chain_nm_ready(2));
        // VR chain needs BOTH sweep kernels plus the state helpers
        m.artifacts.push(mk("svrgc1_sq_d2", ArtifactKind::SvrgChain, 1));
        m.artifacts.push(mk("vravg_d2", ArtifactKind::VrAvg, 1));
        m.artifacts.push(mk("vrreset_d2", ArtifactKind::VrReset, 1));
        assert!(!m.chain_vr_ready("sq", 2));
        m.artifacts.push(mk("sagac1_sq_d2", ArtifactKind::SagaChain, 1));
        assert!(m.chain_vr_ready("sq", 2));
        // a fused width without its chained companion breaks readiness
        m.artifacts.push(mk("gradm4_sq_d2", ArtifactKind::GradMulti, 4));
        assert!(!m.chain_grad_ready("sq", 2));
        m.artifacts.push(mk("gacc4_sq_d2", ArtifactKind::GradAcc, 4));
        assert!(m.chain_grad_ready("sq", 2));
        m.artifacts.push(mk("redm4_d2", ArtifactKind::Reduce, 4));
        assert!(m.red_ready(4, 2));
    }
}
