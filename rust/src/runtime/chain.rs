//! DeviceVec: device-resident vector handles + the typed chained wrappers.
//!
//! A [`DeviceVec`] is a shared handle to a PJRT device buffer (an upload
//! or a chained dispatch's output). Handles clone freely — a clone is an
//! `Rc` bump, not a copy — which is what lets the simulated broadcast
//! hand "every machine" the same resident vector for free while the comm
//! layer charges the paper-units round exactly as the host path does.
//!
//! The wrappers below are the typed surface of the **chain** verb (see
//! the module docs in `runtime`): each dispatches one single-output
//! artifact and returns the output as a new handle. Nothing here ever
//! downloads; bytes leave the device only through
//! [`super::Engine::materialize`].
//!
//! Naming mirrors `python/compile/kernels/chain.py` kernel-for-kernel:
//! `grad_acc`/`nm_acc` (accumulating hot-path reductions), `vr_chain`
//! (the `[2, d]`-state SVRG/SAGA sweep), `vr_reset`/`vr_avg` (state
//! lifecycle), `vec_scale`/`vec_axpby`/`vec_dot` (the loss-independent
//! vector plane), and `reduce_weighted_dev` (the cross-machine kernel the
//! comm layer drives).

use super::exec::BlockLits;
use super::{ArtifactKind, Engine, Manifest};
use crate::data::Loss;
use anyhow::{ensure, Result};
use std::rc::Rc;

/// Rows in a VR sweep state: `[x; avg_accum]`.
pub const VR_STATE_ROWS: usize = 2;

/// A device-resident f32 tensor handle (see module docs).
#[derive(Clone)]
pub struct DeviceVec {
    buf: Rc<xla::PjRtBuffer>,
    dims: Vec<usize>,
}

impl DeviceVec {
    pub(super) fn from_buffer(buf: xla::PjRtBuffer, dims: Vec<usize>) -> DeviceVec {
        DeviceVec { buf: Rc::new(buf), dims }
    }

    /// The underlying device buffer (an execute input).
    pub fn buffer(&self) -> &xla::PjRtBuffer {
        self.buf.as_ref()
    }

    /// Shared handle to the buffer (for session-slot aliasing).
    pub(super) fn shared(&self) -> Rc<xla::PjRtBuffer> {
        Rc::clone(&self.buf)
    }

    /// Logical shape (row-major).
    pub fn dims(&self) -> &[usize] {
        &self.dims
    }

    /// Total element count.
    pub fn len(&self) -> usize {
        self.dims.iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Whether two handles alias the same device buffer.
    pub fn same_buffer(&self, other: &DeviceVec) -> bool {
        Rc::ptr_eq(&self.buf, &other.buf)
    }
}

impl std::fmt::Debug for DeviceVec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "DeviceVec{:?}", self.dims)
    }
}

/// Which chained VR kernel family performs a sweep (the runtime-level
/// mirror of `algos::solvers::LocalSolver`, kept separate so the runtime
/// has no dependency on the algorithm layer).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum VrKernel {
    Svrg,
    Saga,
}

impl VrKernel {
    fn kind(self) -> ArtifactKind {
        match self {
            VrKernel::Svrg => ArtifactKind::SvrgChain,
            VrKernel::Saga => ArtifactKind::SagaChain,
        }
    }
}

impl Engine {
    /// Chained block-gradient accumulate: `acc + grad_sum(blk, w)` for
    /// the (possibly stacked) block group, entirely on device.
    pub fn grad_acc(
        &mut self,
        loss: Loss,
        blk: &BlockLits,
        w: &DeviceVec,
        acc: &DeviceVec,
    ) -> Result<DeviceVec> {
        ensure!(w.dims() == [blk.d], "grad_acc: w {w:?} vs block dim {}", blk.d);
        ensure!(acc.dims() == [blk.d], "grad_acc: acc {acc:?} vs block dim {}", blk.d);
        let name = Manifest::chain_name(ArtifactKind::GradAcc, loss.tag(), blk.d, blk.k)?;
        self.execute_chained(
            &name,
            &[&blk.x, &blk.y, &blk.mask, w.buffer(), acc.buffer()],
            vec![blk.d],
        )
    }

    /// Chained normal-matvec accumulate: `acc + X^T diag(mask) X v`
    /// (squared loss), on device.
    pub fn nm_acc(&mut self, blk: &BlockLits, v: &DeviceVec, acc: &DeviceVec) -> Result<DeviceVec> {
        ensure!(v.dims() == [blk.d] && acc.dims() == [blk.d], "nm_acc operand dims");
        let name =
            Manifest::chain_name(ArtifactKind::NormalMatvecAcc, Loss::Squared.tag(), blk.d, blk.k)?;
        self.execute_chained(&name, &[&blk.x, &blk.mask, v.buffer(), acc.buffer()], vec![blk.d])
    }

    /// Chained VR sweep over one (possibly stacked) block group: advances
    /// the `[2, d]` state `S = [x; avg_accum]` through every stacked
    /// block. `z`/`mu`/`center` are sweep-constant handles; `gamma`/`eta`
    /// are length-1 handles too — sweep constants uploaded ONCE by the
    /// caller, not per dispatch (see [`Engine::scalar_dev`]).
    #[allow(clippy::too_many_arguments)]
    pub fn vr_chain(
        &mut self,
        kernel: VrKernel,
        loss: Loss,
        blk: &BlockLits,
        state: &DeviceVec,
        z: &DeviceVec,
        mu: &DeviceVec,
        center: &DeviceVec,
        gamma: &DeviceVec,
        eta: &DeviceVec,
    ) -> Result<DeviceVec> {
        ensure!(
            state.dims() == [VR_STATE_ROWS, blk.d],
            "vr_chain: state {state:?} vs block dim {}",
            blk.d
        );
        ensure!(
            z.dims() == [blk.d] && mu.dims() == [blk.d] && center.dims() == [blk.d],
            "vr_chain operand dims"
        );
        ensure!(gamma.dims() == [1] && eta.dims() == [1], "vr_chain scalar operand dims");
        let name = Manifest::chain_name(kernel.kind(), loss.tag(), blk.d, blk.k)?;
        self.execute_chained(
            &name,
            &[
                &blk.x,
                &blk.y,
                &blk.mask,
                state.buffer(),
                z.buffer(),
                mu.buffer(),
                center.buffer(),
                gamma.buffer(),
                eta.buffer(),
            ],
            vec![VR_STATE_ROWS, blk.d],
        )
    }

    /// Fresh sweep state from a host iterate: `[x0; 0]`, one upload.
    pub fn vr_state_from(&mut self, x0: &[f32]) -> Result<DeviceVec> {
        let d = x0.len();
        let mut host = Vec::with_capacity(VR_STATE_ROWS * d);
        host.extend_from_slice(x0);
        host.resize(VR_STATE_ROWS * d, 0.0);
        self.upload_dev(&host, &[VR_STATE_ROWS, d])
    }

    /// New-sweep state: keep the carried iterate, zero the accumulator.
    pub fn vr_reset(&mut self, state: &DeviceVec) -> Result<DeviceVec> {
        ensure!(state.dims().len() == 2, "vr_reset on {state:?}");
        let d = state.dims()[1];
        let name = Manifest::vec_name(ArtifactKind::VrReset, d)?;
        self.execute_chained(&name, &[state.buffer()], vec![VR_STATE_ROWS, d])
    }

    /// Sweep average `state[1] * inv_weight`; `inv_weight == 0` returns
    /// the carried iterate `state[0]` (the empty-sweep fallback, matching
    /// the host combiner). The scalar rides the bit-pattern cache.
    pub fn vr_avg(&mut self, state: &DeviceVec, inv_weight: f32) -> Result<DeviceVec> {
        ensure!(state.dims().len() == 2, "vr_avg on {state:?}");
        let d = state.dims()[1];
        let name = Manifest::vec_name(ArtifactKind::VrAvg, d)?;
        let inv = self.scalar_dev(inv_weight)?;
        self.execute_chained(&name, &[state.buffer(), inv.buffer()], vec![d])
    }

    /// `s * x` on device (scalar cached by bit pattern).
    pub fn vec_scale(&mut self, x: &DeviceVec, s: f32) -> Result<DeviceVec> {
        let d = x.len();
        let name = Manifest::vec_name(ArtifactKind::VecScale, d)?;
        let s_dev = self.scalar_dev(s)?;
        self.execute_chained(&name, &[x.buffer(), s_dev.buffer()], vec![d])
    }

    /// `a*u + b*v` on device (the CG recurrence workhorse; the recurring
    /// 1.0/-1.0 coefficients hit the scalar cache, not fresh uploads).
    pub fn vec_axpby(&mut self, a: f32, u: &DeviceVec, b: f32, v: &DeviceVec) -> Result<DeviceVec> {
        ensure!(u.dims() == v.dims(), "vec_axpby: {u:?} vs {v:?}");
        let d = u.len();
        let name = Manifest::vec_name(ArtifactKind::VecAxpby, d)?;
        let a_dev = self.scalar_dev(a)?;
        let b_dev = self.scalar_dev(b)?;
        self.execute_chained(
            &name,
            &[u.buffer(), v.buffer(), a_dev.buffer(), b_dev.buffer()],
            vec![d],
        )
    }

    /// `<u, v>` — computed on device, downloading ONE scalar (4 bytes):
    /// the steady-state downlink of a chained CG iteration.
    pub fn vec_dot(&mut self, u: &DeviceVec, v: &DeviceVec) -> Result<f64> {
        ensure!(u.dims() == v.dims(), "vec_dot: {u:?} vs {v:?}");
        let name = Manifest::vec_name(ArtifactKind::VecDot, u.len())?;
        let out = self.execute_chained(&name, &[u.buffer(), v.buffer()], vec![1])?;
        Ok(self.materialize_scalar(&out)? as f64)
    }

    /// Cross-machine weighted mean of per-machine handles via the
    /// `redm{M}` artifact — the **reduce** verb. The kernel's f64
    /// interior reproduces the host collective bit-for-bit, which is why
    /// every weight MUST be f32-exact (batch counts are, up to 2^24): a
    /// silently rounded weight would break the bit-parity contract, so a
    /// non-exact weight is an error here and the comm layer routes such
    /// reduces through the host collective instead. Unsupported `m`
    /// errors the same way.
    pub fn reduce_weighted_dev(
        &mut self,
        parts: &[DeviceVec],
        weights: &[f64],
    ) -> Result<DeviceVec> {
        ensure!(!parts.is_empty(), "reduce of zero machines");
        ensure!(parts.len() == weights.len(), "reduce weights/machines mismatch");
        ensure!(
            weights_f32_exact(weights),
            "device reduce weights must be f32-exact (got {weights:?})"
        );
        let d = parts[0].len();
        ensure!(parts.iter().all(|p| p.dims() == [d]), "ragged device reduce");
        let m = parts.len();
        let name = Manifest::red_name(m, d)?;
        ensure!(
            self.manifest().find(&name).is_some(),
            "no {name} artifact: cluster size {m} not served on device"
        );
        let w32: Vec<f32> = weights.iter().map(|&w| w as f32).collect();
        // weights are per-batch constants (counts): ride the session
        // pool so K reduces per solve re-upload the vector zero times
        self.session.ensure(&self.client, self.device, &mut self.stats, "red.w", &w32)?;
        let w_buf = self.session.get_shared("red.w")?;
        let mut inputs: Vec<&xla::PjRtBuffer> = parts.iter().map(|p| p.buffer()).collect();
        inputs.push(w_buf.as_ref());
        self.execute_chained(&name, &inputs, vec![d])
    }
}

/// Whether every weight survives an f64 -> f32 -> f64 round trip exactly
/// (the precondition for the device reduce's bit-parity with the host
/// collective, which consumes the f64 originals).
pub fn weights_f32_exact(weights: &[f64]) -> bool {
    weights.iter().all(|&w| (w as f32) as f64 == w)
}
