//! ExecPlane: ONE execution-plane API through which every algorithm
//! drives the runtime.
//!
//! The paper's point is that minibatch-prox trades communication for
//! memory across deployment regimes; the codebase's point is that the
//! *algorithms* should not care which regime they run in. An
//! [`ExecPlane`] owns engine access, the per-machine fan/join, the
//! collectives, the VR sweeps, the materialization points AND the sample
//! **draw** path (the fifth plane verb — see
//! [`ExecPlane::draw_batches`]: shard-resident streams generate and pack
//! on the owning shard with zero coordinator-side sample
//! materialization), with three interchangeable implementations behind
//! one verb set:
//!
//! - **Host** — the legacy per-block pipeline: tupled dispatches, host
//!   accumulation, host collectives. The pre-chaining engine contract,
//!   kept alive (and CI-tested under `PLANE=host`) as the reference
//!   implementation and the fallback for manifests without chained
//!   artifacts.
//! - **Chained** — the single-engine device-resident pipeline: gradients
//!   fold through `gacc{K}` accumulator chains, VR sweeps advance `[2,d]`
//!   states over the fused group uploads, collectives run the `redm{M}`
//!   device reduce, and bytes leave the device only at explicit
//!   materialization points.
//! - **Sharded** — the engine-per-worker plane ([`ShardPool`]): the SAME
//!   chained kernels run per machine on the owning shard's engine, and
//!   cross-machine values travel as host bits through the fixed-order f64
//!   host collectives — bit-identical to the Chained plane for every
//!   shard count (f32 host round trips are exact, and the host collective
//!   interior is bit-identical to the device reduce).
//!
//! Solvers are written ONCE against the verbs below and resolve a
//! [`Lane`] per solve; plane selection is runtime policy
//! ([`PlanePolicy`]: the `plane=` config key / `PLANE` env, resolved once
//! in the coordinator), not per-solver gating. A GPU/TPU backend
//! implements the four device verbs (upload/dispatch/chain/reduce — see
//! the `runtime` module docs; the fifth verb, draw, lives on the plane
//! itself) and inherits every algorithm through this API.
//!
//! # Lanes
//!
//! A [`Lane`] is the *numerical* route a solve takes on its plane:
//! `Host` (legacy per-block kernels), `Grouped` (chained kernels, host
//! collectives — the Sharded plane's lane) or `Dev` (chained kernels,
//! device collectives — the Chained plane's lane). The plane resolves the
//! lane from its kind and the manifest's capabilities
//! ([`ExecPlane::vr_lane`] / [`ExecPlane::cg_lane`]), so a manifest
//! without chained artifacts degrades honestly to the Host lane instead
//! of erroring. `Grouped` and `Dev` are bit-identical by construction;
//! `Host` is numerically equivalent (the parity tests pin 1e-4) with
//! identical paper-units accounting.

use super::chain::VrKernel;
use super::shard::{FanBatch, LaneTicket, Pending, ShardPool};
use super::{DeviceVec, Engine};
use crate::accounting::{ClusterMeter, ResourceMeter};
use crate::comm::Network;
use crate::data::{Loss, MachineStreams};
use crate::objective::{
    distributed_mean_grad, distributed_mean_grad_dev, fan_machine, fan_machines,
    local_grad_sum, local_grad_sum_dev, mean_grad_chained_host, MachineBatch, PackMode,
    ShardBatchMeta,
};
use anyhow::{anyhow, bail, ensure, Result};
use std::collections::VecDeque;
use std::ops::Range;
use std::sync::Arc;
use std::time::Instant;

/// The `plane=` policy: how the coordinator picks an execution plane.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum PlanePolicy {
    /// `Sharded` when a shard pool is attached, `Chained` otherwise —
    /// exactly the pre-policy behavior, bit for bit.
    #[default]
    Auto,
    Host,
    Chained,
    Sharded,
}

impl PlanePolicy {
    pub fn parse(s: &str) -> Option<PlanePolicy> {
        match s.trim().to_ascii_lowercase().as_str() {
            "auto" => Some(PlanePolicy::Auto),
            "host" => Some(PlanePolicy::Host),
            "chained" => Some(PlanePolicy::Chained),
            "sharded" => Some(PlanePolicy::Sharded),
            _ => None,
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            PlanePolicy::Auto => "auto",
            PlanePolicy::Host => "host",
            PlanePolicy::Chained => "chained",
            PlanePolicy::Sharded => "sharded",
        }
    }

    /// Parse the `PLANE` environment variable (unset/empty = `Auto`).
    /// Any other unrecognized value is an error — a typo must not
    /// silently fall back to a different plane.
    pub fn from_env() -> Result<PlanePolicy> {
        match std::env::var("PLANE") {
            Err(_) => Ok(PlanePolicy::Auto),
            Ok(raw) if raw.trim().is_empty() => Ok(PlanePolicy::Auto),
            Ok(raw) => PlanePolicy::parse(&raw)
                .ok_or_else(|| anyhow!("PLANE='{raw}' is not auto|host|chained|sharded")),
        }
    }
}

/// The `prefetch=` policy: whether the Sharded plane's draw verb runs one
/// round ahead of the engine on the per-shard prefetch lane (see
/// `runtime::shard`). Bit-parity is unconditional — the policy trades
/// dispatch-stall time, never bytes — so `Auto` enables it wherever it
/// applies (shard-resident streams); `Off` forces the synchronous
/// draw-then-pack path for diagnostics and A/B stall measurement.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum PrefetchPolicy {
    /// Prefetch on the Sharded plane (where the lane exists), no-op
    /// elsewhere — the default.
    #[default]
    Auto,
    On,
    Off,
}

impl PrefetchPolicy {
    pub fn parse(s: &str) -> Option<PrefetchPolicy> {
        match s.trim().to_ascii_lowercase().as_str() {
            "auto" => Some(PrefetchPolicy::Auto),
            "on" => Some(PrefetchPolicy::On),
            "off" => Some(PrefetchPolicy::Off),
            _ => None,
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            PrefetchPolicy::Auto => "auto",
            PrefetchPolicy::On => "on",
            PrefetchPolicy::Off => "off",
        }
    }

    /// Parse the `PREFETCH` environment variable (unset/empty = `Auto`).
    /// Unrecognized values error — a typo must not silently change the
    /// stall profile being measured.
    pub fn from_env() -> Result<PrefetchPolicy> {
        match std::env::var("PREFETCH") {
            Err(_) => Ok(PrefetchPolicy::Auto),
            Ok(raw) if raw.trim().is_empty() => Ok(PrefetchPolicy::Auto),
            Ok(raw) => PrefetchPolicy::parse(&raw)
                .ok_or_else(|| anyhow!("PREFETCH='{raw}' is not auto|on|off")),
        }
    }

    /// Whether the lane should stage the next round (`Auto` resolves to
    /// on — parity is unconditional, so there is nothing to protect by
    /// defaulting off).
    pub fn enabled(self) -> bool {
        self != PrefetchPolicy::Off
    }
}

/// The `pipeline=` policy: whether the Sharded plane's batched fans
/// software-pipeline within each shard worker — while machine k's packed
/// blocks upload and dispatch, machine k+1's lane request is already in
/// flight (see `runtime::shard`). Bit-parity is unconditional: the next
/// request is issued only AFTER the previous collect, so the lane serves
/// commands in the identical FIFO order as the serial loop and every
/// sample/byte is bit-identical — the policy trades engine idle time,
/// never numerics. `Auto` therefore resolves to on; `Off` forces the
/// strictly serial per-machine loop for diagnostics and A/B overlap
/// measurement (the [`crate::accounting::OverlapMeter`] records which ran).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum PipelinePolicy {
    /// Pipeline the batched fans on the Sharded plane, no-op elsewhere —
    /// the default.
    #[default]
    Auto,
    On,
    Off,
}

impl PipelinePolicy {
    pub fn parse(s: &str) -> Option<PipelinePolicy> {
        match s.trim().to_ascii_lowercase().as_str() {
            "auto" => Some(PipelinePolicy::Auto),
            "on" => Some(PipelinePolicy::On),
            "off" => Some(PipelinePolicy::Off),
            _ => None,
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            PipelinePolicy::Auto => "auto",
            PipelinePolicy::On => "on",
            PipelinePolicy::Off => "off",
        }
    }

    /// Parse the `PIPELINE` environment variable (unset/empty = `Auto`).
    /// Unrecognized values error — a typo must not silently change the
    /// overlap profile being measured.
    pub fn from_env() -> Result<PipelinePolicy> {
        match std::env::var("PIPELINE") {
            Err(_) => Ok(PipelinePolicy::Auto),
            Ok(raw) if raw.trim().is_empty() => Ok(PipelinePolicy::Auto),
            Ok(raw) => PipelinePolicy::parse(&raw)
                .ok_or_else(|| anyhow!("PIPELINE='{raw}' is not auto|on|off")),
        }
    }

    /// Whether fans should stage the next machine's lane request (`Auto`
    /// resolves to on — parity is unconditional, so there is nothing to
    /// protect by defaulting off).
    pub fn enabled(self) -> bool {
        self != PipelinePolicy::Off
    }
}

/// The `upload=` policy: whether each engine routes pooled small-operand
/// transfers through its staging-ring **upload lane**
/// (`ExecSession::ring_stage` + swap-at-dispatch-boundary — see
/// `runtime::session`) instead of the single-slot pool. Bit-parity is
/// unconditional: the lane performs the exact transfer sequence the slot
/// path would (the stage decision compares against the payload last
/// dispatched, never the back half's stale bytes), so uploads and bytes
/// are identical either way and only the staging structure — what an
/// asynchronous backend can overlap with the in-flight dispatch — changes.
/// `Auto` therefore resolves to on; `Off` forces the single-slot path for
/// diagnostics and A/B measurement (the
/// [`crate::accounting::UploadMeter`] records which ran).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum UploadPolicy {
    /// Route pooled operands through the staging rings on every engine
    /// (coordinator + shards) — the default.
    #[default]
    Auto,
    On,
    Off,
}

impl UploadPolicy {
    pub fn parse(s: &str) -> Option<UploadPolicy> {
        match s.trim().to_ascii_lowercase().as_str() {
            "auto" => Some(UploadPolicy::Auto),
            "on" => Some(UploadPolicy::On),
            "off" => Some(UploadPolicy::Off),
            _ => None,
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            UploadPolicy::Auto => "auto",
            UploadPolicy::On => "on",
            UploadPolicy::Off => "off",
        }
    }

    /// Parse the `UPLOAD` environment variable (unset/empty = `Auto`).
    /// Unrecognized values error — a typo must not silently change the
    /// staging profile being measured.
    pub fn from_env() -> Result<UploadPolicy> {
        match std::env::var("UPLOAD") {
            Err(_) => Ok(UploadPolicy::Auto),
            Ok(raw) if raw.trim().is_empty() => Ok(UploadPolicy::Auto),
            Ok(raw) => UploadPolicy::parse(&raw)
                .ok_or_else(|| anyhow!("UPLOAD='{raw}' is not auto|on|off")),
        }
    }

    /// Whether engines should stage through the rings (`Auto` resolves to
    /// on — parity is unconditional, so there is nothing to protect by
    /// defaulting off).
    pub fn enabled(self) -> bool {
        self != UploadPolicy::Off
    }
}

/// A resolved execution plane (no `Auto` left).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PlaneKind {
    Host,
    Chained,
    Sharded,
}

impl PlaneKind {
    pub fn as_str(self) -> &'static str {
        match self {
            PlaneKind::Host => "host",
            PlaneKind::Chained => "chained",
            PlaneKind::Sharded => "sharded",
        }
    }
}

/// The numerical route a solve takes on its plane (see module docs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Lane {
    /// legacy per-block kernels, host collectives
    Host,
    /// chained kernels, host-bits collectives (the Sharded plane's lane)
    Grouped,
    /// chained kernels, device-resident collectives (single engine)
    Dev,
}

/// Which variance-reduced kernel performs the local sweeps.
///
/// The paper's Appendix E uses SAGA for the local DANE subproblems; SVRG
/// is the Algorithm-1 (DSVRG) choice. Both exist as per-block AOT kernels
/// (Host lane) and chained `[2,d]`-state kernels (Grouped/Dev lanes) with
/// identical interfaces.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LocalSolver {
    Svrg,
    Saga,
}

impl LocalSolver {
    pub fn tag(self) -> &'static str {
        match self {
            LocalSolver::Svrg => "svrg",
            LocalSolver::Saga => "saga",
        }
    }

    /// The chained kernel family implementing this solver's sweeps.
    pub fn kernel(self) -> VrKernel {
        match self {
            LocalSolver::Svrg => VrKernel::Svrg,
            LocalSolver::Saga => VrKernel::Saga,
        }
    }
}

/// A plane-resident vector value: host bits on the Host/Grouped lanes, a
/// device handle on the Dev lane. Conversions are f32-exact both ways;
/// only the metered traffic differs, which is why [`ExecPlane::to_host`]
/// charges the Dev-lane materialize like any other download.
#[derive(Clone, Debug)]
pub enum PlaneVec {
    Host(Vec<f32>),
    Dev(DeviceVec),
}

impl PlaneVec {
    pub fn len(&self) -> usize {
        match self {
            PlaneVec::Host(v) => v.len(),
            PlaneVec::Dev(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Host bits, without a device round trip (errors on a Dev value —
    /// the lane contract guarantees reprs line up; use
    /// [`ExecPlane::to_host`] for a charged materialize).
    pub fn host(&self) -> Result<&[f32]> {
        match self {
            PlaneVec::Host(v) => Ok(v),
            PlaneVec::Dev(v) => bail!("expected host-lane vector, got device handle {v:?}"),
        }
    }

    /// The device handle (errors on a host value).
    pub fn dev(&self) -> Result<&DeviceVec> {
        match self {
            PlaneVec::Dev(v) => Ok(v),
            PlaneVec::Host(_) => bail!("expected device-lane vector, got host bits"),
        }
    }
}

/// Per-machine locals awaiting a collective, in lane representation.
pub enum PlaneLocals {
    Host(Vec<Vec<f32>>),
    Dev(Vec<DeviceVec>),
}

/// The execution plane: engine access + (optional) shard pool + the
/// resolved kind, behind the verb set every algorithm is written against.
pub struct ExecPlane<'e> {
    pub engine: &'e mut Engine,
    /// the shard pool backing the Sharded plane; `Some` on the Host plane
    /// too when the process has one attached (legacy per-machine work
    /// still fans across it — engine affinity is a property of where the
    /// batches live, not of the kernel lane)
    pub shards: Option<&'e ShardPool>,
    kind: PlaneKind,
    /// whether the Sharded draw verb stages one round ahead on the
    /// prefetch lane (resolved from the `prefetch=` key / `PREFETCH` env
    /// by the coordinator; `Auto` = on)
    prefetch: PrefetchPolicy,
    /// whether batched shard fans software-pipeline the next machine's
    /// lane request behind the current machine's pack/upload (resolved
    /// from the `pipeline=` key / `PIPELINE` env; `Auto` = on)
    pipeline: PipelinePolicy,
    /// whether every engine under this plane routes pooled operands
    /// through the staging-ring upload lane (resolved from the `upload=`
    /// key / `UPLOAD` env; `Auto` = on). The coordinator enables the
    /// engine-level lanes to match before handing the plane to a solver.
    upload: UploadPolicy,
}

impl<'e> ExecPlane<'e> {
    /// Resolve `policy` against the attached pool. `Chained` with a pool
    /// is an error (the single-engine pipeline cannot honor shard-resident
    /// batches); `Sharded` without a pool is an error (the coordinator
    /// attaches one — see `Runner::context`).
    pub fn new(
        engine: &'e mut Engine,
        shards: Option<&'e ShardPool>,
        policy: PlanePolicy,
    ) -> Result<ExecPlane<'e>> {
        let kind = match policy {
            PlanePolicy::Auto => {
                if shards.is_some() {
                    PlaneKind::Sharded
                } else {
                    PlaneKind::Chained
                }
            }
            PlanePolicy::Host => PlaneKind::Host,
            PlanePolicy::Chained => {
                ensure!(
                    shards.is_none(),
                    "plane=chained is the single-engine pipeline: unset SHARDS or use plane=sharded"
                );
                PlaneKind::Chained
            }
            PlanePolicy::Sharded => {
                ensure!(shards.is_some(), "plane=sharded needs a shard pool (set SHARDS>=1)");
                PlaneKind::Sharded
            }
        };
        Ok(ExecPlane {
            engine,
            shards,
            kind,
            prefetch: PrefetchPolicy::default(),
            pipeline: PipelinePolicy::default(),
            upload: UploadPolicy::default(),
        })
    }

    /// Set the prefetch policy (builder; the coordinator resolves the
    /// per-run key against the process policy before calling this).
    pub fn with_prefetch(mut self, prefetch: PrefetchPolicy) -> ExecPlane<'e> {
        self.prefetch = prefetch;
        self
    }

    pub fn prefetch(&self) -> PrefetchPolicy {
        self.prefetch
    }

    /// Set the pipeline policy (builder; the coordinator resolves the
    /// per-run key against the process policy before calling this).
    pub fn with_pipeline(mut self, pipeline: PipelinePolicy) -> ExecPlane<'e> {
        self.pipeline = pipeline;
        self
    }

    pub fn pipeline(&self) -> PipelinePolicy {
        self.pipeline
    }

    /// Set the upload-lane policy (builder; the coordinator resolves the
    /// per-run key against the process policy — and flips the engine-level
    /// lanes to match — before calling this).
    pub fn with_upload(mut self, upload: UploadPolicy) -> ExecPlane<'e> {
        self.upload = upload;
        self
    }

    pub fn upload(&self) -> UploadPolicy {
        self.upload
    }

    /// The `Auto` resolution (infallible): Sharded with a pool, Chained
    /// without.
    pub fn auto(engine: &'e mut Engine, shards: Option<&'e ShardPool>) -> ExecPlane<'e> {
        ExecPlane::new(engine, shards, PlanePolicy::Auto).expect("auto resolution is infallible")
    }

    /// The single-engine chained plane (tests/benches).
    pub fn chained(engine: &'e mut Engine) -> ExecPlane<'e> {
        ExecPlane {
            engine,
            shards: None,
            kind: PlaneKind::Chained,
            prefetch: PrefetchPolicy::default(),
            pipeline: PipelinePolicy::default(),
            upload: UploadPolicy::default(),
        }
    }

    /// The legacy per-block host plane (tests/benches/diagnostics).
    pub fn host(engine: &'e mut Engine) -> ExecPlane<'e> {
        ExecPlane {
            engine,
            shards: None,
            kind: PlaneKind::Host,
            prefetch: PrefetchPolicy::default(),
            pipeline: PipelinePolicy::default(),
            upload: UploadPolicy::default(),
        }
    }

    pub fn kind(&self) -> PlaneKind {
        self.kind
    }

    /// The VR-family lane (gradient chains + group-aligned sweeps) for
    /// `(loss, d)` on this plane. Degrades to `Host` when the manifest
    /// lacks the chained artifacts.
    pub fn vr_lane(&self, loss: Loss, d: usize) -> Lane {
        let ready = self.engine.chain_grad_ready(loss.tag(), d)
            && self.engine.chain_vr_ready(loss.tag(), d);
        match self.kind {
            PlaneKind::Host => Lane::Host,
            _ if !ready => Lane::Host,
            PlaneKind::Sharded => Lane::Grouped,
            PlaneKind::Chained => Lane::Dev,
        }
    }

    /// The CG-family lane (gradient chains + normal-matvec chains + the
    /// `redm{M}` reduce for `m` machines). The CG recurrence runs on the
    /// coordinator engine on BOTH device-capable planes — the Sharded
    /// plane fans only the matvec partials — so the Dev lane serves both.
    pub fn cg_lane(&self, loss: Loss, d: usize, m: usize) -> Lane {
        let ready = self.engine.chain_grad_ready(loss.tag(), d)
            && self.engine.chain_nm_ready(d)
            && self.engine.red_ready(m, d);
        match self.kind {
            PlaneKind::Host => Lane::Host,
            _ if !ready => Lane::Host,
            _ => Lane::Dev,
        }
    }

    /// The gradient-only lane: just the `gacc{K}` accumulator chain, no
    /// VR or CG artifacts required. The SGD baselines' mean-gradient
    /// route (one chained fold per machine, one materialize per round on
    /// the Dev lane instead of a tupled download per group).
    pub fn grad_lane(&self, loss: Loss, d: usize) -> Lane {
        let ready = self.engine.chain_grad_ready(loss.tag(), d);
        match self.kind {
            PlaneKind::Host => Lane::Host,
            _ if !ready => Lane::Host,
            PlaneKind::Sharded => Lane::Grouped,
            PlaneKind::Chained => Lane::Dev,
        }
    }

    // ---- the draw verb -------------------------------------------------

    /// THE draw verb — the fifth plane verb next to
    /// upload/dispatch/chain/reduce: draw a fresh minibatch of `b_local`
    /// samples per machine from `streams` and pack it (per `mode`) on the
    /// engine that owns the machine.
    ///
    /// Shard-resident streams generate AND pack on the owning shard — no
    /// coordinator-side `Vec<Sample>` ever exists for a shard-owned
    /// machine; the coordinator receives one metadata stub per machine.
    /// Per-machine streams are independent forks, so moving the draw site
    /// changes no sample: every plane draws the identical sequence.
    /// Sample/memory charges land on the per-machine meters in fixed
    /// machine order and count what was *actually* drawn (a finite stream
    /// may come up short at an epoch boundary), identically on every
    /// plane.
    pub fn draw_batches(
        &mut self,
        streams: &mut MachineStreams,
        meter: &mut ClusterMeter,
        d: usize,
        b_local: usize,
        hold: bool,
        mode: PackMode,
    ) -> Result<Vec<MachineBatch>> {
        match streams {
            MachineStreams::Local(ss) => {
                let mut out = Vec::with_capacity(ss.len());
                for (i, s) in ss.iter_mut().enumerate() {
                    let samples = s.draw_many(b_local);
                    let mut batch = MachineBatch::pack_mode(self.engine, d, &samples, mode)?;
                    charge_draw(meter, i, samples.len() as u64, hold, &mut batch);
                    out.push(batch);
                }
                Ok(out)
            }
            MachineStreams::Sharded { m } => {
                let pool = self
                    .shards
                    .ok_or_else(|| anyhow!("shard-resident streams need a shard pool"))?;
                let fans = shard_draw_fan(
                    pool,
                    *m,
                    d,
                    b_local,
                    mode,
                    self.prefetch.enabled(),
                    self.pipeline.enabled(),
                );
                let mut per: Vec<Option<(u64, usize, usize, ShardBatchMeta)>> =
                    (0..*m).map(|_| None).collect();
                for fan in fans {
                    // elastic wait: a worker death here is healed (revive
                    // or reassign) and the draw fan replayed bit-exactly —
                    // streams live on the surviving lanes
                    for (i, r) in pool.wait_elastic(fan)? {
                        per[i] = Some(r);
                    }
                }
                let mut out = Vec::with_capacity(*m);
                for (i, slot) in per.into_iter().enumerate() {
                    let (drawn, n, n_blocks, batch_meta) = slot
                        .ok_or_else(|| anyhow!("machine {i} missing from its shard's draw fan"))?;
                    let mut stub = MachineBatch::stub(d, n, n_blocks, batch_meta);
                    charge_draw(meter, i, drawn, hold, &mut stub);
                    out.push(stub);
                }
                Ok(out)
            }
        }
    }

    /// The draw verb for ONE machine (single-machine methods like the
    /// ideal-solution local SGD): machine `i`'s stream advances and the
    /// batch packs wherever the machine lives. Same charging rules as
    /// [`ExecPlane::draw_batches`].
    #[allow(clippy::too_many_arguments)]
    pub fn draw_machine(
        &mut self,
        streams: &mut MachineStreams,
        meter: &mut ClusterMeter,
        i: usize,
        d: usize,
        n: usize,
        hold: bool,
        mode: PackMode,
    ) -> Result<MachineBatch> {
        match streams {
            MachineStreams::Local(ss) => {
                let samples = ss[i].draw_many(n);
                let mut batch = MachineBatch::pack_mode(self.engine, d, &samples, mode)?;
                charge_draw(meter, i, samples.len() as u64, hold, &mut batch);
                Ok(batch)
            }
            MachineStreams::Sharded { m } => {
                ensure!(i < *m, "machine {i} out of range for {m} shard-resident streams");
                let pool = self
                    .shards
                    .ok_or_else(|| anyhow!("shard-resident streams need a shard pool"))?;
                let (drawn, bn, n_blocks, batch_meta) =
                    shard_draw_job(pool, i, d, n, mode, self.prefetch.enabled()).wait()?;
                let mut stub = MachineBatch::stub(d, bn, n_blocks, batch_meta);
                charge_draw(meter, i, drawn, hold, &mut stub);
                Ok(stub)
            }
        }
    }

    // ---- PlaneVec plumbing ---------------------------------------------

    /// Bring host bits into lane representation (one upload on the Dev
    /// lane, a copy otherwise).
    pub fn lift(&mut self, lane: Lane, v: &[f32]) -> Result<PlaneVec> {
        match lane {
            Lane::Dev => Ok(PlaneVec::Dev(self.engine.upload_dev(v, &[v.len()])?)),
            _ => Ok(PlaneVec::Host(v.to_vec())),
        }
    }

    /// The lane's zero vector (the cached device zero on the Dev lane —
    /// uploaded once per length, ever).
    pub fn zeros(&mut self, lane: Lane, n: usize) -> Result<PlaneVec> {
        match lane {
            Lane::Dev => Ok(PlaneVec::Dev(self.engine.zeros_dev(n)?)),
            _ => Ok(PlaneVec::Host(vec![0.0; n])),
        }
    }

    /// Host bits of a plane vector — THE materialization point: on the
    /// Dev lane this is a charged download (the only way bytes leave the
    /// device), on host lanes a copy.
    pub fn to_host(&mut self, v: &PlaneVec) -> Result<Vec<f32>> {
        match v {
            PlaneVec::Host(h) => Ok(h.clone()),
            PlaneVec::Dev(d) => self.engine.materialize(d),
        }
    }

    /// [`ExecPlane::to_host`], consuming (no copy on host lanes).
    pub fn into_host(&mut self, v: PlaneVec) -> Result<Vec<f32>> {
        match v {
            PlaneVec::Host(h) => Ok(h),
            PlaneVec::Dev(d) => self.engine.materialize(&d),
        }
    }

    /// `<u, v>` in the lane's native precision: f64 accumulation on host
    /// bits, the f32 `vdot` kernel (one scalar download) on device.
    pub fn dot(&mut self, u: &PlaneVec, v: &PlaneVec) -> Result<f64> {
        match (u, v) {
            (PlaneVec::Host(a), PlaneVec::Host(b)) => Ok(crate::linalg::dot(a, b)),
            (PlaneVec::Dev(a), PlaneVec::Dev(b)) => self.engine.vec_dot(a, b),
            _ => bail!("dot across lanes: materialize first"),
        }
    }

    /// `a*u + b*v` elementwise in f32 — identical bit sequence on both
    /// representations (the host loop mirrors the `vaxpby` kernel).
    pub fn axpby(&mut self, a: f32, u: &PlaneVec, b: f32, v: &PlaneVec) -> Result<PlaneVec> {
        match (u, v) {
            (PlaneVec::Host(x), PlaneVec::Host(y)) => {
                ensure!(x.len() == y.len(), "axpby length mismatch");
                Ok(PlaneVec::Host(
                    x.iter().zip(y).map(|(&xi, &yi)| a * xi + b * yi).collect(),
                ))
            }
            (PlaneVec::Dev(x), PlaneVec::Dev(y)) => {
                Ok(PlaneVec::Dev(self.engine.vec_axpby(a, x, b, y)?))
            }
            _ => bail!("axpby across lanes: materialize first"),
        }
    }

    // ---- collectives (one charged round each; identical accounting on
    // every lane — both arms funnel through the same Network::charge) ----

    /// Average per-machine locals; returns the mean every machine ends
    /// with. One round.
    pub fn all_reduce_avg(
        &mut self,
        net: &mut Network,
        meter: &mut ClusterMeter,
        locals: PlaneLocals,
    ) -> Result<PlaneVec> {
        match locals {
            PlaneLocals::Host(mut ls) => {
                net.all_reduce_avg(meter, &mut ls);
                Ok(PlaneVec::Host(ls.pop().expect("nonempty collective")))
            }
            PlaneLocals::Dev(ls) => {
                Ok(PlaneVec::Dev(net.device_all_reduce_avg(meter, self.engine, &ls)?))
            }
        }
    }

    /// Machine `src`'s value becomes known to all. One round.
    pub fn broadcast(
        &mut self,
        net: &mut Network,
        meter: &mut ClusterMeter,
        src: usize,
        v: PlaneVec,
    ) -> PlaneVec {
        match v {
            PlaneVec::Host(h) => {
                let mut ls: Vec<Vec<f32>> = (0..net.m).map(|_| h.clone()).collect();
                net.broadcast(meter, src, &mut ls);
                PlaneVec::Host(ls.swap_remove(src))
            }
            PlaneVec::Dev(d) => PlaneVec::Dev(net.device_broadcast(meter, src, &d)),
        }
    }

    // ---- gradient verbs ------------------------------------------------

    /// Distributed mean gradient at `z` — one weighted all-reduce round,
    /// on the lane's kernels: legacy tupled dispatches (Host), chained
    /// accumulators with the host collective (Grouped), or the fully
    /// device-resident chain + reduce (Dev).
    pub fn mean_grad(
        &mut self,
        lane: Lane,
        net: &mut Network,
        meter: &mut ClusterMeter,
        loss: Loss,
        batches: &[MachineBatch],
        z: &PlaneVec,
    ) -> Result<PlaneVec> {
        match lane {
            Lane::Dev => Ok(PlaneVec::Dev(distributed_mean_grad_dev(
                self.engine,
                self.shards,
                loss,
                batches,
                z.dev()?,
                net,
                meter,
            )?)),
            Lane::Grouped => Ok(PlaneVec::Host(mean_grad_chained_host(
                self.engine,
                self.shards,
                loss,
                batches,
                z.host()?,
                net,
                meter,
            )?)),
            Lane::Host => Ok(PlaneVec::Host(
                distributed_mean_grad(
                    self.engine,
                    self.shards,
                    loss,
                    batches,
                    z.host()?,
                    net,
                    meter,
                )?
                .0,
            )),
        }
    }

    /// Machine-local mean gradient at `z` on `lane` — NO collective, no
    /// round charged: the single-machine methods' gradient read. Runs the
    /// lane's kernels on machine `i`'s engine (inline, or one job on the
    /// owning shard); Grouped and Dev produce bit-identical results (the
    /// same chain + `vec_scale` kernel sequence on whichever engine owns
    /// the batch).
    pub fn local_mean_grad(
        &mut self,
        lane: Lane,
        meter: &mut ClusterMeter,
        loss: Loss,
        batches: &[MachineBatch],
        i: usize,
        z: &PlaneVec,
    ) -> Result<PlaneVec> {
        match lane {
            Lane::Dev => {
                let batch = &batches[i];
                let gsum =
                    local_grad_sum_dev(self.engine, loss, batch, z.dev()?, meter.machine(i))?;
                let cnt = batch.n as f64;
                let gm = if cnt > 0.0 {
                    self.engine.vec_scale(&gsum, (1.0 / cnt) as f32)?
                } else {
                    gsum
                };
                Ok(PlaneVec::Dev(gm))
            }
            Lane::Grouped => {
                let z_s: Arc<[f32]> = Arc::from(z.host()?);
                let g = fan_machine(
                    self.engine,
                    self.shards,
                    batches,
                    i,
                    meter,
                    move |eng, batch, _i, m| {
                        let z_dev = eng.upload_dev(&z_s, &[z_s.len()])?;
                        let gsum = local_grad_sum_dev(eng, loss, batch, &z_dev, m)?;
                        let cnt = batch.n as f64;
                        let gm = if cnt > 0.0 {
                            eng.vec_scale(&gsum, (1.0 / cnt) as f32)?
                        } else {
                            gsum
                        };
                        eng.materialize(&gm)
                    },
                )?;
                Ok(PlaneVec::Host(g))
            }
            Lane::Host => {
                let z_s: Arc<[f32]> = Arc::from(z.host()?);
                let g = fan_machine(
                    self.engine,
                    self.shards,
                    batches,
                    i,
                    meter,
                    move |eng, batch, _i, m| {
                        let out = local_grad_sum(eng, loss, batch, &z_s, m)?;
                        let cnt = out.count.max(0.0);
                        let mut gm = out.grad_sum;
                        if cnt > 0.0 {
                            crate::linalg::scale((1.0 / cnt) as f32, &mut gm);
                        }
                        Ok(gm)
                    },
                )?;
                Ok(PlaneVec::Host(g))
            }
        }
    }

    // ---- VR sweeps -----------------------------------------------------

    /// Open a designated-machine VR sweep session over `batches` with a
    /// `p`-way batch partition per machine (the DSVRG `(j, s)` token's
    /// sweep side): block ranges on the Host lane, fused-group ranges on
    /// the chained lanes, the carried iterate / `[2,d]` device state held
    /// inside.
    #[allow(clippy::too_many_arguments)]
    pub fn vr_sweeper(
        &mut self,
        lane: Lane,
        batches: &[MachineBatch],
        p: usize,
        kernel: LocalSolver,
        x0: &[f32],
        center: &[f32],
        gamma: f32,
        eta: f32,
    ) -> Result<VrSweeper> {
        let ranges: Vec<Vec<Range<usize>>> = batches
            .iter()
            .map(|b| match lane {
                Lane::Host => batch_ranges(b.n_blocks(), p),
                _ => b.group_ranges(p),
            })
            .collect();
        let (state, center_dev, gamma_dev, eta_dev) = if lane == Lane::Dev {
            (
                Some(self.engine.vr_state_from(x0)?),
                Some(self.engine.upload_dev(center, &[center.len()])?),
                Some(self.engine.scalar_dev(gamma)?),
                Some(self.engine.scalar_dev(eta)?),
            )
        } else {
            (None, None, None, None)
        };
        Ok(VrSweeper {
            lane,
            kernel,
            gamma,
            eta,
            center: center.to_vec(),
            ranges,
            x: x0.to_vec(),
            state,
            center_dev,
            gamma_dev,
            eta_dev,
        })
    }

    /// One DANE-style local solve per machine: `passes` VR sweeps over
    /// each machine's FULL batch seeded at `z` (snapshot `z`, gradient
    /// hint `mu`, prox center `center`, strength `gamma`), returning the
    /// per-machine sweep averages in lane representation. `passes > 1`
    /// re-snapshots on the corrected local gradient and runs on the Host
    /// lane only (callers force `Lane::Host`). `z_host` must carry the
    /// same bits as `z` (the caller's round-boundary materialize) so the
    /// Dev lane can seed its sweep states without an extra download.
    #[allow(clippy::too_many_arguments)]
    pub fn local_sweep_all(
        &mut self,
        lane: Lane,
        meter: &mut ClusterMeter,
        loss: Loss,
        kernel: LocalSolver,
        batches: &[MachineBatch],
        z_host: &[f32],
        z: &PlaneVec,
        mu: &PlaneVec,
        center: &[f32],
        gamma: f32,
        eta: f32,
        passes: usize,
    ) -> Result<PlaneLocals> {
        let d = z_host.len();
        match lane {
            Lane::Dev => {
                ensure!(passes <= 1, "multi-pass local solves run on the host lane");
                let z_dev = z.dev()?;
                let mu_dev = mu.dev()?;
                let c_dev = self.engine.upload_dev(center, &[d])?;
                let gamma_dev = self.engine.scalar_dev(gamma)?;
                let eta_dev = self.engine.scalar_dev(eta)?;
                let mut locals = Vec::with_capacity(batches.len());
                for (i, batch) in batches.iter().enumerate() {
                    locals.push(vr_sweep_avg_dev(
                        self.engine,
                        loss,
                        kernel,
                        0..batch.n_groups(),
                        batch,
                        z_host,
                        z_dev,
                        mu_dev,
                        &c_dev,
                        &gamma_dev,
                        &eta_dev,
                        meter.machine(i),
                    )?);
                }
                Ok(PlaneLocals::Dev(locals))
            }
            Lane::Grouped => {
                ensure!(passes <= 1, "multi-pass local solves run on the host lane");
                let z_s: Arc<[f32]> = Arc::from(z.host()?);
                let g_s: Arc<[f32]> = Arc::from(mu.host()?);
                let c_s: Arc<[f32]> = Arc::from(center);
                let locals = fan_machines(
                    self.engine,
                    self.shards,
                    batches,
                    meter,
                    move |eng, batch, _i, m| {
                        let (_x_end, x_avg) = vr_sweep_machine_grouped(
                            eng,
                            loss,
                            kernel,
                            0..batch.n_groups(),
                            batch,
                            &z_s,
                            &z_s,
                            &g_s,
                            &c_s,
                            gamma,
                            eta,
                            m,
                        )?;
                        Ok(x_avg)
                    },
                )?;
                Ok(PlaneLocals::Host(locals))
            }
            Lane::Host => {
                let z_s: Arc<[f32]> = Arc::from(z.host()?);
                let g_s: Arc<[f32]> = Arc::from(mu.host()?);
                let c_s: Arc<[f32]> = Arc::from(center);
                let passes = passes.max(1);
                let locals = fan_machines(
                    self.engine,
                    self.shards,
                    batches,
                    meter,
                    move |eng, batch, _i, m| {
                        let mut xi = z_s.to_vec();
                        let mut snapshot = z_s.to_vec();
                        let mut mu = g_s.to_vec();
                        for pass in 0..passes {
                            if pass > 0 {
                                // re-snapshot locally:
                                // mu' = grad_i(x) + (g - grad_i(z))
                                let gi_z =
                                    crate::objective::local_grad_sum(eng, loss, batch, &z_s, m)?;
                                let gi_x =
                                    crate::objective::local_grad_sum(eng, loss, batch, &xi, m)?;
                                let cnt = gi_z.count.max(1.0) as f32;
                                mu = g_s.to_vec();
                                for j in 0..d {
                                    mu[j] += gi_x.grad_sum[j] / cnt - gi_z.grad_sum[j] / cnt;
                                }
                                snapshot = xi.clone();
                            }
                            let blocks = 0..batch.n_blocks();
                            let (_x_end, x_avg) = vr_sweep_machine(
                                eng, loss, kernel, blocks, batch, &xi, &snapshot, &mu, &c_s,
                                gamma, eta, m,
                            )?;
                            xi = x_avg;
                        }
                        Ok(xi)
                    },
                )?;
                Ok(PlaneLocals::Host(locals))
            }
        }
    }
}

/// The draw verb's ONE charging rule: count what was actually drawn on
/// machine `i`'s meter (holding if requested) and record the hold on the
/// batch itself, so `release_batches` can return exactly it — a ragged
/// final batch can never corrupt the peak-memory meter, on any plane.
fn charge_draw(
    meter: &mut ClusterMeter,
    i: usize,
    drawn: u64,
    hold: bool,
    batch: &mut MachineBatch,
) {
    let mm = meter.machine(i);
    mm.add_samples(drawn);
    if hold {
        mm.hold(drawn);
    }
    batch.held = if hold { drawn } else { 0 };
}

/// Submit machine `i`'s draw+pack to its owning shard: the worker asks
/// the shard's prefetch lane for the packed host blocks (a staged hit
/// when the lane ran ahead, a synchronous draw+pack otherwise — identical
/// samples either way; see `runtime::shard`), times the wait as this
/// round's dispatch stall, uploads/fuses per `mode` on the shard's engine
/// and stores the batch in the shard's batch map; only
/// `(drawn, n, n_blocks, meta)` — pure bookkeeping — crosses back to the
/// coordinator.
fn shard_draw_job(
    pool: &ShardPool,
    i: usize,
    d: usize,
    n: usize,
    mode: PackMode,
    prefetch: bool,
) -> Pending<(u64, usize, usize, ShardBatchMeta)> {
    pool.submit_named(pool.shard_of(i), &format!("machine {i} draw"), move |state| {
        let t0 = Instant::now();
        let reply = state.lane.take(i, n, d, prefetch)?;
        state.stalls.record(reply.hit, t0.elapsed().as_nanos() as u64);
        let t1 = Instant::now();
        let batch = MachineBatch::pack_blocks_mode(&mut state.engine, d, reply.blocks, mode)?;
        state.overlap.record(false, t1.elapsed().as_nanos() as u64);
        let out = (reply.drawn, batch.n, batch.n_blocks(), batch.shard_meta(i));
        state.batches.insert(i, batch);
        Ok(out)
    })
}

/// The batched draw fan: ONE job per shard covering every machine that
/// shard owns (ascending machine order — identical per-shard execution
/// order to the old one-job-per-machine interleaving, so samples, bytes
/// and meters are bit-for-bit unchanged). With `pipeline` on, the worker
/// software-pipelines the loop: machine k+1's lane request is issued the
/// moment machine k's reply is collected, so the lane draws/packs k+1's
/// blocks WHILE the engine thread uploads and fuses k's — the engine-work
/// slice is recorded on the shard's [`crate::accounting::OverlapMeter`] as
/// overlapped. The request is issued only AFTER the previous collect, so
/// lane commands arrive in the identical FIFO order as the serial loop
/// (`pipeline=off`) and the two paths are bit-identical by construction.
fn shard_draw_fan(
    pool: &ShardPool,
    m: usize,
    d: usize,
    n: usize,
    mode: PackMode,
    prefetch: bool,
    pipeline: bool,
) -> Vec<FanBatch<(u64, usize, usize, ShardBatchMeta)>> {
    pool.fan_batches_raw(m, "machine draw fan", move |state, machines| {
        let mut out = Vec::with_capacity(machines.len());
        if !pipeline {
            for &i in machines {
                let t0 = Instant::now();
                let reply = state.lane.take(i, n, d, prefetch)?;
                state.stalls.record(reply.hit, t0.elapsed().as_nanos() as u64);
                let t1 = Instant::now();
                let batch =
                    MachineBatch::pack_blocks_mode(&mut state.engine, d, reply.blocks, mode)?;
                state.overlap.record(false, t1.elapsed().as_nanos() as u64);
                out.push((i, (reply.drawn, batch.n, batch.n_blocks(), batch.shard_meta(i))));
                state.batches.insert(i, batch);
            }
            return Ok(out);
        }
        let mut tickets: VecDeque<LaneTicket> = VecDeque::with_capacity(1);
        tickets.push_back(state.lane.request(machines[0], n, d, prefetch)?);
        for (idx, &i) in machines.iter().enumerate() {
            let ticket = tickets.pop_front().expect("one ticket in flight per collect");
            let t0 = Instant::now();
            let reply = ticket.collect()?;
            state.stalls.record(reply.hit, t0.elapsed().as_nanos() as u64);
            if let Some(&next) = machines.get(idx + 1) {
                tickets.push_back(state.lane.request(next, n, d, prefetch)?);
            }
            let staged = !tickets.is_empty();
            let t1 = Instant::now();
            let batch = MachineBatch::pack_blocks_mode(&mut state.engine, d, reply.blocks, mode)?;
            state.overlap.record(staged, t1.elapsed().as_nanos() as u64);
            out.push((i, (reply.drawn, batch.n, batch.n_blocks(), batch.shard_meta(i))));
            state.batches.insert(i, batch);
        }
        Ok(out)
    })
}

/// Split a machine's block list into `p` near-equal contiguous batches
/// (batch granularity is whole 256-row blocks) — the Host lane's sweep
/// partition; the chained lanes use the group-range equivalent
/// ([`MachineBatch::group_ranges`]).
pub fn batch_ranges(n_blocks: usize, p: usize) -> Vec<Range<usize>> {
    let p = p.clamp(1, n_blocks.max(1));
    crate::data::sampler::shard_ranges(n_blocks, p)
}

/// A designated-machine VR sweep session (see [`ExecPlane::vr_sweeper`]):
/// holds the sweep partition, the solve-constant operands and the carried
/// state, so the solver's `(j, s)` token loop is lane-free.
pub struct VrSweeper {
    lane: Lane,
    kernel: LocalSolver,
    gamma: f32,
    eta: f32,
    /// prox center, host bits (the Dev lane also holds a resident handle)
    center: Vec<f32>,
    /// per-machine sweep partition: block ranges (Host lane) or fused
    /// group ranges (Grouped/Dev)
    ranges: Vec<Vec<Range<usize>>>,
    /// Host/Grouped lanes: the carried iterate x
    x: Vec<f32>,
    /// Dev lane: the carried `[2, d]` sweep state
    state: Option<DeviceVec>,
    center_dev: Option<DeviceVec>,
    gamma_dev: Option<DeviceVec>,
    eta_dev: Option<DeviceVec>,
}

impl VrSweeper {
    /// Number of sweep batches machine `j` holds (the `s` token bound).
    pub fn n_batches(&self, j: usize) -> usize {
        self.ranges[j].len()
    }

    pub fn lane(&self) -> Lane {
        self.lane
    }

    /// Sweep machine `j`'s batch `s` once at snapshot `z` with gradient
    /// `mu`; returns the sweep average (the next iterate) and carries the
    /// end-of-sweep state for the next call. Runs inline on the
    /// coordinator engine or on machine `j`'s shard, whichever owns the
    /// batch.
    #[allow(clippy::too_many_arguments)]
    pub fn sweep(
        &mut self,
        plane: &mut ExecPlane,
        meter: &mut ClusterMeter,
        loss: Loss,
        batches: &[MachineBatch],
        j: usize,
        s: usize,
        z: &PlaneVec,
        mu: &PlaneVec,
    ) -> Result<PlaneVec> {
        let range = self.ranges[j][s.min(self.ranges[j].len() - 1)].clone();
        match self.lane {
            Lane::Dev => {
                // fresh accumulator, carried iterate
                let state = self.state.take().expect("Dev-lane sweeper holds a state");
                let state = plane.engine.vr_reset(&state)?;
                let total_w = sweep_groups_weight(&batches[j], range.clone());
                let state = vr_sweep_groups(
                    plane.engine,
                    loss,
                    self.kernel,
                    range,
                    &batches[j],
                    state,
                    z.dev()?,
                    mu.dev()?,
                    self.center_dev.as_ref().expect("Dev-lane center"),
                    self.gamma_dev.as_ref().expect("Dev-lane gamma"),
                    self.eta_dev.as_ref().expect("Dev-lane eta"),
                    meter.machine(j),
                )?;
                // sweep average (inv weight 0 = empty-sweep fallback to
                // the carried iterate)
                let inv_w = if total_w > 0.0 { (1.0 / total_w) as f32 } else { 0.0 };
                let avg = plane.engine.vr_avg(&state, inv_w)?;
                self.state = Some(state);
                Ok(PlaneVec::Dev(avg))
            }
            // the two host-representation lanes differ ONLY in which
            // sweep primitive advances the iterate
            Lane::Grouped => self.sweep_host_repr(
                plane,
                meter,
                loss,
                batches,
                j,
                range,
                z,
                mu,
                vr_sweep_machine_grouped,
            ),
            Lane::Host => {
                self.sweep_host_repr(plane, meter, loss, batches, j, range, z, mu, vr_sweep_machine)
            }
        }
    }

    /// The shared host-representation arm: run `sweep` on machine `j`'s
    /// batch — inline on the coordinator engine, or as one job on the
    /// owning shard (the closure owns its operands) — and carry `x_end`.
    #[allow(clippy::too_many_arguments)]
    fn sweep_host_repr(
        &mut self,
        plane: &mut ExecPlane,
        meter: &mut ClusterMeter,
        loss: Loss,
        batches: &[MachineBatch],
        j: usize,
        range: Range<usize>,
        z: &PlaneVec,
        mu: &PlaneVec,
        sweep: HostSweepFn,
    ) -> Result<PlaneVec> {
        let (x_end, x_avg) = if batches[j].shard.is_none() {
            sweep(
                plane.engine,
                loss,
                self.kernel,
                range,
                &batches[j],
                &self.x,
                z.host()?,
                mu.host()?,
                &self.center,
                self.gamma,
                self.eta,
                meter.machine(j),
            )?
        } else {
            let (kernel, gamma, eta) = (self.kernel, self.gamma, self.eta);
            let x0 = self.x.clone();
            let (zv, muv) = (z.host()?.to_vec(), mu.host()?.to_vec());
            let cv = self.center.clone();
            fan_machine(
                plane.engine,
                plane.shards,
                batches,
                j,
                meter,
                move |eng, batch, _i, m| {
                    sweep(eng, loss, kernel, range, batch, &x0, &zv, &muv, &cv, gamma, eta, m)
                },
            )?
        };
        self.x = x_end;
        Ok(PlaneVec::Host(x_avg))
    }
}

/// A host-representation sweep primitive ([`vr_sweep_machine`] per-block
/// or [`vr_sweep_machine_grouped`]): the one signature both host-repr
/// lanes dispatch through, so the inline-vs-shard plumbing exists once.
type HostSweepFn = fn(
    &mut Engine,
    Loss,
    LocalSolver,
    Range<usize>,
    &MachineBatch,
    &[f32],
    &[f32],
    &[f32],
    &[f32],
    f32,
    f32,
    &mut ResourceMeter,
) -> Result<(Vec<f32>, Vec<f32>)>;

// ---- the sweep primitives (one implementation each, shared by every
// lane arm above and by the parity tests) -------------------------------

/// Sweep one machine's blocks with per-block variance-reduced passes
/// (SVRG or SAGA kernels) — the Host lane's sweep.
///
/// Runs the artifact block-by-block, carrying the iterate through, and
/// combines per-block running averages weighted by their (1 + valid)
/// counts — the paper's z_k average over r = 0..|B_s|. Returns
/// `(x_end, x_avg)` and charges the swept rows to `meter`.
///
/// Takes the engine and the machine's meter directly (not a run context)
/// so the identical code runs inline on the coordinator OR inside a shard
/// job — the shard plane's per-machine closures are exactly these
/// helpers.
#[allow(clippy::too_many_arguments)]
pub fn vr_sweep_machine(
    engine: &mut Engine,
    loss: Loss,
    solver: LocalSolver,
    batch_blocks: Range<usize>,
    batch: &MachineBatch,
    x0: &[f32],
    z: &[f32],
    mu: &[f32],
    center: &[f32],
    gamma: f32,
    eta: f32,
    meter: &mut ResourceMeter,
) -> Result<(Vec<f32>, Vec<f32>)> {
    let mut x = x0.to_vec();
    let mut avg = crate::linalg::WeightedAvg::new(batch.d);
    let mut total_n = 0u64;
    // per-block buffers, materialized on the batch's first sweep
    let lits = batch.vr_lits(engine)?;
    for bi in batch_blocks {
        let blk = &lits[bi];
        if blk.valid == 0 {
            continue;
        }
        let (x_end, x_avg) = match solver {
            LocalSolver::Svrg => engine.svrg_block(loss, blk, &x, z, mu, center, gamma, eta)?,
            LocalSolver::Saga => engine.saga_block(loss, blk, &x, z, mu, center, gamma, eta)?,
        };
        avg.add((1 + blk.valid) as f64, &x_avg);
        total_n += blk.valid as u64;
        x = x_end;
    }
    drop(lits);
    meter.add_vec_ops(total_n);
    let x_avg = if avg.total_weight() > 0.0 { avg.mean() } else { x.clone() };
    Ok((x, x_avg))
}

/// Chained core of the group-aligned VR sweep: advance the `[2, d]` state
/// through `batch.groups[group_range]` riding the *fused* block uploads —
/// no `vr_lits` materialization, no downloads, no host round-trips
/// between groups. Returns the advanced state; divide by
/// [`sweep_groups_weight`] (via `Engine::vr_avg`) for the sweep average.
/// Charges the swept valid rows to `meter`, like the Host lane.
#[allow(clippy::too_many_arguments)]
pub fn vr_sweep_groups(
    engine: &mut Engine,
    loss: Loss,
    solver: LocalSolver,
    group_range: Range<usize>,
    batch: &MachineBatch,
    state: DeviceVec,
    z: &DeviceVec,
    mu: &DeviceVec,
    center: &DeviceVec,
    gamma: &DeviceVec,
    eta: &DeviceVec,
    meter: &mut ResourceMeter,
) -> Result<DeviceVec> {
    let mut s = state;
    let mut total_n = 0u64;
    for gi in group_range {
        let blk = &batch.groups[gi];
        if blk.valid == 0 {
            continue;
        }
        s = engine.vr_chain(solver.kernel(), loss, blk, &s, z, mu, center, gamma, eta)?;
        total_n += blk.valid as u64;
    }
    meter.add_vec_ops(total_n);
    Ok(s)
}

/// Total sweep-average weight of `batch.groups[group_range]`: the
/// host-side divisor for the chained accumulator (`1 + valid` per
/// non-empty block, matching the Host-lane combiner). Stub-safe — the
/// weights ride the batch metadata, so the coordinator can compute the
/// divisor for a shard-resident batch.
pub fn sweep_groups_weight(batch: &MachineBatch, group_range: Range<usize>) -> f64 {
    group_range.map(|gi| batch.group_sweep_weight(gi)).sum()
}

/// Host-level wrapper over the chained sweep: uploads the state and the
/// sweep-constant vectors, chains through the groups, and materializes
/// `(x_end, x_avg)` — one `[2, d]` download per *sweep* instead of two
/// `[d]` downloads per *block*. Semantics match [`vr_sweep_machine`] over
/// the same blocks (the parity tests pin this down), and the host average
/// (one f32 multiply per element) is bit-identical to the `vr_avg`
/// kernel's, so a shard job running this reproduces the single-engine
/// chained path exactly — the Grouped lane's sweep.
#[allow(clippy::too_many_arguments)]
pub fn vr_sweep_machine_grouped(
    engine: &mut Engine,
    loss: Loss,
    solver: LocalSolver,
    group_range: Range<usize>,
    batch: &MachineBatch,
    x0: &[f32],
    z: &[f32],
    mu: &[f32],
    center: &[f32],
    gamma: f32,
    eta: f32,
    meter: &mut ResourceMeter,
) -> Result<(Vec<f32>, Vec<f32>)> {
    let d = batch.d;
    let state = engine.vr_state_from(x0)?;
    let z_dev = engine.upload_dev(z, &[d])?;
    let mu_dev = engine.upload_dev(mu, &[d])?;
    let c_dev = engine.upload_dev(center, &[d])?;
    // sweep-constant scalars: uploaded once per sweep, not per group
    let gamma_dev = engine.scalar_dev(gamma)?;
    let eta_dev = engine.scalar_dev(eta)?;
    let total_w = sweep_groups_weight(batch, group_range.clone());
    let s = vr_sweep_groups(
        engine,
        loss,
        solver,
        group_range,
        batch,
        state,
        &z_dev,
        &mu_dev,
        &c_dev,
        &gamma_dev,
        &eta_dev,
        meter,
    )?;
    let host = engine.materialize(&s)?;
    let (x_end, acc) = host.split_at(d);
    let x_avg = if total_w > 0.0 {
        let inv = (1.0 / total_w) as f32;
        acc.iter().map(|&a| a * inv).collect()
    } else {
        x_end.to_vec()
    };
    Ok((x_end.to_vec(), x_avg))
}

/// One chained sweep-plus-average, fully on device: seed the `[2, d]`
/// state from the host iterate `x0`, advance it through
/// `batch.groups[group_range]`, and return the sweep average as a handle
/// (`vr_avg`, with the empty-sweep fallback to the carried iterate). The
/// ONE implementation of the parity-sensitive sweep-average sequence —
/// the Dev-lane DANE and one-shot local solves both run exactly this, so
/// the cross-plane bitwise contract cannot drift between them.
#[allow(clippy::too_many_arguments)]
pub fn vr_sweep_avg_dev(
    engine: &mut Engine,
    loss: Loss,
    solver: LocalSolver,
    group_range: Range<usize>,
    batch: &MachineBatch,
    x0: &[f32],
    z: &DeviceVec,
    mu: &DeviceVec,
    center: &DeviceVec,
    gamma: &DeviceVec,
    eta: &DeviceVec,
    meter: &mut ResourceMeter,
) -> Result<DeviceVec> {
    let state = engine.vr_state_from(x0)?;
    let total_w = sweep_groups_weight(batch, group_range.clone());
    let state = vr_sweep_groups(
        engine,
        loss,
        solver,
        group_range,
        batch,
        state,
        z,
        mu,
        center,
        gamma,
        eta,
        meter,
    )?;
    let inv_w = if total_w > 0.0 { (1.0 / total_w) as f32 } else { 0.0 };
    engine.vr_avg(&state, inv_w)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn policy_parses_and_round_trips() {
        for p in [PlanePolicy::Auto, PlanePolicy::Host, PlanePolicy::Chained, PlanePolicy::Sharded]
        {
            assert_eq!(PlanePolicy::parse(p.as_str()), Some(p));
        }
        assert_eq!(PlanePolicy::parse(" Host "), Some(PlanePolicy::Host));
        assert_eq!(PlanePolicy::parse("hots"), None);
    }

    #[test]
    fn prefetch_policy_parses_and_resolves() {
        for p in [PrefetchPolicy::Auto, PrefetchPolicy::On, PrefetchPolicy::Off] {
            assert_eq!(PrefetchPolicy::parse(p.as_str()), Some(p));
        }
        assert_eq!(PrefetchPolicy::parse(" ON "), Some(PrefetchPolicy::On));
        assert_eq!(PrefetchPolicy::parse("of"), None);
        // Auto resolves to on: parity is unconditional, only stalls differ
        assert!(PrefetchPolicy::Auto.enabled());
        assert!(PrefetchPolicy::On.enabled());
        assert!(!PrefetchPolicy::Off.enabled());
        assert_eq!(PrefetchPolicy::default(), PrefetchPolicy::Auto);
    }

    #[test]
    fn pipeline_policy_parses_and_resolves() {
        for p in [PipelinePolicy::Auto, PipelinePolicy::On, PipelinePolicy::Off] {
            assert_eq!(PipelinePolicy::parse(p.as_str()), Some(p));
        }
        assert_eq!(PipelinePolicy::parse(" ON "), Some(PipelinePolicy::On));
        assert_eq!(PipelinePolicy::parse("onn"), None);
        // Auto resolves to on: parity is unconditional, only overlap differs
        assert!(PipelinePolicy::Auto.enabled());
        assert!(PipelinePolicy::On.enabled());
        assert!(!PipelinePolicy::Off.enabled());
        assert_eq!(PipelinePolicy::default(), PipelinePolicy::Auto);
    }

    #[test]
    fn upload_policy_parses_and_resolves() {
        for p in [UploadPolicy::Auto, UploadPolicy::On, UploadPolicy::Off] {
            assert_eq!(UploadPolicy::parse(p.as_str()), Some(p));
        }
        assert_eq!(UploadPolicy::parse(" ON "), Some(UploadPolicy::On));
        assert_eq!(UploadPolicy::parse("uploda"), None);
        // Auto resolves to on: parity is unconditional, only staging differs
        assert!(UploadPolicy::Auto.enabled());
        assert!(UploadPolicy::On.enabled());
        assert!(!UploadPolicy::Off.enabled());
        assert_eq!(UploadPolicy::default(), UploadPolicy::Auto);
    }

    #[test]
    fn batch_ranges_partition_blocks() {
        let r = batch_ranges(10, 3);
        assert_eq!(r.len(), 3);
        assert_eq!(r[0].start, 0);
        assert_eq!(r.last().unwrap().end, 10);
        // p clamps to the block count
        assert_eq!(batch_ranges(2, 5).len(), 2);
        assert_eq!(batch_ranges(0, 3).len(), 1);
    }
}
