//! Deterministic, splittable PRNG for the simulated i.i.d. sample streams.
//!
//! The paper's setting gives every machine an independent stream from the
//! same distribution D (a "button" generating examples). We model that with
//! one root seed split into per-machine/per-purpose streams via SplitMix64,
//! each stream driven by Xoshiro256++ (Blackman & Vigna). In-tree because
//! the image is offline and the `rand` crate is unavailable; the
//! implementations follow the published reference algorithms.

/// SplitMix64: used for seeding / stream splitting.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// Xoshiro256++ — the per-stream generator.
#[derive(Clone, Debug)]
pub struct Prng {
    s: [u64; 4],
    /// cached second normal from Box-Muller
    spare_normal: Option<f64>,
}

impl Prng {
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self { s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()], spare_normal: None }
    }

    /// Derive an independent child stream; `tag` distinguishes purposes
    /// (machine id, dataset half, sampler epoch, ...).
    pub fn split(&self, tag: u64) -> Prng {
        // mix current state with the tag through SplitMix64
        let mut sm = SplitMix64::new(
            self.s[0] ^ self.s[2].rotate_left(17) ^ tag.wrapping_mul(0x9E3779B97F4A7C15),
        );
        Prng::seed_from_u64(sm.next_u64())
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = (self.s[0].wrapping_add(self.s[3]))
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        // 53 high bits -> [0,1)
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in [0, n). Lemire-style rejection-free enough for
    /// simulation purposes (modulo bias negligible for n << 2^64).
    #[inline]
    pub fn next_below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn next_normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        loop {
            let u1 = self.next_f64();
            let u2 = self.next_f64();
            if u1 > f64::MIN_POSITIVE {
                let r = (-2.0 * u1.ln()).sqrt();
                let theta = 2.0 * std::f64::consts::PI * u2;
                self.spare_normal = Some(r * theta.sin());
                return r * theta.cos();
            }
        }
    }

    pub fn next_normal_f32(&mut self) -> f32 {
        self.next_normal() as f32
    }

    /// Pareto(scale = 1, tail index `alpha`) via inverse CDF: u^(-1/alpha)
    /// with u ~ U(0,1). Second moment is finite iff alpha > 2, with
    /// E[X^2] = alpha / (alpha - 2) — the heavy-tailed covariate streams
    /// divide by its square root to keep E‖x‖² pinned.
    pub fn next_pareto(&mut self, alpha: f64) -> f64 {
        debug_assert!(alpha > 0.0);
        loop {
            let u = self.next_f64();
            if u > 0.0 {
                return u.powf(-1.0 / alpha);
            }
        }
    }

    /// In-place Fisher-Yates shuffle (used by the without-replacement
    /// samplers that Algorithm 1 step 2 requires).
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below(i + 1);
            xs.swap(i, j);
        }
    }

    /// A random permutation of 0..n.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut p: Vec<usize> = (0..n).collect();
        self.shuffle(&mut p);
        p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Prng::seed_from_u64(42);
        let mut b = Prng::seed_from_u64(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn split_streams_differ() {
        let root = Prng::seed_from_u64(7);
        let mut a = root.split(0);
        let mut b = root.split(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn uniform_mean_and_range() {
        let mut r = Prng::seed_from_u64(1);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        assert!((sum / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn normal_moments() {
        let mut r = Prng::seed_from_u64(2);
        let n = 50_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let z = r.next_normal();
            s1 += z;
            s2 += z * z;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn permutation_is_bijection() {
        let mut r = Prng::seed_from_u64(3);
        let p = r.permutation(257);
        let mut seen = vec![false; 257];
        for &i in &p {
            assert!(!seen[i]);
            seen[i] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn pareto_moments() {
        let mut r = Prng::seed_from_u64(5);
        let alpha = 4.0;
        let n = 50_000;
        let mut s2 = 0.0;
        for _ in 0..n {
            let x = r.next_pareto(alpha);
            assert!(x >= 1.0);
            s2 += x * x;
        }
        // E[X^2] = alpha/(alpha-2) = 2; heavy tails make this slow, so
        // the tolerance is loose
        let m2 = s2 / n as f64;
        assert!((m2 - 2.0).abs() < 0.4, "E[X^2] = {m2}");
    }

    #[test]
    fn next_below_in_range() {
        let mut r = Prng::seed_from_u64(4);
        for _ in 0..1000 {
            assert!(r.next_below(17) < 17);
        }
    }
}
