//! In-tree property-test harness (offline image: no proptest).
//!
//! `forall(cases, |prng| ...)` runs a closure over `cases` independent PRNG
//! streams derived from a fixed root seed; on failure it reports the case
//! seed so the exact case replays with `replay(seed, ...)`. Shrinking is
//! intentionally out of scope — cases are seed-addressed and deterministic.

use super::prng::Prng;

pub const DEFAULT_CASES: usize = 64;
const ROOT_SEED: u64 = 0x4d42_5052_4f58; // "MBPROX"

/// Run `f` over `cases` independent deterministic PRNG streams; panic with
/// the offending seed on the first failure.
pub fn forall<F: FnMut(&mut Prng)>(cases: usize, mut f: F) {
    let root = Prng::seed_from_u64(ROOT_SEED);
    for case in 0..cases {
        let mut rng = root.split(case as u64);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&mut rng)));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .map(|s| s.as_str())
                .or_else(|| e.downcast_ref::<&str>().copied())
                .unwrap_or("<non-string panic>");
            panic!("property failed on case {case} (replay with forall_case({case})): {msg}");
        }
    }
}

/// Re-run a single failing case.
pub fn forall_case<F: FnMut(&mut Prng)>(case: usize, mut f: F) {
    let root = Prng::seed_from_u64(ROOT_SEED);
    let mut rng = root.split(case as u64);
    f(&mut rng);
}

/// Random vector helpers used across property tests.
pub fn normal_vec(rng: &mut Prng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.next_normal_f32()).collect()
}

pub fn uniform_vec(rng: &mut Prng, n: usize, lo: f32, hi: f32) -> Vec<f32> {
    (0..n).map(|_| lo + (hi - lo) * rng.next_f32()).collect()
}

pub fn assert_close(a: &[f32], b: &[f32], rtol: f32, atol: f32) {
    assert_eq!(a.len(), b.len(), "length mismatch: {} vs {}", a.len(), b.len());
    for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
        let tol = atol + rtol * y.abs().max(x.abs());
        assert!(
            (x - y).abs() <= tol,
            "element {i}: {x} vs {y} (|diff|={} > tol={tol})",
            (x - y).abs()
        );
    }
}

pub fn assert_close_scalar(x: f64, y: f64, rtol: f64, atol: f64) {
    let tol = atol + rtol * y.abs().max(x.abs());
    assert!((x - y).abs() <= tol, "{x} vs {y} (|diff|={} > tol={tol})", (x - y).abs());
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_runs_all_cases() {
        let mut n = 0;
        forall(10, |_| n += 1);
        assert_eq!(n, 10);
    }

    #[test]
    #[should_panic(expected = "property failed on case")]
    fn forall_reports_failures() {
        forall(8, |rng| {
            // fails on some case with overwhelming probability
            assert!(rng.next_f64() < 0.5);
        });
    }

    #[test]
    fn replay_matches_forall_stream() {
        let mut from_forall = Vec::new();
        forall(3, |rng| from_forall.push(rng.next_u64()));
        for (case, expected) in from_forall.iter().enumerate() {
            forall_case(case, |rng| assert_eq!(rng.next_u64(), *expected));
        }
    }

    #[test]
    fn assert_close_accepts_equal() {
        assert_close(&[1.0, 2.0], &[1.0, 2.0], 0.0, 0.0);
    }

    #[test]
    #[should_panic]
    fn assert_close_rejects_far() {
        assert_close(&[1.0], &[2.0], 1e-6, 1e-6);
    }
}
