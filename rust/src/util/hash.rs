//! Stable 64-bit content hashing (FNV-1a): the crate's canonical hash
//! for content-addressed cache keys. `std::hash` is deliberately NOT
//! used here — its output is unspecified across releases and per-process
//! randomized for HashMap, while a content address must be stable (it is
//! serialized into `/stats` output and compared across processes).

/// Incremental FNV-1a 64-bit hasher.
#[derive(Clone, Debug)]
pub struct Fnv64 {
    state: u64,
}

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

impl Default for Fnv64 {
    fn default() -> Self {
        Fnv64 { state: FNV_OFFSET }
    }
}

impl Fnv64 {
    pub fn new() -> Fnv64 {
        Fnv64::default()
    }

    pub fn update(&mut self, bytes: &[u8]) -> &mut Fnv64 {
        for &b in bytes {
            self.state ^= b as u64;
            self.state = self.state.wrapping_mul(FNV_PRIME);
        }
        self
    }

    /// Feed a length-prefixed field: two byte strings concatenated must
    /// not collide with a different split of the same bytes.
    pub fn field(&mut self, bytes: &[u8]) -> &mut Fnv64 {
        self.update(&(bytes.len() as u64).to_le_bytes());
        self.update(bytes)
    }

    pub fn finish(&self) -> u64 {
        self.state
    }
}

/// One-shot FNV-1a 64 of a byte string.
pub fn fnv1a_64(bytes: &[u8]) -> u64 {
    let mut h = Fnv64::new();
    h.update(bytes);
    h.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // published FNV-1a 64 test vectors (Noll's reference suite) — the
        // empty string pins the offset basis, the single bytes pin the
        // xor-then-multiply order (FNV-1a, not FNV-1), and the "fo"…
        // "foobar" ladder pins the per-byte chaining
        assert_eq!(fnv1a_64(b""), 0xcbf29ce484222325);
        assert_eq!(fnv1a_64(b"a"), 0xaf63dc4c8601ec8c);
        assert_eq!(fnv1a_64(b"b"), 0xaf63df4c8601f1a5);
        assert_eq!(fnv1a_64(b"c"), 0xaf63de4c8601eff2);
        assert_eq!(fnv1a_64(b"\x00"), 0xaf63bd4c8601b7df);
        assert_eq!(fnv1a_64(b"fo"), 0x08985907b541d342);
        assert_eq!(fnv1a_64(b"foo"), 0xdcb27518fed9d577);
        assert_eq!(fnv1a_64(b"foob"), 0xdd120e790c2512af);
        assert_eq!(fnv1a_64(b"fooba"), 0xcac165afa2fef40a);
        assert_eq!(fnv1a_64(b"foobar"), 0x85944171f73967e8);
        assert_eq!(fnv1a_64(b"chongo was here!\n"), 0x46810940eff5f915);
        assert_eq!(fnv1a_64(b"64 bit FNV-1a"), 0xac0e8a6f5833bb23);
    }

    #[test]
    fn incremental_matches_one_shot() {
        let mut h = Fnv64::new();
        h.update(b"foo").update(b"bar");
        assert_eq!(h.finish(), fnv1a_64(b"foobar"));
    }

    #[test]
    fn field_prefix_breaks_concatenation_collisions() {
        let mut a = Fnv64::new();
        a.field(b"ab").field(b"c");
        let mut b = Fnv64::new();
        b.field(b"a").field(b"bc");
        assert_ne!(a.finish(), b.finish());
    }
}
