//! Stable 64-bit content hashing (FNV-1a): the crate's canonical hash
//! for content-addressed cache keys. `std::hash` is deliberately NOT
//! used here — its output is unspecified across releases and per-process
//! randomized for HashMap, while a content address must be stable (it is
//! serialized into `/stats` output and compared across processes).

/// Incremental FNV-1a 64-bit hasher.
#[derive(Clone, Debug)]
pub struct Fnv64 {
    state: u64,
}

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

impl Default for Fnv64 {
    fn default() -> Self {
        Fnv64 { state: FNV_OFFSET }
    }
}

impl Fnv64 {
    pub fn new() -> Fnv64 {
        Fnv64::default()
    }

    pub fn update(&mut self, bytes: &[u8]) -> &mut Fnv64 {
        for &b in bytes {
            self.state ^= b as u64;
            self.state = self.state.wrapping_mul(FNV_PRIME);
        }
        self
    }

    /// Feed a length-prefixed field: two byte strings concatenated must
    /// not collide with a different split of the same bytes.
    pub fn field(&mut self, bytes: &[u8]) -> &mut Fnv64 {
        self.update(&(bytes.len() as u64).to_le_bytes());
        self.update(bytes)
    }

    pub fn finish(&self) -> u64 {
        self.state
    }
}

/// One-shot FNV-1a 64 of a byte string.
pub fn fnv1a_64(bytes: &[u8]) -> u64 {
    let mut h = Fnv64::new();
    h.update(bytes);
    h.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // published FNV-1a 64 test vectors
        assert_eq!(fnv1a_64(b""), 0xcbf29ce484222325);
        assert_eq!(fnv1a_64(b"a"), 0xaf63dc4c8601ec8c);
        assert_eq!(fnv1a_64(b"foobar"), 0x85944171f73967e8);
    }

    #[test]
    fn incremental_matches_one_shot() {
        let mut h = Fnv64::new();
        h.update(b"foo").update(b"bar");
        assert_eq!(h.finish(), fnv1a_64(b"foobar"));
    }

    #[test]
    fn field_prefix_breaks_concatenation_collisions() {
        let mut a = Fnv64::new();
        a.field(b"ab").field(b"c");
        let mut b = Fnv64::new();
        b.field(b"a").field(b"bc");
        assert_ne!(a.finish(), b.finish());
    }
}
