//! Dependency-light utility substrates (the image is offline; see
//! Cargo.toml): JSON parsing, deterministic splittable PRNG, and in-tree
//! property-test / micro-bench harnesses.

pub mod benchkit;
pub mod json;
pub mod prng;
pub mod testkit;
