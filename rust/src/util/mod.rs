//! Dependency-light utility substrates (the image is offline; see
//! Cargo.toml): JSON parsing, deterministic splittable PRNG, in-tree
//! property-test / micro-bench harnesses, and the shared did-you-mean
//! name matcher (config keys, scenario names).

pub mod benchkit;
pub mod hash;
pub mod json;
pub mod prng;
pub mod testkit;

/// Classic Levenshtein distance (tiny inputs: config keys, scenario
/// names). Shared by every "unknown name" rejection in the crate so the
/// did-you-mean behavior cannot drift between the config parser and the
/// scenario registry.
pub fn edit_distance(a: &str, b: &str) -> usize {
    let a: Vec<char> = a.chars().collect();
    let b: Vec<char> = b.chars().collect();
    let mut prev: Vec<usize> = (0..=b.len()).collect();
    let mut cur = vec![0usize; b.len() + 1];
    for (i, &ca) in a.iter().enumerate() {
        cur[0] = i + 1;
        for (j, &cb) in b.iter().enumerate() {
            let sub = prev[j] + usize::from(ca != cb);
            cur[j + 1] = sub.min(prev[j + 1] + 1).min(cur[j] + 1);
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    prev[b.len()]
}

/// The closest candidate within edit distance 3 ("did you mean ...?"),
/// or `None` when nothing is plausibly a typo of `name`.
pub fn closest_name<'a>(
    name: &str,
    candidates: impl IntoIterator<Item = &'a str>,
) -> Option<&'a str> {
    candidates
        .into_iter()
        .map(|c| (c, edit_distance(name, c)))
        .min_by_key(|&(_, d)| d)
        .filter(|&(_, d)| d <= 3)
        .map(|(c, _)| c)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn edit_distance_basics() {
        assert_eq!(edit_distance("b_local", "b_local"), 0);
        assert_eq!(edit_distance("b_locl", "b_local"), 1);
        assert_eq!(edit_distance("", "abc"), 3);
        assert_eq!(edit_distance("kitten", "sitting"), 3);
    }

    #[test]
    fn closest_name_suggests_and_gives_up() {
        assert_eq!(closest_name("drfit", ["synth", "drift", "sparse"]), Some("drift"));
        assert_eq!(closest_name("zzzzqqqq", ["synth", "drift"]), None);
    }
}
