//! In-tree micro-benchmark harness (offline image: no criterion).
//!
//! Provides warmup + repeated timed runs with median/mean/p10/p90 stats and
//! a stable text report format consumed by EXPERIMENTS.md. Each paper
//! table/figure bench under `rust/benches/` uses this via `harness = false`.
//!
//! Benches that should be trackable across PRs additionally push their
//! stats into a [`JsonReport`] and write a `BENCH_<name>.json` file
//! (name, mean/p50 latency, throughput, plus engine traffic counters) —
//! machine-readable so the perf trajectory can be diffed by CI.

use std::time::Instant;

#[derive(Clone, Debug)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub p10_ns: f64,
    pub p90_ns: f64,
    pub min_ns: f64,
}

impl BenchStats {
    /// Mean operations per second (inverse mean latency).
    pub fn throughput_ops_per_sec(&self) -> f64 {
        if self.mean_ns <= 0.0 {
            0.0
        } else {
            1e9 / self.mean_ns
        }
    }

    pub fn report(&self) -> String {
        format!(
            "{:<44} iters={:<5} median={:>12} mean={:>12} p10={:>12} p90={:>12}",
            self.name,
            self.iters,
            fmt_ns(self.median_ns),
            fmt_ns(self.mean_ns),
            fmt_ns(self.p10_ns),
            fmt_ns(self.p90_ns),
        )
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1}ns")
    } else if ns < 1e6 {
        format!("{:.2}us", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2}ms", ns / 1e6)
    } else {
        format!("{:.3}s", ns / 1e9)
    }
}

/// Time `f` for `iters` measured runs after `warmup` unmeasured ones.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchStats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_nanos() as f64);
    }
    stats_from(name, &mut samples)
}

/// Time a closure that itself reports how many inner operations it ran;
/// returns per-op stats. Useful when one run is too fast to time alone.
pub fn bench_batched<F: FnMut() -> usize>(
    name: &str,
    warmup: usize,
    iters: usize,
    mut f: F,
) -> BenchStats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        let n = f().max(1);
        samples.push(t0.elapsed().as_nanos() as f64 / n as f64);
    }
    stats_from(name, &mut samples)
}

fn stats_from(name: &str, samples: &mut [f64]) -> BenchStats {
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = samples.len();
    let pick = |q: f64| samples[((n as f64 - 1.0) * q).round() as usize];
    BenchStats {
        name: name.to_string(),
        iters: n,
        mean_ns: samples.iter().sum::<f64>() / n as f64,
        median_ns: pick(0.5),
        p10_ns: pick(0.1),
        p90_ns: pick(0.9),
        min_ns: samples[0],
    }
}

/// Section header for bench output files.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

/// Machine-readable bench report: accumulates [`BenchStats`] rows and
/// named counters (e.g. the engine's upload/download totals), then writes
/// a stable JSON file. No serde in the offline image — the writer emits
/// the small fixed schema by hand:
///
/// ```json
/// {"benches": [{"name": "...", "iters": 50, "mean_ns": 1.0,
///               "p50_ns": 1.0, "p10_ns": 1.0, "p90_ns": 1.0,
///               "min_ns": 1.0, "throughput_ops_per_sec": 1.0,
///               "plane": "chained"}],
///  "counters": {"engine.uploads": 12.0},
///  "notes": {"plane.policy": "auto"}}
/// ```
///
/// The optional per-record `plane` field tags a scenario with the
/// execution plane it ran on (raw per-kernel microbenches carry none);
/// `notes` holds report-level strings.
#[derive(Clone, Debug, Default)]
pub struct JsonReport {
    records: Vec<(BenchStats, Option<String>)>,
    counters: Vec<(String, f64)>,
    notes: Vec<(String, String)>,
}

impl JsonReport {
    pub fn new() -> JsonReport {
        JsonReport::default()
    }

    /// Record one bench result (call after printing its text report).
    pub fn push(&mut self, stats: &BenchStats) {
        self.records.push((stats.clone(), None));
    }

    /// Record one bench result tagged with the execution plane the
    /// scenario resolved to ("host" | "chained" | "sharded").
    pub fn push_on(&mut self, stats: &BenchStats, plane: &str) {
        self.records.push((stats.clone(), Some(plane.to_string())));
    }

    /// Record a named scalar (engine counters, derived ratios, ...).
    pub fn counter(&mut self, name: &str, value: f64) {
        self.counters.push((name.to_string(), value));
    }

    /// Record a report-level string (e.g. the resolved plane policy).
    pub fn note(&mut self, name: &str, value: &str) {
        self.notes.push((name.to_string(), value.to_string()));
    }

    pub fn to_json(&self) -> String {
        let mut out = String::from("{\n  \"benches\": [");
        for (i, (s, plane)) in self.records.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!(
                "\n    {{\"name\": \"{}\", \"iters\": {}, \"mean_ns\": {:.1}, \
                 \"p50_ns\": {:.1}, \"p10_ns\": {:.1}, \"p90_ns\": {:.1}, \
                 \"min_ns\": {:.1}, \"throughput_ops_per_sec\": {:.3}",
                escape(&s.name),
                s.iters,
                s.mean_ns,
                s.median_ns,
                s.p10_ns,
                s.p90_ns,
                s.min_ns,
                s.throughput_ops_per_sec(),
            ));
            if let Some(p) = plane {
                out.push_str(&format!(", \"plane\": \"{}\"", escape(p)));
            }
            out.push('}');
        }
        out.push_str("\n  ],\n  \"counters\": {");
        for (i, (name, value)) in self.counters.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!("\n    \"{}\": {:.3}", escape(name), value));
        }
        out.push_str("\n  },\n  \"notes\": {");
        for (i, (name, value)) in self.notes.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!("\n    \"{}\": \"{}\"", escape(name), escape(value)));
        }
        out.push_str("\n  }\n}\n");
        out
    }

    /// Write the report; returns the path for the bench's log line.
    pub fn write(&self, path: &str) -> std::io::Result<()> {
        std::fs::write(path, self.to_json())
    }
}

fn escape(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_are_ordered() {
        let s = bench("noop-ish", 2, 32, || {
            std::hint::black_box((0..100).sum::<u64>());
        });
        assert!(s.min_ns <= s.median_ns);
        assert!(s.p10_ns <= s.p90_ns);
        assert_eq!(s.iters, 32);
    }

    #[test]
    fn batched_divides_by_count() {
        let s = bench_batched("batch", 1, 8, || {
            std::hint::black_box((0..1000).sum::<u64>());
            1000
        });
        assert!(s.median_ns < 1e6);
    }

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(500.0).ends_with("ns"));
        assert!(fmt_ns(5.0e4).ends_with("us"));
        assert!(fmt_ns(5.0e7).ends_with("ms"));
        assert!(fmt_ns(5.0e9).ends_with('s'));
    }

    #[test]
    fn json_report_round_trips() {
        let mut report = JsonReport::new();
        let s = bench("noop \"quoted\"", 1, 8, || {
            std::hint::black_box((0..10).sum::<u64>());
        });
        report.push(&s);
        report.counter("engine.uploads", 42.0);
        let parsed = crate::util::json::Json::parse(&report.to_json()).unwrap();
        let benches = parsed.get("benches").and_then(crate::util::json::Json::as_arr).unwrap();
        assert_eq!(benches.len(), 1);
        assert_eq!(benches[0].get("name").and_then(crate::util::json::Json::as_str),
            Some("noop \"quoted\""));
        assert_eq!(benches[0].get("iters").and_then(crate::util::json::Json::as_usize), Some(8));
        assert!(benches[0]
            .get("throughput_ops_per_sec")
            .and_then(crate::util::json::Json::as_f64)
            .unwrap()
            > 0.0);
        let up = parsed.get("counters").and_then(|c| c.get("engine.uploads")).unwrap();
        assert_eq!(up.as_f64(), Some(42.0));
    }

    #[test]
    fn json_report_plane_tags_and_notes() {
        let mut report = JsonReport::new();
        let s = bench("tagged", 1, 4, || {
            std::hint::black_box((0..10).sum::<u64>());
        });
        report.push_on(&s, "chained");
        report.push(&s);
        report.note("plane.policy", "auto");
        let parsed = crate::util::json::Json::parse(&report.to_json()).unwrap();
        let benches = parsed.get("benches").and_then(crate::util::json::Json::as_arr).unwrap();
        assert_eq!(
            benches[0].get("plane").and_then(crate::util::json::Json::as_str),
            Some("chained")
        );
        assert!(benches[1].get("plane").is_none(), "untagged records carry no plane field");
        assert_eq!(
            parsed
                .get("notes")
                .and_then(|n| n.get("plane.policy"))
                .and_then(crate::util::json::Json::as_str),
            Some("auto")
        );
    }
}
