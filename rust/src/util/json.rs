//! Minimal JSON parser for the artifact manifest (offline image: no serde).
//!
//! Supports the full JSON value grammar the manifest uses: objects, arrays,
//! strings (with escapes), numbers, booleans, null. Not a general-purpose
//! streaming parser — the manifest is a few KB.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    /// Object field access helper.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|m| m.get(key))
    }
}

#[derive(Debug, Clone)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek().ok_or_else(|| self.err("unexpected end"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.literal("true", Json::Bool(true)),
            b'f' => self.literal("false", Json::Bool(false)),
            b'n' => self.literal("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => Err(self.err(&format!("unexpected byte '{}'", c as char))),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump().ok_or_else(|| self.err("unterminated string"))? {
                b'"' => return Ok(out),
                b'\\' => match self.bump().ok_or_else(|| self.err("bad escape"))? {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'b' => out.push('\u{8}'),
                    b'f' => out.push('\u{c}'),
                    b'n' => out.push('\n'),
                    b'r' => out.push('\r'),
                    b't' => out.push('\t'),
                    b'u' => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u"))? as char;
                            code = code * 16
                                + c.to_digit(16).ok_or_else(|| self.err("bad hex"))?;
                        }
                        out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("unknown escape")),
                },
                b => {
                    // copy raw UTF-8 bytes through
                    let start = self.pos - 1;
                    let mut end = self.pos;
                    if b >= 0x80 {
                        while self.peek().map_or(false, |c| c >= 0x80) {
                            self.pos += 1;
                            end += 1;
                        }
                    }
                    out.push_str(
                        std::str::from_utf8(&self.bytes[start..end])
                            .map_err(|_| self.err("invalid utf8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')) {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }
}

/// Tiny JSON writer for metrics output (objects/arrays built by hand).
pub fn escape_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_document() {
        let text = r#"{
          "block": 256,
          "dims": [64, 128],
          "artifacts": [
            {"name": "grad_sq_d64", "file": "grad_sq_d64.hlo.txt",
             "kind": "grad", "loss": "sq", "d": 64, "block": 256,
             "arg_shapes": [[256, 64], [256], [256], [64]],
             "outputs": ["grad_sum", "loss_sum", "count"],
             "sha256": "abc"}
          ]
        }"#;
        let v = Json::parse(text).unwrap();
        assert_eq!(v.get("block").unwrap().as_usize(), Some(256));
        let arts = v.get("artifacts").unwrap().as_arr().unwrap();
        assert_eq!(arts.len(), 1);
        assert_eq!(arts[0].get("name").unwrap().as_str(), Some("grad_sq_d64"));
        let shapes = arts[0].get("arg_shapes").unwrap().as_arr().unwrap();
        assert_eq!(shapes[0].as_arr().unwrap().len(), 2);
    }

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("true").unwrap().as_bool(), Some(true));
        assert_eq!(Json::parse("1").unwrap().as_bool(), None);
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse(r#""a\nb""#).unwrap(), Json::Str("a\nb".into()));
        assert_eq!(Json::parse(r#""A""#).unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("07a").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("{} trailing").is_err());
    }

    #[test]
    fn nested_structures() {
        let v = Json::parse(r#"{"a": {"b": [1, [2, {"c": null}]]}}"#).unwrap();
        let b = v.get("a").unwrap().get("b").unwrap().as_arr().unwrap();
        assert_eq!(b[0].as_f64(), Some(1.0));
    }

    #[test]
    fn escape_round_trip() {
        let s = "line\n\"quoted\"\tend";
        let esc = escape_str(s);
        assert_eq!(Json::parse(&esc).unwrap(), Json::Str(s.into()));
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
    }
}
