//! Bench: runtime hot-path microbenchmarks (§Perf of EXPERIMENTS.md).
//!
//! Measures the per-call latency of every engine dispatch kind, the block
//! packing + literal conversion cost, a collective round, and one full
//! MP-DSVRG outer step — the numbers the performance pass optimizes.

use mbprox::accounting::ClusterMeter;
use mbprox::comm::{netmodel::NetModel, Network};
use mbprox::coordinator::Runner;
use mbprox::data::blocks::pack_block;
use mbprox::data::synth::{SynthSpec, SynthStream};
use mbprox::data::{Loss, SampleStream};
use mbprox::runtime::exec::BlockLits;
use mbprox::util::benchkit::{bench, section};

fn main() {
    let mut runner = Runner::from_env().expect("run `make artifacts` first");
    runner.engine.warmup_all().expect("warmup");
    let engine = &mut runner.engine;

    section("engine dispatch latency (interpret-mode Pallas on CPU PJRT)");
    for (loss, d) in [(Loss::Squared, 64usize), (Loss::Squared, 128), (Loss::Logistic, 64)] {
        let spec = match loss {
            Loss::Squared => SynthSpec::least_squares(d),
            Loss::Logistic => SynthSpec::logistic(d),
        };
        let mut stream = SynthStream::new(spec, 1);
        let samples = stream.draw_many(256);
        let block = pack_block(&samples, d);
        let lits = BlockLits::from_block(engine, &block).unwrap();
        let w = vec![0.01f32; d];

        let s = bench(&format!("grad_{}_d{d} (256 rows)", loss.tag()), 3, 50, || {
            engine.grad_block(loss, &lits, &w).unwrap();
        });
        println!("{}", s.report());

        if loss == Loss::Squared {
            let s = bench(&format!("nm_sq_d{d} (256 rows)"), 3, 50, || {
                engine.nm_block(&lits, &w).unwrap();
            });
            println!("{}", s.report());
        }

        let z = vec![0.0f32; d];
        let s = bench(&format!("svrg_{}_d{d} (256-row sweep)", loss.tag()), 3, 20, || {
            engine
                .svrg_block(loss, &lits, &w, &z, &z, &z, 0.5, 0.05)
                .unwrap();
        });
        println!("{}", s.report());
    }

    section("host-side costs");
    {
        let mut stream = SynthStream::new(SynthSpec::least_squares(64), 2);
        let samples = stream.draw_many(256);
        let s = bench("pack_block 256x64", 3, 200, || {
            std::hint::black_box(pack_block(&samples, 64));
        });
        println!("{}", s.report());
        let block = pack_block(&samples, 64);
        let s = bench("BlockLits upload 256x64", 3, 200, || {
            std::hint::black_box(BlockLits::from_block(engine, &block).unwrap());
        });
        println!("{}", s.report());
    }

    section("collective round (m=8, d=64)");
    {
        let mut net = Network::new(8, NetModel::default());
        let mut meter = ClusterMeter::new(8);
        let mut locals: Vec<Vec<f32>> = (0..8).map(|i| vec![i as f32; 64]).collect();
        let s = bench("all_reduce_avg m=8 d=64", 10, 500, || {
            net.all_reduce_avg(&mut meter, &mut locals);
        });
        println!("{}", s.report());
    }

    section("end-to-end: one MP-DSVRG outer step (m=4, b=256, d=64)");
    {
        use mbprox::algos::mbprox::MinibatchProx;
        use mbprox::algos::solvers::dsvrg::DsvrgSolver;
        use mbprox::algos::{Method, RunContext};
        use mbprox::objective::Evaluator;

        let root = SynthStream::new(SynthSpec::least_squares(64), 3);
        let mut eval_stream = root.fork_stream(99);
        let eval_samples = eval_stream.draw_many(512);
        let s = bench("mp-dsvrg outer step (T=1, K=5)", 2, 20, || {
            let streams: Vec<Box<dyn SampleStream>> = (0..4)
                .map(|i| Box::new(root.fork_stream(i as u64)) as Box<dyn SampleStream>)
                .collect();
            let evaluator =
                Evaluator::new(engine, 64, Loss::Squared, &eval_samples).unwrap();
            let mut ctx = RunContext {
                engine,
                net: Network::new(4, NetModel::default()),
                meter: ClusterMeter::new(4),
                loss: Loss::Squared,
                d: 64,
                streams,
                evaluator: Some(evaluator),
                eval_every: 0,
            };
            let mut method =
                MinibatchProx::new("bench", 256, 1, 0.5, DsvrgSolver::new(5, 1, 0.05));
            method.run(&mut ctx).unwrap();
        });
        println!("{}", s.report());
    }

    section("engine cumulative stats");
    println!(
        "executions={} mean_execute={}",
        engine.stats.executions,
        mbprox::util::benchkit::fmt_ns(engine.mean_execute_ns())
    );
}
